"""AOT lowering: jax graphs -> HLO text artifacts + manifest for the rust runtime.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
``artifacts`` target).  Python never runs again after this: the rust
coordinator loads ``artifacts/*.hlo.txt`` through PJRT and executes them on
the request path.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly.  Lowering uses ``return_tuple=True``
so the rust side always unwraps a tuple.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, quantize

DTYPE_NAMES = {
    np.dtype(np.float32): "f32",
    np.dtype(np.float16): "f16",
    np.dtype(np.int8): "i8",
    np.dtype(np.int32): "i32",
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(name: str, arr_spec: jax.ShapeDtypeStruct) -> dict:
    return {
        "name": name,
        "dtype": DTYPE_NAMES[np.dtype(arr_spec.dtype)],
        "shape": list(arr_spec.shape),
    }


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


# ---------------------------------------------------------------------------
# GEMM artifacts (kernel correctness + quickstart)
# ---------------------------------------------------------------------------

# (M, N, K) shapes lowered for the rust runtime.  These are correctness /
# example shapes; the paper-scale Figure 2/3 sweep runs on the simulator.
GEMM_SHAPES = [
    (16, 256, 512),
    (16, 512, 2048),
    (16, 2048, 2048),
    (64, 1024, 4096),
]

STRATEGIES = ("splitk", "dp", "fused", "fp16")


def _gemm_fn(strategy: str, cfg: configs.BlockConfig):
    """Build the jitted artifact body for one (strategy, cfg).

    Boundary dtypes are rust-friendly: activations f32 (cast to f16
    inside), packed weights i8, scale/zero f32, output f32.
    """

    def splitk(a, packed, scales, zeros):
        c = model.w4a16_matmul_splitk(a.astype(np.float16), packed, scales, zeros, cfg)
        return (c.astype(np.float32),)

    def dp(a, packed, scales, zeros):
        c = model.w4a16_matmul_dp(a.astype(np.float16), packed, scales, zeros, cfg)
        return (c.astype(np.float32),)

    def fused(a, packed, scales, zeros):
        c = model.w4a16_matmul_fused(a.astype(np.float16), packed, scales, zeros, cfg)
        return (c.astype(np.float32),)

    def fp16(a, b):
        c = model.fp16_matmul(a.astype(np.float16), b.astype(np.float16), cfg)
        return (c.astype(np.float32),)

    return {"splitk": splitk, "dp": dp, "fused": fused, "fp16": fp16}[strategy]


def build_gemm_artifacts(out_dir: str) -> list[dict]:
    entries = []
    for (m, n, k) in GEMM_SHAPES:
        cfg = configs.select_blocks(m, n, k)
        for strategy in STRATEGIES:
            name = f"{strategy}_m{m}_n{n}_k{k}"
            if strategy == "fp16":
                in_specs = [
                    ("a", _sds((m, k), np.float32)),
                    ("b", _sds((k, n), np.float32)),
                ]
            else:
                in_specs = [
                    ("a", _sds((m, k), np.float32)),
                    ("packed", _sds((k // 2, n), np.int8)),
                    ("scales", _sds((k // cfg.group, n), np.float32)),
                    ("zeros", _sds((k // cfg.group, n), np.float32)),
                ]
            fn = _gemm_fn(strategy, cfg)
            t0 = time.time()
            lowered = jax.jit(fn).lower(*[s for _, s in in_specs])
            text = to_hlo_text(lowered)
            path = f"{name}.hlo.txt"
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            entries.append(
                {
                    "name": name,
                    "kind": "gemm",
                    "path": path,
                    "strategy": strategy,
                    "m": m,
                    "n": n,
                    "k": k,
                    "group": cfg.group,
                    "splits": cfg.splits if strategy == "splitk" else 1,
                    "blocks": {"bm": cfg.bm, "bn": cfg.bn, "bk": cfg.bk},
                    "inputs": [_spec(nm, s) for nm, s in in_specs],
                    "outputs": [_spec("c", _sds((m, n), np.float32))],
                }
            )
            print(f"  lowered {name} ({len(text)} chars, {time.time()-t0:.1f}s)")
    return entries


# ---------------------------------------------------------------------------
# Decode-model artifacts (+ weight blobs)
# ---------------------------------------------------------------------------

DECODE_VARIANTS = [
    ("tiny", model.TINY, (1, 4), 0),
    ("small100m", model.SMALL_100M, (1, 2, 4, 8), 1),
]


def _write_weights(out_dir: str, name: str, params: dict[str, np.ndarray]) -> dict:
    """Concatenate weight tensors into one blob with an offset index."""
    path = f"{name}_weights.bin"
    index = []
    offset = 0
    with open(os.path.join(out_dir, path), "wb") as f:
        for key, arr in params.items():
            data = np.ascontiguousarray(arr).tobytes()
            index.append(
                {
                    "name": key,
                    "dtype": DTYPE_NAMES[np.dtype(arr.dtype)],
                    "shape": list(arr.shape),
                    "offset": offset,
                    "nbytes": len(data),
                }
            )
            f.write(data)
            offset += len(data)
    return {"path": path, "tensors": index, "total_bytes": offset}


def build_decode_artifacts(out_dir: str) -> list[dict]:
    entries = []
    for name, cfg, batch_sizes, seed in DECODE_VARIANTS:
        params = model.init_decode_params(cfg, seed=seed)
        weights = _write_weights(out_dir, f"decode_{name}", params)
        param_specs = {k: _sds(v.shape, v.dtype) for k, v in params.items()}

        for b in batch_sizes:
            art = f"decode_{name}_b{b}"

            def step(tokens, positions, cache, **kw):
                return model.decode_step(kw, cfg, tokens, positions, cache)

            io_specs = [
                ("token_ids", _sds((b,), np.int32)),
                ("positions", _sds((b,), np.int32)),
                (
                    "kv_cache",
                    _sds((cfg.layers, 2, b, cfg.max_seq, cfg.hidden), np.float32),
                ),
            ]
            t0 = time.time()
            lowered = jax.jit(step).lower(
                *[s for _, s in io_specs], **param_specs
            )
            text = to_hlo_text(lowered)
            path = f"{art}.hlo.txt"
            with open(os.path.join(out_dir, path), "w") as f:
                f.write(text)
            # Keyword args are passed to XLA sorted by name after the
            # positional ones; record the exact order for the rust loader.
            kw_order = sorted(params.keys())
            entries.append(
                {
                    "name": art,
                    "kind": "decode",
                    "path": path,
                    "model": name,
                    "batch": b,
                    "config": {
                        "vocab": cfg.vocab,
                        "hidden": cfg.hidden,
                        "layers": cfg.layers,
                        "heads": cfg.heads,
                        "ffn": cfg.ffn,
                        "max_seq": cfg.max_seq,
                        "group": cfg.group,
                        "params": cfg.param_count(),
                    },
                    "weights": weights,
                    "inputs": [_spec(nm, s) for nm, s in io_specs]
                    + [_spec(k, param_specs[k]) for k in kw_order],
                    "outputs": [
                        _spec("logits", _sds((b, cfg.vocab), np.float32)),
                        _spec("next_token", _sds((b,), np.int32)),
                        _spec(
                            "kv_cache",
                            _sds(
                                (cfg.layers, 2, b, cfg.max_seq, cfg.hidden),
                                np.float32,
                            ),
                        ),
                    ],
                }
            )
            print(f"  lowered {art} ({len(text)} chars, {time.time()-t0:.1f}s)")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-decode", action="store_true",
                    help="only lower the GEMM artifacts (fast dev loop)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    print("[aot] lowering GEMM artifacts")
    entries = build_gemm_artifacts(args.out)
    if not args.skip_decode:
        print("[aot] lowering decode artifacts")
        entries += build_decode_artifacts(args.out)

    manifest = {
        "version": 1,
        "artifacts": entries,
        "paper_shapes": [
            {"model": s.model, "n": s.n, "k": s.k} for s in configs.PAPER_SHAPES
        ],
        "batch_sizes": list(configs.PAPER_BATCH_SIZES),
        "group": configs.DEFAULT_GROUP,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(entries)} artifacts + manifest.json")


if __name__ == "__main__":
    main()
