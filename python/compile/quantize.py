"""Group-wise INT4 weight quantization and nibble packing.

Host-side (build-time) utilities shared by the kernels, the AOT pipeline and
the tests.  The storage convention matches the rust side
(``rust/src/quant``):

* Weights ``W`` are ``K x N`` (activations ``A`` are ``M x K``; ``C = A @ W``).
* Quantization is **group-wise along K** with group size ``g`` (default 128):
  every column ``n`` and K-group ``t`` share one ``(scale, zero)`` pair, i.e.
  ``scales``/``zeros`` have shape ``(K // g, N)``.
* Quantized codes are **unsigned** nibbles ``q in [0, 15]`` with an affine
  mapping ``w = s * (q - z)`` (uniform affine quantization, eq. (1)+(2) of
  the paper).  Symmetric quantization is the special case ``z = 8``.
* Packing: two codes per byte along K. Byte ``b[k, n]`` holds
  ``q[2k, n]`` in the **low** nibble and ``q[2k + 1, n]`` in the **high**
  nibble, giving a ``(K // 2, N)`` int8 array.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

DEFAULT_GROUP = 128
QMIN = 0
QMAX = 15


@dataclasses.dataclass(frozen=True)
class QuantizedWeight:
    """A K x N weight matrix quantized to packed INT4 + group metadata."""

    packed: np.ndarray  # int8 (K//2, N), two nibbles per byte along K
    scales: np.ndarray  # float32 (K//g, N)
    zeros: np.ndarray  # float32 (K//g, N), in code units (0..15)
    group: int
    k: int
    n: int

    @property
    def packed_bytes(self) -> int:
        return self.packed.size

    def dequantize(self) -> np.ndarray:
        """Reference host dequantization back to float32 (K, N)."""
        q = unpack_int4(self.packed, self.k)
        s = np.repeat(self.scales, self.group, axis=0)
        z = np.repeat(self.zeros, self.group, axis=0)
        return (s * (q.astype(np.float32) - z)).astype(np.float32)


def quantize_groupwise(
    w: np.ndarray, group: int = DEFAULT_GROUP, symmetric: bool = False
) -> QuantizedWeight:
    """Quantize a float (K, N) matrix to group-wise INT4.

    ``symmetric=True`` pins the zero-point to the mid-code 8 and uses a
    scale derived from ``max |w|`` per group; otherwise an asymmetric
    min/max affine fit is used.
    """
    w = np.asarray(w, dtype=np.float32)
    k, n = w.shape
    if k % group != 0:
        raise ValueError(f"K={k} not divisible by group={group}")
    if k % 2 != 0:
        raise ValueError(f"K={k} must be even for nibble packing")
    groups = k // group
    wg = w.reshape(groups, group, n)

    if symmetric:
        amax = np.abs(wg).max(axis=1)  # (groups, n)
        scales = np.where(amax == 0.0, 1.0, amax / 7.0).astype(np.float32)
        zeros = np.full((groups, n), 8.0, dtype=np.float32)
    else:
        lo = wg.min(axis=1)
        hi = wg.max(axis=1)
        span = hi - lo
        # Degenerate (constant) groups fall back to symmetric parameters so
        # the constant value stays exactly representable.
        degenerate = span == 0.0
        sym_scale = np.where(np.abs(lo) == 0.0, 1.0, np.abs(lo) / 7.0)
        scales = np.where(degenerate, sym_scale, span / float(QMAX)).astype(np.float32)
        zeros = np.where(
            degenerate, 8.0, np.clip(np.round(-lo / scales), QMIN, QMAX)
        ).astype(np.float32)

    q = np.round(wg / scales[:, None, :] + zeros[:, None, :])
    q = np.clip(q, QMIN, QMAX).astype(np.uint8).reshape(k, n)
    return QuantizedWeight(
        packed=pack_int4(q), scales=scales, zeros=zeros, group=group, k=k, n=n
    )


def pack_int4(q: np.ndarray) -> np.ndarray:
    """Pack unsigned nibbles (K, N) uint8 -> (K//2, N) int8.

    Row ``2k`` goes to the low nibble, row ``2k+1`` to the high nibble.
    """
    q = np.asarray(q, dtype=np.uint8)
    if q.ndim != 2 or q.shape[0] % 2 != 0:
        raise ValueError(f"bad shape for packing: {q.shape}")
    if q.max(initial=0) > QMAX:
        raise ValueError("nibble out of range")
    lo = q[0::2, :]
    hi = q[1::2, :]
    return ((hi << 4) | lo).astype(np.int8)


def unpack_int4(packed: np.ndarray, k: int) -> np.ndarray:
    """Unpack (K//2, N) int8 -> (K, N) uint8 codes."""
    p = np.asarray(packed).view(np.uint8) if packed.dtype == np.int8 else np.asarray(packed, dtype=np.uint8)
    if p.shape[0] * 2 != k:
        raise ValueError(f"packed rows {p.shape[0]} inconsistent with K={k}")
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    out = np.empty((k, p.shape[1]), dtype=np.uint8)
    out[0::2, :] = lo
    out[1::2, :] = hi
    return out


def unpack_int4_jnp(packed: jnp.ndarray, k: int) -> jnp.ndarray:
    """jnp twin of :func:`unpack_int4` (used in traced code / ref oracle)."""
    p = packed.astype(jnp.uint8)
    lo = p & 0xF
    hi = (p >> 4) & 0xF
    stacked = jnp.stack([lo, hi], axis=1)  # (K//2, 2, N)
    return stacked.reshape(k, p.shape[1])


def random_weight(k: int, n: int, seed: int = 0, scale: float = 0.05) -> np.ndarray:
    """Deterministic synthetic weight matrix with LLM-like magnitude."""
    rng = np.random.default_rng(seed)
    return (rng.standard_normal((k, n)) * scale).astype(np.float32)
