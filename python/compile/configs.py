"""Shape tables and tiling configuration shared by the AOT pipeline, tests
and benchmarks.

The GEMM shape table mirrors the paper's §4.1 evaluation: decode-phase
weight shapes drawn from OpenPangu, DeepSeek-R1, GLM-4.5 and LLaMA-3.2,
plus the batch-size (M) sweep.  Weights are ``K x N`` (``C = A @ W`` with
``A : M x K``), so "K >> N" is the down-projection / small-output regime the
paper highlights for LLM decoding.

The same table is duplicated on the rust side (``rust/src/model/llm.rs``);
`python/tests/test_configs.py` and `rust/tests/schedules.rs` keep the two in
sync through the manifest.
"""

from __future__ import annotations

import dataclasses

DEFAULT_GROUP = 128

# Cube-core granularity: MMAD operates on 16x16x16 FP16 tiles, so every
# dimension fed to Phase 2 is padded to a multiple of 16 (the paper notes
# small batches are padded, which is why exec time is flat in M).
CUBE_TILE = 16


@dataclasses.dataclass(frozen=True)
class GemmShape:
    """One (model, N, K) row of the paper's Figure 2/3 sweep."""

    model: str
    n: int
    k: int

    @property
    def tag(self) -> str:
        return f"{self.model}-n{self.n}-k{self.k}"

    @property
    def k_dominant(self) -> bool:
        """The paper's 'K >> N' regime (where Split-K is claimed to win)."""
        return self.k >= 2 * self.n


# Decode GEMM shapes per model family (hidden sizes from the public configs;
# the paper does not list its exact table, so we take the canonical
# projection shapes of each named model's decode path).
PAPER_SHAPES: tuple[GemmShape, ...] = (
    # LLaMA-3.2-1B: hidden 2048, ffn 8192
    GemmShape("llama32", 2048, 2048),
    GemmShape("llama32", 8192, 2048),
    GemmShape("llama32", 2048, 8192),
    # GLM-4.5 (dense trunk): hidden 5120, ffn 12288
    GemmShape("glm45", 5120, 5120),
    GemmShape("glm45", 12288, 5120),
    GemmShape("glm45", 5120, 12288),
    # DeepSeek-R1: hidden 7168; MoE expert inner 2048; kv-lora 1536
    GemmShape("deepseek", 7168, 7168),
    GemmShape("deepseek", 2048, 7168),
    GemmShape("deepseek", 7168, 2048),
    GemmShape("deepseek", 1536, 7168),
    # OpenPangu (dense): hidden 7680, low-rank projection 1536
    GemmShape("openpangu", 7680, 7680),
    GemmShape("openpangu", 1536, 7680),
)

# Batch sizes (M) swept in Figures 2 and 3.
PAPER_BATCH_SIZES: tuple[int, ...] = (1, 2, 4, 8, 16, 32, 64)


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """Tiling for the three-phase pipeline (the paper's ``[m, n, k]``)."""

    bm: int
    bn: int
    bk: int
    splits: int
    group: int = DEFAULT_GROUP

    def validate(self, m: int, n: int, k: int) -> None:
        if k % self.splits != 0:
            raise ValueError(f"splits={self.splits} !| K={k}")
        ks = k // self.splits
        if m % self.bm or n % self.bn or ks % self.bk:
            raise ValueError(
                f"blocks ({self.bm},{self.bn},{self.bk}) must tile "
                f"({m},{n},{ks})"
            )
        if self.bk % self.group:
            raise ValueError(f"bk={self.bk} !| group={self.group}")


def pad_to(x: int, mult: int) -> int:
    """Round ``x`` up to a multiple of ``mult`` (cube-tile padding)."""
    return ((x + mult - 1) // mult) * mult


def select_blocks(m: int, n: int, k: int, *, group: int = DEFAULT_GROUP,
                  splits: int | None = None) -> BlockConfig:
    """Pick a legal block configuration for padded (m, n, k).

    Mirrors the rust tiler (``rust/src/kernels/tiling.rs``): K blocks are a
    multiple of the quantization group (so dequant tiles map to whole scale
    rows), M blocks cover the whole padded batch (decode M is tiny), and N
    blocks target the L0B capacity.
    """
    if splits is None:
        splits = default_splits(n, k)
    m_pad = pad_to(m, CUBE_TILE)
    bm = min(m_pad, 64)
    while m_pad % bm:
        bm //= 2
    bk = group
    ks = k // splits
    if ks % bk:
        raise ValueError(f"K/S={ks} not a multiple of group={group}")
    bn = 512
    while n % bn:
        bn //= 2
    if bn < CUBE_TILE:
        raise ValueError(f"N={n} not a multiple of the cube tile")
    return BlockConfig(bm=bm, bn=bn, bk=bk, splits=splits, group=group)


def default_splits(n: int, k: int, *, num_cores: int = 32,
                   group: int = DEFAULT_GROUP) -> int:
    """Heuristic split factor: enough K-splits to occupy all cube cores.

    Data-parallel work items = ceil(N / bn); the Split-K factor tops up the
    grid until ``splits * n_tiles >= num_cores`` without exceeding
    K / group (each split must hold at least one quantization group).
    """
    bn = 512
    while n % bn:
        bn //= 2
    n_tiles = max(1, n // bn)
    s = 1
    while s * n_tiles < num_cores and (k // (2 * s)) % group == 0 and k // (2 * s) >= group:
        s *= 2
    return s
