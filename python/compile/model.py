"""L2 — JAX compute graphs composing the Pallas kernels.

Two families live here:

1. **Matmul pipelines** — the paper's Algorithm 1 as a jax function: three
   separate ``pallas_call`` phases (dequant -> Split-K MMAD -> reduce) with
   the FP16 workspace and FP32 split buffers materializing between them,
   plus the data-parallel, fused and native-FP16 comparators.
2. **Decode model** — a ~100M-parameter decoder-only transformer whose every
   linear layer runs through the W4A16 pipeline; one decode step (with KV
   cache) is AOT-lowered for the rust serving runtime.

Everything here is traced/lowered at build time only; the rust coordinator
executes the resulting HLO artifacts through PJRT.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import configs, quantize
from .kernels import dequant as kdequant
from .kernels import fp16_gemm as kfp16
from .kernels import fused_w4a16 as kfused
from .kernels import reduce as kreduce
from .kernels import splitk_matmul as ksplitk

# ---------------------------------------------------------------------------
# Matmul pipelines (Algorithm 1 and its comparators)
# ---------------------------------------------------------------------------


def w4a16_matmul_splitk(a, packed, scales, zeros, cfg: configs.BlockConfig):
    """Three-phase Split-K W4A16 matmul (Algorithm 1).

    a: (M, K) fp16-representable; packed: int8 (K//2, N);
    scales/zeros: f32 (K//group, N).  Returns (M, N) f16.
    """
    m, k = a.shape
    n = packed.shape[1]
    cfg.validate(m, n, k)
    # Phase 1 (AIV): dequantize to the FP16 global-memory workspace.
    workspace = kdequant.dequant(
        packed, scales, zeros, k=k, group=cfg.group, bk=cfg.bk, bn=cfg.bn
    )
    # Phase 2 (AIC): Split-K MMAD into FP32 split buffers.
    partials = ksplitk.splitk_matmul(
        a.astype(jnp.float16), workspace,
        splits=cfg.splits, bm=cfg.bm, bn=cfg.bn, bk=cfg.bk,
    )
    # Phase 3 (AIV): reduce the splits, cast to FP16.
    return kreduce.reduce_splits(partials, bm=cfg.bm, bn=cfg.bn)


def w4a16_matmul_dp(a, packed, scales, zeros, cfg: configs.BlockConfig):
    """Data-parallel comparator: dequant phase + single-pass GEMM (S = 1)."""
    m, k = a.shape
    n = packed.shape[1]
    workspace = kdequant.dequant(
        packed, scales, zeros, k=k, group=cfg.group, bk=cfg.bk, bn=cfg.bn
    )
    return kfp16.fp16_matmul(
        a.astype(jnp.float16), workspace, bm=cfg.bm, bn=cfg.bn, bk=cfg.bk
    )


def w4a16_matmul_fused(a, packed, scales, zeros, cfg: configs.BlockConfig):
    """Future-work ablation: dequant fused into the MMAD kernel (no workspace)."""
    return kfused.fused_w4a16_matmul(
        a.astype(jnp.float16), packed, scales, zeros,
        group=cfg.group, bm=cfg.bm, bn=cfg.bn,
    )


def fp16_matmul(a, b, cfg: configs.BlockConfig):
    """Native FP16 x FP16 comparator (the 'PyTorch' baseline of Figure 3)."""
    return kfp16.fp16_matmul(
        a.astype(jnp.float16), b.astype(jnp.float16),
        bm=cfg.bm, bn=cfg.bn, bk=cfg.bk,
    )


def w4a16_linear(x, packed, scales, zeros, *, group: int = configs.DEFAULT_GROUP):
    """W4A16 linear layer for model code: pads M to the cube tile, picks
    blocks automatically, runs the Split-K pipeline and slices the pad off."""
    m, k = x.shape
    n = packed.shape[1]
    m_pad = configs.pad_to(m, configs.CUBE_TILE)
    cfg = configs.select_blocks(m_pad, n, k, group=group)
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    out = w4a16_matmul_splitk(x, packed, scales, zeros, cfg)
    return out[:m]


# ---------------------------------------------------------------------------
# Decode model (~100M parameters, every linear through W4A16)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer geometry (all dims multiples of the group)."""

    vocab: int = 8192
    hidden: int = 768
    layers: int = 12
    heads: int = 12
    ffn: int = 3072
    max_seq: int = 64
    group: int = configs.DEFAULT_GROUP

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def param_count(self) -> int:
        """Approximate (unquantized) parameter count."""
        per_layer = 4 * self.hidden * self.hidden + 2 * self.hidden * self.ffn
        return self.layers * per_layer + 2 * self.vocab * self.hidden


TINY = ModelConfig(vocab=512, hidden=256, layers=2, heads=4, ffn=512, max_seq=32)
SMALL_100M = ModelConfig()


def _quant_linear_params(rng, k: int, n: int, group: int, name: str):
    w = (rng.standard_normal((k, n)) * (0.8 / np.sqrt(k))).astype(np.float32)
    qw = quantize.quantize_groupwise(w, group=group)
    return {
        f"{name}.packed": qw.packed,
        f"{name}.scales": qw.scales,
        f"{name}.zeros": qw.zeros,
    }


def init_decode_params(cfg: ModelConfig, seed: int = 0) -> dict[str, np.ndarray]:
    """Synthetic-but-deterministic quantized decode weights (host arrays).

    The returned dict ordering is the canonical artifact input order; the
    rust side reads the same ordering from the manifest.
    """
    rng = np.random.default_rng(seed)
    params: dict[str, np.ndarray] = {}
    params["embed"] = (
        rng.standard_normal((cfg.vocab, cfg.hidden)) * 0.02
    ).astype(np.float32)
    for layer in range(cfg.layers):
        pre = f"layer{layer}"
        params[f"{pre}.ln1"] = np.ones(cfg.hidden, dtype=np.float32)
        params.update(
            _quant_linear_params(rng, cfg.hidden, 3 * cfg.hidden, cfg.group, f"{pre}.qkv")
        )
        params.update(
            _quant_linear_params(rng, cfg.hidden, cfg.hidden, cfg.group, f"{pre}.out")
        )
        params[f"{pre}.ln2"] = np.ones(cfg.hidden, dtype=np.float32)
        params.update(
            _quant_linear_params(rng, cfg.hidden, cfg.ffn, cfg.group, f"{pre}.up")
        )
        params.update(
            _quant_linear_params(rng, cfg.ffn, cfg.hidden, cfg.group, f"{pre}.down")
        )
    params["ln_f"] = np.ones(cfg.hidden, dtype=np.float32)
    params.update(
        _quant_linear_params(rng, cfg.hidden, cfg.vocab, cfg.group, "lm_head")
    )
    return params


def _rmsnorm(x, gamma):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + 1e-5) * gamma).astype(x.dtype)


def _linear(params: dict[str, Any], name: str, x, group: int):
    return w4a16_linear(
        x,
        params[f"{name}.packed"],
        params[f"{name}.scales"],
        params[f"{name}.zeros"],
        group=group,
    )


def decode_step(params: dict[str, Any], cfg: ModelConfig, token_ids, positions,
                kv_cache):
    """One batched decode step.

    token_ids: i32 (B,); positions: i32 (B,) — write index per sequence;
    kv_cache: f32 (layers, 2, B, max_seq, hidden).
    Returns (logits f32 (B, vocab), next_token i32 (B,), new_cache).
    """
    b = token_ids.shape[0]
    x = params["embed"].astype(jnp.float16)[token_ids]  # (B, H)
    pos_axis = jnp.arange(cfg.max_seq)[None, :]  # (1, T)
    # valid[t] for key positions t <= current position
    mask = (pos_axis <= positions[:, None]).astype(jnp.float32)  # (B, T)
    new_cache = kv_cache

    for layer in range(cfg.layers):
        pre = f"layer{layer}"
        h = _rmsnorm(x, params[f"{pre}.ln1"])
        qkv = _linear(params, f"{pre}.qkv", h, cfg.group)  # (B, 3H)
        q, k_new, v_new = jnp.split(qkv.astype(jnp.float32), 3, axis=-1)

        # Scatter this step's K/V into the cache at each sequence's position.
        k_cache = new_cache[layer, 0]  # (B, T, H)
        v_cache = new_cache[layer, 1]
        onehot = (pos_axis == positions[:, None]).astype(jnp.float32)  # (B, T)
        k_cache = k_cache * (1.0 - onehot[..., None]) + onehot[..., None] * k_new[:, None, :]
        v_cache = v_cache * (1.0 - onehot[..., None]) + onehot[..., None] * v_new[:, None, :]
        new_cache = new_cache.at[layer, 0].set(k_cache)
        new_cache = new_cache.at[layer, 1].set(v_cache)

        # Attention over the cache (per head).
        hd = cfg.head_dim
        qh = q.reshape(b, cfg.heads, hd)
        kh = k_cache.reshape(b, cfg.max_seq, cfg.heads, hd)
        vh = v_cache.reshape(b, cfg.max_seq, cfg.heads, hd)
        scores = jnp.einsum("bhd,bthd->bht", qh, kh) / np.sqrt(hd)
        scores = jnp.where(mask[:, None, :] > 0, scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bht,bthd->bhd", probs, vh).reshape(b, cfg.hidden)

        x = x + _linear(params, f"{pre}.out", ctx.astype(jnp.float16), cfg.group)
        h = _rmsnorm(x, params[f"{pre}.ln2"])
        u = _linear(params, f"{pre}.up", h, cfg.group)
        u = jax.nn.gelu(u.astype(jnp.float32)).astype(jnp.float16)
        x = x + _linear(params, f"{pre}.down", u, cfg.group)

    h = _rmsnorm(x, params["ln_f"])
    logits = _linear(params, "lm_head", h, cfg.group).astype(jnp.float32)
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return logits, next_token, new_cache


def decode_step_ref(params: dict[str, Any], cfg: ModelConfig, token_ids,
                    positions, kv_cache):
    """Oracle twin of :func:`decode_step` using dequantized FP16 weights and
    plain jnp matmuls (no Pallas) — used by the python tests."""
    from .kernels import ref

    dense: dict[str, Any] = {}
    for key, val in params.items():
        if key.endswith(".packed"):
            base = key[: -len(".packed")]
            kdim = val.shape[0] * 2
            dense[base] = ref.dequant_ref(
                jnp.asarray(val), jnp.asarray(params[f"{base}.scales"]),
                jnp.asarray(params[f"{base}.zeros"]), kdim, cfg.group,
            )
        elif "." not in key or key.endswith(("ln1", "ln2")) or key in ("embed", "ln_f"):
            dense[key] = jnp.asarray(val)

    def lin(name, x):
        return ref.matmul_ref(x, dense[name])

    b = token_ids.shape[0]
    x = dense["embed"].astype(jnp.float16)[token_ids]
    pos_axis = jnp.arange(cfg.max_seq)[None, :]
    mask = (pos_axis <= positions[:, None]).astype(jnp.float32)
    new_cache = kv_cache
    for layer in range(cfg.layers):
        pre = f"layer{layer}"
        h = _rmsnorm(x, dense[f"{pre}.ln1"])
        qkv = lin(f"{pre}.qkv", h)
        q, k_new, v_new = jnp.split(qkv.astype(jnp.float32), 3, axis=-1)
        k_cache = new_cache[layer, 0]
        v_cache = new_cache[layer, 1]
        onehot = (pos_axis == positions[:, None]).astype(jnp.float32)
        k_cache = k_cache * (1.0 - onehot[..., None]) + onehot[..., None] * k_new[:, None, :]
        v_cache = v_cache * (1.0 - onehot[..., None]) + onehot[..., None] * v_new[:, None, :]
        new_cache = new_cache.at[layer, 0].set(k_cache)
        new_cache = new_cache.at[layer, 1].set(v_cache)
        hd = cfg.head_dim
        qh = q.reshape(b, cfg.heads, hd)
        kh = k_cache.reshape(b, cfg.max_seq, cfg.heads, hd)
        vh = v_cache.reshape(b, cfg.max_seq, cfg.heads, hd)
        scores = jnp.einsum("bhd,bthd->bht", qh, kh) / np.sqrt(hd)
        scores = jnp.where(mask[:, None, :] > 0, scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bht,bthd->bhd", probs, vh).reshape(b, cfg.hidden)
        x = x + lin(f"{pre}.out", ctx.astype(jnp.float16))
        h = _rmsnorm(x, dense[f"{pre}.ln2"])
        u = lin(f"{pre}.up", h)
        u = jax.nn.gelu(u.astype(jnp.float32)).astype(jnp.float16)
        x = x + lin(f"{pre}.down", u)
    h = _rmsnorm(x, dense["ln_f"])
    logits = lin("lm_head", h).astype(jnp.float32)
    next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return logits, next_token, new_cache
