"""Phase 3 — Split-buffer reduction + FP32 -> FP16 cast (vector-core / AIV analog).

After all cube cores have finished, vector cores partition the output
elements, sum the ``S`` FP32 partial buffers elementwise and cast the result
to FP16 (Algorithm 1, Phase 3).  The cross-phase barrier ("wait for all AIC
cores") is realized by the data dependence between the ``pallas_call``s.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _reduce_kernel(parts_ref, out_ref):
    """Sum the split axis of an (S, bm, bn) FP32 block, cast to FP16."""
    out_ref[...] = parts_ref[...].sum(axis=0).astype(jnp.float16)


def reduce_splits(partials, *, bm: int, bn: int, interpret: bool = True) -> jnp.ndarray:
    """(S, M, N) f32 partials -> (M, N) f16 output."""
    s, m, n = partials.shape
    if m % bm != 0 or n % bn != 0:
        raise ValueError(f"blocks ({bm},{bn}) must tile ({m},{n})")
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _reduce_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((s, bm, bn), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float16),
        interpret=interpret,
    )(partials)
