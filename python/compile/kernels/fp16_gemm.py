"""Native FP16 x FP16 tiled GEMM — the paper's PyTorch comparator.

Single-pass data-parallel GEMM: weights are read from GM exactly once and
no workspace round trip exists.  This is the baseline Figure 3 measures the
W4A16 kernel against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, out_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


def fp16_matmul(a, b, *, bm: int, bn: int, bk: int,
                interpret: bool = True) -> jnp.ndarray:
    """(M,K) f16 x (K,N) f16 -> (M,N) f16 with FP32 accumulation."""
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if m % bm != 0 or n % bn != 0 or k % bk != 0:
        raise ValueError(f"blocks ({bm},{bn},{bk}) must tile ({m},{n},{k})")
    grid = (m // bm, n // bn, k // bk)
    acc = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float16), b.astype(jnp.float16))
    return acc.astype(jnp.float16)
