"""Pure-jnp correctness oracles for every kernel in this package.

These are the ground truth the Pallas kernels (and, transitively, the rust
runtime executing the AOT artifacts) are validated against.  They use no
Pallas machinery at all — plain jnp ops only.
"""

from __future__ import annotations

import jax.numpy as jnp

from ..quantize import unpack_int4_jnp


def dequant_ref(packed, scales, zeros, k: int, group: int) -> jnp.ndarray:
    """Dequantize packed INT4 codes to FP16: ``w = s * (q - z)``.

    packed: int8 (K//2, N); scales/zeros: f32 (K//g, N) -> f16 (K, N).
    """
    q = unpack_int4_jnp(packed, k).astype(jnp.float32)
    s = jnp.repeat(scales, group, axis=0)
    z = jnp.repeat(zeros, group, axis=0)
    return (s * (q - z)).astype(jnp.float16)


def matmul_ref(a, b) -> jnp.ndarray:
    """FP16 x FP16 -> FP16 GEMM with FP32 accumulation (cube-core semantics)."""
    acc = jnp.dot(
        a.astype(jnp.float16),
        b.astype(jnp.float16),
        preferred_element_type=jnp.float32,
    )
    return acc.astype(jnp.float16)


def splitk_partials_ref(a, b, splits: int) -> jnp.ndarray:
    """FP32 partial products C_i = A[:, ks] @ B[ks, :] per K-split -> (S, M, N)."""
    m, k = a.shape
    ks = k // splits
    parts = []
    for s in range(splits):
        parts.append(
            jnp.dot(
                a[:, s * ks : (s + 1) * ks].astype(jnp.float16),
                b[s * ks : (s + 1) * ks, :].astype(jnp.float16),
                preferred_element_type=jnp.float32,
            )
        )
    return jnp.stack(parts, axis=0)


def reduce_ref(partials) -> jnp.ndarray:
    """Phase-3 oracle: sum FP32 partials over the split axis, cast to FP16."""
    return partials.sum(axis=0).astype(jnp.float16)


def w4a16_ref(a, packed, scales, zeros, group: int) -> jnp.ndarray:
    """End-to-end W4A16 oracle: dequant then FP16 GEMM (FP32 accumulate)."""
    k = a.shape[1]
    b = dequant_ref(packed, scales, zeros, k, group)
    return matmul_ref(a, b)
