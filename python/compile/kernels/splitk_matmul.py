"""Phase 2 — Split-K tiled FP16 GEMM with FP32 accumulation (cube / AIC analog).

Computes partial products ``C_s = A[:, s*K/S:(s+1)*K/S] @ B[...]`` for each
of the ``S`` K-splits and writes them to an FP32 ``(S, M, N)`` split buffer
in global memory — Phase 2 of Algorithm 1.  Each grid step performs one
``(bm x bk) @ (bk x bn)`` MMAD-shaped dot with FP32 accumulation, the Pallas
analog of the cube core's 16x16x16 FP16 ``Mmad`` with the L0C accumulator.

The output revisiting pattern (grid dim ``k`` maps to the same output block)
is how Pallas expresses L0C accumulation across K-steps; the split buffers
live in "GM" (a real output array) exactly as in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _splitk_kernel(a_ref, b_ref, out_ref):
    """One MMAD step: accumulate a (bm,bk)@(bk,bn) dot into the FP32 block."""

    @pl.when(pl.program_id(3) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )[None, :, :]


def splitk_matmul(a, b, *, splits: int, bm: int, bn: int, bk: int,
                  interpret: bool = True) -> jnp.ndarray:
    """Split-K partial GEMM: (M,K) f16 x (K,N) f16 -> (S, M, N) f32 partials.

    ``splits`` must divide K, and (bm, bn, bk) must tile (M, N, K/S).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    if k % splits != 0:
        raise ValueError(f"splits={splits} must divide K={k}")
    ks = k // splits
    if m % bm != 0 or n % bn != 0 or ks % bk != 0:
        raise ValueError(f"blocks ({bm},{bn},{bk}) must tile ({m},{n},{ks})")
    ksteps = ks // bk
    grid = (splits, m // bm, n // bn, ksteps)
    return pl.pallas_call(
        _splitk_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda s, i, j, t: (i, s * (ks // bk) + t)),
            pl.BlockSpec((bk, bn), lambda s, i, j, t: (s * (ks // bk) + t, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, i, j, t: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((splits, m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float16), b.astype(jnp.float16))
