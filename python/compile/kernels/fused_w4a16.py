"""Fused W4A16 GEMM — the paper's *future-work* ablation.

The paper's conclusion calls for "direct data paths between vector and cube
units or fused instructions that bypass global memory".  This kernel models
that hypothetical hardware: dequantization happens *inside* the matmul
kernel on the tile already staged on-chip, so the FP16 weights never make a
global-memory round trip.  Comparing this ablation against the three-phase
pipeline quantifies exactly how much the decoupled architecture costs
(EXPERIMENTS.md, Ablation A).

Constraint: the K block size equals the quantization group size so each
weight tile maps to a single (scale, zero) row.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_kernel(a_ref, packed_ref, scales_ref, zeros_ref, out_ref, *, group: int):
    """Dequantize one (bk, bn) weight tile in-register and MMAD it."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    p = packed_ref[...].astype(jnp.uint8)
    lo = (p & 0xF).astype(jnp.float32)
    hi = ((p >> 4) & 0xF).astype(jnp.float32)
    half_k, bn = p.shape
    q = jnp.stack([lo, hi], axis=1).reshape(half_k * 2, bn)
    w = (scales_ref[...] * (q - zeros_ref[...])).astype(jnp.float16)
    out_ref[...] += jnp.dot(a_ref[...], w, preferred_element_type=jnp.float32)


def fused_w4a16_matmul(a, packed, scales, zeros, *, group: int, bm: int, bn: int,
                       interpret: bool = True) -> jnp.ndarray:
    """(M,K) f16 x packed-INT4 (K//2,N) -> (M,N) f16, dequant fused in-kernel.

    The K block size is pinned to ``group`` (one scale row per tile).
    """
    m, k = a.shape
    n = packed.shape[1]
    bk = group
    if k % bk != 0 or m % bm != 0 or n % bn != 0:
        raise ValueError(f"blocks ({bm},{bn},{bk}) must tile ({m},{n},{k})")
    grid = (m // bm, n // bn, k // bk)
    acc = pl.pallas_call(
        functools.partial(_fused_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, t: (i, t)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((1, bn), lambda i, j, t: (t, j)),
            pl.BlockSpec((1, bn), lambda i, j, t: (t, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, t: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a.astype(jnp.float16), packed, scales, zeros)
    return acc.astype(jnp.float16)
