"""Phase 1 — INT4 -> FP16 dequantization kernel (vector-core / AIV analog).

On the Ascend 910, cube cores cannot perform type conversion, so Algorithm 1
runs dequantization on the vector cores and stages the FP16 result in a
global-memory workspace that the cube cores later re-read.  This kernel is
the Pallas realization of that phase: it is a *separate* ``pallas_call``
whose output materializes as a real intermediate array between phases — the
exact GM round trip the paper's bottleneck analysis is about.

Hardware adaptation (see DESIGN.md §3): the AIV's 2048-bit SIMD lanes map to
VPU-friendly elementwise ops on VMEM tiles; the MTE double-buffering maps to
the Pallas grid pipeline; the Unified Buffer capacity constrains the block
shape (checked in ``configs.select_blocks``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dequant_kernel(packed_ref, scales_ref, zeros_ref, out_ref, *, group: int):
    """Unpack two nibbles per byte and apply ``w = s * (q - z)``.

    packed_ref: (bk // 2, bn) int8 — low nibble is row 2k, high is 2k+1.
    scales_ref / zeros_ref: (bk // group, bn) f32.
    out_ref: (bk, bn) f16.
    """
    p = packed_ref[...].astype(jnp.uint8)
    lo = (p & 0xF).astype(jnp.float32)
    hi = ((p >> 4) & 0xF).astype(jnp.float32)
    half_k, bn = p.shape
    # Interleave rows: out[2k] = lo[k], out[2k+1] = hi[k].
    q = jnp.stack([lo, hi], axis=1).reshape(half_k * 2, bn)
    s = jnp.repeat(scales_ref[...], group, axis=0)
    z = jnp.repeat(zeros_ref[...], group, axis=0)
    out_ref[...] = (s * (q - z)).astype(jnp.float16)


def dequant(packed, scales, zeros, *, k: int, group: int, bk: int, bn: int,
            interpret: bool = True) -> jnp.ndarray:
    """Dequantize packed INT4 weights to an FP16 (K, N) workspace array.

    Args:
      packed: int8 (K//2, N) nibble-packed codes.
      scales/zeros: f32 (K//group, N) group parameters.
      k: logical K (rows of the dequantized matrix).
      group: quantization group size along K.
      bk/bn: block sizes; ``bk`` must be a positive multiple of ``group``
        and divide K; ``bn`` must divide N.
    """
    n = packed.shape[1]
    if bk % group != 0:
        raise ValueError(f"bk={bk} must be a multiple of group={group}")
    if k % bk != 0 or n % bn != 0:
        raise ValueError(f"blocks ({bk},{bn}) must divide ({k},{n})")
    grid = (k // bk, n // bn)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, group=group),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk // 2, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bk // group, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((k, n), jnp.float16),
        interpret=interpret,
    )(packed, scales, zeros)
