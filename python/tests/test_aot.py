"""AOT pipeline tests: HLO text generation and manifest structure.

These keep the build-time contract with the rust loader honest without
paying for a full `make artifacts` run (decode lowering is covered by the
rust integration tests against real artifacts).
"""

import json
import os

import jax
import numpy as np
import pytest

from compile import aot, configs


class TestToHloText:
    def test_emits_parseable_entry(self):
        def fn(x):
            return (x * 2.0,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), np.float32))
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "f32[4]" in text

    def test_tuple_return_convention(self):
        """The rust side always unwraps a tuple — lowering must produce one."""
        def fn(x):
            return (x + 1.0,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2,), np.float32))
        text = aot.to_hlo_text(lowered)
        assert "(f32[2]" in text  # tuple-typed root


class TestGemmArtifacts:
    @pytest.fixture(scope="class")
    def built(self, tmp_path_factory, monkeypatch_class=None):
        out = tmp_path_factory.mktemp("artifacts")
        # Trim to one shape for speed; full set exercised by `make artifacts`.
        orig = aot.GEMM_SHAPES
        aot.GEMM_SHAPES = [(16, 256, 512)]
        try:
            entries = aot.build_gemm_artifacts(str(out))
        finally:
            aot.GEMM_SHAPES = orig
        return out, entries

    def test_all_strategies_emitted(self, built):
        _, entries = built
        assert {e["strategy"] for e in entries} == set(aot.STRATEGIES)

    def test_files_exist_and_nonempty(self, built):
        out, entries = built
        for e in entries:
            p = os.path.join(str(out), e["path"])
            assert os.path.getsize(p) > 100

    def test_input_specs_match_convention(self, built):
        _, entries = built
        for e in entries:
            names = [i["name"] for i in e["inputs"]]
            if e["strategy"] == "fp16":
                assert names == ["a", "b"]
            else:
                assert names == ["a", "packed", "scales", "zeros"]
                packed = e["inputs"][1]
                assert packed["dtype"] == "i8"
                assert packed["shape"] == [e["k"] // 2, e["n"]]

    def test_splits_recorded_only_for_splitk(self, built):
        _, entries = built
        for e in entries:
            if e["strategy"] == "splitk":
                assert e["splits"] >= 1
            else:
                assert e["splits"] == 1

    def test_manifest_round_trips_json(self, built):
        _, entries = built
        manifest = {
            "version": 1,
            "artifacts": entries,
            "paper_shapes": [
                {"model": s.model, "n": s.n, "k": s.k} for s in configs.PAPER_SHAPES
            ],
            "batch_sizes": list(configs.PAPER_BATCH_SIZES),
            "group": configs.DEFAULT_GROUP,
        }
        text = json.dumps(manifest)
        assert json.loads(text)["group"] == 128


class TestWeightBlob:
    def test_offsets_contiguous(self, tmp_path):
        params = {
            "a": np.zeros((4, 4), np.float32),
            "b": np.ones((2,), np.int8),
        }
        info = aot._write_weights(str(tmp_path), "t", params)
        assert info["tensors"][0]["offset"] == 0
        assert info["tensors"][1]["offset"] == 64
        assert info["total_bytes"] == 66
        assert os.path.getsize(tmp_path / "t_weights.bin") == 66

    def test_blob_content_round_trips(self, tmp_path):
        rng = np.random.default_rng(3)
        params = {"w": rng.standard_normal((8, 8)).astype(np.float32)}
        info = aot._write_weights(str(tmp_path), "t2", params)
        raw = (tmp_path / "t2_weights.bin").read_bytes()
        back = np.frombuffer(raw, dtype=np.float32).reshape(8, 8)
        np.testing.assert_array_equal(back, params["w"])
