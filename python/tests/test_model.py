"""Decode-model tests: shapes, cache semantics, oracle agreement."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

CFG = model.TINY


@pytest.fixture(scope="module")
def params():
    return model.init_decode_params(CFG, seed=0)


@pytest.fixture(scope="module")
def step_out(params):
    tokens = jnp.asarray([3, 7, 100, 511], dtype=jnp.int32)
    positions = jnp.asarray([0, 5, 1, 31], dtype=jnp.int32)
    cache = jnp.zeros((CFG.layers, 2, 4, CFG.max_seq, CFG.hidden), jnp.float32)
    return (tokens, positions, cache) + model.decode_step(params, CFG, tokens, positions, cache)


class TestDecodeStep:
    def test_output_shapes(self, step_out):
        _, _, _, logits, nxt, cache = step_out
        assert logits.shape == (4, CFG.vocab)
        assert nxt.shape == (4,)
        assert nxt.dtype == jnp.int32
        assert cache.shape == (CFG.layers, 2, 4, CFG.max_seq, CFG.hidden)

    def test_matches_reference(self, params, step_out):
        tokens, positions, cache0, logits, nxt, cache = step_out
        l2, n2, c2 = model.decode_step_ref(params, CFG, tokens, positions, cache0)
        np.testing.assert_allclose(np.asarray(logits), np.asarray(l2), rtol=5e-2, atol=5e-2)
        assert np.array_equal(np.asarray(nxt), np.asarray(n2))
        np.testing.assert_allclose(np.asarray(cache), np.asarray(c2), rtol=5e-2, atol=5e-2)

    def test_cache_written_only_at_position(self, step_out):
        """KV rows other than each sequence's position must stay zero."""
        _, positions, _, _, _, cache = step_out
        c = np.asarray(cache)
        for b, pos in enumerate(np.asarray(positions)):
            written = np.abs(c[:, :, b]).sum(axis=-1)  # (L, 2, T)
            nonzero_t = np.nonzero(written.sum(axis=(0, 1)))[0]
            assert list(nonzero_t) == [pos]

    def test_argmax_consistent_with_logits(self, step_out):
        _, _, _, logits, nxt, _ = step_out
        assert np.array_equal(np.asarray(jnp.argmax(logits, -1)), np.asarray(nxt))

    def test_deterministic(self, params):
        tokens = jnp.asarray([1], dtype=jnp.int32)
        positions = jnp.asarray([0], dtype=jnp.int32)
        cache = jnp.zeros((CFG.layers, 2, 1, CFG.max_seq, CFG.hidden), jnp.float32)
        a = model.decode_step(params, CFG, tokens, positions, cache)
        b = model.decode_step(params, CFG, tokens, positions, cache)
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))

    def test_multi_step_cache_growth(self, params):
        """Run 3 steps; each step's keys accumulate in the cache."""
        b = 1
        cache = jnp.zeros((CFG.layers, 2, b, CFG.max_seq, CFG.hidden), jnp.float32)
        tok = jnp.asarray([5], dtype=jnp.int32)
        for step in range(3):
            pos = jnp.asarray([step], dtype=jnp.int32)
            _, tok, cache = model.decode_step(params, CFG, tok, pos, cache)
        occupancy = np.abs(np.asarray(cache[0, 0, 0])).sum(axis=-1) > 0
        assert occupancy[:3].all() and not occupancy[3:].any()


class TestModelConfig:
    def test_param_count_small100m(self):
        assert 80e6 < model.SMALL_100M.param_count() < 120e6

    def test_dims_are_group_multiples(self):
        for cfg in (model.TINY, model.SMALL_100M):
            assert cfg.hidden % 128 == 0
            assert cfg.ffn % 128 == 0
            assert (3 * cfg.hidden) % 128 == 0
            assert cfg.vocab % 128 == 0

    def test_init_params_deterministic(self):
        p1 = model.init_decode_params(CFG, seed=0)
        p2 = model.init_decode_params(CFG, seed=0)
        assert set(p1) == set(p2)
        for k in p1:
            np.testing.assert_array_equal(p1[k], p2[k])

    def test_param_ordering_stable(self, params):
        keys = list(params)
        assert keys[0] == "embed"
        assert keys[-1] == "lm_head.zeros"
