"""Tiling / shape-table tests: every paper shape must produce a legal config."""

import pytest

from compile import configs


class TestPaperShapes:
    def test_table_covers_all_models(self):
        models = {s.model for s in configs.PAPER_SHAPES}
        assert models == {"llama32", "glm45", "deepseek", "openpangu"}

    def test_k_dominant_shapes_exist(self):
        """The paper's K >> N regime must be represented."""
        assert any(s.k_dominant for s in configs.PAPER_SHAPES)
        assert any(not s.k_dominant for s in configs.PAPER_SHAPES)

    def test_all_dims_group_multiples(self):
        for s in configs.PAPER_SHAPES:
            assert s.n % configs.DEFAULT_GROUP == 0 or s.n % 512 == 0
            assert s.k % configs.DEFAULT_GROUP == 0


class TestSelectBlocks:
    @pytest.mark.parametrize("shape", configs.PAPER_SHAPES, ids=lambda s: s.tag)
    @pytest.mark.parametrize("m", configs.PAPER_BATCH_SIZES)
    def test_valid_for_paper_sweep(self, shape, m):
        m_pad = configs.pad_to(m, configs.CUBE_TILE)
        cfg = configs.select_blocks(m_pad, shape.n, shape.k)
        cfg.validate(m_pad, shape.n, shape.k)

    def test_split_factor_increases_when_n_small(self):
        s_small_n = configs.select_blocks(16, 512, 8192).splits
        s_large_n = configs.select_blocks(16, 8192, 512).splits
        assert s_small_n > s_large_n

    def test_rejects_non_tile_n(self):
        with pytest.raises(ValueError):
            configs.select_blocks(16, 17, 256)

    def test_pad_to(self):
        assert configs.pad_to(1, 16) == 16
        assert configs.pad_to(16, 16) == 16
        assert configs.pad_to(17, 16) == 32

    def test_block_config_validate_catches_bad(self):
        cfg = configs.BlockConfig(bm=16, bn=64, bk=128, splits=3)
        with pytest.raises(ValueError):
            cfg.validate(16, 64, 512)  # 3 does not divide 512


class TestDefaultSplits:
    def test_at_least_one(self):
        for s in configs.PAPER_SHAPES:
            assert configs.default_splits(s.n, s.k) >= 1

    def test_splits_preserve_group_alignment(self):
        for s in configs.PAPER_SHAPES:
            splits = configs.default_splits(s.n, s.k)
            assert (s.k // splits) % configs.DEFAULT_GROUP == 0
