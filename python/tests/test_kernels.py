"""Pallas kernels vs the pure-jnp oracle — the core correctness signal.

Every phase of Algorithm 1 is tested in isolation and composed, plus the
data-parallel / fused / native-FP16 comparators.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, quantize
from compile.kernels import dequant as kdequant
from compile.kernels import fp16_gemm as kfp16
from compile.kernels import fused_w4a16 as kfused
from compile.kernels import reduce as kreduce
from compile.kernels import ref
from compile.kernels import splitk_matmul as ksplitk


def make_case(m, n, k, seed=0, group=128):
    rng = np.random.default_rng(seed)
    a = jnp.asarray((rng.standard_normal((m, k)) * 0.5).astype(np.float32))
    qw = quantize.quantize_groupwise(quantize.random_weight(k, n, seed=seed + 1), group=group)
    return a, jnp.asarray(qw.packed), jnp.asarray(qw.scales), jnp.asarray(qw.zeros)


class TestDequantKernel:
    @pytest.mark.parametrize("k,n,bk,bn", [(256, 64, 128, 64), (512, 256, 128, 128), (256, 128, 256, 32)])
    def test_matches_ref(self, k, n, bk, bn):
        _, packed, scales, zeros = make_case(16, n, k)
        got = kdequant.dequant(packed, scales, zeros, k=k, group=128, bk=bk, bn=bn)
        want = ref.dequant_ref(packed, scales, zeros, k, 128)
        assert got.dtype == jnp.float16
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_host_dequantize(self):
        k, n = 256, 64
        qw = quantize.quantize_groupwise(quantize.random_weight(k, n, seed=7))
        got = np.asarray(
            kdequant.dequant(
                jnp.asarray(qw.packed), jnp.asarray(qw.scales), jnp.asarray(qw.zeros),
                k=k, group=128, bk=128, bn=64,
            ),
            dtype=np.float32,
        )
        np.testing.assert_allclose(got, qw.dequantize(), atol=2e-4, rtol=1e-3)

    def test_rejects_bad_blocks(self):
        _, packed, scales, zeros = make_case(16, 64, 256)
        with pytest.raises(ValueError):
            kdequant.dequant(packed, scales, zeros, k=256, group=128, bk=96, bn=64)
        with pytest.raises(ValueError):
            kdequant.dequant(packed, scales, zeros, k=256, group=128, bk=128, bn=48)

    def test_extreme_codes(self):
        """All-0 and all-15 codes exercise both nibbles' range ends."""
        k, n = 256, 32
        q = np.zeros((k, n), dtype=np.uint8)
        q[::2] = 15
        packed = jnp.asarray(quantize.pack_int4(q))
        scales = jnp.full((2, n), 0.01, jnp.float32)
        zeros = jnp.full((2, n), 8.0, jnp.float32)
        got = kdequant.dequant(packed, scales, zeros, k=k, group=128, bk=128, bn=32)
        want = ref.dequant_ref(packed, scales, zeros, k, 128)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestSplitKKernel:
    @pytest.mark.parametrize("splits", [1, 2, 4])
    def test_partials_match_ref(self, splits):
        m, n, k = 16, 128, 512
        a, packed, scales, zeros = make_case(m, n, k)
        b = ref.dequant_ref(packed, scales, zeros, k, 128)
        got = ksplitk.splitk_matmul(a.astype(jnp.float16), b, splits=splits, bm=16, bn=64, bk=128)
        want = ref.splitk_partials_ref(a, b, splits)
        assert got.shape == (splits, m, n)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_sum_of_partials_is_full_product(self):
        m, n, k = 16, 64, 1024
        a, packed, scales, zeros = make_case(m, n, k, seed=5)
        b = ref.dequant_ref(packed, scales, zeros, k, 128)
        parts = ksplitk.splitk_matmul(a.astype(jnp.float16), b, splits=4, bm=16, bn=64, bk=128)
        full = jnp.dot(a.astype(jnp.float16), b, preferred_element_type=jnp.float32)
        np.testing.assert_allclose(np.asarray(parts.sum(0)), np.asarray(full), rtol=1e-4, atol=1e-4)

    def test_rejects_bad_splits(self):
        a = jnp.zeros((16, 500), jnp.float16)
        b = jnp.zeros((500, 64), jnp.float16)
        with pytest.raises(ValueError):
            ksplitk.splitk_matmul(a, b, splits=3, bm=16, bn=64, bk=128)

    def test_rejects_mismatched_inner(self):
        with pytest.raises(ValueError):
            ksplitk.splitk_matmul(
                jnp.zeros((16, 256), jnp.float16),
                jnp.zeros((512, 64), jnp.float16),
                splits=2, bm=16, bn=64, bk=128,
            )


class TestReduceKernel:
    @pytest.mark.parametrize("s", [1, 2, 8])
    def test_matches_ref(self, s):
        rng = np.random.default_rng(s)
        parts = jnp.asarray(rng.standard_normal((s, 32, 64)).astype(np.float32))
        got = kreduce.reduce_splits(parts, bm=16, bn=64)
        want = ref.reduce_ref(parts)
        assert got.dtype == jnp.float16
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fp32_accumulation_before_cast(self):
        """Summation must happen in FP32; casting first would lose bits."""
        parts = jnp.asarray(
            np.stack([np.full((16, 16), 1024.0), np.full((16, 16), 0.25)]).astype(np.float32)
        )
        got = np.asarray(kreduce.reduce_splits(parts, bm=16, bn=16), dtype=np.float32)
        # fp16(1024 + 0.25) = 1024.0 vs fp16(1024) + fp16(0.25) summed in fp16
        want = np.asarray(ref.reduce_ref(parts), dtype=np.float32)
        np.testing.assert_array_equal(got, want)


class TestFp16Gemm:
    @pytest.mark.parametrize("m,n,k", [(16, 64, 256), (32, 128, 512)])
    def test_matches_ref(self, m, n, k):
        rng = np.random.default_rng(9)
        a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32) * 0.2)
        b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32) * 0.2)
        got = kfp16.fp16_matmul(a, b, bm=16, bn=64, bk=128)
        want = ref.matmul_ref(a, b)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
            rtol=1e-3, atol=1e-3,
        )


class TestFusedKernel:
    def test_matches_ref(self):
        m, n, k = 16, 128, 512
        a, packed, scales, zeros = make_case(m, n, k, seed=11)
        got = kfused.fused_w4a16_matmul(
            a.astype(jnp.float16), packed, scales, zeros, group=128, bm=16, bn=64
        )
        want = ref.w4a16_ref(a, packed, scales, zeros, 128)
        np.testing.assert_allclose(
            np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
            rtol=1e-3, atol=1e-3,
        )


class TestPipelines:
    """All three W4A16 strategies must agree with the oracle and each other."""

    @pytest.mark.parametrize("m,n,k", [(16, 256, 512), (16, 128, 1024), (64, 512, 1024)])
    def test_strategies_agree(self, m, n, k):
        cfg = configs.select_blocks(m, n, k)
        a, packed, scales, zeros = make_case(m, n, k, seed=13)
        want = np.asarray(ref.w4a16_ref(a, packed, scales, zeros, cfg.group), dtype=np.float32)
        for fn in (model.w4a16_matmul_splitk, model.w4a16_matmul_dp, model.w4a16_matmul_fused):
            got = np.asarray(fn(a, packed, scales, zeros, cfg), dtype=np.float32)
            np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3, err_msg=fn.__name__)

    def test_w4a16_linear_pads_and_slices(self):
        """Odd M (decode batch) is padded to the cube tile then sliced back."""
        m, n, k = 3, 128, 256
        a, packed, scales, zeros = make_case(m, n, k, seed=17)
        got = model.w4a16_linear(a.astype(jnp.float16), packed, scales, zeros)
        assert got.shape == (m, n)
        want = np.asarray(ref.w4a16_ref(a, packed, scales, zeros, 128), dtype=np.float32)
        np.testing.assert_allclose(np.asarray(got, np.float32), want, rtol=2e-3, atol=2e-3)

    def test_splitk_split_invariance(self):
        """The result must not depend on the split factor (reduction assoc.)."""
        m, n, k = 16, 64, 1024
        a, packed, scales, zeros = make_case(m, n, k, seed=19)
        outs = []
        for s in (1, 2, 4, 8):
            cfg = configs.BlockConfig(bm=16, bn=64, bk=128, splits=s)
            outs.append(np.asarray(
                model.w4a16_matmul_splitk(a, packed, scales, zeros, cfg), dtype=np.float32
            ))
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-3, atol=2e-3)
