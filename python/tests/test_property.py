"""Hypothesis sweeps over kernel shapes, block sizes and dtypes.

The strategies draw tile-multiple shapes (the kernels require exact tiling,
as the cube core does) and check the Pallas kernels against the jnp oracle
across the whole space.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import configs, model, quantize
from compile.kernels import dequant as kdequant
from compile.kernels import ref

GROUP = 128


@st.composite
def gemm_shapes(draw):
    """(m, n, k, splits, blocks) all mutually consistent."""
    m = draw(st.sampled_from([16, 32, 64]))
    n_tiles = draw(st.integers(1, 4))
    bn = draw(st.sampled_from([32, 64, 128]))
    n = n_tiles * bn
    k_groups = draw(st.sampled_from([2, 4, 8]))
    k = k_groups * GROUP
    splits = draw(st.sampled_from([s for s in (1, 2, 4) if k_groups % s == 0]))
    bm = draw(st.sampled_from([16, 32]))
    if m % bm:
        bm = 16
    return m, n, k, splits, bm, bn


@settings(max_examples=20, deadline=None)
@given(shape=gemm_shapes(), seed=st.integers(0, 2**16))
def test_splitk_pipeline_matches_oracle(shape, seed):
    m, n, k, splits, bm, bn = shape
    cfg = configs.BlockConfig(bm=bm, bn=bn, bk=GROUP, splits=splits, group=GROUP)
    rng = np.random.default_rng(seed)
    a = jnp.asarray((rng.standard_normal((m, k)) * 0.3).astype(np.float32))
    qw = quantize.quantize_groupwise(quantize.random_weight(k, n, seed=seed + 1), group=GROUP)
    packed, scales, zeros = map(jnp.asarray, (qw.packed, qw.scales, qw.zeros))
    got = np.asarray(
        model.w4a16_matmul_splitk(a, packed, scales, zeros, cfg), dtype=np.float32
    )
    want = np.asarray(ref.w4a16_ref(a, packed, scales, zeros, GROUP), dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=3e-3, atol=3e-3)


@settings(max_examples=20, deadline=None)
@given(
    k_groups=st.integers(1, 6),
    bn=st.sampled_from([16, 32, 64]),
    n_tiles=st.integers(1, 3),
    bk_groups=st.integers(1, 2),
    seed=st.integers(0, 2**16),
)
def test_dequant_matches_oracle(k_groups, bn, n_tiles, bk_groups, seed):
    if k_groups % bk_groups:
        bk_groups = 1
    k = k_groups * GROUP
    n = n_tiles * bn
    qw = quantize.quantize_groupwise(quantize.random_weight(k, n, seed=seed), group=GROUP)
    packed, scales, zeros = map(jnp.asarray, (qw.packed, qw.scales, qw.zeros))
    got = kdequant.dequant(
        packed, scales, zeros, k=k, group=GROUP, bk=bk_groups * GROUP, bn=bn
    )
    want = ref.dequant_ref(packed, scales, zeros, k, GROUP)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(shape=gemm_shapes(), seed=st.integers(0, 2**16))
def test_dp_equals_splitk(shape, seed):
    """Strategy choice must never change the numerics (only the schedule)."""
    m, n, k, splits, bm, bn = shape
    cfg = configs.BlockConfig(bm=bm, bn=bn, bk=GROUP, splits=splits, group=GROUP)
    rng = np.random.default_rng(seed)
    a = jnp.asarray((rng.standard_normal((m, k)) * 0.3).astype(np.float32))
    qw = quantize.quantize_groupwise(quantize.random_weight(k, n, seed=seed + 2), group=GROUP)
    packed, scales, zeros = map(jnp.asarray, (qw.packed, qw.scales, qw.zeros))
    sk = np.asarray(model.w4a16_matmul_splitk(a, packed, scales, zeros, cfg), np.float32)
    dp = np.asarray(model.w4a16_matmul_dp(a, packed, scales, zeros, cfg), np.float32)
    np.testing.assert_allclose(sk, dp, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(
    dtype=st.sampled_from([np.float16, np.float32]),
    m=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**16),
)
def test_activation_dtype_insensitivity(dtype, m, seed):
    """f32 activations are cast to f16 at the boundary — results identical."""
    n, k = 64, 256
    cfg = configs.BlockConfig(bm=16, bn=64, bk=128, splits=2, group=GROUP)
    rng = np.random.default_rng(seed)
    a32 = (rng.standard_normal((m, k)) * 0.3).astype(np.float32)
    a16 = a32.astype(np.float16)
    qw = quantize.quantize_groupwise(quantize.random_weight(k, n, seed=seed + 3))
    packed, scales, zeros = map(jnp.asarray, (qw.packed, qw.scales, qw.zeros))
    out_from_cast = np.asarray(
        model.w4a16_matmul_splitk(jnp.asarray(a16).astype(jnp.float16), packed, scales, zeros, cfg)
    )
    out_requested = np.asarray(
        model.w4a16_matmul_splitk(jnp.asarray(a32.astype(dtype)).astype(jnp.float16), packed, scales, zeros, cfg)
    )
    np.testing.assert_array_equal(out_from_cast, out_requested)
