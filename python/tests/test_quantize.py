"""Unit tests for the INT4 group quantizer and nibble packing."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import quantize


class TestPacking:
    def test_pack_unpack_roundtrip(self):
        rng = np.random.default_rng(0)
        q = rng.integers(0, 16, size=(64, 32), dtype=np.uint8)
        packed = quantize.pack_int4(q)
        assert packed.shape == (32, 32)
        assert packed.dtype == np.int8
        assert np.array_equal(quantize.unpack_int4(packed, 64), q)

    def test_pack_layout_low_nibble_first(self):
        q = np.array([[1], [2]], dtype=np.uint8)  # rows k=0,1
        packed = quantize.pack_int4(q)
        # low nibble = row 0 (1), high nibble = row 1 (2) -> 0x21
        assert packed[0, 0] == 0x21

    def test_pack_high_codes_sign_safe(self):
        """Codes >= 8 set the sign bit of the int8 byte; unpack must mask."""
        q = np.array([[15], [15]], dtype=np.uint8)
        packed = quantize.pack_int4(q)
        assert packed[0, 0] == np.int8(-1)  # 0xFF
        assert np.array_equal(quantize.unpack_int4(packed, 2), q)

    def test_pack_rejects_out_of_range(self):
        with pytest.raises(ValueError):
            quantize.pack_int4(np.full((2, 2), 16, dtype=np.uint8))

    def test_pack_rejects_odd_k(self):
        with pytest.raises(ValueError):
            quantize.pack_int4(np.zeros((3, 2), dtype=np.uint8))

    def test_unpack_jnp_matches_numpy(self):
        rng = np.random.default_rng(1)
        q = rng.integers(0, 16, size=(128, 8), dtype=np.uint8)
        packed = quantize.pack_int4(q)
        got = np.asarray(quantize.unpack_int4_jnp(packed, 128))
        assert np.array_equal(got, q)


class TestGroupQuantizer:
    def test_shapes_and_dtypes(self):
        w = quantize.random_weight(256, 64)
        qw = quantize.quantize_groupwise(w, group=128)
        assert qw.packed.shape == (128, 64)
        assert qw.scales.shape == (2, 64)
        assert qw.zeros.shape == (2, 64)
        assert qw.packed.dtype == np.int8
        assert qw.scales.dtype == np.float32

    def test_quantization_error_bound(self):
        """|w - dequant(quant(w))| <= scale/2 elementwise (affine fit)."""
        w = quantize.random_weight(512, 32, seed=3)
        qw = quantize.quantize_groupwise(w, group=128)
        back = qw.dequantize()
        tol = np.repeat(qw.scales, 128, axis=0) * 0.5 + 1e-7
        assert np.all(np.abs(w - back) <= tol)

    def test_symmetric_zero_point_is_mid_code(self):
        w = quantize.random_weight(128, 16, seed=4)
        qw = quantize.quantize_groupwise(w, group=128, symmetric=True)
        assert np.all(qw.zeros == 8.0)

    def test_symmetric_preserves_sign(self):
        w = np.zeros((128, 2), dtype=np.float32)
        w[:, 0] = 0.5
        w[:, 1] = -0.5
        qw = quantize.quantize_groupwise(w, group=128, symmetric=True)
        back = qw.dequantize()
        assert np.all(back[:, 0] > 0)
        assert np.all(back[:, 1] < 0)

    def test_constant_group_is_exact(self):
        w = np.full((128, 4), 0.25, dtype=np.float32)
        qw = quantize.quantize_groupwise(w, group=128)
        assert np.allclose(qw.dequantize(), w, atol=1e-6)

    def test_zero_weight_no_nan(self):
        w = np.zeros((256, 8), dtype=np.float32)
        qw = quantize.quantize_groupwise(w, group=128)
        back = qw.dequantize()
        assert np.all(np.isfinite(back))
        assert np.allclose(back, 0.0, atol=1e-6)

    def test_rejects_bad_group(self):
        with pytest.raises(ValueError):
            quantize.quantize_groupwise(np.zeros((100, 4), dtype=np.float32), group=128)

    def test_memory_footprint_is_quarter_of_fp16(self):
        """The headline 4x weight compression claim (§2.2)."""
        k, n = 1024, 512
        qw = quantize.quantize_groupwise(quantize.random_weight(k, n))
        fp16_bytes = k * n * 2
        assert qw.packed_bytes == fp16_bytes / 4

    @settings(max_examples=25, deadline=None)
    @given(
        kg=st.integers(1, 8),
        n=st.integers(1, 48),
        seed=st.integers(0, 2**16),
        symmetric=st.booleans(),
    )
    def test_roundtrip_error_bound_property(self, kg, n, seed, symmetric):
        k = kg * 128
        w = quantize.random_weight(k, n, seed=seed)
        qw = quantize.quantize_groupwise(w, group=128, symmetric=symmetric)
        back = qw.dequantize()
        scale_rep = np.repeat(qw.scales, 128, axis=0)
        # Affine: within half a step. Symmetric: codes clamp at 0 so allow a
        # full step of slack on the negative edge.
        slack = 1.0 if symmetric else 0.5
        assert np.all(np.abs(w - back) <= scale_rep * slack + 1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        kg=st.integers(1, 6),
        n=st.integers(1, 32),
        seed=st.integers(0, 2**16),
    )
    def test_pack_roundtrip_property(self, kg, n, seed):
        rng = np.random.default_rng(seed)
        q = rng.integers(0, 16, size=(kg * 128, n), dtype=np.uint8)
        assert np.array_equal(
            quantize.unpack_int4(quantize.pack_int4(q), kg * 128), q
        )
