//! # ascend-w4a16
//!
//! Production-quality reproduction of *"W4A16 Mixed-Precision Matrix
//! Multiplication on Decoupled Architecture: Kernel Design and Memory
//! Bottleneck Analysis for Ascend NPUs"* (CS.DC 2026).
//!
//! The library has four pillars:
//!
//! * [`ascend`] — a cycle-approximate, event-driven simulator of the
//!   Ascend 910's decoupled AI-core architecture (cube + vector cores,
//!   L1/L0/UB buffers, MTE transfer engines, shared L2, HBM contention).
//! * [`kernels`] — kernel *schedules* (the paper's Algorithm 1 Split-K
//!   pipeline, the chunk-pipelined Split-K that pins its workspace in L2,
//!   plus the data-parallel, native-FP16 and fused comparators) that
//!   compile GEMM problems into simulator traces.
//! * [`tune`] — the per-shape schedule autotuner: searches strategies x
//!   tilings on the simulator, persists winners to a JSON cache, and
//!   resolves `Strategy::Auto` for the CLI, benches and router.
//! * [`runtime`] — a PJRT-backed executor that loads the AOT-compiled
//!   HLO artifacts (JAX + Pallas, lowered at build time) and runs the
//!   real numerics on the request path with no Python anywhere.
//! * [`coordinator`] — a decode-serving runtime (request queue, dynamic
//!   batcher, shape router, KV-cache/session management) exercising the
//!   W4A16 pipeline on the paper's motivating workload: LLM decoding.
//!
//! Supporting substrates: [`quant`] (INT4 group quantization + nibble
//! packing), [`tensor`] (host tensors), [`analysis`] (roofline + traffic
//! decomposition behind the paper's §4.2 bottleneck analysis),
//! [`model`] (LLM geometry tables), [`workload`] (request generators)
//! and [`util`] (JSON, CLI, f16, PRNG, stats — the build environment is
//! fully offline, so these are implemented here rather than pulled in).

// The deprecated `simulate_step*` shims (analysis::layer) stay callable
// for one PR, but nothing inside the crate may use them: every internal
// caller goes through `analysis::stepsim::StepSim`.  `#[deprecated]`
// fires for same-crate use, so this turns any backslide into a build
// error (the shims' own bodies are exempt — items inside a deprecated
// item don't lint).
#![deny(deprecated)]

pub mod analysis;
pub mod ascend;
pub mod bench;
pub mod coordinator;
pub mod kernels;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod tune;
pub mod util;
pub mod workload;
