//! Artifact manifest: the build-time contract between `python/compile/aot.py`
//! and the rust runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::tensor::DType;
use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.req_str("name")?.to_string(),
            dtype: DType::from_name(j.req_str("dtype")?)?,
            shape: j
                .req_arr("shape")?
                .iter()
                .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape")))
                .collect::<Result<_, _>>()?,
        })
    }
}

/// One tensor inside a weight blob.
#[derive(Debug, Clone)]
pub struct WeightRecord {
    pub spec: TensorSpec,
    pub offset: usize,
    pub nbytes: usize,
}

/// A weight blob: raw bytes + per-tensor index.
#[derive(Debug, Clone)]
pub struct WeightBlob {
    pub path: PathBuf,
    pub records: Vec<WeightRecord>,
    pub total_bytes: usize,
}

impl WeightBlob {
    fn from_json(dir: &Path, j: &Json) -> anyhow::Result<WeightBlob> {
        let records = j
            .req_arr("tensors")?
            .iter()
            .map(|t| {
                Ok(WeightRecord {
                    spec: TensorSpec::from_json(t)?,
                    offset: t.req_usize("offset")?,
                    nbytes: t.req_usize("nbytes")?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(WeightBlob {
            path: dir.join(j.req_str("path")?),
            records,
            total_bytes: j.req_usize("total_bytes")?,
        })
    }

    /// Read the blob and split it into per-tensor byte vectors by name.
    pub fn load(&self) -> anyhow::Result<BTreeMap<String, Vec<u8>>> {
        let raw = std::fs::read(&self.path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", self.path.display()))?;
        anyhow::ensure!(
            raw.len() == self.total_bytes,
            "weight blob {} has {} bytes, manifest says {}",
            self.path.display(),
            raw.len(),
            self.total_bytes
        );
        let mut out = BTreeMap::new();
        for rec in &self.records {
            anyhow::ensure!(rec.offset + rec.nbytes <= raw.len(), "record out of range");
            anyhow::ensure!(
                rec.nbytes == rec.spec.bytes(),
                "record {} size mismatch", rec.spec.name
            );
            out.insert(
                rec.spec.name.clone(),
                raw[rec.offset..rec.offset + rec.nbytes].to_vec(),
            );
        }
        Ok(out)
    }
}

/// Decode-model geometry recorded in the manifest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeConfig {
    pub vocab: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub max_seq: usize,
    pub group: usize,
    pub params: usize,
    /// Routed expert count (0 = dense FFN; optional in the manifest).
    pub moe_experts: usize,
    /// Experts activated per token (meaningful when `moe_experts > 0`).
    pub moe_topk: usize,
}

impl DecodeConfig {
    fn from_json(j: &Json) -> anyhow::Result<DecodeConfig> {
        Ok(DecodeConfig {
            moe_experts: j.get("moe_experts").and_then(|v| v.as_usize()).unwrap_or(0),
            moe_topk: j.get("moe_topk").and_then(|v| v.as_usize()).unwrap_or(0),
            vocab: j.req_usize("vocab")?,
            hidden: j.req_usize("hidden")?,
            layers: j.req_usize("layers")?,
            heads: j.req_usize("heads")?,
            ffn: j.req_usize("ffn")?,
            max_seq: j.req_usize("max_seq")?,
            group: j.req_usize("group")?,
            params: j.req_usize("params")?,
        })
    }
}

/// One AOT artifact (a compiled HLO module plus its I/O contract).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: String,
    pub hlo_path: PathBuf,
    pub strategy: Option<String>,
    pub gemm: Option<(usize, usize, usize)>, // (m, n, k)
    pub splits: usize,
    pub batch: Option<usize>,
    pub model: Option<String>,
    pub config: Option<DecodeConfig>,
    pub weights: Option<WeightBlob>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactEntry>,
    /// The paper's (model, N, K) sweep table (kept in sync with python).
    pub paper_shapes: Vec<(String, usize, usize)>,
    pub batch_sizes: Vec<usize>,
    pub group: usize,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json")).map_err(|e| {
            anyhow::anyhow!(
                "reading {}/manifest.json (run `make artifacts` first): {e}",
                dir.display()
            )
        })?;
        let j = Json::parse(&text)?;
        let mut artifacts = Vec::new();
        for a in j.req_arr("artifacts")? {
            let gemm = match (a.get("m"), a.get("n"), a.get("k")) {
                (Some(m), Some(n), Some(k)) => Some((
                    m.as_usize().unwrap_or(0),
                    n.as_usize().unwrap_or(0),
                    k.as_usize().unwrap_or(0),
                )),
                _ => None,
            };
            artifacts.push(ArtifactEntry {
                name: a.req_str("name")?.to_string(),
                kind: a.req_str("kind")?.to_string(),
                hlo_path: dir.join(a.req_str("path")?),
                strategy: a.get("strategy").and_then(|s| s.as_str()).map(String::from),
                gemm,
                splits: a.get("splits").and_then(|s| s.as_usize()).unwrap_or(1),
                batch: a.get("batch").and_then(|s| s.as_usize()),
                model: a.get("model").and_then(|s| s.as_str()).map(String::from),
                config: match a.get("config") {
                    Some(c) => Some(DecodeConfig::from_json(c)?),
                    None => None,
                },
                weights: match a.get("weights") {
                    Some(w) => Some(WeightBlob::from_json(&dir, w)?),
                    None => None,
                },
                inputs: a
                    .req_arr("inputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_, _>>()?,
                outputs: a
                    .req_arr("outputs")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<_, _>>()?,
            });
        }
        let paper_shapes = j
            .req_arr("paper_shapes")?
            .iter()
            .map(|s| {
                Ok((
                    s.req_str("model")?.to_string(),
                    s.req_usize("n")?,
                    s.req_usize("k")?,
                ))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let batch_sizes = j
            .req_arr("batch_sizes")?
            .iter()
            .filter_map(|v| v.as_usize())
            .collect();
        Ok(Manifest {
            dir,
            artifacts,
            paper_shapes,
            batch_sizes,
            group: j.req_usize("group")?,
        })
    }

    pub fn find(&self, name: &str) -> anyhow::Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))
    }

    /// All GEMM artifacts of one strategy.
    pub fn gemms(&self, strategy: &str) -> Vec<&ArtifactEntry> {
        self.artifacts
            .iter()
            .filter(|a| a.kind == "gemm" && a.strategy.as_deref() == Some(strategy))
            .collect()
    }

    /// Decode artifact for (model, batch).
    pub fn decode(&self, model: &str, batch: usize) -> anyhow::Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| {
                a.kind == "decode"
                    && a.model.as_deref() == Some(model)
                    && a.batch == Some(batch)
            })
            .ok_or_else(|| anyhow::anyhow!("no decode artifact for {model} b={batch}"))
    }

    /// Batch sizes available for a decode model, ascending.
    pub fn decode_batches(&self, model: &str) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.kind == "decode" && a.model.as_deref() == Some(model))
            .filter_map(|a| a.batch)
            .collect();
        v.sort_unstable();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const REPO_ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

    fn have_artifacts() -> bool {
        std::path::Path::new(REPO_ARTIFACTS).join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: run `make artifacts`");
            return;
        }
        let m = Manifest::load(REPO_ARTIFACTS).unwrap();
        assert_eq!(m.group, 128);
        assert!(m.artifacts.len() >= 16);
        assert_eq!(m.paper_shapes.len(), 12);
        // every strategy present
        for s in ["splitk", "dp", "fused", "fp16"] {
            assert!(!m.gemms(s).is_empty(), "missing {s} artifacts");
        }
    }

    #[test]
    fn gemm_artifact_contract() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(REPO_ARTIFACTS).unwrap();
        let a = m.find("splitk_m16_n256_k512").unwrap();
        assert_eq!(a.gemm, Some((16, 256, 512)));
        assert_eq!(a.inputs.len(), 4);
        assert_eq!(a.inputs[1].dtype, DType::I8);
        assert_eq!(a.inputs[1].shape, vec![256, 256]);
        assert_eq!(a.outputs[0].shape, vec![16, 256]);
        assert!(a.hlo_path.exists());
    }

    #[test]
    fn decode_artifact_and_weights() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(REPO_ARTIFACTS).unwrap();
        let a = m.decode("tiny", 1).unwrap();
        let cfg = a.config.unwrap();
        assert_eq!(cfg.layers, 2);
        let weights = a.weights.as_ref().unwrap().load().unwrap();
        assert!(weights.contains_key("embed"));
        assert!(weights.contains_key("layer0.qkv.packed"));
        // decode inputs: 3 io + all params
        assert_eq!(a.inputs.len(), 3 + weights.len());
        assert_eq!(m.decode_batches("tiny"), vec![1, 4]);
    }

    #[test]
    fn missing_artifact_errors() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(REPO_ARTIFACTS).unwrap();
        assert!(m.find("nope").is_err());
        assert!(m.decode("tiny", 999).is_err());
    }
}
