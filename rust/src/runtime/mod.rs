//! PJRT runtime: loads the AOT-compiled HLO artifacts (lowered from
//! JAX + Pallas at build time) and executes them on the request path.
//!
//! Python never runs here.  The interchange format is HLO *text*
//! (`artifacts/*.hlo.txt`): jax >= 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects, while the text parser
//! reassigns ids cleanly (see `python/compile/aot.py`).
//!
//! * [`artifacts`] — manifest parsing (`artifacts/manifest.json`) and
//!   weight-blob loading.
//! * [`client`] — `PjRtClient` wrapper: compile HLO text, typed host
//!   tensors <-> literals, executable cache.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactEntry, Manifest, TensorSpec, WeightBlob};
pub use client::{retry_with_backoff, Executable, HostTensor, RetryPolicy, RetryStats, Runtime};
