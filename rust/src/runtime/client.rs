//! PJRT client wrapper: compile HLO text, move typed host tensors across
//! the boundary, cache compiled executables.
//!
//! The `xla` bindings are only present when the `pjrt` cargo feature is
//! enabled (the offline build image does not ship them).  Without the
//! feature this module compiles a stub with the same API surface whose
//! operations fail cleanly at artifact-load / literal-conversion time, so
//! the simulator, tuner and coordinator logic build and test everywhere.

use std::path::Path;

#[cfg(feature = "pjrt")]
use std::collections::HashMap;
#[cfg(feature = "pjrt")]
use std::sync::Mutex;

use crate::tensor::DType;
use crate::util::f16;
use crate::util::prng::Rng;

use super::artifacts::{ArtifactEntry, TensorSpec};

/// Retry-with-exponential-backoff policy for transient execution faults
/// (DESIGN.md §14).  Backoff is *virtual*: [`RetryPolicy::backoff_us`]
/// returns the wait instead of sleeping it, so the serving loop advances
/// its own clock and tests stay fast and deterministic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts, including the first (1 = no retries).
    pub max_attempts: u32,
    /// Backoff before the first retry (µs).
    pub base_backoff_us: u64,
    /// Backoff ceiling (µs) — the exponential curve saturates here.
    pub cap_backoff_us: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 4, base_backoff_us: 500, cap_backoff_us: 8_000 }
    }
}

impl RetryPolicy {
    /// Virtual backoff before retrying after failed attempt `attempt`
    /// (0-based): exponential in the attempt, capped, with ±25% jitter
    /// drawn from the caller's seeded PRNG so replays are bit-identical.
    pub fn backoff_us(&self, attempt: u32, rng: &mut Rng) -> u64 {
        let exp = self.base_backoff_us.saturating_mul(1u64 << attempt.min(20));
        let capped = exp.min(self.cap_backoff_us).max(1);
        let jitter = 0.75 + 0.5 * rng.f64();
        ((capped as f64 * jitter) as u64).max(1)
    }
}

/// What a retried operation cost: attempts burned and virtual backoff
/// accumulated (the caller charges it to its clock).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Attempts executed (1 = first try succeeded).
    pub attempts: u32,
    /// Summed virtual backoff between attempts (µs).
    pub backoff_us: u64,
}

/// Run `op` under a retry policy.  `op` receives the 0-based attempt
/// index; the final error propagates once attempts are exhausted.  The
/// stats are returned in both cases so callers can charge the backoff.
pub fn retry_with_backoff<T>(
    policy: &RetryPolicy,
    rng: &mut Rng,
    mut op: impl FnMut(u32) -> anyhow::Result<T>,
) -> (anyhow::Result<T>, RetryStats) {
    let mut stats = RetryStats::default();
    let attempts = policy.max_attempts.max(1);
    for attempt in 0..attempts {
        stats.attempts = attempt + 1;
        match op(attempt) {
            Ok(v) => return (Ok(v), stats),
            Err(e) => {
                if attempt + 1 >= attempts {
                    return (Err(e), stats);
                }
                stats.backoff_us += policy.backoff_us(attempt, rng);
            }
        }
    }
    unreachable!("retry loop returns on the final attempt")
}

/// Device-side literal handle.  With `pjrt` this is the real
/// `xla::Literal`; otherwise an opaque placeholder that can never be
/// constructed through the public API (every constructor errors).
#[cfg(feature = "pjrt")]
pub type Literal = xla::Literal;

/// Stub literal for builds without the `pjrt` feature.
#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct Literal {
    _opaque: (),
}

#[cfg(not(feature = "pjrt"))]
fn no_pjrt<T>(what: &str) -> anyhow::Result<T> {
    anyhow::bail!("{what} requires the 'pjrt' cargo feature (xla bindings not built in)")
}

/// A typed host tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    /// Raw little-endian f16 payloads (the host treats them as opaque).
    F16Bytes(Vec<u8>),
}

impl HostTensor {
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I8(_) => DType::I8,
            HostTensor::I32(_) => DType::I32,
            HostTensor::F16Bytes(_) => DType::F16,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I8(v) => v.len(),
            HostTensor::I32(v) => v.len(),
            HostTensor::F16Bytes(v) => v.len() / 2,
        }
    }

    // Only the real `to_literal` consumes this outside of tests.
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn bytes(&self) -> Vec<u8> {
        match self {
            HostTensor::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            HostTensor::I8(v) => v.iter().map(|&x| x as u8).collect(),
            HostTensor::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            HostTensor::F16Bytes(v) => v.clone(),
        }
    }

    #[cfg(feature = "pjrt")]
    fn element_type(&self) -> xla::ElementType {
        match self {
            HostTensor::F32(_) => xla::ElementType::F32,
            HostTensor::I8(_) => xla::ElementType::S8,
            HostTensor::I32(_) => xla::ElementType::S32,
            HostTensor::F16Bytes(_) => xla::ElementType::F16,
        }
    }

    /// Convert into a PJRT literal of the given shape.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self, shape: &[usize]) -> anyhow::Result<Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(
            n == self.elements(),
            "shape {shape:?} has {n} elements, tensor has {}",
            self.elements()
        );
        xla::Literal::create_from_shape_and_untyped_data(
            self.element_type(),
            shape,
            &self.bytes(),
        )
        .map_err(|e| anyhow::anyhow!("literal creation failed: {e}"))
    }

    /// Convert into a PJRT literal of the given shape (stub: errors).
    #[cfg(not(feature = "pjrt"))]
    pub fn to_literal(&self, shape: &[usize]) -> anyhow::Result<Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(
            n == self.elements(),
            "shape {shape:?} has {n} elements, tensor has {}",
            self.elements()
        );
        no_pjrt("literal creation")
    }

    /// Build from raw bytes + a manifest spec (weight blobs).
    pub fn from_bytes(dtype: DType, raw: &[u8]) -> anyhow::Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => HostTensor::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::I8 => HostTensor::I8(raw.iter().map(|&b| b as i8).collect()),
            DType::I32 => HostTensor::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::F16 => HostTensor::F16Bytes(raw.to_vec()),
        })
    }

    /// View as f32s (converting f16 payloads; errors on integer tensors).
    pub fn as_f32(&self) -> anyhow::Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v.clone()),
            HostTensor::F16Bytes(v) => Ok(f16::f16_bytes_to_f32_vec(v)),
            other => anyhow::bail!("tensor is {:?}, not float", other.dtype()),
        }
    }
}

/// Read a literal back into a typed host tensor.
#[cfg(feature = "pjrt")]
pub fn literal_to_host(lit: &Literal) -> anyhow::Result<HostTensor> {
    use xla::ElementType as E;
    Ok(match lit.ty()? {
        E::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
        E::S8 => HostTensor::I8(lit.to_vec::<i8>()?),
        E::S32 => HostTensor::I32(lit.to_vec::<i32>()?),
        E::F16 => {
            // No native f16 host type: copy raw u16 payloads.
            let n = lit.element_count();
            let mut buf = vec![0u16; n];
            lit.copy_raw_to::<u16>(&mut buf)
                .map_err(|e| anyhow::anyhow!("raw f16 copy: {e}"))?;
            HostTensor::F16Bytes(buf.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        other => anyhow::bail!("unsupported output element type {other:?}"),
    })
}

/// Read a literal back into a typed host tensor (stub: unreachable, since
/// stub literals cannot be constructed).
#[cfg(not(feature = "pjrt"))]
pub fn literal_to_host(_lit: &Literal) -> anyhow::Result<HostTensor> {
    no_pjrt("literal readback")
}

/// A compiled artifact bound to its I/O contract.
pub struct Executable {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    #[cfg(feature = "pjrt")]
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns decomposed output literals.
    pub fn run(&self, args: &[HostTensor]) -> anyhow::Result<Vec<Literal>> {
        anyhow::ensure!(
            args.len() == self.inputs.len(),
            "{}: got {} args, artifact expects {}",
            self.name,
            args.len(),
            self.inputs.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.inputs) {
            anyhow::ensure!(
                arg.dtype() == spec.dtype,
                "{}: input '{}' expects {:?}, got {:?}",
                self.name, spec.name, spec.dtype, arg.dtype()
            );
            literals.push(arg.to_literal(&spec.shape)?);
        }
        self.run_literals(&literals)
    }

    /// Execute with host tensors under a retry policy: transient execute
    /// failures back off (virtually — see [`RetryPolicy`]) and retry up
    /// to `policy.max_attempts` times before the last error propagates.
    pub fn run_with_retry(
        &self,
        args: &[HostTensor],
        policy: &RetryPolicy,
        rng: &mut Rng,
    ) -> (anyhow::Result<Vec<Literal>>, RetryStats) {
        retry_with_backoff(policy, rng, |_| self.run(args))
    }

    /// Execute with prepared literals (hot path: no host conversion).
    #[cfg(feature = "pjrt")]
    pub fn run_literals(&self, literals: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<Literal>(literals)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e}", self.name))?;
        Self::unwrap_tuple(&self.name, result)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn run_literals(&self, _literals: &[Literal]) -> anyhow::Result<Vec<Literal>> {
        no_pjrt("execution")
    }

    /// Execute with borrowed literals — avoids cloning staged weights on
    /// the serving hot path.
    #[cfg(feature = "pjrt")]
    pub fn run_literals_ref(&self, literals: &[&Literal]) -> anyhow::Result<Vec<Literal>> {
        let result = self
            .exe
            .execute::<&Literal>(literals)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e}", self.name))?;
        Self::unwrap_tuple(&self.name, result)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn run_literals_ref(&self, _literals: &[&Literal]) -> anyhow::Result<Vec<Literal>> {
        no_pjrt("execution")
    }

    #[cfg(feature = "pjrt")]
    fn unwrap_tuple(
        name: &str,
        result: Vec<Vec<xla::PjRtBuffer>>,
    ) -> anyhow::Result<Vec<Literal>> {
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name}: readback failed: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("{name}: tuple decompose failed: {e}"))
    }
}

/// The PJRT runtime: one CPU client + a compiled-executable cache.
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: xla::PjRtClient,
    #[cfg(feature = "pjrt")]
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
    #[cfg(not(feature = "pjrt"))]
    _private: (),
}

impl Runtime {
    /// Create a CPU PJRT client.
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    /// Create a stub runtime (no PJRT): succeeds so callers can construct
    /// the serving stack, but any artifact load errors cleanly.
    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> anyhow::Result<Runtime> {
        Ok(Runtime { _private: () })
    }

    pub fn platform(&self) -> String {
        #[cfg(feature = "pjrt")]
        {
            self.client.platform_name()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            "stub (built without the 'pjrt' feature)".to_string()
        }
    }

    /// Compile HLO text from a file (uncached).
    #[cfg(feature = "pjrt")]
    pub fn compile_file(
        &self,
        name: &str,
        path: &Path,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
    ) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        Ok(Executable { name: name.to_string(), inputs, outputs, exe })
    }

    /// Compile HLO text from a file (stub: reads the file so missing-path
    /// errors stay informative, then reports the missing feature).
    #[cfg(not(feature = "pjrt"))]
    pub fn compile_file(
        &self,
        name: &str,
        path: &Path,
        _inputs: Vec<TensorSpec>,
        _outputs: Vec<TensorSpec>,
    ) -> anyhow::Result<Executable> {
        std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        no_pjrt(&format!("compiling '{name}'"))
    }

    /// Compile a manifest artifact, with caching by name.
    #[cfg(feature = "pjrt")]
    pub fn load(&self, entry: &ArtifactEntry) -> anyhow::Result<std::sync::Arc<Executable>> {
        if let Some(hit) = self.cache.lock().unwrap().get(&entry.name) {
            return Ok(hit.clone());
        }
        let exe = std::sync::Arc::new(self.compile_file(
            &entry.name,
            &entry.hlo_path,
            entry.inputs.clone(),
            entry.outputs.clone(),
        )?);
        self.cache
            .lock()
            .unwrap()
            .insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    #[cfg(not(feature = "pjrt"))]
    pub fn load(&self, entry: &ArtifactEntry) -> anyhow::Result<std::sync::Arc<Executable>> {
        self.compile_file(
            &entry.name,
            &entry.hlo_path,
            entry.inputs.clone(),
            entry.outputs.clone(),
        )
        .map(std::sync::Arc::new)
    }

    /// Number of cached executables (metrics).
    pub fn cached(&self) -> usize {
        #[cfg(feature = "pjrt")]
        {
            self.cache.lock().unwrap().len()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_round_trips_bytes() {
        let t = HostTensor::F32(vec![1.0, -2.5]);
        let b = t.bytes();
        let back = HostTensor::from_bytes(DType::F32, &b).unwrap();
        assert_eq!(back.as_f32().unwrap(), vec![1.0, -2.5]);
    }

    #[test]
    fn i8_preserves_sign_bits() {
        let t = HostTensor::I8(vec![-1, 0x21]);
        let b = t.bytes();
        assert_eq!(b, vec![0xFF, 0x21]);
        match HostTensor::from_bytes(DType::I8, &b).unwrap() {
            HostTensor::I8(v) => assert_eq!(v, vec![-1, 0x21]),
            _ => panic!(),
        }
    }

    #[test]
    fn f16_payloads_convert() {
        let raw = crate::util::f16::f32_slice_to_f16_bytes(&[0.5, -1.0]);
        let t = HostTensor::from_bytes(DType::F16, &raw).unwrap();
        assert_eq!(t.as_f32().unwrap(), vec![0.5, -1.0]);
        assert_eq!(t.elements(), 2);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        let t = HostTensor::F32(vec![1.0; 6]);
        assert!(t.to_literal(&[2, 2]).is_err());
        #[cfg(feature = "pjrt")]
        assert!(t.to_literal(&[2, 3]).is_ok());
    }

    #[test]
    fn retry_succeeds_after_transient_failures() {
        let policy = RetryPolicy::default();
        let mut rng = Rng::new(1);
        let mut calls = 0;
        let (result, stats) = retry_with_backoff(&policy, &mut rng, |attempt| {
            calls += 1;
            anyhow::ensure!(attempt >= 2, "transient failure at attempt {attempt}");
            Ok(attempt)
        });
        assert_eq!(result.unwrap(), 2);
        assert_eq!(calls, 3);
        assert_eq!(stats.attempts, 3);
        assert!(stats.backoff_us > 0, "two retries must accumulate backoff");
    }

    #[test]
    fn retry_exhaustion_returns_last_error() {
        let policy = RetryPolicy { max_attempts: 3, base_backoff_us: 10, cap_backoff_us: 20 };
        let mut rng = Rng::new(2);
        let (result, stats) =
            retry_with_backoff(&policy, &mut rng, |attempt| -> anyhow::Result<()> {
                anyhow::bail!("always fails (attempt {attempt})")
            });
        let err = result.unwrap_err().to_string();
        assert!(err.contains("attempt 2"), "last error must surface: {err}");
        assert_eq!(stats.attempts, 3);
    }

    #[test]
    fn backoff_is_exponential_capped_and_deterministic() {
        let policy = RetryPolicy { max_attempts: 8, base_backoff_us: 100, cap_backoff_us: 1_000 };
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for attempt in 0..8 {
            let ba = policy.backoff_us(attempt, &mut a);
            assert_eq!(ba, policy.backoff_us(attempt, &mut b), "same seed, same jitter");
            // ±25% jitter around min(base * 2^attempt, cap).
            let nominal = (100u64 << attempt).min(1_000) as f64;
            assert!(ba as f64 >= nominal * 0.75 - 1.0, "attempt {attempt}: {ba}");
            assert!(ba as f64 <= nominal * 1.25 + 1.0, "attempt {attempt}: {ba}");
        }
    }

    #[test]
    fn zero_attempt_policy_still_runs_once() {
        let policy = RetryPolicy { max_attempts: 0, base_backoff_us: 1, cap_backoff_us: 1 };
        let mut rng = Rng::new(3);
        let (result, stats) = retry_with_backoff(&policy, &mut rng, |_| Ok(7));
        assert_eq!(result.unwrap(), 7);
        assert_eq!(stats.attempts, 1);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_runtime_constructs_but_cannot_compile() {
        let rt = Runtime::cpu().unwrap();
        assert!(rt.platform().contains("stub"));
        assert_eq!(rt.cached(), 0);
        let err = rt
            .compile_file("x", Path::new("/nonexistent.hlo.txt"), vec![], vec![])
            .unwrap_err()
            .to_string();
        assert!(err.contains("/nonexistent.hlo.txt"), "{err}");
    }
}
