//! PJRT client wrapper: compile HLO text, move typed host tensors across
//! the boundary, cache compiled executables.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::tensor::DType;
use crate::util::f16;

use super::artifacts::{ArtifactEntry, TensorSpec};

/// A typed host tensor crossing the PJRT boundary.
#[derive(Debug, Clone)]
pub enum HostTensor {
    F32(Vec<f32>),
    I8(Vec<i8>),
    I32(Vec<i32>),
    /// Raw little-endian f16 payloads (the host treats them as opaque).
    F16Bytes(Vec<u8>),
}

impl HostTensor {
    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(_) => DType::F32,
            HostTensor::I8(_) => DType::I8,
            HostTensor::I32(_) => DType::I32,
            HostTensor::F16Bytes(_) => DType::F16,
        }
    }

    pub fn elements(&self) -> usize {
        match self {
            HostTensor::F32(v) => v.len(),
            HostTensor::I8(v) => v.len(),
            HostTensor::I32(v) => v.len(),
            HostTensor::F16Bytes(v) => v.len() / 2,
        }
    }

    fn bytes(&self) -> Vec<u8> {
        match self {
            HostTensor::F32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            HostTensor::I8(v) => v.iter().map(|&x| x as u8).collect(),
            HostTensor::I32(v) => v.iter().flat_map(|x| x.to_le_bytes()).collect(),
            HostTensor::F16Bytes(v) => v.clone(),
        }
    }

    fn element_type(&self) -> xla::ElementType {
        match self {
            HostTensor::F32(_) => xla::ElementType::F32,
            HostTensor::I8(_) => xla::ElementType::S8,
            HostTensor::I32(_) => xla::ElementType::S32,
            HostTensor::F16Bytes(_) => xla::ElementType::F16,
        }
    }

    /// Convert into a PJRT literal of the given shape.
    pub fn to_literal(&self, shape: &[usize]) -> anyhow::Result<xla::Literal> {
        let n: usize = shape.iter().product();
        anyhow::ensure!(
            n == self.elements(),
            "shape {shape:?} has {n} elements, tensor has {}",
            self.elements()
        );
        xla::Literal::create_from_shape_and_untyped_data(
            self.element_type(),
            shape,
            &self.bytes(),
        )
        .map_err(|e| anyhow::anyhow!("literal creation failed: {e}"))
    }

    /// Build from raw bytes + a manifest spec (weight blobs).
    pub fn from_bytes(dtype: DType, raw: &[u8]) -> anyhow::Result<HostTensor> {
        Ok(match dtype {
            DType::F32 => HostTensor::F32(
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::I8 => HostTensor::I8(raw.iter().map(|&b| b as i8).collect()),
            DType::I32 => HostTensor::I32(
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            ),
            DType::F16 => HostTensor::F16Bytes(raw.to_vec()),
        })
    }

    /// View as f32s (converting f16 payloads; errors on integer tensors).
    pub fn as_f32(&self) -> anyhow::Result<Vec<f32>> {
        match self {
            HostTensor::F32(v) => Ok(v.clone()),
            HostTensor::F16Bytes(v) => Ok(f16::f16_bytes_to_f32_vec(v)),
            other => anyhow::bail!("tensor is {:?}, not float", other.dtype()),
        }
    }
}

/// Read a literal back into a typed host tensor.
pub fn literal_to_host(lit: &xla::Literal) -> anyhow::Result<HostTensor> {
    use xla::ElementType as E;
    Ok(match lit.ty()? {
        E::F32 => HostTensor::F32(lit.to_vec::<f32>()?),
        E::S8 => HostTensor::I8(lit.to_vec::<i8>()?),
        E::S32 => HostTensor::I32(lit.to_vec::<i32>()?),
        E::F16 => {
            // No native f16 host type: copy raw u16 payloads.
            let n = lit.element_count();
            let mut buf = vec![0u16; n];
            lit.copy_raw_to::<u16>(&mut buf)
                .map_err(|e| anyhow::anyhow!("raw f16 copy: {e}"))?;
            HostTensor::F16Bytes(buf.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        other => anyhow::bail!("unsupported output element type {other:?}"),
    })
}

/// A compiled artifact bound to its I/O contract.
pub struct Executable {
    pub name: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with host tensors; returns decomposed output literals.
    pub fn run(&self, args: &[HostTensor]) -> anyhow::Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            args.len() == self.inputs.len(),
            "{}: got {} args, artifact expects {}",
            self.name,
            args.len(),
            self.inputs.len()
        );
        let mut literals = Vec::with_capacity(args.len());
        for (arg, spec) in args.iter().zip(&self.inputs) {
            anyhow::ensure!(
                arg.dtype() == spec.dtype,
                "{}: input '{}' expects {:?}, got {:?}",
                self.name, spec.name, spec.dtype, arg.dtype()
            );
            literals.push(arg.to_literal(&spec.shape)?);
        }
        self.run_literals(&literals)
    }

    /// Execute with prepared literals (hot path: no host conversion).
    pub fn run_literals(&self, literals: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(literals)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e}", self.name))?;
        Self::unwrap_tuple(&self.name, result)
    }

    /// Execute with borrowed literals — avoids cloning staged weights on
    /// the serving hot path.
    pub fn run_literals_ref(
        &self,
        literals: &[&xla::Literal],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow::anyhow!("{}: execute failed: {e}", self.name))?;
        Self::unwrap_tuple(&self.name, result)
    }

    fn unwrap_tuple(
        name: &str,
        result: Vec<Vec<xla::PjRtBuffer>>,
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name}: readback failed: {e}"))?;
        // aot.py lowers with return_tuple=True: always a tuple.
        tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("{name}: tuple decompose failed: {e}"))
    }
}

/// The PJRT runtime: one CPU client + a compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime { client, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile HLO text from a file (uncached).
    pub fn compile_file(
        &self,
        name: &str,
        path: &Path,
        inputs: Vec<TensorSpec>,
        outputs: Vec<TensorSpec>,
    ) -> anyhow::Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
        Ok(Executable { name: name.to_string(), inputs, outputs, exe })
    }

    /// Compile a manifest artifact, with caching by name.
    pub fn load(&self, entry: &ArtifactEntry) -> anyhow::Result<std::sync::Arc<Executable>> {
        if let Some(hit) = self.cache.lock().unwrap().get(&entry.name) {
            return Ok(hit.clone());
        }
        let exe = std::sync::Arc::new(self.compile_file(
            &entry.name,
            &entry.hlo_path,
            entry.inputs.clone(),
            entry.outputs.clone(),
        )?);
        self.cache
            .lock()
            .unwrap()
            .insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Number of cached executables (metrics).
    pub fn cached(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_tensor_round_trips_bytes() {
        let t = HostTensor::F32(vec![1.0, -2.5]);
        let b = t.bytes();
        let back = HostTensor::from_bytes(DType::F32, &b).unwrap();
        assert_eq!(back.as_f32().unwrap(), vec![1.0, -2.5]);
    }

    #[test]
    fn i8_preserves_sign_bits() {
        let t = HostTensor::I8(vec![-1, 0x21]);
        let b = t.bytes();
        assert_eq!(b, vec![0xFF, 0x21]);
        match HostTensor::from_bytes(DType::I8, &b).unwrap() {
            HostTensor::I8(v) => assert_eq!(v, vec![-1, 0x21]),
            _ => panic!(),
        }
    }

    #[test]
    fn f16_payloads_convert() {
        let raw = crate::util::f16::f32_slice_to_f16_bytes(&[0.5, -1.0]);
        let t = HostTensor::from_bytes(DType::F16, &raw).unwrap();
        assert_eq!(t.as_f32().unwrap(), vec![0.5, -1.0]);
        assert_eq!(t.elements(), 2);
    }

    #[test]
    fn literal_shape_mismatch_errors() {
        let t = HostTensor::F32(vec![1.0; 6]);
        assert!(t.to_literal(&[2, 2]).is_err());
        assert!(t.to_literal(&[2, 3]).is_ok());
    }
}
