//! Model-level definitions: the paper's LLM shape tables and the decode
//! engine that drives the AOT decode-step artifacts.

pub mod decode;
pub mod llm;

pub use decode::{synthetic_next_token, DecodeEngine, Engine, SimEngine, StepOutput};
pub use llm::{paper_shapes, LlmShape, PAPER_BATCH_SIZES};
