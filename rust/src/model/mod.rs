//! Model-level definitions: the paper's LLM shape tables and the decode
//! engine that drives the AOT decode-step artifacts.

pub mod decode;
pub mod kv_cache;
pub mod llm;
pub mod quant;

pub use decode::{synthetic_next_token, DecodeEngine, Engine, SimEngine, StepOutput};
pub use kv_cache::{kv_bytes_per_token, KvPager, DEFAULT_PAGE_BYTES};
pub use llm::{paper_shapes, LlmShape, PAPER_BATCH_SIZES};
pub use quant::Precision;
