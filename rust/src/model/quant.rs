//! Precision families for mixed-precision GEMM (DESIGN.md §16).
//!
//! The paper's kernel is W4A16: INT4 group-quantized weights, FP16
//! activations, FP16 MMAD on the cube core.  Opening the precision axis
//! as a first-class model lets the schedules and the tuner reason about
//! a *family* of precisions instead of hard-coding one: each member
//! fixes the bits per weight, the bits per activation, and therefore the
//! HBM stream width of every buffer class and the MACs-per-cycle the
//! cube core retires.
//!
//! W4A8 (the LiquidGEMM/ANT lineage): weights stay INT4, activations
//! are quantized to INT8 by a vector prologue, and the cube core runs
//! INT8 MMAD at twice the FP16 MAC rate.  The activation stream to the
//! MTEs halves; the price is the activation-quantize vector pass and a
//! per-group rescale that the schedule may defer into the reduce
//! epilogue (the `rebalance` tiling knob).

/// One member of the precision family: weight bits x activation bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Default)]
pub enum Precision {
    /// INT4 weights, FP16 activations, FP16 MMAD (the paper's kernel).
    #[default]
    W4A16,
    /// INT4 weights, INT8 activations, INT8 MMAD at 2x the MAC rate.
    W4A8,
}

impl Precision {
    /// Accepted `--precision` spellings, first alias canonical.
    pub const CHOICES: &'static [(&'static [&'static str], Precision)] =
        &[(&["w4a16"], Precision::W4A16), (&["w4a8"], Precision::W4A8)];

    pub fn name(&self) -> &'static str {
        match self {
            Precision::W4A16 => "w4a16",
            Precision::W4A8 => "w4a8",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Precision> {
        let lower = name.to_ascii_lowercase();
        for (aliases, precision) in Self::CHOICES {
            if aliases.contains(&lower.as_str()) {
                return Ok(*precision);
            }
        }
        anyhow::bail!("unknown precision '{name}' (expected w4a16 or w4a8)")
    }

    /// Bits per packed weight element (both members pack INT4).
    pub fn weight_bits(&self) -> u32 {
        4
    }

    /// Bits per activation element as streamed to the cube core.
    pub fn activation_bits(&self) -> u32 {
        match self {
            Precision::W4A16 => 16,
            Precision::W4A8 => 8,
        }
    }

    /// Bytes per activation element (the A-tile MTE stream width).
    pub fn activation_bytes(&self) -> usize {
        (self.activation_bits() / 8) as usize
    }

    /// Bytes per element of the dequantized/quantized weight workspace the
    /// cube core consumes (FP16 for W4A16, INT8 codes for W4A8).
    pub fn workspace_bytes_per_elem(&self) -> usize {
        match self {
            Precision::W4A16 => 2,
            Precision::W4A8 => 1,
        }
    }

    /// MACs per cube core per cycle at this operand width.
    pub fn cube_macs_per_cycle(&self, machine: &crate::ascend::MachineConfig) -> f64 {
        match self {
            Precision::W4A16 => machine.cube_macs_per_cycle,
            Precision::W4A8 => machine.cube_macs_per_cycle_int8,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::MachineConfig;

    #[test]
    fn names_round_trip() {
        for p in [Precision::W4A16, Precision::W4A8] {
            assert_eq!(Precision::from_name(p.name()).unwrap(), p);
        }
        assert_eq!(Precision::from_name("W4A8").unwrap(), Precision::W4A8);
        assert!(Precision::from_name("w4a4").is_err());
    }

    #[test]
    fn default_is_the_paper_kernel() {
        assert_eq!(Precision::default(), Precision::W4A16);
    }

    #[test]
    fn stream_widths_halve_from_a16_to_a8() {
        assert_eq!(Precision::W4A16.activation_bytes(), 2);
        assert_eq!(Precision::W4A8.activation_bytes(), 1);
        assert_eq!(Precision::W4A16.workspace_bytes_per_elem(), 2);
        assert_eq!(Precision::W4A8.workspace_bytes_per_elem(), 1);
        assert_eq!(Precision::W4A16.weight_bits(), Precision::W4A8.weight_bits());
    }

    #[test]
    fn int8_mac_rate_doubles() {
        let m = MachineConfig::ascend910();
        assert_eq!(
            Precision::W4A8.cube_macs_per_cycle(&m),
            2.0 * Precision::W4A16.cube_macs_per_cycle(&m)
        );
    }
}
