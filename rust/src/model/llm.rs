//! The paper's evaluation shape table (§4.1): decode-phase GEMM shapes
//! from OpenPangu, DeepSeek-R1, GLM-4.5 and LLaMA-3.2.
//!
//! Rust twin of `python/compile/configs.PAPER_SHAPES`; the integration
//! tests cross-check this table against the artifact manifest so the two
//! sides cannot drift.

/// One (model, N, K) row: weights are `K x N`, activations `M x K`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LlmShape {
    pub model: &'static str,
    pub n: usize,
    pub k: usize,
}

impl LlmShape {
    /// The paper's "K >> N" decode regime where Split-K is claimed to win.
    pub fn k_dominant(&self) -> bool {
        self.k >= 2 * self.n
    }

    pub fn tag(&self) -> String {
        format!("{}-n{}-k{}", self.model, self.n, self.k)
    }
}

/// Batch sizes (M) swept in Figures 2 and 3.
pub const PAPER_BATCH_SIZES: [usize; 7] = [1, 2, 4, 8, 16, 32, 64];

/// The twelve decode GEMM shapes of the evaluation sweep.
pub fn paper_shapes() -> Vec<LlmShape> {
    vec![
        // LLaMA-3.2-1B: hidden 2048, ffn 8192
        LlmShape { model: "llama32", n: 2048, k: 2048 },
        LlmShape { model: "llama32", n: 8192, k: 2048 },
        LlmShape { model: "llama32", n: 2048, k: 8192 },
        // GLM-4.5 dense trunk: hidden 5120, ffn 12288
        LlmShape { model: "glm45", n: 5120, k: 5120 },
        LlmShape { model: "glm45", n: 12288, k: 5120 },
        LlmShape { model: "glm45", n: 5120, k: 12288 },
        // DeepSeek-R1: hidden 7168, expert inner 2048, kv-lora 1536
        LlmShape { model: "deepseek", n: 7168, k: 7168 },
        LlmShape { model: "deepseek", n: 2048, k: 7168 },
        LlmShape { model: "deepseek", n: 7168, k: 2048 },
        LlmShape { model: "deepseek", n: 1536, k: 7168 },
        // OpenPangu dense: hidden 7680, low-rank projection 1536
        LlmShape { model: "openpangu", n: 7680, k: 7680 },
        LlmShape { model: "openpangu", n: 1536, k: 7680 },
    ]
}

/// Geometry of one dense decoder layer: the four projection GEMMs a decode
/// step issues are fully determined by these widths (see
/// [`crate::workload::decode_layer::DecodeLayer`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerGeometry {
    /// Model hidden width (activations, attention output, down output).
    pub hidden: usize,
    /// FFN inner width (the K of the paper's bottleneck down-projection).
    pub ffn: usize,
    /// K/V projection width: `hidden` for vanilla MHA, lower for the
    /// GQA / low-rank (MLA-style) variants in the shape table.
    pub kv: usize,
    /// Weight-quantization group size along K.
    pub group: usize,
}

impl LayerGeometry {
    /// Vanilla multi-head attention: K/V width equals the hidden width.
    pub fn mha(hidden: usize, ffn: usize) -> LayerGeometry {
        LayerGeometry { hidden, ffn, kv: hidden, group: 128 }
    }
}

/// Routed mixture-of-experts geometry of one decoder layer: the FFN block
/// is replaced by `experts` routed experts of inner width `expert_ffn`,
/// `topk` of which fire per token.  At decode batch M the M·topk routed
/// (token, expert) pairs group into batched small-N / large-K expert GEMMs
/// (see [`crate::workload::decode_layer::DecodeLayer::gemm_nodes`]) — the
/// regime LiquidGEMM's serving-level evaluation argues matters most, and a
/// natural fit for the chunked schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MoeGeometry {
    /// Routed expert count E.
    pub experts: usize,
    /// Experts activated per token (top-k routing).
    pub topk: usize,
    /// Expert FFN inner width (the K of the expert down-projection).
    pub expert_ffn: usize,
}

impl MoeGeometry {
    /// Routed (token, expert) pairs at decode batch `batch`.
    pub fn routed_pairs(&self, batch: usize) -> usize {
        batch * self.topk
    }

    /// Experts with at least one routed token, under the balanced-routing
    /// assumption the simulator prices (load balancing is the router's
    /// job; imbalance only shifts work between identical GEMMs).
    pub fn active_experts(&self, batch: usize) -> usize {
        self.routed_pairs(batch).min(self.experts).max(1)
    }

    /// Tokens each active expert batches into its GEMMs (balanced routing,
    /// rounded up — stragglers pad to the cube tile anyway).
    pub fn tokens_per_expert(&self, batch: usize) -> usize {
        self.routed_pairs(batch).div_ceil(self.active_experts(batch))
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.experts >= 1, "MoE needs at least one expert");
        anyhow::ensure!(
            self.topk >= 1 && self.topk <= self.experts,
            "topk={} must be in 1..=experts={}",
            self.topk,
            self.experts
        );
        anyhow::ensure!(self.expert_ffn >= 1, "expert_ffn must be positive");
        Ok(())
    }
}

/// Decoder-layer geometry per evaluated model, consistent with the
/// [`paper_shapes`] table (the up/down projections of each model appear
/// there as (N, K) rows; the kv widths come from the low-rank rows).
pub fn paper_layer_geometries() -> Vec<(&'static str, LayerGeometry)> {
    vec![
        ("llama32", LayerGeometry::mha(2048, 8192)),
        ("glm45", LayerGeometry::mha(5120, 12288)),
        // DeepSeek-R1: expert inner 2048, kv-lora rank 1536.
        ("deepseek", LayerGeometry { hidden: 7168, ffn: 2048, kv: 1536, group: 128 }),
        // OpenPangu dense: low-rank projection 1536.
        ("openpangu", LayerGeometry { hidden: 7680, ffn: 7680, kv: 1536, group: 128 }),
    ]
}

/// MoE decoding scenarios: the evaluated models whose FFN block routes
/// over experts.  DeepSeek-R1's 256 routed experts (top-8, inner 2048)
/// batch-multiply many small down-projections per decode step.
pub fn paper_moe_geometries() -> Vec<(&'static str, LayerGeometry, MoeGeometry)> {
    vec![(
        "deepseek-moe",
        LayerGeometry { hidden: 7168, ffn: 2048, kv: 1536, group: 128 },
        MoeGeometry { experts: 256, topk: 8, expert_ffn: 2048 },
    )]
}

/// Look up a paper model's decoder-layer geometry by name (MoE model
/// names resolve to their dense trunk geometry; pair with
/// [`moe_geometry`] for the expert fan-out).
pub fn layer_geometry(model: &str) -> anyhow::Result<LayerGeometry> {
    if let Some((_, g, _)) = paper_moe_geometries().into_iter().find(|(name, _, _)| *name == model)
    {
        return Ok(g);
    }
    paper_layer_geometries()
        .into_iter()
        .find(|(name, _)| *name == model)
        .map(|(_, g)| g)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model '{model}' (try llama32, glm45, deepseek, openpangu, deepseek-moe)"
            )
        })
}

/// The expert fan-out of a named MoE model (`None` for dense models).
pub fn moe_geometry(model: &str) -> Option<MoeGeometry> {
    paper_moe_geometries()
        .into_iter()
        .find(|(name, _, _)| *name == model)
        .map(|(_, _, m)| m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_shapes_four_models() {
        let shapes = paper_shapes();
        assert_eq!(shapes.len(), 12);
        let models: std::collections::BTreeSet<_> =
            shapes.iter().map(|s| s.model).collect();
        assert_eq!(models.len(), 4);
    }

    #[test]
    fn both_regimes_present() {
        let shapes = paper_shapes();
        assert!(shapes.iter().any(|s| s.k_dominant()));
        assert!(shapes.iter().any(|s| !s.k_dominant()));
    }

    #[test]
    fn group_aligned() {
        for s in paper_shapes() {
            assert_eq!(s.k % 128, 0, "{}", s.tag());
        }
    }

    #[test]
    fn layer_geometries_cover_all_models_and_align() {
        let geoms = paper_layer_geometries();
        assert_eq!(geoms.len(), 4);
        for (model, g) in &geoms {
            assert_eq!(g.hidden % g.group, 0, "{model}: hidden not group-aligned");
            assert_eq!(g.ffn % g.group, 0, "{model}: ffn not group-aligned");
            assert_eq!(g.kv % 16, 0, "{model}: kv not cube-tile aligned");
        }
        assert_eq!(layer_geometry("glm45").unwrap(), LayerGeometry::mha(5120, 12288));
        assert!(layer_geometry("nope").is_err());
    }

    #[test]
    fn moe_models_resolve_and_balance_routing() {
        let (name, geom, moe) = paper_moe_geometries().remove(0);
        assert_eq!(layer_geometry(name).unwrap(), geom);
        assert_eq!(moe_geometry(name), Some(moe));
        assert_eq!(moe_geometry("glm45"), None);
        moe.validate().unwrap();
        // b=8, top-8 over 256 experts: 64 routed pairs, 64 active experts,
        // one token each.
        assert_eq!(moe.routed_pairs(8), 64);
        assert_eq!(moe.active_experts(8), 64);
        assert_eq!(moe.tokens_per_expert(8), 1);
        // b=64: 512 pairs saturate all 256 experts with two tokens each.
        assert_eq!(moe.active_experts(64), 256);
        assert_eq!(moe.tokens_per_expert(64), 2);
        // Routed work is never lost: pairs <= active * tokens_per_expert.
        for batch in [1usize, 3, 8, 17, 64] {
            assert!(
                moe.active_experts(batch) * moe.tokens_per_expert(batch)
                    >= moe.routed_pairs(batch)
            );
        }
        let bad = MoeGeometry { experts: 4, topk: 8, expert_ffn: 2048 };
        assert!(bad.validate().is_err());
    }
}
