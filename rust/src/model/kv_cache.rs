//! Paged KV-cache allocator for the continuous-batching serve loop.
//!
//! The pager carves the HBM budget left after weights into fixed-size
//! pages and accounts them per sequence, vLLM-style but conservative:
//! admission *reserves* the worst case (prompt + full output budget), so
//! a request admitted once can never fail mid-flight for cache space —
//! over-capacity admission is a typed shed at the door, not an eviction
//! storm later.  Actual allocation starts at the prompt footprint and
//! grows page-by-page as tokens decode, so the live-page telemetry still
//! reflects real occupancy.
//!
//! Invariants (property-tested in `tests/serve_load.rs`):
//! * allocated pages never exceed reserved pages never exceed capacity;
//! * a sequence's pages are monotone non-decreasing until terminal;
//! * after every sequence reaches a terminal outcome, zero pages remain.

use std::collections::HashMap;

use crate::ascend::MachineConfig;

/// Default KV page size: 2 MiB, large enough that page counts stay small
/// at paper-model token widths, small enough to track occupancy.
pub const DEFAULT_PAGE_BYTES: u64 = 2 << 20;

/// KV bytes one decoded token pins for the whole model: `layers` layers,
/// K and V planes of `kv` width each, FP16.
pub fn kv_bytes_per_token(layers: usize, kv_width: usize) -> u64 {
    layers as u64 * 2 * kv_width as u64 * 2
}

#[derive(Debug, Clone)]
struct SeqAlloc {
    bytes_per_token: u64,
    /// Worst-case pages reserved at admission (prompt + output budget).
    reserved_pages: u64,
    /// Pages actually allocated so far (grows with decoded tokens).
    pages: u64,
    /// Tokens currently resident (prompt + generated).
    tokens: usize,
}

/// Fixed-page KV-cache allocator over an HBM capacity budget.
#[derive(Debug, Clone)]
pub struct KvPager {
    page_bytes: u64,
    capacity_pages: u64,
    reserved_pages: u64,
    allocated_pages: u64,
    peak_allocated_pages: u64,
    seqs: HashMap<u64, SeqAlloc>,
}

impl KvPager {
    pub fn new(page_bytes: u64, capacity_bytes: u64) -> KvPager {
        let page_bytes = page_bytes.max(1);
        KvPager {
            page_bytes,
            capacity_pages: capacity_bytes / page_bytes,
            reserved_pages: 0,
            allocated_pages: 0,
            peak_allocated_pages: 0,
            seqs: HashMap::new(),
        }
    }

    /// Pager over the machine's HBM budget net of resident weights.
    pub fn for_machine(machine: &MachineConfig, weight_bytes: u64, page_bytes: u64) -> KvPager {
        KvPager::new(page_bytes, machine.hbm_capacity_bytes.saturating_sub(weight_bytes))
    }

    /// Pages needed to hold `tokens` tokens at `bytes_per_token`.
    pub fn pages_for(&self, tokens: usize, bytes_per_token: u64) -> u64 {
        (tokens as u64 * bytes_per_token).div_ceil(self.page_bytes)
    }

    /// Admit a sequence, reserving its worst-case footprint and allocating
    /// its prompt pages.  Returns `false` (caller sheds) when the
    /// reservation does not fit the remaining capacity.
    pub fn try_admit(
        &mut self,
        id: u64,
        prompt_tokens: usize,
        max_new_tokens: usize,
        bytes_per_token: u64,
    ) -> bool {
        assert!(!self.seqs.contains_key(&id), "sequence {id} admitted twice");
        let worst = self.pages_for(prompt_tokens + max_new_tokens, bytes_per_token);
        if self.reserved_pages + worst > self.capacity_pages {
            return false;
        }
        let pages = self.pages_for(prompt_tokens, bytes_per_token);
        self.reserved_pages += worst;
        self.allocated_pages += pages;
        self.peak_allocated_pages = self.peak_allocated_pages.max(self.allocated_pages);
        self.seqs.insert(
            id,
            SeqAlloc { bytes_per_token, reserved_pages: worst, pages, tokens: prompt_tokens },
        );
        true
    }

    /// Grow a sequence by one decoded token.  Cannot fail: admission
    /// reserved the worst case, so growth stays within the reservation.
    pub fn grow(&mut self, id: u64) {
        let seq = self.seqs.get_mut(&id).expect("grow on unknown sequence");
        seq.tokens += 1;
        let need = (seq.tokens as u64 * seq.bytes_per_token).div_ceil(self.page_bytes);
        if need > seq.pages {
            let delta = need - seq.pages;
            seq.pages = need;
            self.allocated_pages += delta;
            self.peak_allocated_pages = self.peak_allocated_pages.max(self.allocated_pages);
        }
        debug_assert!(seq.pages <= seq.reserved_pages, "growth escaped its reservation");
        debug_assert!(self.allocated_pages <= self.capacity_pages);
    }

    /// Release a sequence on any terminal outcome (completed, expired,
    /// failed).  Returns the pages freed.
    pub fn release(&mut self, id: u64) -> u64 {
        let seq = self.seqs.remove(&id).expect("release on unknown sequence");
        self.reserved_pages -= seq.reserved_pages;
        self.allocated_pages -= seq.pages;
        seq.pages
    }

    /// Preempt a resident sequence: drop its pages *and* its worst-case
    /// reservation, exactly like [`KvPager::release`], but return the
    /// `(pages, resident_bytes)` footprint the victim held so the caller
    /// can price the recovery path (swap traffic is pages × page size;
    /// recompute re-ingests the resident tokens).  The sequence re-enters
    /// later through [`KvPager::try_resume`], so the reservation invariant
    /// never leaks: between preempt and resume the pager holds nothing
    /// for the victim.
    pub fn preempt(&mut self, id: u64) -> (u64, u64) {
        let seq = self.seqs.remove(&id).expect("preempt on unknown sequence");
        self.reserved_pages -= seq.reserved_pages;
        self.allocated_pages -= seq.pages;
        (seq.pages, seq.pages * self.page_bytes)
    }

    /// Re-admit a preempted sequence at its resume footprint:
    /// `resident_tokens` (prompt + generated prefix) allocate immediately,
    /// `remaining_new_tokens` re-reserve the rest of the output budget.
    /// Because `resident + remaining == prompt + max_new`, the worst case
    /// re-reserved here never exceeds what the original admission held —
    /// a sequence that fit once always fits again on an otherwise-empty
    /// pager.  Returns `false` when capacity is currently occupied by
    /// others; the caller parks the victim and retries later.
    pub fn try_resume(
        &mut self,
        id: u64,
        resident_tokens: usize,
        remaining_new_tokens: usize,
        bytes_per_token: u64,
    ) -> bool {
        self.try_admit(id, resident_tokens, remaining_new_tokens, bytes_per_token)
    }

    /// Pages currently allocated to `id`, if resident.
    pub fn pages_of(&self, id: u64) -> Option<u64> {
        self.seqs.get(&id).map(|s| s.pages)
    }

    pub fn page_bytes(&self) -> u64 {
        self.page_bytes
    }

    pub fn capacity_pages(&self) -> u64 {
        self.capacity_pages
    }

    pub fn allocated_pages(&self) -> u64 {
        self.allocated_pages
    }

    pub fn reserved_pages(&self) -> u64 {
        self.reserved_pages
    }

    pub fn peak_allocated_pages(&self) -> u64 {
        self.peak_allocated_pages
    }

    /// Sequences currently resident.
    pub fn in_flight(&self) -> usize {
        self.seqs.len()
    }

    /// True when every page has been returned — the leak check.
    pub fn idle(&self) -> bool {
        self.seqs.is_empty() && self.allocated_pages == 0 && self.reserved_pages == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn admit_grow_release_round_trip() {
        let mut p = KvPager::new(1024, 16 * 1024);
        assert_eq!(p.capacity_pages(), 16);
        // 4 prompt tokens at 256 B/token = 1 page; worst case 4+8 tokens = 3 pages.
        assert!(p.try_admit(7, 4, 8, 256));
        assert_eq!(p.allocated_pages(), 1);
        assert_eq!(p.reserved_pages(), 3);
        for _ in 0..8 {
            p.grow(7);
        }
        assert_eq!(p.pages_of(7), Some(3));
        assert_eq!(p.release(7), 3);
        assert!(p.idle());
    }

    #[test]
    fn admission_sheds_past_capacity_and_never_overcommits() {
        let mut p = KvPager::new(1024, 4 * 1024);
        assert!(p.try_admit(0, 4, 4, 256)); // reserves 2 pages
        assert!(p.try_admit(1, 4, 4, 256)); // reserves 2 more: full
        assert!(!p.try_admit(2, 1, 1, 256), "capacity exhausted must shed");
        // Growth within reservations can never exceed capacity.
        for _ in 0..4 {
            p.grow(0);
            p.grow(1);
        }
        assert!(p.allocated_pages() <= p.capacity_pages());
        p.release(0);
        assert!(p.try_admit(2, 1, 1, 256), "released pages re-admit");
        p.release(1);
        p.release(2);
        assert!(p.idle());
        assert_eq!(p.peak_allocated_pages(), 4);
    }

    #[test]
    fn growth_is_monotone() {
        let mut p = KvPager::new(512, 1 << 20);
        assert!(p.try_admit(3, 2, 64, 128));
        let mut last = p.pages_of(3).unwrap();
        for _ in 0..64 {
            p.grow(3);
            let now = p.pages_of(3).unwrap();
            assert!(now >= last, "pages must be monotone until terminal");
            last = now;
        }
    }

    #[test]
    fn preempt_resume_conserves_the_reservation_invariant() {
        let mut p = KvPager::new(1024, 8 * 1024);
        // 4 prompt + 8 new at 256 B/token: worst = 3 pages, prompt = 1.
        assert!(p.try_admit(7, 4, 8, 256));
        for _ in 0..3 {
            p.grow(7); // 7 tokens resident -> 2 pages
        }
        let (pages, bytes) = p.preempt(7);
        assert_eq!((pages, bytes), (2, 2048));
        assert!(p.idle(), "preempt must free pages AND reservation");
        // Resume at 7 resident + 5 remaining: same worst case (12 tokens).
        assert!(p.try_resume(7, 7, 5, 256));
        assert_eq!(p.reserved_pages(), 3);
        assert_eq!(p.pages_of(7), Some(2), "resume re-allocates the resident prefix");
        for _ in 0..5 {
            p.grow(7);
        }
        assert_eq!(p.release(7), 3);
        assert!(p.idle());
    }

    #[test]
    fn a_sequence_that_fit_once_fits_again_on_an_empty_pager() {
        let mut p = KvPager::new(512, 4 * 512);
        assert!(p.try_admit(1, 3, 5, 128)); // worst = 2 pages of 4
        for _ in 0..2 {
            p.grow(1);
        }
        p.preempt(1);
        // Resume footprint (5 resident + 3 remaining) equals the original
        // worst case, so an empty pager can never refuse it.
        assert!(p.try_resume(1, 5, 3, 128));
        p.release(1);
        assert!(p.idle());
    }

    #[test]
    fn machine_budget_nets_out_weights() {
        let m = MachineConfig::ascend910();
        let p = KvPager::for_machine(&m, 8 << 30, DEFAULT_PAGE_BYTES);
        assert_eq!(p.capacity_pages(), (24u64 << 30) / DEFAULT_PAGE_BYTES);
    }
}
