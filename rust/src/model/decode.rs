//! Decode engine: drives one AOT decode-step artifact (fixed batch size)
//! with persistent KV-cache state and pre-staged weight literals.
//!
//! The engine owns the serving hot path: per step it builds two tiny i32
//! literals (tokens, positions), reuses the weight literals staged at
//! construction and the KV-cache literal produced by the previous step,
//! and executes the compiled module.  No Python, no re-compilation, no
//! weight re-conversion anywhere on this path.

use crate::runtime::client::{literal_to_host, Literal};
use crate::runtime::{ArtifactEntry, Executable, HostTensor, Runtime};

use std::sync::Arc;

/// Output of one batched decode step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Next token per slot (argmax over logits, computed in-graph).
    pub next_tokens: Vec<i32>,
}

/// A decode engine bound to one (model, batch-size) artifact.
pub struct DecodeEngine {
    exe: Arc<Executable>,
    /// Weight literals in artifact input order (inputs[3..]).
    weights: Vec<Literal>,
    /// Persistent KV cache literal (output of the previous step).
    cache: Literal,
    pub batch: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub layers: usize,
    pub hidden: usize,
    steps_taken: usize,
}

impl DecodeEngine {
    /// Compile the artifact and stage its weights.
    pub fn new(rt: &Runtime, entry: &ArtifactEntry) -> anyhow::Result<DecodeEngine> {
        anyhow::ensure!(entry.kind == "decode", "'{}' is not a decode artifact", entry.name);
        let cfg = entry
            .config
            .ok_or_else(|| anyhow::anyhow!("decode artifact missing config"))?;
        let batch = entry
            .batch
            .ok_or_else(|| anyhow::anyhow!("decode artifact missing batch"))?;
        let blob = entry
            .weights
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("decode artifact missing weights"))?
            .load()?;
        let exe = rt.load(entry)?;

        let mut weights = Vec::with_capacity(entry.inputs.len() - 3);
        for spec in &entry.inputs[3..] {
            let raw = blob
                .get(&spec.name)
                .ok_or_else(|| anyhow::anyhow!("weight '{}' missing from blob", spec.name))?;
            weights.push(HostTensor::from_bytes(spec.dtype, raw)?.to_literal(&spec.shape)?);
        }
        let cache_elems = cfg.layers * 2 * batch * cfg.max_seq * cfg.hidden;
        let cache = HostTensor::F32(vec![0.0; cache_elems])
            .to_literal(&entry.inputs[2].shape)?;
        Ok(DecodeEngine {
            exe,
            weights,
            cache,
            batch,
            vocab: cfg.vocab,
            max_seq: cfg.max_seq,
            layers: cfg.layers,
            hidden: cfg.hidden,
            steps_taken: 0,
        })
    }

    /// Reset the KV cache to zeros (new decode group).
    pub fn reset(&mut self) -> anyhow::Result<()> {
        let elems = self.layers * 2 * self.batch * self.max_seq * self.hidden;
        self.cache = HostTensor::F32(vec![0.0; elems]).to_literal(&[
            self.layers,
            2,
            self.batch,
            self.max_seq,
            self.hidden,
        ])?;
        self.steps_taken = 0;
        Ok(())
    }

    /// One batched decode step. `tokens`/`positions` must have `batch`
    /// entries; idle slots should pass token 0 at their previous position.
    pub fn step(&mut self, tokens: &[i32], positions: &[i32]) -> anyhow::Result<StepOutput> {
        anyhow::ensure!(tokens.len() == self.batch, "expected {} tokens", self.batch);
        anyhow::ensure!(positions.len() == self.batch, "positions arity");
        for &p in positions {
            anyhow::ensure!(
                (p as usize) < self.max_seq,
                "position {p} exceeds max_seq {}", self.max_seq
            );
        }
        let tok = HostTensor::I32(tokens.to_vec()).to_literal(&[self.batch])?;
        let pos = HostTensor::I32(positions.to_vec()).to_literal(&[self.batch])?;

        let mut args: Vec<&Literal> = Vec::with_capacity(3 + self.weights.len());
        args.push(&tok);
        args.push(&pos);
        args.push(&self.cache);
        args.extend(self.weights.iter());

        let mut outs = self.exe.run_literals_ref(&args)?;
        // outputs: (logits, next_token, kv_cache)
        anyhow::ensure!(outs.len() == 3, "decode artifact must return 3 outputs");
        let cache = outs.pop().unwrap();
        let next = outs.pop().unwrap();
        self.cache = cache;
        self.steps_taken += 1;
        let next_tokens = match literal_to_host(&next)? {
            HostTensor::I32(v) => v,
            other => anyhow::bail!("next_token dtype {:?}", other.dtype()),
        };
        Ok(StepOutput { next_tokens })
    }

    pub fn steps_taken(&self) -> usize {
        self.steps_taken
    }

    /// Approximate bytes of the persistent KV cache (capacity planning).
    pub fn cache_bytes(&self) -> usize {
        self.layers * 2 * self.batch * self.max_seq * self.hidden * 4
    }
}

#[cfg(test)]
mod tests {
    // Engine construction requires real artifacts; covered by
    // rust/tests/e2e.rs and rust/tests/coordinator.rs.
}
