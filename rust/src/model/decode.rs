//! Decode engine: drives one AOT decode-step artifact (fixed batch size)
//! with persistent KV-cache state and pre-staged weight literals.
//!
//! The engine owns the serving hot path: per step it builds two tiny i32
//! literals (tokens, positions), reuses the weight literals staged at
//! construction and the KV-cache literal produced by the previous step,
//! and executes the compiled module.  No Python, no re-compilation, no
//! weight re-conversion anywhere on this path.

use crate::runtime::artifacts::DecodeConfig;
use crate::runtime::client::{literal_to_host, Literal};
use crate::runtime::{ArtifactEntry, Executable, HostTensor, Runtime};

use std::sync::Arc;

/// Output of one batched decode step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    /// Next token per slot (argmax over logits, computed in-graph).
    pub next_tokens: Vec<i32>,
}

/// A decode engine bound to one (model, batch-size) artifact.
pub struct DecodeEngine {
    exe: Arc<Executable>,
    /// Weight literals in artifact input order (inputs[3..]).
    weights: Vec<Literal>,
    /// Persistent KV cache literal (output of the previous step).
    cache: Literal,
    pub batch: usize,
    pub vocab: usize,
    pub max_seq: usize,
    pub layers: usize,
    pub hidden: usize,
    steps_taken: usize,
}

impl DecodeEngine {
    /// Compile the artifact and stage its weights.
    pub fn new(rt: &Runtime, entry: &ArtifactEntry) -> anyhow::Result<DecodeEngine> {
        anyhow::ensure!(entry.kind == "decode", "'{}' is not a decode artifact", entry.name);
        let cfg = entry
            .config
            .ok_or_else(|| anyhow::anyhow!("decode artifact missing config"))?;
        let batch = entry
            .batch
            .ok_or_else(|| anyhow::anyhow!("decode artifact missing batch"))?;
        let blob = entry
            .weights
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("decode artifact missing weights"))?
            .load()?;
        let exe = rt.load(entry)?;

        let mut weights = Vec::with_capacity(entry.inputs.len() - 3);
        for spec in &entry.inputs[3..] {
            let raw = blob
                .get(&spec.name)
                .ok_or_else(|| anyhow::anyhow!("weight '{}' missing from blob", spec.name))?;
            weights.push(HostTensor::from_bytes(spec.dtype, raw)?.to_literal(&spec.shape)?);
        }
        let cache_elems = cfg.layers * 2 * batch * cfg.max_seq * cfg.hidden;
        let cache = HostTensor::F32(vec![0.0; cache_elems])
            .to_literal(&entry.inputs[2].shape)?;
        Ok(DecodeEngine {
            exe,
            weights,
            cache,
            batch,
            vocab: cfg.vocab,
            max_seq: cfg.max_seq,
            layers: cfg.layers,
            hidden: cfg.hidden,
            steps_taken: 0,
        })
    }

    /// Reset the KV cache to zeros (new decode group).
    pub fn reset(&mut self) -> anyhow::Result<()> {
        let elems = self.layers * 2 * self.batch * self.max_seq * self.hidden;
        self.cache = HostTensor::F32(vec![0.0; elems]).to_literal(&[
            self.layers,
            2,
            self.batch,
            self.max_seq,
            self.hidden,
        ])?;
        self.steps_taken = 0;
        Ok(())
    }

    /// One batched decode step. `tokens`/`positions` must have `batch`
    /// entries; idle slots should pass token 0 at their previous position.
    pub fn step(&mut self, tokens: &[i32], positions: &[i32]) -> anyhow::Result<StepOutput> {
        anyhow::ensure!(tokens.len() == self.batch, "expected {} tokens", self.batch);
        anyhow::ensure!(positions.len() == self.batch, "positions arity");
        for &p in positions {
            anyhow::ensure!(
                (p as usize) < self.max_seq,
                "position {p} exceeds max_seq {}", self.max_seq
            );
        }
        let tok = HostTensor::I32(tokens.to_vec()).to_literal(&[self.batch])?;
        let pos = HostTensor::I32(positions.to_vec()).to_literal(&[self.batch])?;

        let mut args: Vec<&Literal> = Vec::with_capacity(3 + self.weights.len());
        args.push(&tok);
        args.push(&pos);
        args.push(&self.cache);
        args.extend(self.weights.iter());

        let mut outs = self.exe.run_literals_ref(&args)?;
        // outputs: (logits, next_token, kv_cache)
        anyhow::ensure!(outs.len() == 3, "decode artifact must return 3 outputs");
        let cache = outs.pop().unwrap();
        let next = outs.pop().unwrap();
        self.cache = cache;
        self.steps_taken += 1;
        let next_tokens = match literal_to_host(&next)? {
            HostTensor::I32(v) => v,
            other => anyhow::bail!("next_token dtype {:?}", other.dtype()),
        };
        Ok(StepOutput { next_tokens })
    }

    pub fn steps_taken(&self) -> usize {
        self.steps_taken
    }

    /// Approximate bytes of the persistent KV cache (capacity planning).
    pub fn cache_bytes(&self) -> usize {
        self.layers * 2 * self.batch * self.max_seq * self.hidden * 4
    }
}

/// The synthetic next-token function of [`SimEngine`]: a pure per-slot
/// hash of `(token, position)` folded into the vocab.  Purity is the
/// load-bearing property — a slot's output depends only on its own input
/// pair, so decoding a prompt yields bit-identical tokens regardless of
/// group composition, padding, injected faults, or retries.
pub fn synthetic_next_token(token: i32, position: i32, vocab: usize) -> i32 {
    let mut z = (token as u64)
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((position as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
        .wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    ((z ^ (z >> 31)) % vocab.max(1) as u64) as i32
}

/// A synthetic decode engine for weightless decode artifacts (a config
/// but no weight blob, as the test manifests ship): same stepping
/// contract as [`DecodeEngine`], next tokens from
/// [`synthetic_next_token`].  This lets the whole serving stack — batcher,
/// router, deadlines, fault injection — run end to end without PJRT or
/// staged weights.
pub struct SimEngine {
    pub batch: usize,
    pub vocab: usize,
    pub max_seq: usize,
    steps_taken: usize,
}

impl SimEngine {
    pub fn new(cfg: &DecodeConfig, batch: usize) -> SimEngine {
        SimEngine { batch, vocab: cfg.vocab, max_seq: cfg.max_seq, steps_taken: 0 }
    }

    pub fn reset(&mut self) -> anyhow::Result<()> {
        self.steps_taken = 0;
        Ok(())
    }

    /// One batched step under the [`DecodeEngine::step`] contract.
    pub fn step(&mut self, tokens: &[i32], positions: &[i32]) -> anyhow::Result<StepOutput> {
        anyhow::ensure!(tokens.len() == self.batch, "expected {} tokens", self.batch);
        anyhow::ensure!(positions.len() == self.batch, "positions arity");
        for &p in positions {
            anyhow::ensure!(
                (p as usize) < self.max_seq,
                "position {p} exceeds max_seq {}", self.max_seq
            );
        }
        self.steps_taken += 1;
        let next_tokens = tokens
            .iter()
            .zip(positions)
            .map(|(&t, &p)| synthetic_next_token(t, p, self.vocab))
            .collect();
        Ok(StepOutput { next_tokens })
    }

    pub fn steps_taken(&self) -> usize {
        self.steps_taken
    }
}

/// The router's engine slot: a real PJRT-backed decode engine when the
/// artifact ships weights, or the synthetic engine when it only carries
/// a config (test/synthetic manifests).
pub enum Engine {
    Real(DecodeEngine),
    Synthetic(SimEngine),
}

impl Engine {
    pub fn vocab(&self) -> usize {
        match self {
            Engine::Real(e) => e.vocab,
            Engine::Synthetic(e) => e.vocab,
        }
    }

    pub fn max_seq(&self) -> usize {
        match self {
            Engine::Real(e) => e.max_seq,
            Engine::Synthetic(e) => e.max_seq,
        }
    }

    pub fn batch(&self) -> usize {
        match self {
            Engine::Real(e) => e.batch,
            Engine::Synthetic(e) => e.batch,
        }
    }

    pub fn is_synthetic(&self) -> bool {
        matches!(self, Engine::Synthetic(_))
    }

    pub fn reset(&mut self) -> anyhow::Result<()> {
        match self {
            Engine::Real(e) => e.reset(),
            Engine::Synthetic(e) => e.reset(),
        }
    }

    pub fn step(&mut self, tokens: &[i32], positions: &[i32]) -> anyhow::Result<StepOutput> {
        match self {
            Engine::Real(e) => e.step(tokens, positions),
            Engine::Synthetic(e) => e.step(tokens, positions),
        }
    }
}

#[cfg(test)]
mod tests {
    // Real-engine construction requires artifacts; covered by
    // rust/tests/e2e.rs and rust/tests/coordinator.rs.
    use super::*;

    fn cfg() -> DecodeConfig {
        DecodeConfig {
            vocab: 512,
            hidden: 256,
            layers: 2,
            heads: 4,
            ffn: 1024,
            max_seq: 64,
            group: 128,
            params: 0,
            moe_experts: 0,
            moe_topk: 0,
        }
    }

    #[test]
    fn synthetic_next_token_is_pure_and_in_vocab() {
        for t in 0..64 {
            for p in 0..16 {
                let a = synthetic_next_token(t, p, 512);
                assert_eq!(a, synthetic_next_token(t, p, 512));
                assert!((0..512).contains(&a), "token {a} outside vocab");
            }
        }
        // Not constant: the stream must actually vary.
        assert_ne!(synthetic_next_token(1, 0, 512), synthetic_next_token(2, 0, 512));
    }

    #[test]
    fn sim_engine_steps_are_slot_independent() {
        let c = cfg();
        let mut wide = SimEngine::new(&c, 4);
        let mut narrow = SimEngine::new(&c, 1);
        let wide_out = wide.step(&[5, 9, 17, 0], &[0, 0, 0, 0]).unwrap();
        let narrow_out = narrow.step(&[9], &[0]).unwrap();
        assert_eq!(wide_out.next_tokens[1], narrow_out.next_tokens[0]);
        assert_eq!(wide.steps_taken(), 1);
    }

    #[test]
    fn sim_engine_enforces_the_step_contract() {
        let c = cfg();
        let mut e = SimEngine::new(&c, 2);
        assert!(e.step(&[1], &[0]).is_err(), "batch arity");
        assert!(e.step(&[1, 2], &[0]).is_err(), "positions arity");
        assert!(e.step(&[1, 2], &[0, 64]).is_err(), "position past max_seq");
        assert!(e.step(&[1, 2], &[0, 63]).is_ok());
        e.reset().unwrap();
        assert_eq!(e.steps_taken(), 0);
    }

    #[test]
    fn engine_enum_dispatches_to_the_synthetic_side() {
        let c = cfg();
        let mut e = Engine::Synthetic(SimEngine::new(&c, 2));
        assert!(e.is_synthetic());
        assert_eq!((e.vocab(), e.max_seq(), e.batch()), (512, 64, 2));
        e.reset().unwrap();
        let out = e.step(&[3, 4], &[0, 0]).unwrap();
        assert_eq!(out.next_tokens.len(), 2);
    }
}

