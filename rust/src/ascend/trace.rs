//! Kernel-schedule IR: what a kernel *does*, independent of how long it takes.
//!
//! Schedules (`kernels/*`) compile a GEMM problem into a [`KernelTrace`]:
//! an ordered list of [`Phase`]s, each a set of per-engine [`TileStep`]
//! sequences.  The simulator ([`super::npu`]) then prices the trace on a
//! [`super::MachineConfig`].  Keeping schedule and timing separate lets the
//! tests assert *coverage* invariants (every tile computed exactly once)
//! without any timing model in the loop.

/// Which engine class executes a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Unit {
    /// Cube (AIC) — matrix multiply only; cannot convert types.
    Cube,
    /// Vector (AIV) — SIMD elementwise / reduction / type conversion.
    Vector,
}

/// Traffic class of a transfer, for the §4.2 bottleneck decomposition.
/// The memory model also uses the class to decide L2 residency: workspace
/// and partials are producer-consumer traffic between phases and may hit
/// L2; weights and activations are cold HBM reads.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BufferClass {
    /// Packed INT4 weights (cold read from HBM).
    WeightPacked,
    /// FP16 weights (cold read from HBM — native FP16 baseline only).
    WeightF16,
    /// FP16 activations A.
    Activation,
    /// Dequantized-weight workspace (vector -> cube round trip).
    Workspace,
    /// FP32 Split-K partial buffers.
    Partial,
    /// Final FP16 output C.
    Output,
    /// Quantization scales / zero points.
    QuantParam,
    /// Split-K partials of an *upstream* kernel, carried across a kernel
    /// boundary by the phase-level co-scheduler (DESIGN.md §12): a spliced
    /// reduce step reads them inside the downstream kernel, so their L2
    /// residency is the producer kernel's, not this kernel's.  A standalone
    /// `Simulator::run` prices them cold (conservative);
    /// `Simulator::run_merged` carries the producer's residency over.
    CarriedPartial,
    /// Packed INT4 weights + quant params that the step-level residency
    /// planner (DESIGN.md §13) pinned in L2 across the decode step: decode
    /// re-reads the same weights token after token, so a pinned node's
    /// weight reads are served at L2 bandwidth instead of cold HBM.  The
    /// residency is owned by the step-level `ResidencyLedger`
    /// (`crate::ascend::memory`), not by any single kernel; a standalone
    /// `Simulator::run` prices these cold (conservative).
    CarriedWeight,
}

/// One compute operation on a tile, with enough shape info to price it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ComputeOp {
    /// Cube MMAD of an (m, k) x (k, n) block, FP32 accumulate in L0C.
    Mmad { m: usize, n: usize, k: usize },
    /// Cube MMAD on the INT8 datapath (W4A8): same block shape, INT32
    /// accumulate, retired at the machine's INT8 MAC rate.
    MmadInt8 { m: usize, n: usize, k: usize },
    /// Vector dequantization of `elems` INT4 codes -> FP16 (unpack, sub, mul).
    Dequant { elems: usize },
    /// Vector elementwise reduction of `elems` FP32 values over `terms`
    /// split buffers, then cast to FP16.
    Reduce { elems: usize, terms: usize },
    /// Vector FP32 -> FP16 cast of `elems` values.
    Cast { elems: usize },
    /// Vector FP16 -> INT8 activation quantization of `elems` values
    /// (scale, round, clamp — the W4A8 prologue).
    QuantizeAct { elems: usize },
    /// No computation (pure data movement step).
    Nop,
}

/// One pipelined step of an engine: bytes moved in/out plus a compute op.
/// The MTE double-buffers transfers against compute across steps.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TileStep {
    pub compute: ComputeOp,
    /// Bytes read, by class (max two distinct classes per step keeps this
    /// flat and copy-friendly; schedules split steps if they need more).
    pub reads: [(BufferClass, u64); 2],
    /// Bytes written, by class.
    pub writes: [(BufferClass, u64); 2],
    /// Contiguous row-segment length of this step's dominant transfer in
    /// bytes (0 = fully contiguous).  Segments shorter than the machine's
    /// DMA burst size waste bandwidth proportionally.
    pub burst: u64,
}

impl TileStep {
    pub fn new(compute: ComputeOp) -> TileStep {
        TileStep {
            compute,
            reads: [(BufferClass::Activation, 0), (BufferClass::Activation, 0)],
            writes: [(BufferClass::Output, 0), (BufferClass::Output, 0)],
            burst: 0,
        }
    }

    /// Set the contiguous row-segment length of the step's transfers.
    pub fn with_burst(mut self, bytes: u64) -> TileStep {
        self.burst = bytes;
        self
    }

    pub fn read(mut self, class: BufferClass, bytes: u64) -> TileStep {
        if self.reads[0].1 == 0 {
            self.reads[0] = (class, bytes);
        } else {
            debug_assert_eq!(self.reads[1].1, 0, "more than two read classes");
            self.reads[1] = (class, bytes);
        }
        self
    }

    pub fn write(mut self, class: BufferClass, bytes: u64) -> TileStep {
        if self.writes[0].1 == 0 {
            self.writes[0] = (class, bytes);
        } else {
            debug_assert_eq!(self.writes[1].1, 0, "more than two write classes");
            self.writes[1] = (class, bytes);
        }
        self
    }

    pub fn read_bytes(&self) -> u64 {
        self.reads[0].1 + self.reads[1].1
    }

    pub fn write_bytes(&self) -> u64 {
        self.writes[0].1 + self.writes[1].1
    }
}

/// A phase: one engine class, one step sequence per engine instance, and a
/// barrier before the next phase (Algorithm 1's event synchronization)
/// unless `pipelined_with_prev` marks it as double-buffered against the
/// previous phase (producer-consumer overlap at tile granularity, the
/// paper's §3 "hide the dequantization latency in data copy operations").
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub unit: Unit,
    /// `steps[i]` is the step sequence of engine instance `i`; instances
    /// with no work get an empty vec.  Length must not exceed the machine's
    /// engine count for `unit` (validated by the simulator).
    pub steps_per_engine: Vec<Vec<TileStep>>,
    /// If true, this phase streams concurrently with the previous phase
    /// (shared resources are serialized, different engines overlap).
    pub pipelined_with_prev: bool,
    /// K-chunk index for chunk-pipelined schedules (`None` for monolithic
    /// phases).  Within a pipelined group the chunk indices must be
    /// non-decreasing; the executor charges one buffer-rotation event per
    /// chunk boundary (DESIGN.md §8).
    pub chunk: Option<u32>,
}

impl Phase {
    /// Stable splice tag: a vector-core reduce phase (barrier `reduce`,
    /// streamed `reduce_stream`, or the final `reduce_tail` wave).  The
    /// phase names are part of the schedule contract (golden fixtures pin
    /// them), so the co-scheduler keys off them rather than positions.
    pub fn is_reduce(&self) -> bool {
        self.unit == Unit::Vector && self.name.starts_with("reduce")
    }

    /// Stable splice tag: a weight-only dequant phase (`dequant`,
    /// `chunk_dequant`, or an already-spliced `spliced_dequant`).  These
    /// read only weights + quant params — never upstream activations — so
    /// an upstream kernel's exposed reduce can legally share their vector
    /// engines (disjoint buffers).
    pub fn is_dequant(&self) -> bool {
        self.unit == Unit::Vector && self.name.contains("dequant")
    }

    pub fn active_engines(&self) -> usize {
        self.steps_per_engine.iter().filter(|s| !s.is_empty()).count()
    }

    pub fn total_steps(&self) -> usize {
        self.steps_per_engine.iter().map(|s| s.len()).sum()
    }

    /// Total bytes read in a given class across all engines.
    pub fn read_bytes(&self, class: BufferClass) -> u64 {
        self.steps_per_engine
            .iter()
            .flatten()
            .flat_map(|s| s.reads.iter())
            .filter(|(c, _)| *c == class)
            .map(|(_, b)| b)
            .sum()
    }

    pub fn write_bytes(&self, class: BufferClass) -> u64 {
        self.steps_per_engine
            .iter()
            .flatten()
            .flat_map(|s| s.writes.iter())
            .filter(|(c, _)| *c == class)
            .map(|(_, b)| b)
            .sum()
    }
}

/// How Workspace-class traffic is kept resident in L2 — the §4.2 lever.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WorkspacePolicy {
    /// Whole-buffer handoff (Algorithm 1): the full workspace is produced
    /// before consumption, so residency is capacity-shaped and spills once
    /// the footprint exceeds the retained L2 capacity.
    Buffered,
    /// Chunk-rotated slices pinned in L2 (the chunked schedule): only
    /// `resident_bytes` of rotating double-buffered slices are ever live,
    /// so Workspace traffic stays on-chip as long as they fit.
    Pinned {
        /// Live bytes of the rotating slice set (typically 2 slices).
        resident_bytes: u64,
    },
}

/// A whole kernel: named phases plus the GM workspace footprint (drives the
/// L2 residency model for Workspace-class traffic).
#[derive(Debug, Clone)]
pub struct KernelTrace {
    pub name: String,
    pub phases: Vec<Phase>,
    /// Bytes of the dequantized-weight workspace allocated in GM.
    pub workspace_bytes: u64,
    /// Bytes of the Split-K partial buffers allocated in GM.
    pub partial_bytes: u64,
    /// Residency policy for Workspace-class traffic.
    pub workspace_policy: WorkspacePolicy,
}

impl KernelTrace {
    /// Total MACs across all MMAD ops (for roofline / utilization).
    pub fn total_macs(&self) -> u64 {
        self.phases
            .iter()
            .flat_map(|p| p.steps_per_engine.iter().flatten())
            .map(|s| match s.compute {
                ComputeOp::Mmad { m, n, k } | ComputeOp::MmadInt8 { m, n, k } => (m * n * k) as u64,
                _ => 0,
            })
            .sum()
    }

    /// Total reduce steps across all phases (conservation checks for the
    /// co-scheduler: a splice moves reduce steps, it never drops them).
    pub fn reduce_steps(&self) -> usize {
        self.phases
            .iter()
            .flat_map(|p| p.steps_per_engine.iter().flatten())
            .filter(|s| matches!(s.compute, ComputeOp::Reduce { .. }))
            .count()
    }

    /// The *exposed* reduce sub-trace: the trailing barrier group, when it
    /// consists solely of reduce phases.  This is the spliceable producer
    /// side of the phase-level co-scheduler (DESIGN.md §12) — the vector
    /// work a downstream kernel's dequant prologue can absorb.  `None`
    /// when the trace is a single pipelined group (nothing is exposed) or
    /// the trailing group carries non-reduce work.
    pub fn exposed_reduce_range(&self) -> Option<std::ops::Range<usize>> {
        let n = self.phases.len();
        if n == 0 {
            return None;
        }
        let mut start = n - 1;
        while start > 0 && self.phases[start].pipelined_with_prev {
            start -= 1;
        }
        if start == 0 {
            return None;
        }
        self.phases[start..].iter().all(|p| p.is_reduce()).then_some(start..n)
    }

    /// The dequant prologue: the spliceable consumer side — the kernel's
    /// opening weight-only vector phase.  The prologue must *open* the
    /// trace (no upstream dependency inside this kernel) for the splice to
    /// be sound, so anything later does not qualify.
    pub fn dequant_prologue(&self) -> Option<usize> {
        self.phases.first()?.is_dequant().then_some(0)
    }
}

/// A merged multi-kernel trace, as produced by the phase-level
/// co-scheduler ([`crate::analysis::coschedule`]): the kernels run back to
/// back, each keeping its own launch and intra-kernel barriers, and
/// cross-kernel state (the producer's split buffers read by spliced
/// [`BufferClass::CarriedPartial`] steps) is carried across the boundary
/// by [`super::npu::Simulator::run_merged`].
#[derive(Debug, Clone)]
pub struct MergedTrace {
    pub name: String,
    /// The spliced kernels in issue order.
    pub kernels: Vec<KernelTrace>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tile_step_builder_accumulates_classes() {
        let s = TileStep::new(ComputeOp::Nop)
            .read(BufferClass::WeightPacked, 100)
            .read(BufferClass::QuantParam, 10)
            .write(BufferClass::Workspace, 400);
        assert_eq!(s.read_bytes(), 110);
        assert_eq!(s.write_bytes(), 400);
    }

    #[test]
    fn phase_byte_accounting() {
        let step = TileStep::new(ComputeOp::Nop).read(BufferClass::Workspace, 64);
        let phase = Phase {
            name: "p",
            unit: Unit::Cube,
            steps_per_engine: vec![vec![step; 3], vec![], vec![step]],
            pipelined_with_prev: false,
            chunk: None,
        };
        assert_eq!(phase.active_engines(), 2);
        assert_eq!(phase.total_steps(), 4);
        assert_eq!(phase.read_bytes(BufferClass::Workspace), 256);
        assert_eq!(phase.read_bytes(BufferClass::Activation), 0);
    }

    #[test]
    fn splice_tags_and_exposed_reduce_range() {
        let reduce_step = TileStep::new(ComputeOp::Reduce { elems: 64, terms: 2 });
        let dequant = Phase {
            name: "dequant",
            unit: Unit::Vector,
            steps_per_engine: vec![vec![TileStep::new(ComputeOp::Dequant { elems: 64 })]],
            pipelined_with_prev: false,
            chunk: None,
        };
        let mmad = Phase {
            name: "splitk_mmad",
            unit: Unit::Cube,
            steps_per_engine: vec![vec![TileStep::new(ComputeOp::Mmad { m: 16, n: 16, k: 16 })]],
            pipelined_with_prev: true,
            chunk: None,
        };
        let reduce = Phase {
            name: "reduce",
            unit: Unit::Vector,
            steps_per_engine: vec![vec![reduce_step; 2]],
            pipelined_with_prev: false,
            chunk: None,
        };
        assert!(dequant.is_dequant() && !dequant.is_reduce());
        assert!(reduce.is_reduce() && !reduce.is_dequant());
        assert!(!mmad.is_reduce() && !mmad.is_dequant());

        let t = KernelTrace {
            name: "t".into(),
            phases: vec![dequant.clone(), mmad.clone(), reduce],
            workspace_bytes: 0,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        };
        assert_eq!(t.exposed_reduce_range(), Some(2..3));
        assert_eq!(t.dequant_prologue(), Some(0));
        assert_eq!(t.reduce_steps(), 2);

        // Single pipelined group: nothing exposed.
        let single = KernelTrace {
            name: "s".into(),
            phases: vec![dequant, mmad],
            workspace_bytes: 0,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        };
        assert_eq!(single.exposed_reduce_range(), None);
        assert_eq!(single.reduce_steps(), 0);
    }

    #[test]
    fn trace_mac_count() {
        let step = TileStep::new(ComputeOp::Mmad { m: 16, n: 16, k: 16 });
        let t = KernelTrace {
            name: "t".into(),
            phases: vec![Phase {
                name: "mm",
                unit: Unit::Cube,
                steps_per_engine: vec![vec![step, step]],
                pipelined_with_prev: false,
                chunk: None,
            }],
            workspace_bytes: 0,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        };
        assert_eq!(t.total_macs(), 2 * 4096);
    }
}
