//! Machine description of the Ascend 910 (DaVinci Max) used by the simulator.
//!
//! Values are drawn from public Huawei documentation and the paper's §2.3:
//! 32 AI cores at ~1 GHz, each with one 16x16x16-FP16 cube core, two
//! 2048-bit vector cores, private L1/L0A/L0B/L0C/UB buffers and MTE
//! engines; a shared on-chip buffer (L2); HBM2 at ~1.2 TB/s.  The chip
//! peak of 32 x 4096 MAC/cycle x 2 flops x 1 GHz = 262 TFLOPS FP16 matches
//! the marketed 256 TFLOPS within rounding.

/// Full machine description.  All bandwidths are bytes/ns (== GB/s / 1e0,
/// since 1 GB/s = 1 byte/ns exactly in our unit system).
#[derive(Debug, Clone)]
pub struct MachineConfig {
    /// Number of AI cores (each one cube + `vector_per_core` vector units).
    pub ai_cores: usize,
    /// Vector cores per AI core (paper: two on Ascend 910).
    pub vector_per_core: usize,
    /// Core clock in GHz (cycles per ns).
    pub clock_ghz: f64,

    // --- cube core -------------------------------------------------------
    /// MMAD tile edge: the cube core multiplies 16x16x16 FP16 tiles.
    pub cube_tile: usize,
    /// MACs retired per cube core per cycle (16^3 = 4096).
    pub cube_macs_per_cycle: f64,
    /// MACs retired per cube core per cycle on the INT8 datapath: the
    /// narrower operands double the systolic throughput (2 x 4096), the
    /// lever the W4A8 precision family rides (DESIGN.md §16).
    pub cube_macs_per_cycle_int8: f64,

    // --- vector core -----------------------------------------------------
    /// FP16 lanes per vector core per cycle (2048-bit SIMD = 128 lanes).
    pub vector_lanes_f16: f64,
    /// FP32 lanes per vector core per cycle (half the f16 lanes).
    pub vector_lanes_f32: f64,

    // --- on-chip buffers (per AI core, bytes) ------------------------------
    pub l1_bytes: u64,
    pub l0a_bytes: u64,
    pub l0b_bytes: u64,
    pub l0c_bytes: u64,
    pub ub_bytes: u64,

    // --- memory system -----------------------------------------------------
    /// Shared on-chip buffer capacity (bytes).
    pub l2_bytes: u64,
    /// Aggregate L2 bandwidth (bytes/ns).
    pub l2_bw: f64,
    /// Aggregate HBM bandwidth (bytes/ns).
    pub hbm_bw: f64,
    /// HBM device-memory capacity (bytes) — the budget the KV-cache pager
    /// allocates against after weights are resident (Ascend 910: 32 GiB).
    pub hbm_capacity_bytes: u64,
    /// Per-core MTE bandwidth cap (bytes/ns): one core cannot saturate HBM.
    pub mte_core_bw: f64,
    /// Host-link (PCIe/HCCS to host DRAM) bandwidth in bytes/ns — the
    /// channel KV pages cross when a preempted sequence is swapped out to
    /// host memory and back (DESIGN.md §18).  Roughly an order of
    /// magnitude below HBM, which is exactly why recompute-vs-swap is a
    /// real pricing decision and not a foregone conclusion.
    pub host_link_bw: f64,
    /// L2 residency retention factor in [0,1]: fraction of capacity that
    /// usefully survives between producer and consumer phases (conflict
    /// misses, other traffic).
    pub l2_retention: f64,
    /// DMA burst size (bytes) below which MTE transfers lose efficiency:
    /// a transfer whose contiguous row segment is `b < dma_burst_bytes`
    /// achieves only `b / dma_burst_bytes` of peak bandwidth.  This is why
    /// narrow B tiles cannot substitute for Split-K occupancy.
    pub dma_burst_bytes: f64,

    // --- synchronization ----------------------------------------------------
    /// One-time kernel launch latency (ns).
    pub launch_ns: f64,
    /// Grid-wide barrier between phases (ns) — the "wait for all AIC cores"
    /// event sync of Algorithm 1.
    pub barrier_ns: f64,
    /// Per-tile event handshake between MTE and compute (ns); the double
    /// buffering pipeline hides most but not all of it.
    pub event_ns: f64,
}

impl MachineConfig {
    /// The Ascend 910 description used throughout the paper reproduction.
    pub fn ascend910() -> MachineConfig {
        MachineConfig {
            ai_cores: 32,
            vector_per_core: 2,
            clock_ghz: 1.0,
            cube_tile: 16,
            cube_macs_per_cycle: 4096.0,
            cube_macs_per_cycle_int8: 8192.0,
            vector_lanes_f16: 128.0,
            vector_lanes_f32: 64.0,
            l1_bytes: 1 << 20,        // 1 MiB
            l0a_bytes: 64 << 10,      // 64 KiB
            l0b_bytes: 64 << 10,      // 64 KiB
            l0c_bytes: 256 << 10,     // 256 KiB
            ub_bytes: 256 << 10,      // 256 KiB
            l2_bytes: 32 << 20,       // 32 MiB shared
            l2_bw: 3600.0,            // 3.6 TB/s aggregate on-chip buffer
            hbm_bw: 1200.0,           // 1.2 TB/s
            hbm_capacity_bytes: 32 << 30, // 32 GiB HBM2
            mte_core_bw: 500.0,       // 500 GB/s per core (L1 <-> L2/GM port)
            host_link_bw: 64.0,       // 64 GB/s host link (PCIe4 x16 class)
            l2_retention: 0.90,
            dma_burst_bytes: 256.0,
            launch_ns: 5_000.0,
            barrier_ns: 2_000.0,
            event_ns: 50.0,
        }
    }

    /// Total vector cores on the chip.
    pub fn total_vector_cores(&self) -> usize {
        self.ai_cores * self.vector_per_core
    }

    /// Chip peak FP16 throughput in TFLOPS (2 flops per MAC).
    pub fn peak_tflops_f16(&self) -> f64 {
        self.ai_cores as f64 * self.cube_macs_per_cycle * 2.0 * self.clock_ghz / 1000.0
    }

    /// Cube-core cycles for one (m, n, k) MMAD block (FP16, FP32 accumulate).
    pub fn mmad_cycles(&self, m: usize, n: usize, k: usize) -> f64 {
        (m * n * k) as f64 / self.cube_macs_per_cycle
    }

    /// Nanoseconds for `cycles` at the core clock.
    pub fn cycles_to_ns(&self, cycles: f64) -> f64 {
        cycles / self.clock_ghz
    }

    /// Sanity-check invariants (used by tests and the CLI on startup).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ai_cores > 0, "need at least one AI core");
        anyhow::ensure!(self.hbm_bw > 0.0 && self.l2_bw >= self.hbm_bw,
            "L2 must be at least as fast as HBM");
        anyhow::ensure!((0.0..=1.0).contains(&self.l2_retention));
        anyhow::ensure!(
            self.cube_macs_per_cycle_int8 >= self.cube_macs_per_cycle,
            "the INT8 datapath cannot be slower than FP16"
        );
        anyhow::ensure!(self.l0a_bytes <= self.l1_bytes);
        anyhow::ensure!(
            self.hbm_capacity_bytes > self.l2_bytes,
            "HBM capacity must exceed the on-chip buffer"
        );
        anyhow::ensure!(
            self.host_link_bw > 0.0 && self.host_link_bw < self.hbm_bw,
            "the host link must be slower than HBM (and positive)"
        );
        Ok(())
    }
}

impl Default for MachineConfig {
    fn default() -> Self {
        MachineConfig::ascend910()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascend910_peak_matches_datasheet() {
        let m = MachineConfig::ascend910();
        let tflops = m.peak_tflops_f16();
        assert!((tflops - 262.144).abs() < 1.0, "got {tflops}");
    }

    #[test]
    fn mmad_cycles_for_native_tile_is_one() {
        let m = MachineConfig::ascend910();
        assert_eq!(m.mmad_cycles(16, 16, 16), 1.0);
        assert_eq!(m.mmad_cycles(16, 256, 128), 128.0);
    }

    #[test]
    fn validates() {
        MachineConfig::ascend910().validate().unwrap();
        let mut bad = MachineConfig::ascend910();
        bad.l2_bw = 1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn host_link_is_slower_than_hbm_and_validated() {
        let m = MachineConfig::ascend910();
        assert!(m.host_link_bw > 0.0 && m.host_link_bw < m.hbm_bw);
        let mut bad = MachineConfig::ascend910();
        bad.host_link_bw = bad.hbm_bw;
        assert!(bad.validate().is_err());
        bad.host_link_bw = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn vector_core_count() {
        assert_eq!(MachineConfig::ascend910().total_vector_cores(), 64);
    }

    #[test]
    fn int8_datapath_doubles_the_mac_rate() {
        let m = MachineConfig::ascend910();
        assert_eq!(m.cube_macs_per_cycle_int8, 2.0 * m.cube_macs_per_cycle);
        let mut bad = MachineConfig::ascend910();
        bad.cube_macs_per_cycle_int8 = 1024.0;
        assert!(bad.validate().is_err());
    }
}
