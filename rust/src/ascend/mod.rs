//! Cycle-approximate, event-driven simulator of the Ascend 910's decoupled
//! AI-core architecture.
//!
//! This is the substrate substitution for the paper's hardware (see
//! DESIGN.md §2): the findings under reproduction are *architectural* —
//! they follow from (a) cube and vector units that communicate only
//! through global memory, (b) the ratio of HBM bandwidth to MMAD
//! throughput, and (c) Split-K occupancy at decode shapes — so a simulator
//! that models exactly those mechanisms reproduces the shape of the
//! paper's Figures 2 and 3 from first principles.
//!
//! Model summary:
//! * [`config::MachineConfig`] — machine description (32 AI cores, each
//!   1 cube + 2 vector cores; L1/L0A/L0B/L0C/UB buffers; MTE engines;
//!   shared L2; HBM).
//! * [`trace`] — the kernel-schedule IR: phases of per-core tile steps,
//!   each step naming its compute op and its traffic per buffer class.
//! * [`memory`] — L2 residency / spill model and bandwidth fair-sharing.
//! * [`cube`] / [`vector`] — compute-unit timing (MMAD tiles, SIMD lanes).
//! * [`mte`] — memory-transfer-engine timing with double buffering.
//! * [`event`] — synchronization costs (event latency, phase barriers,
//!   kernel launch).
//! * [`npu`] — the chip-level executor: walks a trace, resolves bandwidth
//!   contention, applies double buffering, and returns a [`npu::SimReport`]
//!   with per-phase times and a byte-accurate traffic ledger.
//! * [`vecpass`] — whole-chip vector passes: the bandwidth/compute model
//!   pricing the non-GEMM decode-step nodes (attention, norms, glue).

pub mod config;
pub mod cube;
pub mod event;
pub mod memory;
pub mod mte;
pub mod npu;
pub mod trace;
pub mod vecpass;
pub mod vector;

pub use config::MachineConfig;
pub use memory::ResidencyLedger;
pub use npu::{MergedReport, SimReport, Simulator};
pub use trace::{
    BufferClass, ComputeOp, KernelTrace, MergedTrace, Phase, TileStep, Unit, WorkspacePolicy,
};
pub use vecpass::VecPassCost;
