//! Whole-chip vector passes: the bandwidth/compute model behind the
//! non-GEMM decode-step nodes (attention score/softmax/AV, RMSNorm,
//! residual adds, activation glue, MoE routing — DESIGN.md §11).
//!
//! A pass streams `elems` elements through every vector engine with a
//! fixed SIMD cost per element, moving `hbm_bytes` against HBM (cold
//! reads: KV cache, router weights) and `l2_bytes` against the shared L2
//! (activation-sized producer/consumer traffic).  The MTEs double-buffer
//! transfers against compute, so — exactly as in the §7 group execution
//! model — the pass costs the *maximum* of its three streams, plus one
//! grid barrier for the phase boundary.  This is deliberately the same
//! pricing a one-phase vector [`KernelTrace`](super::KernelTrace) would
//! get from the simulator, without building per-tile step lists for ops
//! whose only levers are bytes and lanes.

use super::config::MachineConfig;
use super::{event, mte};

/// Priced streams of one vector pass.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct VecPassCost {
    /// SIMD time of the straggler engine (perfect element spread).
    pub compute_ns: f64,
    /// HBM transfer time at the engines' aggregate bandwidth.
    pub hbm_ns: f64,
    /// L2 transfer time at the engines' aggregate bandwidth.
    pub l2_ns: f64,
    /// Phase-boundary synchronization (one grid barrier).
    pub sync_ns: f64,
    /// max(streams) + sync.
    pub total_ns: f64,
}

/// Price one whole-chip vector pass.
pub fn price_pass(
    machine: &MachineConfig,
    elems: u64,
    ops_per_elem: f64,
    hbm_bytes: u64,
    l2_bytes: u64,
) -> VecPassCost {
    let engines = machine.total_vector_cores().max(1);
    let per_engine = elems as f64 / engines as f64;
    let compute_ns =
        machine.cycles_to_ns(per_engine * ops_per_elem / machine.vector_lanes_f16);
    let hbm_ns = if hbm_bytes == 0 {
        0.0
    } else {
        hbm_bytes as f64 / mte::aggregate_bw(machine, machine.hbm_bw, engines)
    };
    let l2_ns = if l2_bytes == 0 {
        0.0
    } else {
        l2_bytes as f64 / mte::aggregate_bw(machine, machine.l2_bw, engines)
    };
    let sync_ns = event::barrier(machine);
    VecPassCost {
        compute_ns,
        hbm_ns,
        l2_ns,
        sync_ns,
        total_ns: compute_ns.max(hbm_ns).max(l2_ns) + sync_ns,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn empty_pass_costs_one_barrier() {
        let c = price_pass(&m(), 0, 4.0, 0, 0);
        assert_eq!(c.total_ns, m().barrier_ns);
        assert_eq!((c.compute_ns, c.hbm_ns, c.l2_ns), (0.0, 0.0, 0.0));
    }

    #[test]
    fn compute_bound_pass_matches_lane_math() {
        // 64 engines x 128 lanes at 1 GHz = 8192 elem-ops/ns.
        let c = price_pass(&m(), 8192 * 1000, 1.0, 0, 0);
        assert!((c.compute_ns - 1000.0).abs() < 1e-9);
        assert_eq!(c.total_ns, c.compute_ns + c.sync_ns);
    }

    #[test]
    fn hbm_bound_pass_uses_aggregate_bandwidth() {
        // 64 engines saturate the 1200 B/ns HBM stream.
        let c = price_pass(&m(), 64, 1.0, 1_200_000, 0);
        assert!((c.hbm_ns - 1000.0).abs() < 1e-9);
        assert!(c.total_ns >= c.hbm_ns);
    }

    #[test]
    fn streams_take_max_not_sum() {
        let c = price_pass(&m(), 8192 * 500, 1.0, 600_000, 360_000);
        let max = c.compute_ns.max(c.hbm_ns).max(c.l2_ns);
        assert_eq!(c.total_ns, max + c.sync_ns);
        assert!(c.compute_ns > 0.0 && c.hbm_ns > 0.0 && c.l2_ns > 0.0);
    }

    #[test]
    fn cost_monotone_in_every_input() {
        let base = price_pass(&m(), 1 << 20, 4.0, 1 << 20, 1 << 20);
        assert!(price_pass(&m(), 1 << 21, 4.0, 1 << 20, 1 << 20).total_ns >= base.total_ns);
        assert!(price_pass(&m(), 1 << 20, 8.0, 1 << 20, 1 << 20).total_ns >= base.total_ns);
        assert!(price_pass(&m(), 1 << 20, 4.0, 1 << 22, 1 << 20).total_ns >= base.total_ns);
        assert!(price_pass(&m(), 1 << 20, 4.0, 1 << 20, 1 << 22).total_ns >= base.total_ns);
    }
}
