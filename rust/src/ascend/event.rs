//! Synchronization-cost model: kernel launch, inter-phase barriers and
//! per-tile MTE/compute event handshakes.
//!
//! Algorithm 1 synchronizes (a) globally between phases ("wait for all AIC
//! cores to finish") and (b) per tile between the Memory Transfer Engines
//! and the compute pipes (double-buffering events).  Double buffering
//! hides the per-tile events except for the pipeline fill; barriers and
//! launch latency are exposed in full.

use super::config::MachineConfig;

/// Cost accumulator for a kernel's synchronization events.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SyncCosts {
    pub launch_ns: f64,
    pub barrier_ns: f64,
    pub fill_ns: f64,
    pub barriers: usize,
}

impl SyncCosts {
    pub fn total_ns(&self) -> f64 {
        self.launch_ns + self.barrier_ns + self.fill_ns
    }
}

/// One kernel launch.
pub fn launch(machine: &MachineConfig) -> f64 {
    machine.launch_ns
}

/// One grid-wide barrier (phase boundary).
pub fn barrier(machine: &MachineConfig) -> f64 {
    machine.barrier_ns
}

/// Pipeline-fill cost for a double-buffered phase: the first tile's
/// transfer cannot overlap anything, and each engine pays one event
/// handshake entering the steady state.
pub fn pipeline_fill(machine: &MachineConfig, first_transfer_ns: f64) -> f64 {
    first_transfer_ns + machine.event_ns
}

/// Rotating the pinned workspace slice of a chunk-pipelined group: one
/// event handshake per chunk boundary (the vector cores flip the double
/// buffer and signal the cube cores; no grid-wide barrier).
pub fn chunk_rotation(machine: &MachineConfig) -> f64 {
    machine.event_ns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn costs_accumulate() {
        let m = MachineConfig::ascend910();
        let c = SyncCosts {
            launch_ns: launch(&m),
            barrier_ns: 2.0 * barrier(&m),
            fill_ns: pipeline_fill(&m, 100.0),
            barriers: 2,
        };
        assert_eq!(c.total_ns(), 5_000.0 + 4_000.0 + 150.0);
    }
}
