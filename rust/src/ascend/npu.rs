//! Chip-level executor: prices a [`KernelTrace`] on a [`MachineConfig`].
//!
//! Execution model (see DESIGN.md §7):
//! * Phases are grouped by `pipelined_with_prev`: inside a group, different
//!   engine classes and transfer streams overlap (double buffering); the
//!   group takes the *maximum* of its resource-stream times.  Between
//!   groups there is a grid-wide barrier (Algorithm 1's event sync).
//! * Resource streams: HBM bytes, L2 bytes, cube compute, vector compute.
//!   Transfer streams honour per-engine MTE caps and fair-shared aggregate
//!   bandwidth; the straggler engine gates each phase.
//! * The L2 residency model decides which Workspace/Partial bytes are
//!   served on-chip versus spilled to HBM — the mechanism behind the
//!   paper's §4.2 bottleneck analysis.

use std::collections::BTreeMap;

use super::config::MachineConfig;
use super::event;
use super::memory::{L2Model, ResidencyLedger};
use super::mte::{self, PhaseDemand};
use super::trace::{BufferClass, KernelTrace, MergedTrace, Phase, Unit};

/// Byte ledger for one buffer class.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ClassTraffic {
    pub hbm_read: f64,
    pub hbm_write: f64,
    pub l2_read: f64,
    pub l2_write: f64,
}

impl ClassTraffic {
    pub fn hbm_total(&self) -> f64 {
        self.hbm_read + self.hbm_write
    }

    pub fn l2_total(&self) -> f64 {
        self.l2_read + self.l2_write
    }
}

/// Byte-accurate traffic decomposition of one kernel execution.
#[derive(Debug, Clone, Default)]
pub struct TrafficLedger {
    pub by_class: BTreeMap<BufferClass, ClassTraffic>,
}

impl TrafficLedger {
    pub fn class(&self, c: BufferClass) -> ClassTraffic {
        self.by_class.get(&c).copied().unwrap_or_default()
    }

    pub fn hbm_total(&self) -> f64 {
        self.by_class.values().map(|t| t.hbm_total()).sum()
    }

    pub fn l2_total(&self) -> f64 {
        self.by_class.values().map(|t| t.l2_total()).sum()
    }
}

/// Timing of one phase (within its group).
#[derive(Debug, Clone)]
pub struct PhaseTime {
    pub name: &'static str,
    pub unit: Unit,
    pub group: usize,
    pub active_engines: usize,
    pub steps: usize,
    pub hbm_ns: f64,
    pub l2_ns: f64,
    pub compute_ns: f64,
    /// This phase's own critical time (max of its streams) if it ran alone.
    pub standalone_ns: f64,
}

/// Timing of one pipelined group.
#[derive(Debug, Clone)]
pub struct GroupTime {
    pub phases: Vec<usize>,
    pub hbm_ns: f64,
    pub l2_ns: f64,
    pub cube_ns: f64,
    pub vector_ns: f64,
    pub fill_ns: f64,
    /// Buffer-rotation handshakes of a chunk-pipelined group: one event
    /// per chunk boundary (double buffering hides everything else).
    pub chunk_sync_ns: f64,
    /// max over streams + fill + chunk sync
    pub total_ns: f64,
    /// Which stream bound the group ("hbm", "l2", "cube", "vector").
    pub bound_by: &'static str,
}

/// Full result of simulating one kernel.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub name: String,
    pub total_ns: f64,
    pub launch_ns: f64,
    pub barrier_ns: f64,
    pub groups: Vec<GroupTime>,
    pub phase_times: Vec<PhaseTime>,
    pub ledger: TrafficLedger,
    pub total_macs: u64,
    pub l2_model: L2Model,
}

impl SimReport {
    /// Achieved FP16 TFLOPS (2 flops per MAC).
    pub fn achieved_tflops(&self) -> f64 {
        if self.total_ns == 0.0 {
            return 0.0;
        }
        self.total_macs as f64 * 2.0 / self.total_ns / 1000.0
    }

    /// Fraction of machine peak FP16 throughput achieved.
    pub fn mxu_utilization(&self, machine: &MachineConfig) -> f64 {
        self.achieved_tflops() / machine.peak_tflops_f16()
    }

    /// Average HBM bandwidth utilization over the run.
    pub fn hbm_utilization(&self, machine: &MachineConfig) -> f64 {
        if self.total_ns == 0.0 {
            return 0.0;
        }
        (self.ledger.hbm_total() / self.total_ns) / machine.hbm_bw
    }
}

/// Result of simulating a merged multi-kernel trace: the kernels run back
/// to back (each pays its own launch and intra-kernel barriers; a spliced
/// producer has already lost its tail group and the barrier in front of
/// it), with the producer's partial-buffer residency carried into each
/// successor's [`BufferClass::CarriedPartial`] reads.
#[derive(Debug, Clone)]
pub struct MergedReport {
    pub name: String,
    pub total_ns: f64,
    /// Per-kernel reports, in issue order.
    pub kernels: Vec<SimReport>,
}

/// Timing-core output shared by the full report path and the price-only
/// path: the scalar times plus whatever detail the caller asked for
/// (`groups`/`phase_times` are empty on price-only runs).
struct CoreRun {
    total_ns: f64,
    launch_ns: f64,
    barrier_ns: f64,
    groups: Vec<GroupTime>,
    phase_times: Vec<PhaseTime>,
    l2: L2Model,
}

/// The simulator: a machine description plus the pricing logic.
#[derive(Debug, Clone, Default)]
pub struct Simulator {
    pub machine: MachineConfig,
}

impl Simulator {
    pub fn new(machine: MachineConfig) -> Simulator {
        Simulator { machine }
    }

    /// Validate a trace against the machine (engine counts, op legality).
    pub fn validate(&self, trace: &KernelTrace) -> anyhow::Result<()> {
        for phase in &trace.phases {
            let limit = match phase.unit {
                Unit::Cube => self.machine.ai_cores,
                Unit::Vector => self.machine.total_vector_cores(),
            };
            anyhow::ensure!(
                phase.steps_per_engine.len() <= limit,
                "phase '{}' uses {} engines, machine has {limit}",
                phase.name,
                phase.steps_per_engine.len()
            );
        }
        anyhow::ensure!(!trace.phases.is_empty(), "trace has no phases");
        anyhow::ensure!(
            !trace.phases[0].pipelined_with_prev,
            "first phase cannot pipeline with a predecessor"
        );
        // Chunk indices must be non-decreasing inside a pipelined group:
        // the rotating workspace slice is a FIFO, chunk i+1 cannot be
        // produced before chunk i has been scheduled.
        let mut prev_chunk: Option<u32> = None;
        for phase in &trace.phases {
            if !phase.pipelined_with_prev {
                prev_chunk = None;
            }
            if let Some(c) = phase.chunk {
                if let Some(p) = prev_chunk {
                    anyhow::ensure!(
                        c >= p,
                        "phase '{}' rewinds chunk {c} after chunk {p}",
                        phase.name
                    );
                }
                prev_chunk = Some(c);
            }
        }
        if let super::trace::WorkspacePolicy::Pinned { resident_bytes } = trace.workspace_policy
        {
            anyhow::ensure!(
                resident_bytes > 0,
                "pinned workspace policy with zero resident bytes"
            );
        }
        Ok(())
    }

    /// Simulate one kernel execution.  Carried-partial and carried-weight
    /// reads (spliced / pinned steps run standalone) are priced cold.
    pub fn run(&self, trace: &KernelTrace) -> anyhow::Result<SimReport> {
        self.run_with_residency(trace, &ResidencyLedger::default())
    }

    /// Simulate one kernel with an explicit residency for
    /// [`BufferClass::CarriedPartial`] reads — the cross-kernel state a
    /// merged trace carries over the kernel boundary (DESIGN.md §12).
    pub fn run_with_carry(&self, trace: &KernelTrace, carried_hit: f64) -> anyhow::Result<SimReport> {
        self.run_with_residency(trace, &ResidencyLedger::with_carried_partials(carried_hit))
    }

    /// Simulate one kernel under a cross-kernel [`ResidencyLedger`] — the
    /// one owner of everything that crosses a kernel boundary (DESIGN.md
    /// §13): the splice producer's partial residency, the step-level
    /// pinned-weight residency, and the retained-capacity carve-out those
    /// pins impose on this kernel's own buffers.
    pub fn run_with_residency(
        &self,
        trace: &KernelTrace,
        ledger: &ResidencyLedger,
    ) -> anyhow::Result<SimReport> {
        let core = self.run_core(trace, ledger, true)?;
        Ok(SimReport {
            name: trace.name.clone(),
            total_ns: core.total_ns,
            launch_ns: core.launch_ns,
            barrier_ns: core.barrier_ns,
            groups: core.groups,
            phase_times: core.phase_times,
            ledger: build_ledger(&core.l2, &trace.phases),
            total_macs: trace.total_macs(),
            l2_model: core.l2,
        })
    }

    /// Price one kernel under a ledger *without* assembling the report:
    /// identical float arithmetic to [`Simulator::run_with_residency`]
    /// (same demands, same stream maxima, same accumulation order — the
    /// returned time is bit-identical to `run_with_residency(..).total_ns`),
    /// but the byte ledger, MAC census (which walks every step of every
    /// phase) and per-phase/group report structs are skipped.  This is the
    /// hot path of the residency planner's prefix re-pricing and the
    /// co-scheduler's merged-trace decisions.
    pub fn price_with_residency(
        &self,
        trace: &KernelTrace,
        ledger: &ResidencyLedger,
    ) -> anyhow::Result<f64> {
        Ok(self.run_core(trace, ledger, false)?.total_ns)
    }

    /// [`Simulator::price_with_residency`] with a default (cold) ledger.
    pub fn price(&self, trace: &KernelTrace) -> anyhow::Result<f64> {
        self.price_with_residency(trace, &ResidencyLedger::default())
    }

    /// The shared timing core.  `detail` controls only whether the
    /// [`PhaseTime`]/[`GroupTime`] report structs are collected; every
    /// floating-point operation that feeds `total_ns` runs identically in
    /// both modes (the bit-identity contract the price path depends on).
    fn run_core(
        &self,
        trace: &KernelTrace,
        ledger: &ResidencyLedger,
        detail: bool,
    ) -> anyhow::Result<CoreRun> {
        self.validate(trace)?;
        let m = &self.machine;
        let l2 = L2Model::for_trace_with_ledger(m, trace, ledger);

        // Price every phase.
        let mut demands: Vec<PhaseDemand> = Vec::with_capacity(trace.phases.len());
        for phase in &trace.phases {
            demands.push(mte::phase_demand(m, &l2, phase)?);
        }

        // Group phases by pipelining.
        let mut groups: Vec<Vec<usize>> = Vec::new();
        for (i, phase) in trace.phases.iter().enumerate() {
            if i == 0 || !phase.pipelined_with_prev {
                groups.push(vec![i]);
            } else {
                groups.last_mut().unwrap().push(i);
            }
        }

        let mut phase_times = Vec::new();
        let mut group_times = Vec::new();
        let mut total = event::launch(m);
        let launch_ns = total;
        let barrier_ns = event::barrier(m) * (groups.len().saturating_sub(1)) as f64;
        total += barrier_ns;

        for (gi, group) in groups.iter().enumerate() {
            let mut g = GroupTime {
                phases: if detail { group.clone() } else { Vec::new() },
                hbm_ns: 0.0,
                l2_ns: 0.0,
                cube_ns: 0.0,
                vector_ns: 0.0,
                fill_ns: 0.0,
                chunk_sync_ns: 0.0,
                total_ns: 0.0,
                bound_by: "hbm",
            };
            for &pi in group {
                let d = &demands[pi];
                let phase = &trace.phases[pi];
                let hbm_ns = mte::hbm_time_ns(m, d);
                let l2_ns = mte::l2_time_ns(m, d);
                let compute_ns = d.compute_ns_max_engine;
                g.hbm_ns += hbm_ns;
                g.l2_ns += l2_ns;
                match phase.unit {
                    Unit::Cube => g.cube_ns += compute_ns,
                    Unit::Vector => g.vector_ns += compute_ns,
                }
                if detail {
                    phase_times.push(PhaseTime {
                        name: phase.name,
                        unit: phase.unit,
                        group: gi,
                        active_engines: d.active,
                        steps: d.steps,
                        hbm_ns,
                        l2_ns,
                        compute_ns,
                        standalone_ns: hbm_ns.max(l2_ns).max(compute_ns),
                    });
                }
            }
            let streams = [
                (g.hbm_ns, "hbm"),
                (g.l2_ns, "l2"),
                (g.cube_ns, "cube"),
                (g.vector_ns, "vector"),
            ];
            let (max_ns, bound) = streams
                .iter()
                .cloned()
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap();
            // Pipeline fill: before steady-state overlap, one step of the
            // group's first phase is exposed.  The exposed latency is
            // bounded by the *smaller* of the two stream step times — the
            // other stream overlaps it from the second step on (double
            // buffering hides the rest).
            let first = &demands[group[0]];
            let steps_per_engine =
                (first.steps as f64 / first.active.max(1) as f64).max(1.0);
            let transfer_step_ns =
                (mte::hbm_time_ns(m, first) + mte::l2_time_ns(m, first)) / steps_per_engine;
            let compute_step_ns = first.compute_ns_max_engine / steps_per_engine;
            g.fill_ns = event::pipeline_fill(m, transfer_step_ns.min(compute_step_ns));
            // Chunk-pipelined groups rotate the pinned workspace slice once
            // per chunk boundary; each rotation costs one event handshake
            // (the transfers themselves are double-buffered as usual).  The
            // boundary count is the chunk-index span of the group, so a
            // group covering a window [lo..hi] is charged hi - lo events.
            let mut chunk_ids = group.iter().filter_map(|&pi| trace.phases[pi].chunk);
            let rotations = match chunk_ids.next() {
                Some(first) => {
                    let (lo, hi) = chunk_ids.fold((first, first), |(lo, hi), c| {
                        (lo.min(c), hi.max(c))
                    });
                    (hi - lo) as f64
                }
                None => 0.0,
            };
            g.chunk_sync_ns = event::chunk_rotation(m) * rotations;
            g.total_ns = max_ns + g.fill_ns + g.chunk_sync_ns;
            g.bound_by = bound;
            total += g.total_ns;
            if detail {
                group_times.push(g);
            }
        }

        Ok(CoreRun {
            total_ns: total,
            launch_ns,
            barrier_ns,
            groups: group_times,
            phase_times,
            l2,
        })
    }

    /// Simulate a merged multi-kernel trace (the co-scheduler's output):
    /// kernels are priced back to back, and every kernel after the first
    /// reads its spliced [`BufferClass::CarriedPartial`] bytes at the
    /// *splice producer's* (the head kernel's) partial residency — the
    /// cross-kernel event the first-order overlap ledger cannot model.
    /// On chains longer than one consumer the carried residency is
    /// attenuated once per intervening kernel (its own resident working
    /// set evicts the producer's partials proportionally — DESIGN.md §13).
    pub fn run_merged(&self, merged: &MergedTrace) -> anyhow::Result<MergedReport> {
        self.run_merged_with(merged, &ResidencyLedger::default())
    }

    /// [`Simulator::run_merged`] under a step-level base ledger: the
    /// pinned-weight residency and its capacity carve-out apply to every
    /// kernel of the chain on top of the merged-pair partial carry.
    pub fn run_merged_with(
        &self,
        merged: &MergedTrace,
        base: &ResidencyLedger,
    ) -> anyhow::Result<MergedReport> {
        anyhow::ensure!(!merged.kernels.is_empty(), "merged trace has no kernels");
        let mut kernels = Vec::with_capacity(merged.kernels.len());
        let mut total = 0.0;
        let mut carried_hit = 0.0;
        for (i, trace) in merged.kernels.iter().enumerate() {
            let ledger = ResidencyLedger { carried_partial_hit: carried_hit, ..*base };
            let r = self.run_with_residency(trace, &ledger)?;
            if i == 0 {
                // The head kernel owns the spliced partials.
                carried_hit = r.l2_model.partial_hit;
            } else {
                // Each intervening consumer's own working set evicts part
                // of the producer's partials before the next consumer's
                // carried steps read them.
                carried_hit *= ledger.attenuation(&self.machine, trace);
            }
            total += r.total_ns;
            kernels.push(r);
        }
        Ok(MergedReport { name: merged.name.clone(), total_ns: total, kernels })
    }

    /// Price a merged multi-kernel trace without assembling the per-kernel
    /// reports: the same per-kernel ledger threading and carried-residency
    /// attenuation as [`Simulator::run_merged_with`], through the
    /// bit-identical price path — `price_merged_with(..)` equals
    /// `run_merged_with(..).total_ns` to the last bit.
    pub fn price_merged_with(
        &self,
        merged: &MergedTrace,
        base: &ResidencyLedger,
    ) -> anyhow::Result<f64> {
        anyhow::ensure!(!merged.kernels.is_empty(), "merged trace has no kernels");
        let mut total = 0.0;
        let mut carried_hit = 0.0;
        for (i, trace) in merged.kernels.iter().enumerate() {
            let ledger = ResidencyLedger { carried_partial_hit: carried_hit, ..*base };
            let core = self.run_core(trace, &ledger, false)?;
            if i == 0 {
                carried_hit = core.l2.partial_hit;
            } else {
                carried_hit *= ledger.attenuation(&self.machine, trace);
            }
            total += core.total_ns;
        }
        Ok(total)
    }
}

/// Accumulate the byte ledger (independent of timing).  Like the demand
/// pass, runs of identical steps are priced once and multiplied.
fn build_ledger(l2: &L2Model, phases: &[Phase]) -> TrafficLedger {
    let mut ledger = TrafficLedger::default();
    for phase in phases {
        for steps in &phase.steps_per_engine {
            let mut i = 0;
            while i < steps.len() {
                let step = &steps[i];
                let mut run = 1usize;
                while i + run < steps.len() && steps[i + run] == *step {
                    run += 1;
                }
                for &(class, bytes) in &step.reads {
                    if bytes == 0 {
                        continue;
                    }
                    let split = l2.read_split(class);
                    let t = ledger.by_class.entry(class).or_default();
                    t.l2_read += (bytes * run as u64) as f64 * split.l2_fraction;
                    t.hbm_read += (bytes * run as u64) as f64 * (1.0 - split.l2_fraction);
                }
                for &(class, bytes) in &step.writes {
                    if bytes == 0 {
                        continue;
                    }
                    let split = l2.write_split(class);
                    let t = ledger.by_class.entry(class).or_default();
                    t.l2_write += (bytes * run as u64) as f64 * split.l2_fraction;
                    t.hbm_write += (bytes * run as u64) as f64 * split.writeback_fraction;
                }
                i += run;
            }
        }
    }
    ledger
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::trace::{ComputeOp, TileStep};

    fn machine() -> MachineConfig {
        MachineConfig::ascend910()
    }

    use crate::ascend::trace::WorkspacePolicy;

    fn simple_phase(unit: Unit, engines: usize, steps: usize, step: TileStep) -> Phase {
        Phase {
            name: "p",
            unit,
            steps_per_engine: vec![vec![step; steps]; engines],
            pipelined_with_prev: false,
            chunk: None,
        }
    }

    fn trace_of(phases: Vec<Phase>) -> KernelTrace {
        KernelTrace {
            name: "t".into(),
            phases,
            workspace_bytes: 0,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        }
    }

    #[test]
    fn single_phase_bandwidth_bound() {
        // 32 cube engines each read 1 MiB of cold weights: 32 MiB over
        // 1200 B/ns (fair-shared) ~ 27962 ns + launch + fill.
        let step = TileStep::new(ComputeOp::Nop).read(BufferClass::WeightF16, 1 << 20);
        let t = trace_of(vec![simple_phase(Unit::Cube, 32, 1, step)]);
        let sim = Simulator::new(machine());
        let r = sim.run(&t).unwrap();
        let expect_stream = (1 << 20) as f64 / 37.5;
        assert!((r.groups[0].hbm_ns - expect_stream).abs() < 1.0);
        assert_eq!(r.groups[0].bound_by, "hbm");
        assert!(r.total_ns > r.launch_ns + expect_stream);
    }

    #[test]
    fn fewer_engines_take_longer() {
        let step = TileStep::new(ComputeOp::Nop).read(BufferClass::WeightF16, 1 << 20);
        let sim = Simulator::new(machine());
        // Same total bytes (8 MiB), spread over 2 vs 8 engines.
        let r2 = sim
            .run(&trace_of(vec![simple_phase(Unit::Cube, 2, 4, step)]))
            .unwrap();
        let r8 = sim
            .run(&trace_of(vec![simple_phase(Unit::Cube, 8, 1, step)]))
            .unwrap();
        assert!(r2.total_ns > r8.total_ns, "{} vs {}", r2.total_ns, r8.total_ns);
    }

    #[test]
    fn pipelined_group_takes_max_not_sum() {
        let read = TileStep::new(ComputeOp::Nop).read(BufferClass::WeightF16, 1 << 20);
        let mmad = TileStep::new(ComputeOp::Mmad { m: 256, n: 256, k: 256 });
        let mut p2 = simple_phase(Unit::Cube, 8, 4, mmad);
        p2.pipelined_with_prev = true;
        let p1 = simple_phase(Unit::Vector, 8, 1, read);
        let piped = trace_of(vec![p1.clone(), p2.clone()]);
        let mut unpiped_p2 = p2.clone();
        unpiped_p2.pipelined_with_prev = false;
        let unpiped = trace_of(vec![p1, unpiped_p2]);
        let sim = Simulator::new(machine());
        let rp = sim.run(&piped).unwrap();
        let ru = sim.run(&unpiped).unwrap();
        assert!(rp.total_ns < ru.total_ns);
        assert_eq!(rp.groups.len(), 1);
        assert_eq!(ru.groups.len(), 2);
        // The unpipelined version also pays a barrier.
        assert!(ru.barrier_ns > 0.0 && rp.barrier_ns == 0.0);
    }

    #[test]
    fn workspace_round_trip_appears_in_ledger() {
        let write = TileStep::new(ComputeOp::Dequant { elems: 1024 })
            .write(BufferClass::Workspace, 2048);
        let read = TileStep::new(ComputeOp::Mmad { m: 16, n: 16, k: 16 })
            .read(BufferClass::Workspace, 2048);
        let p1 = simple_phase(Unit::Vector, 1, 1, write);
        let p2 = simple_phase(Unit::Cube, 1, 1, read);
        let mut t = trace_of(vec![p1, p2]);
        t.workspace_bytes = 2048; // fits L2 -> full residency
        let r = Simulator::new(machine()).run(&t).unwrap();
        let ws = r.ledger.class(BufferClass::Workspace);
        assert_eq!(ws.l2_write, 2048.0);
        assert_eq!(ws.l2_read, 2048.0);
        assert_eq!(ws.hbm_read, 0.0); // resident
        assert_eq!(ws.hbm_write, 0.0); // no spill
    }

    #[test]
    fn oversized_workspace_spills() {
        let bytes = 128u64 << 20;
        let write = TileStep::new(ComputeOp::Nop).write(BufferClass::Workspace, bytes);
        let read = TileStep::new(ComputeOp::Nop).read(BufferClass::Workspace, bytes);
        let mut t = trace_of(vec![
            simple_phase(Unit::Vector, 1, 1, write),
            simple_phase(Unit::Cube, 1, 1, read),
        ]);
        t.workspace_bytes = bytes;
        let r = Simulator::new(machine()).run(&t).unwrap();
        let ws = r.ledger.class(BufferClass::Workspace);
        assert!(ws.hbm_write > 0.0, "spill write-back expected");
        assert!(ws.hbm_read > 0.0, "miss reads expected");
        assert!(ws.l2_read > 0.0);
    }

    #[test]
    fn pinned_workspace_never_spills() {
        // Same oversized footprint as `oversized_workspace_spills`, but the
        // trace pins a rotating slice set that fits L2: zero HBM traffic.
        let bytes = 128u64 << 20;
        let write = TileStep::new(ComputeOp::Nop).write(BufferClass::Workspace, bytes);
        let read = TileStep::new(ComputeOp::Nop).read(BufferClass::Workspace, bytes);
        let mut p1 = simple_phase(Unit::Vector, 1, 1, write);
        p1.chunk = Some(0);
        let mut p2 = simple_phase(Unit::Cube, 1, 1, read);
        p2.pipelined_with_prev = true;
        p2.chunk = Some(0);
        let mut t = trace_of(vec![p1, p2]);
        t.workspace_bytes = bytes;
        t.workspace_policy = WorkspacePolicy::Pinned { resident_bytes: 8 << 20 };
        let r = Simulator::new(machine()).run(&t).unwrap();
        let ws = r.ledger.class(BufferClass::Workspace);
        assert_eq!(ws.hbm_read, 0.0);
        assert_eq!(ws.hbm_write, 0.0);
        assert_eq!(ws.l2_read, bytes as f64);
    }

    #[test]
    fn chunk_rotations_cost_one_event_each() {
        let step = TileStep::new(ComputeOp::Nop).read(BufferClass::Activation, 1024);
        let mut phases = Vec::new();
        for c in 0..4u32 {
            let mut p = simple_phase(Unit::Cube, 1, 1, step);
            p.pipelined_with_prev = c > 0;
            p.chunk = Some(c);
            phases.push(p);
        }
        let t = trace_of(phases);
        let r = Simulator::new(machine()).run(&t).unwrap();
        assert_eq!(r.groups.len(), 1);
        assert_eq!(r.groups[0].chunk_sync_ns, 3.0 * machine().event_ns);

        // A group covering a single (offset) chunk has no boundaries.
        let mut lone = simple_phase(Unit::Cube, 1, 1, step);
        lone.chunk = Some(3);
        let r = Simulator::new(machine()).run(&trace_of(vec![lone])).unwrap();
        assert_eq!(r.groups[0].chunk_sync_ns, 0.0);
    }

    #[test]
    fn rejects_chunk_rewind_within_group() {
        let step = TileStep::new(ComputeOp::Nop);
        let mut p1 = simple_phase(Unit::Cube, 1, 1, step);
        p1.chunk = Some(1);
        let mut p2 = simple_phase(Unit::Cube, 1, 1, step);
        p2.pipelined_with_prev = true;
        p2.chunk = Some(0);
        assert!(Simulator::new(machine()).run(&trace_of(vec![p1, p2])).is_err());
    }

    #[test]
    fn rejects_too_many_engines() {
        let step = TileStep::new(ComputeOp::Nop);
        let t = trace_of(vec![simple_phase(Unit::Cube, 33, 1, step)]);
        assert!(Simulator::new(machine()).run(&t).is_err());
    }

    #[test]
    fn rejects_illegal_op_placement() {
        let step = TileStep::new(ComputeOp::Dequant { elems: 4 });
        let t = trace_of(vec![simple_phase(Unit::Cube, 1, 1, step)]);
        assert!(Simulator::new(machine()).run(&t).is_err());
    }

    #[test]
    fn run_merged_carries_partial_residency_across_kernels() {
        use crate::ascend::trace::MergedTrace;
        // 8 engines x 1 MiB: aggregate HBM (1200 B/ns) vs L2 (4000 B/ns)
        // diverge (a single engine is MTE-capped at 500 either way).
        let engines = 8u64;
        let bytes = 1u64 << 20;
        let total = engines * bytes; // 8 MiB fits L2 -> partial_hit = 1.0
        let producer = {
            let write = TileStep::new(ComputeOp::Nop).write(BufferClass::Partial, bytes);
            let mut t = trace_of(vec![simple_phase(Unit::Cube, engines as usize, 1, write)]);
            t.partial_bytes = total;
            t
        };
        let carried_read =
            TileStep::new(ComputeOp::Nop).read(BufferClass::CarriedPartial, bytes);
        let consumer =
            trace_of(vec![simple_phase(Unit::Vector, engines as usize, 1, carried_read)]);
        let sim = Simulator::new(machine());

        // Standalone, the carried read is cold (all HBM).
        let solo = sim.run(&consumer).unwrap();
        let cp = solo.ledger.class(BufferClass::CarriedPartial);
        assert_eq!(cp.hbm_read, total as f64);
        assert_eq!(cp.l2_read, 0.0);

        // Merged, it inherits the producer's full residency (all L2).
        let merged = MergedTrace {
            name: "m".into(),
            kernels: vec![producer.clone(), consumer.clone()],
        };
        let r = sim.run_merged(&merged).unwrap();
        assert_eq!(r.kernels.len(), 2);
        let cp = r.kernels[1].ledger.class(BufferClass::CarriedPartial);
        assert_eq!(cp.hbm_read, 0.0);
        assert_eq!(cp.l2_read, total as f64);
        // The merged total is the per-kernel sum (launches included).
        let want: f64 = r.kernels.iter().map(|k| k.total_ns).sum();
        assert!((r.total_ns - want).abs() < 1e-9);
        // And faster than running the consumer cold.
        assert!(r.kernels[1].total_ns < solo.total_ns);
    }

    #[test]
    fn pinned_weight_reads_serve_from_l2_under_the_ledger() {
        use crate::ascend::memory::ResidencyLedger;
        // 32 engines each read 1 MiB of weights: cold the phase moves
        // 32 MiB over HBM; pinned, over L2 (3x the bandwidth).
        let bytes = 1u64 << 20;
        let cold_step = TileStep::new(ComputeOp::Nop).read(BufferClass::WeightPacked, bytes);
        let pinned_step = TileStep::new(ComputeOp::Nop).read(BufferClass::CarriedWeight, bytes);
        let sim = Simulator::new(machine());
        let cold = sim
            .run(&trace_of(vec![simple_phase(Unit::Cube, 32, 1, cold_step)]))
            .unwrap();
        // Standalone (no ledger), carried weights price cold — identical.
        let unpinned = sim
            .run(&trace_of(vec![simple_phase(Unit::Cube, 32, 1, pinned_step)]))
            .unwrap();
        assert!((unpinned.total_ns - cold.total_ns).abs() < 1e-9);
        let ledger = ResidencyLedger::with_pinned_weights(32 << 20);
        let resident = sim
            .run_with_residency(
                &trace_of(vec![simple_phase(Unit::Cube, 32, 1, pinned_step)]),
                &ledger,
            )
            .unwrap();
        assert!(resident.total_ns < cold.total_ns);
        let cw = resident.ledger.class(BufferClass::CarriedWeight);
        assert_eq!(cw.hbm_read, 0.0);
        assert_eq!(cw.l2_read, (32u64 << 20) as f64);
        // Byte conservation: pinning moved the bytes, it did not shrink them.
        let cold_w = cold.ledger.class(BufferClass::WeightPacked);
        assert_eq!(cw.l2_read + cw.hbm_read, cold_w.l2_read + cold_w.hbm_read);
    }

    #[test]
    fn chain_carry_attenuates_across_intervening_kernels() {
        use crate::ascend::memory::ResidencyLedger;
        use crate::ascend::trace::MergedTrace;
        let bytes = 1u64 << 20;
        let producer = {
            let write = TileStep::new(ComputeOp::Nop).write(BufferClass::Partial, bytes);
            let mut t = trace_of(vec![simple_phase(Unit::Cube, 8, 1, write)]);
            t.partial_bytes = 8 * bytes; // fits L2 -> partial_hit = 1.0
            t
        };
        let carried_read = TileStep::new(ComputeOp::Nop).read(BufferClass::CarriedPartial, bytes);
        let consumer = trace_of(vec![simple_phase(Unit::Vector, 8, 1, carried_read)]);
        // An intervening kernel whose buffered working set covers half the
        // retained capacity: the second consumer's carried reads see the
        // producer's residency halved.
        let cap = ResidencyLedger::default().available_capacity(&machine());
        let mut intervening = consumer.clone();
        intervening.workspace_bytes = (cap / 2.0) as u64;
        let merged = MergedTrace {
            name: "chain".into(),
            kernels: vec![producer, intervening, consumer.clone()],
        };
        let sim = Simulator::new(machine());
        let r = sim.run_merged(&merged).unwrap();
        assert_eq!(r.kernels.len(), 3);
        // First consumer: full producer residency.
        assert_eq!(r.kernels[1].l2_model.carried_hit, 1.0);
        // Second consumer: attenuated by the intervening working set.
        let hit = r.kernels[2].l2_model.carried_hit;
        assert!((hit - 0.5).abs() < 1e-6, "expected ~0.5, got {hit}");
    }

    #[test]
    fn price_path_is_bit_identical_to_run() {
        use crate::ascend::memory::ResidencyLedger;
        use crate::ascend::trace::MergedTrace;
        use crate::kernels::{self, GemmProblem, Strategy};
        let m = machine();
        let sim = Simulator::new(m.clone());
        let ledgers = [
            ResidencyLedger::default(),
            ResidencyLedger::with_carried_partials(0.6),
            ResidencyLedger::with_pinned_weights(9 << 20),
        ];
        let mut traces = Vec::new();
        for strategy in [Strategy::SplitK, Strategy::Chunked, Strategy::DataParallel] {
            traces.push(
                kernels::schedule(&m, &GemmProblem::new(8, 2048, 7168), strategy).unwrap(),
            );
        }
        traces.push(kernels::schedule(&m, &GemmProblem::new(64, 512, 16384), Strategy::SplitK).unwrap());
        for trace in &traces {
            for ledger in &ledgers {
                let run = sim.run_with_residency(trace, ledger).unwrap().total_ns;
                let price = sim.price_with_residency(trace, ledger).unwrap();
                assert_eq!(price.to_bits(), run.to_bits(), "{}", trace.name);
            }
        }
        // Merged chains: the carried-residency threading must match too.
        let merged = MergedTrace {
            name: "pair".into(),
            kernels: vec![traces[0].clone(), traces[1].clone(), traces[2].clone()],
        };
        for ledger in &ledgers {
            let run = sim.run_merged_with(&merged, ledger).unwrap().total_ns;
            let price = sim.price_merged_with(&merged, ledger).unwrap();
            assert_eq!(price.to_bits(), run.to_bits());
        }
        // And both paths reject the same invalid traces.
        let bad = trace_of(vec![simple_phase(
            Unit::Cube,
            33,
            1,
            TileStep::new(ComputeOp::Nop),
        )]);
        assert!(sim.price(&bad).is_err());
    }

    #[test]
    fn utilization_metrics() {
        let mmad = TileStep::new(ComputeOp::Mmad { m: 16, n: 16, k: 16 });
        let t = trace_of(vec![simple_phase(Unit::Cube, 32, 1000, mmad)]);
        let r = Simulator::new(machine()).run(&t).unwrap();
        assert_eq!(r.total_macs, 32 * 1000 * 4096);
        let util = r.mxu_utilization(&machine());
        assert!(util > 0.0 && util <= 1.0, "util {util}");
    }
}
