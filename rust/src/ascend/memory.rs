//! Memory-system model: L2 residency for producer-consumer traffic and
//! fair-share bandwidth partitioning between engines.
//!
//! The decisive mechanism of the paper's §4.2 lives here: the dequantized
//! FP16 workspace written by the vector cores must be re-read by the cube
//! cores through the memory system.  Whatever fraction of it is still
//! resident in the shared L2 when Phase 2 starts is served at L2 bandwidth;
//! the rest spills to HBM.  Since Algorithm 1 places a full barrier between
//! the phases, residency is capacity-shaped: `min(1, retention * L2 / WS)`.

use super::config::MachineConfig;
use super::trace::{BufferClass, KernelTrace, WorkspacePolicy};

/// Where a transfer class is served from, split into L2-hit and HBM parts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServiceSplit {
    /// Fraction served from L2 (0..1); the rest goes to HBM.
    pub l2_fraction: f64,
    /// Extra HBM write-back bytes per byte written (spill on the write path).
    pub writeback_fraction: f64,
}

impl ServiceSplit {
    pub const COLD: ServiceSplit = ServiceSplit { l2_fraction: 0.0, writeback_fraction: 1.0 };
}

/// Cross-kernel residency state threaded through a chain of kernels — the
/// single ledger that owns everything crossing a kernel boundary
/// (DESIGN.md §13).  PR 4's merged-pair carry and the step-level pinned
/// weights both live here: one ledger, not per-feature carries.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResidencyLedger {
    /// Residency of the splice producer's partial buffers for this
    /// kernel's [`BufferClass::CarriedPartial`] reads (0..1).
    pub carried_partial_hit: f64,
    /// Residency of the step-level weight pins for this kernel's
    /// [`BufferClass::CarriedWeight`] reads (0..1).  The planner only
    /// pins whole weight footprints that fit the retained budget, so a
    /// pinned node reads at 1.0 and an unpinned node never carries the
    /// class at all.
    pub carried_weight_hit: f64,
    /// Weight bytes the step-level plan keeps pinned chip-wide for the
    /// whole decode step: every kernel in the chain loses this much
    /// retained L2 capacity for its own workspace / partial buffers —
    /// the capacity shaping that keeps the plan honest.
    pub reserved_bytes: u64,
}

impl ResidencyLedger {
    /// The PR-4 merged-pair carry: only the producer's partial residency
    /// crosses the boundary.
    pub fn with_carried_partials(hit: f64) -> ResidencyLedger {
        ResidencyLedger { carried_partial_hit: hit, ..ResidencyLedger::default() }
    }

    /// A step-level weight-pinning ledger: `reserved_bytes` of weights
    /// held resident (served at full L2 residency), no partial carry.
    pub fn with_pinned_weights(reserved_bytes: u64) -> ResidencyLedger {
        ResidencyLedger {
            carried_weight_hit: 1.0,
            reserved_bytes,
            ..ResidencyLedger::default()
        }
    }

    /// Retained L2 capacity left for a kernel's own buffers after the
    /// step-level pins.
    pub fn available_capacity(&self, machine: &MachineConfig) -> f64 {
        (machine.l2_retention * machine.l2_bytes as f64 - self.reserved_bytes as f64).max(0.0)
    }

    /// Fraction of carried-partial residency that survives one more
    /// intervening kernel in a chain splice: the kernel's own resident
    /// footprint evicts the producer's partials proportionally
    /// (DESIGN.md §13).  1.0 when the kernel leaves the whole capacity
    /// untouched, 0.0 when its working set covers it.
    pub fn attenuation(&self, machine: &MachineConfig, trace: &KernelTrace) -> f64 {
        let cap = self.available_capacity(machine);
        if cap <= 0.0 {
            return 0.0;
        }
        let footprint = match trace.workspace_policy {
            WorkspacePolicy::Buffered => trace.workspace_bytes + trace.partial_bytes,
            WorkspacePolicy::Pinned { resident_bytes } => resident_bytes + trace.partial_bytes,
        };
        (1.0 - footprint as f64 / cap).max(0.0)
    }
}

/// L2 residency model for one kernel execution.
#[derive(Debug, Clone)]
pub struct L2Model {
    /// Residency of the workspace when re-read (0..1).
    pub workspace_hit: f64,
    /// Residency of the Split-K partial buffers when re-read (0..1).
    pub partial_hit: f64,
    /// Residency of an *upstream kernel's* partial buffers read by spliced
    /// [`BufferClass::CarriedPartial`] steps (0..1).  Standalone runs price
    /// them cold (0.0 — conservative); `Simulator::run_merged` sets this to
    /// the producer kernel's `partial_hit` when it crosses the boundary.
    pub carried_hit: f64,
    /// Residency of the step-level weight pins for
    /// [`BufferClass::CarriedWeight`] reads (0..1); cold standalone.
    pub carried_weight_hit: f64,
}

impl L2Model {
    /// Compute residency from buffer footprints.
    ///
    /// With a barrier between producer and consumer phases, the whole
    /// buffer is produced before any consumption: L2 retains at most
    /// `retention * capacity` bytes of it, so the expected hit fraction on
    /// the consumer side is `min(1, retention * capacity / footprint)`.
    /// The workspace and the partial buffers share capacity in proportion
    /// to their sizes.
    pub fn new(machine: &MachineConfig, workspace_bytes: u64, partial_bytes: u64) -> L2Model {
        let cap = machine.l2_retention * machine.l2_bytes as f64;
        L2Model::with_capacity(cap, workspace_bytes, partial_bytes)
    }

    /// The capacity-shaped model against an explicit retained capacity —
    /// the step-level residency ledger reduces it by the pinned weight
    /// bytes (DESIGN.md §13).
    fn with_capacity(cap: f64, workspace_bytes: u64, partial_bytes: u64) -> L2Model {
        let hit = |bytes: u64| -> f64 {
            if bytes == 0 {
                return 0.0;
            }
            let total = (workspace_bytes + partial_bytes) as f64;
            // Each buffer gets a proportional share of retained capacity.
            let share = cap * bytes as f64 / total;
            (share / bytes as f64).min(1.0)
        };
        L2Model {
            workspace_hit: hit(workspace_bytes),
            partial_hit: hit(partial_bytes),
            carried_hit: 0.0,
            carried_weight_hit: 0.0,
        }
    }

    /// Residency for a whole trace, honouring its workspace policy.
    ///
    /// * [`WorkspacePolicy::Buffered`] — the capacity-shaped model above.
    /// * [`WorkspacePolicy::Pinned`] — the schedule guarantees that only a
    ///   rotating set of slices (`resident_bytes`) is ever live, and the
    ///   chunk-granular producer-consumer handoff keeps them hot: the hit
    ///   fraction is 1.0 whenever the slices fit the retained capacity
    ///   (and degrades proportionally when they do not).  Partial buffers
    ///   get whatever capacity the pinned slices leave behind.
    pub fn for_trace(machine: &MachineConfig, trace: &KernelTrace) -> L2Model {
        L2Model::for_trace_with_ledger(machine, trace, &ResidencyLedger::default())
    }

    /// Residency for a trace under a cross-kernel [`ResidencyLedger`]:
    /// the ledger's pinned weight bytes are carved out of the retained
    /// capacity before the kernel's own buffers shape their residency,
    /// and the carried hits cross the boundary into the carried classes.
    pub fn for_trace_with_ledger(
        machine: &MachineConfig,
        trace: &KernelTrace,
        ledger: &ResidencyLedger,
    ) -> L2Model {
        let cap = ledger.available_capacity(machine);
        let mut model = match trace.workspace_policy {
            WorkspacePolicy::Buffered => {
                L2Model::with_capacity(cap, trace.workspace_bytes, trace.partial_bytes)
            }
            WorkspacePolicy::Pinned { resident_bytes } => {
                let pinned = (resident_bytes as f64).min(cap);
                let workspace_hit = if resident_bytes == 0 {
                    0.0
                } else {
                    pinned / resident_bytes as f64
                };
                let leftover = (cap - pinned).max(0.0);
                let partial_hit = if trace.partial_bytes == 0 {
                    0.0
                } else {
                    (leftover / trace.partial_bytes as f64).min(1.0)
                };
                L2Model {
                    workspace_hit,
                    partial_hit,
                    carried_hit: 0.0,
                    carried_weight_hit: 0.0,
                }
            }
        };
        model.carried_hit = ledger.carried_partial_hit.clamp(0.0, 1.0);
        model.carried_weight_hit = ledger.carried_weight_hit.clamp(0.0, 1.0);
        model
    }

    /// Service split for a *read* of the given class.
    pub fn read_split(&self, class: BufferClass) -> ServiceSplit {
        match class {
            BufferClass::Workspace => ServiceSplit {
                l2_fraction: self.workspace_hit,
                writeback_fraction: 0.0,
            },
            BufferClass::Partial => ServiceSplit {
                l2_fraction: self.partial_hit,
                writeback_fraction: 0.0,
            },
            // Carried partials: the upstream kernel's residency (0 when no
            // merged context carried one over).
            BufferClass::CarriedPartial => ServiceSplit {
                l2_fraction: self.carried_hit,
                writeback_fraction: 0.0,
            },
            // Step-level pinned weights: the residency plan's hit (0 when
            // no step-level ledger pinned this kernel's weights).
            BufferClass::CarriedWeight => ServiceSplit {
                l2_fraction: self.carried_weight_hit,
                writeback_fraction: 0.0,
            },
            // Activations are small and typically L2-resident after first
            // touch, but the first touch is cold; model them as cold reads
            // (they are negligible at decode shapes either way).
            _ => ServiceSplit::COLD,
        }
    }

    /// Service split for a *write* of the given class.  Writes land in L2;
    /// the fraction that will not survive until the consumer phase is
    /// charged as HBM write-back bandwidth.
    pub fn write_split(&self, class: BufferClass) -> ServiceSplit {
        match class {
            BufferClass::Workspace => ServiceSplit {
                l2_fraction: 1.0,
                writeback_fraction: 1.0 - self.workspace_hit,
            },
            BufferClass::Partial => ServiceSplit {
                l2_fraction: 1.0,
                writeback_fraction: 1.0 - self.partial_hit,
            },
            // Outputs are written once and consumed by the host: write-back.
            _ => ServiceSplit { l2_fraction: 1.0, writeback_fraction: 1.0 },
        }
    }
}

/// Effective per-engine bandwidths for a phase with `active` engines.
#[derive(Debug, Clone, Copy)]
pub struct PhaseBandwidth {
    /// Bytes/ns one engine can move against HBM.
    pub hbm_per_engine: f64,
    /// Bytes/ns one engine can move against L2.
    pub l2_per_engine: f64,
}

/// Fair-share bandwidth partitioning: each engine is capped by its MTE and
/// by an equal share of the aggregate L2/HBM bandwidth.  This is the
/// occupancy lever behind Figure 2: a data-parallel schedule that keeps
/// only 4 of 32 cores busy moves at most 4 x min(MTE, HBM/4) bytes/ns.
pub fn phase_bandwidth(machine: &MachineConfig, active_engines: usize) -> PhaseBandwidth {
    let active = active_engines.max(1) as f64;
    PhaseBandwidth {
        hbm_per_engine: machine.mte_core_bw.min(machine.hbm_bw / active),
        l2_per_engine: machine.mte_core_bw.min(machine.l2_bw / active),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn workspace_fitting_l2_hits_fully() {
        // 16 MiB workspace < 0.9 * 32 MiB retained capacity
        let l2 = L2Model::new(&m(), 16 << 20, 0);
        assert_eq!(l2.workspace_hit, 1.0);
    }

    #[test]
    fn oversized_workspace_hits_partially() {
        // 128 MiB workspace >> 32 MiB L2: hit ~ 0.9*32/128 = 0.225
        let l2 = L2Model::new(&m(), 128 << 20, 0);
        assert!((l2.workspace_hit - 0.225).abs() < 1e-9, "{}", l2.workspace_hit);
    }

    #[test]
    fn shared_capacity_splits_proportionally() {
        let l2 = L2Model::new(&m(), 64 << 20, 64 << 20);
        // each gets 0.9*32/2 = 14.4 MiB of 64 MiB -> 0.225
        assert!((l2.workspace_hit - 0.225).abs() < 1e-9);
        assert!((l2.partial_hit - 0.225).abs() < 1e-9);
    }

    #[test]
    fn cold_classes_go_to_hbm() {
        let l2 = L2Model::new(&m(), 1 << 20, 0);
        let split = l2.read_split(BufferClass::WeightPacked);
        assert_eq!(split.l2_fraction, 0.0);
    }

    #[test]
    fn carried_partials_use_the_carried_residency() {
        let mut l2 = L2Model::new(&m(), 1 << 20, 1 << 20);
        // Standalone: carried reads are cold.
        assert_eq!(l2.read_split(BufferClass::CarriedPartial).l2_fraction, 0.0);
        // Merged context: the producer's residency crosses the boundary.
        l2.carried_hit = 0.75;
        assert_eq!(l2.read_split(BufferClass::CarriedPartial).l2_fraction, 0.75);
        // This kernel's own partials are unaffected.
        assert_eq!(l2.read_split(BufferClass::Partial).l2_fraction, l2.partial_hit);
    }

    #[test]
    fn write_spill_complements_hit() {
        let l2 = L2Model::new(&m(), 128 << 20, 0);
        let ws = l2.write_split(BufferClass::Workspace);
        assert!((ws.writeback_fraction - (1.0 - l2.workspace_hit)).abs() < 1e-12);
    }

    #[test]
    fn pinned_slices_stay_resident_regardless_of_footprint() {
        use crate::ascend::trace::{KernelTrace, WorkspacePolicy};
        // A 128 MiB workspace would spill badly under Buffered, but the
        // chunked schedule only keeps 2 x 4 MiB slices live.
        let t = KernelTrace {
            name: "t".into(),
            phases: vec![],
            workspace_bytes: 8 << 20,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Pinned { resident_bytes: 8 << 20 },
        };
        let l2 = L2Model::for_trace(&m(), &t);
        assert_eq!(l2.workspace_hit, 1.0);
        // Oversized slices degrade proportionally instead of thrashing.
        let big = KernelTrace {
            workspace_policy: WorkspacePolicy::Pinned { resident_bytes: 64 << 20 },
            ..t
        };
        let l2 = L2Model::for_trace(&m(), &big);
        assert!((l2.workspace_hit - 0.45).abs() < 1e-9, "{}", l2.workspace_hit);
    }

    #[test]
    fn pinned_leftover_capacity_serves_partials() {
        use crate::ascend::trace::{KernelTrace, WorkspacePolicy};
        let t = KernelTrace {
            name: "t".into(),
            phases: vec![],
            workspace_bytes: 8 << 20,
            partial_bytes: 4 << 20,
            workspace_policy: WorkspacePolicy::Pinned { resident_bytes: 8 << 20 },
        };
        let l2 = L2Model::for_trace(&m(), &t);
        // 0.9*32 - 8 = 20.8 MiB leftover > 4 MiB of partials.
        assert_eq!(l2.partial_hit, 1.0);
    }

    #[test]
    fn reserved_weight_bytes_shrink_workspace_capacity() {
        use crate::ascend::trace::{KernelTrace, WorkspacePolicy};
        // 16 MiB workspace fits the full 28.8 MiB retained capacity, but
        // not once the step-level plan pins 20 MiB of weights.
        let t = KernelTrace {
            name: "t".into(),
            phases: vec![],
            workspace_bytes: 16 << 20,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        };
        let free = L2Model::for_trace_with_ledger(&m(), &t, &ResidencyLedger::default());
        assert_eq!(free.workspace_hit, 1.0);
        let pinned = ResidencyLedger::with_pinned_weights(20 << 20);
        let l2 = L2Model::for_trace_with_ledger(&m(), &t, &pinned);
        // (0.9*32 - 20) MiB / 16 MiB = 0.55
        assert!((l2.workspace_hit - 0.55).abs() < 1e-9, "{}", l2.workspace_hit);
        assert_eq!(l2.carried_weight_hit, 1.0);
        // The pinned-policy path also loses the reserved capacity.
        let pt = KernelTrace {
            workspace_policy: WorkspacePolicy::Pinned { resident_bytes: 16 << 20 },
            ..t
        };
        let l2 = L2Model::for_trace_with_ledger(&m(), &pt, &pinned);
        assert!((l2.workspace_hit - 0.55).abs() < 1e-9, "{}", l2.workspace_hit);
    }

    #[test]
    fn carried_weight_reads_use_the_ledger_hit() {
        let l2 = L2Model::new(&m(), 1 << 20, 0);
        // Standalone: pinned-weight reads are cold.
        assert_eq!(l2.read_split(BufferClass::CarriedWeight).l2_fraction, 0.0);
        use crate::ascend::trace::{KernelTrace, WorkspacePolicy};
        let t = KernelTrace {
            name: "t".into(),
            phases: vec![],
            workspace_bytes: 1 << 20,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        };
        let l2 =
            L2Model::for_trace_with_ledger(&m(), &t, &ResidencyLedger::with_pinned_weights(1));
        assert_eq!(l2.read_split(BufferClass::CarriedWeight).l2_fraction, 1.0);
        // Plain weight reads stay cold — only the re-classed pins hit.
        assert_eq!(l2.read_split(BufferClass::WeightPacked).l2_fraction, 0.0);
    }

    #[test]
    fn attenuation_tracks_intervening_footprint() {
        use crate::ascend::trace::{KernelTrace, WorkspacePolicy};
        let ledger = ResidencyLedger::default();
        let cap = ledger.available_capacity(&m());
        let t = |ws: u64| KernelTrace {
            name: "t".into(),
            phases: vec![],
            workspace_bytes: ws,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        };
        // A tiny intervening kernel barely evicts anything.
        assert!(ledger.attenuation(&m(), &t(1 << 10)) > 0.999);
        // A capacity-sized working set evicts everything.
        assert_eq!(ledger.attenuation(&m(), &t(cap as u64 + 1)), 0.0);
        // Half the capacity evicts half.
        let half = ledger.attenuation(&m(), &t((cap / 2.0) as u64));
        assert!((half - 0.5).abs() < 1e-6, "{half}");
        // With everything reserved, nothing survives.
        let full = ResidencyLedger::with_pinned_weights(cap as u64);
        assert_eq!(full.attenuation(&m(), &t(1)), 0.0);
    }

    #[test]
    fn bandwidth_fair_share_caps() {
        let bw = phase_bandwidth(&m(), 4);
        // 4 cores: HBM/4 = 300 < MTE 500 -> 300 each
        assert!((bw.hbm_per_engine - 300.0).abs() < 1e-9);
        let bw32 = phase_bandwidth(&m(), 32);
        // 32 cores: HBM/32 = 37.5 each
        assert!((bw32.hbm_per_engine - 37.5).abs() < 1e-9);
        // one core is MTE-capped against L2 (4800 > 500)
        let bw1 = phase_bandwidth(&m(), 1);
        assert_eq!(bw1.l2_per_engine, 500.0);
    }
}
