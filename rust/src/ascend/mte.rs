//! Memory-Transfer-Engine model: converts a phase's tile steps into
//! bandwidth demand against HBM and L2, honouring the L2 residency splits.
//!
//! Each engine's MTE moves its steps' bytes; double buffering overlaps the
//! moves with compute, so the executor prices a phase as the *maximum* of
//! its transfer streams and its compute stream (plus pipeline fill).

use super::config::MachineConfig;
use super::memory::L2Model;
use super::trace::{Phase, TileStep, Unit};
use super::{cube, vector};

/// Aggregated demand of one phase, with straggler (max-engine) loads.
#[derive(Debug, Clone, Copy, Default)]
pub struct PhaseDemand {
    pub active: usize,
    /// Total bytes against HBM / L2 across all engines.
    pub hbm_total: f64,
    pub l2_total: f64,
    /// Heaviest single engine's bytes (stragglers gate the phase).
    pub hbm_max_engine: f64,
    pub l2_max_engine: f64,
    /// Heaviest single engine's compute time.
    pub compute_ns_max_engine: f64,
    /// Total compute time across engines (utilization reporting).
    pub compute_ns_total: f64,
    /// Average first-step transfer bytes of the heaviest engine (pipeline fill).
    pub fill_bytes: f64,
    pub steps: usize,
}

/// Price one step's compute on the phase's unit; errors if the unit cannot
/// execute the op (e.g. a type conversion scheduled on a cube core).
fn step_compute_ns(machine: &MachineConfig, unit: Unit, step: &TileStep) -> anyhow::Result<f64> {
    let ns = match unit {
        Unit::Cube => cube::op_ns(machine, step.compute),
        Unit::Vector => vector::op_ns(machine, step.compute),
    };
    ns.ok_or_else(|| {
        anyhow::anyhow!("op {:?} not executable on {:?} unit", step.compute, unit)
    })
}

/// Split one step's traffic into (hbm_bytes, l2_bytes) under the L2 model.
fn step_traffic(l2: &L2Model, step: &TileStep) -> (f64, f64) {
    let mut hbm = 0.0;
    let mut l2b = 0.0;
    for &(class, bytes) in &step.reads {
        if bytes == 0 {
            continue;
        }
        let split = l2.read_split(class);
        l2b += bytes as f64 * split.l2_fraction;
        hbm += bytes as f64 * (1.0 - split.l2_fraction);
    }
    for &(class, bytes) in &step.writes {
        if bytes == 0 {
            continue;
        }
        let split = l2.write_split(class);
        l2b += bytes as f64 * split.l2_fraction;
        hbm += bytes as f64 * split.writeback_fraction;
    }
    (hbm, l2b)
}

/// Compute the demand profile of a phase.
pub fn phase_demand(
    machine: &MachineConfig,
    l2: &L2Model,
    phase: &Phase,
) -> anyhow::Result<PhaseDemand> {
    let mut d = PhaseDemand { active: phase.active_engines(), ..Default::default() };
    let mut max_engine_bytes = 0.0f64;
    for steps in &phase.steps_per_engine {
        if steps.is_empty() {
            continue;
        }
        let mut e_hbm = 0.0;
        let mut e_l2 = 0.0;
        let mut e_compute = 0.0;
        // Hot path: schedules emit long runs of identical steps (the K
        // walk of one tile).  Price each run once and multiply.
        let mut i = 0;
        while i < steps.len() {
            let step = &steps[i];
            let mut run = 1usize;
            while i + run < steps.len() && steps[i + run] == *step {
                run += 1;
            }
            let (hbm, l2b) = step_traffic(l2, step);
            // Short row segments waste DMA bandwidth: charge the effective
            // (inflated) byte count against the transfer streams.
            let eff = burst_efficiency(machine, step.burst);
            e_hbm += hbm / eff * run as f64;
            e_l2 += l2b / eff * run as f64;
            e_compute += step_compute_ns(machine, phase.unit, step)? * run as f64;
            i += run;
        }
        d.hbm_total += e_hbm;
        d.l2_total += e_l2;
        d.compute_ns_total += e_compute;
        d.hbm_max_engine = d.hbm_max_engine.max(e_hbm);
        d.l2_max_engine = d.l2_max_engine.max(e_l2);
        d.compute_ns_max_engine = d.compute_ns_max_engine.max(e_compute);
        d.steps += steps.len();
        if e_hbm + e_l2 > max_engine_bytes {
            max_engine_bytes = e_hbm + e_l2;
            d.fill_bytes = (e_hbm + e_l2) / steps.len() as f64;
        }
    }
    Ok(d)
}

/// Bandwidth efficiency of a transfer whose contiguous row segment is
/// `burst` bytes (1.0 when 0 = contiguous or >= the machine burst size).
pub fn burst_efficiency(machine: &MachineConfig, burst: u64) -> f64 {
    if burst == 0 {
        return 1.0;
    }
    (burst as f64 / machine.dma_burst_bytes).min(1.0)
}

/// Effective per-engine bandwidth against a shared resource: the engine's
/// MTE cap or a fair share of the aggregate, whichever binds.
pub fn effective_bw(machine: &MachineConfig, shared_bw: f64, active: usize) -> f64 {
    machine.mte_core_bw.min(shared_bw / active.max(1) as f64)
}

/// Aggregate bandwidth the phase's active engines can raise against a
/// shared resource (each engine capped by its MTE).
pub fn aggregate_bw(machine: &MachineConfig, shared_bw: f64, active: usize) -> f64 {
    (machine.mte_core_bw * active.max(1) as f64).min(shared_bw)
}

/// Transfer time of the phase against HBM.
///
/// Bandwidth-bound transfers see no straggler penalty: when the tail wave
/// leaves engines idle, the remaining MTEs absorb their share of the
/// aggregate bandwidth (work imbalance only gates the *compute* stream).
pub fn hbm_time_ns(machine: &MachineConfig, d: &PhaseDemand) -> f64 {
    if d.hbm_total == 0.0 {
        return 0.0;
    }
    d.hbm_total / aggregate_bw(machine, machine.hbm_bw, d.active)
}

/// Transfer time of the phase against L2.
pub fn l2_time_ns(machine: &MachineConfig, d: &PhaseDemand) -> f64 {
    if d.l2_total == 0.0 {
        return 0.0;
    }
    d.l2_total / aggregate_bw(machine, machine.l2_bw, d.active)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::trace::{BufferClass, ComputeOp};

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    fn phase(steps_per_engine: Vec<Vec<TileStep>>, unit: Unit) -> Phase {
        Phase { name: "t", unit, steps_per_engine, pipelined_with_prev: false, chunk: None }
    }

    #[test]
    fn demand_accumulates_and_tracks_straggler() {
        let l2 = L2Model::new(&m(), 0, 0);
        let step = TileStep::new(ComputeOp::Nop).read(BufferClass::WeightPacked, 1000);
        let p = phase(vec![vec![step; 2], vec![step]], Unit::Vector);
        let d = phase_demand(&m(), &l2, &p).unwrap();
        assert_eq!(d.active, 2);
        assert_eq!(d.hbm_total, 3000.0);
        assert_eq!(d.hbm_max_engine, 2000.0);
        assert_eq!(d.l2_total, 0.0);
    }

    #[test]
    fn workspace_reads_split_by_residency() {
        // Oversized workspace: hit 0.225 (see memory tests)
        let l2 = L2Model::new(&m(), 128 << 20, 0);
        let step = TileStep::new(ComputeOp::Nop).read(BufferClass::Workspace, 1000);
        let p = phase(vec![vec![step]], Unit::Cube);
        let d = phase_demand(&m(), &l2, &p).unwrap();
        assert!((d.l2_total - 225.0).abs() < 1e-9);
        assert!((d.hbm_total - 775.0).abs() < 1e-9);
    }

    #[test]
    fn invalid_op_for_unit_errors() {
        let l2 = L2Model::new(&m(), 0, 0);
        let step = TileStep::new(ComputeOp::Dequant { elems: 128 });
        let p = phase(vec![vec![step]], Unit::Cube);
        assert!(phase_demand(&m(), &l2, &p).is_err());
    }

    #[test]
    fn effective_bandwidth_caps() {
        // 1 engine: MTE-capped; 32 engines: fair-share capped
        assert_eq!(effective_bw(&m(), 1200.0, 1), m().mte_core_bw.min(1200.0));
        assert_eq!(effective_bw(&m(), 1200.0, 32), 37.5);
    }
}
