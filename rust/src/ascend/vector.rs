//! Vector-core (AIV) timing: 2048-bit SIMD elementwise / conversion unit.
//!
//! The vector core is the only unit that can convert types on the Ascend
//! 910, so Phase 1 (INT4 -> FP16 dequantization) and Phase 3 (FP32 split
//! reduction + cast) of Algorithm 1 run here.

use super::config::MachineConfig;
use super::trace::ComputeOp;

/// SIMD operations per element for the dequant sequence:
/// unpack shift + mask, subtract zero point, multiply by scale (the
/// "native data type-cast" path the paper chooses over Marlin-style bit
/// tricks, since the conversion runs on a real vector unit here).
const DEQUANT_OPS_PER_ELEM: f64 = 4.0;

/// SIMD operations per element for FP16 -> INT8 activation quantization
/// (W4A8 prologue): multiply by the inverse scale, round, clamp.
const QUANTIZE_ACT_OPS_PER_ELEM: f64 = 3.0;

/// Nanoseconds for one compute op on a vector core; `None` for MMAD (the
/// vector unit has no matrix datapath).
pub fn op_ns(machine: &MachineConfig, op: ComputeOp) -> Option<f64> {
    match op {
        ComputeOp::Dequant { elems } => {
            let cycles = elems as f64 * DEQUANT_OPS_PER_ELEM / machine.vector_lanes_f16;
            Some(machine.cycles_to_ns(cycles))
        }
        ComputeOp::Reduce { elems, terms } => {
            // (terms - 1) adds in f32 plus one cast per output element.
            let adds = elems as f64 * (terms.saturating_sub(1)) as f64;
            let casts = elems as f64;
            let cycles =
                adds / machine.vector_lanes_f32 + casts / machine.vector_lanes_f16;
            Some(machine.cycles_to_ns(cycles))
        }
        ComputeOp::Cast { elems } => {
            Some(machine.cycles_to_ns(elems as f64 / machine.vector_lanes_f16))
        }
        ComputeOp::QuantizeAct { elems } => {
            let cycles = elems as f64 * QUANTIZE_ACT_OPS_PER_ELEM / machine.vector_lanes_f16;
            Some(machine.cycles_to_ns(cycles))
        }
        ComputeOp::Nop => Some(0.0),
        ComputeOp::Mmad { .. } | ComputeOp::MmadInt8 { .. } => None,
    }
}

/// Check UB capacity for a dequant tile: packed in + f16 out, double buffered.
pub fn dequant_tile_fits_ub(machine: &MachineConfig, bk: usize, bn: usize) -> bool {
    let packed = bk * bn / 2;
    let out = bk * bn * 2;
    (2 * (packed + out)) as u64 <= machine.ub_bytes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn dequant_throughput() {
        // 128 lanes, 4 ops/elem: 128 elems = 4 cycles = 4 ns at 1 GHz
        assert_eq!(op_ns(&m(), ComputeOp::Dequant { elems: 128 }), Some(4.0));
    }

    #[test]
    fn reduce_cost_scales_with_terms() {
        let r2 = op_ns(&m(), ComputeOp::Reduce { elems: 64, terms: 2 }).unwrap();
        let r8 = op_ns(&m(), ComputeOp::Reduce { elems: 64, terms: 8 }).unwrap();
        assert!(r8 > r2);
        // terms=1 degenerates to a pure cast
        let r1 = op_ns(&m(), ComputeOp::Reduce { elems: 64, terms: 1 }).unwrap();
        let cast = op_ns(&m(), ComputeOp::Cast { elems: 64 }).unwrap();
        assert_eq!(r1, cast);
    }

    #[test]
    fn vector_cannot_mmad() {
        assert_eq!(op_ns(&m(), ComputeOp::Mmad { m: 16, n: 16, k: 16 }), None);
        assert_eq!(op_ns(&m(), ComputeOp::MmadInt8 { m: 16, n: 16, k: 16 }), None);
    }

    #[test]
    fn quantize_act_throughput() {
        // 128 lanes, 3 ops/elem: 128 elems = 3 cycles = 3 ns at 1 GHz —
        // cheaper than dequant (no unpack) but not free.
        assert_eq!(op_ns(&m(), ComputeOp::QuantizeAct { elems: 128 }), Some(3.0));
        let q = op_ns(&m(), ComputeOp::QuantizeAct { elems: 256 }).unwrap();
        let d = op_ns(&m(), ComputeOp::Dequant { elems: 256 }).unwrap();
        assert!(q < d);
    }

    #[test]
    fn ub_capacity() {
        assert!(dequant_tile_fits_ub(&m(), 128, 256)); // 2*(16K+64K)=160K < 256K
        assert!(!dequant_tile_fits_ub(&m(), 512, 512));
    }
}
