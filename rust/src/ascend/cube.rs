//! Cube-core (AIC) timing: the 16x16x16 FP16 MMAD systolic unit.
//!
//! The cube core retires one 16x16x16 FP16 multiply-accumulate tile per
//! cycle into the FP32 L0C accumulator.  It cannot perform type
//! conversion or general elementwise arithmetic — the architectural fact
//! Algorithm 1 is built around.

use super::config::MachineConfig;
use super::trace::ComputeOp;

/// Nanoseconds for one compute op on a cube core; `None` if the op is not
/// executable on this unit (type conversion / elementwise work).
pub fn op_ns(machine: &MachineConfig, op: ComputeOp) -> Option<f64> {
    match op {
        ComputeOp::Mmad { m, n, k } => {
            // Dimensions are padded up to whole cube tiles by the hardware
            // (the paper: small batches are padded, hence flat time in M).
            let t = machine.cube_tile;
            let pad = |x: usize| x.div_ceil(t) * t;
            let cycles = machine.mmad_cycles(pad(m), pad(n), pad(k));
            Some(machine.cycles_to_ns(cycles))
        }
        ComputeOp::MmadInt8 { m, n, k } => {
            // Same padded-tile walk at the INT8 datapath's MAC rate.
            let t = machine.cube_tile;
            let pad = |x: usize| x.div_ceil(t) * t;
            let macs = (pad(m) * pad(n) * pad(k)) as f64;
            Some(machine.cycles_to_ns(macs / machine.cube_macs_per_cycle_int8))
        }
        ComputeOp::Nop => Some(0.0),
        // No conversion / elementwise datapath on the cube core.
        ComputeOp::Dequant { .. }
        | ComputeOp::Reduce { .. }
        | ComputeOp::Cast { .. }
        | ComputeOp::QuantizeAct { .. } => None,
    }
}

/// Check L0 capacity for an MMAD block: A tile in L0A, B tile in L0B
/// (double-buffered: x2), C tile in L0C (FP32).
pub fn block_fits_l0(machine: &MachineConfig, bm: usize, bn: usize, bk: usize) -> bool {
    let a = 2 * bm * bk * 2; // f16, double buffered
    let b = 2 * bk * bn * 2;
    let c = bm * bn * 4; // f32 accumulator
    (a as u64) <= machine.l0a_bytes && (b as u64) <= machine.l0b_bytes && (c as u64) <= machine.l0c_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::trace::ComputeOp;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn native_tile_is_one_cycle() {
        assert_eq!(op_ns(&m(), ComputeOp::Mmad { m: 16, n: 16, k: 16 }), Some(1.0));
    }

    #[test]
    fn padding_to_cube_tile() {
        // m=1 is padded to 16: same cost as m=16 (flat-in-M behaviour)
        let one = op_ns(&m(), ComputeOp::Mmad { m: 1, n: 256, k: 128 }).unwrap();
        let sixteen = op_ns(&m(), ComputeOp::Mmad { m: 16, n: 256, k: 128 }).unwrap();
        assert_eq!(one, sixteen);
    }

    #[test]
    fn cube_cannot_convert_types() {
        assert_eq!(op_ns(&m(), ComputeOp::Dequant { elems: 10 }), None);
        assert_eq!(op_ns(&m(), ComputeOp::Cast { elems: 10 }), None);
        assert_eq!(op_ns(&m(), ComputeOp::QuantizeAct { elems: 10 }), None);
    }

    #[test]
    fn int8_mmad_runs_at_twice_the_fp16_rate() {
        let f16 = op_ns(&m(), ComputeOp::Mmad { m: 16, n: 256, k: 128 }).unwrap();
        let i8 = op_ns(&m(), ComputeOp::MmadInt8 { m: 16, n: 256, k: 128 }).unwrap();
        assert_eq!(i8 * 2.0, f16);
        // Padding applies to the INT8 path identically.
        let one = op_ns(&m(), ComputeOp::MmadInt8 { m: 1, n: 256, k: 128 }).unwrap();
        assert_eq!(one, i8);
    }

    #[test]
    fn l0_capacity_check() {
        // B tile double-buffered: 2*128*128*2 = 64 KiB == L0B exactly
        assert!(block_fits_l0(&m(), 16, 128, 128));
        // 2*128*256*2 = 128 KiB > 64 KiB L0B
        assert!(!block_fits_l0(&m(), 16, 256, 128));
        // 512x512 f32 accumulator = 1 MiB > 256 KiB L0C
        assert!(!block_fits_l0(&m(), 512, 512, 128));
    }
}
