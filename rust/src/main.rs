//! `repro` — CLI front end for the W4A16 reproduction.
//!
//! Subcommands:
//! * `machine`    — print the simulated Ascend 910 description.
//! * `simulate`   — simulate one GEMM (`--n --k --batch --strategy`,
//!   including `--strategy auto` through the tune cache).
//! * `layer`      — simulate one full decode step (attention, glue, the
//!   projection GEMMs or MoE expert fan-out, cross-node overlap — the
//!   DESIGN.md §10–§11 graph), GEMMs resolved through the tune cache.
//! * `tune`       — autotune the paper sweep + the decode-layer graphs,
//!   persist the winners.
//! * `fig2`       — regenerate the paper's Figure 2 (Split-K vs DP sweep).
//! * `fig3`       — regenerate Figure 3 (W4A16 vs native FP16 sweep).
//! * `analyze`    — §4.2 memory-bottleneck decomposition for one shape.
//! * `quickstart` — execute a real W4A16 artifact through PJRT.
//! * `serve`      — run the decode-serving coordinator on synthetic load.
//! * `serve-load` — continuous-batching serve: Poisson/trace arrivals,
//!   chunked prefill interleaved with decode, KV paging, SLO metrics.

#![deny(deprecated)]

use ascend_w4a16::analysis::report::Report;
use ascend_w4a16::analysis::stepsim::StepSim;
use ascend_w4a16::analysis::{layer, report, residency, roofline, sensitivity, timeline, traffic};
use ascend_w4a16::ascend::{BufferClass, MachineConfig, Simulator};
use ascend_w4a16::coordinator::{
    Admission, BatchPolicy, Batcher, FaultPlan, PreemptPolicy, Router, ServeOptions, Server,
    DEFAULT_MAX_PREEMPTIONS, DEFAULT_MAX_WAIT_US, DEFAULT_PREFILL_CHUNK, DEFAULT_QUEUE_CAP,
};
use ascend_w4a16::kernels::{self, GemmProblem, Strategy};
use ascend_w4a16::model::llm::{self, LayerGeometry, MoeGeometry};
use ascend_w4a16::model::Precision;
use ascend_w4a16::quant;
use ascend_w4a16::runtime::client::literal_to_host;
use ascend_w4a16::runtime::{HostTensor, Manifest, Runtime};
use ascend_w4a16::tensor::MatF32;
use ascend_w4a16::tune::{self, Tuner};
use ascend_w4a16::util::cli::Args;
use ascend_w4a16::util::pool;
use ascend_w4a16::util::prng::Rng;
use ascend_w4a16::util::stats;
use ascend_w4a16::workload::{self, DecodeLayer, DecodeStep, RequestGenerator};

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    match args.subcommand.as_deref() {
        Some("machine") => cmd_machine(),
        Some("simulate") => cmd_simulate(args),
        Some("layer") => cmd_layer(args),
        Some("tune") => cmd_tune(args),
        Some("bench-diff") => cmd_bench_diff(args),
        Some("fig2") => cmd_fig2(args),
        Some("fig3") => cmd_fig3(args),
        Some("analyze") => cmd_analyze(args),
        Some("sensitivity") => cmd_sensitivity(args),
        Some("trace") => cmd_trace(args),
        Some("quickstart") => cmd_quickstart(args),
        Some("serve") => cmd_serve(args),
        Some("serve-load") => cmd_serve_load(args),
        other => {
            if let Some(name) = other {
                eprintln!("unknown subcommand '{name}'\n");
            }
            print_usage();
            Ok(())
        }
    }
}

fn print_usage() {
    println!(
        "repro — W4A16 mixed-precision matmul on a decoupled NPU (paper reproduction)

USAGE: repro <subcommand> [options]

  machine                          print the simulated Ascend 910 description
  simulate --n N --k K [--batch M] [--strategy splitk|dp|fp16|fused|chunked|w4a8|auto]
           [--precision w4a16|w4a8] [--tune-cache PATH]
                                   ('auto' resolves through the tune cache;
                                   the w4a8 strategy needs --precision w4a8)
  layer [--model llama32|glm45|deepseek|openpangu|deepseek-moe
         | --hidden H --ffn F [--kv W] [--group G]]
        [--batch M] [--layers L] [--kv-len T] [--heads H]
        [--moe-experts E] [--moe-topk K]
        [--overlap sequential|overlapped|exact|auto]
        [--residency off|auto] [--precision w4a16|w4a8]
        [--strategy auto|...] [--tune-cache PATH] [--json PATH]
                                   simulate one FULL decode step: attention
                                   score/softmax/AV + RMSNorm/residual/glue on
                                   the vector cores, the projection GEMMs (or
                                   the routed MoE expert fan-out), each GEMM
                                   resolved through the tune cache with 'auto',
                                   and the cross-node reduce/dequant overlap —
                                   'overlapped' prices the first-order ledger,
                                   'exact' re-simulates the co-scheduled merged
                                   traces (DESIGN.md §12), 'auto' serves
                                   min(sequential, overlapped, exact);
                                   '--residency auto' (default) additionally
                                   plans step-level L2 weight pinning
                                   (DESIGN.md §13) and serves
                                   min(plan, resident plan) — never slower
  tune [--out PATH] [--artifacts DIR] [--n N --k K [--batch M]] [--prune]
       [--precision w4a16|w4a8]    autotune strategies x tilings (the paper
                                   sweep, plus DIR's decode-model shapes)
                                   and persist the winners to PATH
                                   (default tune_cache.json); also seeds the
                                   co-schedule pair decisions and the
                                   step-level residency plans so the router
                                   resolves both cache-only; --prune drops
                                   entries whose machine tag no longer
                                   matches this machine, then exits
  bench-diff --baseline B.json --current C.json [--threshold 0.02]
             [--out REPORT.json] [--bless]
                                   gate a BENCH_*.json run against its
                                   committed baseline: any simulated-cycle
                                   cell slower by more than the threshold
                                   fails (exit 1); --bless overwrites the
                                   baseline with the current run
  fig2 [--json PATH]               Figure 2: Split-K vs Data-Parallel sweep
  fig3 [--json PATH]               Figure 3: W4A16 vs native FP16 sweep
  analyze [--n N --k K --batch M]  §4.2 memory-bottleneck decomposition
  sensitivity [--knob l2_bw|hbm_bw|l2_bytes|mte_core_bw|barrier_ns] [--batch M]
                                   how the paper's headline numbers move with
                                   the architecture (co-design exploration)
  trace --out FILE.json [--n N --k K --batch M --strategy S]
                                   chrome://tracing timeline of one kernel
  quickstart [--artifacts DIR]     run a real W4A16 artifact through PJRT
  serve [--model tiny|small100m] [--requests N] [--seed S] [--artifacts DIR]
        [--fault-rate P --fault-seed S] [--deadline-us D]
        [--queue-cap N] [--max-wait-us W]
                                   run the decode-serving coordinator on
                                   synthetic load; --fault-rate injects
                                   seeded stragglers / transient step
                                   failures (retried with backoff),
                                   --deadline-us attaches a per-request
                                   SLO, --queue-cap bounds admission
                                   (overflow sheds with a retry hint)
  serve-load [--model tiny|small100m] [--artifacts DIR] [--batch B]
             [--requests N] [--mean-gap-us G] [--seed S] [--chunk C]
             [--queue-cap N] [--deadline-us D]
             [--fault-rate P --fault-seed S]
             [--kv-capacity-bytes BYTES] [--page-bytes BYTES]
             [--precision w4a16|w4a8]
             [--preempt off|recompute|swap|auto] [--max-preemptions N]
             [--trace IN.json] [--trace-out OUT.json]
                                   continuous-batching serve on the
                                   virtual clock: seeded Poisson arrivals
                                   (or a replayed --trace file), chunked
                                   prefill interleaved against in-flight
                                   decode, KV-cache paging against the
                                   HBM budget; reports TTFT / per-token
                                   latency percentiles and goodput.
                                   --preempt evicts LRU victims under KV
                                   pressure instead of shedding, resuming
                                   them by re-prefill (recompute), host-
                                   link paging (swap), or the cheaper of
                                   the two (auto)"
    );
}

fn machine() -> MachineConfig {
    MachineConfig::ascend910()
}

/// The `--precision` flag shared by simulate/layer/tune/serve-load
/// (default: the paper's W4A16 kernel).
fn cli_precision(args: &Args) -> anyhow::Result<Precision> {
    args.get_choice("precision", Precision::CHOICES, Precision::W4A16)
}

fn cmd_machine() -> anyhow::Result<()> {
    let m = machine();
    m.validate()?;
    println!("Ascend 910 (simulated)");
    println!("  AI cores            : {} (x{} vector cores each)", m.ai_cores, m.vector_per_core);
    println!("  clock               : {:.1} GHz", m.clock_ghz);
    println!("  peak FP16           : {:.1} TFLOPS", m.peak_tflops_f16());
    println!("  HBM bandwidth       : {:.0} GB/s", m.hbm_bw);
    println!("  L2 buffer           : {} @ {:.0} GB/s", stats::fmt_bytes(m.l2_bytes as f64), m.l2_bw);
    println!("  per-core MTE        : {:.0} GB/s", m.mte_core_bw);
    println!("  L1/L0A/L0B/L0C/UB   : {}/{}/{}/{}/{}",
        stats::fmt_bytes(m.l1_bytes as f64),
        stats::fmt_bytes(m.l0a_bytes as f64),
        stats::fmt_bytes(m.l0b_bytes as f64),
        stats::fmt_bytes(m.l0c_bytes as f64),
        stats::fmt_bytes(m.ub_bytes as f64));
    println!("  roofline ridge      : {:.0} flops/byte", roofline::ridge_point(&m));
    Ok(())
}

/// Resolve a CLI strategy for one problem: concrete strategies keep their
/// heuristic tiling; `auto` goes through the tune cache at `--tune-cache`
/// (falling back to a live search that warms the cache file).
fn resolve_cli_strategy(
    args: &Args,
    m: &MachineConfig,
    p: &GemmProblem,
    strategy: Strategy,
) -> anyhow::Result<(Strategy, kernels::tiling::Tiling)> {
    if strategy != Strategy::Auto {
        return Ok((strategy, kernels::select_tiling(m, p, strategy)?));
    }
    let path = args.get_or("tune-cache", tune::DEFAULT_CACHE_FILE);
    let mut tuner = Tuner::load(m.clone(), path)?;
    let resolved = tuner.resolve_strategy(p, Strategy::Auto)?;
    if tuner.searches > 0 {
        tuner.save()?;
        println!("auto: searched {} (cache warmed at {path})", resolved.0.name());
    } else {
        println!("auto: cache hit -> {}", resolved.0.name());
    }
    Ok(resolved)
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 2048)?;
    let k = args.get_usize("k", 7168)?;
    let batch = args.get_usize("batch", 8)?;
    let strategy = Strategy::from_name(args.get_or("strategy", "splitk"))?;
    let m = machine();
    let p = GemmProblem::new(batch, n, k).with_precision(cli_precision(args)?);
    let (strategy, tiling) = resolve_cli_strategy(args, &m, &p, strategy)?;
    let trace = kernels::schedule_with(&m, &p, strategy, &tiling)?;
    let r = Simulator::new(m.clone()).run(&trace)?;
    println!("kernel {}  ({} phases)", r.name, r.phase_times.len());
    println!("total: {}   (launch {} + barriers {})",
        stats::fmt_ns(r.total_ns), stats::fmt_ns(r.launch_ns), stats::fmt_ns(r.barrier_ns));
    for pt in &r.phase_times {
        println!(
            "  phase {:<12} [{:?}] engines={:<3} steps={:<6} hbm {:>10} l2 {:>10} compute {:>10}",
            pt.name, pt.unit, pt.active_engines, pt.steps,
            stats::fmt_ns(pt.hbm_ns), stats::fmt_ns(pt.l2_ns), stats::fmt_ns(pt.compute_ns)
        );
    }
    for g in &r.groups {
        println!("  group {:?}: {} (bound by {})", g.phases, stats::fmt_ns(g.total_ns), g.bound_by);
    }
    let point = roofline::place(&m, &r);
    println!(
        "achieved {:.1} TFLOPS ({:.1}% of attainable {:.1}; {})",
        point.achieved_tflops,
        100.0 * point.efficiency,
        point.attainable_tflops,
        if point.memory_bound { "memory-bound" } else { "compute-bound" }
    );
    Ok(())
}

fn cmd_layer(args: &Args) -> anyhow::Result<()> {
    let m = machine();
    let batch = args.get_usize("batch", 8)?;
    let layers = args.get_usize("layers", 32)?;
    let strategy = Strategy::from_name(args.get_or("strategy", "auto"))?;
    let overlap = args.get_choice("overlap", layer::OverlapMode::CHOICES, layer::OverlapMode::Auto)?;
    let residency_mode = args.get_choice(
        "residency",
        residency::ResidencyMode::CHOICES,
        residency::ResidencyMode::Auto,
    )?;
    let (geometry, preset_moe) = match args.get("model") {
        Some(name) => (llm::layer_geometry(name)?, llm::moe_geometry(name)),
        None => {
            let hidden = args.get_usize("hidden", 5120)?;
            let geometry = LayerGeometry {
                hidden,
                ffn: args.get_usize("ffn", 12288)?,
                kv: args.get_usize("kv", hidden)?,
                group: args.get_usize("group", 128)?,
            };
            (geometry, None)
        }
    };
    // --moe-experts/--moe-topk enable (or override a preset's) routed
    // expert fan-out; the expert inner width defaults to the FFN width.
    let experts = args.get_usize("moe-experts", preset_moe.map_or(0, |mo| mo.experts))?;
    let moe = if experts > 0 {
        Some(MoeGeometry {
            experts,
            topk: args.get_usize("moe-topk", preset_moe.map_or(2, |mo| mo.topk))?,
            expert_ffn: preset_moe.map_or(geometry.ffn, |mo| mo.expert_ffn),
        })
    } else {
        None
    };
    let mut decode_layer = DecodeLayer::new(geometry, batch).with_precision(cli_precision(args)?);
    if let Some(moe) = moe {
        decode_layer = decode_layer.with_moe(moe);
    }
    decode_layer.validate()?;
    let kv_len = args.get_usize("kv-len", 2048)?;
    let heads = args.get_usize("heads", DecodeStep::default_heads(&geometry))?;
    let step = DecodeStep::new(decode_layer, kv_len, heads);

    let rep = if strategy == Strategy::Auto {
        let path = args.get_or("tune-cache", tune::DEFAULT_CACHE_FILE);
        let mut tuner = Tuner::load(m.clone(), path)?;
        let rep = StepSim::new(&m, &step)
            .overlap(overlap)
            .residency(residency_mode)
            .tuner(&mut tuner)
            .run()?;
        if tuner.searches > 0 {
            tuner.save()?;
            println!("auto: searched {} shapes (cache warmed at {path})\n", tuner.searches);
        } else {
            println!("auto: every GEMM node served from the tune cache at {path}\n");
        }
        rep
    } else {
        StepSim::new(&m, &step)
            .overlap(overlap)
            .residency(residency_mode)
            .resolver(|p| {
                Ok((strategy, kernels::select_tiling(&m, p, strategy)?, layer::Resolution::Heuristic))
            })
            .run()?
    };

    print!("{}", layer::render_step(&rep, layers));
    if let Some(path) = args.get("json") {
        std::fs::write(path, layer::step_json(&rep).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_tune(args: &Args) -> anyhow::Result<()> {
    let m = machine();
    let out = args.get_or("out", tune::DEFAULT_CACHE_FILE);
    let mut tuner = Tuner::load(m.clone(), out)?;
    if args.flag("prune") {
        // Eviction of machine-tag-mismatched entries: the tag key already
        // guarantees stale entries are never served; pruning reclaims the
        // cache file after a machine-config change.
        let tag = tune::machine_tag(&m);
        let before =
            tuner.cache.len() + tuner.cache.overlap_len() + tuner.cache.residency_len();
        let removed = tuner.cache.prune_mismatched(&tag);
        tuner.save()?;
        println!(
            "pruned {removed} of {before} cached entries whose machine tag != {tag} -> {out}"
        );
        return Ok(());
    }
    let sim = Simulator::new(m.clone());
    // `--precision w4a8` tunes the same sweep under W4A8-tagged keys, so
    // a cache can hold both families side by side (W4A16 keys unchanged).
    let precision = cli_precision(args)?;

    // One explicit shape, or the full paper sweep; with --artifacts, also
    // every decode model's layer graph per compiled batch size so the
    // serving router's cache-only lookups actually hit.  The layer list
    // is built ONCE and drives both the per-shape tuning below and the
    // co-schedule pair seeding after it — the shape cache and the pair
    // cache can never enumerate different graphs.
    let mut layers: Vec<DecodeLayer> = Vec::new();
    let problems: Vec<GemmProblem> = match (args.get("n"), args.get("k")) {
        (Some(_), _) | (_, Some(_)) => {
            // Single-shape run: no layer graph, so no pairs to seed.
            let n = args.get_usize("n", 2048)?;
            let k = args.get_usize("k", 7168)?;
            let batch = args.get_usize("batch", 8)?;
            vec![GemmProblem::new(batch, n, k).with_precision(precision)]
        }
        _ => {
            // Every paper model's full decode-layer GEMM graph (qkv,
            // attn_out, up_gate, down — or the routed expert pair) per
            // batch size, so `repro layer --strategy auto` is a pure
            // cache hit afterwards.
            for (_, geom) in llm::paper_layer_geometries() {
                for &batch in &llm::PAPER_BATCH_SIZES {
                    layers.push(DecodeLayer::new(geom, batch).with_precision(precision));
                }
            }
            for (_, geom, moe) in llm::paper_moe_geometries() {
                for &batch in &llm::PAPER_BATCH_SIZES {
                    layers.push(
                        DecodeLayer::new(geom, batch).with_moe(moe).with_precision(precision),
                    );
                }
            }
            if let Some(dir) = args.get("artifacts") {
                let mf = Manifest::load(dir)?;
                for entry in mf.artifacts.iter().filter(|a| a.kind == "decode") {
                    if let (Some(cfg), Some(batch)) = (entry.config, entry.batch) {
                        layers.push(
                            DecodeLayer::from_decode_config(&cfg, batch)
                                .with_precision(precision),
                        );
                    }
                }
            }
            let mut problems: Vec<GemmProblem> = workload::paper_sweep()
                .iter()
                .map(|(shape, batch)| {
                    workload::problem_for(shape, *batch).with_precision(precision)
                })
                .collect();
            for decode_layer in &layers {
                for node in decode_layer.gemm_nodes() {
                    if node.problem.validate().is_ok() {
                        problems.push(node.problem);
                    }
                }
            }
            // Padded-M aliasing makes many cells share a cache entry; drop
            // exact duplicate keys so the report stays readable.
            let mut seen = std::collections::BTreeSet::new();
            problems.retain(|p| seen.insert(tune::shape_key(&m, p)));
            problems
        }
    };

    println!(
        "{:<28} {:>12} {:>10} {:>10} {:>9}",
        "shape", "winner", "tuned_us", "splitk_us", "speedup"
    );
    // Tune-cache misses search in parallel (`resolve_many`), and the
    // Split-K reference sims price in parallel too; rows still print in
    // sweep order, so the report is byte-identical to the serial loop.
    let entries = tuner.resolve_many(&problems)?;
    let splitk_ns = pool::par_map(&problems, |p| -> anyhow::Result<f64> {
        Ok(sim.run(&kernels::schedule(&m, p, Strategy::SplitK)?)?.total_ns)
    });
    let mut speedups = Vec::new();
    for ((p, e), sk_ns) in problems.iter().zip(&entries).zip(splitk_ns) {
        let sk_ns = sk_ns?;
        let speedup = sk_ns / e.total_ns;
        speedups.push(speedup);
        println!(
            "{:<28} {:>12} {:>10.2} {:>10.2} {:>8.2}x",
            format!("m{}_n{}_k{}", p.m, p.n, p.k),
            e.strategy.name(),
            e.total_ns / 1e3,
            sk_ns / 1e3,
            speedup,
        );
    }
    // Seed the co-schedule pair decisions for every enumerated layer
    // graph (paper presets, MoE presets, artifact configs — the same
    // `layers` the shape tuning above came from), so `Router::layer_plan`
    // and `repro layer --overlap exact/auto` resolve the cross-node
    // overlap cache-only (DESIGN.md §12) — and the step-level residency
    // plans (DESIGN.md §13) for the same graphs, so the router's
    // residency column resolves cache-only too.
    for decode_layer in &layers {
        for pair in decode_layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer)?;
        }
        tuner.resolve_residency(decode_layer)?;
    }
    tuner.save()?;
    println!(
        "\ntuned {} shapes ({} searched, {} cache hits) -> {out}",
        problems.len(),
        tuner.searches,
        tuner.hits
    );
    println!(
        "co-schedule pairs: {} cached ({} simulated, {} hits)",
        tuner.cache.overlap_len(),
        tuner.overlap_searches,
        tuner.overlap_hits
    );
    println!(
        "residency plans: {} cached ({} planned, {} hits)",
        tuner.cache.residency_len(),
        tuner.residency_searches,
        tuner.residency_hits
    );
    println!(
        "geomean speedup over heuristic splitk: {:.2}x",
        stats::geomean(&speedups)
    );
    println!("serving picks these up automatically (tune_cache.json next to the artifacts).");
    Ok(())
}

fn cmd_bench_diff(args: &Args) -> anyhow::Result<()> {
    use ascend_w4a16::bench::diff;
    let baseline_path = args
        .get("baseline")
        .ok_or_else(|| anyhow::anyhow!("--baseline BENCH.json is required"))?;
    let current_path = args
        .get("current")
        .ok_or_else(|| anyhow::anyhow!("--current BENCH.json is required"))?;
    let threshold = args.get_f64("threshold", diff::DEFAULT_THRESHOLD)?;
    anyhow::ensure!(threshold > 0.0, "--threshold must be positive");

    let current_text = std::fs::read_to_string(current_path)
        .map_err(|e| anyhow::anyhow!("reading {current_path}: {e}"))?;
    // Parse before anything else: a truncated bench output must neither
    // gate nor (worse) be blessed over a good baseline.
    let current = ascend_w4a16::util::json::Json::parse(&current_text)
        .map_err(|e| anyhow::anyhow!("parsing {current_path}: {e}"))?;
    if args.flag("bless") {
        if let Some(parent) = std::path::Path::new(baseline_path).parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(baseline_path, &current_text)?;
        println!("blessed {current_path} -> {baseline_path}");
        return Ok(());
    }
    let baseline_text = std::fs::read_to_string(baseline_path)
        .map_err(|e| anyhow::anyhow!("reading {baseline_path}: {e}"))?;
    let baseline = ascend_w4a16::util::json::Json::parse(&baseline_text)
        .map_err(|e| anyhow::anyhow!("parsing {baseline_path}: {e}"))?;

    let report = diff::diff(&baseline, &current, threshold);
    print!("{}", report.render());
    if let Some(out) = args.get("out") {
        std::fs::write(out, report.to_json().to_string())?;
        println!("wrote {out}");
    }
    anyhow::ensure!(
        report.gate_passes(),
        "bench trajectory regressed vs {baseline_path} (see report above)"
    );
    Ok(())
}

fn cmd_fig2(args: &Args) -> anyhow::Result<()> {
    let m = machine();
    let cells = report::fig2_sweep(&m)?;
    print!("{}", report::render_fig2(&cells));
    if let Some(path) = args.get("json") {
        std::fs::write(path, report::fig2_json(&cells).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_fig3(args: &Args) -> anyhow::Result<()> {
    let m = machine();
    let cells = report::fig3_sweep(&m)?;
    print!("{}", report::render_fig3(&cells));
    if let Some(path) = args.get("json") {
        std::fs::write(path, report::fig3_json(&cells).to_string())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 2048)?;
    let k = args.get_usize("k", 7168)?;
    let batch = args.get_usize("batch", 8)?;
    let m = machine();
    let p = GemmProblem::new(batch, n, k);
    let sim = Simulator::new(m.clone());
    let sk = sim.run(&kernels::schedule(&m, &p, Strategy::SplitK)?)?;
    println!("{}", report::render_bottleneck(&m, &sk));
    let fp16 = sim.run(&kernels::schedule(&m, &p, Strategy::Fp16Native)?)?;
    let fused = sim.run(&kernels::schedule(&m, &p, Strategy::Fused)?)?;
    let chunked = sim.run(&kernels::schedule(&m, &p, Strategy::Chunked)?)?;
    println!("cross-strategy timing at M={batch}, N={n}, K={k}:");
    println!("  fp16 native : {}", stats::fmt_ns(fp16.total_ns));
    println!("  w4a16 splitk: {}  ({:.2}x vs fp16)", stats::fmt_ns(sk.total_ns), fp16.total_ns / sk.total_ns);
    println!("  w4a16 chunked: {}  ({:.2}x vs fp16)",
        stats::fmt_ns(chunked.total_ns), fp16.total_ns / chunked.total_ns);
    println!("  fused (hypothetical direct path): {}  ({:.2}x vs fp16)",
        stats::fmt_ns(fused.total_ns), fp16.total_ns / fused.total_ns);
    let b = traffic::decompose(&sk);
    println!(
        "\nthe workspace round trip moves {} vs {} of packed weights — removing it (fused) \
         recovers the latency headroom the paper attributes to the decoupled architecture.",
        stats::fmt_bytes(b.round_trip_bytes),
        stats::fmt_bytes(b.packed_bytes),
    );
    let sk_ws = sk.ledger.class(BufferClass::Workspace);
    let ck_ws = chunked.ledger.class(BufferClass::Workspace);
    println!(
        "workspace HBM traffic: splitk {} -> chunked {} (the chunk pipeline keeps the \
         rotating slice pinned in L2; see DESIGN.md §8)",
        stats::fmt_bytes(sk_ws.hbm_total()),
        stats::fmt_bytes(ck_ws.hbm_total()),
    );
    Ok(())
}

fn cmd_sensitivity(args: &Args) -> anyhow::Result<()> {
    let batch = args.get_usize("batch", 8)?;
    let base = machine();
    let scales = [0.25, 0.5, 1.0, 2.0, 4.0];
    let knobs: Vec<sensitivity::Knob> = match args.get("knob") {
        Some(name) => vec![sensitivity::Knob::from_name(name)?],
        None => sensitivity::Knob::all().to_vec(),
    };
    println!("baseline = simulated Ascend 910; scale 1.00x rows reproduce Figures 2/3\n");
    for knob in knobs {
        let points = sensitivity::sweep(&base, knob, &scales, batch)?;
        print!("{}\n", sensitivity::render(knob, &points));
    }
    println!("reading: the W4A16 cap tracks the L2:HBM bandwidth ratio and L2 \
              capacity — the quantitative form of the paper's co-design call.");
    Ok(())
}

fn cmd_trace(args: &Args) -> anyhow::Result<()> {
    let n = args.get_usize("n", 512)?;
    let k = args.get_usize("k", 16384)?;
    let batch = args.get_usize("batch", 8)?;
    let strategy = Strategy::from_name(args.get_or("strategy", "splitk"))?;
    let out = args
        .get("out")
        .ok_or_else(|| anyhow::anyhow!("--out FILE.json is required"))?;
    let m = machine();
    let p = GemmProblem::new(batch, n, k);
    let (strategy, tiling) = resolve_cli_strategy(args, &m, &p, strategy)?;
    let r = Simulator::new(m.clone()).run(&kernels::schedule_with(&m, &p, strategy, &tiling)?)?;
    std::fs::write(out, timeline::chrome_trace(&r).to_string())?;
    println!(
        "wrote {out} ({}; open in chrome://tracing or ui.perfetto.dev)",
        stats::fmt_ns(r.total_ns)
    );
    Ok(())
}

fn cmd_quickstart(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let mf = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let entry = mf.find("splitk_m16_n256_k512")?;
    let (m, n, k) = entry.gemm.unwrap();
    let mut rng = Rng::new(42);
    let a = MatF32::from_vec(m, k, rng.normal_vec(m * k, 0.5));
    let w = MatF32::from_vec(k, n, rng.normal_vec(k * n, 0.05));
    let qw = quant::quantize_groupwise(&w, mf.group, false)?;
    println!(
        "quantized {}x{} weights: {} packed (4x smaller than FP16)",
        k, n, stats::fmt_bytes(qw.packed_bytes() as f64)
    );
    let exe = rt.load(entry)?;
    let t0 = std::time::Instant::now();
    let out = exe.run(&[
        HostTensor::F32(a.data.clone()),
        HostTensor::I8(qw.packed.clone()),
        HostTensor::F32(qw.scales.clone()),
        HostTensor::F32(qw.zeros.clone()),
    ])?;
    let got = MatF32::from_vec(m, n, literal_to_host(&out[0])?.as_f32()?);
    let want = quant::w4a16_reference(&a, &qw);
    println!(
        "executed {} in {} — max |err| vs host reference: {:.2e}",
        entry.name,
        stats::fmt_ns(t0.elapsed().as_nanos() as f64),
        got.max_abs_diff(&want)
    );
    anyhow::ensure!(got.allclose(&want, 2e-2, 2e-2), "numerics mismatch");
    println!("quickstart OK");
    Ok(())
}

fn cmd_serve_load(args: &Args) -> anyhow::Result<()> {
    use ascend_w4a16::workload::ArrivalPlan;
    let dir = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "tiny").to_string();
    let n_requests = args.get_usize("requests", 64)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let mean_gap_us = args.get_f64("mean-gap-us", 2_000.0)?;
    let chunk = args.get_usize("chunk", DEFAULT_PREFILL_CHUNK)?;
    let queue_cap = args.get_usize("queue-cap", DEFAULT_QUEUE_CAP)?;
    let deadline_us = args.get_usize("deadline-us", 0)? as u64;
    let fault_rate = args.get_f64("fault-rate", 0.0)?;
    let fault_seed = args.get_usize("fault-seed", 0x5eed)? as u64;
    let kv_capacity_bytes = args.get_usize("kv-capacity-bytes", 0)? as u64;
    let page_bytes = args.get_usize("page-bytes", 0)? as u64;
    let preempt = args.get_choice("preempt", PreemptPolicy::CHOICES, PreemptPolicy::Off)?;
    let max_preemptions =
        args.get_usize("max-preemptions", DEFAULT_MAX_PREEMPTIONS as usize)? as u32;

    let mf = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    let mut router = Router::new(&rt, mf, &model)?;
    let precision = cli_precision(args)?;
    router.set_precision(precision);
    let sizes = router.batch_sizes();
    let batch = args.get_usize("batch", *sizes.last().unwrap())?;
    println!(
        "continuous serve on model '{model}': batch {batch}, chunk {chunk}, precision {}",
        precision.name()
    );
    let mut server = Server::new(router, Batcher::new(BatchPolicy::new(sizes)?));
    if fault_rate > 0.0 {
        println!("fault injection: rate {fault_rate:.3}, seed {fault_seed} (deterministic)");
        server.set_faults(Some(FaultPlan::new(fault_seed, fault_rate)));
    }

    let max_seq = server.router.engine(batch)?.max_seq();
    let plan = match args.get("trace") {
        Some(path) => {
            let plan = ArrivalPlan::load(std::path::Path::new(path))?;
            println!("replaying {} arrivals from {path}", plan.arrivals.len());
            plan
        }
        None => {
            println!(
                "poisson arrivals: {n_requests} requests, mean gap {mean_gap_us:.0} µs, \
                 seed {seed}"
            );
            ArrivalPlan::poisson(seed, mean_gap_us, n_requests, max_seq)
        }
    };
    if let Some(out) = args.get("trace-out") {
        plan.save(std::path::Path::new(out))?;
        println!("wrote arrival trace -> {out}");
    }

    let mut opts = ServeOptions::new(batch, chunk).with_queue_cap(queue_cap);
    if deadline_us > 0 {
        opts = opts.with_deadline_us(deadline_us);
    }
    if kv_capacity_bytes > 0 {
        opts = opts.with_kv_capacity_bytes(kv_capacity_bytes);
    }
    if page_bytes > 0 {
        opts = opts.with_page_bytes(page_bytes);
    }
    if preempt != PreemptPolicy::Off {
        println!("preemption: policy {}, max {max_preemptions} cycles/request", preempt.name());
        opts = opts.with_preempt(preempt).with_max_preemptions(max_preemptions);
    }

    let t0 = std::time::Instant::now();
    let report = server.serve_load(&plan, &opts)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut tally: std::collections::BTreeMap<&str, usize> = Default::default();
    for r in &report.results {
        *tally.entry(r.outcome.name()).or_insert(0) += 1;
    }
    let tally = tally
        .iter()
        .map(|(k, v)| format!("{v} {k}"))
        .collect::<Vec<_>>()
        .join(", ");
    println!(
        "served {} of {} offered requests in {wall:.2}s ({}) — {} virtual µs",
        report.results.len(),
        plan.arrivals.len(),
        if tally.is_empty() { "none".to_string() } else { tally },
        report.horizon_us
    );
    print!("{}", Report::render(&report));
    let snapshot = server.metrics.snapshot();
    println!(
        "goodput: {:.1} generated tokens/s (virtual)",
        snapshot.goodput_tokens_per_s(report.horizon_us)
    );
    print!("{}", snapshot.render(wall));
    anyhow::ensure!(
        snapshot.outcomes_accounted(),
        "metrics conservation violated: admitted != completed + shed + expired + failed"
    );
    anyhow::ensure!(
        snapshot.sheds_accounted(),
        "typed shed breakdown does not sum to requests_shed"
    );
    anyhow::ensure!(
        snapshot.preemptions_accounted(),
        "preemption conservation violated: preempted != resumed + lost"
    );
    anyhow::ensure!(report.kv_idle, "kv pager leaked pages after drain");
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.get_or("artifacts", "artifacts");
    let model = args.get_or("model", "tiny").to_string();
    let n_requests = args.get_usize("requests", 16)?;
    let seed = args.get_usize("seed", 7)? as u64;
    let fault_rate = args.get_f64("fault-rate", 0.0)?;
    let fault_seed = args.get_usize("fault-seed", 0x5eed)? as u64;
    let deadline_us = args.get_usize("deadline-us", 0)? as u64;
    let queue_cap = args.get_usize("queue-cap", DEFAULT_QUEUE_CAP)?;
    let max_wait_us = args.get_usize("max-wait-us", DEFAULT_MAX_WAIT_US as usize)? as u64;
    let mf = Manifest::load(dir)?;
    let rt = Runtime::cpu()?;
    let router = Router::new(&rt, mf, &model)?;
    let sizes = router.batch_sizes();
    println!("serving model '{model}' with batch sizes {sizes:?}");
    let policy = BatchPolicy::new(sizes)?
        .with_queue_cap(queue_cap)
        .with_max_wait_us(max_wait_us);
    let mut server = Server::new(router, Batcher::new(policy));
    if fault_rate > 0.0 {
        println!("fault injection: rate {fault_rate:.3}, seed {fault_seed} (deterministic)");
        server.set_faults(Some(FaultPlan::new(fault_seed, fault_rate)));
    }
    println!(
        "tune cache: {}",
        if server.router.has_tune_cache() {
            "found — decode groups serve their tuned schedules"
        } else {
            "absent/unreadable — groups route down the degradation ladder \
             (run `repro tune --artifacts DIR --out DIR/tune_cache.json` to warm)"
        }
    );

    // Peek model limits from the first engine.
    let (vocab, max_seq) = {
        let first = *server.router.batch_sizes().first().unwrap();
        let e = server.router.engine(first)?;
        (e.vocab(), e.max_seq())
    };
    let mut generator = RequestGenerator::new(seed, vocab, max_seq);
    let t0 = std::time::Instant::now();
    let mut shed = 0usize;
    for req in generator.burst(n_requests) {
        let req = if deadline_us > 0 { req.with_deadline_us(deadline_us) } else { req };
        if let Admission::Shed { .. } = server.submit(req) {
            shed += 1;
        }
    }
    let results = server.drain()?;
    let wall = t0.elapsed().as_secs_f64();
    let mut tally: std::collections::BTreeMap<&str, usize> = Default::default();
    for r in &results {
        *tally.entry(r.outcome.name()).or_insert(0) += 1;
    }
    let tally = tally
        .iter()
        .map(|(k, v)| format!("{v} {k}"))
        .collect::<Vec<_>>()
        .join(", ");
    println!(
        "served {} of {n_requests} offered requests in {wall:.2}s ({}; {shed} shed) — {} virtual µs",
        results.len(),
        if tally.is_empty() { "none".to_string() } else { tally },
        server.now_us()
    );
    let snapshot = server.metrics.snapshot();
    print!("{}", snapshot.render(wall));
    anyhow::ensure!(
        snapshot.outcomes_accounted(),
        "metrics conservation violated: admitted != completed + shed + expired + failed"
    );
    Ok(())
}
