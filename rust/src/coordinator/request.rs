//! Request / response types of the decode-serving coordinator.

use std::time::Instant;

/// A decode request: a prompt plus a generation budget.
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Enqueue timestamp (set by the server when admitted).
    pub arrived: Option<Instant>,
}

impl DecodeRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> DecodeRequest {
        DecodeRequest { id, prompt, max_new_tokens, arrived: None }
    }

    /// Steps this request needs: prompt ingestion + generation.
    pub fn total_steps(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    pub fn validate(&self, vocab: usize, max_seq: usize) -> anyhow::Result<()> {
        anyhow::ensure!(!self.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            self.total_steps() <= max_seq,
            "prompt {} + generation {} exceeds max_seq {max_seq}",
            self.prompt.len(),
            self.max_new_tokens
        );
        for &t in &self.prompt {
            anyhow::ensure!(
                t >= 0 && (t as usize) < vocab,
                "token {t} outside vocab {vocab}"
            );
        }
        Ok(())
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub id: u64,
    /// Generated tokens (prompt not included).
    pub tokens: Vec<i32>,
    /// Queue-to-first-token latency (seconds).
    pub ttft_s: f64,
    /// Queue-to-completion latency (seconds).
    pub total_s: f64,
    /// Decode steps this request's group executed while it was active.
    pub steps: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        let r = DecodeRequest::new(1, vec![1, 2, 3], 10);
        assert!(r.validate(512, 32).is_ok());
        assert!(r.validate(512, 12).is_err()); // 13 steps > 12
        assert!(r.validate(2, 32).is_err()); // token 3 outside vocab
        assert!(DecodeRequest::new(2, vec![], 4).validate(512, 32).is_err());
    }

    #[test]
    fn step_budget() {
        assert_eq!(DecodeRequest::new(1, vec![1, 2], 5).total_steps(), 7);
    }
}
