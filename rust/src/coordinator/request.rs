//! Request / response types of the decode-serving coordinator.

use std::time::Instant;

/// A decode request: a prompt plus a generation budget, with an optional
/// per-request deadline (SLO).
#[derive(Debug, Clone)]
pub struct DecodeRequest {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Enqueue timestamp (set by the server when admitted).
    pub arrived: Option<Instant>,
    /// Optional SLO: the request expires this many *virtual* microseconds
    /// after admission (DESIGN.md §14).  `None` = no deadline.
    pub deadline_us: Option<u64>,
    /// Virtual admission timestamp (set by the server when admitted).
    pub enqueued_at_us: Option<u64>,
}

impl DecodeRequest {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> DecodeRequest {
        DecodeRequest {
            id,
            prompt,
            max_new_tokens,
            arrived: None,
            deadline_us: None,
            enqueued_at_us: None,
        }
    }

    /// Attach a deadline (virtual µs after admission).
    pub fn with_deadline_us(mut self, deadline_us: u64) -> DecodeRequest {
        self.deadline_us = Some(deadline_us);
        self
    }

    /// Whether the deadline has passed at virtual time `now_us`.  A
    /// request with no deadline (or not yet admitted) never expires.
    pub fn expired(&self, now_us: u64) -> bool {
        match (self.deadline_us, self.enqueued_at_us) {
            (Some(d), Some(t0)) => now_us.saturating_sub(t0) > d,
            _ => false,
        }
    }

    /// Steps this request needs: prompt ingestion + generation.
    pub fn total_steps(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    pub fn validate(&self, vocab: usize, max_seq: usize) -> anyhow::Result<()> {
        anyhow::ensure!(!self.prompt.is_empty(), "empty prompt");
        anyhow::ensure!(
            self.total_steps() <= max_seq,
            "prompt {} + generation {} exceeds max_seq {max_seq}",
            self.prompt.len(),
            self.max_new_tokens
        );
        for &t in &self.prompt {
            anyhow::ensure!(
                t >= 0 && (t as usize) < vocab,
                "token {t} outside vocab {vocab}"
            );
        }
        Ok(())
    }
}

/// How a request left the server.  Every *admitted* request ends in
/// exactly one of these (shed requests never enter the queue and are
/// counted separately) — the metrics conservation law of DESIGN.md §14.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// The full generation budget was produced.
    Completed,
    /// The deadline passed before completion; `tokens` holds the partial
    /// generation produced before expiry.
    Expired,
    /// The request failed (invalid, or its group's step exhausted the
    /// retry policy); `error` names the cause.
    Failed,
}

impl Outcome {
    pub fn name(&self) -> &'static str {
        match self {
            Outcome::Completed => "completed",
            Outcome::Expired => "expired",
            Outcome::Failed => "failed",
        }
    }
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct DecodeResult {
    pub id: u64,
    /// Generated tokens (prompt not included; partial on expiry/failure).
    pub tokens: Vec<i32>,
    /// Queue-to-first-token latency (seconds).
    pub ttft_s: f64,
    /// Queue-to-completion latency (seconds).
    pub total_s: f64,
    /// Decode steps this request's group executed while it was active.
    pub steps: usize,
    /// How the request ended.
    pub outcome: Outcome,
    /// Failure detail (`None` unless `outcome == Failed`).
    pub error: Option<String>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validation() {
        let r = DecodeRequest::new(1, vec![1, 2, 3], 10);
        assert!(r.validate(512, 32).is_ok());
        assert!(r.validate(512, 12).is_err()); // 13 steps > 12
        assert!(r.validate(2, 32).is_err()); // token 3 outside vocab
        assert!(DecodeRequest::new(2, vec![], 4).validate(512, 32).is_err());
    }

    #[test]
    fn step_budget() {
        assert_eq!(DecodeRequest::new(1, vec![1, 2], 5).total_steps(), 7);
    }

    #[test]
    fn deadlines_expire_relative_to_admission() {
        let mut r = DecodeRequest::new(1, vec![1], 4).with_deadline_us(100);
        assert!(!r.expired(1_000), "unadmitted requests never expire");
        r.enqueued_at_us = Some(500);
        assert!(!r.expired(600), "deadline is inclusive");
        assert!(r.expired(601));
        let no_deadline = DecodeRequest::new(2, vec![1], 4);
        assert!(!no_deadline.expired(u64::MAX));
    }

    #[test]
    fn outcome_names_are_stable() {
        assert_eq!(Outcome::Completed.name(), "completed");
        assert_eq!(Outcome::Expired.name(), "expired");
        assert_eq!(Outcome::Failed.name(), "failed");
    }
}
