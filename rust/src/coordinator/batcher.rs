//! Dynamic batcher: groups queued decode requests into fixed-size decode
//! groups matching the available AOT artifact batch sizes.
//!
//! The AOT decode artifacts are compiled per batch size (1, 2, 4, 8, ...),
//! so the batcher picks the smallest available size that fits the waiting
//! requests (or the largest size if more are waiting), padding unused
//! slots.  Padding slots replay token 0 at position 0 and their outputs
//! are discarded — exactly the hardware padding the paper notes makes
//! small-batch time flat.

use std::collections::VecDeque;

use super::request::DecodeRequest;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Batch sizes with a compiled artifact, ascending (e.g. [1, 2, 4, 8]).
    pub available_sizes: Vec<usize>,
    /// Form a group as soon as this many requests wait (<= max size).
    pub target_fill: usize,
}

impl BatchPolicy {
    pub fn new(mut available_sizes: Vec<usize>) -> anyhow::Result<BatchPolicy> {
        anyhow::ensure!(!available_sizes.is_empty(), "no batch sizes available");
        available_sizes.sort_unstable();
        let target_fill = *available_sizes.last().unwrap();
        Ok(BatchPolicy { available_sizes, target_fill })
    }

    /// Smallest available batch size that holds `waiting` requests, or the
    /// largest size if the queue overflows it.
    pub fn pick_size(&self, waiting: usize) -> usize {
        for &s in &self.available_sizes {
            if waiting <= s {
                return s;
            }
        }
        *self.available_sizes.last().unwrap()
    }
}

/// A formed decode group: up to `batch` member requests plus padding.
#[derive(Debug)]
pub struct DecodeGroup {
    pub batch: usize,
    pub members: Vec<DecodeRequest>,
}

impl DecodeGroup {
    /// Number of real (non-padding) slots.
    pub fn occupancy(&self) -> usize {
        self.members.len()
    }

    /// Decode steps the group needs: the longest member's budget.
    pub fn steps(&self) -> usize {
        self.members.iter().map(|r| r.total_steps()).max().unwrap_or(0)
    }
}

/// FIFO queue + group formation.
#[derive(Debug)]
pub struct Batcher {
    pub policy: BatchPolicy,
    queue: VecDeque<DecodeRequest>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, req: DecodeRequest) {
        self.queue.push_back(req);
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// Form the next group if the queue is non-empty.  `drain=true` forms a
    /// group regardless of fill level (shutdown / idle flush); otherwise a
    /// group forms only when the target fill is reached.
    pub fn form_group(&mut self, drain: bool) -> Option<DecodeGroup> {
        if self.queue.is_empty() {
            return None;
        }
        if !drain && self.queue.len() < self.policy.target_fill {
            return None;
        }
        let batch = self.policy.pick_size(self.queue.len());
        let take = batch.min(self.queue.len());
        let members = self.queue.drain(..take).collect();
        Some(DecodeGroup { batch, members })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> DecodeRequest {
        DecodeRequest::new(id, vec![1, 2], 4)
    }

    fn batcher(sizes: Vec<usize>) -> Batcher {
        Batcher::new(BatchPolicy::new(sizes).unwrap())
    }

    #[test]
    fn picks_smallest_fitting_size() {
        let p = BatchPolicy::new(vec![8, 1, 2, 4]).unwrap();
        assert_eq!(p.pick_size(1), 1);
        assert_eq!(p.pick_size(3), 4);
        assert_eq!(p.pick_size(8), 8);
        assert_eq!(p.pick_size(20), 8);
    }

    #[test]
    fn waits_for_fill_unless_draining() {
        let mut b = batcher(vec![1, 4]);
        b.push(req(1));
        b.push(req(2));
        assert!(b.form_group(false).is_none(), "should wait for fill");
        let g = b.form_group(true).unwrap();
        assert_eq!(g.batch, 4); // smallest available size >= 2
        assert_eq!(g.occupancy(), 2);
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn full_queue_forms_immediately() {
        let mut b = batcher(vec![1, 2, 4]);
        for i in 0..5 {
            b.push(req(i));
        }
        let g = b.form_group(false).unwrap();
        assert_eq!(g.batch, 4);
        assert_eq!(g.occupancy(), 4);
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn group_steps_is_max_member_budget() {
        let mut b = batcher(vec![4]);
        b.push(DecodeRequest::new(1, vec![1], 2)); // 3 steps
        b.push(DecodeRequest::new(2, vec![1, 2, 3], 7)); // 10 steps
        let g = b.form_group(true).unwrap();
        assert_eq!(g.steps(), 10);
    }

    #[test]
    fn empty_queue_never_forms() {
        let mut b = batcher(vec![1]);
        assert!(b.form_group(true).is_none());
    }
}
