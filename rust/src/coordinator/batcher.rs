//! Dynamic batcher: groups queued decode requests into fixed-size decode
//! groups matching the available AOT artifact batch sizes.
//!
//! The AOT decode artifacts are compiled per batch size (1, 2, 4, 8, ...),
//! so the batcher picks the smallest available size that fits the waiting
//! requests (or the largest size if more are waiting), padding unused
//! slots.  Padding slots replay token 0 at position 0 and their outputs
//! are discarded — exactly the hardware padding the paper notes makes
//! small-batch time flat.
//!
//! Admission control (DESIGN.md §14): the queue is bounded — a push past
//! `queue_cap` returns a typed [`Admission::Shed`] with a retry-after
//! hint instead of growing without bound or erroring.  Group formation
//! carries a max-wait timer: once the oldest waiter has waited
//! `max_wait_us` (virtual µs), a group forms below `target_fill`, so a
//! lone request cannot starve.  Already-expired requests are dropped by
//! [`Batcher::expire`] before they can occupy (and pad) a group.

use std::collections::VecDeque;

use super::request::DecodeRequest;

/// Default max-wait before a sub-`target_fill` group forms (virtual µs).
pub const DEFAULT_MAX_WAIT_US: u64 = 50_000;
/// Default admission-queue bound.
pub const DEFAULT_QUEUE_CAP: usize = 1024;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Batch sizes with a compiled artifact, ascending (e.g. [1, 2, 4, 8]).
    pub available_sizes: Vec<usize>,
    /// Form a group as soon as this many requests wait (<= max size).
    pub target_fill: usize,
    /// Form a group below `target_fill` once the oldest waiter has waited
    /// this long (virtual µs) — a lone request must not starve.
    pub max_wait_us: u64,
    /// Admission-queue bound: pushes beyond this shed (typed, not error).
    pub queue_cap: usize,
}

impl BatchPolicy {
    pub fn new(mut available_sizes: Vec<usize>) -> anyhow::Result<BatchPolicy> {
        anyhow::ensure!(!available_sizes.is_empty(), "no batch sizes available");
        available_sizes.sort_unstable();
        let target_fill = *available_sizes.last().unwrap();
        Ok(BatchPolicy {
            available_sizes,
            target_fill,
            max_wait_us: DEFAULT_MAX_WAIT_US,
            queue_cap: DEFAULT_QUEUE_CAP,
        })
    }

    pub fn with_max_wait_us(mut self, max_wait_us: u64) -> BatchPolicy {
        self.max_wait_us = max_wait_us;
        self
    }

    pub fn with_queue_cap(mut self, queue_cap: usize) -> BatchPolicy {
        self.queue_cap = queue_cap.max(1);
        self
    }

    /// Smallest available batch size that holds `waiting` requests, or the
    /// largest size if the queue overflows it.
    pub fn pick_size(&self, waiting: usize) -> usize {
        for &s in &self.available_sizes {
            if waiting <= s {
                return s;
            }
        }
        *self.available_sizes.last().unwrap()
    }
}

/// Typed admission decision: the queue either took the request or shed
/// it with a backpressure hint.  Shedding is an expected overload
/// response, not an error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    Admitted,
    /// The queue is full; retry after roughly this many virtual µs —
    /// queue depth × observed mean step time (how long the backlog
    /// actually takes to drain), or one max-wait window before any step
    /// has completed.
    Shed { retry_after_us: u64 },
}

/// A formed decode group: up to `batch` member requests plus padding.
#[derive(Debug)]
pub struct DecodeGroup {
    pub batch: usize,
    pub members: Vec<DecodeRequest>,
}

impl DecodeGroup {
    /// Number of real (non-padding) slots.
    pub fn occupancy(&self) -> usize {
        self.members.len()
    }

    /// Decode steps the group needs: the longest member's budget.
    pub fn steps(&self) -> usize {
        self.members.iter().map(|r| r.total_steps()).max().unwrap_or(0)
    }
}

/// FIFO queue + group formation.
#[derive(Debug)]
pub struct Batcher {
    pub policy: BatchPolicy,
    queue: VecDeque<DecodeRequest>,
    /// Completed-step count feeding the shed hint's drain-rate estimate.
    steps_noted: u64,
    /// Summed step time (virtual µs) over `steps_noted`.
    step_us_sum: u64,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher { policy, queue: VecDeque::new(), steps_noted: 0, step_us_sum: 0 }
    }

    /// Note one completed decode step's virtual duration.  The running
    /// mean prices the shed hint: a full queue drains at roughly one
    /// request per mean step, so a shed client should retry after
    /// `queue_len * mean_step_us`, not a constant.
    pub fn note_step_time(&mut self, step_us: u64) {
        self.steps_noted += 1;
        self.step_us_sum = self.step_us_sum.saturating_add(step_us);
    }

    /// Mean completed-step time (virtual µs), if any step has been noted.
    pub fn mean_step_us(&self) -> Option<u64> {
        if self.steps_noted == 0 {
            None
        } else {
            Some((self.step_us_sum / self.steps_noted).max(1))
        }
    }

    /// Backpressure hint for a shed at the current backlog: queue depth
    /// times the observed mean step time (>= 1µs), falling back to one
    /// max-wait window before any step has completed.
    pub fn shed_retry_after_us(&self) -> u64 {
        match self.mean_step_us() {
            Some(mean) => (self.queue.len() as u64).saturating_mul(mean).max(1),
            None => self.policy.max_wait_us.max(1),
        }
    }

    /// Backpressure hint for a `kv_capacity` shed: the expected next page
    /// release.  The closest-to-done in-flight request frees its pages
    /// (and its worst-case reservation) in roughly its remaining tokens ×
    /// the observed mean token gap — in continuous serve every noted step
    /// is one decode tick emitting one token per active slot, so
    /// [`Batcher::mean_step_us`] *is* the observed mean token gap.  Falls
    /// back to the generic queue-depth hint when nothing is in flight or
    /// nothing has ticked yet (there is no release to predict).
    pub fn kv_retry_after_us(&self, min_remaining_tokens: Option<u64>) -> u64 {
        match (min_remaining_tokens, self.mean_step_us()) {
            (Some(remaining), Some(gap)) => remaining.max(1).saturating_mul(gap).max(1),
            _ => self.shed_retry_after_us(),
        }
    }

    /// Admit a request at virtual time `now_us`, or shed it if the queue
    /// is at capacity.  Stamps `enqueued_at_us` (unless the caller did).
    pub fn push(&mut self, mut req: DecodeRequest, now_us: u64) -> Admission {
        if self.queue.len() >= self.policy.queue_cap {
            return Admission::Shed { retry_after_us: self.shed_retry_after_us() };
        }
        if req.enqueued_at_us.is_none() {
            req.enqueued_at_us = Some(now_us);
        }
        self.queue.push_back(req);
        Admission::Admitted
    }

    pub fn waiting(&self) -> usize {
        self.queue.len()
    }

    /// How long the oldest waiter has been queued at `now_us` (virtual
    /// µs) — the serve loop's starvation signal: a head that has
    /// out-waited the batching window with every slot busy is what the
    /// preemption policy exists to unblock (DESIGN.md §18).
    pub fn head_wait_us(&self, now_us: u64) -> Option<u64> {
        self.queue
            .front()
            .map(|r| r.enqueued_at_us.map(|t0| now_us.saturating_sub(t0)).unwrap_or(0))
    }

    /// Pop the oldest waiting request — the continuous-batching slot
    /// refill path (group formation stays the burst-mode path).
    pub fn pop_next(&mut self) -> Option<DecodeRequest> {
        self.queue.pop_front()
    }

    /// Remove and return every queued request whose deadline has passed
    /// at `now_us` — dropped *before* group formation so an expired
    /// request never occupies (or pads) an engine slot.
    pub fn expire(&mut self, now_us: u64) -> Vec<DecodeRequest> {
        let mut expired = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for req in self.queue.drain(..) {
            if req.expired(now_us) {
                expired.push(req);
            } else {
                kept.push_back(req);
            }
        }
        self.queue = kept;
        expired
    }

    /// Form the next group if the queue is non-empty.  `drain=true` forms
    /// a group regardless of fill level (shutdown / idle flush); otherwise
    /// a group forms when the target fill is reached OR the oldest waiter
    /// has exceeded the policy's max wait at `now_us`.
    pub fn form_group(&mut self, drain: bool, now_us: u64) -> Option<DecodeGroup> {
        if self.queue.is_empty() {
            return None;
        }
        let oldest_wait_us = self
            .queue
            .front()
            .and_then(|r| r.enqueued_at_us)
            .map(|t0| now_us.saturating_sub(t0))
            .unwrap_or(0);
        let overdue = oldest_wait_us >= self.policy.max_wait_us;
        if !drain && !overdue && self.queue.len() < self.policy.target_fill {
            return None;
        }
        let batch = self.policy.pick_size(self.queue.len());
        let take = batch.min(self.queue.len());
        let members = self.queue.drain(..take).collect();
        Some(DecodeGroup { batch, members })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> DecodeRequest {
        DecodeRequest::new(id, vec![1, 2], 4)
    }

    fn batcher(sizes: Vec<usize>) -> Batcher {
        Batcher::new(BatchPolicy::new(sizes).unwrap())
    }

    #[test]
    fn picks_smallest_fitting_size() {
        let p = BatchPolicy::new(vec![8, 1, 2, 4]).unwrap();
        assert_eq!(p.pick_size(1), 1);
        assert_eq!(p.pick_size(3), 4);
        assert_eq!(p.pick_size(8), 8);
        assert_eq!(p.pick_size(20), 8);
    }

    #[test]
    fn waits_for_fill_unless_draining() {
        let mut b = batcher(vec![1, 4]);
        b.push(req(1), 0);
        b.push(req(2), 0);
        assert!(b.form_group(false, 0).is_none(), "should wait for fill");
        let g = b.form_group(true, 0).unwrap();
        assert_eq!(g.batch, 4); // smallest available size >= 2
        assert_eq!(g.occupancy(), 2);
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn full_queue_forms_immediately() {
        let mut b = batcher(vec![1, 2, 4]);
        for i in 0..5 {
            b.push(req(i), 0);
        }
        let g = b.form_group(false, 0).unwrap();
        assert_eq!(g.batch, 4);
        assert_eq!(g.occupancy(), 4);
        assert_eq!(b.waiting(), 1);
    }

    #[test]
    fn group_steps_is_max_member_budget() {
        let mut b = batcher(vec![4]);
        b.push(DecodeRequest::new(1, vec![1], 2), 0); // 3 steps
        b.push(DecodeRequest::new(2, vec![1, 2, 3], 7), 0); // 10 steps
        let g = b.form_group(true, 0).unwrap();
        assert_eq!(g.steps(), 10);
    }

    #[test]
    fn empty_queue_never_forms() {
        let mut b = batcher(vec![1]);
        assert!(b.form_group(true, 0).is_none());
    }

    #[test]
    fn lone_request_groups_at_batch_one_after_max_wait() {
        // The starvation bugfix: a single waiter below target_fill must
        // form once the max-wait timer fires, at the smallest batch size.
        let mut b = Batcher::new(
            BatchPolicy::new(vec![1, 4]).unwrap().with_max_wait_us(1_000),
        );
        b.push(req(1), 0);
        assert!(b.form_group(false, 0).is_none(), "fresh waiter holds");
        assert!(b.form_group(false, 999).is_none(), "still inside the window");
        let g = b.form_group(false, 1_000).expect("max wait must force a group");
        assert_eq!(g.batch, 1);
        assert_eq!(g.occupancy(), 1);
        assert_eq!(b.waiting(), 0);
    }

    #[test]
    fn bounded_queue_sheds_with_retry_hint() {
        let mut b = Batcher::new(
            BatchPolicy::new(vec![1, 2]).unwrap().with_queue_cap(2).with_max_wait_us(500),
        );
        assert_eq!(b.push(req(1), 0), Admission::Admitted);
        assert_eq!(b.push(req(2), 0), Admission::Admitted);
        match b.push(req(3), 0) {
            Admission::Shed { retry_after_us } => assert_eq!(retry_after_us, 500),
            Admission::Admitted => panic!("push past queue_cap must shed"),
        }
        assert_eq!(b.waiting(), 2, "shed requests never enter the queue");
    }

    #[test]
    fn shed_hint_scales_with_backlog_and_step_time() {
        // Regression: the hint used to be the constant `max_wait_us`, so
        // overloaded clients retried into a still-full queue.  It must now
        // track queue depth × recent mean step time.
        let mut b = Batcher::new(
            BatchPolicy::new(vec![1]).unwrap().with_queue_cap(3).with_max_wait_us(500),
        );
        b.note_step_time(200);
        b.note_step_time(400); // mean 300 µs
        for i in 0..3 {
            assert_eq!(b.push(req(i), 0), Admission::Admitted);
        }
        let hint_full = match b.push(req(10), 0) {
            Admission::Shed { retry_after_us } => retry_after_us,
            Admission::Admitted => panic!("must shed at cap"),
        };
        assert_eq!(hint_full, 3 * 300, "depth 3 x mean 300 µs");

        // A deeper backlog (larger cap, same mean) hints a longer wait.
        let mut deep = Batcher::new(
            BatchPolicy::new(vec![1]).unwrap().with_queue_cap(8).with_max_wait_us(500),
        );
        deep.note_step_time(300);
        for i in 0..8 {
            assert_eq!(deep.push(req(i), 0), Admission::Admitted);
        }
        match deep.push(req(20), 0) {
            Admission::Shed { retry_after_us } => {
                assert_eq!(retry_after_us, 8 * 300);
                assert!(retry_after_us > hint_full, "hint grows with backlog");
            }
            Admission::Admitted => panic!("must shed at cap"),
        }
    }

    #[test]
    fn kv_shed_hint_is_the_expected_next_page_release() {
        // Regression: the kv_capacity shed used to reuse the generic
        // queue-depth hint, which says when the QUEUE drains — useless to
        // a client shed for PAGES.  The hint must be when the closest-to-
        // done in-flight request releases its reservation: min remaining
        // tokens × observed mean token gap.
        let mut b = Batcher::new(
            BatchPolicy::new(vec![1]).unwrap().with_queue_cap(4).with_max_wait_us(500),
        );
        b.note_step_time(100);
        b.note_step_time(300); // mean token gap 200 µs
        for i in 0..4 {
            assert_eq!(b.push(req(i), 0), Admission::Admitted);
        }
        assert_eq!(b.kv_retry_after_us(Some(7)), 7 * 200, "7 tokens to the next release");
        let generic = b.shed_retry_after_us();
        assert_eq!(generic, 4 * 200, "queue-depth hint measures the wrong thing");
        assert_ne!(b.kv_retry_after_us(Some(7)), generic);
        // Nothing in flight (or nothing ticked): fall back to the generic hint.
        assert_eq!(b.kv_retry_after_us(None), generic);
        let idle = Batcher::new(
            BatchPolicy::new(vec![1]).unwrap().with_queue_cap(4).with_max_wait_us(500),
        );
        assert_eq!(idle.kv_retry_after_us(Some(3)), 500, "pre-first-tick fallback");
        // A zero-remaining edge still hints at least one gap.
        assert_eq!(b.kv_retry_after_us(Some(0)), 200);
    }

    #[test]
    fn expire_drops_only_overdue_requests_in_order() {
        let mut b = batcher(vec![4]);
        b.push(req(1).with_deadline_us(100), 0);
        b.push(req(2), 0); // no deadline
        b.push(req(3).with_deadline_us(10_000), 0);
        let dropped = b.expire(101);
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].id, 1);
        assert_eq!(b.waiting(), 2);
        let g = b.form_group(true, 101).unwrap();
        assert_eq!(g.members[0].id, 2, "FIFO order preserved across expiry");
    }

    #[test]
    fn pop_next_is_fifo() {
        let mut b = batcher(vec![4]);
        b.push(req(1), 0);
        b.push(req(2), 0);
        assert_eq!(b.pop_next().map(|r| r.id), Some(1));
        assert_eq!(b.pop_next().map(|r| r.id), Some(2));
        assert!(b.pop_next().is_none());
    }

    #[test]
    fn push_preserves_caller_stamped_admission_time() {
        let mut b = batcher(vec![1]);
        let mut r = req(1);
        r.enqueued_at_us = Some(42);
        b.push(r, 100);
        let g = b.form_group(true, 100).unwrap();
        assert_eq!(g.members[0].enqueued_at_us, Some(42));
    }
}
