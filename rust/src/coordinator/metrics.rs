//! Serving metrics: counters and latency summaries.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::stats::Summary;

/// Shared metrics sink (cheap Mutex; the hot path touches it once per
/// request completion, not per step).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    /// Requests offered to the server (admitted + shed): the left side of
    /// the conservation law `admitted = completed + shed + expired +
    /// failed` (DESIGN.md §14).
    requests_admitted: u64,
    requests_completed: u64,
    /// Requests shed at admission (bounded queue overflow).
    requests_shed: u64,
    /// Requests whose deadline passed before completion.
    requests_expired: u64,
    /// Requests that failed (invalid, or retry-exhausted step).
    requests_failed: u64,
    tokens_generated: u64,
    steps_executed: u64,
    groups_formed: u64,
    padded_slots: u64,
    /// Groups served per degradation-ladder rung ("full", "tuned_only",
    /// "retuned", "default_splitk") — the per-rung fallback counters.
    route_rungs: BTreeMap<String, u64>,
    /// Why routing left the top rung (keyed by `RouteReason::name`).
    route_reasons: BTreeMap<String, u64>,
    /// Injected faults observed, per kind ("straggler", "engine_fault",
    /// "client_error").
    faults: BTreeMap<String, u64>,
    /// Step retries executed under the retry policy.
    retries: u64,
    /// Summed virtual-clock penalty charged by injected stragglers (µs).
    /// Every injected straggler charges >= 1µs (the truncation-bug
    /// regression in `tests/chaos.rs` asserts this stays positive).
    straggler_penalty_us: u64,
    ttft_s: Vec<f64>,
    total_s: Vec<f64>,
    /// Groups served per kernel-schedule strategy ("untuned" when no tune
    /// cache backed the group's batch size).
    schedules: BTreeMap<String, u64>,
    /// Per-(projection GEMM kind, strategy) serving tallies: every routed
    /// decode batch records all four layer nodes (qkv, attn_out, up_gate,
    /// down), so per-GEMM tuning coverage and predicted kernel latency are
    /// visible at a glance.
    gemm_schedules: BTreeMap<String, BTreeMap<String, GemmScheduleStat>>,
    /// Per-batch-size predicted cross-node gains of the served plans: the
    /// co-scheduled overlap (`LayerPlan::overlap_gain_ns`) and the
    /// step-level weight-residency gain, both resolved cache-only by the
    /// router — the predicted-overlap column of the serving report.
    plan_gains: BTreeMap<usize, PlanGainStat>,
    /// Continuous-serve TTFT samples on the *virtual* clock (µs from
    /// arrival to the first generated token) — wall-clock `ttft_s` stays
    /// for the group-mode path (DESIGN.md §15).
    serve_ttft_us: Vec<f64>,
    /// Continuous-serve per-generated-token gap samples (virtual µs).
    serve_token_gap_us: Vec<f64>,
    /// Prefill chunk ticks executed by the continuous serve loop.
    prefill_steps: u64,
    /// Prompt tokens ingested by prefill ticks.
    prefill_tokens: u64,
    /// Decode ticks executed by the continuous serve loop.
    decode_steps: u64,
    /// Decode ticks that paid the residency re-pin cost because a prefill
    /// burst invalidated the decode-steady pin set.
    repins: u64,
    /// Summed re-pin cost paid (ns).
    repin_ns_sum: f64,
    /// Shed breakdown by cause ("queue_full", "kv_capacity",
    /// "admission_fault") — sums to `requests_shed` on the serve path.
    shed_reasons: BTreeMap<String, u64>,
    /// Last `retry_after_us` hint issued per shed cause (the kv_capacity
    /// hint is the expected next page release, DESIGN.md §18).
    shed_hints_us: BTreeMap<String, u64>,
    /// KV-pager high-water mark (pages) observed by the serve loop.
    pager_peak_pages: u64,
    /// KV-pager capacity (pages) the serve loop ran against.
    pager_capacity_pages: u64,
    /// Preemption events (a victim's pages were freed mid-flight).
    requests_preempted: u64,
    /// Preemptions whose priced recovery path was recompute / swap.
    preempt_recompute: u64,
    preempt_swap: u64,
    /// Preempted requests successfully reseated from the resume queue.
    requests_resumed: u64,
    /// Preempted requests lost at resume (preempt/swap fault chains);
    /// every one is also a `requests_failed` terminal.
    requests_preempt_failed: u64,
    /// Bytes moved across the host link by swap recovery (out + in).
    swap_bytes: u64,
    /// Virtual-clock µs charged for swap traffic (out + in).
    swap_us_sum: u64,
    /// Prefill ticks spent re-ingesting preempted prefixes (recompute
    /// recovery); a subset of `prefill_steps`.
    recompute_ticks: u64,
    /// Virtual-clock µs those recompute ticks charged.
    recompute_us_sum: u64,
}

/// Predicted-gain tally of one decode-group batch size.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PlanGainStat {
    /// Groups served at this batch size.
    pub groups: u64,
    /// Groups whose layer plan carried a resolved overlap prediction.
    pub overlap_resolved: u64,
    /// Summed predicted overlap gain (ns) over resolved groups.
    pub overlap_gain_ns_sum: f64,
    /// Groups whose plan carried a resolved residency prediction.
    pub residency_resolved: u64,
    /// Summed predicted residency gain (ns) over resolved groups.
    pub residency_gain_ns_sum: f64,
}

impl PlanGainStat {
    /// Mean predicted overlap gain per resolved group, in µs.
    pub fn mean_overlap_us(&self) -> f64 {
        if self.overlap_resolved == 0 {
            0.0
        } else {
            self.overlap_gain_ns_sum / self.overlap_resolved as f64 / 1e3
        }
    }

    /// Mean predicted residency gain per resolved group, in µs.
    pub fn mean_residency_us(&self) -> f64 {
        if self.residency_resolved == 0 {
            0.0
        } else {
            self.residency_gain_ns_sum / self.residency_resolved as f64 / 1e3
        }
    }
}

/// Serving tally of one (GEMM kind, strategy) pair.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct GemmScheduleStat {
    /// Decode groups served under this strategy.
    pub groups: u64,
    /// GEMM instances issued: equals `groups` for dense nodes; MoE expert
    /// nodes contribute their active-expert fan-out per group.
    pub gemms: u64,
    /// Summed predicted kernel time of the tuned schedule (ns; untuned
    /// nodes contribute 0 — no prediction exists for them).
    pub predicted_ns_sum: f64,
}

impl GemmScheduleStat {
    /// Mean predicted kernel time per group, in µs.
    pub fn mean_predicted_us(&self) -> f64 {
        if self.groups == 0 {
            0.0
        } else {
            self.predicted_ns_sum / self.groups as f64 / 1e3
        }
    }
}

/// A point-in-time snapshot.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests_admitted: u64,
    pub requests_completed: u64,
    pub requests_shed: u64,
    pub requests_expired: u64,
    pub requests_failed: u64,
    pub tokens_generated: u64,
    pub steps_executed: u64,
    pub groups_formed: u64,
    pub padded_slots: u64,
    pub ttft: Summary,
    pub total: Summary,
    pub schedules: BTreeMap<String, u64>,
    pub gemm_schedules: BTreeMap<String, BTreeMap<String, GemmScheduleStat>>,
    pub plan_gains: BTreeMap<usize, PlanGainStat>,
    pub route_rungs: BTreeMap<String, u64>,
    pub route_reasons: BTreeMap<String, u64>,
    pub faults: BTreeMap<String, u64>,
    pub retries: u64,
    /// Summed virtual-clock penalty charged by injected stragglers (µs).
    pub straggler_penalty_us: u64,
    /// Virtual-clock TTFT summary (µs) from the continuous serve loop.
    pub serve_ttft_us: Summary,
    /// Virtual-clock per-token gap summary (µs), continuous serve loop.
    pub serve_token_gap_us: Summary,
    pub prefill_steps: u64,
    pub prefill_tokens: u64,
    pub decode_steps: u64,
    pub repins: u64,
    pub repin_ns_sum: f64,
    pub shed_reasons: BTreeMap<String, u64>,
    /// Last `retry_after_us` hint issued per shed cause.
    pub shed_hints_us: BTreeMap<String, u64>,
    pub pager_peak_pages: u64,
    pub pager_capacity_pages: u64,
    pub requests_preempted: u64,
    pub preempt_recompute: u64,
    pub preempt_swap: u64,
    pub requests_resumed: u64,
    pub requests_preempt_failed: u64,
    pub swap_bytes: u64,
    pub swap_us_sum: u64,
    pub recompute_ticks: u64,
    pub recompute_us_sum: u64,
}

impl MetricsSnapshot {
    /// The conservation law: every offered request is accounted for in
    /// exactly one terminal counter.
    pub fn outcomes_accounted(&self) -> bool {
        self.requests_admitted
            == self.requests_completed
                + self.requests_shed
                + self.requests_expired
                + self.requests_failed
    }

    /// The serve-path shed breakdown must itself account for every shed
    /// request (trivially true when the breakdown was never used, i.e.
    /// the group-mode path recorded untyped sheds).
    pub fn sheds_accounted(&self) -> bool {
        let typed: u64 = self.shed_reasons.values().sum();
        typed == 0 || typed == self.requests_shed
    }

    /// The preemption extension of the conservation law (DESIGN.md §18):
    /// after drain every preempted request either reseated from the
    /// resume queue or terminated on a recovery fault — preemptions only
    /// move in-flight work, they never lose it.  The mode split must also
    /// cover every event.
    pub fn preemptions_accounted(&self) -> bool {
        self.requests_preempted == self.requests_resumed + self.requests_preempt_failed
            && self.requests_preempted == self.preempt_recompute + self.preempt_swap
    }

    /// Completed-output tokens per second of virtual time — the goodput
    /// axis of the serve-load curves.  `horizon_us` is the virtual clock
    /// at drain.
    pub fn goodput_tokens_per_s(&self, horizon_us: u64) -> f64 {
        if horizon_us == 0 {
            0.0
        } else {
            self.tokens_generated as f64 / (horizon_us as f64 / 1e6)
        }
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_group(&self, batch: usize, occupancy: usize, steps: usize) {
        let mut g = self.inner.lock().unwrap();
        g.groups_formed += 1;
        g.padded_slots += (batch - occupancy) as u64;
        g.steps_executed += steps as u64;
    }

    /// Record which kernel-schedule strategy served a decode group.
    pub fn record_schedule(&self, strategy: &str) {
        let mut g = self.inner.lock().unwrap();
        *g.schedules.entry(strategy.to_string()).or_insert(0) += 1;
    }

    /// Record the strategy serving one projection GEMM of a routed group,
    /// with the tuned schedule's predicted kernel time when available.
    pub fn record_gemm_schedule(&self, kind: &str, strategy: &str, predicted_ns: Option<f64>) {
        self.record_gemm_schedule_n(kind, strategy, predicted_ns, 1);
    }

    /// Like [`Metrics::record_gemm_schedule`], for a node that issues
    /// `count` identical GEMMs per group (MoE expert fan-outs).
    /// `predicted_ns` is the node total (already count-multiplied).
    pub fn record_gemm_schedule_n(
        &self,
        kind: &str,
        strategy: &str,
        predicted_ns: Option<f64>,
        count: u64,
    ) {
        let mut g = self.inner.lock().unwrap();
        let stat = g
            .gemm_schedules
            .entry(kind.to_string())
            .or_default()
            .entry(strategy.to_string())
            .or_default();
        stat.groups += 1;
        stat.gemms += count.max(1);
        stat.predicted_ns_sum += predicted_ns.unwrap_or(0.0);
    }

    /// Record the predicted cross-node gains of the layer plan serving
    /// one routed decode group (`None` = the prediction did not resolve
    /// from the tune cache — the group still serves, unpredicted).
    pub fn record_group_plan(
        &self,
        batch: usize,
        overlap_gain_ns: Option<f64>,
        residency_gain_ns: Option<f64>,
    ) {
        let mut g = self.inner.lock().unwrap();
        let stat = g.plan_gains.entry(batch).or_default();
        stat.groups += 1;
        if let Some(ns) = overlap_gain_ns {
            stat.overlap_resolved += 1;
            stat.overlap_gain_ns_sum += ns;
        }
        if let Some(ns) = residency_gain_ns {
            stat.residency_resolved += 1;
            stat.residency_gain_ns_sum += ns;
        }
    }

    pub fn record_completion(&self, tokens: usize, ttft_s: f64, total_s: f64) {
        let mut g = self.inner.lock().unwrap();
        g.requests_completed += 1;
        g.tokens_generated += tokens as u64;
        g.ttft_s.push(ttft_s);
        g.total_s.push(total_s);
    }

    /// Record one request offered to the server (before the admission
    /// decision; shed requests are counted here too).
    pub fn record_admitted(&self) {
        self.inner.lock().unwrap().requests_admitted += 1;
    }

    /// Record requests shed at admission (bounded-queue overflow).
    pub fn record_shed(&self, n: u64) {
        self.inner.lock().unwrap().requests_shed += n;
    }

    /// Record requests whose deadline passed before completion.
    pub fn record_expired(&self, n: u64) {
        self.inner.lock().unwrap().requests_expired += n;
    }

    /// Record requests that failed (invalid, or retry-exhausted step).
    pub fn record_failed(&self, n: u64) {
        self.inner.lock().unwrap().requests_failed += n;
    }

    /// Record which degradation-ladder rung served a routed group, and
    /// why routing landed there.
    pub fn record_route(&self, rung: &str, reason: &str) {
        let mut g = self.inner.lock().unwrap();
        *g.route_rungs.entry(rung.to_string()).or_insert(0) += 1;
        *g.route_reasons.entry(reason.to_string()).or_insert(0) += 1;
    }

    /// Record one injected (or observed) fault by kind.
    pub fn record_fault(&self, kind: &str) {
        let mut g = self.inner.lock().unwrap();
        *g.faults.entry(kind.to_string()).or_insert(0) += 1;
    }

    /// Record one step retry executed under the retry policy.
    pub fn record_retry(&self) {
        self.inner.lock().unwrap().retries += 1;
    }

    /// Record the virtual-clock penalty one injected straggler charged.
    pub fn record_straggler_penalty_us(&self, us: u64) {
        self.inner.lock().unwrap().straggler_penalty_us += us;
    }

    /// Record a shed request with its cause ("queue_full", "kv_capacity",
    /// "admission_fault") — the serve-path counterpart of
    /// [`Metrics::record_shed`]; increments the conservation counter too.
    pub fn record_shed_reason(&self, reason: &str) {
        let mut g = self.inner.lock().unwrap();
        g.requests_shed += 1;
        *g.shed_reasons.entry(reason.to_string()).or_insert(0) += 1;
    }

    /// Like [`Metrics::record_shed_reason`], keeping the `retry_after_us`
    /// hint the server would hand the client (last-writer-wins per cause;
    /// the hint is advisory telemetry, not a conservation counter).
    pub fn record_shed_reason_with_hint(&self, reason: &str, retry_after_us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.requests_shed += 1;
        *g.shed_reasons.entry(reason.to_string()).or_insert(0) += 1;
        g.shed_hints_us.insert(reason.to_string(), retry_after_us);
    }

    /// Record one preemption event and which recovery path priced cheaper.
    pub fn record_preempted(&self, swap: bool) {
        let mut g = self.inner.lock().unwrap();
        g.requests_preempted += 1;
        if swap {
            g.preempt_swap += 1;
        } else {
            g.preempt_recompute += 1;
        }
    }

    /// Record host-link swap traffic: `bytes` moved, `us` charged on the
    /// virtual clock (one call per direction).
    pub fn record_swap(&self, bytes: u64, us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.swap_bytes += bytes;
        g.swap_us_sum += us;
    }

    /// Record a preempted request reseated from the resume queue.
    pub fn record_resumed(&self) {
        self.inner.lock().unwrap().requests_resumed += 1;
    }

    /// Record a preempted request lost at resume (recovery fault).  The
    /// caller records the `requests_failed` terminal separately.
    pub fn record_preempt_failed(&self) {
        self.inner.lock().unwrap().requests_preempt_failed += 1;
    }

    /// Record one prefill tick spent re-ingesting a preempted prefix.
    pub fn record_recompute_tick(&self, us: u64) {
        let mut g = self.inner.lock().unwrap();
        g.recompute_ticks += 1;
        g.recompute_us_sum += us;
    }

    /// Record one continuous-serve TTFT sample (virtual µs from arrival
    /// to the first generated token).
    pub fn record_serve_ttft_us(&self, ttft_us: u64) {
        self.inner.lock().unwrap().serve_ttft_us.push(ttft_us as f64);
    }

    /// Record `n` per-token gaps of `gap_us` virtual µs each (one decode
    /// tick emits one token per active slot, all at the same gap).
    pub fn record_serve_token_gaps_us(&self, gap_us: u64, n: usize) {
        let mut g = self.inner.lock().unwrap();
        g.serve_token_gap_us.extend(std::iter::repeat(gap_us as f64).take(n));
    }

    /// Record one prefill chunk tick that ingested `tokens` prompt tokens.
    pub fn record_prefill_step(&self, tokens: usize) {
        let mut g = self.inner.lock().unwrap();
        g.prefill_steps += 1;
        g.prefill_tokens += tokens as u64;
        g.steps_executed += 1;
    }

    /// Record one continuous-mode decode tick.
    pub fn record_decode_step(&self) {
        let mut g = self.inner.lock().unwrap();
        g.decode_steps += 1;
        g.steps_executed += 1;
    }

    /// Record a paid residency re-pin (a decode tick following a prefill
    /// burst re-established the pin set at `repin_ns` cost).
    pub fn record_repin(&self, repin_ns: f64) {
        let mut g = self.inner.lock().unwrap();
        g.repins += 1;
        g.repin_ns_sum += repin_ns;
    }

    /// Publish the KV-pager high-water mark and capacity (pages).
    pub fn set_pager_stats(&self, peak_pages: u64, capacity_pages: u64) {
        let mut g = self.inner.lock().unwrap();
        g.pager_peak_pages = g.pager_peak_pages.max(peak_pages);
        g.pager_capacity_pages = capacity_pages;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let g = self.inner.lock().unwrap();
        MetricsSnapshot {
            requests_admitted: g.requests_admitted,
            requests_completed: g.requests_completed,
            requests_shed: g.requests_shed,
            requests_expired: g.requests_expired,
            requests_failed: g.requests_failed,
            tokens_generated: g.tokens_generated,
            steps_executed: g.steps_executed,
            groups_formed: g.groups_formed,
            padded_slots: g.padded_slots,
            ttft: Summary::of(&g.ttft_s),
            total: Summary::of(&g.total_s),
            schedules: g.schedules.clone(),
            gemm_schedules: g.gemm_schedules.clone(),
            plan_gains: g.plan_gains.clone(),
            route_rungs: g.route_rungs.clone(),
            route_reasons: g.route_reasons.clone(),
            faults: g.faults.clone(),
            retries: g.retries,
            straggler_penalty_us: g.straggler_penalty_us,
            serve_ttft_us: Summary::of(&g.serve_ttft_us),
            serve_token_gap_us: Summary::of(&g.serve_token_gap_us),
            prefill_steps: g.prefill_steps,
            prefill_tokens: g.prefill_tokens,
            decode_steps: g.decode_steps,
            repins: g.repins,
            repin_ns_sum: g.repin_ns_sum,
            shed_reasons: g.shed_reasons.clone(),
            shed_hints_us: g.shed_hints_us.clone(),
            pager_peak_pages: g.pager_peak_pages,
            pager_capacity_pages: g.pager_capacity_pages,
            requests_preempted: g.requests_preempted,
            preempt_recompute: g.preempt_recompute,
            preempt_swap: g.preempt_swap,
            requests_resumed: g.requests_resumed,
            requests_preempt_failed: g.requests_preempt_failed,
            swap_bytes: g.swap_bytes,
            swap_us_sum: g.swap_us_sum,
            recompute_ticks: g.recompute_ticks,
            recompute_us_sum: g.recompute_us_sum,
        }
    }
}

impl MetricsSnapshot {
    /// Render a human-readable metrics block.
    pub fn render(&self, wall_s: f64) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "requests: {}  tokens: {}  groups: {}  padded slots: {}  steps: {}\n",
            self.requests_completed,
            self.tokens_generated,
            self.groups_formed,
            self.padded_slots,
            self.steps_executed,
        ));
        if self.requests_admitted > 0 {
            out.push_str(&format!(
                "outcomes: admitted {} = completed {} + shed {} + expired {} + failed {}{}\n",
                self.requests_admitted,
                self.requests_completed,
                self.requests_shed,
                self.requests_expired,
                self.requests_failed,
                if self.outcomes_accounted() { "" } else { "  [IMBALANCED]" },
            ));
        }
        if wall_s > 0.0 {
            out.push_str(&format!(
                "throughput: {:.1} tokens/s, {:.2} requests/s\n",
                self.tokens_generated as f64 / wall_s,
                self.requests_completed as f64 / wall_s,
            ));
        }
        out.push_str(&format!(
            "ttft    p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms\n",
            self.ttft.p50 * 1e3,
            self.ttft.p90 * 1e3,
            self.ttft.p99 * 1e3,
        ));
        out.push_str(&format!(
            "latency p50 {:.1} ms  p90 {:.1} ms  p99 {:.1} ms\n",
            self.total.p50 * 1e3,
            self.total.p90 * 1e3,
            self.total.p99 * 1e3,
        ));
        if self.serve_ttft_us.n > 0 {
            out.push_str(&format!(
                "serve ttft   p50 {:.0} us  p99 {:.0} us   token gap p50 {:.0} us  p99 {:.0} us\n",
                self.serve_ttft_us.p50,
                self.serve_ttft_us.p99,
                self.serve_token_gap_us.p50,
                self.serve_token_gap_us.p99,
            ));
        }
        if self.prefill_steps > 0 || self.decode_steps > 0 {
            out.push_str(&format!(
                "serve ticks: {} prefill ({} tokens), {} decode, {} re-pins (~{:.1} us total)\n",
                self.prefill_steps,
                self.prefill_tokens,
                self.decode_steps,
                self.repins,
                self.repin_ns_sum / 1e3,
            ));
        }
        if self.requests_preempted > 0 {
            out.push_str(&format!(
                "preemption: {} preempted ({} recompute, {} swap) = {} resumed + {} lost{}  \
                 swap {} bytes (~{} us)  recompute {} ticks (~{} us)\n",
                self.requests_preempted,
                self.preempt_recompute,
                self.preempt_swap,
                self.requests_resumed,
                self.requests_preempt_failed,
                if self.preemptions_accounted() { "" } else { "  [IMBALANCED]" },
                self.swap_bytes,
                self.swap_us_sum,
                self.recompute_ticks,
                self.recompute_us_sum,
            ));
        }
        if !self.shed_reasons.is_empty() {
            let parts: Vec<String> =
                self.shed_reasons.iter().map(|(r, n)| format!("{r}={n}")).collect();
            let hints: Vec<String> =
                self.shed_hints_us.iter().map(|(r, us)| format!("{r}~{us}us")).collect();
            out.push_str(&format!(
                "shed: {}{}{}\n",
                parts.join("  "),
                if hints.is_empty() {
                    String::new()
                } else {
                    format!("  (retry hints: {})", hints.join("  "))
                },
                if self.sheds_accounted() { "" } else { "  [IMBALANCED]" },
            ));
        }
        if self.pager_capacity_pages > 0 {
            out.push_str(&format!(
                "kv pager: peak {} / {} pages\n",
                self.pager_peak_pages, self.pager_capacity_pages,
            ));
        }
        if !self.schedules.is_empty() {
            let parts: Vec<String> = self
                .schedules
                .iter()
                .map(|(s, n)| format!("{s}={n}"))
                .collect();
            out.push_str(&format!("schedules: {}\n", parts.join("  ")));
        }
        for (kind, stats) in &self.gemm_schedules {
            let parts: Vec<String> = stats
                .iter()
                .map(|(s, st)| {
                    let mut part = format!("{s}={}", st.groups);
                    if st.gemms > st.groups {
                        part.push_str(&format!(" [{} gemms]", st.gemms));
                    }
                    if st.predicted_ns_sum > 0.0 {
                        part.push_str(&format!(" (~{:.1} us)", st.mean_predicted_us()));
                    }
                    part
                })
                .collect();
            out.push_str(&format!("gemm {:<10}: {}\n", kind, parts.join("  ")));
        }
        // Predicted cross-node gains per group (cache-only layer plans):
        // the co-scheduled overlap and the step-level weight residency.
        for (batch, st) in &self.plan_gains {
            out.push_str(&format!(
                "plan b{batch:<4}: {} groups, predicted overlap ~{:.1} us/group ({} resolved), \
                 residency ~{:.1} us/group ({} resolved)\n",
                st.groups,
                st.mean_overlap_us(),
                st.overlap_resolved,
                st.mean_residency_us(),
                st.residency_resolved,
            ));
        }
        if !self.route_rungs.is_empty() {
            let rungs: Vec<String> =
                self.route_rungs.iter().map(|(r, n)| format!("{r}={n}")).collect();
            let reasons: Vec<String> =
                self.route_reasons.iter().map(|(r, n)| format!("{r}={n}")).collect();
            out.push_str(&format!(
                "routing: {}  (reasons: {})\n",
                rungs.join("  "),
                reasons.join("  "),
            ));
        }
        if !self.faults.is_empty() || self.retries > 0 {
            let parts: Vec<String> =
                self.faults.iter().map(|(k, n)| format!("{k}={n}")).collect();
            out.push_str(&format!(
                "faults: {}  retries: {}{}\n",
                if parts.is_empty() { "none".to_string() } else { parts.join("  ") },
                self.retries,
                if self.straggler_penalty_us > 0 {
                    format!("  straggler penalty: {} us", self.straggler_penalty_us)
                } else {
                    String::new()
                },
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_counters_accumulate() {
        let m = Metrics::new();
        m.record_schedule("chunked");
        m.record_schedule("chunked");
        m.record_schedule("untuned");
        let s = m.snapshot();
        assert_eq!(s.schedules.get("chunked"), Some(&2));
        assert_eq!(s.schedules.get("untuned"), Some(&1));
        assert!(s.render(1.0).contains("chunked=2"));
    }

    #[test]
    fn gemm_schedule_counters_track_kind_strategy_and_latency() {
        let m = Metrics::new();
        for kind in ["qkv", "attn_out", "up_gate", "down"] {
            m.record_gemm_schedule(kind, "chunked", Some(12_000.0));
        }
        m.record_gemm_schedule("down", "chunked", Some(18_000.0));
        m.record_gemm_schedule("down", "untuned", None);
        let s = m.snapshot();
        assert_eq!(s.gemm_schedules.len(), 4);
        let down = &s.gemm_schedules["down"]["chunked"];
        assert_eq!(down.groups, 2);
        assert_eq!(down.gemms, 2, "dense nodes issue one GEMM per group");
        assert!((down.mean_predicted_us() - 15.0).abs() < 1e-9);
        assert_eq!(s.gemm_schedules["down"]["untuned"].groups, 1);
        let text = s.render(1.0);
        for kind in ["qkv", "attn_out", "up_gate", "down"] {
            assert!(text.contains(&format!("gemm {kind:<10}")), "missing {kind} in:\n{text}");
        }
        assert!(text.contains("(~15.0 us)"), "latency missing in:\n{text}");
    }

    #[test]
    fn moe_expert_fanout_counts_gemm_instances() {
        let m = Metrics::new();
        // Two expert nodes (up + down) of 64 active experts each, twice.
        for _ in 0..2 {
            m.record_gemm_schedule_n("moe_expert", "chunked", Some(640_000.0), 64);
            m.record_gemm_schedule_n("moe_expert", "splitk", Some(320_000.0), 64);
        }
        let s = m.snapshot();
        let chunked = &s.gemm_schedules["moe_expert"]["chunked"];
        assert_eq!(chunked.groups, 2);
        assert_eq!(chunked.gemms, 128, "per-kind expert counts");
        let text = s.render(1.0);
        assert!(text.contains("moe_expert"), "render missing moe_expert:\n{text}");
        assert!(text.contains("[128 gemms]"), "render missing expert count:\n{text}");
    }

    #[test]
    fn plan_gain_column_tracks_overlap_and_residency_per_group() {
        let m = Metrics::new();
        m.record_group_plan(8, Some(12_000.0), Some(4_000.0));
        m.record_group_plan(8, Some(8_000.0), None);
        m.record_group_plan(16, None, None);
        let s = m.snapshot();
        let b8 = &s.plan_gains[&8];
        assert_eq!(b8.groups, 2);
        assert_eq!(b8.overlap_resolved, 2);
        assert!((b8.mean_overlap_us() - 10.0).abs() < 1e-9);
        assert_eq!(b8.residency_resolved, 1);
        assert!((b8.mean_residency_us() - 4.0).abs() < 1e-9);
        let b16 = &s.plan_gains[&16];
        assert_eq!((b16.groups, b16.overlap_resolved, b16.residency_resolved), (1, 0, 0));
        let text = s.render(1.0);
        assert!(text.contains("plan b8"), "render missing plan column:\n{text}");
        assert!(text.contains("residency"), "render missing residency column:\n{text}");
    }

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_group(4, 3, 10);
        m.record_group(2, 2, 5);
        m.record_completion(8, 0.010, 0.050);
        m.record_completion(4, 0.020, 0.030);
        let s = m.snapshot();
        assert_eq!(s.groups_formed, 2);
        assert_eq!(s.padded_slots, 1);
        assert_eq!(s.steps_executed, 15);
        assert_eq!(s.requests_completed, 2);
        assert_eq!(s.tokens_generated, 12);
        assert!((s.ttft.mean - 0.015).abs() < 1e-12);
    }

    #[test]
    fn outcome_conservation_holds_and_imbalance_is_flagged() {
        let m = Metrics::new();
        for _ in 0..5 {
            m.record_admitted();
        }
        m.record_completion(4, 0.0, 0.0);
        m.record_completion(4, 0.0, 0.0);
        m.record_shed(1);
        m.record_expired(1);
        m.record_failed(1);
        let s = m.snapshot();
        assert!(s.outcomes_accounted(), "2+1+1+1 = 5");
        assert!(s.render(1.0).contains("admitted 5 = completed 2 + shed 1"));
        m.record_admitted();
        let s2 = m.snapshot();
        assert!(!s2.outcomes_accounted());
        assert!(s2.render(1.0).contains("[IMBALANCED]"));
    }

    #[test]
    fn route_rung_and_fault_counters_render() {
        let m = Metrics::new();
        m.record_route("full", "warm_cache");
        m.record_route("retuned", "shape_miss");
        m.record_route("retuned", "shape_miss");
        m.record_fault("straggler");
        m.record_fault("engine_fault");
        m.record_retry();
        m.record_straggler_penalty_us(3);
        m.record_straggler_penalty_us(1);
        let s = m.snapshot();
        assert_eq!(s.route_rungs.get("retuned"), Some(&2));
        assert_eq!(s.route_reasons.get("shape_miss"), Some(&2));
        assert_eq!(s.faults.get("straggler"), Some(&1));
        assert_eq!(s.retries, 1);
        assert_eq!(s.straggler_penalty_us, 4);
        let text = s.render(1.0);
        assert!(text.contains("routing: full=1  retuned=2"), "{text}");
        assert!(text.contains("reasons:"), "{text}");
        assert!(text.contains("faults: engine_fault=1  straggler=1  retries: 1"), "{text}");
        assert!(text.contains("straggler penalty: 4 us"), "{text}");
    }

    #[test]
    fn serve_mode_counters_and_goodput() {
        let m = Metrics::new();
        m.record_admitted();
        m.record_admitted();
        m.record_shed_reason("queue_full");
        m.record_shed_reason("kv_capacity");
        m.record_serve_ttft_us(1_500);
        m.record_serve_token_gaps_us(400, 3);
        m.record_prefill_step(128);
        m.record_decode_step();
        m.record_decode_step();
        m.record_repin(25_000.0);
        m.set_pager_stats(7, 64);
        m.set_pager_stats(5, 64); // peak is a high-water mark
        m.record_completion(10, 0.0, 0.0);
        let s = m.snapshot();
        assert_eq!(s.requests_shed, 2);
        assert_eq!(s.shed_reasons.get("queue_full"), Some(&1));
        assert!(s.sheds_accounted());
        assert_eq!(s.serve_ttft_us.n, 1);
        assert_eq!(s.serve_token_gap_us.n, 3);
        assert!((s.serve_token_gap_us.p50 - 400.0).abs() < 1e-9);
        assert_eq!((s.prefill_steps, s.prefill_tokens, s.decode_steps), (1, 128, 2));
        assert_eq!(s.steps_executed, 3, "serve ticks feed the shared step counter");
        assert_eq!((s.repins, s.pager_peak_pages, s.pager_capacity_pages), (1, 7, 64));
        assert!((s.goodput_tokens_per_s(2_000_000) - 5.0).abs() < 1e-9);
        assert_eq!(s.goodput_tokens_per_s(0), 0.0);
        let text = s.render(1.0);
        assert!(text.contains("serve ttft"), "{text}");
        assert!(text.contains("re-pins"), "{text}");
        assert!(text.contains("kv pager: peak 7 / 64 pages"), "{text}");
        assert!(text.contains("shed: kv_capacity=1  queue_full=1"), "{text}");
    }

    #[test]
    fn preemption_counters_conserve_and_render() {
        let m = Metrics::new();
        m.record_preempted(false);
        m.record_preempted(true);
        m.record_preempted(true);
        m.record_swap(4096, 64);
        m.record_swap(4096, 64);
        m.record_recompute_tick(120);
        m.record_recompute_tick(80);
        m.record_resumed();
        m.record_resumed();
        let s = m.snapshot();
        assert!(!s.preemptions_accounted(), "one victim still parked");
        assert!(s.render(1.0).contains("[IMBALANCED]"));
        m.record_preempt_failed();
        let s2 = m.snapshot();
        assert!(s2.preemptions_accounted(), "3 preempted = 2 resumed + 1 lost");
        assert_eq!((s2.preempt_recompute, s2.preempt_swap), (1, 2));
        assert_eq!((s2.swap_bytes, s2.swap_us_sum), (8192, 128));
        assert_eq!((s2.recompute_ticks, s2.recompute_us_sum), (2, 200));
        let text = s2.render(1.0);
        assert!(text.contains("preemption: 3 preempted (1 recompute, 2 swap)"), "{text}");
        assert!(text.contains("2 resumed + 1 lost"), "{text}");
        assert!(text.contains("swap 8192 bytes"), "{text}");
    }

    #[test]
    fn zero_preemptions_are_vacuously_accounted_and_unrendered() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert!(s.preemptions_accounted());
        assert!(!s.render(1.0).contains("preemption:"));
    }

    #[test]
    fn shed_hints_record_last_value_and_render() {
        let m = Metrics::new();
        m.record_shed_reason_with_hint("kv_capacity", 900);
        m.record_shed_reason_with_hint("kv_capacity", 350);
        m.record_shed_reason("queue_full");
        let s = m.snapshot();
        assert_eq!(s.requests_shed, 3);
        assert_eq!(s.shed_reasons.get("kv_capacity"), Some(&2));
        assert_eq!(s.shed_hints_us.get("kv_capacity"), Some(&350));
        assert!(s.sheds_accounted());
        let text = s.render(1.0);
        assert!(text.contains("kv_capacity~350us"), "{text}");
    }

    #[test]
    fn untyped_sheds_keep_the_breakdown_trivially_accounted() {
        let m = Metrics::new();
        m.record_admitted();
        m.record_shed(1);
        let s = m.snapshot();
        assert!(s.sheds_accounted(), "empty breakdown is vacuously consistent");
        assert!(s.outcomes_accounted());
    }

    #[test]
    fn render_contains_throughput() {
        let m = Metrics::new();
        m.record_completion(10, 0.01, 0.02);
        let text = m.snapshot().render(2.0);
        assert!(text.contains("5.0 tokens/s"));
    }
}
