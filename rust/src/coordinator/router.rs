//! Router: maps a decode group to the engine compiled for its batch size,
//! and to the tuned kernel schedule for its dominant GEMM shape.
//!
//! Engines are constructed lazily (compiling an HLO module and staging
//! ~100M parameters of weight literals is expensive) and cached for the
//! server's lifetime — the per-shape executable pool of the serving stack.
//!
//! Schedule tuning: if a tune cache (`tune_cache.json`, written by
//! `repro tune`) sits next to the artifact manifest, the router resolves
//! each decode batch size's bottleneck GEMM — the FFN down-projection
//! `(M=batch, N=hidden, K=ffn)`, the paper's K >> N decode shape —
//! through it, so every group is served under its tuned strategy.  The
//! lookup is cache-only: the serving hot path never pays a search.

use std::collections::HashMap;

use crate::ascend::MachineConfig;
use crate::kernels::{GemmProblem, Strategy};
use crate::model::DecodeEngine;
use crate::runtime::{Manifest, Runtime};
use crate::tune::{Tuner, DEFAULT_CACHE_FILE};

/// The tuned plan for one decode batch size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedPlan {
    pub strategy: Strategy,
    /// Simulated kernel time of the tuned schedule (ns).
    pub predicted_ns: f64,
}

/// Engine pool keyed by batch size for one decode model.
pub struct Router<'rt> {
    rt: &'rt Runtime,
    manifest: Manifest,
    model: String,
    engines: HashMap<usize, DecodeEngine>,
    /// Schedule tuner backed by the cache next to the artifacts (None when
    /// no cache file exists — groups then serve under the default splitk).
    tuner: Option<Tuner>,
    plans: HashMap<usize, Option<TunedPlan>>,
}

impl<'rt> Router<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: Manifest, model: &str) -> anyhow::Result<Router<'rt>> {
        anyhow::ensure!(
            !manifest.decode_batches(model).is_empty(),
            "no decode artifacts for model '{model}'"
        );
        let cache_path = manifest.dir.join(DEFAULT_CACHE_FILE);
        let tuner = if cache_path.exists() {
            Some(Tuner::load(MachineConfig::ascend910(), &cache_path)?)
        } else {
            None
        };
        Ok(Router {
            rt,
            manifest,
            model: model.to_string(),
            engines: HashMap::new(),
            tuner,
            plans: HashMap::new(),
        })
    }

    /// Batch sizes this model was compiled for (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.manifest.decode_batches(&self.model)
    }

    /// Get (or build) the engine for a batch size.
    pub fn engine(&mut self, batch: usize) -> anyhow::Result<&mut DecodeEngine> {
        if !self.engines.contains_key(&batch) {
            let entry = self.manifest.decode(&self.model, batch)?;
            let engine = DecodeEngine::new(self.rt, entry)?;
            self.engines.insert(batch, engine);
        }
        Ok(self.engines.get_mut(&batch).unwrap())
    }

    /// The tuned schedule for a batch size's bottleneck decode GEMM, from
    /// the persisted cache (`None` when untuned: no cache, cache miss, or
    /// the artifact has no decode config).  Memoized per batch size.
    pub fn tuned_plan(&mut self, batch: usize) -> Option<TunedPlan> {
        if let Some(plan) = self.plans.get(&batch) {
            return *plan;
        }
        let plan = self.resolve_plan(batch);
        self.plans.insert(batch, plan);
        plan
    }

    fn resolve_plan(&mut self, batch: usize) -> Option<TunedPlan> {
        let cfg = self
            .manifest
            .decode(&self.model, batch)
            .ok()
            .and_then(|e| e.config)?;
        let tuner = self.tuner.as_mut()?;
        // The FFN down-projection is the decode GEMM the paper profiles:
        // K = ffn >> N = hidden once the batch is small.
        let mut p = GemmProblem::new(batch, cfg.hidden, cfg.ffn);
        p.group = cfg.group;
        let e = tuner.lookup(&p)?;
        Some(TunedPlan { strategy: e.strategy, predicted_ns: e.total_ns })
    }

    /// Whether a tune cache was found next to the artifacts.
    pub fn has_tune_cache(&self) -> bool {
        self.tuner.is_some()
    }

    /// Number of engines built so far.
    pub fn engines_built(&self) -> usize {
        self.engines.len()
    }

    pub fn model(&self) -> &str {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    // Router construction needs real artifacts + a PJRT client; exercised
    // by rust/tests/coordinator.rs (including the tuned-plan path).
}
