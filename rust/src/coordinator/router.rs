//! Router: maps a decode group to the engine compiled for its batch size,
//! and to the tuned kernel schedule for its dominant GEMM shape.
//!
//! Engines are constructed lazily (compiling an HLO module and staging
//! ~100M parameters of weight literals is expensive) and cached for the
//! server's lifetime — the per-shape executable pool of the serving stack.
//!
//! Schedule tuning: if a tune cache (`tune_cache.json`, written by
//! `repro tune`) sits next to the artifact manifest, the router resolves
//! every GEMM node of the decode layer — QKV, attention-out, the dense
//! up/gate + down pair (the paper's K >> N bottleneck), or the routed
//! MoE expert fan-out — through it, so each group is served under its
//! per-node tuned strategies.  The lookup is cache-only: the serving hot
//! path never pays a search.

use std::collections::HashMap;

use crate::ascend::MachineConfig;
use crate::kernels::Strategy;
use crate::model::DecodeEngine;
use crate::runtime::{Manifest, Runtime};
use crate::tune::{Tuner, DEFAULT_CACHE_FILE};
use crate::workload::decode_layer::{DecodeLayer, GemmKind};

/// The tuned plan for one GEMM node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedPlan {
    pub strategy: Strategy,
    /// Simulated kernel time of ONE tuned GEMM (ns).
    pub predicted_ns: f64,
}

/// One resolved node of a decode layer's GEMM graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanNode {
    pub kind: GemmKind,
    /// Identical GEMMs the node issues per decode step (the active-expert
    /// fan-out on MoE layers, 1 for dense projections).
    pub count: usize,
    /// `None` on a cache miss — that node serves untuned.
    pub plan: Option<TunedPlan>,
}

/// Tuned plans for every GEMM node of one decode layer — the four dense
/// projections, or the attention pair plus the MoE expert fan-out.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub nodes: Vec<PlanNode>,
    /// Total exact co-schedule gain of the layer's adjacent
    /// (reduce -> dequant) pairs — expert batches contribute `count - 1`
    /// internal pairs — resolved *cache-only* through the tune cache's
    /// pair decisions (DESIGN.md §12).  `None` when any pair is missing
    /// from the cache (the plan still serves; it just carries no overlap
    /// prediction).
    pub overlap_gain_ns: Option<f64>,
    /// What the step-level weight-residency plan buys per step (DESIGN.md
    /// §13), resolved cache-only from the tune cache's `residency` map.
    /// `None` when the layer's plan is missing from the cache.
    pub residency_gain_ns: Option<f64>,
    /// Weight bytes that plan holds L2-resident (0 when nothing pins).
    pub residency_pinned_bytes: Option<u64>,
}

impl LayerPlan {
    /// First node of a kind (MoE layers carry two `MoeExpert` nodes).
    pub fn get(&self, kind: GemmKind) -> Option<TunedPlan> {
        self.nodes.iter().find(|n| n.kind == kind).and_then(|n| n.plan)
    }

    /// Strategy label for the metrics sink ("untuned" on a cache miss).
    pub fn strategy_label(&self, kind: GemmKind) -> &'static str {
        self.get(kind).map(|p| p.strategy.name()).unwrap_or("untuned")
    }

    /// Whether every node resolved through the cache.
    pub fn fully_resolved(&self) -> bool {
        self.nodes.iter().all(|n| n.plan.is_some())
    }

    /// Predicted GEMM time of the whole layer (only when fully resolved);
    /// expert nodes contribute their full fan-out.
    pub fn predicted_layer_ns(&self) -> Option<f64> {
        self.nodes
            .iter()
            .map(|n| n.plan.map(|p| p.predicted_ns * n.count as f64))
            .sum::<Option<f64>>()
    }

    /// Predicted layer GEMM time with the co-scheduled overlap applied
    /// (only when both the node plans and every pair decision resolved).
    pub fn predicted_overlapped_ns(&self) -> Option<f64> {
        Some((self.predicted_layer_ns()? - self.overlap_gain_ns?).max(0.0))
    }

    /// Predicted layer GEMM time with the overlap AND the step-level
    /// weight-residency gains applied (the two compose: the residency
    /// gain is a delta between two chains that both price the overlap).
    pub fn predicted_resident_ns(&self) -> Option<f64> {
        Some((self.predicted_overlapped_ns()? - self.residency_gain_ns?).max(0.0))
    }

    /// The group's headline plan: the paper's bottleneck down-projection,
    /// or the expert down-projection (the last expert node) on MoE layers.
    pub fn headline(&self) -> Option<TunedPlan> {
        self.get(GemmKind::Down).or_else(|| {
            self.nodes
                .iter()
                .rev()
                .find(|n| n.kind == GemmKind::MoeExpert)
                .and_then(|n| n.plan)
        })
    }
}

/// Engine pool keyed by batch size for one decode model.
pub struct Router<'rt> {
    rt: &'rt Runtime,
    manifest: Manifest,
    model: String,
    engines: HashMap<usize, DecodeEngine>,
    /// Schedule tuner backed by the cache next to the artifacts (None when
    /// no cache file exists — groups then serve under the default splitk).
    tuner: Option<Tuner>,
    plans: HashMap<usize, Option<LayerPlan>>,
}

impl<'rt> Router<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: Manifest, model: &str) -> anyhow::Result<Router<'rt>> {
        anyhow::ensure!(
            !manifest.decode_batches(model).is_empty(),
            "no decode artifacts for model '{model}'"
        );
        let cache_path = manifest.dir.join(DEFAULT_CACHE_FILE);
        let tuner = if cache_path.exists() {
            Some(Tuner::load(MachineConfig::ascend910(), &cache_path)?)
        } else {
            None
        };
        Ok(Router {
            rt,
            manifest,
            model: model.to_string(),
            engines: HashMap::new(),
            tuner,
            plans: HashMap::new(),
        })
    }

    /// Batch sizes this model was compiled for (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.manifest.decode_batches(&self.model)
    }

    /// Get (or build) the engine for a batch size.
    pub fn engine(&mut self, batch: usize) -> anyhow::Result<&mut DecodeEngine> {
        if !self.engines.contains_key(&batch) {
            let entry = self.manifest.decode(&self.model, batch)?;
            let engine = DecodeEngine::new(self.rt, entry)?;
            self.engines.insert(batch, engine);
        }
        Ok(self.engines.get_mut(&batch).unwrap())
    }

    /// Plans for every GEMM node of a batch size's decode layer (dense
    /// projections plus the MoE expert fan-out when the config routes
    /// experts).  `None` only when the artifact has no decode config —
    /// without a tune cache the nodes are still enumerated (so metrics
    /// stay kind-accurate) but every per-node plan is `None` (untuned).
    /// Memoized per batch size.
    pub fn layer_plan(&mut self, batch: usize) -> Option<LayerPlan> {
        if let Some(plan) = self.plans.get(&batch) {
            return plan.clone();
        }
        let plan = self.resolve_layer_plan(batch);
        self.plans.insert(batch, plan.clone());
        plan
    }

    /// The tuned schedule for the batch's bottleneck GEMM — the FFN
    /// down-projection the paper profiles (K = ffn >> N = hidden), or
    /// the expert down-projection on MoE models.
    pub fn tuned_plan(&mut self, batch: usize) -> Option<TunedPlan> {
        self.layer_plan(batch).and_then(|plan| plan.headline())
    }

    fn resolve_layer_plan(&mut self, batch: usize) -> Option<LayerPlan> {
        let cfg = self
            .manifest
            .decode(&self.model, batch)
            .ok()
            .and_then(|e| e.config)?;
        let layer = DecodeLayer::from_decode_config(&cfg, batch);
        let gemm_nodes = layer.gemm_nodes();
        let mut tuner = self.tuner.as_mut();
        let nodes = gemm_nodes
            .iter()
            .map(|node| {
                // Cache-only: the serving hot path never pays a search.
                // With no cache file the node list still describes the
                // layer; every plan is just untuned.
                let plan = match tuner.as_deref_mut() {
                    Some(t) if node.problem.validate().is_ok() => t
                        .lookup(&node.problem)
                        .map(|e| TunedPlan { strategy: e.strategy, predicted_ns: e.total_ns }),
                    _ => None,
                };
                PlanNode { kind: node.kind, count: node.count, plan }
            })
            .collect();
        // Co-schedule decisions for the layer's adjacent pairs, also
        // cache-only (`repro tune` seeds the same `overlap_pairs` set,
        // so a warmed cache always hits here).
        let overlap_gain_ns = tuner.as_deref_mut().and_then(|t| {
            let mut total = 0.0;
            for pair in layer.overlap_pairs() {
                total += pair.pairs as f64 * t.lookup_overlap(&pair.producer, &pair.consumer)?;
            }
            Some(total)
        });
        // The step-level weight-residency plan, cache-only as well
        // (`repro tune` seeds every enumerated layer graph's plan).
        let residency = tuner.and_then(|t| t.lookup_residency(&layer));
        Some(LayerPlan {
            nodes,
            overlap_gain_ns,
            residency_gain_ns: residency.map(|r| r.gain_ns),
            residency_pinned_bytes: residency.map(|r| r.pinned_bytes),
        })
    }

    /// Whether a tune cache was found next to the artifacts.
    pub fn has_tune_cache(&self) -> bool {
        self.tuner.is_some()
    }

    /// Number of engines built so far.
    pub fn engines_built(&self) -> usize {
        self.engines.len()
    }

    pub fn model(&self) -> &str {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    // Router construction needs real artifacts + a PJRT client; exercised
    // by rust/tests/coordinator.rs (including the tuned-plan path).
}
