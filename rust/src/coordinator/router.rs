//! Router: maps a decode group to the engine compiled for its batch size,
//! and to the tuned kernel schedule for its dominant GEMM shape.
//!
//! Engines are constructed lazily (compiling an HLO module and staging
//! ~100M parameters of weight literals is expensive) and cached for the
//! server's lifetime — the per-shape executable pool of the serving stack.
//! Weightless decode artifacts (a config but no weight blob, as the test
//! manifests ship) get a [`SimEngine`] instead, so the serving loop runs
//! end to end without PJRT.
//!
//! Routing never fails a request (DESIGN.md §14).  On a tune-cache miss,
//! a stale machine tag, or an unreadable cache file, the router walks an
//! explicit degradation ladder:
//!
//! 1. **full** — tuned winners + co-schedule overlap + residency gains,
//!    all cache-only (the fast path; never pays a search).
//! 2. **tuned_only** — tuned winners, but some cross-node gain (pair or
//!    residency decision) is missing; the plan serves unpredicted gains.
//! 3. **retuned** — some shape missed the cache; it is re-tuned inline
//!    (`Strategy::Auto` search) under a per-router budget.
//! 4. **default_splitk** — budget exhausted (or search failed): the safe
//!    default splitk schedule, priced by the simulator.
//!
//! Each rung is priced by the same simulator, and each rung is
//! never-slower than the rung below it *by construction*: the gains of
//! rung 1 subtract via `max(0, ·)` (so `resident <= overlapped <=
//! layer`), and a tuned/retuned winner is the argmin of a search space
//! that contains splitk, so `tuned_ns <= splitk_ns` on every shape.

use std::collections::HashMap;

use crate::analysis::stepop::StepOp;
use crate::ascend::{MachineConfig, Simulator};
use crate::kernels::{self, GemmProblem, Strategy};
use crate::model::{DecodeEngine, Engine, Precision, SimEngine};
use crate::runtime::{Manifest, Runtime};
use crate::tune::{machine_tag, Tuner, DEFAULT_CACHE_FILE};
use crate::workload::decode_layer::{DecodeLayer, GemmKind};

/// Inline re-tunes a router may pay over its lifetime (rung 3).  Each
/// search prices one shape; the budget bounds worst-case serve latency
/// when the cache is cold or stale.
pub const DEFAULT_RETUNE_BUDGET: usize = 32;

/// Default token-bucket refill interval for the re-tune budget (virtual
/// µs per credit) when refill is enabled (DESIGN.md §15): one search per
/// quarter virtual second keeps inline re-tunes off the hot path while
/// letting a long-running server recover from a cold or stale cache.
pub const DEFAULT_RETUNE_REFILL_INTERVAL_US: u64 = 250_000;

/// The tuned plan for one GEMM node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedPlan {
    pub strategy: Strategy,
    /// Simulated kernel time of ONE tuned GEMM (ns).
    pub predicted_ns: f64,
}

/// One resolved node of a decode layer's GEMM graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanNode {
    pub kind: GemmKind,
    /// Identical GEMMs the node issues per decode step (the active-expert
    /// fan-out on MoE layers, 1 for dense projections).
    pub count: usize,
    /// `None` only for structurally unpriceable nodes (invalid problem);
    /// cache misses resolve down the ladder instead.
    pub plan: Option<TunedPlan>,
}

/// Tuned plans for every GEMM node of one decode layer — the four dense
/// projections, or the attention pair plus the MoE expert fan-out.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerPlan {
    pub nodes: Vec<PlanNode>,
    /// Total exact co-schedule gain of the layer's adjacent
    /// (reduce -> dequant) pairs — expert batches contribute `count - 1`
    /// internal pairs — resolved *cache-only* through the tune cache's
    /// pair decisions (DESIGN.md §12).  `None` when any pair is missing
    /// from the cache (the plan still serves; it just carries no overlap
    /// prediction).
    pub overlap_gain_ns: Option<f64>,
    /// What the step-level weight-residency plan buys per step (DESIGN.md
    /// §13), resolved cache-only from the tune cache's `residency` map.
    /// `None` when the layer's plan is missing from the cache.
    pub residency_gain_ns: Option<f64>,
    /// Weight bytes that plan holds L2-resident (0 when nothing pins).
    pub residency_pinned_bytes: Option<u64>,
}

impl LayerPlan {
    /// First node of a kind (MoE layers carry two `MoeExpert` nodes).
    pub fn get(&self, kind: GemmKind) -> Option<TunedPlan> {
        self.nodes.iter().find(|n| n.kind == kind).and_then(|n| n.plan)
    }

    /// Strategy label for the metrics sink ("untuned" on a cache miss).
    pub fn strategy_label(&self, kind: GemmKind) -> &'static str {
        self.get(kind).map(|p| p.strategy.name()).unwrap_or("untuned")
    }

    /// Whether every node resolved through the cache.
    pub fn fully_resolved(&self) -> bool {
        self.nodes.iter().all(|n| n.plan.is_some())
    }

    /// Predicted GEMM time of the whole layer (only when fully resolved);
    /// expert nodes contribute their full fan-out.
    pub fn predicted_layer_ns(&self) -> Option<f64> {
        self.nodes
            .iter()
            .map(|n| n.plan.map(|p| p.predicted_ns * n.count as f64))
            .sum::<Option<f64>>()
    }

    /// Predicted layer GEMM time with the co-scheduled overlap applied
    /// (only when both the node plans and every pair decision resolved).
    pub fn predicted_overlapped_ns(&self) -> Option<f64> {
        Some((self.predicted_layer_ns()? - self.overlap_gain_ns?).max(0.0))
    }

    /// Predicted layer GEMM time with the overlap AND the step-level
    /// weight-residency gains applied (the two compose: the residency
    /// gain is a delta between two chains that both price the overlap).
    pub fn predicted_resident_ns(&self) -> Option<f64> {
        Some((self.predicted_overlapped_ns()? - self.residency_gain_ns?).max(0.0))
    }

    /// The best available step-time prediction: resident if both gains
    /// resolved, else overlapped, else the bare layer sum.
    pub fn predicted_served_ns(&self) -> Option<f64> {
        self.predicted_resident_ns()
            .or_else(|| self.predicted_overlapped_ns())
            .or_else(|| self.predicted_layer_ns())
    }

    /// The group's headline plan: the paper's bottleneck down-projection,
    /// or the expert down-projection (the last expert node) on MoE layers.
    pub fn headline(&self) -> Option<TunedPlan> {
        self.get(GemmKind::Down).or_else(|| {
            self.nodes
                .iter()
                .rev()
                .find(|n| n.kind == GemmKind::MoeExpert)
                .and_then(|n| n.plan)
        })
    }
}

/// Which degradation-ladder rung served a routed group.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RouteRung {
    /// Tuned winners + overlap + residency, all cache-only.
    Full,
    /// Tuned winners; some cross-node gain missing from the cache.
    TunedOnly,
    /// At least one shape re-tuned inline under the budget.
    Retuned,
    /// At least one node fell to the safe default splitk schedule.
    DefaultSplitk,
}

impl RouteRung {
    pub fn name(&self) -> &'static str {
        match self {
            RouteRung::Full => "full",
            RouteRung::TunedOnly => "tuned_only",
            RouteRung::Retuned => "retuned",
            RouteRung::DefaultSplitk => "default_splitk",
        }
    }
}

/// Why routing landed on its rung.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteReason {
    /// Everything resolved cache-only (rung `full`).
    WarmCache,
    /// Shape winners hit, but a pair/residency decision is missing.
    GainsMissing,
    /// Some shape key missed a present, current-tagged cache.
    ShapeMiss,
    /// The cache holds entries, but none tuned on this machine.
    StaleMachineTag,
    /// The cache file exists but failed to parse (corrupt/truncated).
    CacheUnreadable,
    /// No cache file next to the artifacts.
    NoCacheFile,
    /// Misses remained after the inline re-tune budget ran out.
    RetuneBudgetExhausted,
    /// The artifact carries no decode config: nothing to plan over.
    NoDecodeConfig,
}

impl RouteReason {
    pub fn name(&self) -> &'static str {
        match self {
            RouteReason::WarmCache => "warm_cache",
            RouteReason::GainsMissing => "gains_missing",
            RouteReason::ShapeMiss => "shape_miss",
            RouteReason::StaleMachineTag => "stale_machine_tag",
            RouteReason::CacheUnreadable => "cache_unreadable",
            RouteReason::NoCacheFile => "no_cache_file",
            RouteReason::RetuneBudgetExhausted => "retune_budget_exhausted",
            RouteReason::NoDecodeConfig => "no_decode_config",
        }
    }
}

/// The typed routing decision: which rung served, why, and how many
/// nodes each fallback mechanism touched.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteOutcome {
    pub rung: RouteRung,
    pub reason: RouteReason,
    /// Detail for the unreadable-cache reason (the parse error).
    pub detail: Option<String>,
    /// Nodes re-tuned inline (rung 3).
    pub retuned_nodes: usize,
    /// Nodes served by the default splitk schedule (rung 4).
    pub defaulted_nodes: usize,
}

/// A routed plan: the (possibly degraded) layer plan plus the typed
/// outcome that tells metrics which rung served it.
#[derive(Debug, Clone, PartialEq)]
pub struct RoutedPlan {
    /// `None` only when the artifact has no decode config.
    pub plan: Option<LayerPlan>,
    pub outcome: RouteOutcome,
}

/// Price one problem under the safe default splitk schedule (rung 4).
fn splitk_plan(machine: &MachineConfig, p: &GemmProblem) -> Option<TunedPlan> {
    let trace = kernels::schedule(machine, p, Strategy::SplitK).ok()?;
    let report = Simulator::new(machine.clone()).run(&trace).ok()?;
    Some(TunedPlan { strategy: Strategy::SplitK, predicted_ns: report.total_ns })
}

/// Engine pool keyed by batch size for one decode model.
pub struct Router<'rt> {
    rt: &'rt Runtime,
    manifest: Manifest,
    model: String,
    machine: MachineConfig,
    engines: HashMap<usize, Engine>,
    /// Schedule tuner backed by the cache next to the artifacts.  `None`
    /// until the ladder needs one (no cache file, or unreadable file) —
    /// an inline re-tune then creates an in-memory tuner on demand.
    tuner: Option<Tuner>,
    /// Whether a cache file existed next to the artifacts at startup.
    cache_file_found: bool,
    /// The parse error, when the cache file existed but was unreadable.
    cache_load_error: Option<String>,
    /// Whether the loaded cache holds entries for a *different* machine
    /// tag only (tuned on other hardware) — computed once at startup.
    stale_tag: bool,
    /// Remaining inline re-tune searches (rung 3).
    retune_budget: usize,
    /// Token-bucket refill: one re-tune credit per this many virtual µs
    /// (`None` = the fixed lifetime budget of DESIGN.md §14, no refill).
    retune_refill_interval_us: Option<u64>,
    /// Bucket capacity the refill credits up to.
    retune_budget_cap: usize,
    /// Virtual time through which refill credits have been granted.
    last_refill_us: u64,
    /// Precision family every routed layer is tagged with.  W4A16 keeps
    /// every tune-cache key byte-identical to the pre-precision format;
    /// W4A8 keys carry the `_a8` suffix, so a stale W4A16-only cache
    /// simply misses and the plan resolves down the ladder (never abort).
    precision: Precision,
    routes: HashMap<usize, RoutedPlan>,
    /// Memoized prefill-chunk routes, keyed by chunk token count `m`
    /// (disjoint from `routes`: a decode batch and a prefill chunk of
    /// the same size share GEMM shapes but are distinct route entries).
    prefill_routes: HashMap<usize, RoutedPlan>,
}

impl<'rt> Router<'rt> {
    /// Build the router.  An unreadable tune cache is *not* an error: it
    /// is recorded, and every route degrades down the ladder instead.
    pub fn new(rt: &'rt Runtime, manifest: Manifest, model: &str) -> anyhow::Result<Router<'rt>> {
        anyhow::ensure!(
            !manifest.decode_batches(model).is_empty(),
            "no decode artifacts for model '{model}'"
        );
        let machine = MachineConfig::ascend910();
        let cache_path = manifest.dir.join(DEFAULT_CACHE_FILE);
        let cache_file_found = cache_path.exists();
        let mut cache_load_error = None;
        let tuner = if cache_file_found {
            match Tuner::load(machine.clone(), &cache_path) {
                Ok(t) => Some(t),
                Err(e) => {
                    cache_load_error = Some(format!("{e:#}"));
                    None
                }
            }
        } else {
            None
        };
        let stale_tag = tuner
            .as_ref()
            .map(|t| t.cache.total_len() > 0 && !t.cache.has_tag(&machine_tag(&machine)))
            .unwrap_or(false);
        Ok(Router {
            rt,
            manifest,
            model: model.to_string(),
            machine,
            engines: HashMap::new(),
            tuner,
            cache_file_found,
            cache_load_error,
            stale_tag,
            retune_budget: DEFAULT_RETUNE_BUDGET,
            retune_refill_interval_us: None,
            retune_budget_cap: DEFAULT_RETUNE_BUDGET,
            last_refill_us: 0,
            precision: Precision::default(),
            routes: HashMap::new(),
            prefill_routes: HashMap::new(),
        })
    }

    /// Batch sizes this model was compiled for (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.manifest.decode_batches(&self.model)
    }

    /// Get (or build) the engine for a batch size: PJRT-backed when the
    /// artifact ships weights, synthetic when it only carries a config.
    pub fn engine(&mut self, batch: usize) -> anyhow::Result<&mut Engine> {
        if !self.engines.contains_key(&batch) {
            let entry = self.manifest.decode(&self.model, batch)?;
            let engine = if entry.weights.is_some() {
                Engine::Real(DecodeEngine::new(self.rt, entry)?)
            } else {
                let cfg = entry.config.ok_or_else(|| {
                    anyhow::anyhow!("decode artifact '{}' has neither weights nor config", entry.name)
                })?;
                Engine::Synthetic(SimEngine::new(&cfg, batch))
            };
            self.engines.insert(batch, engine);
        }
        Ok(self.engines.get_mut(&batch).unwrap())
    }

    /// Route a batch size down the degradation ladder.  Never fails:
    /// the worst case is an unplanned group (no decode config) served
    /// under rung 4 accounting.  Memoized per batch size.
    pub fn route(&mut self, batch: usize) -> RoutedPlan {
        if let Some(hit) = self.routes.get(&batch) {
            return hit.clone();
        }
        let routed = self.resolve_route(batch);
        self.routes.insert(batch, routed.clone());
        routed
    }

    /// Plans for every GEMM node of a batch size's decode layer (dense
    /// projections plus the MoE expert fan-out when the config routes
    /// experts).  `None` only when the artifact has no decode config.
    /// Degraded resolution per the ladder; memoized per batch size.
    pub fn layer_plan(&mut self, batch: usize) -> Option<LayerPlan> {
        self.route(batch).plan
    }

    /// The tuned schedule for the batch's bottleneck GEMM — the FFN
    /// down-projection the paper profiles (K = ffn >> N = hidden), or
    /// the expert down-projection on MoE models.
    pub fn tuned_plan(&mut self, batch: usize) -> Option<TunedPlan> {
        self.layer_plan(batch).and_then(|plan| plan.headline())
    }

    /// Route a prefill chunk of `chunk` prompt tokens down the same
    /// degradation ladder (DESIGN.md §15).  The chunk's projection GEMMs
    /// are the decode problems at M = chunk, so tuned winners, pair
    /// decisions and residency plans resolve through the same tune
    /// cache; no compiled per-M artifact is needed — the simulator
    /// prices any M.  Memoized per chunk size.
    pub fn route_prefill(&mut self, chunk: usize) -> RoutedPlan {
        if let Some(hit) = self.prefill_routes.get(&chunk) {
            return hit.clone();
        }
        let routed = match self.first_decode_config() {
            None => RoutedPlan { plan: None, outcome: Self::no_config_outcome() },
            Some(cfg) => {
                let layer =
                    DecodeLayer::from_decode_config(&cfg, chunk).with_precision(self.precision);
                self.resolve_layer_route(&layer)
            }
        };
        self.prefill_routes.insert(chunk, routed.clone());
        routed
    }

    /// The model's decode config from its first (smallest-batch)
    /// artifact — the geometry source for prefill-chunk routing.
    pub fn first_decode_config(&self) -> Option<crate::runtime::artifacts::DecodeConfig> {
        self.manifest
            .decode_batches(&self.model)
            .into_iter()
            .find_map(|b| self.manifest.decode(&self.model, b).ok().and_then(|e| e.config))
    }

    /// Enable token-bucket refill of the re-tune budget: one credit per
    /// `interval_us` virtual µs, up to `cap` banked credits (DESIGN.md
    /// §15).  Replaces PR 6's fixed lifetime budget with a sustainable
    /// background rate.
    pub fn set_retune_refill(&mut self, interval_us: u64, cap: usize) {
        self.retune_refill_interval_us = Some(interval_us.max(1));
        self.retune_budget_cap = cap.max(1);
    }

    /// Advance the router's view of the virtual clock, crediting the
    /// re-tune token bucket.  When credits land, memoized routes are
    /// cleared so batches that degraded on an empty bucket re-walk the
    /// ladder (cache-only for warm shapes — re-resolution is cheap).
    pub fn advance_clock(&mut self, now_us: u64) {
        let Some(interval) = self.retune_refill_interval_us else {
            return;
        };
        if now_us <= self.last_refill_us {
            return;
        }
        let credits = (now_us - self.last_refill_us) / interval;
        if credits == 0 {
            return;
        }
        self.last_refill_us += credits * interval;
        if self.retune_budget < self.retune_budget_cap {
            self.retune_budget =
                (self.retune_budget + credits as usize).min(self.retune_budget_cap);
            self.routes.clear();
            self.prefill_routes.clear();
        }
    }

    /// Re-tune one decode batch in the background: fully resolve its
    /// shape winners, pair decisions and residency plan into the tuner
    /// (paying the searches now, off the serving path), then drop the
    /// memoized route so the next [`Router::route`] call lands on rung
    /// `full`.  Does not consume the inline re-tune bucket.
    pub fn background_retune(&mut self, batch: usize) -> anyhow::Result<()> {
        let cfg = self
            .manifest
            .decode(&self.model, batch)
            .ok()
            .and_then(|e| e.config)
            .ok_or_else(|| anyhow::anyhow!("no decode config for batch {batch}"))?;
        let layer =
            DecodeLayer::from_decode_config(&cfg, batch).with_precision(self.precision);
        let machine = self.machine.clone();
        let tuner = self.tuner.get_or_insert_with(|| Tuner::new(machine));
        // Walk the layer's op list through the StepOp trait: only
        // GEMM-backed ops key the tune cache (a future op kind without a
        // tunable schedule just yields `None` here).
        for op in layer.gemm_nodes() {
            let Some(node) = StepOp::gemm(&op) else { continue };
            if node.problem.validate().is_ok() {
                tuner.resolve(&node.problem)?;
            }
        }
        for pair in layer.overlap_pairs() {
            tuner.resolve_overlap(&pair.producer, &pair.consumer)?;
        }
        tuner.resolve_residency(&layer)?;
        self.routes.remove(&batch);
        Ok(())
    }

    /// A `no decode config` outcome (the only unplanned route).
    fn no_config_outcome() -> RouteOutcome {
        RouteOutcome {
            rung: RouteRung::DefaultSplitk,
            reason: RouteReason::NoDecodeConfig,
            detail: None,
            retuned_nodes: 0,
            defaulted_nodes: 0,
        }
    }

    fn resolve_route(&mut self, batch: usize) -> RoutedPlan {
        let cfg = match self.manifest.decode(&self.model, batch).ok().and_then(|e| e.config) {
            Some(cfg) => cfg,
            None => return RoutedPlan { plan: None, outcome: Self::no_config_outcome() },
        };
        let layer =
            DecodeLayer::from_decode_config(&cfg, batch).with_precision(self.precision);
        self.resolve_layer_route(&layer)
    }

    /// The shared ladder body: price every GEMM node of one layer graph
    /// down the degradation ladder and resolve the cross-node gains
    /// cache-only.  Decode batches and prefill chunks both route here —
    /// their projection GEMMs differ only in M, so they key through the
    /// same tune cache.
    fn resolve_layer_route(&mut self, layer: &DecodeLayer) -> RoutedPlan {
        let machine = self.machine.clone();
        let gemm_nodes = layer.gemm_nodes();
        let mut retuned = 0usize;
        let mut defaulted = 0usize;
        let mut nodes = Vec::with_capacity(gemm_nodes.len());
        // The ladder walks the op list through the StepOp trait — ops
        // without an underlying GEMM carry no tunable schedule and are
        // not planned (none exist in today's layer graphs).
        for op in &gemm_nodes {
            let Some(node) = StepOp::gemm(op) else { continue };
            let count = StepOp::count(op);
            if node.problem.validate().is_err() {
                // Structurally unpriceable: no rung can serve a plan.
                nodes.push(PlanNode { kind: node.kind, count, plan: None });
                continue;
            }
            // Rungs 1/2: cache-only tuned lookup (the fast path).
            let mut plan = self
                .tuner
                .as_mut()
                .and_then(|t| t.lookup(&node.problem))
                .map(|e| TunedPlan { strategy: e.strategy, predicted_ns: e.total_ns });
            if plan.is_none() {
                // Rung 3: inline re-tune under the budget.  The search
                // fills the (in-memory) cache, so aliased shapes and
                // later groups hit rungs 1/2 again.
                if self.retune_budget > 0 {
                    let tuner =
                        self.tuner.get_or_insert_with(|| Tuner::new(machine.clone()));
                    if let Ok(e) = tuner.resolve(&node.problem) {
                        plan = Some(TunedPlan { strategy: e.strategy, predicted_ns: e.total_ns });
                        retuned += 1;
                    }
                    self.retune_budget -= 1;
                }
                if plan.is_none() {
                    // Rung 4: the safe default, priced by the simulator.
                    defaulted += 1;
                    plan = splitk_plan(&machine, &node.problem);
                }
            }
            nodes.push(PlanNode { kind: node.kind, count, plan });
        }
        // Cross-node gains stay cache-only: re-deriving a pair or
        // residency decision costs merged-trace simulations, which the
        // serving path never pays.  Missing gains degrade the rung, not
        // the plan.
        let overlap_gain_ns = self.tuner.as_mut().and_then(|t| {
            let mut total = 0.0;
            for pair in layer.overlap_pairs() {
                total += pair.pairs as f64 * t.lookup_overlap(&pair.producer, &pair.consumer)?;
            }
            Some(total)
        });
        let residency = self.tuner.as_mut().and_then(|t| t.lookup_residency(layer));
        let rung = if defaulted > 0 {
            RouteRung::DefaultSplitk
        } else if retuned > 0 {
            RouteRung::Retuned
        } else if overlap_gain_ns.is_some() && residency.is_some() {
            RouteRung::Full
        } else {
            RouteRung::TunedOnly
        };
        let reason = if self.cache_load_error.is_some() {
            RouteReason::CacheUnreadable
        } else if !self.cache_file_found {
            RouteReason::NoCacheFile
        } else if self.stale_tag && retuned + defaulted > 0 {
            RouteReason::StaleMachineTag
        } else {
            match rung {
                RouteRung::Full => RouteReason::WarmCache,
                RouteRung::TunedOnly => RouteReason::GainsMissing,
                RouteRung::Retuned => RouteReason::ShapeMiss,
                RouteRung::DefaultSplitk => RouteReason::RetuneBudgetExhausted,
            }
        };
        RoutedPlan {
            plan: Some(LayerPlan {
                nodes,
                overlap_gain_ns,
                residency_gain_ns: residency.map(|r| r.gain_ns),
                residency_pinned_bytes: residency.map(|r| r.pinned_bytes),
            }),
            outcome: RouteOutcome {
                rung,
                reason,
                detail: self.cache_load_error.clone(),
                retuned_nodes: retuned,
                defaulted_nodes: defaulted,
            },
        }
    }

    /// Serve every layer at `precision` from now on.  Clears memoized
    /// routes: the same batch re-walks the ladder under the new tags
    /// (cache-only when the cache was tuned for that precision; retune /
    /// default-splitk rungs otherwise — a pre-precision cache is a miss,
    /// never an error).
    pub fn set_precision(&mut self, precision: Precision) {
        if self.precision == precision {
            return;
        }
        self.precision = precision;
        self.routes.clear();
        self.prefill_routes.clear();
    }

    /// The precision family routed layers are tagged with.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Whether a readable tune cache was found next to the artifacts.
    pub fn has_tune_cache(&self) -> bool {
        self.cache_file_found && self.cache_load_error.is_none()
    }

    /// Remaining inline re-tune searches (rung 3 of the ladder).
    pub fn retune_budget(&self) -> usize {
        self.retune_budget
    }

    /// Override the inline re-tune budget (0 forces rung 4 on misses).
    /// Clears memoized routes so the new budget applies to every batch
    /// and chunk.
    pub fn set_retune_budget(&mut self, budget: usize) {
        self.retune_budget = budget;
        self.routes.clear();
        self.prefill_routes.clear();
    }

    /// Number of engines built so far.
    pub fn engines_built(&self) -> usize {
        self.engines.len()
    }

    /// The machine model the router prices against.
    pub fn machine(&self) -> &MachineConfig {
        &self.machine
    }

    pub fn model(&self) -> &str {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    // Router construction needs a manifest on disk; the ladder is
    // exercised end to end by rust/tests/layer_graph.rs (synthetic
    // manifests), rust/tests/failure_injection.rs (corrupt/stale caches)
    // and rust/tests/coordinator.rs (real artifacts + PJRT).
}
