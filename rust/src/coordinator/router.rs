//! Router: maps a decode group to the engine compiled for its batch size,
//! and to the tuned kernel schedule for its dominant GEMM shape.
//!
//! Engines are constructed lazily (compiling an HLO module and staging
//! ~100M parameters of weight literals is expensive) and cached for the
//! server's lifetime — the per-shape executable pool of the serving stack.
//!
//! Schedule tuning: if a tune cache (`tune_cache.json`, written by
//! `repro tune`) sits next to the artifact manifest, the router resolves
//! every projection GEMM of the decode layer — QKV, attention-out,
//! up/gate and the FFN down-projection (the paper's K >> N bottleneck) —
//! through it, so each group is served under its per-node tuned
//! strategies.  The lookup is cache-only: the serving hot path never pays
//! a search.

use std::collections::HashMap;

use crate::ascend::MachineConfig;
use crate::kernels::Strategy;
use crate::model::DecodeEngine;
use crate::runtime::{Manifest, Runtime};
use crate::tune::{Tuner, DEFAULT_CACHE_FILE};
use crate::workload::decode_layer::{DecodeLayer, GemmKind};

/// The tuned plan for one GEMM node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedPlan {
    pub strategy: Strategy,
    /// Simulated kernel time of the tuned schedule (ns).
    pub predicted_ns: f64,
}

/// Tuned plans for all four projection GEMMs of one decode layer
/// (`None` per node on a cache miss — that node serves untuned).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LayerPlan {
    pub nodes: [(GemmKind, Option<TunedPlan>); 4],
}

impl LayerPlan {
    pub fn get(&self, kind: GemmKind) -> Option<TunedPlan> {
        self.nodes.iter().find(|(k, _)| *k == kind).and_then(|(_, plan)| *plan)
    }

    /// Strategy label for the metrics sink ("untuned" on a cache miss).
    pub fn strategy_label(&self, kind: GemmKind) -> &'static str {
        self.get(kind).map(|p| p.strategy.name()).unwrap_or("untuned")
    }

    /// Whether every node resolved through the cache.
    pub fn fully_resolved(&self) -> bool {
        self.nodes.iter().all(|(_, plan)| plan.is_some())
    }

    /// Predicted GEMM time of the whole layer (only when fully resolved).
    pub fn predicted_layer_ns(&self) -> Option<f64> {
        self.nodes
            .iter()
            .map(|&(_, plan)| plan.map(|p| p.predicted_ns))
            .sum::<Option<f64>>()
    }
}

/// Engine pool keyed by batch size for one decode model.
pub struct Router<'rt> {
    rt: &'rt Runtime,
    manifest: Manifest,
    model: String,
    engines: HashMap<usize, DecodeEngine>,
    /// Schedule tuner backed by the cache next to the artifacts (None when
    /// no cache file exists — groups then serve under the default splitk).
    tuner: Option<Tuner>,
    plans: HashMap<usize, Option<LayerPlan>>,
}

impl<'rt> Router<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: Manifest, model: &str) -> anyhow::Result<Router<'rt>> {
        anyhow::ensure!(
            !manifest.decode_batches(model).is_empty(),
            "no decode artifacts for model '{model}'"
        );
        let cache_path = manifest.dir.join(DEFAULT_CACHE_FILE);
        let tuner = if cache_path.exists() {
            Some(Tuner::load(MachineConfig::ascend910(), &cache_path)?)
        } else {
            None
        };
        Ok(Router {
            rt,
            manifest,
            model: model.to_string(),
            engines: HashMap::new(),
            tuner,
            plans: HashMap::new(),
        })
    }

    /// Batch sizes this model was compiled for (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.manifest.decode_batches(&self.model)
    }

    /// Get (or build) the engine for a batch size.
    pub fn engine(&mut self, batch: usize) -> anyhow::Result<&mut DecodeEngine> {
        if !self.engines.contains_key(&batch) {
            let entry = self.manifest.decode(&self.model, batch)?;
            let engine = DecodeEngine::new(self.rt, entry)?;
            self.engines.insert(batch, engine);
        }
        Ok(self.engines.get_mut(&batch).unwrap())
    }

    /// Tuned plans for all four projection GEMMs of a batch size's decode
    /// layer, from the persisted cache (`None` when the artifact has no
    /// decode config or no cache file was found; per-node `None` on a
    /// cache miss).  Memoized per batch size.
    pub fn layer_plan(&mut self, batch: usize) -> Option<LayerPlan> {
        if let Some(plan) = self.plans.get(&batch) {
            return *plan;
        }
        let plan = self.resolve_layer_plan(batch);
        self.plans.insert(batch, plan);
        plan
    }

    /// The tuned schedule for the batch's bottleneck GEMM — the FFN
    /// down-projection the paper profiles (K = ffn >> N = hidden).
    pub fn tuned_plan(&mut self, batch: usize) -> Option<TunedPlan> {
        self.layer_plan(batch).and_then(|plan| plan.get(GemmKind::Down))
    }

    fn resolve_layer_plan(&mut self, batch: usize) -> Option<LayerPlan> {
        let cfg = self
            .manifest
            .decode(&self.model, batch)
            .ok()
            .and_then(|e| e.config)?;
        let tuner = self.tuner.as_mut()?;
        let layer = DecodeLayer::from_decode_config(&cfg, batch);
        let mut nodes = [(GemmKind::Down, None); 4];
        for (slot, (kind, p)) in nodes.iter_mut().zip(layer.problems()) {
            // Cache-only: the serving hot path never pays a search.
            let plan = if p.validate().is_ok() {
                tuner
                    .lookup(&p)
                    .map(|e| TunedPlan { strategy: e.strategy, predicted_ns: e.total_ns })
            } else {
                None
            };
            *slot = (kind, plan);
        }
        Some(LayerPlan { nodes })
    }

    /// Whether a tune cache was found next to the artifacts.
    pub fn has_tune_cache(&self) -> bool {
        self.tuner.is_some()
    }

    /// Number of engines built so far.
    pub fn engines_built(&self) -> usize {
        self.engines.len()
    }

    pub fn model(&self) -> &str {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    // Router construction needs real artifacts + a PJRT client; exercised
    // by rust/tests/coordinator.rs (including the tuned-plan path).
}
