//! Router: maps a decode group to the engine compiled for its batch size.
//!
//! Engines are constructed lazily (compiling an HLO module and staging
//! ~100M parameters of weight literals is expensive) and cached for the
//! server's lifetime — the per-shape executable pool of the serving stack.

use std::collections::HashMap;

use crate::model::DecodeEngine;
use crate::runtime::{Manifest, Runtime};

/// Engine pool keyed by batch size for one decode model.
pub struct Router<'rt> {
    rt: &'rt Runtime,
    manifest: Manifest,
    model: String,
    engines: HashMap<usize, DecodeEngine>,
}

impl<'rt> Router<'rt> {
    pub fn new(rt: &'rt Runtime, manifest: Manifest, model: &str) -> anyhow::Result<Router<'rt>> {
        anyhow::ensure!(
            !manifest.decode_batches(model).is_empty(),
            "no decode artifacts for model '{model}'"
        );
        Ok(Router { rt, manifest, model: model.to_string(), engines: HashMap::new() })
    }

    /// Batch sizes this model was compiled for (ascending).
    pub fn batch_sizes(&self) -> Vec<usize> {
        self.manifest.decode_batches(&self.model)
    }

    /// Get (or build) the engine for a batch size.
    pub fn engine(&mut self, batch: usize) -> anyhow::Result<&mut DecodeEngine> {
        if !self.engines.contains_key(&batch) {
            let entry = self.manifest.decode(&self.model, batch)?;
            let engine = DecodeEngine::new(self.rt, entry)?;
            self.engines.insert(batch, engine);
        }
        Ok(self.engines.get_mut(&batch).unwrap())
    }

    /// Number of engines built so far.
    pub fn engines_built(&self) -> usize {
        self.engines.len()
    }

    pub fn model(&self) -> &str {
        &self.model
    }
}

#[cfg(test)]
mod tests {
    // Router construction needs real artifacts + a PJRT client; exercised
    // by rust/tests/coordinator.rs.
}
