//! The serving loop: queue -> batcher -> router -> decode engine.
//!
//! Group-synchronous iteration batching: the server drains the queue into
//! a fixed-size decode group (padding idle slots), then steps the group's
//! engine until every member has consumed its prompt and produced its
//! generation budget.  Prompt tokens are ingested through the same decode
//! step (teacher-forced positions), so the whole serving path — prefill
//! and decode — runs the W4A16 pipeline under test.

use std::time::Instant;

use super::batcher::{Batcher, DecodeGroup};
use super::metrics::Metrics;
use super::request::{DecodeRequest, DecodeResult};
use super::router::{LayerPlan, Router};
use crate::workload::decode_layer::GemmKind;

/// Per-slot decode state inside a running group.
struct Slot<'r> {
    req: &'r DecodeRequest,
    /// Next position to write in the KV cache.
    position: usize,
    /// Token to feed next step.
    next_input: i32,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
    done: bool,
}

/// The decode server for one model.
pub struct Server<'rt> {
    pub router: Router<'rt>,
    pub batcher: Batcher,
    pub metrics: Metrics,
}

impl<'rt> Server<'rt> {
    pub fn new(router: Router<'rt>, batcher: Batcher) -> Server<'rt> {
        Server { router, batcher, metrics: Metrics::new() }
    }

    /// Admit a request into the queue.
    pub fn submit(&mut self, mut req: DecodeRequest) {
        req.arrived = Some(Instant::now());
        self.batcher.push(req);
    }

    /// Serve until the queue is empty; returns all results.
    pub fn drain(&mut self) -> anyhow::Result<Vec<DecodeResult>> {
        let mut results = Vec::new();
        while let Some(group) = self.batcher.form_group(true) {
            results.extend(self.run_group(group)?);
        }
        Ok(results)
    }

    /// Serve exactly one group if one can be formed.
    pub fn serve_one(&mut self, drain: bool) -> anyhow::Result<Vec<DecodeResult>> {
        match self.batcher.form_group(drain) {
            Some(group) => self.run_group(group),
            None => Ok(Vec::new()),
        }
    }

    /// Record which tuned schedule serves each GEMM node of a routed
    /// group — the dense projections or the MoE expert fan-out, with its
    /// per-kind expert counts; the down-projection (the paper's
    /// bottleneck; the expert down-projection on MoE models) doubles as
    /// the group's headline schedule counter.
    pub fn record_group_schedules(metrics: &Metrics, plan: Option<&LayerPlan>) {
        match plan {
            Some(p) => {
                for node in &p.nodes {
                    let label = node.plan.map(|t| t.strategy.name()).unwrap_or("untuned");
                    metrics.record_gemm_schedule_n(
                        node.kind.name(),
                        label,
                        node.plan.map(|t| t.predicted_ns * node.count as f64),
                        node.count as u64,
                    );
                }
            }
            None => {
                for kind in GemmKind::all() {
                    metrics.record_gemm_schedule(kind.name(), "untuned", None);
                }
            }
        }
        let headline = plan
            .and_then(|p| p.headline())
            .map(|p| p.strategy.name())
            .unwrap_or("untuned");
        metrics.record_schedule(headline);
    }

    /// Decode one group to completion.
    fn run_group(&mut self, group: DecodeGroup) -> anyhow::Result<Vec<DecodeResult>> {
        // Which kernel schedules serve this group's decode-layer GEMMs:
        // the tuned winners from the persisted cache, or untuned defaults.
        let plan = self.router.layer_plan(group.batch);
        Server::record_group_schedules(&self.metrics, plan.as_ref());
        // The plan's predicted cross-node gains (overlap + residency),
        // cache-only — the predicted-overlap column of the metrics report.
        if let Some(p) = plan.as_ref() {
            self.metrics.record_group_plan(group.batch, p.overlap_gain_ns, p.residency_gain_ns);
        }
        let engine = self.router.engine(group.batch)?;
        engine.reset()?;
        let vocab = engine.vocab;
        let max_seq = engine.max_seq;
        for req in &group.members {
            req.validate(vocab, max_seq)?;
        }

        let mut slots: Vec<Slot> = group
            .members
            .iter()
            .map(|req| Slot {
                req,
                position: 0,
                next_input: req.prompt[0],
                generated: Vec::new(),
                first_token_at: None,
                done: false,
            })
            .collect();

        let mut steps = 0usize;
        while slots.iter().any(|s| !s.done) {
            // Assemble the step: idle/finished/padding slots replay token 0
            // at their last written position (harmless rewrite).
            let mut tokens = vec![0i32; group.batch];
            let mut positions = vec![0i32; group.batch];
            for (i, slot) in slots.iter().enumerate() {
                tokens[i] = if slot.done { 0 } else { slot.next_input };
                positions[i] = slot.position as i32;
            }
            let out = engine.step(&tokens, &positions)?;
            steps += 1;

            for (i, slot) in slots.iter_mut().enumerate() {
                if slot.done {
                    continue;
                }
                let produced = out.next_tokens[i];
                slot.position += 1;
                if slot.position < slot.req.prompt.len() {
                    // Still ingesting the prompt (teacher forcing).
                    slot.next_input = slot.req.prompt[slot.position];
                } else {
                    // Generating.
                    if slot.first_token_at.is_none() {
                        slot.first_token_at = Some(Instant::now());
                    }
                    slot.generated.push(produced);
                    slot.next_input = produced;
                    if slot.generated.len() >= slot.req.max_new_tokens
                        || slot.position + 1 >= max_seq
                    {
                        slot.done = true;
                    }
                }
            }
        }

        self.metrics.record_group(group.batch, group.occupancy(), steps);
        let now = Instant::now();
        let results = slots
            .into_iter()
            .map(|slot| {
                let arrived = slot.req.arrived.unwrap_or(now);
                let ttft = slot
                    .first_token_at
                    .map(|t| t.duration_since(arrived).as_secs_f64())
                    .unwrap_or(0.0);
                let total = now.duration_since(arrived).as_secs_f64();
                self.metrics
                    .record_completion(slot.generated.len(), ttft, total);
                DecodeResult {
                    id: slot.req.id,
                    tokens: slot.generated,
                    ttft_s: ttft,
                    total_s: total,
                    steps,
                }
            })
            .collect();
        Ok(results)
    }
}

#[cfg(test)]
mod tests {
    // Full server behaviour needs artifacts + PJRT; see
    // rust/tests/coordinator.rs and examples/llm_decode.rs.
}
