//! The serving loop: queue -> batcher -> router -> decode engine.
//!
//! Group-synchronous iteration batching: the server drains the queue into
//! a fixed-size decode group (padding idle slots), then steps the group's
//! engine until every member has consumed its prompt and produced its
//! generation budget.  Prompt tokens are ingested through the same decode
//! step (teacher-forced positions), so the whole serving path — prefill
//! and decode — runs the W4A16 pipeline under test.
//!
//! Fault tolerance (DESIGN.md §14): the server owns a *virtual clock*
//! (µs) that advances by the routed plan's predicted step time, so
//! deadlines, max-wait batching, stragglers and retry backoff are all
//! deterministic — no wall-clock sleeps anywhere.  An optional seeded
//! [`FaultPlan`] injects stragglers (the step lands late but correct)
//! and transient engine/client errors (the step is retried with
//! exponential backoff under [`RetryPolicy`]).  A group step that
//! exhausts its retries fails only that group's unfinished members —
//! never the server: `drain` always returns a result for every admitted
//! request, each carrying exactly one [`Outcome`].

use std::time::Instant;

use super::batcher::{Admission, Batcher, DecodeGroup};
use super::faults::{
    FaultKind, FaultPlan, ADMISSION_FAULT_NAME, CACHE_WRITE_FAULT_NAME, MEMBER_FAULT_NAME,
    PREEMPT_FAULT_NAME, SWAP_FAULT_NAME,
};
use super::metrics::Metrics;
use super::request::{DecodeRequest, DecodeResult, Outcome};
use super::router::{LayerPlan, Router};
use crate::analysis::layer::repin_decayed_ns;
use crate::ascend::{vecpass, MachineConfig};
use crate::model::{kv_bytes_per_token, KvPager, DEFAULT_PAGE_BYTES};
use crate::runtime::artifacts::DecodeConfig;
use crate::runtime::RetryPolicy;
use crate::util::prng::Rng;
use crate::workload::decode_layer::{DecodeLayer, GemmKind, StepNode};
use crate::workload::{ArrivalPlan, PrefillStep};

/// Virtual step cost when the routed plan carries no prediction (µs).
pub const DEFAULT_STEP_US: u64 = 1_000;

/// Default prompt tokens one prefill tick ingests (DESIGN.md §15).
pub const DEFAULT_PREFILL_CHUNK: usize = 128;

/// How the serve loop reclaims KV pages under pressure (DESIGN.md §18).
///
/// With preemption off, an arrival whose worst-case reservation does not
/// fit is a `kv_capacity` shed at the door (§15).  The other policies
/// instead evict a resident victim — LRU by last-scheduled tick, ties to
/// the shortest generation — and park it on a resume queue that seats
/// ahead of new arrivals.  What differs is how the victim's KV state
/// comes back: recompute re-prefills the prompt plus the generated
/// prefix through the chunked prefill path; swap writes the victim's
/// live pages across the host link and reads them back at resume;
/// auto prices both paths per victim and takes the cheaper.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PreemptPolicy {
    /// Never preempt: over-capacity arrivals shed at admission.
    #[default]
    Off,
    /// Drop the victim's pages; re-prefill prompt + generated prefix.
    Recompute,
    /// Move the victim's live pages to host memory and back.
    Swap,
    /// Price recompute vs. swap per victim; take the cheaper path.
    Auto,
}

impl PreemptPolicy {
    /// CLI spellings for `--preempt`, aligned with [`PreemptPolicy::name`].
    pub const CHOICES: &'static [(&'static [&'static str], PreemptPolicy)] = &[
        (&["off", "none"], PreemptPolicy::Off),
        (&["recompute"], PreemptPolicy::Recompute),
        (&["swap"], PreemptPolicy::Swap),
        (&["auto"], PreemptPolicy::Auto),
    ];

    pub fn name(self) -> &'static str {
        match self {
            PreemptPolicy::Off => "off",
            PreemptPolicy::Recompute => "recompute",
            PreemptPolicy::Swap => "swap",
            PreemptPolicy::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> Option<PreemptPolicy> {
        match name {
            "off" => Some(PreemptPolicy::Off),
            "recompute" => Some(PreemptPolicy::Recompute),
            "swap" => Some(PreemptPolicy::Swap),
            "auto" => Some(PreemptPolicy::Auto),
            _ => None,
        }
    }
}

/// Surcharge one straggling batch member bills the group clock (µs).
///
/// A member fault serializes only the straggler's slot share of the
/// step tail — `ceil(step/batch)` — scaled by the multiplier's excess
/// over 1.0x, rounded up with a 1µs floor so sub-µs steps still charge
/// (same floor as the whole-step straggler chain).  `batch = 1`
/// degenerates to the whole-step straggler charge, which is why a
/// member fault at `batch > 1` is always cheaper than failing the
/// whole step for the same multiplier.
pub fn member_tail_penalty_us(step_us: u64, batch: usize, mult_x100: u32) -> u64 {
    step_us
        .div_ceil(batch.max(1) as u64)
        .saturating_mul(mult_x100.saturating_sub(100) as u64)
        .div_ceil(100)
        .max(1)
}

/// Default bound on how often one request may be preempted.  Each
/// preemption increments the victim's cycle count; at the bound it stops
/// being victim-eligible, so admission pressure can never bounce the
/// same request forever (the no-livelock guarantee of DESIGN.md §18).
pub const DEFAULT_MAX_PREEMPTIONS: u32 = 2;

/// Knobs of one continuous-batching serve run (DESIGN.md §15).
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Engine batch size (the slot count) — must have a compiled decode
    /// artifact.
    pub batch: usize,
    /// Max prompt tokens one prefill tick ingests.
    pub chunk: usize,
    /// Admission-queue bound (waiting requests, not counting slots).
    pub queue_cap: usize,
    /// Optional per-request SLO (virtual µs from arrival).
    pub deadline_us: Option<u64>,
    /// KV-cache page size (bytes).
    pub page_bytes: u64,
    /// HBM bytes already claimed by resident weights (subtracted from
    /// the machine's capacity before paging).
    pub weight_bytes: u64,
    /// Override the KV budget outright (tests force small capacities);
    /// `None` derives it from the machine config minus `weight_bytes`.
    pub hbm_capacity_bytes: Option<u64>,
    /// How KV pressure reclaims pages from residents (DESIGN.md §18).
    pub preempt: PreemptPolicy,
    /// Max preemption cycles per request before it stops being
    /// victim-eligible (bounded preemption — no livelock).
    pub max_preemptions: u32,
}

impl ServeOptions {
    pub fn new(batch: usize, chunk: usize) -> ServeOptions {
        ServeOptions {
            batch,
            chunk: chunk.max(1),
            queue_cap: super::batcher::DEFAULT_QUEUE_CAP,
            deadline_us: None,
            page_bytes: DEFAULT_PAGE_BYTES,
            weight_bytes: 0,
            hbm_capacity_bytes: None,
            preempt: PreemptPolicy::Off,
            max_preemptions: DEFAULT_MAX_PREEMPTIONS,
        }
    }

    pub fn with_queue_cap(mut self, queue_cap: usize) -> ServeOptions {
        self.queue_cap = queue_cap.max(1);
        self
    }

    pub fn with_deadline_us(mut self, deadline_us: u64) -> ServeOptions {
        self.deadline_us = Some(deadline_us);
        self
    }

    pub fn with_page_bytes(mut self, page_bytes: u64) -> ServeOptions {
        self.page_bytes = page_bytes.max(1);
        self
    }

    pub fn with_weight_bytes(mut self, weight_bytes: u64) -> ServeOptions {
        self.weight_bytes = weight_bytes;
        self
    }

    pub fn with_kv_capacity_bytes(mut self, capacity_bytes: u64) -> ServeOptions {
        self.hbm_capacity_bytes = Some(capacity_bytes);
        self
    }

    pub fn with_preempt(mut self, preempt: PreemptPolicy) -> ServeOptions {
        self.preempt = preempt;
        self
    }

    pub fn with_max_preemptions(mut self, max_preemptions: u32) -> ServeOptions {
        self.max_preemptions = max_preemptions;
        self
    }
}

/// What one continuous-batching serve run produced.
#[derive(Debug)]
pub struct ServeReport {
    /// Terminal result of every request that entered the queue (shed
    /// requests are metrics-only — they never held state).
    pub results: Vec<DecodeResult>,
    /// Virtual clock at drain (µs) — the goodput denominator.
    pub horizon_us: u64,
    /// KV-pager high-water mark (pages).
    pub kv_peak_pages: u64,
    /// KV-pager capacity (pages).
    pub kv_capacity_pages: u64,
    /// Whether the pager drained to zero pages (leak check).
    pub kv_idle: bool,
    /// Preemption cycles this run performed (0 with the policy off).
    pub preempted: u64,
    /// Preempted victims successfully re-seated.
    pub resumed: u64,
    /// Bytes moved across the host link (swap-out + swap-in).
    pub swap_bytes: u64,
    /// Prefill ticks spent re-ingesting preempted prefixes.
    pub recompute_ticks: u64,
}

impl ServeReport {
    /// Terminal-outcome tally: (completed, expired, failed).
    pub fn outcome_counts(&self) -> (usize, usize, usize) {
        let mut counts = (0, 0, 0);
        for r in &self.results {
            match r.outcome {
                Outcome::Completed => counts.0 += 1,
                Outcome::Expired => counts.1 += 1,
                Outcome::Failed => counts.2 += 1,
            }
        }
        counts
    }
}

impl crate::analysis::report::Report for ServeReport {
    fn render(&self) -> String {
        let mut out = format!(
            "kv pager: peak {} / {} pages, drained: {}\n",
            self.kv_peak_pages, self.kv_capacity_pages, self.kv_idle
        );
        if self.preempted > 0 {
            out.push_str(&format!(
                "preemption: {} cycles, {} resumed, {} swap bytes, {} recompute ticks\n",
                self.preempted, self.resumed, self.swap_bytes, self.recompute_ticks
            ));
        }
        out
    }

    fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let (completed, expired, failed) = self.outcome_counts();
        Json::obj(vec![
            ("requests", Json::num(self.results.len() as f64)),
            ("completed", Json::num(completed as f64)),
            ("expired", Json::num(expired as f64)),
            ("failed", Json::num(failed as f64)),
            ("horizon_us", Json::num(self.horizon_us as f64)),
            ("kv_peak_pages", Json::num(self.kv_peak_pages as f64)),
            ("kv_capacity_pages", Json::num(self.kv_capacity_pages as f64)),
            ("kv_idle", Json::Bool(self.kv_idle)),
            ("preempted", Json::num(self.preempted as f64)),
            ("resumed", Json::num(self.resumed as f64)),
            ("swap_bytes", Json::num(self.swap_bytes as f64)),
            ("recompute_ticks", Json::num(self.recompute_ticks as f64)),
        ])
    }
}

/// Per-slot state inside the continuous-batching serve loop (owned —
/// a request lives in its slot from refill to terminal outcome).
struct ServeSlot {
    req: DecodeRequest,
    /// Sequence positions already ingested by prefill ticks.
    prefilled: usize,
    /// Positions prefill must ingest before the slot is decode-ready:
    /// `prompt - 1` for a fresh seat, `prompt + generated - 1` for a
    /// recompute resume re-staging its generated prefix.
    prefill_target: usize,
    /// Next KV position to write.
    position: usize,
    /// Token the next decode tick feeds.
    next_input: i32,
    generated: Vec<i32>,
    /// Virtual time of the first generated token.
    first_token_us: Option<u64>,
    /// Ticks (prefill + decode) this slot participated in.
    ticks: usize,
    /// Tick sequence number this slot last participated in — the LRU
    /// coordinate victim selection minimizes over.
    last_tick: u64,
    /// Preemption cycles suffered so far (bounds victim eligibility).
    preempt_count: u32,
    /// True while a recompute resume is re-ingesting prior tokens —
    /// those prefill ticks are the recompute overhead metric.
    recovering: bool,
    outcome: Outcome,
    error: Option<String>,
}

impl ServeSlot {
    /// Sequence positions still to ingest by prefill ticks.  The *final*
    /// staged token is fed by the slot's next decode tick — exactly the
    /// position the group-mode teacher forcing feeds it at, so both
    /// paths produce bit-identical token streams.
    fn prefill_remaining(&self) -> usize {
        self.prefill_target - self.prefilled
    }

    /// Token at sequence position `pos`: the prompt, then the generated
    /// prefix a recompute resume re-ingests (teacher-forcing its own
    /// earlier output, so the resumed stream stays bit-identical).
    fn ingest(&self, pos: usize) -> i32 {
        if pos < self.req.prompt.len() {
            self.req.prompt[pos]
        } else {
            self.generated[pos - self.req.prompt.len()]
        }
    }
}

/// How a parked victim's KV state comes back at resume.
enum ResumeMode {
    /// Re-prefill prompt + generated prefix through the chunk graph.
    Recompute,
    /// Swap the recorded live-page footprint back over the host link.
    Swap { bytes: u64 },
}

/// A preempted request waiting to re-seat.  It holds *no* pager state —
/// preemption dropped both pages and reservation — only the slot
/// snapshot needed to resume, and the cycle number keying its
/// resume-path fault chain.
struct Parked {
    slot: ServeSlot,
    mode: ResumeMode,
    cycle: u64,
}

/// LRU victim pick: the occupied, still-eligible slot least recently
/// scheduled; ties break to the shortest generation (least work lost),
/// then the lowest slot index.  Only decode-phase residents are
/// eligible: a mid-prefill slot has emitted no token yet, so evicting
/// it would push its TTFT out by a whole park/resume cycle while
/// reclaiming pages that cost un-billed prefill work to rebuild.
/// `None` when no resident is eligible (all mid-prefill or out of
/// preemption budget).
fn pick_victim(slots: &[Option<ServeSlot>], max_preemptions: u32) -> Option<usize> {
    let mut best: Option<usize> = None;
    for (i, slot) in slots.iter().enumerate() {
        let Some(s) = slot.as_ref() else { continue };
        if s.preempt_count >= max_preemptions || s.prefill_remaining() > 0 {
            continue;
        }
        let better = match best {
            None => true,
            Some(b) => {
                let bs = slots[b].as_ref().expect("best points at an occupied slot");
                (s.last_tick, s.generated.len()) < (bs.last_tick, bs.generated.len())
            }
        };
        if better {
            best = Some(i);
        }
    }
    best
}

/// Virtual µs to move `bytes` across the host link one way.
fn swap_tick_us(machine: &MachineConfig, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    ((bytes as f64 / machine.host_link_bw / 1_000.0).ceil() as u64).max(1)
}

/// Packed-weight bytes one prefill chunk of width `m` streams through
/// the cache hierarchy — the traffic that displaces decode-pinned
/// residents, driving the churn-fraction repin decay (DESIGN.md §18).
/// Counts the *issued* GEMMs (active experts only on MoE layers), not
/// the resident footprint: only streamed weights churn the pin set.
fn prefill_chunk_weight_bytes(cfg: &DecodeConfig, m: usize) -> u64 {
    DecodeLayer::from_decode_config(cfg, m)
        .gemm_nodes()
        .iter()
        .map(|node| node.count as u64 * node.problem.packed_weight_bytes())
        .sum()
}

/// Release the slot's KV pages, record its terminal outcome, and emit
/// its result (virtual-clock latencies, in seconds for the shared
/// [`DecodeResult`] fields).
fn finalize_serve_slot(
    metrics: &Metrics,
    pager: &mut KvPager,
    slot: ServeSlot,
    now_us: u64,
) -> DecodeResult {
    pager.release(slot.req.id);
    finalize_unpaged(metrics, slot, now_us)
}

/// Terminal accounting for a slot the pager holds nothing for — parked
/// victims (preemption already dropped pages and reservation) that
/// expire or fail on the resume path.  Calling [`finalize_serve_slot`]
/// on one would panic releasing an unknown sequence.
fn finalize_unpaged(metrics: &Metrics, slot: ServeSlot, now_us: u64) -> DecodeResult {
    let enqueued_us = slot.req.enqueued_at_us.unwrap_or(0);
    let ttft_s = slot
        .first_token_us
        .map(|t| t.saturating_sub(enqueued_us) as f64 / 1e6)
        .unwrap_or(0.0);
    let total_s = now_us.saturating_sub(enqueued_us) as f64 / 1e6;
    match slot.outcome {
        Outcome::Completed => metrics.record_completion(slot.generated.len(), ttft_s, total_s),
        Outcome::Expired => metrics.record_expired(1),
        Outcome::Failed => metrics.record_failed(1),
    }
    DecodeResult {
        id: slot.req.id,
        tokens: slot.generated,
        ttft_s,
        total_s,
        steps: slot.ticks,
        outcome: slot.outcome,
        error: slot.error,
    }
}

/// Analytic vector-pass cost (ns) of one causal prefill chunk: every
/// non-GEMM node of the chunk graph priced by the vecpass bandwidth
/// model — the same pricing `StepSim::prefill` charges them.
pub fn prefill_vector_ns(machine: &MachineConfig, step: &PrefillStep) -> f64 {
    step.nodes()
        .iter()
        .map(|node| match node {
            StepNode::Vector(op) => {
                vecpass::price_pass(machine, op.elems, op.ops_per_elem, op.hbm_bytes, op.l2_bytes)
                    .total_ns
            }
            StepNode::Gemm(_) => 0.0,
        })
        .sum()
}

/// Serving-loop knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Retry policy for group decode steps (injected or real failures).
    pub retry: RetryPolicy,
    /// Virtual step cost when no plan prices the group (µs).
    pub default_step_us: u64,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { retry: RetryPolicy::default(), default_step_us: DEFAULT_STEP_US }
    }
}

/// Per-slot decode state inside a running group.
struct Slot<'r> {
    req: &'r DecodeRequest,
    /// Next position to write in the KV cache.
    position: usize,
    /// Token to feed next step.
    next_input: i32,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
    done: bool,
    /// Final outcome once `done` (starts `Completed`; expiry/failure
    /// overwrite it).
    outcome: Outcome,
    error: Option<String>,
}

/// The decode server for one model.
pub struct Server<'rt> {
    pub router: Router<'rt>,
    pub batcher: Batcher,
    pub metrics: Metrics,
    pub config: ServerConfig,
    faults: Option<FaultPlan>,
    /// Jitter source for retry backoff — seeded, so runs are replayable.
    rng: Rng,
    /// Virtual time (µs): advances by predicted step cost, straggler
    /// penalties and retry backoff.  Drives deadlines and max-wait.
    clock_us: u64,
    /// Groups started so far — the fault plan's group coordinate.
    groups_started: u64,
}

impl<'rt> Server<'rt> {
    pub fn new(router: Router<'rt>, batcher: Batcher) -> Server<'rt> {
        Server {
            router,
            batcher,
            metrics: Metrics::new(),
            config: ServerConfig::default(),
            faults: None,
            rng: Rng::new(0x5eed),
            clock_us: 0,
            groups_started: 0,
        }
    }

    pub fn with_config(mut self, config: ServerConfig) -> Server<'rt> {
        self.config = config;
        self
    }

    /// Arm (or disarm) deterministic fault injection.
    pub fn set_faults(&mut self, faults: Option<FaultPlan>) {
        self.faults = faults;
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Server<'rt> {
        self.faults = Some(faults);
        self
    }

    /// Current virtual time (µs).
    pub fn now_us(&self) -> u64 {
        self.clock_us
    }

    /// Advance the virtual clock (e.g. to model arrival gaps between
    /// bursts, or to let a max-wait window elapse in tests).
    pub fn advance_clock(&mut self, us: u64) {
        self.clock_us = self.clock_us.saturating_add(us);
    }

    /// Offer a request to the bounded queue.  Every offered request is
    /// counted as admitted traffic; a shed one is typed backpressure,
    /// not an error, and is accounted under the shed outcome.
    pub fn submit(&mut self, mut req: DecodeRequest) -> Admission {
        req.arrived = Some(Instant::now());
        self.metrics.record_admitted();
        let admission = self.batcher.push(req, self.clock_us);
        if let Admission::Shed { .. } = admission {
            self.metrics.record_shed(1);
        }
        admission
    }

    /// Serve until the queue is empty; returns a result for every queued
    /// request.  Group failures mark their members [`Outcome::Failed`] —
    /// they never abort the drain.
    pub fn drain(&mut self) -> anyhow::Result<Vec<DecodeResult>> {
        let mut results = Vec::new();
        loop {
            results.extend(self.expire_queued());
            match self.batcher.form_group(true, self.clock_us) {
                Some(group) => results.extend(self.run_group(group)),
                None => break,
            }
        }
        Ok(results)
    }

    /// Serve exactly one group if the policy forms one at the current
    /// virtual time (`drain=true` forces formation below target fill).
    pub fn serve_one(&mut self, drain: bool) -> anyhow::Result<Vec<DecodeResult>> {
        let mut results = self.expire_queued();
        if let Some(group) = self.batcher.form_group(drain, self.clock_us) {
            results.extend(self.run_group(group));
        }
        Ok(results)
    }

    /// Drop queued requests whose deadline has already passed — they
    /// must not occupy (or pad) an engine slot.
    fn expire_queued(&mut self) -> Vec<DecodeResult> {
        let now = Instant::now();
        self.batcher
            .expire(self.clock_us)
            .into_iter()
            .map(|req| {
                self.metrics.record_expired(1);
                DecodeResult {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft_s: 0.0,
                    total_s: req
                        .arrived
                        .map(|a| now.duration_since(a).as_secs_f64())
                        .unwrap_or(0.0),
                    steps: 0,
                    outcome: Outcome::Expired,
                    error: None,
                }
            })
            .collect()
    }

    /// Fail every member of a group (engine could not be built/reset).
    fn fail_group(&self, group: &DecodeGroup, error: &str) -> Vec<DecodeResult> {
        let now = Instant::now();
        group
            .members
            .iter()
            .map(|req| {
                self.metrics.record_failed(1);
                DecodeResult {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft_s: 0.0,
                    total_s: req
                        .arrived
                        .map(|a| now.duration_since(a).as_secs_f64())
                        .unwrap_or(0.0),
                    steps: 0,
                    outcome: Outcome::Failed,
                    error: Some(error.to_string()),
                }
            })
            .collect()
    }

    /// Record which tuned schedule serves each GEMM node of a routed
    /// group — the dense projections or the MoE expert fan-out, with its
    /// per-kind expert counts; the down-projection (the paper's
    /// bottleneck; the expert down-projection on MoE models) doubles as
    /// the group's headline schedule counter.
    pub fn record_group_schedules(metrics: &Metrics, plan: Option<&LayerPlan>) {
        match plan {
            Some(p) => {
                for node in &p.nodes {
                    let label = node.plan.map(|t| t.strategy.name()).unwrap_or("untuned");
                    metrics.record_gemm_schedule_n(
                        node.kind.name(),
                        label,
                        node.plan.map(|t| t.predicted_ns * node.count as f64),
                        node.count as u64,
                    );
                }
            }
            None => {
                for kind in GemmKind::all() {
                    metrics.record_gemm_schedule(kind.name(), "untuned", None);
                }
            }
        }
        let headline = plan
            .and_then(|p| p.headline())
            .map(|p| p.strategy.name())
            .unwrap_or("untuned");
        metrics.record_schedule(headline);
    }

    /// Decode one group to completion.  Infallible by design: engine or
    /// step failures convert into per-member [`Outcome::Failed`] results.
    fn run_group(&mut self, group: DecodeGroup) -> Vec<DecodeResult> {
        let group_seq = self.groups_started;
        self.groups_started += 1;
        // Route down the degradation ladder: which kernel schedules
        // serve this group's decode-layer GEMMs, and which rung supplied
        // them (warm cache, inline re-tune, or the splitk default).
        let routed = self.router.route(group.batch);
        self.metrics
            .record_route(routed.outcome.rung.name(), routed.outcome.reason.name());
        let plan = routed.plan;
        Server::record_group_schedules(&self.metrics, plan.as_ref());
        // The plan's predicted cross-node gains (overlap + residency),
        // cache-only — the predicted-overlap column of the metrics report.
        if let Some(p) = plan.as_ref() {
            self.metrics.record_group_plan(group.batch, p.overlap_gain_ns, p.residency_gain_ns);
        }
        // What one decode step costs on the virtual clock: the routed
        // plan's best prediction (resident <= overlapped <= layer), or
        // the configured default when the group is unpriced.
        let step_us = plan
            .as_ref()
            .and_then(|p| p.predicted_served_ns())
            .map(|ns| ((ns / 1_000.0).ceil() as u64).max(1))
            .unwrap_or(self.config.default_step_us);

        if let Err(e) = self.router.engine(group.batch).and_then(|eng| eng.reset()) {
            return self.fail_group(&group, &format!("engine unavailable: {e:#}"));
        }
        let engine = self.router.engine(group.batch).expect("engine just built");
        let vocab = engine.vocab();
        let max_seq = engine.max_seq();

        // Invalid members fail at admission-to-group time (their slot is
        // born done); the rest of the group still decodes.
        let mut slots: Vec<Slot> = group
            .members
            .iter()
            .map(|req| {
                let (done, outcome, error) = match req.validate(vocab, max_seq) {
                    Ok(()) => (false, Outcome::Completed, None),
                    Err(e) => (true, Outcome::Failed, Some(format!("invalid request: {e:#}"))),
                };
                Slot {
                    req,
                    position: 0,
                    next_input: req.prompt.first().copied().unwrap_or(0),
                    generated: Vec::new(),
                    first_token_at: None,
                    done,
                    outcome,
                    error,
                }
            })
            .collect();

        let mut steps = 0usize;
        'group: while slots.iter().any(|s| !s.done) {
            // Deadlines are checked between steps on the virtual clock:
            // an expired slot stops consuming steps and keeps its
            // partial generation.
            for slot in slots.iter_mut() {
                if !slot.done && slot.req.expired(self.clock_us) {
                    slot.done = true;
                    slot.outcome = Outcome::Expired;
                }
            }
            if slots.iter().all(|s| s.done) {
                break;
            }
            // Assemble the step: idle/finished/padding slots replay token 0
            // at their last written position (harmless rewrite).
            let mut tokens = vec![0i32; group.batch];
            let mut positions = vec![0i32; group.batch];
            for (i, slot) in slots.iter().enumerate() {
                tokens[i] = if slot.done { 0 } else { slot.next_input };
                positions[i] = slot.position as i32;
            }
            // Execute the step under the fault plan + retry policy.  A
            // straggler lands late but correct; an injected engine/client
            // error is retried with (virtual) exponential backoff.  The
            // fault plan is keyed on (group, step, attempt), so a retry
            // re-rolls its fate deterministically.
            let mut attempt = 0u32;
            let out = loop {
                let fault = self
                    .faults
                    .as_ref()
                    .and_then(|f| f.step_fault(group_seq, steps as u64, attempt));
                let step_res = match fault {
                    Some(FaultKind::Straggler { mult_x100 }) => {
                        self.metrics.record_fault("straggler");
                        // Round UP with a >=1µs floor: flooring division
                        // charged zero for sub-µs steps (a 1µs step with a
                        // 1.5x straggler injected nothing), silently
                        // understating chaos-bench latency.
                        let penalty = step_us
                            .saturating_mul(mult_x100.saturating_sub(100) as u64)
                            .div_ceil(100)
                            .max(1);
                        self.metrics.record_straggler_penalty_us(penalty);
                        self.clock_us = self.clock_us.saturating_add(penalty);
                        engine.step(&tokens, &positions)
                    }
                    Some(kind) => {
                        self.metrics.record_fault(kind.name());
                        Err(anyhow::anyhow!(
                            "injected {} (group {group_seq}, step {steps}, attempt {attempt})",
                            kind.name()
                        ))
                    }
                    None => engine.step(&tokens, &positions),
                };
                match step_res {
                    Ok(out) => break out,
                    Err(e) => {
                        if attempt + 1 >= self.config.retry.max_attempts.max(1) {
                            // Retries exhausted: fail the group's
                            // unfinished members, keep the server alive.
                            let msg = format!(
                                "step {steps} failed after {} attempts: {e:#}",
                                attempt + 1
                            );
                            for slot in slots.iter_mut().filter(|s| !s.done) {
                                slot.done = true;
                                slot.outcome = Outcome::Failed;
                                slot.error = Some(msg.clone());
                            }
                            break 'group;
                        }
                        self.metrics.record_retry();
                        let backoff = self.config.retry.backoff_us(attempt, &mut self.rng);
                        self.clock_us = self.clock_us.saturating_add(backoff);
                        attempt += 1;
                    }
                }
            };
            steps += 1;
            self.clock_us = self.clock_us.saturating_add(step_us);
            // Feed the batcher's recent-step-time window so shed hints
            // scale with how fast the queue actually drains.
            self.batcher.note_step_time(step_us);
            // Sub-step stragglers (DESIGN.md §18): a member fault lands
            // one slot late, serializing only that slot's share of the
            // step tail — the group neither waits a full step nor fails.
            // Keyed on the same step coordinate as the whole-step chain.
            if self.faults.is_some() {
                for (i, slot) in slots.iter().enumerate() {
                    if slot.done {
                        continue;
                    }
                    let hit = self
                        .faults
                        .as_ref()
                        .and_then(|f| f.member_fault(group_seq, (steps - 1) as u64, i as u64));
                    if let Some(mult_x100) = hit {
                        let penalty = member_tail_penalty_us(step_us, group.batch, mult_x100);
                        self.metrics.record_fault(MEMBER_FAULT_NAME);
                        self.metrics.record_straggler_penalty_us(penalty);
                        self.clock_us = self.clock_us.saturating_add(penalty);
                    }
                }
            }

            for (i, slot) in slots.iter_mut().enumerate() {
                if slot.done {
                    continue;
                }
                let produced = out.next_tokens[i];
                slot.position += 1;
                if slot.position < slot.req.prompt.len() {
                    // Still ingesting the prompt (teacher forcing).
                    slot.next_input = slot.req.prompt[slot.position];
                } else {
                    // Generating.
                    if slot.first_token_at.is_none() {
                        slot.first_token_at = Some(Instant::now());
                    }
                    slot.generated.push(produced);
                    slot.next_input = produced;
                    if slot.generated.len() >= slot.req.max_new_tokens
                        || slot.position + 1 >= max_seq
                    {
                        slot.done = true;
                    }
                }
            }
        }

        self.metrics.record_group(group.batch, group.occupancy(), steps);
        let now = Instant::now();
        slots
            .into_iter()
            .map(|slot| {
                let arrived = slot.req.arrived.unwrap_or(now);
                let ttft = slot
                    .first_token_at
                    .map(|t| t.duration_since(arrived).as_secs_f64())
                    .unwrap_or(0.0);
                let total = now.duration_since(arrived).as_secs_f64();
                match slot.outcome {
                    Outcome::Completed => {
                        self.metrics.record_completion(slot.generated.len(), ttft, total)
                    }
                    Outcome::Expired => self.metrics.record_expired(1),
                    Outcome::Failed => self.metrics.record_failed(1),
                }
                DecodeResult {
                    id: slot.req.id,
                    tokens: slot.generated,
                    ttft_s: ttft,
                    total_s: total,
                    steps,
                    outcome: slot.outcome,
                    error: slot.error,
                }
            })
            .collect()
    }

    /// Virtual cost (µs) of one prefill tick: the routed chunk plan's
    /// GEMM prediction (same degradation ladder and tune cache as
    /// decode) plus the analytic vector passes of the causal chunk graph
    /// at this KV depth.  Falls back to the configured default step cost
    /// when the chunk GEMMs are unpriced.
    fn prefill_tick_us(
        &mut self,
        cfg: &DecodeConfig,
        machine: &MachineConfig,
        m: usize,
        kv_base: usize,
        seen_chunks: &mut std::collections::BTreeSet<usize>,
    ) -> u64 {
        let routed = self.router.route_prefill(m);
        if seen_chunks.insert(m) {
            self.metrics.record_route(routed.outcome.rung.name(), routed.outcome.reason.name());
        }
        let layer = DecodeLayer::from_decode_config(cfg, m);
        let step = PrefillStep::new(layer, kv_base, cfg.heads.max(1));
        let vector_ns = prefill_vector_ns(machine, &step);
        match routed.plan.as_ref().and_then(|p| p.predicted_served_ns()) {
            Some(gemm_ns) => (((gemm_ns + vector_ns) / 1_000.0).ceil() as u64).max(1),
            None => self.config.default_step_us,
        }
    }

    /// Price the recompute recovery path: the exact virtual cost of
    /// re-prefilling `resident_tokens` (prompt + generated prefix), in
    /// the same chunk schedule the resumed slot will actually run — so
    /// the `auto` policy compares the true future bill, not an estimate.
    fn price_recompute_us(
        &mut self,
        cfg: &DecodeConfig,
        machine: &MachineConfig,
        resident_tokens: usize,
        chunk: usize,
        seen_chunks: &mut std::collections::BTreeSet<usize>,
    ) -> u64 {
        let target = resident_tokens.saturating_sub(1);
        let mut done = 0usize;
        let mut total = 0u64;
        while done < target {
            let m = (target - done).min(chunk.max(1));
            total = total.saturating_add(self.prefill_tick_us(cfg, machine, m, done, seen_chunks));
            done += m;
        }
        total
    }

    /// Evict one LRU victim to relieve KV pressure (DESIGN.md §18):
    /// free its pages *and* reservation, pick the recovery path per the
    /// policy (`auto` prices swap round-trip vs. exact re-prefill), and
    /// park it on the resume queue.  Swap-out is charged to the virtual
    /// clock here; swap-in at the re-seat.  Returns `false` when no slot
    /// is victim-eligible (all have exhausted their preemption budget).
    fn preempt_victim(
        &mut self,
        slots: &mut [Option<ServeSlot>],
        pager: &mut KvPager,
        parked: &mut Vec<Parked>,
        opts: &ServeOptions,
        cfg: &DecodeConfig,
        seen_chunks: &mut std::collections::BTreeSet<usize>,
    ) -> bool {
        let Some(idx) = pick_victim(slots, opts.max_preemptions) else {
            return false;
        };
        let mut s = slots[idx].take().expect("victim slot is occupied");
        let (_pages, bytes) = pager.preempt(s.req.id);
        s.preempt_count += 1;
        let cycle = s.preempt_count as u64;
        let machine = self.router.machine().clone();
        let swap_one_way_us = swap_tick_us(&machine, bytes);
        let mode = match opts.preempt {
            PreemptPolicy::Recompute => ResumeMode::Recompute,
            PreemptPolicy::Swap => ResumeMode::Swap { bytes },
            PreemptPolicy::Auto => {
                let resident = s.req.prompt.len() + s.generated.len();
                let recompute_us =
                    self.price_recompute_us(cfg, &machine, resident, opts.chunk, seen_chunks);
                // Swap pays the host link twice: out now, in at resume.
                if swap_one_way_us.saturating_mul(2) <= recompute_us {
                    ResumeMode::Swap { bytes }
                } else {
                    ResumeMode::Recompute
                }
            }
            PreemptPolicy::Off => unreachable!("preempt_victim is never called with the policy off"),
        };
        match mode {
            ResumeMode::Recompute => {
                // Rewind to position zero; the generated prefix is kept
                // and re-ingested by teacher-forced prefill ticks, so
                // the resumed stream is bit-identical (§18).
                s.recovering = true;
                s.prefill_target = (s.req.prompt.len() + s.generated.len()).saturating_sub(1);
                s.prefilled = 0;
                s.position = 0;
                s.next_input = s.req.prompt.first().copied().unwrap_or(0);
                self.metrics.record_preempted(false);
            }
            ResumeMode::Swap { bytes } => {
                self.clock_us = self.clock_us.saturating_add(swap_one_way_us);
                self.metrics.record_swap(bytes, swap_one_way_us);
                self.metrics.record_preempted(true);
            }
        }
        parked.push(Parked { slot: s, mode, cycle });
        true
    }

    /// Continuous-batching serve loop (DESIGN.md §15): admit the arrival
    /// plan onto the virtual clock, interleave chunked prefill against
    /// in-flight decode on one fixed-batch engine, page the KV cache
    /// against the HBM budget, and drain to completion.
    ///
    /// Every offered request ends in exactly one terminal account — the
    /// §14 conservation law extends to the serve path with a typed shed
    /// breakdown (`queue_full`, `kv_capacity`, `admission_fault`) — and
    /// the pager provably drains: the report carries its high-water mark
    /// and a leak check.  The loop itself only errors when the engine
    /// cannot be built at all.
    pub fn serve_load(
        &mut self,
        plan: &ArrivalPlan,
        opts: &ServeOptions,
    ) -> anyhow::Result<ServeReport> {
        anyhow::ensure!(opts.batch >= 1, "serve batch must be >= 1");
        // Metrics accumulate across a server's lifetime; the report
        // carries this run's preemption activity as a delta.
        let base = self.metrics.snapshot();
        let machine = self.router.machine().clone();
        let cfg = self
            .router
            .first_decode_config()
            .ok_or_else(|| anyhow::anyhow!("serve-load needs a decode config"))?;
        self.batcher.policy.queue_cap = opts.queue_cap.max(1);
        let bytes_per_token = kv_bytes_per_token(cfg.layers.max(1), cfg.hidden.max(1));
        let mut pager = match opts.hbm_capacity_bytes {
            Some(capacity) => KvPager::new(opts.page_bytes, capacity),
            None => KvPager::for_machine(&machine, opts.weight_bytes, opts.page_bytes),
        };

        self.router.engine(opts.batch).and_then(|e| e.reset())?;
        let (vocab, max_seq) = {
            let engine = self.router.engine(opts.batch)?;
            (engine.vocab(), engine.max_seq())
        };

        // Route the decode batch once; the plan prices every decode tick.
        let routed = self.router.route(opts.batch);
        self.metrics.record_route(routed.outcome.rung.name(), routed.outcome.reason.name());
        Server::record_group_schedules(&self.metrics, routed.plan.as_ref());
        if let Some(p) = routed.plan.as_ref() {
            self.metrics.record_group_plan(opts.batch, p.overlap_gain_ns, p.residency_gain_ns);
        }
        let decode_step_us = routed
            .plan
            .as_ref()
            .and_then(|p| p.predicted_served_ns())
            .map(|ns| ((ns / 1_000.0).ceil() as u64).max(1))
            .unwrap_or(self.config.default_step_us);
        // The decode-steady residency pins a prefill burst invalidates:
        // the first decode tick after prefill traffic re-streams the
        // fraction the burst actually churned (LRU half-life, §18).
        let pinned_bytes =
            routed.plan.as_ref().and_then(|p| p.residency_pinned_bytes).unwrap_or(0);
        let group_seq = self.groups_started;
        self.groups_started += 1;

        let mut slots: Vec<Option<ServeSlot>> = (0..opts.batch).map(|_| None).collect();
        let mut parked: Vec<Parked> = Vec::new();
        let mut results: Vec<DecodeResult> = Vec::new();
        let mut seen_chunks = std::collections::BTreeSet::new();
        let mut next_arrival = 0usize;
        // Pinned bytes displaced by prefill traffic since the last
        // decode tick — prices the next repin at the churned fraction.
        let mut evicted_bytes = 0u64;
        let mut last_was_prefill = false;
        let mut decode_ticks = 0u64;
        // Global scheduling sequence (prefill + decode ticks) — the LRU
        // clock victim selection reads.
        let mut tick_seq = 0u64;

        loop {
            // Credit the router's re-tune token bucket (DESIGN.md §15).
            self.router.advance_clock(self.clock_us);

            // 1. Admit every arrival due at the current virtual time.
            while next_arrival < plan.arrivals.len()
                && plan.arrivals[next_arrival].at_us <= self.clock_us
            {
                let a = plan.arrivals[next_arrival];
                let id = next_arrival as u64;
                next_arrival += 1;
                self.metrics.record_admitted();
                if self.faults.as_ref().map(|f| f.admission_fault(id)).unwrap_or(false) {
                    self.metrics.record_fault(ADMISSION_FAULT_NAME);
                    self.metrics.record_shed_reason(ADMISSION_FAULT_NAME);
                    continue;
                }
                let prompt: Vec<i32> = (0..a.prompt_len)
                    .map(|p| crate::workload::prompt_token(id, p, vocab))
                    .collect();
                let mut req = DecodeRequest::new(id, prompt, a.max_new_tokens);
                req.deadline_us = opts.deadline_us;
                req.enqueued_at_us = Some(a.at_us);
                if let Err(e) = req.validate(vocab, max_seq) {
                    self.metrics.record_failed(1);
                    results.push(DecodeResult {
                        id,
                        tokens: Vec::new(),
                        ttft_s: 0.0,
                        total_s: 0.0,
                        steps: 0,
                        outcome: Outcome::Failed,
                        error: Some(format!("invalid request: {e:#}")),
                    });
                    continue;
                }
                if self.batcher.waiting() >= self.batcher.policy.queue_cap {
                    self.metrics.record_shed_reason("queue_full");
                    continue;
                }
                // Conservative KV admission: reserve the worst case now
                // so per-token growth can never fail mid-flight.  Under
                // pressure the preemption policy evicts LRU victims
                // until the reservation fits; only when no eligible
                // victim remains (or the request could never fit even
                // on an empty pager) does the arrival shed, carrying
                // the expected-next-page-release retry hint.
                if !pager.try_admit(id, a.prompt_len, a.max_new_tokens, bytes_per_token) {
                    let worst = pager.pages_for(a.prompt_len + a.max_new_tokens, bytes_per_token);
                    let mut admitted = false;
                    if opts.preempt != PreemptPolicy::Off && worst <= pager.capacity_pages() {
                        while self.preempt_victim(
                            &mut slots,
                            &mut pager,
                            &mut parked,
                            opts,
                            &cfg,
                            &mut seen_chunks,
                        ) {
                            if pager.try_admit(id, a.prompt_len, a.max_new_tokens, bytes_per_token)
                            {
                                admitted = true;
                                break;
                            }
                        }
                    }
                    if !admitted {
                        let min_remaining = slots
                            .iter()
                            .flatten()
                            .map(|s| s.req.max_new_tokens.saturating_sub(s.generated.len()) as u64)
                            .min();
                        let hint = self.batcher.kv_retry_after_us(min_remaining);
                        self.metrics.record_shed_reason_with_hint("kv_capacity", hint);
                        continue;
                    }
                }
                let admission = self.batcher.push(req, self.clock_us);
                debug_assert_eq!(admission, Admission::Admitted);
            }

            // 2. Expired queued requests release their KV reservations.
            for req in self.batcher.expire(self.clock_us) {
                pager.release(req.id);
                let enqueued_us = req.enqueued_at_us.unwrap_or(0);
                self.metrics.record_expired(1);
                results.push(DecodeResult {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft_s: 0.0,
                    total_s: self.clock_us.saturating_sub(enqueued_us) as f64 / 1e6,
                    steps: 0,
                    outcome: Outcome::Expired,
                    error: None,
                });
            }

            // 3. Mid-flight deadline expiry: the slot keeps its partial
            // generation and frees its pages.
            for slot in slots.iter_mut() {
                if slot.as_ref().map(|s| s.req.expired(self.clock_us)).unwrap_or(false) {
                    let mut s = slot.take().unwrap();
                    s.outcome = Outcome::Expired;
                    results.push(finalize_serve_slot(&self.metrics, &mut pager, s, self.clock_us));
                }
            }
            // Parked victims expire too: they hold no pages, but their
            // deadline keeps running — a preemption that never resumes
            // is a lost cycle in the preemption conservation law.
            let mut pi = 0;
            while pi < parked.len() {
                if parked[pi].slot.req.expired(self.clock_us) {
                    let mut s = parked.remove(pi).slot;
                    s.outcome = Outcome::Expired;
                    self.metrics.record_preempt_failed();
                    results.push(finalize_unpaged(&self.metrics, s, self.clock_us));
                } else {
                    pi += 1;
                }
            }

            // 3b. Anti-starvation: every slot busy and the queue head
            // has out-waited the batching window — preempt one victim
            // and seat the head (which already holds its KV
            // reservation) directly into the freed slot.  The direct
            // seat matters: the refill phase prefers the resume queue,
            // so under light KV pressure the victim would instantly
            // reclaim its own slot and the head would starve forever.
            // Bounded per victim by `max_preemptions` and by victim
            // eligibility (decode-phase only), so pressure can never
            // livelock.
            if opts.preempt != PreemptPolicy::Off
                && slots.iter().all(|s| s.is_some())
                && self
                    .batcher
                    .head_wait_us(self.clock_us)
                    .map(|w| w >= self.batcher.policy.max_wait_us)
                    .unwrap_or(false)
                && self.preempt_victim(
                    &mut slots,
                    &mut pager,
                    &mut parked,
                    opts,
                    &cfg,
                    &mut seen_chunks,
                )
            {
                let req = self.batcher.pop_next().expect("a starved head is queued");
                let next_input = req.prompt.first().copied().unwrap_or(0);
                let prefill_target = req.prompt.len().saturating_sub(1);
                let idx = slots
                    .iter()
                    .position(|s| s.is_none())
                    .expect("preempt_victim freed a slot");
                slots[idx] = Some(ServeSlot {
                    req,
                    prefilled: 0,
                    prefill_target,
                    position: 0,
                    next_input,
                    generated: Vec::new(),
                    first_token_us: None,
                    ticks: 0,
                    last_tick: tick_seq,
                    preempt_count: 0,
                    recovering: false,
                    outcome: Outcome::Completed,
                    error: None,
                });
            }

            // 4. Refill free slots: the resume queue seats ahead of new
            // arrivals, first-fit FIFO — a victim that cannot
            // re-reserve yet never blocks one that can, nor fresh work
            // (whose reservations it could not claim anyway).
            'refill: for slot in slots.iter_mut() {
                if slot.is_some() {
                    continue;
                }
                let mut pi = 0;
                while pi < parked.len() {
                    let id = parked[pi].slot.req.id;
                    let cycle = parked[pi].cycle;
                    // The resume path has its own fault surface, keyed
                    // (request, cycle): a recompute that faults lost its
                    // recomputation; a swap that faults lost its pages.
                    let fault_name = match parked[pi].mode {
                        ResumeMode::Recompute => self
                            .faults
                            .as_ref()
                            .map(|f| f.preempt_fault(id, cycle))
                            .unwrap_or(false)
                            .then_some(PREEMPT_FAULT_NAME),
                        ResumeMode::Swap { .. } => self
                            .faults
                            .as_ref()
                            .map(|f| f.swap_fault(id, cycle))
                            .unwrap_or(false)
                            .then_some(SWAP_FAULT_NAME),
                    };
                    if let Some(name) = fault_name {
                        let mut s = parked.remove(pi).slot;
                        self.metrics.record_fault(name);
                        self.metrics.record_preempt_failed();
                        s.outcome = Outcome::Failed;
                        s.error = Some(format!("injected {name} (request {id}, cycle {cycle})"));
                        results.push(finalize_unpaged(&self.metrics, s, self.clock_us));
                        continue;
                    }
                    let (resident, remaining) = {
                        let s = &parked[pi].slot;
                        (
                            s.req.prompt.len() + s.generated.len(),
                            s.req.max_new_tokens.saturating_sub(s.generated.len()),
                        )
                    };
                    // resident + remaining == prompt + max_new: the
                    // resume re-reserves exactly the original worst
                    // case, so a sequence that fit once always fits
                    // again once the pager drains.
                    if pager.try_resume(id, resident, remaining, bytes_per_token) {
                        let p = parked.remove(pi);
                        if let ResumeMode::Swap { bytes } = p.mode {
                            let swap_in_us = swap_tick_us(&machine, bytes);
                            self.clock_us = self.clock_us.saturating_add(swap_in_us);
                            self.metrics.record_swap(bytes, swap_in_us);
                        }
                        self.metrics.record_resumed();
                        let mut s = p.slot;
                        s.last_tick = tick_seq;
                        *slot = Some(s);
                        continue 'refill;
                    }
                    pi += 1;
                }
                match self.batcher.pop_next() {
                    Some(req) => {
                        let next_input = req.prompt.first().copied().unwrap_or(0);
                        let prefill_target = req.prompt.len().saturating_sub(1);
                        *slot = Some(ServeSlot {
                            req,
                            prefilled: 0,
                            prefill_target,
                            position: 0,
                            next_input,
                            generated: Vec::new(),
                            first_token_us: None,
                            ticks: 0,
                            last_tick: tick_seq,
                            preempt_count: 0,
                            recovering: false,
                            outcome: Outcome::Completed,
                            error: None,
                        });
                    }
                    None => break,
                }
            }

            // 5. Idle: jump to the next arrival, or drain out.  A
            // non-empty resume queue with every slot idle cannot happen:
            // an empty pager (no slots, no queue) always re-admits.
            if slots.iter().all(|s| s.is_none()) {
                debug_assert!(parked.is_empty(), "idle slots must have drained the resume queue");
                match plan.arrivals.get(next_arrival) {
                    Some(a) => {
                        self.clock_us = self.clock_us.max(a.at_us);
                        continue;
                    }
                    None => break,
                }
            }

            // 6. One tick.  Prefill and decode alternate strictly when
            // both have work, so a prefill burst can neither starve
            // in-flight decode nor be starved by it.
            let has_prefill = slots.iter().flatten().any(|s| s.prefill_remaining() > 0);
            let has_decode = slots.iter().flatten().any(|s| s.prefill_remaining() == 0);
            if has_prefill && (!has_decode || !last_was_prefill) {
                // Prefill tick: one chunk of the lowest-index slot that
                // still has prompt to ingest.
                let idx = slots
                    .iter()
                    .position(|s| s.as_ref().map(|s| s.prefill_remaining() > 0).unwrap_or(false))
                    .expect("has_prefill implies a prefill slot");
                let (m, kv_base) = {
                    let s = slots[idx].as_ref().unwrap();
                    (s.prefill_remaining().min(opts.chunk.max(1)), s.position)
                };
                let tick_us = self.prefill_tick_us(&cfg, &machine, m, kv_base, &mut seen_chunks);
                self.clock_us = self.clock_us.saturating_add(tick_us);
                tick_seq += 1;
                // The chunk's streamed weights displace pinned decode
                // residents; the next decode tick repins only what this
                // burst actually churned (capped at the pinned set).
                evicted_bytes = evicted_bytes
                    .saturating_add(prefill_chunk_weight_bytes(&cfg, m))
                    .min(pinned_bytes);
                let s = slots[idx].as_mut().unwrap();
                s.prefilled += m;
                s.position += m;
                s.next_input = s.ingest(s.position);
                s.ticks += 1;
                s.last_tick = tick_seq;
                self.metrics.record_prefill_step(m);
                if s.recovering {
                    // Re-ingesting a preempted prefix: the recompute
                    // overhead the §18 telemetry prices.
                    self.metrics.record_recompute_tick(tick_us);
                    if s.prefill_remaining() == 0 {
                        s.recovering = false;
                    }
                }
                last_was_prefill = true;
            } else {
                // Decode tick: every slot whose prompt is fully staged.
                let active: Vec<usize> = slots
                    .iter()
                    .enumerate()
                    .filter(|(_, s)| {
                        s.as_ref().map(|s| s.prefill_remaining() == 0).unwrap_or(false)
                    })
                    .map(|(i, _)| i)
                    .collect();
                let tick_start_us = self.clock_us;
                let tick_no = decode_ticks;
                let mut tokens = vec![0i32; opts.batch];
                let mut positions = vec![0i32; opts.batch];
                for &i in &active {
                    let s = slots[i].as_ref().unwrap();
                    tokens[i] = s.next_input;
                    positions[i] = s.position as i32;
                }
                // Fault + retry loop, keyed (serve session, decode tick,
                // attempt) — same coordinates as the group-mode path.
                let mut attempt = 0u32;
                let step_out = loop {
                    let fault = self
                        .faults
                        .as_ref()
                        .and_then(|f| f.step_fault(group_seq, decode_ticks, attempt));
                    let step_res = match fault {
                        Some(FaultKind::Straggler { mult_x100 }) => {
                            self.metrics.record_fault("straggler");
                            // Same ceil + floor as the group path: every
                            // injected straggler charges at least 1µs.
                            let penalty = decode_step_us
                                .saturating_mul(mult_x100.saturating_sub(100) as u64)
                                .div_ceil(100)
                                .max(1);
                            self.metrics.record_straggler_penalty_us(penalty);
                            self.clock_us = self.clock_us.saturating_add(penalty);
                            self.router
                                .engine(opts.batch)
                                .expect("engine built at serve start")
                                .step(&tokens, &positions)
                        }
                        Some(kind) => {
                            self.metrics.record_fault(kind.name());
                            Err(anyhow::anyhow!(
                                "injected {} (serve {group_seq}, tick {decode_ticks}, \
                                 attempt {attempt})",
                                kind.name()
                            ))
                        }
                        None => self
                            .router
                            .engine(opts.batch)
                            .expect("engine built at serve start")
                            .step(&tokens, &positions),
                    };
                    match step_res {
                        Ok(out) => break Ok(out),
                        Err(e) => {
                            if attempt + 1 >= self.config.retry.max_attempts.max(1) {
                                break Err(format!(
                                    "tick {decode_ticks} failed after {} attempts: {e:#}",
                                    attempt + 1
                                ));
                            }
                            self.metrics.record_retry();
                            let backoff = self.config.retry.backoff_us(attempt, &mut self.rng);
                            self.clock_us = self.clock_us.saturating_add(backoff);
                            attempt += 1;
                        }
                    }
                };
                decode_ticks += 1;
                tick_seq += 1;
                match step_out {
                    Err(msg) => {
                        // Retries exhausted: fail the decode-ready slots
                        // (their step can never land).  Prefill-pending
                        // slots and the queue keep serving — the server
                        // never dies.
                        self.clock_us = self.clock_us.saturating_add(decode_step_us);
                        self.metrics.record_decode_step();
                        self.batcher.note_step_time(decode_step_us);
                        for &i in &active {
                            let mut s = slots[i].take().unwrap();
                            s.outcome = Outcome::Failed;
                            s.error = Some(msg.clone());
                            results.push(finalize_serve_slot(
                                &self.metrics,
                                &mut pager,
                                s,
                                self.clock_us,
                            ));
                        }
                    }
                    Ok(out) => {
                        let mut tick_us = decode_step_us;
                        if evicted_bytes > 0 && pinned_bytes > 0 {
                            // Churn-fraction repin (§18): the surcharge
                            // scales with the pinned bytes the prefill
                            // burst actually displaced, not the whole
                            // pinned set.
                            let repin = repin_decayed_ns(&machine, pinned_bytes, evicted_bytes);
                            if repin > 0.0 {
                                self.metrics.record_repin(repin);
                                tick_us = tick_us
                                    .saturating_add(((repin / 1_000.0).ceil() as u64).max(1));
                            }
                        }
                        evicted_bytes = 0;
                        self.clock_us = self.clock_us.saturating_add(tick_us);
                        self.metrics.record_decode_step();
                        self.batcher.note_step_time(tick_us);
                        // Sub-step stragglers (§18): a member fault
                        // lands one slot late, serializing only that
                        // slot's share of the step tail — charged on
                        // top of the group step, never failing it.
                        if self.faults.is_some() {
                            for &i in &active {
                                let hit = self
                                    .faults
                                    .as_ref()
                                    .and_then(|f| f.member_fault(group_seq, tick_no, i as u64));
                                if let Some(mult_x100) = hit {
                                    let penalty = member_tail_penalty_us(
                                        decode_step_us,
                                        opts.batch,
                                        mult_x100,
                                    );
                                    self.metrics.record_fault(MEMBER_FAULT_NAME);
                                    self.metrics.record_straggler_penalty_us(penalty);
                                    self.clock_us = self.clock_us.saturating_add(penalty);
                                }
                            }
                        }
                        let mut emitted = 0usize;
                        for &i in &active {
                            let produced = out.next_tokens[i];
                            let finished = {
                                let s = slots[i].as_mut().unwrap();
                                s.ticks += 1;
                                s.last_tick = tick_seq;
                                s.position += 1;
                                let token_index = s.generated.len() as u64;
                                let write_fault = self
                                    .faults
                                    .as_ref()
                                    .map(|f| f.cache_write_fault(s.req.id, token_index))
                                    .unwrap_or(false);
                                if write_fault {
                                    self.metrics.record_fault(CACHE_WRITE_FAULT_NAME);
                                    s.outcome = Outcome::Failed;
                                    s.error = Some(format!(
                                        "kv cache write fault at token {token_index}"
                                    ));
                                    true
                                } else {
                                    pager.grow(s.req.id);
                                    emitted += 1;
                                    if s.generated.is_empty() {
                                        s.first_token_us = Some(self.clock_us);
                                        let enqueued_us = s.req.enqueued_at_us.unwrap_or(0);
                                        self.metrics.record_serve_ttft_us(
                                            self.clock_us.saturating_sub(enqueued_us),
                                        );
                                    }
                                    s.generated.push(produced);
                                    s.next_input = produced;
                                    s.generated.len() >= s.req.max_new_tokens
                                        || s.position + 1 >= max_seq
                                }
                            };
                            if finished {
                                let s = slots[i].take().unwrap();
                                results.push(finalize_serve_slot(
                                    &self.metrics,
                                    &mut pager,
                                    s,
                                    self.clock_us,
                                ));
                            }
                        }
                        let gap_us = self.clock_us.saturating_sub(tick_start_us);
                        self.metrics.record_serve_token_gaps_us(gap_us, emitted);
                    }
                }
                last_was_prefill = false;
            }
        }

        self.metrics.set_pager_stats(pager.peak_allocated_pages(), pager.capacity_pages());
        debug_assert!(pager.idle(), "kv pager must drain with the queue");
        let snap = self.metrics.snapshot();
        debug_assert!(
            snap.preemptions_accounted(),
            "every preemption must resolve to a resume or a loss"
        );
        Ok(ServeReport {
            horizon_us: self.clock_us,
            kv_peak_pages: pager.peak_allocated_pages(),
            kv_capacity_pages: pager.capacity_pages(),
            kv_idle: pager.idle(),
            preempted: snap.requests_preempted - base.requests_preempted,
            resumed: snap.requests_resumed - base.requests_resumed,
            swap_bytes: snap.swap_bytes - base.swap_bytes,
            recompute_ticks: snap.recompute_ticks - base.recompute_ticks,
            results,
        })
    }
}

#[cfg(test)]
mod tests {
    // Full server behaviour needs a manifest on disk; the fault-tolerant
    // serving loop is exercised end to end by rust/tests/chaos.rs
    // (synthetic manifests, seeded fault plans), the continuous-batching
    // loop by rust/tests/serve_load.rs (conservation, pager invariants,
    // seed replay), and the real-artifact path by
    // rust/tests/coordinator.rs.
}
