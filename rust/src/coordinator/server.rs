//! The serving loop: queue -> batcher -> router -> decode engine.
//!
//! Group-synchronous iteration batching: the server drains the queue into
//! a fixed-size decode group (padding idle slots), then steps the group's
//! engine until every member has consumed its prompt and produced its
//! generation budget.  Prompt tokens are ingested through the same decode
//! step (teacher-forced positions), so the whole serving path — prefill
//! and decode — runs the W4A16 pipeline under test.
//!
//! Fault tolerance (DESIGN.md §14): the server owns a *virtual clock*
//! (µs) that advances by the routed plan's predicted step time, so
//! deadlines, max-wait batching, stragglers and retry backoff are all
//! deterministic — no wall-clock sleeps anywhere.  An optional seeded
//! [`FaultPlan`] injects stragglers (the step lands late but correct)
//! and transient engine/client errors (the step is retried with
//! exponential backoff under [`RetryPolicy`]).  A group step that
//! exhausts its retries fails only that group's unfinished members —
//! never the server: `drain` always returns a result for every admitted
//! request, each carrying exactly one [`Outcome`].

use std::time::Instant;

use super::batcher::{Admission, Batcher, DecodeGroup};
use super::faults::{FaultKind, FaultPlan};
use super::metrics::Metrics;
use super::request::{DecodeRequest, DecodeResult, Outcome};
use super::router::{LayerPlan, Router};
use crate::runtime::RetryPolicy;
use crate::util::prng::Rng;
use crate::workload::decode_layer::GemmKind;

/// Virtual step cost when the routed plan carries no prediction (µs).
pub const DEFAULT_STEP_US: u64 = 1_000;

/// Serving-loop knobs.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Retry policy for group decode steps (injected or real failures).
    pub retry: RetryPolicy,
    /// Virtual step cost when no plan prices the group (µs).
    pub default_step_us: u64,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig { retry: RetryPolicy::default(), default_step_us: DEFAULT_STEP_US }
    }
}

/// Per-slot decode state inside a running group.
struct Slot<'r> {
    req: &'r DecodeRequest,
    /// Next position to write in the KV cache.
    position: usize,
    /// Token to feed next step.
    next_input: i32,
    generated: Vec<i32>,
    first_token_at: Option<Instant>,
    done: bool,
    /// Final outcome once `done` (starts `Completed`; expiry/failure
    /// overwrite it).
    outcome: Outcome,
    error: Option<String>,
}

/// The decode server for one model.
pub struct Server<'rt> {
    pub router: Router<'rt>,
    pub batcher: Batcher,
    pub metrics: Metrics,
    pub config: ServerConfig,
    faults: Option<FaultPlan>,
    /// Jitter source for retry backoff — seeded, so runs are replayable.
    rng: Rng,
    /// Virtual time (µs): advances by predicted step cost, straggler
    /// penalties and retry backoff.  Drives deadlines and max-wait.
    clock_us: u64,
    /// Groups started so far — the fault plan's group coordinate.
    groups_started: u64,
}

impl<'rt> Server<'rt> {
    pub fn new(router: Router<'rt>, batcher: Batcher) -> Server<'rt> {
        Server {
            router,
            batcher,
            metrics: Metrics::new(),
            config: ServerConfig::default(),
            faults: None,
            rng: Rng::new(0x5eed),
            clock_us: 0,
            groups_started: 0,
        }
    }

    pub fn with_config(mut self, config: ServerConfig) -> Server<'rt> {
        self.config = config;
        self
    }

    /// Arm (or disarm) deterministic fault injection.
    pub fn set_faults(&mut self, faults: Option<FaultPlan>) {
        self.faults = faults;
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> Server<'rt> {
        self.faults = Some(faults);
        self
    }

    /// Current virtual time (µs).
    pub fn now_us(&self) -> u64 {
        self.clock_us
    }

    /// Advance the virtual clock (e.g. to model arrival gaps between
    /// bursts, or to let a max-wait window elapse in tests).
    pub fn advance_clock(&mut self, us: u64) {
        self.clock_us = self.clock_us.saturating_add(us);
    }

    /// Offer a request to the bounded queue.  Every offered request is
    /// counted as admitted traffic; a shed one is typed backpressure,
    /// not an error, and is accounted under the shed outcome.
    pub fn submit(&mut self, mut req: DecodeRequest) -> Admission {
        req.arrived = Some(Instant::now());
        self.metrics.record_admitted();
        let admission = self.batcher.push(req, self.clock_us);
        if let Admission::Shed { .. } = admission {
            self.metrics.record_shed(1);
        }
        admission
    }

    /// Serve until the queue is empty; returns a result for every queued
    /// request.  Group failures mark their members [`Outcome::Failed`] —
    /// they never abort the drain.
    pub fn drain(&mut self) -> anyhow::Result<Vec<DecodeResult>> {
        let mut results = Vec::new();
        loop {
            results.extend(self.expire_queued());
            match self.batcher.form_group(true, self.clock_us) {
                Some(group) => results.extend(self.run_group(group)),
                None => break,
            }
        }
        Ok(results)
    }

    /// Serve exactly one group if the policy forms one at the current
    /// virtual time (`drain=true` forces formation below target fill).
    pub fn serve_one(&mut self, drain: bool) -> anyhow::Result<Vec<DecodeResult>> {
        let mut results = self.expire_queued();
        if let Some(group) = self.batcher.form_group(drain, self.clock_us) {
            results.extend(self.run_group(group));
        }
        Ok(results)
    }

    /// Drop queued requests whose deadline has already passed — they
    /// must not occupy (or pad) an engine slot.
    fn expire_queued(&mut self) -> Vec<DecodeResult> {
        let now = Instant::now();
        self.batcher
            .expire(self.clock_us)
            .into_iter()
            .map(|req| {
                self.metrics.record_expired(1);
                DecodeResult {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft_s: 0.0,
                    total_s: req
                        .arrived
                        .map(|a| now.duration_since(a).as_secs_f64())
                        .unwrap_or(0.0),
                    steps: 0,
                    outcome: Outcome::Expired,
                    error: None,
                }
            })
            .collect()
    }

    /// Fail every member of a group (engine could not be built/reset).
    fn fail_group(&self, group: &DecodeGroup, error: &str) -> Vec<DecodeResult> {
        let now = Instant::now();
        group
            .members
            .iter()
            .map(|req| {
                self.metrics.record_failed(1);
                DecodeResult {
                    id: req.id,
                    tokens: Vec::new(),
                    ttft_s: 0.0,
                    total_s: req
                        .arrived
                        .map(|a| now.duration_since(a).as_secs_f64())
                        .unwrap_or(0.0),
                    steps: 0,
                    outcome: Outcome::Failed,
                    error: Some(error.to_string()),
                }
            })
            .collect()
    }

    /// Record which tuned schedule serves each GEMM node of a routed
    /// group — the dense projections or the MoE expert fan-out, with its
    /// per-kind expert counts; the down-projection (the paper's
    /// bottleneck; the expert down-projection on MoE models) doubles as
    /// the group's headline schedule counter.
    pub fn record_group_schedules(metrics: &Metrics, plan: Option<&LayerPlan>) {
        match plan {
            Some(p) => {
                for node in &p.nodes {
                    let label = node.plan.map(|t| t.strategy.name()).unwrap_or("untuned");
                    metrics.record_gemm_schedule_n(
                        node.kind.name(),
                        label,
                        node.plan.map(|t| t.predicted_ns * node.count as f64),
                        node.count as u64,
                    );
                }
            }
            None => {
                for kind in GemmKind::all() {
                    metrics.record_gemm_schedule(kind.name(), "untuned", None);
                }
            }
        }
        let headline = plan
            .and_then(|p| p.headline())
            .map(|p| p.strategy.name())
            .unwrap_or("untuned");
        metrics.record_schedule(headline);
    }

    /// Decode one group to completion.  Infallible by design: engine or
    /// step failures convert into per-member [`Outcome::Failed`] results.
    fn run_group(&mut self, group: DecodeGroup) -> Vec<DecodeResult> {
        let group_seq = self.groups_started;
        self.groups_started += 1;
        // Route down the degradation ladder: which kernel schedules
        // serve this group's decode-layer GEMMs, and which rung supplied
        // them (warm cache, inline re-tune, or the splitk default).
        let routed = self.router.route(group.batch);
        self.metrics
            .record_route(routed.outcome.rung.name(), routed.outcome.reason.name());
        let plan = routed.plan;
        Server::record_group_schedules(&self.metrics, plan.as_ref());
        // The plan's predicted cross-node gains (overlap + residency),
        // cache-only — the predicted-overlap column of the metrics report.
        if let Some(p) = plan.as_ref() {
            self.metrics.record_group_plan(group.batch, p.overlap_gain_ns, p.residency_gain_ns);
        }
        // What one decode step costs on the virtual clock: the routed
        // plan's best prediction (resident <= overlapped <= layer), or
        // the configured default when the group is unpriced.
        let step_us = plan
            .as_ref()
            .and_then(|p| p.predicted_served_ns())
            .map(|ns| ((ns / 1_000.0).ceil() as u64).max(1))
            .unwrap_or(self.config.default_step_us);

        if let Err(e) = self.router.engine(group.batch).and_then(|eng| eng.reset()) {
            return self.fail_group(&group, &format!("engine unavailable: {e:#}"));
        }
        let engine = self.router.engine(group.batch).expect("engine just built");
        let vocab = engine.vocab();
        let max_seq = engine.max_seq();

        // Invalid members fail at admission-to-group time (their slot is
        // born done); the rest of the group still decodes.
        let mut slots: Vec<Slot> = group
            .members
            .iter()
            .map(|req| {
                let (done, outcome, error) = match req.validate(vocab, max_seq) {
                    Ok(()) => (false, Outcome::Completed, None),
                    Err(e) => (true, Outcome::Failed, Some(format!("invalid request: {e:#}"))),
                };
                Slot {
                    req,
                    position: 0,
                    next_input: req.prompt.first().copied().unwrap_or(0),
                    generated: Vec::new(),
                    first_token_at: None,
                    done,
                    outcome,
                    error,
                }
            })
            .collect();

        let mut steps = 0usize;
        'group: while slots.iter().any(|s| !s.done) {
            // Deadlines are checked between steps on the virtual clock:
            // an expired slot stops consuming steps and keeps its
            // partial generation.
            for slot in slots.iter_mut() {
                if !slot.done && slot.req.expired(self.clock_us) {
                    slot.done = true;
                    slot.outcome = Outcome::Expired;
                }
            }
            if slots.iter().all(|s| s.done) {
                break;
            }
            // Assemble the step: idle/finished/padding slots replay token 0
            // at their last written position (harmless rewrite).
            let mut tokens = vec![0i32; group.batch];
            let mut positions = vec![0i32; group.batch];
            for (i, slot) in slots.iter().enumerate() {
                tokens[i] = if slot.done { 0 } else { slot.next_input };
                positions[i] = slot.position as i32;
            }
            // Execute the step under the fault plan + retry policy.  A
            // straggler lands late but correct; an injected engine/client
            // error is retried with (virtual) exponential backoff.  The
            // fault plan is keyed on (group, step, attempt), so a retry
            // re-rolls its fate deterministically.
            let mut attempt = 0u32;
            let out = loop {
                let fault = self
                    .faults
                    .as_ref()
                    .and_then(|f| f.step_fault(group_seq, steps as u64, attempt));
                let step_res = match fault {
                    Some(FaultKind::Straggler { mult_x100 }) => {
                        self.metrics.record_fault("straggler");
                        let penalty =
                            step_us.saturating_mul(mult_x100.saturating_sub(100) as u64) / 100;
                        self.clock_us = self.clock_us.saturating_add(penalty);
                        engine.step(&tokens, &positions)
                    }
                    Some(kind) => {
                        self.metrics.record_fault(kind.name());
                        Err(anyhow::anyhow!(
                            "injected {} (group {group_seq}, step {steps}, attempt {attempt})",
                            kind.name()
                        ))
                    }
                    None => engine.step(&tokens, &positions),
                };
                match step_res {
                    Ok(out) => break out,
                    Err(e) => {
                        if attempt + 1 >= self.config.retry.max_attempts.max(1) {
                            // Retries exhausted: fail the group's
                            // unfinished members, keep the server alive.
                            let msg = format!(
                                "step {steps} failed after {} attempts: {e:#}",
                                attempt + 1
                            );
                            for slot in slots.iter_mut().filter(|s| !s.done) {
                                slot.done = true;
                                slot.outcome = Outcome::Failed;
                                slot.error = Some(msg.clone());
                            }
                            break 'group;
                        }
                        self.metrics.record_retry();
                        let backoff = self.config.retry.backoff_us(attempt, &mut self.rng);
                        self.clock_us = self.clock_us.saturating_add(backoff);
                        attempt += 1;
                    }
                }
            };
            steps += 1;
            self.clock_us = self.clock_us.saturating_add(step_us);

            for (i, slot) in slots.iter_mut().enumerate() {
                if slot.done {
                    continue;
                }
                let produced = out.next_tokens[i];
                slot.position += 1;
                if slot.position < slot.req.prompt.len() {
                    // Still ingesting the prompt (teacher forcing).
                    slot.next_input = slot.req.prompt[slot.position];
                } else {
                    // Generating.
                    if slot.first_token_at.is_none() {
                        slot.first_token_at = Some(Instant::now());
                    }
                    slot.generated.push(produced);
                    slot.next_input = produced;
                    if slot.generated.len() >= slot.req.max_new_tokens
                        || slot.position + 1 >= max_seq
                    {
                        slot.done = true;
                    }
                }
            }
        }

        self.metrics.record_group(group.batch, group.occupancy(), steps);
        let now = Instant::now();
        slots
            .into_iter()
            .map(|slot| {
                let arrived = slot.req.arrived.unwrap_or(now);
                let ttft = slot
                    .first_token_at
                    .map(|t| t.duration_since(arrived).as_secs_f64())
                    .unwrap_or(0.0);
                let total = now.duration_since(arrived).as_secs_f64();
                match slot.outcome {
                    Outcome::Completed => {
                        self.metrics.record_completion(slot.generated.len(), ttft, total)
                    }
                    Outcome::Expired => self.metrics.record_expired(1),
                    Outcome::Failed => self.metrics.record_failed(1),
                }
                DecodeResult {
                    id: slot.req.id,
                    tokens: slot.generated,
                    ttft_s: ttft,
                    total_s: total,
                    steps,
                    outcome: slot.outcome,
                    error: slot.error,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Full server behaviour needs a manifest on disk; the fault-tolerant
    // serving loop is exercised end to end by rust/tests/chaos.rs
    // (synthetic manifests, seeded fault plans) and, against real
    // artifacts + PJRT, by rust/tests/coordinator.rs.
}
