//! Seeded, deterministic fault injection for the serving loop.
//!
//! A [`FaultPlan`] is a *stateless* function of `(seed, group, step,
//! attempt)`: every decision is derived by hashing the coordinates, never
//! by advancing shared PRNG state.  That makes the chaos harness
//! order-independent — retrying one step re-rolls only that step's
//! `attempt + 1` coordinate, while every other step's fate is unchanged,
//! and two servers given the same seed inject the identical fault
//! schedule regardless of how their groups interleave.
//!
//! Three fault kinds cover the failure modes the coordinator must absorb
//! (DESIGN.md §14): straggler steps (a latency multiplier on the virtual
//! clock — the step still succeeds), transient engine failures (the step
//! errors before execution), and runtime-client errors (the
//! execute/readback boundary errors).  The latter two are retryable; a
//! fresh attempt re-rolls, so transient faults usually clear under the
//! retry policy.
//!
//! Two further *request-keyed* fault surfaces cover the serving control
//! plane (DESIGN.md §15): [`FaultPlan::admission_fault`] fails the
//! admission path itself (the request is shed, typed, before it ever
//! queues — never retried, because the client owns the retry), and
//! [`FaultPlan::cache_write_fault`] fails a KV-cache page write for one
//! (request, decode-token) coordinate, failing the request
//! deterministically.  Both chain the same splitmix64 mixer under
//! distinct salts, so they are independent of the step-fault schedule
//! and of each other.

/// One injected fault.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The step completes but takes `mult_x100 / 100` times its budget
    /// (e.g. 300 = a 3x straggler).  Never retried — slow is not failed.
    Straggler { mult_x100: u32 },
    /// Transient whole-step engine failure (retryable).
    EngineFault,
    /// Runtime-client error at the execute/readback boundary (retryable).
    ClientError,
}

impl FaultKind {
    /// Stable label for the metrics sink.
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Straggler { .. } => "straggler",
            FaultKind::EngineFault => "engine_fault",
            FaultKind::ClientError => "client_error",
        }
    }
}

/// A seeded fault schedule over the serving loop's step coordinates.
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    seed: u64,
    /// Probability in [0, 1] that any one (group, step, attempt) faults.
    rate: f64,
}

/// splitmix64 finalizer — the same mixer `util::prng` seeds with.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    pub fn new(seed: u64, rate: f64) -> FaultPlan {
        FaultPlan { seed, rate: rate.clamp(0.0, 1.0) }
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Hash the step coordinates into one 64-bit decision word.
    fn word(&self, group: u64, step: u64, attempt: u32) -> u64 {
        let mut h = mix64(self.seed);
        h = mix64(h ^ group.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = mix64(h ^ step.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        mix64(h ^ attempt as u64)
    }

    /// The fault (if any) injected at one step attempt.  Deterministic in
    /// the coordinates alone: call order and call count never matter.
    pub fn step_fault(&self, group: u64, step: u64, attempt: u32) -> Option<FaultKind> {
        if self.rate <= 0.0 {
            return None;
        }
        let h = self.word(group, step, attempt);
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u >= self.rate {
            return None;
        }
        // Split the fault budget: half stragglers, the rest transient
        // failures split between the engine and the client boundary.
        // Straggler multipliers span 1.5x..7x — the mild (sub-2x) end is
        // what exposed the penalty-truncation bug on sub-µs steps.
        let k = mix64(h);
        Some(match k % 10 {
            0..=4 => FaultKind::Straggler { mult_x100: 150 + 50 * (k / 10 % 12) as u32 },
            5..=7 => FaultKind::EngineFault,
            _ => FaultKind::ClientError,
        })
    }

    /// Whether the admission path faults for this request id.  Keyed by
    /// the request alone (salt [`ADMISSION_SALT`]): re-offering the same
    /// id re-faults, so the decision is replay-stable.
    pub fn admission_fault(&self, request_id: u64) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        let mut h = mix64(self.seed ^ ADMISSION_SALT);
        h = mix64(h ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.rate
    }

    /// Whether the KV-cache write for `(request, generated-token index)`
    /// faults (salt [`CACHE_WRITE_SALT`]).  A cache-write fault is not
    /// retryable — the page content is lost — so the serving loop fails
    /// the request deterministically.
    pub fn cache_write_fault(&self, request_id: u64, token_index: u64) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        let mut h = mix64(self.seed ^ CACHE_WRITE_SALT);
        h = mix64(h ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = mix64(h ^ token_index.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.rate
    }

    /// Sub-step fault domain (salt [`MEMBER_SALT`]): a straggler hitting a
    /// single group *member* rather than the whole step.  Only the
    /// straggler half of the fault budget applies — a member cannot fail
    /// the step for the rest of the batch, it can only trail it — so
    /// roughly `rate / 2` of the (group, step, member) coordinates return
    /// a multiplier and the rest clear.  The caller charges only the
    /// straggled member's slot tail (DESIGN.md §18), never the whole step.
    pub fn member_fault(&self, group: u64, step: u64, member: u64) -> Option<u32> {
        if self.rate <= 0.0 {
            return None;
        }
        let mut h = mix64(self.seed ^ MEMBER_SALT);
        h = mix64(h ^ group.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = mix64(h ^ step.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        h = mix64(h ^ member.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u >= self.rate {
            return None;
        }
        let k = mix64(h);
        match k % 10 {
            0..=4 => Some(150 + 50 * (k / 10 % 12) as u32),
            _ => None,
        }
    }

    /// Whether the recompute-recovery path faults for `(request,
    /// preemption cycle)` (salt [`PREEMPT_SALT`]): the stashed generated
    /// prefix is lost before the victim reseats, so the request fails
    /// terminally at resume instead of re-prefilling.  Not retryable —
    /// the state is gone.
    pub fn preempt_fault(&self, request_id: u64, cycle: u64) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        let mut h = mix64(self.seed ^ PREEMPT_SALT);
        h = mix64(h ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = mix64(h ^ cycle.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.rate
    }

    /// Whether the swap-in for `(request, preemption cycle)` faults (salt
    /// [`SWAP_SALT`]): the host-side pages are lost in transit, so the
    /// request fails terminally at resume.  Independent of the
    /// recompute-path chain so `auto`'s pricing choice also selects which
    /// fault surface the victim is exposed to.
    pub fn swap_fault(&self, request_id: u64, cycle: u64) -> bool {
        if self.rate <= 0.0 {
            return false;
        }
        let mut h = mix64(self.seed ^ SWAP_SALT);
        h = mix64(h ^ request_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        h = mix64(h ^ cycle.wrapping_mul(0xC2B2_AE3D_27D4_EB4F));
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        u < self.rate
    }
}

/// Salt decorrelating the admission-fault chain from step faults.
pub const ADMISSION_SALT: u64 = 0xAD31_55D0_0FA1_7001;
/// Salt decorrelating the cache-write-fault chain from both others.
pub const CACHE_WRITE_SALT: u64 = 0xCAC8_E3B1_7E5A_1002;

/// Salt decorrelating the per-member straggler chain from step faults.
pub const MEMBER_SALT: u64 = 0x3E3B_0A57_AC6D_4003;
/// Salt decorrelating the recompute-recovery fault chain.
pub const PREEMPT_SALT: u64 = 0x9EE3_27F0_5CA4_D004;
/// Salt decorrelating the swap-in fault chain.
pub const SWAP_SALT: u64 = 0x51AB_BED5_70C1_E005;

/// Metrics label for admission-path faults.
pub const ADMISSION_FAULT_NAME: &str = "admission_fault";
/// Metrics label for KV-cache write faults.
pub const CACHE_WRITE_FAULT_NAME: &str = "cache_write_fault";
/// Metrics label for single-member stragglers (sub-step fault domain).
pub const MEMBER_FAULT_NAME: &str = "member_straggler";
/// Metrics label for recompute-recovery faults at resume.
pub const PREEMPT_FAULT_NAME: &str = "preempt_fault";
/// Metrics label for swap-in faults at resume.
pub const SWAP_FAULT_NAME: &str = "swap_fault";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_order_independent() {
        let p = FaultPlan::new(42, 0.5);
        let forward: Vec<_> = (0..64).map(|s| p.step_fault(3, s, 0)).collect();
        let backward: Vec<_> = (0..64).rev().map(|s| p.step_fault(3, s, 0)).collect();
        let reversed: Vec<_> = backward.into_iter().rev().collect();
        assert_eq!(forward, reversed, "decisions must not depend on call order");
        let again: Vec<_> = (0..64).map(|s| p.step_fault(3, s, 0)).collect();
        assert_eq!(forward, again, "decisions must not depend on call count");
    }

    #[test]
    fn zero_rate_never_faults_and_full_rate_always_faults() {
        let none = FaultPlan::new(7, 0.0);
        let all = FaultPlan::new(7, 1.0);
        for s in 0..256 {
            assert_eq!(none.step_fault(0, s, 0), None);
            assert!(all.step_fault(0, s, 0).is_some());
        }
    }

    #[test]
    fn rate_is_roughly_respected() {
        let p = FaultPlan::new(11, 0.1);
        let faults = (0..10_000).filter(|&s| p.step_fault(0, s, 0).is_some()).count();
        assert!((800..1200).contains(&faults), "10% rate gave {faults}/10000");
    }

    #[test]
    fn retries_reroll_the_attempt_coordinate() {
        let p = FaultPlan::new(13, 0.3);
        // Find a faulting step whose first retry clears: with a 30% rate
        // the expected search is short, and determinism makes it stable.
        let step = (0..10_000)
            .find(|&s| p.step_fault(0, s, 0).is_some() && p.step_fault(0, s, 1).is_none())
            .expect("some fault must clear on retry");
        assert!(p.step_fault(0, step, 0).is_some());
        assert_eq!(p.step_fault(0, step, 1), None);
    }

    #[test]
    fn kinds_cover_all_three_and_stragglers_bound_their_multiplier() {
        let p = FaultPlan::new(17, 1.0);
        let mut seen = std::collections::BTreeSet::new();
        for s in 0..512 {
            match p.step_fault(0, s, 0).unwrap() {
                FaultKind::Straggler { mult_x100 } => {
                    assert!((150..=700).contains(&mult_x100), "mult {mult_x100}");
                    assert_eq!(mult_x100 % 50, 0, "multiplier grid is 0.5x steps");
                    seen.insert("straggler");
                }
                FaultKind::EngineFault => {
                    seen.insert("engine_fault");
                }
                FaultKind::ClientError => {
                    seen.insert("client_error");
                }
            }
        }
        assert_eq!(seen.len(), 3, "all kinds must appear: {seen:?}");
    }

    #[test]
    fn admission_faults_are_request_keyed_and_rate_bounded() {
        let p = FaultPlan::new(23, 0.2);
        let first: Vec<bool> = (0..4096u64).map(|id| p.admission_fault(id)).collect();
        let again: Vec<bool> = (0..4096u64).map(|id| p.admission_fault(id)).collect();
        assert_eq!(first, again, "admission decision must be pure in the id");
        let hits = first.iter().filter(|&&b| b).count();
        assert!((600..1100).contains(&hits), "20% admission rate gave {hits}/4096");
        assert!(!FaultPlan::new(23, 0.0).admission_fault(0));
        assert!(FaultPlan::new(23, 1.0).admission_fault(0));
    }

    #[test]
    fn cache_write_faults_are_independent_of_step_and_admission_chains() {
        let p = FaultPlan::new(29, 0.5);
        let writes: Vec<bool> = (0..256u64).map(|t| p.cache_write_fault(7, t)).collect();
        let admits: Vec<bool> = (0..256u64).map(|id| p.admission_fault(id)).collect();
        let steps: Vec<bool> = (0..256u64).map(|s| p.step_fault(7, s, 0).is_some()).collect();
        assert_ne!(writes, admits, "salts must decorrelate the chains");
        assert_ne!(writes, steps, "salts must decorrelate the chains");
        let other_req: Vec<bool> = (0..256u64).map(|t| p.cache_write_fault(8, t)).collect();
        assert_ne!(writes, other_req, "request coordinate must matter");
        assert_eq!(writes, (0..256u64).map(|t| p.cache_write_fault(7, t)).collect::<Vec<_>>());
    }

    #[test]
    fn member_faults_are_stragglers_only_and_member_keyed() {
        let p = FaultPlan::new(31, 1.0);
        let mut hit = 0usize;
        for s in 0..512u64 {
            if let Some(mult) = p.member_fault(0, s, 1) {
                assert!((150..=700).contains(&mult), "mult {mult}");
                assert_eq!(mult % 50, 0, "multiplier grid is 0.5x steps");
                hit += 1;
            }
        }
        // At rate 1.0 exactly the straggler half of the budget fires.
        assert!((180..=330).contains(&hit), "straggler half gave {hit}/512");
        let a: Vec<_> = (0..128u64).map(|s| p.member_fault(0, s, 0)).collect();
        let b: Vec<_> = (0..128u64).map(|s| p.member_fault(0, s, 1)).collect();
        assert_ne!(a, b, "member coordinate must decorrelate schedules");
        assert_eq!(a, (0..128u64).map(|s| p.member_fault(0, s, 0)).collect::<Vec<_>>());
        assert_eq!(FaultPlan::new(31, 0.0).member_fault(0, 0, 0), None);
    }

    #[test]
    fn preempt_and_swap_chains_are_independent_and_cycle_keyed() {
        let p = FaultPlan::new(37, 0.5);
        let pre: Vec<bool> = (0..256u64).map(|c| p.preempt_fault(7, c)).collect();
        let swp: Vec<bool> = (0..256u64).map(|c| p.swap_fault(7, c)).collect();
        let cache: Vec<bool> = (0..256u64).map(|t| p.cache_write_fault(7, t)).collect();
        assert_ne!(pre, swp, "salts must decorrelate the recovery chains");
        assert_ne!(pre, cache, "salts must decorrelate the recovery chains");
        assert_ne!(swp, cache, "salts must decorrelate the recovery chains");
        let other: Vec<bool> = (0..256u64).map(|c| p.preempt_fault(8, c)).collect();
        assert_ne!(pre, other, "request coordinate must matter");
        assert_eq!(pre, (0..256u64).map(|c| p.preempt_fault(7, c)).collect::<Vec<_>>());
        assert!(!FaultPlan::new(37, 0.0).preempt_fault(0, 0));
        assert!(!FaultPlan::new(37, 0.0).swap_fault(0, 0));
        assert!(FaultPlan::new(37, 1.0).preempt_fault(0, 0));
        assert!(FaultPlan::new(37, 1.0).swap_fault(0, 0));
    }

    #[test]
    fn different_groups_fault_independently() {
        let p = FaultPlan::new(19, 0.5);
        let a: Vec<_> = (0..128).map(|s| p.step_fault(0, s, 0)).collect();
        let b: Vec<_> = (0..128).map(|s| p.step_fault(1, s, 0)).collect();
        assert_ne!(a, b, "group coordinate must decorrelate schedules");
    }
}
