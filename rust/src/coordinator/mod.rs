//! Decode-serving coordinator — the L3 runtime exercising the W4A16
//! pipeline on the paper's motivating workload (LLM decoding).
//!
//! Architecture (vLLM-router-inspired, std-thread based):
//!
//! ```text
//!  clients --> bounded queue --> Batcher (admission, deadlines, padding)
//!                  |                |
//!                  v                v
//!              Metrics        Router (degradation ladder -> Engine)
//!                  ^                |
//!                  |                v
//!              FaultPlan ~~> PJRT / synthetic decode-step engine
//! ```
//!
//! * [`request`] — request/response types, deadlines, typed [`Outcome`].
//! * [`batcher`] — groups queued requests into fixed-size decode groups
//!   (the AOT artifacts are compiled per batch size), padding idle
//!   slots; bounded admission queue (typed shed) + max-wait timer.
//! * [`router`] — lazily constructs and caches one engine per batch
//!   size, and routes each group down the degradation ladder
//!   (full -> tuned_only -> retuned -> default_splitk) so routing never
//!   fails a request.
//! * [`server`] — the serving loops: the group-synchronous burst path
//!   (drain queue -> form group -> decode until every member finishes)
//!   and the continuous-batching path ([`Server::serve_load`]): arrival
//!   plans on the virtual clock, chunked prefill interleaved against
//!   in-flight decode, KV-cache paging, SLO latencies; both share the
//!   virtual clock, deadline enforcement, fault injection and step
//!   retry.
//! * [`faults`] — the seeded, coordinate-keyed fault plan (whole-step
//!   and single-member stragglers, transient engine/client errors,
//!   admission, KV-cache-write and preemption-recovery faults) behind
//!   the chaos harness.
//! * [`metrics`] — latency/throughput counters, outcome conservation
//!   (with a typed shed breakdown on the serve path), per-rung fallback
//!   and fault/retry counters, TTFT/token-gap percentiles and KV-pager
//!   occupancy.

pub mod batcher;
pub mod faults;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{
    Admission, Batcher, BatchPolicy, DecodeGroup, DEFAULT_MAX_WAIT_US, DEFAULT_QUEUE_CAP,
};
pub use faults::{
    FaultKind, FaultPlan, ADMISSION_FAULT_NAME, ADMISSION_SALT, CACHE_WRITE_FAULT_NAME,
    CACHE_WRITE_SALT, MEMBER_FAULT_NAME, MEMBER_SALT, PREEMPT_FAULT_NAME, PREEMPT_SALT,
    SWAP_FAULT_NAME, SWAP_SALT,
};
pub use metrics::{GemmScheduleStat, Metrics, MetricsSnapshot};
pub use request::{DecodeRequest, DecodeResult, Outcome};
pub use router::{
    LayerPlan, PlanNode, RouteOutcome, RouteReason, RouteRung, RoutedPlan, Router, TunedPlan,
    DEFAULT_RETUNE_BUDGET, DEFAULT_RETUNE_REFILL_INTERVAL_US,
};
pub use server::{
    member_tail_penalty_us, prefill_vector_ns, PreemptPolicy, ServeOptions, ServeReport, Server,
    ServerConfig, DEFAULT_MAX_PREEMPTIONS, DEFAULT_PREFILL_CHUNK, DEFAULT_STEP_US,
};
