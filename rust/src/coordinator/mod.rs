//! Decode-serving coordinator — the L3 runtime exercising the W4A16
//! pipeline on the paper's motivating workload (LLM decoding).
//!
//! Architecture (vLLM-router-inspired, std-thread based):
//!
//! ```text
//!  clients --> RequestQueue --> Batcher (group formation, padding)
//!                  |                |
//!                  v                v
//!              Metrics        Router (batch size -> DecodeEngine)
//!                                   |
//!                                   v
//!                          PJRT decode-step artifact
//! ```
//!
//! * [`request`] — request/response types.
//! * [`batcher`] — groups queued requests into fixed-size decode groups
//!   (the AOT artifacts are compiled per batch size), padding idle slots.
//! * [`router`] — lazily constructs and caches one [`DecodeEngine`]
//!   (weights staged, executable compiled) per batch size.
//! * [`server`] — the serving loop: drain queue -> form group -> decode
//!   until every member finishes -> publish results + metrics.
//! * [`metrics`] — latency/throughput counters.

pub mod batcher;
pub mod metrics;
pub mod request;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy, DecodeGroup};
pub use metrics::{GemmScheduleStat, Metrics};
pub use request::{DecodeRequest, DecodeResult};
pub use router::{LayerPlan, PlanNode, Router, TunedPlan};
pub use server::Server;
