//! Roofline model for the Ascend 910 machine description.
//!
//! Arithmetic intensity is measured against *HBM* bytes (the scarce
//! resource); attainable throughput is `min(peak, AI x BW)`.  The W4A16
//! kernel's whole premise is moving the GEMM up the roofline by shrinking
//! weight bytes — and §4.2's finding is that the decoupled round trip
//! pushes it back down.

use crate::ascend::{MachineConfig, SimReport};

/// Roofline placement of one simulated kernel.
#[derive(Debug, Clone, Copy)]
pub struct RooflinePoint {
    /// FLOPs per HBM byte.
    pub arithmetic_intensity: f64,
    /// TFLOPS bound for this intensity on this machine.
    pub attainable_tflops: f64,
    /// TFLOPS the simulated kernel actually achieved.
    pub achieved_tflops: f64,
    /// achieved / attainable (the efficiency ratio reported in DESIGN.md).
    pub efficiency: f64,
    /// True if the kernel sits left of the ridge (bandwidth-bound).
    pub memory_bound: bool,
}

/// Intensity at which compute and bandwidth bounds meet.
pub fn ridge_point(machine: &MachineConfig) -> f64 {
    machine.peak_tflops_f16() * 1000.0 / machine.hbm_bw
}

/// Place a simulated kernel on the roofline.
pub fn place(machine: &MachineConfig, report: &SimReport) -> RooflinePoint {
    let flops = report.total_macs as f64 * 2.0;
    let hbm_bytes = report.ledger.hbm_total().max(1.0);
    let ai = flops / hbm_bytes;
    let attainable = (machine.peak_tflops_f16()).min(ai * machine.hbm_bw / 1000.0);
    let achieved = report.achieved_tflops();
    RooflinePoint {
        arithmetic_intensity: ai,
        attainable_tflops: attainable,
        achieved_tflops: achieved,
        efficiency: if attainable > 0.0 { achieved / attainable } else { 0.0 },
        memory_bound: ai < ridge_point(machine),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{self, GemmProblem, Strategy};
    use crate::ascend::Simulator;

    #[test]
    fn ridge_point_is_peak_over_bandwidth() {
        let m = MachineConfig::ascend910();
        let ridge = ridge_point(&m);
        // 262 TFLOPS / 1.2 TB/s ~ 218 flops/byte
        assert!((ridge - 218.0).abs() < 2.0, "ridge {ridge}");
    }

    #[test]
    fn decode_gemm_is_memory_bound() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 2048, 7168);
        let trace = kernels::schedule(&m, &p, Strategy::Fp16Native).unwrap();
        let r = Simulator::new(m.clone()).run(&trace).unwrap();
        let point = place(&m, &r);
        assert!(point.memory_bound);
        assert!(point.efficiency > 0.3 && point.efficiency <= 1.0,
            "efficiency {}", point.efficiency);
    }

    #[test]
    fn w4a16_raises_intensity_vs_fp16() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 2048, 7168);
        let fp16 = Simulator::new(m.clone())
            .run(&kernels::schedule(&m, &p, Strategy::Fp16Native).unwrap())
            .unwrap();
        let sk = Simulator::new(m.clone())
            .run(&kernels::schedule(&m, &p, Strategy::SplitK).unwrap())
            .unwrap();
        // Workspace round trip stays on-chip, so HBM intensity rises.
        assert!(
            place(&m, &sk).arithmetic_intensity > place(&m, &fp16).arithmetic_intensity
        );
    }
}
