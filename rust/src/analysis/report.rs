//! Table / figure renderers shared by the CLI and the bench harnesses.
//!
//! Each renderer prints the same rows the paper's figures plot, plus a JSON
//! form for machine consumption (EXPERIMENTS.md records both).

use crate::ascend::{MachineConfig, SimReport};
use crate::util::json::Json;
use crate::util::stats;

use super::traffic;

/// A simulation artifact with a human rendering and a machine (JSON)
/// form.  Every top-level report — [`LayerReport`], [`StepReport`],
/// [`ServeReport`] — implements this, so the CLI and the bench harnesses
/// print and persist through one surface; the legacy `render_layer` /
/// `render_step` / `layer_json` / `step_json` free functions are one-line
/// forwarders onto it.
///
/// [`LayerReport`]: super::layer::LayerReport
/// [`StepReport`]: super::layer::StepReport
/// [`ServeReport`]: crate::coordinator::server::ServeReport
pub trait Report {
    /// Human-readable rendering (the CLI's stdout form).
    fn render(&self) -> String;

    /// Machine-readable form (what the bench snapshots persist).
    fn to_json(&self) -> Json;
}

/// One (shape, batch) cell of the Figure 2 sweep.
#[derive(Debug, Clone)]
pub struct Fig2Cell {
    pub model: String,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    pub splitk_us: f64,
    pub dp_us: f64,
}

impl Fig2Cell {
    pub fn speedup(&self) -> f64 {
        self.dp_us / self.splitk_us
    }
}

/// One (shape, batch) cell of the Figure 3 sweep.
#[derive(Debug, Clone)]
pub struct Fig3Cell {
    pub model: String,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    pub w4a16_us: f64,
    pub fp16_us: f64,
}

impl Fig3Cell {
    pub fn speedup(&self) -> f64 {
        self.fp16_us / self.w4a16_us
    }
}

/// Render the Figure 2 table (execution time, Split-K vs Data-Parallel).
pub fn render_fig2(cells: &[Fig2Cell]) -> String {
    let mut out = String::new();
    out.push_str("Figure 2 — INT4xFP16 execution time: Split-K vs Data-Parallel (simulated µs)\n");
    out.push_str(&format!(
        "{:<12} {:>6} {:>6} {:>6} | {:>10} {:>10} {:>8} {:>6}\n",
        "model", "N", "K", "M", "splitk_us", "dp_us", "speedup", "K>>N"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<12} {:>6} {:>6} {:>6} | {:>10.2} {:>10.2} {:>7.2}x {:>6}\n",
            c.model, c.n, c.k, c.batch, c.splitk_us, c.dp_us,
            c.speedup(),
            if c.k >= 2 * c.n { "yes" } else { "" },
        ));
    }
    let kd: Vec<f64> = cells.iter().filter(|c| c.k >= 2 * c.n).map(|c| c.speedup()).collect();
    let all: Vec<f64> = cells.iter().map(|c| c.speedup()).collect();
    if !kd.is_empty() {
        out.push_str(&format!(
            "\nK>>N regime: speedup range [{:.2}x, {:.2}x], geomean {:.2}x  (paper: 1.01x-1.74x)\n",
            kd.iter().cloned().fold(f64::INFINITY, f64::min),
            kd.iter().cloned().fold(0.0, f64::max),
            stats::geomean(&kd),
        ));
    }
    out.push_str(&format!(
        "All shapes:  speedup range [{:.2}x, {:.2}x], geomean {:.2}x\n",
        all.iter().cloned().fold(f64::INFINITY, f64::min),
        all.iter().cloned().fold(0.0, f64::max),
        stats::geomean(&all),
    ));
    out
}

/// Render the Figure 3 table (W4A16 Split-K speedup over native FP16).
pub fn render_fig3(cells: &[Fig3Cell]) -> String {
    let mut out = String::new();
    out.push_str("Figure 3 — Split-K W4A16 speedup over native FP16xFP16 (simulated)\n");
    out.push_str(&format!(
        "{:<12} {:>6} {:>6} {:>6} | {:>10} {:>10} {:>8}\n",
        "model", "N", "K", "M", "w4a16_us", "fp16_us", "speedup"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<12} {:>6} {:>6} {:>6} | {:>10.2} {:>10.2} {:>7.2}x\n",
            c.model, c.n, c.k, c.batch, c.w4a16_us, c.fp16_us, c.speedup(),
        ));
    }
    let all: Vec<f64> = cells.iter().map(|c| c.speedup()).collect();
    out.push_str(&format!(
        "\nmax speedup {:.2}x (paper: at most 1.48x, far below the theoretical ~4x)\n",
        all.iter().cloned().fold(0.0, f64::max),
    ));
    out
}

/// JSON form of the Figure 2 sweep.
pub fn fig2_json(cells: &[Fig2Cell]) -> Json {
    Json::arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("model", Json::str(c.model.clone())),
                    ("n", Json::num(c.n as f64)),
                    ("k", Json::num(c.k as f64)),
                    ("batch", Json::num(c.batch as f64)),
                    ("splitk_us", Json::num(c.splitk_us)),
                    ("dp_us", Json::num(c.dp_us)),
                    ("speedup", Json::num(c.speedup())),
                ])
            })
            .collect(),
    )
}

/// JSON form of the Figure 3 sweep.
pub fn fig3_json(cells: &[Fig3Cell]) -> Json {
    Json::arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("model", Json::str(c.model.clone())),
                    ("n", Json::num(c.n as f64)),
                    ("k", Json::num(c.k as f64)),
                    ("batch", Json::num(c.batch as f64)),
                    ("w4a16_us", Json::num(c.w4a16_us)),
                    ("fp16_us", Json::num(c.fp16_us)),
                    ("speedup", Json::num(c.speedup())),
                ])
            })
            .collect(),
    )
}

/// One (shape, batch) cell of the chunked-ablation sweep.
#[derive(Debug, Clone)]
pub struct ChunkedCell {
    pub model: String,
    pub n: usize,
    pub k: usize,
    pub batch: usize,
    pub chunks: usize,
    pub chunked_us: f64,
    pub splitk_us: f64,
    pub fp16_us: f64,
    /// Workspace bytes that touched HBM under each W4A16 schedule.
    pub ws_hbm_splitk: f64,
    pub ws_hbm_chunked: f64,
}

impl ChunkedCell {
    pub fn speedup_vs_splitk(&self) -> f64 {
        self.splitk_us / self.chunked_us
    }

    pub fn speedup_vs_fp16(&self) -> f64 {
        self.fp16_us / self.chunked_us
    }
}

/// Run the chunked-vs-splitk-vs-fp16 ablation over the paper sweep.
pub fn chunked_sweep(machine: &MachineConfig) -> anyhow::Result<Vec<ChunkedCell>> {
    use crate::ascend::{BufferClass, Simulator};
    use crate::kernels::{self, tiling, Strategy};
    use crate::workload;

    let sim = Simulator::new(machine.clone());
    let mut cells = Vec::new();
    for (shape, batch) in workload::paper_sweep() {
        let p = workload::problem_for(&shape, batch);
        let t = tiling::select_chunked(machine, &p)?;
        let ck = sim.run(&kernels::schedule_with(machine, &p, Strategy::Chunked, &t)?)?;
        let sk = sim.run(&kernels::schedule(machine, &p, Strategy::SplitK)?)?;
        let fp16 = sim.run(&kernels::schedule(machine, &p, Strategy::Fp16Native)?)?;
        cells.push(ChunkedCell {
            model: shape.model.to_string(),
            n: shape.n,
            k: shape.k,
            batch,
            chunks: t.chunks,
            chunked_us: ck.total_ns / 1e3,
            splitk_us: sk.total_ns / 1e3,
            fp16_us: fp16.total_ns / 1e3,
            ws_hbm_splitk: sk.ledger.class(BufferClass::Workspace).hbm_total(),
            ws_hbm_chunked: ck.ledger.class(BufferClass::Workspace).hbm_total(),
        });
    }
    Ok(cells)
}

/// Render the chunked-ablation table: the analysis-report section showing
/// Workspace HBM traffic dropping to ~0 under the chunk pipeline.
pub fn render_chunked(cells: &[ChunkedCell]) -> String {
    let mut out = String::new();
    out.push_str(
        "Chunk-pipelined Split-K vs Algorithm 1 vs native FP16 (simulated µs)\n",
    );
    out.push_str(&format!(
        "{:<12} {:>6} {:>6} {:>6} {:>3} | {:>10} {:>10} {:>10} {:>8} | {:>11} {:>11}\n",
        "model", "N", "K", "M", "C", "chunked_us", "splitk_us", "fp16_us", "vs_sk",
        "wsHBM_sk", "wsHBM_ck"
    ));
    for c in cells {
        out.push_str(&format!(
            "{:<12} {:>6} {:>6} {:>6} {:>3} | {:>10.2} {:>10.2} {:>10.2} {:>7.2}x | {:>11} {:>11}\n",
            c.model,
            c.n,
            c.k,
            c.batch,
            c.chunks,
            c.chunked_us,
            c.splitk_us,
            c.fp16_us,
            c.speedup_vs_splitk(),
            stats::fmt_bytes(c.ws_hbm_splitk),
            stats::fmt_bytes(c.ws_hbm_chunked),
        ));
    }
    let kd: Vec<f64> = cells
        .iter()
        .filter(|c| c.k >= 2 * c.n)
        .map(|c| c.speedup_vs_splitk())
        .collect();
    if !kd.is_empty() {
        out.push_str(&format!(
            "\nK>>N regime: chunked vs splitk geomean {:.2}x (max {:.2}x)\n",
            stats::geomean(&kd),
            kd.iter().cloned().fold(0.0, f64::max),
        ));
    }
    let spilled: f64 = cells.iter().map(|c| c.ws_hbm_splitk).sum();
    let pinned: f64 = cells.iter().map(|c| c.ws_hbm_chunked).sum();
    out.push_str(&format!(
        "workspace HBM traffic across the sweep: splitk {} -> chunked {} \
         (the rotating slice pair stays pinned in L2)\n",
        stats::fmt_bytes(spilled),
        stats::fmt_bytes(pinned),
    ));
    out
}

/// JSON form of the chunked-ablation sweep (BENCH_chunked.json).
pub fn chunked_json(cells: &[ChunkedCell]) -> Json {
    Json::arr(
        cells
            .iter()
            .map(|c| {
                Json::obj(vec![
                    ("model", Json::str(c.model.clone())),
                    ("n", Json::num(c.n as f64)),
                    ("k", Json::num(c.k as f64)),
                    ("batch", Json::num(c.batch as f64)),
                    ("chunks", Json::num(c.chunks as f64)),
                    ("chunked_us", Json::num(c.chunked_us)),
                    ("splitk_us", Json::num(c.splitk_us)),
                    ("fp16_us", Json::num(c.fp16_us)),
                    ("speedup_vs_splitk", Json::num(c.speedup_vs_splitk())),
                    ("speedup_vs_fp16", Json::num(c.speedup_vs_fp16())),
                    ("ws_hbm_splitk_bytes", Json::num(c.ws_hbm_splitk)),
                    ("ws_hbm_chunked_bytes", Json::num(c.ws_hbm_chunked)),
                ])
            })
            .collect(),
    )
}

/// Render the §4.2 bottleneck decomposition for one simulated kernel.
pub fn render_bottleneck(machine: &MachineConfig, report: &SimReport) -> String {
    let b = traffic::decompose(report);
    let mut out = String::new();
    out.push_str(&format!("Memory-traffic decomposition — {}\n", report.name));
    out.push_str(&format!(
        "{:<24} {:>12} {:>12}\n",
        "buffer class", "HBM bytes", "L2 bytes"
    ));
    for row in &b.rows {
        out.push_str(&format!(
            "{:<24} {:>12} {:>12}\n",
            row.label,
            stats::fmt_bytes(row.hbm_bytes),
            stats::fmt_bytes(row.l2_bytes),
        ));
    }
    out.push_str(&format!(
        "\nworkspace round trip: {} = {:.1}x the packed weight bytes\n",
        stats::fmt_bytes(b.round_trip_bytes),
        b.round_trip_ratio
    ));
    out.push_str(&format!(
        "type-cast compute {} vs transfer streams {} -> bottleneck: {}\n",
        stats::fmt_ns(b.cast_compute_ns),
        stats::fmt_ns(b.transfer_ns),
        if b.transfer_bound { "MEMORY TRANSFER (paper §4.2 confirmed)" } else { "type-cast" },
    ));
    out.push_str(&format!(
        "speedup ceiling from traffic: {:.2}x (theoretical 4.0x without round trip)\n",
        traffic::theoretical_speedup_ceiling(machine, report)
    ));
    for g in &report.groups {
        out.push_str(&format!(
            "group {:?}: {} (bound by {})\n",
            g.phases,
            stats::fmt_ns(g.total_ns),
            g.bound_by
        ));
    }
    out
}

/// Run the full Figure 2 sweep (every paper shape x batch size) on the
/// simulator.  Shared by the CLI (`repro fig2`) and the bench target.
pub fn fig2_sweep(machine: &MachineConfig) -> anyhow::Result<Vec<Fig2Cell>> {
    use crate::ascend::Simulator;
    use crate::kernels::{self, Strategy};
    use crate::workload;

    let sim = Simulator::new(machine.clone());
    let mut cells = Vec::new();
    for (shape, batch) in workload::paper_sweep() {
        let p = workload::problem_for(&shape, batch);
        let sk = sim.run(&kernels::schedule(machine, &p, Strategy::SplitK)?)?;
        let dp = sim.run(&kernels::schedule(machine, &p, Strategy::DataParallel)?)?;
        cells.push(Fig2Cell {
            model: shape.model.to_string(),
            n: shape.n,
            k: shape.k,
            batch,
            splitk_us: sk.total_ns / 1e3,
            dp_us: dp.total_ns / 1e3,
        });
    }
    Ok(cells)
}

/// Run the full Figure 3 sweep on the simulator.
pub fn fig3_sweep(machine: &MachineConfig) -> anyhow::Result<Vec<Fig3Cell>> {
    use crate::ascend::Simulator;
    use crate::kernels::{self, Strategy};
    use crate::workload;

    let sim = Simulator::new(machine.clone());
    let mut cells = Vec::new();
    for (shape, batch) in workload::paper_sweep() {
        let p = workload::problem_for(&shape, batch);
        let sk = sim.run(&kernels::schedule(machine, &p, Strategy::SplitK)?)?;
        let fp16 = sim.run(&kernels::schedule(machine, &p, Strategy::Fp16Native)?)?;
        cells.push(Fig3Cell {
            model: shape.model.to_string(),
            n: shape.n,
            k: shape.k,
            batch,
            w4a16_us: sk.total_ns / 1e3,
            fp16_us: fp16.total_ns / 1e3,
        });
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::{self, GemmProblem, Strategy};

    #[test]
    fn fig2_render_contains_summary() {
        let cells = vec![Fig2Cell {
            model: "deepseek".into(), n: 2048, k: 7168, batch: 8,
            splitk_us: 10.0, dp_us: 14.0,
        }];
        let s = render_fig2(&cells);
        assert!(s.contains("1.40x"));
        assert!(s.contains("K>>N regime"));
    }

    #[test]
    fn fig3_render_tracks_max() {
        let cells = vec![
            Fig3Cell { model: "a".into(), n: 1, k: 1, batch: 1, w4a16_us: 10.0, fp16_us: 13.0 },
            Fig3Cell { model: "b".into(), n: 1, k: 1, batch: 1, w4a16_us: 10.0, fp16_us: 11.0 },
        ];
        let s = render_fig3(&cells);
        assert!(s.contains("max speedup 1.30x"));
    }

    #[test]
    fn bottleneck_report_renders() {
        let m = MachineConfig::ascend910();
        let r = Simulator::new(m.clone())
            .run(&kernels::schedule(&m, &GemmProblem::new(8, 2048, 7168), Strategy::SplitK).unwrap())
            .unwrap();
        let s = render_bottleneck(&m, &r);
        assert!(s.contains("dequant workspace"));
        assert!(s.contains("MEMORY TRANSFER"));
    }

    #[test]
    fn chunked_render_reports_traffic_drop() {
        let cells = vec![ChunkedCell {
            model: "deepseek".into(),
            n: 512,
            k: 16384,
            batch: 8,
            chunks: 4,
            chunked_us: 10.0,
            splitk_us: 14.0,
            fp16_us: 20.0,
            ws_hbm_splitk: 4.0e6,
            ws_hbm_chunked: 0.0,
        }];
        let s = render_chunked(&cells);
        assert!(s.contains("1.40x"));
        assert!(s.contains("workspace HBM traffic"));
        let j = chunked_json(&cells).to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.as_arr().unwrap()[0].req_usize("chunks").unwrap(), 4);
    }

    #[test]
    fn report_trait_is_object_safe() {
        // Reports render through dyn dispatch (mixed report lists).
        fn _take(_: &dyn Report) {}
    }

    #[test]
    fn json_round_trips() {
        let cells = vec![Fig2Cell {
            model: "x".into(), n: 2, k: 3, batch: 4, splitk_us: 1.0, dp_us: 2.0,
        }];
        let j = fig2_json(&cells).to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.as_arr().unwrap()[0].req_usize("n").unwrap(), 2);
    }
}
