//! The single step-simulation entry point (DESIGN.md §17).
//!
//! [`StepSim`] replaces the six `simulate_step*` free functions of
//! `analysis::layer` with one builder:
//!
//! ```text
//! StepSim::new(&machine, &step)        // or ::prefill(&machine, &chunk)
//!     .overlap(OverlapMode::Auto)
//!     .residency(ResidencyMode::Auto)
//!     .tuner(&mut tuner)               // or .resolver(|p| ...)
//!     .run()?
//! ```
//!
//! The builder walks the step graph as one uniform op list through the
//! [`StepOp`] trait — pricing, co-scheduling eligibility and residency
//! inputs all come off the trait, so a new op kind (a collective, a new
//! precision strategy) needs no changes here.  Defaults: overlap `Auto`,
//! residency `Off` (matching the old `simulate_step`), resolver
//! **required** — `run` errors when neither `.tuner()` nor `.resolver()`
//! was called.
//!
//! [`StepOp`]: super::stepop::StepOp

use super::coschedule;
use super::layer::{
    ChainOverlap, NodeReport, OverlapMode, OverlapPair, Resolution, StepNodeReport, StepReport,
};
use super::residency::{self, ResidencyMode};
use super::stepop::{Assignment, PriceCtx, PricedOp, StepOp};
use crate::ascend::{KernelTrace, MachineConfig, Simulator};
use crate::kernels::GemmProblem;
use crate::tune::Tuner;
use crate::workload::decode_layer::{DecodeStep, StepNode};
use crate::workload::PrefillStep;

/// Resolve through a tuner (cache hit, or live search that warms the
/// cache), tracking how each node was resolved.
pub(crate) fn tuner_resolve(tuner: &mut Tuner, p: &GemmProblem) -> anyhow::Result<Assignment> {
    let before = tuner.searches;
    let e = tuner.resolve(p)?;
    let resolution = if tuner.searches > before {
        Resolution::Searched
    } else {
        Resolution::CacheHit
    };
    Ok((e.strategy, e.tiling, resolution))
}

enum Resolver<'a> {
    Tuner(&'a mut Tuner),
    Custom(Box<dyn FnMut(&GemmProblem) -> anyhow::Result<Assignment> + 'a>),
}

/// Builder for one step-graph simulation — decode or prefill, any
/// overlap/residency mode, tuned or custom-resolved.
pub struct StepSim<'a> {
    machine: &'a MachineConfig,
    ops: Vec<StepNode>,
    batch: usize,
    kv_len: usize,
    overlap: OverlapMode,
    residency: ResidencyMode,
    resolver: Option<Resolver<'a>>,
}

impl<'a> StepSim<'a> {
    /// Simulate a full decode step (attention, glue, GEMM chain, MoE
    /// fan-out).
    pub fn new(machine: &'a MachineConfig, step: &DecodeStep) -> Self {
        Self::over(machine, step.nodes(), step.layer.batch, step.kv_len)
    }

    /// Simulate a causal prefill chunk (DESIGN.md §15): same graph shape
    /// as decode at M = chunk tokens, causal-context attention passes.
    /// The report's `batch` is the chunk's token count and `kv_len` the
    /// cache length after the chunk lands.
    pub fn prefill(machine: &'a MachineConfig, step: &PrefillStep) -> Self {
        Self::over(machine, step.nodes(), step.chunk_tokens(), step.kv_end())
    }

    /// Simulate an explicit op list — the escape hatch for synthetic
    /// graphs (tests, future collectives) that no workload type builds.
    pub fn over(
        machine: &'a MachineConfig,
        ops: Vec<StepNode>,
        batch: usize,
        kv_len: usize,
    ) -> Self {
        StepSim {
            machine,
            ops,
            batch,
            kv_len,
            overlap: OverlapMode::default(),
            residency: ResidencyMode::Off,
            resolver: None,
        }
    }

    /// Set the overlap mode (default `Auto`).
    pub fn overlap(mut self, mode: OverlapMode) -> Self {
        self.overlap = mode;
        self
    }

    /// Set the residency mode (default `Off`).
    pub fn residency(mut self, mode: ResidencyMode) -> Self {
        self.residency = mode;
        self
    }

    /// Resolve every GEMM node through the tuner (cache hit or live
    /// search).  Overrides any previous `.tuner()`/`.resolver()`.
    pub fn tuner(mut self, tuner: &'a mut Tuner) -> Self {
        self.resolver = Some(Resolver::Tuner(tuner));
        self
    }

    /// Resolve every GEMM node through a custom closure (fixed-strategy
    /// and forced-split paths).  Overrides any previous resolver.
    pub fn resolver(
        mut self,
        resolve: impl FnMut(&GemmProblem) -> anyhow::Result<Assignment> + 'a,
    ) -> Self {
        self.resolver = Some(Resolver::Custom(Box::new(resolve)));
        self
    }

    /// Price the step graph.
    pub fn run(self) -> anyhow::Result<StepReport> {
        let StepSim { machine, ops, batch, kv_len, overlap, residency, resolver } = self;
        let mut resolver = resolver.ok_or_else(|| {
            anyhow::anyhow!(
                "StepSim has no resolver: call .tuner(&mut tuner) or .resolver(|p| ...) \
                 before .run()"
            )
        })?;
        let mut resolve = |p: &GemmProblem| -> anyhow::Result<Assignment> {
            match &mut resolver {
                Resolver::Tuner(t) => tuner_resolve(t, p),
                Resolver::Custom(f) => f(p),
            }
        };
        simulate_ops(machine, &ops, batch, kv_len, overlap, residency, &mut resolve)
    }
}

/// The step-graph core: price an issue-ordered op list (decode or
/// prefill — the simulator only consumes the ops, the batch label and
/// the kv length) under an overlap mode and a residency mode.  Every op
/// is priced through [`StepOp::price`]; residency inputs come off
/// [`StepOp::residency_input`].
fn simulate_ops(
    machine: &MachineConfig,
    ops: &[StepNode],
    batch: usize,
    kv_len: usize,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    resolve: &mut dyn FnMut(&GemmProblem) -> anyhow::Result<Assignment>,
) -> anyhow::Result<StepReport> {
    let sim = Simulator::new(machine.clone());
    let mut priced: Vec<PricedOp> = Vec::with_capacity(ops.len());
    {
        let mut ctx = PriceCtx { machine, sim: &sim, resolve };
        for op in ops {
            priced.push(op.price(&mut ctx)?);
        }
    }
    let nodes: Vec<StepNodeReport> = priced.iter().map(|p| p.report.clone()).collect();
    let traces: Vec<Option<KernelTrace>> = priced.iter().map(|p| p.trace.clone()).collect();

    let sequential_ns: f64 = nodes.iter().map(|n| n.total_ns()).sum();
    let price_exact = matches!(mode, OverlapMode::Exact | OverlapMode::Auto);
    let ledger = build_ledger(&sim, &nodes, &traces, price_exact)?;
    let gain: f64 = ledger.iter().map(|p| p.total_gain_ns()).sum();
    let exact_gain: f64 = ledger.iter().map(|p| p.total_exact_gain_ns()).sum();
    let residency = match residency_mode {
        ResidencyMode::Off => None,
        ResidencyMode::Auto => {
            let mut inputs = Vec::new();
            let mut extra_ns = 0.0;
            for (op, p) in ops.iter().zip(&priced) {
                match op.residency_input(p) {
                    Some(input) => inputs.push(input),
                    None => extra_ns += p.report.total_ns(),
                }
            }
            Some(residency::plan_nodes(machine, &inputs, extra_ns, price_exact)?)
        }
    };
    Ok(StepReport {
        batch,
        kv_len,
        mode,
        nodes,
        ledger,
        sequential_ns,
        overlapped_ns: sequential_ns - gain,
        exact_ns: sequential_ns - exact_gain,
        residency,
    })
}

/// Build the overlap ledger over the step's GEMM sub-chain: expert
/// batches overlap internally (`count - 1` pairs), and each GEMM's
/// trailing reduce overlaps the next GEMM's dequant prologue.  Vector
/// glue between two GEMMs does not break eligibility — the consumer's
/// dequant touches only its own weights, so it is independent of every
/// intervening activation op (DESIGN.md §11).
///
/// `traces` holds each node's served kernel trace (aligned with `nodes`,
/// `None` for vector nodes): when `price_exact` is set (the `Exact` and
/// `Auto` modes — `Sequential`/`Overlapped` never serve the result, so
/// they skip the extra merged-trace simulations), wherever the
/// producer's reduce tail and the consumer's dequant prologue are
/// spliceable, the pair also carries the co-scheduler's exact
/// merged-trace pricing (DESIGN.md §12).  An entry appears whenever
/// either pricing finds a positive gain.
fn build_ledger(
    sim: &Simulator,
    nodes: &[StepNodeReport],
    traces: &[Option<KernelTrace>],
    price_exact: bool,
) -> anyhow::Result<Vec<OverlapPair>> {
    let gemms: Vec<(usize, &NodeReport)> = nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| match n {
            StepNodeReport::Gemm(g) => Some((i, g)),
            StepNodeReport::Vector(_) => None,
        })
        .collect();
    let mut ledger = Vec::new();
    let mut push = |ledger: &mut Vec<OverlapPair>,
                    producer: (usize, &NodeReport),
                    consumer: (usize, &NodeReport),
                    pairs: usize|
     -> anyhow::Result<()> {
        let (pi, p) = producer;
        let (ci, c) = consumer;
        let gain = p.reduce_tail_ns.min(c.dequant_slack_ns);
        let exact = match (&traces[pi], &traces[ci]) {
            (Some(pt), Some(ct)) if price_exact => {
                coschedule::pair_decision(sim, pt, ct, p.unit_ns + c.unit_ns)?
            }
            _ => None,
        };
        if gain > 0.0 || exact.is_some_and(|d| d.gain_ns > 0.0) {
            ledger.push(OverlapPair {
                producer: pi,
                consumer: ci,
                pairs,
                reduce_ns: p.reduce_tail_ns,
                slack_ns: c.dequant_slack_ns,
                gain_ns: gain,
                exact,
                chain: None,
                superseded: false,
            });
        }
        Ok(())
    };
    for &(i, g) in &gemms {
        if g.count > 1 {
            push(&mut ledger, (i, g), (i, g), g.count - 1)?;
        }
    }
    for w in gemms.windows(2) {
        push(&mut ledger, w[0], w[1], 1)?;
    }

    if price_exact {
        resolve_chains(sim, &gemms, traces, &mut ledger)?;
    }
    Ok(ledger)
}

/// Chain-level co-scheduling pass (DESIGN.md §13): for every consecutive
/// GEMM triple whose producer tail saturates the first prologue, price
/// the two-consumer chain splice and apply it greedily when it strictly
/// beats BOTH the two pair decisions it replaces and their first-order
/// ledger terms.  Each prologue is consumed by at most one splice: a
/// chained producer's second consumer supersedes the (first consumer ->
/// second consumer) pair, and a superseded or already-chained entry is
/// never chained again — no vector engine is double-booked across
/// decisions.
fn resolve_chains(
    sim: &Simulator,
    gemms: &[(usize, &NodeReport)],
    traces: &[Option<KernelTrace>],
    ledger: &mut Vec<OverlapPair>,
) -> anyhow::Result<()> {
    for w in gemms.windows(3) {
        let [(ai, a), (bi, b), (ci, c)] = [w[0], w[1], w[2]];
        // Chains only over single-instance nodes: an expert batch in the
        // middle would run count-1 more instances between the spliced
        // first consumer and the second one, evicting the carried
        // partials far beyond the one attenuation step the merged trace
        // prices — the three-kernel simulation would overstate the gain.
        if a.count != 1 || b.count != 1 || c.count != 1 {
            continue;
        }
        let (Some(ta), Some(tb), Some(tc)) = (&traces[ai], &traces[bi], &traces[ci]) else {
            continue;
        };
        if !coschedule::saturates(ta, tb) {
            continue;
        }
        let entry_pos = |p: usize, q: usize, l: &[OverlapPair]| {
            l.iter().position(|e| e.producer == p && e.consumer == q)
        };
        // Skip when either prologue is already spoken for.
        let first = entry_pos(ai, bi, ledger);
        if first.is_some_and(|i| ledger[i].chain.is_some() || ledger[i].superseded) {
            continue;
        }
        let second = entry_pos(bi, ci, ledger);
        if second.is_some_and(|i| ledger[i].chain.is_some() || ledger[i].superseded) {
            continue;
        }
        let sequential = a.unit_ns + b.unit_ns + c.unit_ns;
        let Some(decision) = coschedule::chain_decision(sim, ta, tb, tc, sequential)? else {
            continue;
        };
        let replaced_exact = first.map_or(0.0, |i| ledger[i].exact_gain_ns())
            + second.map_or(0.0, |i| ledger[i].exact_gain_ns());
        let replaced_ledger =
            first.map_or(0.0, |i| ledger[i].gain_ns) + second.map_or(0.0, |i| ledger[i].gain_ns);
        if decision.gain_ns <= replaced_exact.max(replaced_ledger) + 1e-9 {
            continue;
        }
        let chain = ChainOverlap { second_consumer: ci, decision };
        match first {
            Some(i) => ledger[i].chain = Some(chain),
            None => ledger.push(OverlapPair {
                producer: ai,
                consumer: bi,
                pairs: 1,
                reduce_ns: a.reduce_tail_ns,
                slack_ns: b.dequant_slack_ns,
                gain_ns: a.reduce_tail_ns.min(b.dequant_slack_ns),
                exact: None,
                chain: Some(chain),
                superseded: false,
            }),
        }
        if let Some(i) = second {
            ledger[i].superseded = true;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernels::{self, Strategy};
    use crate::model::llm::layer_geometry;
    use crate::workload::decode_layer::DecodeLayer;

    #[test]
    fn run_without_a_resolver_is_a_clear_error() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let err = StepSim::new(&m, &step).run().unwrap_err();
        assert!(err.to_string().contains("no resolver"), "unexpected error: {err}");
    }

    #[test]
    fn builder_defaults_match_the_plain_step_path() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let rep = StepSim::new(&m, &step)
            .resolver(|p| {
                Ok((
                    Strategy::Fused,
                    kernels::select_tiling(&m, p, Strategy::Fused)?,
                    Resolution::Heuristic,
                ))
            })
            .run()
            .unwrap();
        assert_eq!(rep.mode, OverlapMode::Auto);
        assert!(rep.residency.is_none(), "residency defaults Off");
        assert_eq!(rep.batch, 8);
        assert_eq!(rep.kv_len, 2048);
        assert!(rep.served_ns() > 0.0 && rep.served_ns() <= rep.sequential_ns * 1.000001);
    }

    #[test]
    fn later_resolver_calls_override_earlier_ones() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        // A failing resolver overridden by a working one must not fire.
        let rep = StepSim::new(&m, &step)
            .resolver(|_| anyhow::bail!("must never be called"))
            .resolver(|p| {
                Ok((
                    Strategy::SplitK,
                    kernels::select_tiling(&m, p, Strategy::SplitK)?,
                    Resolution::Heuristic,
                ))
            })
            .run()
            .unwrap();
        assert!(rep.sequential_ns > 0.0);
    }
}
