//! Chrome-trace (about://tracing / Perfetto) export of a simulated kernel.
//!
//! Turns a [`SimReport`] into the Trace Event JSON format so the phase
//! overlap, barriers and per-stream occupancy can be inspected visually —
//! the simulator's equivalent of the NPU profiler timelines the paper's
//! authors used for §4.2.

use crate::ascend::npu::SimReport;
use crate::ascend::trace::Unit;
use crate::util::json::Json;

/// Build the Trace Event JSON for one simulated kernel.
///
/// Rows (tids): 0 = sync (launch/barriers), 1 = cube stream, 2 = vector
/// stream, 3 = HBM stream, 4 = L2 stream.  Durations are the per-group
/// stream times laid out sequentially with barriers between groups.
pub fn chrome_trace(report: &SimReport) -> Json {
    let mut events = Vec::new();
    let mut emit = |name: String, tid: u32, ts_us: f64, dur_us: f64| {
        if dur_us <= 0.0 {
            return;
        }
        events.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("cat", Json::str("sim")),
            ("ph", Json::str("X")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(tid as f64)),
            ("ts", Json::num(ts_us)),
            ("dur", Json::num(dur_us)),
        ]));
    };

    let mut cursor = 0.0f64; // µs
    emit("launch".into(), 0, cursor, report.launch_ns / 1e3);
    cursor += report.launch_ns / 1e3;

    let barrier_each = if report.groups.len() > 1 {
        report.barrier_ns / 1e3 / (report.groups.len() - 1) as f64
    } else {
        0.0
    };

    for (gi, group) in report.groups.iter().enumerate() {
        if gi > 0 {
            emit(format!("barrier {gi}"), 0, cursor, barrier_each);
            cursor += barrier_each;
        }
        // Streams of this group run concurrently from `cursor`.
        emit(format!("group{gi} hbm"), 3, cursor, group.hbm_ns / 1e3);
        emit(format!("group{gi} l2"), 4, cursor, group.l2_ns / 1e3);
        emit(format!("group{gi} cube"), 1, cursor, group.cube_ns / 1e3);
        emit(format!("group{gi} vector"), 2, cursor, group.vector_ns / 1e3);
        // Phase annotations on their unit's row.
        for &pi in &group.phases {
            let pt = &report.phase_times[pi];
            let tid = match pt.unit {
                Unit::Cube => 1,
                Unit::Vector => 2,
            };
            emit(format!("{} ({} engines)", pt.name, pt.active_engines),
                 tid, cursor, pt.compute_ns / 1e3);
        }
        emit(format!("group{gi} fill"), 0, cursor, group.fill_ns / 1e3);
        cursor += group.total_ns / 1e3;
    }

    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ns")),
        (
            "otherData",
            Json::obj(vec![
                ("kernel", Json::str(report.name.clone())),
                ("total_us", Json::num(report.total_ns / 1e3)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::{MachineConfig, Simulator};
    use crate::kernels::{self, GemmProblem, Strategy};

    fn report() -> SimReport {
        let m = MachineConfig::ascend910();
        Simulator::new(m.clone())
            .run(&kernels::schedule(&m, &GemmProblem::new(8, 512, 16384), Strategy::SplitK).unwrap())
            .unwrap()
    }

    #[test]
    fn emits_valid_trace_json() {
        let r = report();
        let j = chrome_trace(&r);
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.req_arr("traceEvents").unwrap();
        assert!(events.len() >= 5);
        for e in events {
            assert_eq!(e.req_str("ph").unwrap(), "X");
            assert!(e.get("dur").unwrap().as_f64().unwrap() > 0.0);
        }
    }

    #[test]
    fn timeline_spans_the_total() {
        let r = report();
        let j = chrome_trace(&r);
        let events = j.req_arr("traceEvents").unwrap();
        let end = events
            .iter()
            .map(|e| {
                e.get("ts").unwrap().as_f64().unwrap()
                    + e.get("dur").unwrap().as_f64().unwrap()
            })
            .fold(0.0f64, f64::max);
        // Last event must end at (or just below) the reported total.
        assert!((end - r.total_ns / 1e3).abs() / (r.total_ns / 1e3) < 0.05,
            "end {end} vs total {}", r.total_ns / 1e3);
    }

    #[test]
    fn barrier_present_for_multi_group_kernels() {
        let r = report();
        assert!(r.groups.len() >= 2, "need a 3-phase kernel for this test");
        let text = chrome_trace(&r).to_string();
        assert!(text.contains("barrier 1"));
    }
}
