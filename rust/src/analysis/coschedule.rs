//! Phase-level cross-node co-scheduler (DESIGN.md §12).
//!
//! PR 3's overlap ledger prices the reduce/dequant overlap to first order:
//! `min(exposed_reduce, vector_slack)` per adjacent GEMM pair.  This
//! module prices it *exactly* by restructuring the schedules themselves:
//!
//! 1. [`splice`] takes two adjacent kernel traces, removes the producer's
//!    exposed reduce tail (the trailing barrier group of reduce phases)
//!    and splices those steps — engine tags preserved, intra-engine
//!    ordering preserved, partial reads re-classed as
//!    [`BufferClass::CarriedPartial`] so the boundary residency is the
//!    producer's — into the consumer's weight-only dequant prologue.
//! 2. [`pair_decision`] re-runs the cycle-accurate simulator on the merged
//!    trace ([`Simulator::run_merged`]) and compares it against the
//!    sequential pair.  The co-scheduler *declines* to merge when the
//!    merged trace prices slower, so the decision's gain is clamped at
//!    zero and `OverlapMode::Exact` is never slower than `Sequential` by
//!    construction.
//!
//! The splice is sound because the two workloads touch disjoint buffers:
//! the reduce reads the producer's split partials and writes the
//! producer's output; the dequant prologue reads only the consumer's
//! packed weights and quant params.  They share only the vector engines,
//! and the splice serializes them *per engine* (no engine is ever
//! double-booked at any simulated cycle — each engine's step list is a
//! single sequence).  The consumer's chunk-group tags are untouched, so
//! the chunked pipeline's rotation events are unchanged.
//!
//! PR 5 adds the *chain-level* schedule (DESIGN.md §13): when the
//! producer's exposed tail [`saturates`] the first prologue (more carried
//! steps than the prologue has dequant steps to hide them under),
//! [`splice_chain`] spreads the overflow across up to TWO downstream
//! dequant prologues and re-balances each merged phase least-loaded over
//! the machine's full vector-engine set.  The overflow crosses two kernel
//! boundaries, so `Simulator::run_merged` attenuates its carried-partial
//! residency by the intervening kernel's working set; [`chain_decision`]
//! declines chains that price slower, exactly like the pair decision.

use crate::ascend::{
    BufferClass, KernelTrace, MergedTrace, Phase, ResidencyLedger, Simulator, TileStep,
};

/// Exact pricing of one co-scheduled adjacent pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PairDecision {
    /// The pair priced back to back (producer + consumer, full traces).
    pub sequential_ns: f64,
    /// The merged trace's simulated latency.
    pub merged_ns: f64,
    /// What the co-scheduler realizes: `max(0, sequential - merged)` —
    /// zero when it declines to merge.
    pub gain_ns: f64,
}

impl PairDecision {
    /// Whether the co-scheduler actually serves the merged trace.
    pub fn merged_applied(&self) -> bool {
        self.gain_ns > 0.0
    }
}

/// Re-class a step's `Partial` reads as `CarriedPartial`: once spliced
/// into the downstream kernel, the bytes belong to the *upstream* kernel's
/// split buffers and must be priced under its residency.
fn carry_step(step: &TileStep) -> TileStep {
    let mut s = *step;
    for read in s.reads.iter_mut() {
        if read.0 == BufferClass::Partial && read.1 > 0 {
            read.0 = BufferClass::CarriedPartial;
        }
    }
    s
}

/// Splice `producer`'s exposed reduce tail into `consumer`'s dequant
/// prologue, returning the merged two-kernel trace — or `None` when either
/// side has no spliceable sub-trace (no exposed reduce, or the consumer
/// does not open with a weight-only dequant phase).
pub fn splice(producer: &KernelTrace, consumer: &KernelTrace) -> Option<MergedTrace> {
    let tail = producer.exposed_reduce_range()?;
    let dq = consumer.dequant_prologue()?;

    // The producer loses its tail group (and, in simulation, the barrier
    // that fronted it — one fewer group).
    let mut head = producer.clone();
    head.phases.truncate(tail.start);
    head.name = format!("{}_head", producer.name);

    // Collect the tail's steps per engine, preserving phase order and each
    // engine's intra-phase ordering, with partial reads carried.
    let mut carried: Vec<Vec<TileStep>> = Vec::new();
    for phase in &producer.phases[tail] {
        if phase.steps_per_engine.len() > carried.len() {
            carried.resize(phase.steps_per_engine.len(), Vec::new());
        }
        for (e, steps) in phase.steps_per_engine.iter().enumerate() {
            carried[e].extend(steps.iter().map(carry_step));
        }
    }

    // Prepend the carried steps to the prologue's engines: the leftover
    // reduce work drains first on each engine, then its dequant steps run
    // — both sequences keep their own order, and no engine is ever booked
    // twice in the same slot.
    let mut spliced = consumer.clone();
    let phase: &mut Phase = &mut spliced.phases[dq];
    if carried.len() > phase.steps_per_engine.len() {
        phase.steps_per_engine.resize(carried.len(), Vec::new());
    }
    for (e, mut steps) in carried.into_iter().enumerate() {
        if steps.is_empty() {
            continue;
        }
        steps.append(&mut phase.steps_per_engine[e]);
        phase.steps_per_engine[e] = steps;
    }
    phase.name = "spliced_dequant";
    spliced.name = format!("{}_spliced", consumer.name);

    Some(MergedTrace {
        name: format!("merged_{}__{}", producer.name, consumer.name),
        kernels: vec![head, spliced],
    })
}

/// Price one adjacent pair exactly: splice, simulate the merged trace, and
/// decide.  `sequential_ns` is the pair's back-to-back latency under the
/// served schedules (the caller already has it from pricing the nodes —
/// `producer_ns + consumer_ns`, one GEMM each).  Returns `None` when the
/// pair is not spliceable.
pub fn pair_decision(
    sim: &Simulator,
    producer: &KernelTrace,
    consumer: &KernelTrace,
    sequential_ns: f64,
) -> anyhow::Result<Option<PairDecision>> {
    pair_decision_with(sim, producer, consumer, sequential_ns, &ResidencyLedger::default())
}

/// [`pair_decision`] under a step-level base ledger: the residency
/// planner prices the same splices with the pinned-weight residency and
/// its capacity carve-out applied to both kernels (DESIGN.md §13).
pub fn pair_decision_with(
    sim: &Simulator,
    producer: &KernelTrace,
    consumer: &KernelTrace,
    sequential_ns: f64,
    base: &ResidencyLedger,
) -> anyhow::Result<Option<PairDecision>> {
    match splice(producer, consumer) {
        Some(merged) => Ok(Some(decide_merged(sim, &merged, sequential_ns, base)?)),
        None => Ok(None),
    }
}

/// Price an already-spliced merged trace against its sequential latency.
/// Uses the simulator's detail-free price path, which is bit-identical to
/// `run_merged_with` (the report assembly it skips never feeds the float
/// accumulation) — this is what lets the residency planner hoist splice
/// construction out of its prefix loop and re-price cheaply.
pub fn decide_merged(
    sim: &Simulator,
    merged: &MergedTrace,
    sequential_ns: f64,
    base: &ResidencyLedger,
) -> anyhow::Result<PairDecision> {
    let merged_ns = sim.price_merged_with(merged, base)?;
    Ok(PairDecision {
        sequential_ns,
        merged_ns,
        gain_ns: (sequential_ns - merged_ns).max(0.0),
    })
}

/// Steps in the producer's exposed reduce tail (0 when nothing is
/// exposed) — the work a splice has to place downstream.
pub fn exposed_tail_steps(producer: &KernelTrace) -> usize {
    match producer.exposed_reduce_range() {
        Some(range) => producer.phases[range].iter().map(|p| p.total_steps()).sum(),
        None => 0,
    }
}

/// Steps in the consumer's dequant prologue (0 when it has none) — the
/// splice capacity of one downstream kernel: one carried reduce step per
/// dequant step keeps the merged phase's transfer stream able to hide the
/// moved compute, so a tail larger than this *saturates* the prologue.
pub fn prologue_steps(consumer: &KernelTrace) -> usize {
    match consumer.dequant_prologue() {
        Some(dq) => consumer.phases[dq].total_steps(),
        None => 0,
    }
}

/// Whether `producer`'s exposed tail overflows `consumer`'s prologue —
/// the gate for trying the two-consumer chain splice (DESIGN.md §13).
pub fn saturates(producer: &KernelTrace, consumer: &KernelTrace) -> bool {
    let tail = exposed_tail_steps(producer);
    tail > 0 && tail > prologue_steps(consumer)
}

/// Distribute carried steps over a prologue's engines *least-loaded*:
/// unlike the adjacent-pair splice (which preserves the producer's engine
/// tags), the chain splice re-balances — each carried step goes to the
/// engine with the fewest total (dequant + carried) steps, ties to the
/// lowest index, and the engine list may grow up to the machine's vector
/// cores.  Sound for the same reason the pair splice is: every carried
/// reduce step is independent of every other (each reduces a distinct
/// output tile) and of every dequant step (disjoint buffers), so any
/// serialized per-engine order is legal; carried steps still run before
/// the engine's dequant steps.
fn distribute_balanced(phase: &mut Phase, carried: &[TileStep], vec_engines: usize) {
    if carried.is_empty() {
        return;
    }
    let slots = vec_engines.max(phase.steps_per_engine.len());
    phase.steps_per_engine.resize(slots, Vec::new());
    let mut load: Vec<usize> = phase.steps_per_engine.iter().map(|s| s.len()).collect();
    let mut assigned: Vec<Vec<TileStep>> = vec![Vec::new(); slots];
    for step in carried {
        let e = (0..slots).min_by_key(|&e| (load[e], e)).unwrap();
        load[e] += 1;
        assigned[e].push(*step);
    }
    for (e, mut steps) in assigned.into_iter().enumerate() {
        if steps.is_empty() {
            continue;
        }
        steps.append(&mut phase.steps_per_engine[e]);
        phase.steps_per_engine[e] = steps;
    }
    phase.name = "spliced_dequant";
}

/// Chain-level splice (DESIGN.md §13): when `producer`'s exposed tail
/// saturates `first`'s dequant prologue, hide the overflow in `second`'s
/// prologue as well — `first` absorbs one carried step per dequant step
/// (its capacity), `second` takes the rest — and re-balance each merged
/// phase least-loaded across the machine's vector engines.  Returns the
/// three-kernel merged trace, or `None` when any side lacks its
/// spliceable sub-trace.  The overflow steps read the producer's partials
/// across TWO kernel boundaries, which `Simulator::run_merged` prices
/// with one attenuation step — the chain only serves when the exact
/// re-simulation still beats the alternatives.
pub fn splice_chain(
    vec_engines: usize,
    producer: &KernelTrace,
    first: &KernelTrace,
    second: &KernelTrace,
) -> Option<MergedTrace> {
    let tail = producer.exposed_reduce_range()?;
    let dq1 = first.dequant_prologue()?;
    let dq2 = second.dequant_prologue()?;

    let mut head = producer.clone();
    head.phases.truncate(tail.start);
    head.name = format!("{}_head", producer.name);

    // Flatten the tail's steps (phase order, then engine order) with
    // partial reads carried; the re-balance re-assigns engines anyway.
    let mut carried: Vec<TileStep> = Vec::new();
    for phase in &producer.phases[tail] {
        for steps in &phase.steps_per_engine {
            carried.extend(steps.iter().map(carry_step));
        }
    }

    let cap1 = prologue_steps(first).min(carried.len());
    let (to_first, to_second) = carried.split_at(cap1);

    let mut spliced1 = first.clone();
    distribute_balanced(&mut spliced1.phases[dq1], to_first, vec_engines);
    spliced1.name = format!("{}_spliced", first.name);

    let mut spliced2 = second.clone();
    distribute_balanced(&mut spliced2.phases[dq2], to_second, vec_engines);
    spliced2.name = format!("{}_spliced2", second.name);

    Some(MergedTrace {
        name: format!(
            "chain_{}__{}__{}",
            producer.name, first.name, second.name
        ),
        kernels: vec![head, spliced1, spliced2],
    })
}

/// Price one two-consumer chain exactly (DESIGN.md §13).  `sequential_ns`
/// is the three nodes' back-to-back latency under the served schedules.
/// Returns `None` when the chain is not spliceable.
pub fn chain_decision(
    sim: &Simulator,
    producer: &KernelTrace,
    first: &KernelTrace,
    second: &KernelTrace,
    sequential_ns: f64,
) -> anyhow::Result<Option<PairDecision>> {
    let engines = sim.machine.total_vector_cores();
    match splice_chain(engines, producer, first, second) {
        Some(merged) => Ok(Some(decide_merged(
            sim,
            &merged,
            sequential_ns,
            &ResidencyLedger::default(),
        )?)),
        None => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::{ComputeOp, MachineConfig};
    use crate::kernels::tiling::Tiling;
    use crate::kernels::{chunked, splitk, GemmProblem, ReduceMode};

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    /// A small resident-partial producer: N=512, K=16384, S=16 (the
    /// paper's acceptance decode shape; partials + workspace fit L2).
    fn producer() -> KernelTrace {
        let p = GemmProblem::new(8, 512, 16384);
        let t = Tiling {
            bm: 16,
            bn: 256,
            bk: 64,
            splits: 16,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        splitk::schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap()
    }

    fn consumer() -> KernelTrace {
        let p = GemmProblem::new(8, 2048, 8192);
        let t = Tiling {
            bm: 16,
            bn: 128,
            bk: 128,
            splits: 2,
            chunks: 4,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        chunked::schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap()
    }

    #[test]
    fn splice_moves_the_tail_and_conserves_work() {
        let prod = producer();
        let cons = consumer();
        let merged = splice(&prod, &cons).expect("pair must be spliceable");
        assert_eq!(merged.kernels.len(), 2);
        let (head, spliced) = (&merged.kernels[0], &merged.kernels[1]);

        // The head lost exactly the exposed reduce group.
        let tail = prod.exposed_reduce_range().unwrap();
        assert_eq!(head.phases.len(), tail.start);
        assert_eq!(head.exposed_reduce_range(), None);

        // MACs and reduce steps are conserved across the splice.
        let macs = head.total_macs() + spliced.total_macs();
        assert_eq!(macs, prod.total_macs() + cons.total_macs());
        let reduces = head.reduce_steps() + spliced.reduce_steps();
        assert_eq!(reduces, prod.reduce_steps() + cons.reduce_steps());

        // The spliced prologue serializes per engine: carried steps first
        // (reduce ops on CarriedPartial), then the original dequant steps.
        let phase = &spliced.phases[0];
        assert_eq!(phase.name, "spliced_dequant");
        let moved: usize = prod.phases[tail].iter().map(|p| p.total_steps()).sum();
        assert_eq!(phase.total_steps(), cons.phases[0].total_steps() + moved);
        for steps in &phase.steps_per_engine {
            let first_dequant = steps
                .iter()
                .position(|s| matches!(s.compute, ComputeOp::Dequant { .. }));
            if let Some(i) = first_dequant {
                assert!(
                    steps[..i]
                        .iter()
                        .all(|s| matches!(s.compute, ComputeOp::Reduce { .. })),
                    "carried reduce steps must precede every dequant step"
                );
                assert!(
                    steps[i..]
                        .iter()
                        .all(|s| matches!(s.compute, ComputeOp::Dequant { .. })),
                    "dequant steps must keep their contiguous order"
                );
            }
        }
        // Partial reads were re-classed; no spliced step still reads the
        // producer's partials under this kernel's own residency.
        assert_eq!(phase.read_bytes(BufferClass::Partial), 0);
        assert!(phase.read_bytes(BufferClass::CarriedPartial) > 0);
        // The consumer's chunk tag survived (chunked prologue = chunk 0).
        assert_eq!(phase.chunk, cons.phases[0].chunk);
    }

    #[test]
    fn merged_trace_simulates_and_never_overbooks_engines() {
        let merged = splice(&producer(), &consumer()).unwrap();
        let sim = Simulator::new(m());
        for k in &merged.kernels {
            assert!(
                k.phases
                    .iter()
                    .all(|p| p.steps_per_engine.len() <= m().total_vector_cores().max(m().ai_cores)),
                "engine lists must stay within the machine"
            );
        }
        let r = sim.run_merged(&merged).unwrap();
        assert!(r.total_ns > 0.0 && r.total_ns.is_finite());
    }

    #[test]
    fn decision_gain_is_exact_and_clamped() {
        let sim = Simulator::new(m());
        let prod = producer();
        let cons = consumer();
        let seq = sim.run(&prod).unwrap().total_ns + sim.run(&cons).unwrap().total_ns;
        let d = pair_decision(&sim, &prod, &cons, seq).unwrap().unwrap();
        assert!((d.sequential_ns - seq).abs() < 1e-9);
        assert!(d.gain_ns >= 0.0);
        assert!((d.gain_ns - (seq - d.merged_ns).max(0.0)).abs() < 1e-9);
        // This pair's partials are L2-resident, so the merged trace
        // recovers the tail group plus its barrier: a strict win.
        assert!(d.merged_applied(), "resident-partial pair must merge: {d:?}");
    }

    #[test]
    fn unspliceable_pairs_return_none() {
        let m = m();
        // S=1 producer: no reduce at all, nothing exposed.
        let p = GemmProblem::new(8, 2048, 7168);
        let t = crate::kernels::tiling::select_data_parallel(&m, &p).unwrap();
        let dp = crate::kernels::data_parallel::schedule(&m, &p, &t).unwrap();
        assert!(splice(&dp, &consumer()).is_none());
        // FP16-native consumer: no dequant prologue.
        let t = crate::kernels::tiling::select_fp16(&m, &p).unwrap();
        let fp16 = crate::kernels::fp16_native::schedule(&m, &p, &t).unwrap();
        assert!(splice(&producer(), &fp16).is_none());
        let sim = Simulator::new(m);
        assert!(pair_decision(&sim, &producer(), &fp16, 1.0).unwrap().is_none());
    }

    /// A saturating producer: the expert down-projection shape under a
    /// barrier reduce exposes all 224 output tiles.
    fn saturating_producer() -> KernelTrace {
        let p = GemmProblem::new(8, 7168, 2048);
        let t = Tiling {
            bm: 16,
            bn: 32,
            bk: 128,
            splits: 4,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        splitk::schedule_reduce(&m(), &p, &t, ReduceMode::Barrier).unwrap()
    }

    /// A consumer with a small (32-step) dequant prologue.
    fn small_consumer() -> KernelTrace {
        let p = GemmProblem::new(8, 512, 2048);
        let t = Tiling {
            bm: 16,
            bn: 256,
            bk: 128,
            splits: 2,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        splitk::schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap()
    }

    #[test]
    fn chain_splice_splits_at_prologue_capacity_and_rebalances() {
        let m = m();
        let prod = saturating_producer();
        let c1 = small_consumer();
        let c2 = small_consumer();
        assert_eq!(exposed_tail_steps(&prod), 224, "barrier reduce exposes every tile");
        assert_eq!(prologue_steps(&c1), 32);
        assert!(saturates(&prod, &c1));
        assert!(!saturates(&c1, &c2), "the small pair itself does not saturate");
        let merged = splice_chain(m.total_vector_cores(), &prod, &c1, &c2)
            .expect("chain must be spliceable");
        assert_eq!(merged.kernels.len(), 3);
        let (head, s1, s2) = (&merged.kernels[0], &merged.kernels[1], &merged.kernels[2]);

        // Work conservation across the three kernels.
        let macs: u64 = merged.kernels.iter().map(|k| k.total_macs()).sum();
        assert_eq!(macs, prod.total_macs() + c1.total_macs() + c2.total_macs());
        let reduces: usize = merged.kernels.iter().map(|k| k.reduce_steps()).sum();
        assert_eq!(reduces, prod.reduce_steps() + c1.reduce_steps() + c2.reduce_steps());
        assert_eq!(head.exposed_reduce_range(), None);

        // The split lands exactly at the first prologue's capacity.
        let tail_steps = exposed_tail_steps(&prod);
        let cap1 = prologue_steps(&c1).min(tail_steps);
        assert_eq!(s1.phases[0].total_steps(), prologue_steps(&c1) + cap1);
        assert_eq!(
            s2.phases[0].total_steps(),
            prologue_steps(&c2) + (tail_steps - cap1)
        );

        // Re-balance: engine lists stay within the machine, per-engine
        // ordering keeps carried reduce steps ahead of dequant steps, and
        // the carried load is near-even (least-loaded greedy).
        for spliced in [s1, s2] {
            let phase = &spliced.phases[0];
            assert!(phase.steps_per_engine.len() <= m.total_vector_cores());
            for steps in &phase.steps_per_engine {
                let mut seen_dequant = false;
                for s in steps {
                    match s.compute {
                        ComputeOp::Reduce { .. } => {
                            assert!(!seen_dequant, "reduce after dequant: ordering broken")
                        }
                        ComputeOp::Dequant { .. } => seen_dequant = true,
                        _ => {}
                    }
                }
            }
            let loads: Vec<usize> =
                phase.steps_per_engine.iter().map(|s| s.len()).filter(|&l| l > 0).collect();
            let (min, max) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
            assert!(max - min <= 1, "least-loaded fill must stay near-even: {loads:?}");
            // Carried reads were re-classed.
            assert_eq!(phase.read_bytes(BufferClass::Partial), 0);
            assert!(phase.read_bytes(BufferClass::CarriedWeight) == 0);
        }
        assert!(
            s1.phases[0].read_bytes(BufferClass::CarriedPartial) > 0
                && s2.phases[0].read_bytes(BufferClass::CarriedPartial) > 0,
            "both prologues carry part of the tail"
        );

        // The merged chain validates and prices.
        let sim = Simulator::new(m.clone());
        let r = sim.run_merged(&merged).unwrap();
        assert!(r.total_ns > 0.0 && r.total_ns.is_finite());
    }

    #[test]
    fn chain_decision_clamps_and_declines() {
        let m = m();
        let sim = Simulator::new(m.clone());
        let prod = producer();
        let cons = consumer();
        let unit_p = sim.run(&prod).unwrap().total_ns;
        let unit_c = sim.run(&cons).unwrap().total_ns;
        let seq = unit_p + 2.0 * unit_c;
        let d = chain_decision(&sim, &prod, &cons, &cons, seq).unwrap().unwrap();
        assert!(d.gain_ns >= 0.0);
        assert!((d.gain_ns - (seq - d.merged_ns).max(0.0)).abs() < 1e-9);
        // Unspliceable chains return None (fp16 consumer has no prologue).
        let p = GemmProblem::new(8, 2048, 7168);
        let t = crate::kernels::tiling::select_fp16(&m, &p).unwrap();
        let fp16 = crate::kernels::fp16_native::schedule(&m, &p, &t).unwrap();
        assert!(chain_decision(&sim, &prod, &cons, &fp16, seq).unwrap().is_none());
        assert!(chain_decision(&sim, &prod, &fp16, &cons, seq).unwrap().is_none());
    }

    #[test]
    fn internal_expert_pair_splices_with_itself() {
        // One routed expert's down-projection (the MoE expert-batch
        // internal pair: instance i's tail hides in instance i+1's
        // prologue).
        let p = GemmProblem::new(1, 7168, 2048);
        let t = Tiling {
            bm: 16,
            bn: 32,
            bk: 128,
            splits: 4,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        let tr = splitk::schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap();
        let sim = Simulator::new(m());
        let unit = sim.run(&tr).unwrap().total_ns;
        let d = pair_decision(&sim, &tr, &tr, 2.0 * unit).unwrap().unwrap();
        assert!(d.merged_ns > 0.0);
        assert!(d.gain_ns >= 0.0);
    }
}
