//! Decode-layer / decode-step report types and the GEMM-chain layer
//! simulator (DESIGN.md §10–§11).
//!
//! Two granularities:
//! * [`simulate_layer`] — the GEMM sub-chain only (PR-2 surface): layer
//!   latency is the sum of the node kernel times, each priced under the
//!   served reduce and under Algorithm 1's barrier reduce.
//! * the full decode/prefill step — priced by
//!   [`StepSim`](super::stepsim::StepSim), which walks the step graph as
//!   one uniform [`StepOp`](super::stepop::StepOp) list: attention
//!   score/softmax/AV, RMSNorm/residual/activation glue and MoE routing
//!   priced by the vecpass bandwidth model, the MoE expert fan-out as
//!   batched GEMM nodes, an [`OverlapMode`] ledger that overlaps node i's
//!   exposed Split-K reduce with node i+1's weight-only dequant prologue,
//!   and an optional step-level weight-residency plan.
//!
//! The old `simulate_step*` free functions live on as thin
//! `#[deprecated]` shims around `StepSim` for one PR — migrate
//! `simulate_step(_with)` / `simulate_step_tuned(_with)` /
//! `simulate_prefill_step(_tuned)_with` calls to the builder.

use super::coschedule::PairDecision;
use super::report::Report;
use super::residency::{ResidencyMode, ResidencyPlan};
use super::stepop::{simulate_gemm_node, Assignment};
use super::stepsim::{tuner_resolve, StepSim};
use crate::ascend::{MachineConfig, Simulator};
use crate::kernels::{self, tiling::Tiling, GemmProblem, Strategy};
use crate::tune::Tuner;
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::decode_layer::{DecodeLayer, DecodeStep, GemmKind, VectorOp};

/// How one graph node's (strategy, tiling) assignment was determined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// Served from the persisted tune cache (the hot-path lookup).
    CacheHit,
    /// A live search filled the cache (first run / cold cache).
    Searched,
    /// A concrete strategy with its heuristic tiling (no tuner involved).
    Heuristic,
}

impl Resolution {
    pub fn name(&self) -> &'static str {
        match self {
            Resolution::CacheHit => "cache",
            Resolution::Searched => "searched",
            Resolution::Heuristic => "heuristic",
        }
    }
}

/// Whether the step simulator may overlap adjacent GEMM nodes
/// (DESIGN.md §11–§12): node i's exposed post-barrier reduce runs in the
/// vector-engine slack of node i+1's weight-only dequant prologue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// PR-2's ledger: nodes priced strictly back to back.
    Sequential,
    /// Every eligible adjacent pair overlaps under the first-order ledger
    /// (`min(exposed_reduce, vector_slack)` per pair).  With the ledger's
    /// gains clamped non-negative this is never slower than `Sequential`
    /// by construction.
    Overlapped,
    /// The phase-level co-scheduler (DESIGN.md §12): node i's reduce tail
    /// is spliced into node i+1's dequant phase and the merged trace is
    /// re-simulated, replacing the first-order ledger term with the exact
    /// simulated gain wherever a merged trace is available.  Each pair's
    /// merge is declined when it prices slower, so `Exact` is never
    /// slower than `Sequential` by construction.
    Exact,
    /// Price all three, serve `min(sequential, overlapped, exact)` — the
    /// never-slower guarantee is *structural*: neither a pessimistic
    /// ledger nor an adversarial merged trace can regress the served
    /// plan below the sequential chain or PR 3's ledger.
    #[default]
    Auto,
}

impl OverlapMode {
    /// Accepted `--overlap` spellings, first alias canonical.
    pub const CHOICES: &'static [(&'static [&'static str], OverlapMode)] = &[
        (&["sequential", "seq"], OverlapMode::Sequential),
        (&["overlapped", "overlap", "ledger"], OverlapMode::Overlapped),
        (&["exact", "coschedule"], OverlapMode::Exact),
        (&["auto"], OverlapMode::Auto),
    ];

    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::Sequential => "sequential",
            OverlapMode::Overlapped => "overlapped",
            OverlapMode::Exact => "exact",
            OverlapMode::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<OverlapMode> {
        let lower = name.to_ascii_lowercase();
        for (aliases, mode) in Self::CHOICES {
            if aliases.contains(&lower.as_str()) {
                return Ok(*mode);
            }
        }
        anyhow::bail!("unknown overlap mode '{name}'")
    }
}

/// One simulated GEMM node (`count` identical GEMMs for expert batches).
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub kind: GemmKind,
    pub problem: GemmProblem,
    /// Identical GEMMs this node issues back to back (1 for dense nodes).
    pub count: usize,
    pub strategy: Strategy,
    pub tiling: Tiling,
    pub resolution: Resolution,
    /// Simulated time of ONE GEMM under the served (auto) reduce schedule.
    pub unit_ns: f64,
    /// One GEMM under Algorithm 1's barrier reduce (>= unit_ns).
    pub unit_barrier_ns: f64,
    /// `count * unit_ns` — the node's sequential contribution.
    pub total_ns: f64,
    /// `count * unit_barrier_ns`.
    pub barrier_ns: f64,
    /// Exposed post-barrier reduce group of one GEMM (0 when the reduce
    /// streams entirely, or the strategy has no reduce) — what a
    /// downstream dequant can hide (DESIGN.md §11).
    pub reduce_tail_ns: f64,
    /// Vector-engine idle headroom of one GEMM's leading weight-only
    /// dequant phase (transfer time minus SIMD time) — where an upstream
    /// reduce can hide.
    pub dequant_slack_ns: f64,
}

impl NodeReport {
    /// What the pipelined reduce buys on this node (>= 1.0 by construction).
    pub fn reduce_speedup(&self) -> f64 {
        if self.total_ns == 0.0 {
            return 1.0;
        }
        self.barrier_ns / self.total_ns
    }
}

/// The simulated layer: the GEMM sub-chain at one batch size.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub batch: usize,
    pub nodes: Vec<NodeReport>,
}

impl LayerReport {
    /// Layer GEMM latency under the served schedules.
    pub fn layer_ns(&self) -> f64 {
        self.nodes.iter().map(|n| n.total_ns).sum()
    }

    /// Layer GEMM latency with every reduce behind the grid barrier.
    pub fn layer_barrier_ns(&self) -> f64 {
        self.nodes.iter().map(|n| n.barrier_ns).sum()
    }

    /// Per-decode-step GEMM latency for a model with `layers` layers.
    pub fn step_ns(&self, layers: usize) -> f64 {
        self.layer_ns() * layers as f64
    }

    pub fn node(&self, kind: GemmKind) -> Option<&NodeReport> {
        self.nodes.iter().find(|n| n.kind == kind)
    }

    /// Render the per-node table plus layer / step totals, scaling the
    /// step line to a `layers`-layer model.
    pub fn render_scaled(&self, layers: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Decode-layer GEMM graph — batch {} (simulated)\n",
            self.batch
        ));
        out.push_str(&format!(
            "{:<10} {:<20} {:>5} {:>12} {:>10} | {:>10} {:>11} {:>8}\n",
            "node", "shape", "x", "strategy", "via", "served_us", "barrier_us", "reduce"
        ));
        for n in &self.nodes {
            out.push_str(&format!(
                "{:<10} {:<20} {:>5} {:>12} {:>10} | {:>10.2} {:>11.2} {:>7.2}x\n",
                n.kind.name(),
                format!("m{}_n{}_k{}", n.problem.m, n.problem.n, n.problem.k),
                n.count,
                n.strategy.name(),
                n.resolution.name(),
                n.total_ns / 1e3,
                n.barrier_ns / 1e3,
                n.reduce_speedup(),
            ));
        }
        out.push_str(&format!(
            "\nlayer: {} served vs {} barrier-reduce ({:.3}x from reduce pipelining)\n",
            stats::fmt_ns(self.layer_ns()),
            stats::fmt_ns(self.layer_barrier_ns()),
            self.layer_barrier_ns() / self.layer_ns(),
        ));
        out.push_str(&format!(
            "step ({layers} layers): {}  -> {:.0} decode steps/s of pure GEMM headroom\n",
            stats::fmt_ns(self.step_ns(layers)),
            1e9 / self.step_ns(layers),
        ));
        out
    }
}

impl Report for LayerReport {
    fn render(&self) -> String {
        self.render_scaled(1)
    }

    fn to_json(&self) -> Json {
        let nodes = self
            .nodes
            .iter()
            .map(|n| {
                Json::obj(vec![
                    ("kind", Json::str(n.kind.name())),
                    ("m", Json::num(n.problem.m as f64)),
                    ("n", Json::num(n.problem.n as f64)),
                    ("k", Json::num(n.problem.k as f64)),
                    ("count", Json::num(n.count as f64)),
                    ("strategy", Json::str(n.strategy.name())),
                    ("resolution", Json::str(n.resolution.name())),
                    ("served_ns", Json::num(n.total_ns)),
                    ("barrier_ns", Json::num(n.barrier_ns)),
                    ("reduce_speedup", Json::num(n.reduce_speedup())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("batch", Json::num(self.batch as f64)),
            ("layer_ns", Json::num(self.layer_ns())),
            ("layer_barrier_ns", Json::num(self.layer_barrier_ns())),
            ("nodes", Json::arr(nodes)),
        ])
    }
}

/// Simulate one decode layer's GEMM chain.  `resolve` assigns each node
/// its (strategy, tiling) — a tuner closure on the tuned path, a constant
/// on the fixed-strategy path.
pub fn simulate_layer(
    machine: &MachineConfig,
    layer: &DecodeLayer,
    mut resolve: impl FnMut(&GemmProblem) -> anyhow::Result<Assignment>,
) -> anyhow::Result<LayerReport> {
    let sim = Simulator::new(machine.clone());
    let mut nodes = Vec::with_capacity(4);
    for node in layer.gemm_nodes() {
        let assignment = resolve(&node.problem)?;
        let (report, _) = simulate_gemm_node(machine, &sim, &node, assignment)?;
        nodes.push(report);
    }
    Ok(LayerReport { batch: layer.batch, nodes })
}

/// Simulate a layer with every node resolved through the tuner — the
/// `repro layer --strategy auto` and `e2e_layer` bench path.
pub fn simulate_layer_tuned(
    machine: &MachineConfig,
    layer: &DecodeLayer,
    tuner: &mut Tuner,
) -> anyhow::Result<LayerReport> {
    simulate_layer(machine, layer, |p| tuner_resolve(tuner, p))
}

/// One simulated non-GEMM node of the step graph.
#[derive(Debug, Clone)]
pub struct VectorNodeReport {
    pub op: VectorOp,
    pub total_ns: f64,
    pub compute_ns: f64,
    pub hbm_ns: f64,
    pub l2_ns: f64,
}

/// One node of the simulated decode-step graph, in issue order.
#[derive(Debug, Clone)]
pub enum StepNodeReport {
    Gemm(NodeReport),
    Vector(VectorNodeReport),
}

impl StepNodeReport {
    pub fn name(&self) -> &'static str {
        match self {
            StepNodeReport::Gemm(n) => n.kind.name(),
            StepNodeReport::Vector(v) => v.op.kind.name(),
        }
    }

    pub fn total_ns(&self) -> f64 {
        match self {
            StepNodeReport::Gemm(n) => n.total_ns,
            StepNodeReport::Vector(v) => v.total_ns,
        }
    }
}

/// One entry of the overlap ledger: `pairs` adjacent (producer reduce,
/// consumer dequant) overlaps, each hiding `gain_ns` of vector work under
/// the first-order ledger — plus, when the pair's schedules are
/// spliceable, the co-scheduler's exact pricing of the same overlap
/// (DESIGN.md §12).  Within an expert batch the producer and consumer are
/// instances of the same node (`producer == consumer`, `pairs == count -
/// 1`).
#[derive(Debug, Clone)]
pub struct OverlapPair {
    /// Index into [`StepReport::nodes`] of the node whose reduce moves.
    pub producer: usize,
    /// Index of the node whose dequant prologue hides it.
    pub consumer: usize,
    /// Adjacent GEMM pairs this entry covers.
    pub pairs: usize,
    /// Exposed reduce time available per pair (the producer's tail).
    pub reduce_ns: f64,
    /// Vector slack available per pair (the consumer's dequant headroom).
    pub slack_ns: f64,
    /// min(reduce_ns, slack_ns) — the first-order ledger's gain per pair.
    pub gain_ns: f64,
    /// The co-scheduler's exact decision for one pair (merged-trace
    /// re-simulation), `None` when no merged trace is available.
    pub exact: Option<PairDecision>,
    /// The chain-level schedule for a saturating producer (DESIGN.md
    /// §13): the tail spread across this consumer's AND the next
    /// prologue, re-balanced.  Set only when the chain priced strictly
    /// better than the two pair decisions it replaces.
    pub chain: Option<ChainOverlap>,
    /// This pair's prologue was consumed by an upstream chain; its own
    /// exact gain is not served (the ledger estimate still renders).
    pub superseded: bool,
}

/// The chain-level decision attached to a saturating producer's entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainOverlap {
    /// Index into [`StepReport::nodes`] of the SECOND consumer whose
    /// prologue absorbs the tail overflow.
    pub second_consumer: usize,
    /// Exact three-kernel pricing (sequential covers all three nodes).
    pub decision: PairDecision,
}

impl OverlapPair {
    pub fn total_gain_ns(&self) -> f64 {
        self.pairs as f64 * self.gain_ns
    }

    /// The per-pair gain `OverlapMode::Exact` realizes: the co-schedule
    /// decision where a merged trace exists, the ledger term otherwise.
    pub fn exact_gain_ns(&self) -> f64 {
        self.exact.map(|d| d.gain_ns).unwrap_or(self.gain_ns)
    }

    /// The per-pair gain the exact plan actually serves once chain-level
    /// decisions are resolved: the chain's gain where one was applied,
    /// zero where an upstream chain consumed this prologue, the pair
    /// decision (or ledger fallback) otherwise.
    pub fn served_exact_gain_ns(&self) -> f64 {
        if self.superseded {
            return 0.0;
        }
        match self.chain {
            Some(c) => c.decision.gain_ns,
            None => self.exact_gain_ns(),
        }
    }

    pub fn total_exact_gain_ns(&self) -> f64 {
        self.pairs as f64 * self.served_exact_gain_ns()
    }

    /// Exact minus ledger, per pair (positive when the merged trace beats
    /// the first-order estimate).
    pub fn exact_vs_ledger_ns(&self) -> f64 {
        self.exact_gain_ns() - self.gain_ns
    }
}

/// The simulated full decode step of one layer.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub batch: usize,
    pub kv_len: usize,
    /// The requested overlap mode (what `served_ns` prices).
    pub mode: OverlapMode,
    pub nodes: Vec<StepNodeReport>,
    /// Every eligible adjacent overlap (empty under zero-gain graphs).
    pub ledger: Vec<OverlapPair>,
    /// Sum of all node times, strictly back to back (PR-2's ledger).
    pub sequential_ns: f64,
    /// `sequential_ns` minus every ledger gain (never larger).
    pub overlapped_ns: f64,
    /// `sequential_ns` minus every co-scheduled exact gain (DESIGN.md
    /// §12); equals `overlapped_ns` where no merged trace was available —
    /// including under `Sequential`/`Overlapped`, which skip the
    /// merged-trace simulations entirely (they never serve this value).
    pub exact_ns: f64,
    /// The step-level weight-residency plan (DESIGN.md §13), present when
    /// the residency mode asked for one.  Its `resident_ns` is the exact
    /// price of the step with the plan's weights pinned; `served_ns`
    /// takes `min(mode plan, resident plan)`, so residency is never
    /// slower by construction.
    pub residency: Option<ResidencyPlan>,
}

impl StepReport {
    /// What `OverlapMode::Auto` would serve WITHOUT the residency plan —
    /// the PR-4 Auto base the residency speedup is measured against.
    pub fn auto_ns(&self) -> f64 {
        self.exact_ns.min(self.overlapped_ns).min(self.sequential_ns)
    }

    /// The step latency the requested mode serves.
    pub fn served_ns(&self) -> f64 {
        let base = match self.mode {
            OverlapMode::Sequential => self.sequential_ns,
            OverlapMode::Overlapped => self.overlapped_ns,
            OverlapMode::Exact => self.exact_ns,
            OverlapMode::Auto => self.auto_ns(),
        };
        match &self.residency {
            Some(plan) => base.min(plan.resident_ns),
            None => base,
        }
    }

    /// The resident plan's exact step price (`None` when residency was
    /// off or planning found nothing to pin beyond the baseline).
    pub fn resident_ns(&self) -> Option<f64> {
        self.residency.as_ref().map(|p| p.resident_ns)
    }

    /// What the weight-residency plan buys over its unpinned baseline.
    pub fn residency_gain_ns(&self) -> f64 {
        self.residency.as_ref().map(|p| p.gain_ns()).unwrap_or(0.0)
    }

    /// Per-decode-step latency for a model with `layers` layers.
    pub fn step_ns(&self, layers: usize) -> f64 {
        self.served_ns() * layers as f64
    }

    /// Total overlap gain of the first-order ledger.
    pub fn overlap_gain_ns(&self) -> f64 {
        self.ledger.iter().map(|p| p.total_gain_ns()).sum()
    }

    /// Total gain the co-scheduler realizes (exact terms where merged
    /// traces exist, ledger terms elsewhere).
    pub fn exact_gain_ns(&self) -> f64 {
        self.ledger.iter().map(|p| p.total_exact_gain_ns()).sum()
    }

    /// Summed GEMM node time (sequential pricing).
    pub fn gemm_ns(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n, StepNodeReport::Gemm(_)))
            .map(|n| n.total_ns())
            .sum()
    }

    /// Summed non-GEMM (attention + glue) node time.
    pub fn vector_ns(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n, StepNodeReport::Vector(_)))
            .map(|n| n.total_ns())
            .sum()
    }

    /// The GEMM sub-chain as a [`LayerReport`] (issue order preserved).
    pub fn gemm_report(&self) -> LayerReport {
        LayerReport {
            batch: self.batch,
            nodes: self
                .nodes
                .iter()
                .filter_map(|n| match n {
                    StepNodeReport::Gemm(g) => Some(g.clone()),
                    StepNodeReport::Vector(_) => None,
                })
                .collect(),
        }
    }

    /// Render the full decode-step graph with the overlap ledger,
    /// scaling the step line to a `layers`-layer model.
    pub fn render_scaled(&self, layers: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Full decode-step graph — batch {}, kv_len {} (simulated, overlap {})\n",
            self.batch,
            self.kv_len,
            self.mode.name()
        ));
        out.push_str(&format!(
            "{:<12} {:<20} {:>5} {:>12} {:>10} | {:>10}\n",
            "node", "shape", "x", "strategy", "via", "served_us"
        ));
        for n in &self.nodes {
            match n {
                StepNodeReport::Gemm(g) => out.push_str(&format!(
                    "{:<12} {:<20} {:>5} {:>12} {:>10} | {:>10.2}\n",
                    g.kind.name(),
                    format!("m{}_n{}_k{}", g.problem.m, g.problem.n, g.problem.k),
                    g.count,
                    g.strategy.name(),
                    g.resolution.name(),
                    g.total_ns / 1e3,
                )),
                StepNodeReport::Vector(v) => out.push_str(&format!(
                    "{:<12} {:<20} {:>5} {:>12} {:>10} | {:>10.2}\n",
                    v.op.kind.name(),
                    format!("{} elems", v.op.elems),
                    1,
                    "-",
                    "-",
                    v.total_ns / 1e3,
                )),
            }
        }
        let pairs: usize = self.ledger.iter().map(|p| p.pairs).sum();
        out.push_str(&format!(
            "\ngemm {} + attention/glue {}  ({} eligible reduce/dequant overlaps hide {} \
             ledger / {} exact)\n",
            stats::fmt_ns(self.gemm_ns()),
            stats::fmt_ns(self.vector_ns()),
            pairs,
            stats::fmt_ns(self.overlap_gain_ns()),
            stats::fmt_ns(self.exact_gain_ns()),
        ));
        for p in &self.ledger {
            let exact = match p.exact {
                Some(d) => format!(
                    "exact {}/pair (merged {}, {}{} vs ledger)",
                    stats::fmt_ns(d.gain_ns),
                    stats::fmt_ns(d.merged_ns),
                    if p.exact_vs_ledger_ns() >= 0.0 { "+" } else { "" },
                    stats::fmt_ns(p.exact_vs_ledger_ns()),
                ),
                None => "no merged trace (ledger term serves)".to_string(),
            };
            out.push_str(&format!(
                "  overlap {}->{} x{}: ledger {}/pair  {}\n",
                self.nodes[p.producer].name(),
                self.nodes[p.consumer].name(),
                p.pairs,
                stats::fmt_ns(p.gain_ns),
                exact,
            ));
            if let Some(c) = p.chain {
                out.push_str(&format!(
                    "    chain ->{} (saturated prologue, re-balanced): {} served over the \
                     pair decisions\n",
                    self.nodes[c.second_consumer].name(),
                    stats::fmt_ns(c.decision.gain_ns),
                ));
            }
            if p.superseded {
                out.push_str("    (prologue consumed by the upstream chain)\n");
            }
        }
        if let Some(plan) = &self.residency {
            let pins: Vec<String> = plan
                .pins
                .iter()
                .map(|pin| format!("{}x{}", pin.kind.name(), pin.instances))
                .collect();
            out.push_str(&format!(
                "residency: pinned {} of {} budget ({}) -> resident {} ({} vs unpinned)\n",
                stats::fmt_bytes(plan.pinned_bytes as f64),
                stats::fmt_bytes(plan.budget_bytes as f64),
                if pins.is_empty() { "nothing worth pinning".to_string() } else { pins.join(" ") },
                stats::fmt_ns(plan.resident_ns),
                stats::fmt_ns(plan.gain_ns()),
            ));
        }
        out.push_str(&format!(
            "layer: {} sequential vs {} overlapped vs {} exact{} -> served {}\n",
            stats::fmt_ns(self.sequential_ns),
            stats::fmt_ns(self.overlapped_ns),
            stats::fmt_ns(self.exact_ns),
            match self.resident_ns() {
                Some(r) => format!(" vs {} resident", stats::fmt_ns(r)),
                None => String::new(),
            },
            stats::fmt_ns(self.served_ns()),
        ));
        out.push_str(&format!(
            "step ({layers} layers): {}  -> {:.0} decode steps/s end to end\n",
            stats::fmt_ns(self.step_ns(layers)),
            1e9 / self.step_ns(layers),
        ));
        out
    }
}

impl Report for StepReport {
    fn render(&self) -> String {
        self.render_scaled(1)
    }

    fn to_json(&self) -> Json {
        let nodes = self
            .nodes
            .iter()
            .map(|n| match n {
                StepNodeReport::Gemm(g) => Json::obj(vec![
                    ("node", Json::str("gemm")),
                    ("kind", Json::str(g.kind.name())),
                    ("m", Json::num(g.problem.m as f64)),
                    ("n", Json::num(g.problem.n as f64)),
                    ("k", Json::num(g.problem.k as f64)),
                    ("count", Json::num(g.count as f64)),
                    ("strategy", Json::str(g.strategy.name())),
                    ("resolution", Json::str(g.resolution.name())),
                    ("served_ns", Json::num(g.total_ns)),
                    ("barrier_ns", Json::num(g.barrier_ns)),
                    ("reduce_tail_ns", Json::num(g.reduce_tail_ns)),
                    ("dequant_slack_ns", Json::num(g.dequant_slack_ns)),
                ]),
                StepNodeReport::Vector(v) => Json::obj(vec![
                    ("node", Json::str("vector")),
                    ("kind", Json::str(v.op.kind.name())),
                    ("elems", Json::num(v.op.elems as f64)),
                    ("served_ns", Json::num(v.total_ns)),
                    ("compute_ns", Json::num(v.compute_ns)),
                    ("hbm_ns", Json::num(v.hbm_ns)),
                    ("l2_ns", Json::num(v.l2_ns)),
                ]),
            })
            .collect();
        let overlap = self
            .ledger
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("producer", Json::num(p.producer as f64)),
                    ("consumer", Json::num(p.consumer as f64)),
                    ("pairs", Json::num(p.pairs as f64)),
                    ("reduce_ns", Json::num(p.reduce_ns)),
                    ("slack_ns", Json::num(p.slack_ns)),
                    ("gain_ns", Json::num(p.gain_ns)),
                    ("total_gain_ns", Json::num(p.total_gain_ns())),
                    (
                        "exact_merged_ns",
                        p.exact.map(|d| Json::num(d.merged_ns)).unwrap_or(Json::Null),
                    ),
                    (
                        "exact_gain_ns",
                        p.exact.map(|d| Json::num(d.gain_ns)).unwrap_or(Json::Null),
                    ),
                    ("exact_vs_ledger_ns", Json::num(p.exact_vs_ledger_ns())),
                    (
                        "chain_gain_ns",
                        p.chain.map(|c| Json::num(c.decision.gain_ns)).unwrap_or(Json::Null),
                    ),
                    (
                        "chain_second_consumer",
                        p.chain
                            .map(|c| Json::num(c.second_consumer as f64))
                            .unwrap_or(Json::Null),
                    ),
                    ("superseded", Json::Bool(p.superseded)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("batch", Json::num(self.batch as f64)),
            ("kv_len", Json::num(self.kv_len as f64)),
            ("overlap_mode", Json::str(self.mode.name())),
            ("sequential_ns", Json::num(self.sequential_ns)),
            ("overlapped_ns", Json::num(self.overlapped_ns)),
            ("exact_ns", Json::num(self.exact_ns)),
            (
                "resident_ns",
                self.resident_ns().map(Json::num).unwrap_or(Json::Null),
            ),
            ("residency_gain_ns", Json::num(self.residency_gain_ns())),
            (
                "residency",
                self.residency
                    .as_ref()
                    .map(|p| p.to_json())
                    .unwrap_or(Json::Null),
            ),
            ("served_ns", Json::num(self.served_ns())),
            ("gemm_ns", Json::num(self.gemm_ns())),
            ("vector_ns", Json::num(self.vector_ns())),
            ("nodes", Json::arr(nodes)),
            ("overlap", Json::arr(overlap)),
        ])
    }
}

/// Simulate the full decode-step graph under an overlap mode (weight
/// residency off — the PR-4 surface).
#[deprecated(
    note = "use StepSim::new(machine, step).overlap(mode).resolver(resolve).run() \
            (analysis::stepsim)"
)]
pub fn simulate_step(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    resolve: impl FnMut(&GemmProblem) -> anyhow::Result<Assignment>,
) -> anyhow::Result<StepReport> {
    StepSim::new(machine, step).overlap(mode).resolver(resolve).run()
}

/// Simulate the full decode-step graph under an overlap mode AND a
/// step-level weight-residency mode (DESIGN.md §13).
#[deprecated(
    note = "use StepSim::new(machine, step).overlap(mode).residency(residency_mode)\
            .resolver(resolve).run() (analysis::stepsim)"
)]
pub fn simulate_step_with(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    resolve: impl FnMut(&GemmProblem) -> anyhow::Result<Assignment>,
) -> anyhow::Result<StepReport> {
    StepSim::new(machine, step)
        .overlap(mode)
        .residency(residency_mode)
        .resolver(resolve)
        .run()
}

/// Simulate a causal prefill chunk (DESIGN.md §15) under the same
/// overlap + residency machinery as decode.
#[deprecated(
    note = "use StepSim::prefill(machine, step).overlap(mode).residency(residency_mode)\
            .resolver(resolve).run() (analysis::stepsim)"
)]
pub fn simulate_prefill_step_with(
    machine: &MachineConfig,
    step: &crate::workload::PrefillStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    resolve: impl FnMut(&GemmProblem) -> anyhow::Result<Assignment>,
) -> anyhow::Result<StepReport> {
    StepSim::prefill(machine, step)
        .overlap(mode)
        .residency(residency_mode)
        .resolver(resolve)
        .run()
}

/// Tuned prefill-chunk simulation — the serving warm-up and
/// `e2e_serve` bench path.
#[deprecated(
    note = "use StepSim::prefill(machine, step).overlap(mode).residency(residency_mode)\
            .tuner(tuner).run() (analysis::stepsim)"
)]
pub fn simulate_prefill_step_tuned_with(
    machine: &MachineConfig,
    step: &crate::workload::PrefillStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    tuner: &mut Tuner,
) -> anyhow::Result<StepReport> {
    StepSim::prefill(machine, step)
        .overlap(mode)
        .residency(residency_mode)
        .tuner(tuner)
        .run()
}

/// A Split-K resolver that forces a K split where legal — the overlap
/// sweep harness shared by the tests and the bench stress leg.  The
/// wide-N heuristics (and the tuner, which mostly prefers the fused
/// ablation) pick S = 1 on most decode shapes — no reduce, nothing to
/// overlap — so overlap-focused sweeps force S >= 2 to exercise the
/// ledger and the co-scheduler non-vacuously.
pub fn forced_split_resolver(
    machine: &MachineConfig,
) -> impl FnMut(&GemmProblem) -> anyhow::Result<Assignment> + '_ {
    move |p| {
        let mut t = kernels::select_tiling(machine, p, Strategy::SplitK)?;
        let split = Tiling { splits: t.splits.max(2), ..t };
        if split.validate(machine, p).is_ok() {
            t = split;
        }
        Ok((Strategy::SplitK, t, Resolution::Heuristic))
    }
}

/// Simulate the full step with every GEMM node resolved through the tuner.
#[deprecated(
    note = "use StepSim::new(machine, step).overlap(mode).tuner(tuner).run() \
            (analysis::stepsim)"
)]
pub fn simulate_step_tuned(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    tuner: &mut Tuner,
) -> anyhow::Result<StepReport> {
    StepSim::new(machine, step).overlap(mode).tuner(tuner).run()
}

/// Tuned full-step simulation with an explicit residency mode — the
/// `repro layer --residency` and `e2e_layer` bench path.
#[deprecated(
    note = "use StepSim::new(machine, step).overlap(mode).residency(residency_mode)\
            .tuner(tuner).run() (analysis::stepsim)"
)]
pub fn simulate_step_tuned_with(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    tuner: &mut Tuner,
) -> anyhow::Result<StepReport> {
    StepSim::new(machine, step)
        .overlap(mode)
        .residency(residency_mode)
        .tuner(tuner)
        .run()
}

/// Cost of re-establishing a residency plan's L2 pins after a prefill
/// chunk (or any other burst) streamed its own weights and activations
/// through the shared buffer (DESIGN.md §15): the pinned packed weights
/// re-stream from HBM once before the next decode step regains the
/// plan's residency_gain.  Pure bandwidth term — integer bytes over the
/// machine's HBM rate — so the serve-loop mirror reproduces it exactly.
pub fn repin_ns(machine: &MachineConfig, pinned_bytes: u64) -> f64 {
    pinned_bytes as f64 / machine.hbm_bw
}

/// Churn-decayed re-pin cost (DESIGN.md §18): only the fraction of the
/// pinned set a prefill burst actually evicted re-streams.  The serve
/// loop tracks the bytes each prefill tick pushed through L2 (the
/// chunk's packed-weight traffic) and caps the accumulator at the pinned
/// footprint, so the decayed surcharge is always ≤ the binary
/// full-re-pin cost and equals it exactly at full churn — the LRU
/// half-life the binary model over-charged light interleave with.
pub fn repin_decayed_ns(machine: &MachineConfig, pinned_bytes: u64, evicted_bytes: u64) -> f64 {
    repin_ns(machine, evicted_bytes.min(pinned_bytes))
}

/// Render the per-node table plus layer / step totals (GEMM chain only).
pub fn render_layer(report: &LayerReport, layers: usize) -> String {
    report.render_scaled(layers)
}

/// Render the full decode-step graph with the overlap ledger.
pub fn render_step(report: &StepReport, layers: usize) -> String {
    report.render_scaled(layers)
}

/// JSON form of a layer report (BENCH_layer.json, `repro layer --json`).
pub fn layer_json(report: &LayerReport) -> Json {
    report.to_json()
}

/// JSON form of a full decode-step report (`repro layer --overlap --json`).
pub fn step_json(report: &StepReport) -> Json {
    report.to_json()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::{layer_geometry, moe_geometry};

    #[test]
    fn repin_decay_is_bounded_by_the_full_surcharge_and_exact_at_full_churn() {
        let m = MachineConfig::ascend910();
        crate::util::proptest::forall("repin decay <= full surcharge", 200, |rng| {
            let pinned = rng.next_u64() % (1 << 30);
            let evicted = rng.next_u64() % (1 << 31);
            let decayed = repin_decayed_ns(&m, pinned, evicted);
            let full = repin_ns(&m, pinned);
            let bounded = decayed <= full && decayed >= 0.0;
            // At (or past) full churn the decayed cost IS the full re-pin.
            let exact = evicted < pinned || decayed == full;
            (bounded && exact, format!("pinned={pinned} evicted={evicted}"))
        });
        // Zero churn pays nothing; partial churn pays the evicted fraction.
        assert_eq!(repin_decayed_ns(&m, 1 << 20, 0), 0.0);
        let half = repin_decayed_ns(&m, 1 << 20, 1 << 19);
        assert!((half - repin_ns(&m, 1 << 19)).abs() < 1e-12);
    }

    fn fixed(
        machine: &MachineConfig,
        strategy: Strategy,
    ) -> impl FnMut(&GemmProblem) -> anyhow::Result<Assignment> + '_ {
        move |p| {
            Ok((strategy, kernels::select_tiling(machine, p, strategy)?, Resolution::Heuristic))
        }
    }

    #[test]
    fn simulates_all_four_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(r.nodes.len(), 4);
        for n in &r.nodes {
            assert!(n.total_ns > 0.0 && n.total_ns.is_finite());
            assert!(
                n.total_ns <= n.barrier_ns * 1.000001,
                "{}: served {} slower than barrier {}",
                n.kind.name(),
                n.total_ns,
                n.barrier_ns
            );
            assert_eq!(n.count, 1);
            assert_eq!(n.total_ns, n.unit_ns);
        }
        assert!(r.layer_ns() > r.nodes[0].total_ns);
        assert_eq!(r.step_ns(2), 2.0 * r.layer_ns());
    }

    #[test]
    fn render_and_json_carry_all_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::Chunked)).unwrap();
        let text = render_layer(&r, 16);
        for kind in GemmKind::all() {
            assert!(text.contains(kind.name()), "missing {}", kind.name());
        }
        let j = layer_json(&r).to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.req("nodes").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn resolver_errors_propagate() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let r = simulate_layer(&m, &layer, |_| anyhow::bail!("no assignment"));
        assert!(r.is_err());
    }

    #[test]
    fn moe_layer_multiplies_expert_batches() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(r.nodes.len(), 4);
        let experts: Vec<&NodeReport> =
            r.nodes.iter().filter(|n| n.kind == GemmKind::MoeExpert).collect();
        assert_eq!(experts.len(), 2);
        for e in experts {
            assert_eq!(e.count, 64);
            assert!((e.total_ns - 64.0 * e.unit_ns).abs() < 1e-6);
        }
    }

    #[test]
    fn step_covers_gemm_and_vector_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let r = StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .resolver(fixed(&m, Strategy::SplitK))
            .run()
            .unwrap();
        assert_eq!(r.nodes.len(), 12);
        assert!(r.gemm_ns() > 0.0 && r.vector_ns() > 0.0);
        assert!((r.sequential_ns - r.gemm_ns() - r.vector_ns()).abs() < 1e-6);
        assert!(r.overlapped_ns <= r.sequential_ns);
        assert!(r.served_ns() <= r.sequential_ns);
        assert_eq!(r.gemm_report().nodes.len(), 4);
        // The overlap accounting balances exactly.
        assert!(
            (r.sequential_ns - r.overlap_gain_ns() - r.overlapped_ns).abs() < 1e-6,
            "ledger must price every gain exactly once"
        );
        let text = render_step(&r, 32);
        for name in ["attn_score", "rmsnorm", "qkv", "down", "overlap"] {
            assert!(text.contains(name), "render missing {name}:\n{text}");
        }
        let parsed = Json::parse(&step_json(&r).to_string()).unwrap();
        assert_eq!(parsed.req("nodes").unwrap().as_arr().unwrap().len(), 12);
    }

    #[test]
    fn overlap_modes_order_correctly() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        let step = DecodeStep::new(layer, 2048, 56);
        let seq = StepSim::new(&m, &step)
            .overlap(OverlapMode::Sequential)
            .resolver(fixed(&m, Strategy::SplitK))
            .run()
            .unwrap();
        let auto = StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .resolver(fixed(&m, Strategy::SplitK))
            .run()
            .unwrap();
        assert_eq!(seq.served_ns(), seq.sequential_ns);
        assert!(auto.served_ns() <= seq.served_ns() * 1.000001);
        // Auto serves the min of all three plans — structurally never
        // slower than PR 3's ledger or the exact co-schedule.
        assert!(auto.served_ns() <= auto.overlapped_ns * 1.000001);
        assert!(auto.served_ns() <= auto.exact_ns * 1.000001);
        // Exact itself never loses to the sequential chain: every merge
        // is declined when it prices slower.
        assert!(auto.exact_ns <= auto.sequential_ns * 1.000001);
        // Expert batches expose internal overlap pairs.
        assert!(
            auto.ledger.iter().any(|p| p.producer == p.consumer && p.pairs > 1)
                || auto.ledger.is_empty(),
            "expert fan-out should ledger internal pairs when any gain exists"
        );
    }

    #[test]
    fn residency_auto_never_slower_and_json_carries_the_plan() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let off = StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .resolver(fixed(&m, Strategy::Fused))
            .run()
            .unwrap();
        let on = StepSim::new(&m, &step)
            .overlap(OverlapMode::Auto)
            .residency(ResidencyMode::Auto)
            .resolver(fixed(&m, Strategy::Fused))
            .run()
            .unwrap();
        // Identical chain, so the non-residency prices agree; the resident
        // plan can only improve the served step.
        assert!((on.sequential_ns - off.sequential_ns).abs() < 1e-6);
        assert!(on.served_ns() <= off.served_ns() * 1.000001);
        let plan = on.residency.as_ref().expect("residency auto must carry a plan");
        assert!(plan.pinned_bytes <= plan.budget_bytes);
        assert!(plan.resident_ns <= plan.baseline_ns * 1.000001);
        // llama32's fused K>>N nodes fit the budget: pinning must win.
        assert!(
            on.residency_gain_ns() > 0.0,
            "resident weights must pay on the llama32 fused chain: {plan:?}"
        );
        assert!(on.served_ns() < off.served_ns(), "strictly faster with residency");
        let j = Json::parse(&step_json(&on).to_string()).unwrap();
        assert!(j.req("resident_ns").unwrap().as_f64().is_some());
        assert!(j.req("residency").unwrap().get("pins").is_some());
        let rendered = render_step(&on, 16);
        assert!(rendered.contains("residency:"), "render missing residency:\n{rendered}");
        // Residency off leaves the PR-4 JSON shape (null cells).
        let j = Json::parse(&step_json(&off).to_string()).unwrap();
        assert!(j.req("resident_ns").unwrap().as_f64().is_none());
        assert_eq!(j.req("residency_gain_ns").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn exact_mode_prices_merged_traces_on_forced_splits() {
        // Force a K split on every node so each carries an exposed reduce
        // tail: the co-scheduler must find spliceable pairs, and the
        // served Exact plan must beat (or tie) the sequential chain.
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let rep = StepSim::new(&m, &step)
            .overlap(OverlapMode::Exact)
            .resolver(forced_split_resolver(&m))
            .run()
            .unwrap();
        assert_eq!(rep.served_ns(), rep.exact_ns);
        assert!(rep.exact_ns <= rep.sequential_ns * 1.000001);
        let with_merged: Vec<&OverlapPair> =
            rep.ledger.iter().filter(|p| p.exact.is_some()).collect();
        assert!(
            !with_merged.is_empty(),
            "forced splits must yield at least one spliceable pair: {:?}",
            rep.ledger
        );
        for p in &with_merged {
            let d = p.exact.unwrap();
            assert!(d.gain_ns >= 0.0);
            assert!(d.merged_ns > 0.0 && d.merged_ns.is_finite());
            assert!(
                (d.gain_ns - (d.sequential_ns - d.merged_ns).max(0.0)).abs() < 1e-6,
                "exact gain must be the clamped merged-vs-sequential delta"
            );
        }
        // The accounting balances: exact_ns = sequential - exact gains.
        assert!(
            (rep.sequential_ns - rep.exact_gain_ns() - rep.exact_ns).abs() < 1e-6,
            "exact ledger must price every gain exactly once"
        );
        // JSON carries the exact cells.
        let j = Json::parse(&step_json(&rep).to_string()).unwrap();
        assert_eq!(j.req_str("overlap_mode").unwrap(), "exact");
        assert!(j.req("exact_ns").unwrap().as_f64().unwrap() > 0.0);
        let overlap = j.req("overlap").unwrap().as_arr().unwrap();
        assert!(overlap
            .iter()
            .any(|o| o.req("exact_gain_ns").unwrap().as_f64().is_some()));
    }
}
