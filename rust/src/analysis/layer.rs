//! Decode-layer graph simulator: composes per-GEMM [`KernelTrace`]
//! results into per-layer and per-step latency, with a strategy
//! assignment per node (DESIGN.md §10).
//!
//! The graph is a chain — each projection consumes the previous one's
//! activations — so layer latency is the sum of the node kernel times
//! (each node already overlaps its own dequant/MMAD/reduce internally;
//! attention itself and the elementwise glue are out of scope, as in the
//! paper's evaluation).  Every node is priced twice: under the served
//! reduce schedule (`ReduceMode::Auto`, pipelined fixup when it wins) and
//! under Algorithm 1's barrier reduce, so the report shows exactly what
//! the reduce pipelining buys per node and per layer.
//!
//! [`KernelTrace`]: crate::ascend::KernelTrace

use crate::ascend::{MachineConfig, Simulator};
use crate::kernels::{self, tiling::Tiling, GemmProblem, ReduceMode, Strategy};
use crate::tune::Tuner;
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::decode_layer::{DecodeLayer, GemmKind};

/// How one graph node's (strategy, tiling) assignment was determined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// Served from the persisted tune cache (the hot-path lookup).
    CacheHit,
    /// A live search filled the cache (first run / cold cache).
    Searched,
    /// A concrete strategy with its heuristic tiling (no tuner involved).
    Heuristic,
}

impl Resolution {
    pub fn name(&self) -> &'static str {
        match self {
            Resolution::CacheHit => "cache",
            Resolution::Searched => "searched",
            Resolution::Heuristic => "heuristic",
        }
    }
}

/// One simulated graph node.
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub kind: GemmKind,
    pub problem: GemmProblem,
    pub strategy: Strategy,
    pub tiling: Tiling,
    pub resolution: Resolution,
    /// Simulated kernel time under the served (auto) reduce schedule.
    pub total_ns: f64,
    /// The same schedule under Algorithm 1's barrier reduce (>= total_ns).
    pub barrier_ns: f64,
}

impl NodeReport {
    /// What the pipelined reduce buys on this node (>= 1.0 by construction).
    pub fn reduce_speedup(&self) -> f64 {
        if self.total_ns == 0.0 {
            return 1.0;
        }
        self.barrier_ns / self.total_ns
    }
}

/// The simulated layer: all four nodes at one batch size.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub batch: usize,
    pub nodes: Vec<NodeReport>,
}

impl LayerReport {
    /// Layer GEMM latency under the served schedules.
    pub fn layer_ns(&self) -> f64 {
        self.nodes.iter().map(|n| n.total_ns).sum()
    }

    /// Layer GEMM latency with every reduce behind the grid barrier.
    pub fn layer_barrier_ns(&self) -> f64 {
        self.nodes.iter().map(|n| n.barrier_ns).sum()
    }

    /// Per-decode-step GEMM latency for a model with `layers` layers.
    pub fn step_ns(&self, layers: usize) -> f64 {
        self.layer_ns() * layers as f64
    }

    pub fn node(&self, kind: GemmKind) -> Option<&NodeReport> {
        self.nodes.iter().find(|n| n.kind == kind)
    }
}

/// Simulate one decode layer.  `resolve` assigns each node its
/// (strategy, tiling) — a tuner closure on the tuned path, a constant on
/// the fixed-strategy path.
pub fn simulate_layer(
    machine: &MachineConfig,
    layer: &DecodeLayer,
    mut resolve: impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)>,
) -> anyhow::Result<LayerReport> {
    let sim = Simulator::new(machine.clone());
    let mut nodes = Vec::with_capacity(4);
    for (kind, p) in layer.problems() {
        let (strategy, tiling, resolution) = resolve(&p)?;
        let served =
            kernels::schedule_with_reduce(machine, &p, strategy, &tiling, ReduceMode::Auto)?;
        let total_ns = sim.run(&served)?.total_ns;
        // Only the Split-K family has a reduce; for the other strategies
        // the barrier variant IS the served trace — skip the re-build.
        let barrier_ns = match strategy {
            Strategy::SplitK | Strategy::Chunked => {
                let barrier = kernels::schedule_with_reduce(
                    machine,
                    &p,
                    strategy,
                    &tiling,
                    ReduceMode::Barrier,
                )?;
                sim.run(&barrier)?.total_ns
            }
            _ => total_ns,
        };
        nodes.push(NodeReport {
            kind,
            problem: p,
            strategy,
            tiling,
            resolution,
            total_ns,
            barrier_ns,
        });
    }
    Ok(LayerReport { batch: layer.batch, nodes })
}

/// Simulate a layer with every node resolved through the tuner (cache
/// hit, or live search that warms the cache) — the `repro layer
/// --strategy auto` and `e2e_layer` bench path.
pub fn simulate_layer_tuned(
    machine: &MachineConfig,
    layer: &DecodeLayer,
    tuner: &mut Tuner,
) -> anyhow::Result<LayerReport> {
    simulate_layer(machine, layer, |p| {
        let before = tuner.searches;
        let e = tuner.resolve(p)?;
        let resolution = if tuner.searches > before {
            Resolution::Searched
        } else {
            Resolution::CacheHit
        };
        Ok((e.strategy, e.tiling, resolution))
    })
}

/// Render the per-node table plus layer / step totals.
pub fn render_layer(report: &LayerReport, layers: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Decode-layer GEMM graph — batch {} (simulated)\n",
        report.batch
    ));
    out.push_str(&format!(
        "{:<9} {:<20} {:>12} {:>10} | {:>10} {:>11} {:>8}\n",
        "node", "shape", "strategy", "via", "served_us", "barrier_us", "reduce"
    ));
    for n in &report.nodes {
        out.push_str(&format!(
            "{:<9} {:<20} {:>12} {:>10} | {:>10.2} {:>11.2} {:>7.2}x\n",
            n.kind.name(),
            format!("m{}_n{}_k{}", n.problem.m, n.problem.n, n.problem.k),
            n.strategy.name(),
            n.resolution.name(),
            n.total_ns / 1e3,
            n.barrier_ns / 1e3,
            n.reduce_speedup(),
        ));
    }
    out.push_str(&format!(
        "\nlayer: {} served vs {} barrier-reduce ({:.3}x from reduce pipelining)\n",
        stats::fmt_ns(report.layer_ns()),
        stats::fmt_ns(report.layer_barrier_ns()),
        report.layer_barrier_ns() / report.layer_ns(),
    ));
    out.push_str(&format!(
        "step ({layers} layers): {}  -> {:.0} decode steps/s of pure GEMM headroom\n",
        stats::fmt_ns(report.step_ns(layers)),
        1e9 / report.step_ns(layers),
    ));
    out
}

/// JSON form of a layer report (BENCH_layer.json, `repro layer --json`).
pub fn layer_json(report: &LayerReport) -> Json {
    let nodes = report
        .nodes
        .iter()
        .map(|n| {
            Json::obj(vec![
                ("kind", Json::str(n.kind.name())),
                ("m", Json::num(n.problem.m as f64)),
                ("n", Json::num(n.problem.n as f64)),
                ("k", Json::num(n.problem.k as f64)),
                ("strategy", Json::str(n.strategy.name())),
                ("resolution", Json::str(n.resolution.name())),
                ("served_ns", Json::num(n.total_ns)),
                ("barrier_ns", Json::num(n.barrier_ns)),
                ("reduce_speedup", Json::num(n.reduce_speedup())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("batch", Json::num(report.batch as f64)),
        ("layer_ns", Json::num(report.layer_ns())),
        ("layer_barrier_ns", Json::num(report.layer_barrier_ns())),
        ("nodes", Json::arr(nodes)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::layer_geometry;

    fn fixed(
        machine: &MachineConfig,
        strategy: Strategy,
    ) -> impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)> + '_ {
        move |p| {
            Ok((strategy, kernels::select_tiling(machine, p, strategy)?, Resolution::Heuristic))
        }
    }

    #[test]
    fn simulates_all_four_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(r.nodes.len(), 4);
        for n in &r.nodes {
            assert!(n.total_ns > 0.0 && n.total_ns.is_finite());
            assert!(
                n.total_ns <= n.barrier_ns * 1.000001,
                "{}: served {} slower than barrier {}",
                n.kind.name(),
                n.total_ns,
                n.barrier_ns
            );
        }
        assert!(r.layer_ns() > r.nodes[0].total_ns);
        assert_eq!(r.step_ns(2), 2.0 * r.layer_ns());
    }

    #[test]
    fn render_and_json_carry_all_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::Chunked)).unwrap();
        let text = render_layer(&r, 16);
        for kind in GemmKind::all() {
            assert!(text.contains(kind.name()), "missing {}", kind.name());
        }
        let j = layer_json(&r).to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.req("nodes").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn resolver_errors_propagate() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let r = simulate_layer(&m, &layer, |_| anyhow::bail!("no assignment"));
        assert!(r.is_err());
    }
}
