//! Decode-layer / decode-step graph simulator: composes per-GEMM
//! [`KernelTrace`] results and [`vecpass`] vector passes into per-layer
//! and per-step latency, with a strategy assignment per GEMM node and a
//! cross-node overlap ledger (DESIGN.md §10–§11).
//!
//! Two granularities:
//! * [`simulate_layer`] — the GEMM sub-chain only (PR-2 surface): layer
//!   latency is the sum of the node kernel times, each priced under the
//!   served reduce and under Algorithm 1's barrier reduce.
//! * [`simulate_step`] — the full decode step: attention score/softmax/AV,
//!   RMSNorm/residual/activation glue and MoE routing priced by the
//!   [`vecpass`] bandwidth model, the MoE expert fan-out as batched GEMM
//!   nodes, and an [`OverlapMode`] ledger that overlaps node i's exposed
//!   Split-K reduce with node i+1's weight-only dequant prologue (same
//!   vector cores, disjoint buffers).  `Auto` prices both ledgers and
//!   serves the winner, so the served plan is never slower than the
//!   sequential chain.
//!
//! [`KernelTrace`]: crate::ascend::KernelTrace
//! [`vecpass`]: crate::ascend::vecpass

use super::coschedule::{self, PairDecision};
use super::residency::{self, ResidencyMode, ResidencyPlan};
use crate::ascend::{vecpass, KernelTrace, MachineConfig, SimReport, Simulator};
use crate::kernels::{self, tiling::Tiling, GemmProblem, ReduceMode, Strategy};
use crate::tune::Tuner;
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::decode_layer::{
    DecodeLayer, DecodeStep, GemmKind, GemmNode, StepNode, VectorOp,
};

/// How one graph node's (strategy, tiling) assignment was determined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resolution {
    /// Served from the persisted tune cache (the hot-path lookup).
    CacheHit,
    /// A live search filled the cache (first run / cold cache).
    Searched,
    /// A concrete strategy with its heuristic tiling (no tuner involved).
    Heuristic,
}

impl Resolution {
    pub fn name(&self) -> &'static str {
        match self {
            Resolution::CacheHit => "cache",
            Resolution::Searched => "searched",
            Resolution::Heuristic => "heuristic",
        }
    }
}

/// Whether the step simulator may overlap adjacent GEMM nodes
/// (DESIGN.md §11–§12): node i's exposed post-barrier reduce runs in the
/// vector-engine slack of node i+1's weight-only dequant prologue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// PR-2's ledger: nodes priced strictly back to back.
    Sequential,
    /// Every eligible adjacent pair overlaps under the first-order ledger
    /// (`min(exposed_reduce, vector_slack)` per pair).  With the ledger's
    /// gains clamped non-negative this is never slower than `Sequential`
    /// by construction.
    Overlapped,
    /// The phase-level co-scheduler (DESIGN.md §12): node i's reduce tail
    /// is spliced into node i+1's dequant phase and the merged trace is
    /// re-simulated, replacing the first-order ledger term with the exact
    /// simulated gain wherever a merged trace is available.  Each pair's
    /// merge is declined when it prices slower, so `Exact` is never
    /// slower than `Sequential` by construction.
    Exact,
    /// Price all three, serve `min(sequential, overlapped, exact)` — the
    /// never-slower guarantee is *structural*: neither a pessimistic
    /// ledger nor an adversarial merged trace can regress the served
    /// plan below the sequential chain or PR 3's ledger.
    #[default]
    Auto,
}

impl OverlapMode {
    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::Sequential => "sequential",
            OverlapMode::Overlapped => "overlapped",
            OverlapMode::Exact => "exact",
            OverlapMode::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<OverlapMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => OverlapMode::Sequential,
            "overlapped" | "overlap" | "ledger" => OverlapMode::Overlapped,
            "exact" | "coschedule" => OverlapMode::Exact,
            "auto" => OverlapMode::Auto,
            other => anyhow::bail!("unknown overlap mode '{other}'"),
        })
    }
}

/// One simulated GEMM node (`count` identical GEMMs for expert batches).
#[derive(Debug, Clone)]
pub struct NodeReport {
    pub kind: GemmKind,
    pub problem: GemmProblem,
    /// Identical GEMMs this node issues back to back (1 for dense nodes).
    pub count: usize,
    pub strategy: Strategy,
    pub tiling: Tiling,
    pub resolution: Resolution,
    /// Simulated time of ONE GEMM under the served (auto) reduce schedule.
    pub unit_ns: f64,
    /// One GEMM under Algorithm 1's barrier reduce (>= unit_ns).
    pub unit_barrier_ns: f64,
    /// `count * unit_ns` — the node's sequential contribution.
    pub total_ns: f64,
    /// `count * unit_barrier_ns`.
    pub barrier_ns: f64,
    /// Exposed post-barrier reduce group of one GEMM (0 when the reduce
    /// streams entirely, or the strategy has no reduce) — what a
    /// downstream dequant can hide (DESIGN.md §11).
    pub reduce_tail_ns: f64,
    /// Vector-engine idle headroom of one GEMM's leading weight-only
    /// dequant phase (transfer time minus SIMD time) — where an upstream
    /// reduce can hide.
    pub dequant_slack_ns: f64,
}

impl NodeReport {
    /// What the pipelined reduce buys on this node (>= 1.0 by construction).
    pub fn reduce_speedup(&self) -> f64 {
        if self.total_ns == 0.0 {
            return 1.0;
        }
        self.barrier_ns / self.total_ns
    }
}

/// The simulated layer: the GEMM sub-chain at one batch size.
#[derive(Debug, Clone)]
pub struct LayerReport {
    pub batch: usize,
    pub nodes: Vec<NodeReport>,
}

impl LayerReport {
    /// Layer GEMM latency under the served schedules.
    pub fn layer_ns(&self) -> f64 {
        self.nodes.iter().map(|n| n.total_ns).sum()
    }

    /// Layer GEMM latency with every reduce behind the grid barrier.
    pub fn layer_barrier_ns(&self) -> f64 {
        self.nodes.iter().map(|n| n.barrier_ns).sum()
    }

    /// Per-decode-step GEMM latency for a model with `layers` layers.
    pub fn step_ns(&self, layers: usize) -> f64 {
        self.layer_ns() * layers as f64
    }

    pub fn node(&self, kind: GemmKind) -> Option<&NodeReport> {
        self.nodes.iter().find(|n| n.kind == kind)
    }
}

/// The overlap terms of one served trace: (exposed post-barrier reduce
/// group time, vector-engine slack of the leading dequant phase).
fn overlap_terms(r: &SimReport) -> (f64, f64) {
    let reduce_tail = match r.groups.last() {
        Some(g) if r.groups.len() > 1 => {
            let all_reduce = g
                .phases
                .iter()
                .all(|&pi| r.phase_times[pi].name.starts_with("reduce"));
            if all_reduce {
                g.total_ns
            } else {
                0.0
            }
        }
        _ => 0.0,
    };
    // The weight-only prologue: the first dequant phase's transfer time is
    // independent of upstream activations, so its vector-compute headroom
    // (standalone minus SIMD time) is where an upstream reduce can hide.
    let dequant_slack = r
        .phase_times
        .iter()
        .find(|pt| pt.name.contains("dequant"))
        .map(|pt| (pt.standalone_ns - pt.compute_ns).max(0.0))
        .unwrap_or(0.0);
    (reduce_tail, dequant_slack)
}

/// Simulate one GEMM node: served (auto-reduce) and barrier-reduce
/// pricing plus the overlap terms, multiplied over the node's count.
/// Also returns the served trace itself — the co-scheduler splices it.
fn simulate_gemm_node(
    machine: &MachineConfig,
    sim: &Simulator,
    node: &GemmNode,
    assignment: (Strategy, Tiling, Resolution),
) -> anyhow::Result<(NodeReport, KernelTrace)> {
    let (strategy, tiling, resolution) = assignment;
    let p = &node.problem;
    let served = kernels::schedule_with_reduce(machine, p, strategy, &tiling, ReduceMode::Auto)?;
    let served_run = sim.run(&served)?;
    let unit_ns = served_run.total_ns;
    let (reduce_tail_ns, dequant_slack_ns) = overlap_terms(&served_run);
    // Only the Split-K family has a reduce; for the other strategies
    // the barrier variant IS the served trace — skip the re-build.
    let unit_barrier_ns = match strategy {
        Strategy::SplitK | Strategy::Chunked => {
            let barrier =
                kernels::schedule_with_reduce(machine, p, strategy, &tiling, ReduceMode::Barrier)?;
            sim.run(&barrier)?.total_ns
        }
        _ => unit_ns,
    };
    let count = node.count.max(1) as f64;
    let report = NodeReport {
        kind: node.kind,
        problem: *p,
        count: node.count.max(1),
        strategy,
        tiling,
        resolution,
        unit_ns,
        unit_barrier_ns,
        total_ns: unit_ns * count,
        barrier_ns: unit_barrier_ns * count,
        reduce_tail_ns,
        dequant_slack_ns,
    };
    Ok((report, served))
}

/// Simulate one decode layer's GEMM chain.  `resolve` assigns each node
/// its (strategy, tiling) — a tuner closure on the tuned path, a constant
/// on the fixed-strategy path.
pub fn simulate_layer(
    machine: &MachineConfig,
    layer: &DecodeLayer,
    mut resolve: impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)>,
) -> anyhow::Result<LayerReport> {
    let sim = Simulator::new(machine.clone());
    let mut nodes = Vec::with_capacity(4);
    for node in layer.gemm_nodes() {
        let assignment = resolve(&node.problem)?;
        let (report, _) = simulate_gemm_node(machine, &sim, &node, assignment)?;
        nodes.push(report);
    }
    Ok(LayerReport { batch: layer.batch, nodes })
}

/// Resolve through a tuner (cache hit, or live search that warms the
/// cache), tracking how each node was resolved.
fn tuner_resolve(
    tuner: &mut Tuner,
    p: &GemmProblem,
) -> anyhow::Result<(Strategy, Tiling, Resolution)> {
    let before = tuner.searches;
    let e = tuner.resolve(p)?;
    let resolution = if tuner.searches > before {
        Resolution::Searched
    } else {
        Resolution::CacheHit
    };
    Ok((e.strategy, e.tiling, resolution))
}

/// Simulate a layer with every node resolved through the tuner — the
/// `repro layer --strategy auto` and `e2e_layer` bench path.
pub fn simulate_layer_tuned(
    machine: &MachineConfig,
    layer: &DecodeLayer,
    tuner: &mut Tuner,
) -> anyhow::Result<LayerReport> {
    simulate_layer(machine, layer, |p| tuner_resolve(tuner, p))
}

/// One simulated non-GEMM node of the step graph.
#[derive(Debug, Clone)]
pub struct VectorNodeReport {
    pub op: VectorOp,
    pub total_ns: f64,
    pub compute_ns: f64,
    pub hbm_ns: f64,
    pub l2_ns: f64,
}

/// One node of the simulated decode-step graph, in issue order.
#[derive(Debug, Clone)]
pub enum StepNodeReport {
    Gemm(NodeReport),
    Vector(VectorNodeReport),
}

impl StepNodeReport {
    pub fn name(&self) -> &'static str {
        match self {
            StepNodeReport::Gemm(n) => n.kind.name(),
            StepNodeReport::Vector(v) => v.op.kind.name(),
        }
    }

    pub fn total_ns(&self) -> f64 {
        match self {
            StepNodeReport::Gemm(n) => n.total_ns,
            StepNodeReport::Vector(v) => v.total_ns,
        }
    }
}

/// One entry of the overlap ledger: `pairs` adjacent (producer reduce,
/// consumer dequant) overlaps, each hiding `gain_ns` of vector work under
/// the first-order ledger — plus, when the pair's schedules are
/// spliceable, the co-scheduler's exact pricing of the same overlap
/// (DESIGN.md §12).  Within an expert batch the producer and consumer are
/// instances of the same node (`producer == consumer`, `pairs == count -
/// 1`).
#[derive(Debug, Clone)]
pub struct OverlapPair {
    /// Index into [`StepReport::nodes`] of the node whose reduce moves.
    pub producer: usize,
    /// Index of the node whose dequant prologue hides it.
    pub consumer: usize,
    /// Adjacent GEMM pairs this entry covers.
    pub pairs: usize,
    /// Exposed reduce time available per pair (the producer's tail).
    pub reduce_ns: f64,
    /// Vector slack available per pair (the consumer's dequant headroom).
    pub slack_ns: f64,
    /// min(reduce_ns, slack_ns) — the first-order ledger's gain per pair.
    pub gain_ns: f64,
    /// The co-scheduler's exact decision for one pair (merged-trace
    /// re-simulation), `None` when no merged trace is available.
    pub exact: Option<PairDecision>,
    /// The chain-level schedule for a saturating producer (DESIGN.md
    /// §13): the tail spread across this consumer's AND the next
    /// prologue, re-balanced.  Set only when the chain priced strictly
    /// better than the two pair decisions it replaces.
    pub chain: Option<ChainOverlap>,
    /// This pair's prologue was consumed by an upstream chain; its own
    /// exact gain is not served (the ledger estimate still renders).
    pub superseded: bool,
}

/// The chain-level decision attached to a saturating producer's entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChainOverlap {
    /// Index into [`StepReport::nodes`] of the SECOND consumer whose
    /// prologue absorbs the tail overflow.
    pub second_consumer: usize,
    /// Exact three-kernel pricing (sequential covers all three nodes).
    pub decision: PairDecision,
}

impl OverlapPair {
    pub fn total_gain_ns(&self) -> f64 {
        self.pairs as f64 * self.gain_ns
    }

    /// The per-pair gain `OverlapMode::Exact` realizes: the co-schedule
    /// decision where a merged trace exists, the ledger term otherwise.
    pub fn exact_gain_ns(&self) -> f64 {
        self.exact.map(|d| d.gain_ns).unwrap_or(self.gain_ns)
    }

    /// The per-pair gain the exact plan actually serves once chain-level
    /// decisions are resolved: the chain's gain where one was applied,
    /// zero where an upstream chain consumed this prologue, the pair
    /// decision (or ledger fallback) otherwise.
    pub fn served_exact_gain_ns(&self) -> f64 {
        if self.superseded {
            return 0.0;
        }
        match self.chain {
            Some(c) => c.decision.gain_ns,
            None => self.exact_gain_ns(),
        }
    }

    pub fn total_exact_gain_ns(&self) -> f64 {
        self.pairs as f64 * self.served_exact_gain_ns()
    }

    /// Exact minus ledger, per pair (positive when the merged trace beats
    /// the first-order estimate).
    pub fn exact_vs_ledger_ns(&self) -> f64 {
        self.exact_gain_ns() - self.gain_ns
    }
}

/// The simulated full decode step of one layer.
#[derive(Debug, Clone)]
pub struct StepReport {
    pub batch: usize,
    pub kv_len: usize,
    /// The requested overlap mode (what `served_ns` prices).
    pub mode: OverlapMode,
    pub nodes: Vec<StepNodeReport>,
    /// Every eligible adjacent overlap (empty under zero-gain graphs).
    pub ledger: Vec<OverlapPair>,
    /// Sum of all node times, strictly back to back (PR-2's ledger).
    pub sequential_ns: f64,
    /// `sequential_ns` minus every ledger gain (never larger).
    pub overlapped_ns: f64,
    /// `sequential_ns` minus every co-scheduled exact gain (DESIGN.md
    /// §12); equals `overlapped_ns` where no merged trace was available —
    /// including under `Sequential`/`Overlapped`, which skip the
    /// merged-trace simulations entirely (they never serve this value).
    pub exact_ns: f64,
    /// The step-level weight-residency plan (DESIGN.md §13), present when
    /// the residency mode asked for one.  Its `resident_ns` is the exact
    /// price of the step with the plan's weights pinned; `served_ns`
    /// takes `min(mode plan, resident plan)`, so residency is never
    /// slower by construction.
    pub residency: Option<ResidencyPlan>,
}

impl StepReport {
    /// What `OverlapMode::Auto` would serve WITHOUT the residency plan —
    /// the PR-4 Auto base the residency speedup is measured against.
    pub fn auto_ns(&self) -> f64 {
        self.exact_ns.min(self.overlapped_ns).min(self.sequential_ns)
    }

    /// The step latency the requested mode serves.
    pub fn served_ns(&self) -> f64 {
        let base = match self.mode {
            OverlapMode::Sequential => self.sequential_ns,
            OverlapMode::Overlapped => self.overlapped_ns,
            OverlapMode::Exact => self.exact_ns,
            OverlapMode::Auto => self.auto_ns(),
        };
        match &self.residency {
            Some(plan) => base.min(plan.resident_ns),
            None => base,
        }
    }

    /// The resident plan's exact step price (`None` when residency was
    /// off or planning found nothing to pin beyond the baseline).
    pub fn resident_ns(&self) -> Option<f64> {
        self.residency.as_ref().map(|p| p.resident_ns)
    }

    /// What the weight-residency plan buys over its unpinned baseline.
    pub fn residency_gain_ns(&self) -> f64 {
        self.residency.as_ref().map(|p| p.gain_ns()).unwrap_or(0.0)
    }

    /// Per-decode-step latency for a model with `layers` layers.
    pub fn step_ns(&self, layers: usize) -> f64 {
        self.served_ns() * layers as f64
    }

    /// Total overlap gain of the first-order ledger.
    pub fn overlap_gain_ns(&self) -> f64 {
        self.ledger.iter().map(|p| p.total_gain_ns()).sum()
    }

    /// Total gain the co-scheduler realizes (exact terms where merged
    /// traces exist, ledger terms elsewhere).
    pub fn exact_gain_ns(&self) -> f64 {
        self.ledger.iter().map(|p| p.total_exact_gain_ns()).sum()
    }

    /// Summed GEMM node time (sequential pricing).
    pub fn gemm_ns(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n, StepNodeReport::Gemm(_)))
            .map(|n| n.total_ns())
            .sum()
    }

    /// Summed non-GEMM (attention + glue) node time.
    pub fn vector_ns(&self) -> f64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n, StepNodeReport::Vector(_)))
            .map(|n| n.total_ns())
            .sum()
    }

    /// The GEMM sub-chain as a [`LayerReport`] (issue order preserved).
    pub fn gemm_report(&self) -> LayerReport {
        LayerReport {
            batch: self.batch,
            nodes: self
                .nodes
                .iter()
                .filter_map(|n| match n {
                    StepNodeReport::Gemm(g) => Some(g.clone()),
                    StepNodeReport::Vector(_) => None,
                })
                .collect(),
        }
    }
}

/// Build the overlap ledger over the step's GEMM sub-chain: expert
/// batches overlap internally (`count - 1` pairs), and each GEMM's
/// trailing reduce overlaps the next GEMM's dequant prologue.  Vector
/// glue between two GEMMs does not break eligibility — the consumer's
/// dequant touches only its own weights, so it is independent of every
/// intervening activation op (DESIGN.md §11).
///
/// `traces` holds each node's served kernel trace (aligned with `nodes`,
/// `None` for vector nodes): when `price_exact` is set (the `Exact` and
/// `Auto` modes — `Sequential`/`Overlapped` never serve the result, so
/// they skip the extra merged-trace simulations), wherever the
/// producer's reduce tail and the consumer's dequant prologue are
/// spliceable, the pair also carries the co-scheduler's exact
/// merged-trace pricing (DESIGN.md §12).  An entry appears whenever
/// either pricing finds a positive gain.
fn build_ledger(
    sim: &Simulator,
    nodes: &[StepNodeReport],
    traces: &[Option<KernelTrace>],
    price_exact: bool,
) -> anyhow::Result<Vec<OverlapPair>> {
    let gemms: Vec<(usize, &NodeReport)> = nodes
        .iter()
        .enumerate()
        .filter_map(|(i, n)| match n {
            StepNodeReport::Gemm(g) => Some((i, g)),
            StepNodeReport::Vector(_) => None,
        })
        .collect();
    let mut ledger = Vec::new();
    let mut push = |ledger: &mut Vec<OverlapPair>,
                    producer: (usize, &NodeReport),
                    consumer: (usize, &NodeReport),
                    pairs: usize|
     -> anyhow::Result<()> {
        let (pi, p) = producer;
        let (ci, c) = consumer;
        let gain = p.reduce_tail_ns.min(c.dequant_slack_ns);
        let exact = match (&traces[pi], &traces[ci]) {
            (Some(pt), Some(ct)) if price_exact => {
                coschedule::pair_decision(sim, pt, ct, p.unit_ns + c.unit_ns)?
            }
            _ => None,
        };
        if gain > 0.0 || exact.is_some_and(|d| d.gain_ns > 0.0) {
            ledger.push(OverlapPair {
                producer: pi,
                consumer: ci,
                pairs,
                reduce_ns: p.reduce_tail_ns,
                slack_ns: c.dequant_slack_ns,
                gain_ns: gain,
                exact,
                chain: None,
                superseded: false,
            });
        }
        Ok(())
    };
    for &(i, g) in &gemms {
        if g.count > 1 {
            push(&mut ledger, (i, g), (i, g), g.count - 1)?;
        }
    }
    for w in gemms.windows(2) {
        push(&mut ledger, w[0], w[1], 1)?;
    }

    if price_exact {
        resolve_chains(sim, &gemms, traces, &mut ledger)?;
    }
    Ok(ledger)
}

/// Chain-level co-scheduling pass (DESIGN.md §13): for every consecutive
/// GEMM triple whose producer tail saturates the first prologue, price
/// the two-consumer chain splice and apply it greedily when it strictly
/// beats BOTH the two pair decisions it replaces and their first-order
/// ledger terms.  Each prologue is consumed by at most one splice: a
/// chained producer's second consumer supersedes the (first consumer ->
/// second consumer) pair, and a superseded or already-chained entry is
/// never chained again — no vector engine is double-booked across
/// decisions.
fn resolve_chains(
    sim: &Simulator,
    gemms: &[(usize, &NodeReport)],
    traces: &[Option<KernelTrace>],
    ledger: &mut Vec<OverlapPair>,
) -> anyhow::Result<()> {
    for w in gemms.windows(3) {
        let [(ai, a), (bi, b), (ci, c)] = [w[0], w[1], w[2]];
        // Chains only over single-instance nodes: an expert batch in the
        // middle would run count-1 more instances between the spliced
        // first consumer and the second one, evicting the carried
        // partials far beyond the one attenuation step the merged trace
        // prices — the three-kernel simulation would overstate the gain.
        if a.count != 1 || b.count != 1 || c.count != 1 {
            continue;
        }
        let (Some(ta), Some(tb), Some(tc)) = (&traces[ai], &traces[bi], &traces[ci]) else {
            continue;
        };
        if !coschedule::saturates(ta, tb) {
            continue;
        }
        let entry_pos = |p: usize, q: usize, l: &[OverlapPair]| {
            l.iter().position(|e| e.producer == p && e.consumer == q)
        };
        // Skip when either prologue is already spoken for.
        let first = entry_pos(ai, bi, ledger);
        if first.is_some_and(|i| ledger[i].chain.is_some() || ledger[i].superseded) {
            continue;
        }
        let second = entry_pos(bi, ci, ledger);
        if second.is_some_and(|i| ledger[i].chain.is_some() || ledger[i].superseded) {
            continue;
        }
        let sequential = a.unit_ns + b.unit_ns + c.unit_ns;
        let Some(decision) = coschedule::chain_decision(sim, ta, tb, tc, sequential)? else {
            continue;
        };
        let replaced_exact = first.map_or(0.0, |i| ledger[i].exact_gain_ns())
            + second.map_or(0.0, |i| ledger[i].exact_gain_ns());
        let replaced_ledger =
            first.map_or(0.0, |i| ledger[i].gain_ns) + second.map_or(0.0, |i| ledger[i].gain_ns);
        if decision.gain_ns <= replaced_exact.max(replaced_ledger) + 1e-9 {
            continue;
        }
        let chain = ChainOverlap { second_consumer: ci, decision };
        match first {
            Some(i) => ledger[i].chain = Some(chain),
            None => ledger.push(OverlapPair {
                producer: ai,
                consumer: bi,
                pairs: 1,
                reduce_ns: a.reduce_tail_ns,
                slack_ns: b.dequant_slack_ns,
                gain_ns: a.reduce_tail_ns.min(b.dequant_slack_ns),
                exact: None,
                chain: Some(chain),
                superseded: false,
            }),
        }
        if let Some(i) = second {
            ledger[i].superseded = true;
        }
    }
    Ok(())
}

/// Simulate the full decode-step graph under an overlap mode (weight
/// residency off — the PR-4 surface).
pub fn simulate_step(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    resolve: impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)>,
) -> anyhow::Result<StepReport> {
    simulate_step_with(machine, step, mode, ResidencyMode::Off, resolve)
}

/// Simulate the full decode-step graph under an overlap mode AND a
/// step-level weight-residency mode (DESIGN.md §13).
pub fn simulate_step_with(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    resolve: impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)>,
) -> anyhow::Result<StepReport> {
    simulate_step_nodes(
        machine,
        step.nodes(),
        step.layer.batch,
        step.kv_len,
        mode,
        residency_mode,
        resolve,
    )
}

/// Simulate a causal prefill chunk (DESIGN.md §15) under the same
/// overlap + residency machinery as decode: the graph shape is identical
/// (same GEMM chain at M = chunk tokens, same ledger eligibility, same
/// residency planner), only the attention passes are causal-context
/// sized.  `batch` in the report is the chunk's token count and `kv_len`
/// the cache length after the chunk lands.
pub fn simulate_prefill_step_with(
    machine: &MachineConfig,
    step: &crate::workload::PrefillStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    resolve: impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)>,
) -> anyhow::Result<StepReport> {
    simulate_step_nodes(
        machine,
        step.nodes(),
        step.chunk_tokens(),
        step.kv_end(),
        mode,
        residency_mode,
        resolve,
    )
}

/// Tuned prefill-chunk simulation — the serving warm-up and
/// `e2e_serve` bench path.
pub fn simulate_prefill_step_tuned_with(
    machine: &MachineConfig,
    step: &crate::workload::PrefillStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    tuner: &mut Tuner,
) -> anyhow::Result<StepReport> {
    simulate_prefill_step_with(machine, step, mode, residency_mode, |p| tuner_resolve(tuner, p))
}

/// Shared step-graph core: price an issue-ordered node list (decode or
/// prefill — the simulator only consumes the nodes, the batch label and
/// the kv length) under an overlap mode and a residency mode.
fn simulate_step_nodes(
    machine: &MachineConfig,
    specs: Vec<StepNode>,
    batch: usize,
    kv_len: usize,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    mut resolve: impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)>,
) -> anyhow::Result<StepReport> {
    let sim = Simulator::new(machine.clone());
    let mut nodes = Vec::new();
    let mut traces: Vec<Option<KernelTrace>> = Vec::new();
    for spec in specs {
        nodes.push(match spec {
            StepNode::Gemm(node) => {
                let assignment = resolve(&node.problem)?;
                let (report, trace) = simulate_gemm_node(machine, &sim, &node, assignment)?;
                traces.push(Some(trace));
                StepNodeReport::Gemm(report)
            }
            StepNode::Vector(op) => {
                let c = vecpass::price_pass(
                    machine,
                    op.elems,
                    op.ops_per_elem,
                    op.hbm_bytes,
                    op.l2_bytes,
                );
                traces.push(None);
                StepNodeReport::Vector(VectorNodeReport {
                    op,
                    total_ns: c.total_ns,
                    compute_ns: c.compute_ns,
                    hbm_ns: c.hbm_ns,
                    l2_ns: c.l2_ns,
                })
            }
        });
    }
    let sequential_ns: f64 = nodes.iter().map(|n| n.total_ns()).sum();
    let price_exact = matches!(mode, OverlapMode::Exact | OverlapMode::Auto);
    let ledger = build_ledger(&sim, &nodes, &traces, price_exact)?;
    let gain: f64 = ledger.iter().map(|p| p.total_gain_ns()).sum();
    let exact_gain: f64 = ledger.iter().map(|p| p.total_exact_gain_ns()).sum();
    let residency = match residency_mode {
        ResidencyMode::Off => None,
        ResidencyMode::Auto => {
            let mut inputs = Vec::new();
            let mut extra_ns = 0.0;
            for (node, trace) in nodes.iter().zip(&traces) {
                match (node, trace) {
                    (StepNodeReport::Gemm(g), Some(t)) => inputs.push(residency::PlanNodeInput {
                        kind: g.kind,
                        problem: g.problem,
                        count: g.count,
                        unit_ns: g.unit_ns,
                        trace: t.clone(),
                    }),
                    _ => extra_ns += node.total_ns(),
                }
            }
            Some(residency::plan_nodes(machine, &inputs, extra_ns, price_exact)?)
        }
    };
    Ok(StepReport {
        batch,
        kv_len,
        mode,
        nodes,
        ledger,
        sequential_ns,
        overlapped_ns: sequential_ns - gain,
        exact_ns: sequential_ns - exact_gain,
        residency,
    })
}

/// A Split-K resolver that forces a K split where legal — the overlap
/// sweep harness shared by the tests and the bench stress leg.  The
/// wide-N heuristics (and the tuner, which mostly prefers the fused
/// ablation) pick S = 1 on most decode shapes — no reduce, nothing to
/// overlap — so overlap-focused sweeps force S >= 2 to exercise the
/// ledger and the co-scheduler non-vacuously.
pub fn forced_split_resolver(
    machine: &MachineConfig,
) -> impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)> + '_ {
    move |p| {
        let mut t = kernels::select_tiling(machine, p, Strategy::SplitK)?;
        let split = Tiling { splits: t.splits.max(2), ..t };
        if split.validate(machine, p).is_ok() {
            t = split;
        }
        Ok((Strategy::SplitK, t, Resolution::Heuristic))
    }
}

/// Simulate the full step with every GEMM node resolved through the tuner.
pub fn simulate_step_tuned(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    tuner: &mut Tuner,
) -> anyhow::Result<StepReport> {
    simulate_step(machine, step, mode, |p| tuner_resolve(tuner, p))
}

/// Tuned full-step simulation with an explicit residency mode — the
/// `repro layer --residency` and `e2e_layer` bench path.
pub fn simulate_step_tuned_with(
    machine: &MachineConfig,
    step: &DecodeStep,
    mode: OverlapMode,
    residency_mode: ResidencyMode,
    tuner: &mut Tuner,
) -> anyhow::Result<StepReport> {
    simulate_step_with(machine, step, mode, residency_mode, |p| tuner_resolve(tuner, p))
}

/// Cost of re-establishing a residency plan's L2 pins after a prefill
/// chunk (or any other burst) streamed its own weights and activations
/// through the shared buffer (DESIGN.md §15): the pinned packed weights
/// re-stream from HBM once before the next decode step regains the
/// plan's residency_gain.  Pure bandwidth term — integer bytes over the
/// machine's HBM rate — so the serve-loop mirror reproduces it exactly.
pub fn repin_ns(machine: &MachineConfig, pinned_bytes: u64) -> f64 {
    pinned_bytes as f64 / machine.hbm_bw
}

/// Render the per-node table plus layer / step totals (GEMM chain only).
pub fn render_layer(report: &LayerReport, layers: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Decode-layer GEMM graph — batch {} (simulated)\n",
        report.batch
    ));
    out.push_str(&format!(
        "{:<10} {:<20} {:>5} {:>12} {:>10} | {:>10} {:>11} {:>8}\n",
        "node", "shape", "x", "strategy", "via", "served_us", "barrier_us", "reduce"
    ));
    for n in &report.nodes {
        out.push_str(&format!(
            "{:<10} {:<20} {:>5} {:>12} {:>10} | {:>10.2} {:>11.2} {:>7.2}x\n",
            n.kind.name(),
            format!("m{}_n{}_k{}", n.problem.m, n.problem.n, n.problem.k),
            n.count,
            n.strategy.name(),
            n.resolution.name(),
            n.total_ns / 1e3,
            n.barrier_ns / 1e3,
            n.reduce_speedup(),
        ));
    }
    out.push_str(&format!(
        "\nlayer: {} served vs {} barrier-reduce ({:.3}x from reduce pipelining)\n",
        stats::fmt_ns(report.layer_ns()),
        stats::fmt_ns(report.layer_barrier_ns()),
        report.layer_barrier_ns() / report.layer_ns(),
    ));
    out.push_str(&format!(
        "step ({layers} layers): {}  -> {:.0} decode steps/s of pure GEMM headroom\n",
        stats::fmt_ns(report.step_ns(layers)),
        1e9 / report.step_ns(layers),
    ));
    out
}

/// Render the full decode-step graph with the overlap ledger.
pub fn render_step(report: &StepReport, layers: usize) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Full decode-step graph — batch {}, kv_len {} (simulated, overlap {})\n",
        report.batch,
        report.kv_len,
        report.mode.name()
    ));
    out.push_str(&format!(
        "{:<12} {:<20} {:>5} {:>12} {:>10} | {:>10}\n",
        "node", "shape", "x", "strategy", "via", "served_us"
    ));
    for n in &report.nodes {
        match n {
            StepNodeReport::Gemm(g) => out.push_str(&format!(
                "{:<12} {:<20} {:>5} {:>12} {:>10} | {:>10.2}\n",
                g.kind.name(),
                format!("m{}_n{}_k{}", g.problem.m, g.problem.n, g.problem.k),
                g.count,
                g.strategy.name(),
                g.resolution.name(),
                g.total_ns / 1e3,
            )),
            StepNodeReport::Vector(v) => out.push_str(&format!(
                "{:<12} {:<20} {:>5} {:>12} {:>10} | {:>10.2}\n",
                v.op.kind.name(),
                format!("{} elems", v.op.elems),
                1,
                "-",
                "-",
                v.total_ns / 1e3,
            )),
        }
    }
    let pairs: usize = report.ledger.iter().map(|p| p.pairs).sum();
    out.push_str(&format!(
        "\ngemm {} + attention/glue {}  ({} eligible reduce/dequant overlaps hide {} \
         ledger / {} exact)\n",
        stats::fmt_ns(report.gemm_ns()),
        stats::fmt_ns(report.vector_ns()),
        pairs,
        stats::fmt_ns(report.overlap_gain_ns()),
        stats::fmt_ns(report.exact_gain_ns()),
    ));
    for p in &report.ledger {
        let exact = match p.exact {
            Some(d) => format!(
                "exact {}/pair (merged {}, {}{} vs ledger)",
                stats::fmt_ns(d.gain_ns),
                stats::fmt_ns(d.merged_ns),
                if p.exact_vs_ledger_ns() >= 0.0 { "+" } else { "" },
                stats::fmt_ns(p.exact_vs_ledger_ns()),
            ),
            None => "no merged trace (ledger term serves)".to_string(),
        };
        out.push_str(&format!(
            "  overlap {}->{} x{}: ledger {}/pair  {}\n",
            report.nodes[p.producer].name(),
            report.nodes[p.consumer].name(),
            p.pairs,
            stats::fmt_ns(p.gain_ns),
            exact,
        ));
        if let Some(c) = p.chain {
            out.push_str(&format!(
                "    chain ->{} (saturated prologue, re-balanced): {} served over the \
                 pair decisions\n",
                report.nodes[c.second_consumer].name(),
                stats::fmt_ns(c.decision.gain_ns),
            ));
        }
        if p.superseded {
            out.push_str("    (prologue consumed by the upstream chain)\n");
        }
    }
    if let Some(plan) = &report.residency {
        let pins: Vec<String> = plan
            .pins
            .iter()
            .map(|pin| format!("{}x{}", pin.kind.name(), pin.instances))
            .collect();
        out.push_str(&format!(
            "residency: pinned {} of {} budget ({}) -> resident {} ({} vs unpinned)\n",
            stats::fmt_bytes(plan.pinned_bytes as f64),
            stats::fmt_bytes(plan.budget_bytes as f64),
            if pins.is_empty() { "nothing worth pinning".to_string() } else { pins.join(" ") },
            stats::fmt_ns(plan.resident_ns),
            stats::fmt_ns(plan.gain_ns()),
        ));
    }
    out.push_str(&format!(
        "layer: {} sequential vs {} overlapped vs {} exact{} -> served {}\n",
        stats::fmt_ns(report.sequential_ns),
        stats::fmt_ns(report.overlapped_ns),
        stats::fmt_ns(report.exact_ns),
        match report.resident_ns() {
            Some(r) => format!(" vs {} resident", stats::fmt_ns(r)),
            None => String::new(),
        },
        stats::fmt_ns(report.served_ns()),
    ));
    out.push_str(&format!(
        "step ({layers} layers): {}  -> {:.0} decode steps/s end to end\n",
        stats::fmt_ns(report.step_ns(layers)),
        1e9 / report.step_ns(layers),
    ));
    out
}

/// JSON form of a layer report (BENCH_layer.json, `repro layer --json`).
pub fn layer_json(report: &LayerReport) -> Json {
    let nodes = report
        .nodes
        .iter()
        .map(|n| {
            Json::obj(vec![
                ("kind", Json::str(n.kind.name())),
                ("m", Json::num(n.problem.m as f64)),
                ("n", Json::num(n.problem.n as f64)),
                ("k", Json::num(n.problem.k as f64)),
                ("count", Json::num(n.count as f64)),
                ("strategy", Json::str(n.strategy.name())),
                ("resolution", Json::str(n.resolution.name())),
                ("served_ns", Json::num(n.total_ns)),
                ("barrier_ns", Json::num(n.barrier_ns)),
                ("reduce_speedup", Json::num(n.reduce_speedup())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("batch", Json::num(report.batch as f64)),
        ("layer_ns", Json::num(report.layer_ns())),
        ("layer_barrier_ns", Json::num(report.layer_barrier_ns())),
        ("nodes", Json::arr(nodes)),
    ])
}

/// JSON form of a full decode-step report (`repro layer --overlap --json`).
pub fn step_json(report: &StepReport) -> Json {
    let nodes = report
        .nodes
        .iter()
        .map(|n| match n {
            StepNodeReport::Gemm(g) => Json::obj(vec![
                ("node", Json::str("gemm")),
                ("kind", Json::str(g.kind.name())),
                ("m", Json::num(g.problem.m as f64)),
                ("n", Json::num(g.problem.n as f64)),
                ("k", Json::num(g.problem.k as f64)),
                ("count", Json::num(g.count as f64)),
                ("strategy", Json::str(g.strategy.name())),
                ("resolution", Json::str(g.resolution.name())),
                ("served_ns", Json::num(g.total_ns)),
                ("barrier_ns", Json::num(g.barrier_ns)),
                ("reduce_tail_ns", Json::num(g.reduce_tail_ns)),
                ("dequant_slack_ns", Json::num(g.dequant_slack_ns)),
            ]),
            StepNodeReport::Vector(v) => Json::obj(vec![
                ("node", Json::str("vector")),
                ("kind", Json::str(v.op.kind.name())),
                ("elems", Json::num(v.op.elems as f64)),
                ("served_ns", Json::num(v.total_ns)),
                ("compute_ns", Json::num(v.compute_ns)),
                ("hbm_ns", Json::num(v.hbm_ns)),
                ("l2_ns", Json::num(v.l2_ns)),
            ]),
        })
        .collect();
    let overlap = report
        .ledger
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("producer", Json::num(p.producer as f64)),
                ("consumer", Json::num(p.consumer as f64)),
                ("pairs", Json::num(p.pairs as f64)),
                ("reduce_ns", Json::num(p.reduce_ns)),
                ("slack_ns", Json::num(p.slack_ns)),
                ("gain_ns", Json::num(p.gain_ns)),
                ("total_gain_ns", Json::num(p.total_gain_ns())),
                (
                    "exact_merged_ns",
                    p.exact.map(|d| Json::num(d.merged_ns)).unwrap_or(Json::Null),
                ),
                (
                    "exact_gain_ns",
                    p.exact.map(|d| Json::num(d.gain_ns)).unwrap_or(Json::Null),
                ),
                ("exact_vs_ledger_ns", Json::num(p.exact_vs_ledger_ns())),
                (
                    "chain_gain_ns",
                    p.chain.map(|c| Json::num(c.decision.gain_ns)).unwrap_or(Json::Null),
                ),
                (
                    "chain_second_consumer",
                    p.chain
                        .map(|c| Json::num(c.second_consumer as f64))
                        .unwrap_or(Json::Null),
                ),
                ("superseded", Json::Bool(p.superseded)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("batch", Json::num(report.batch as f64)),
        ("kv_len", Json::num(report.kv_len as f64)),
        ("overlap_mode", Json::str(report.mode.name())),
        ("sequential_ns", Json::num(report.sequential_ns)),
        ("overlapped_ns", Json::num(report.overlapped_ns)),
        ("exact_ns", Json::num(report.exact_ns)),
        (
            "resident_ns",
            report.resident_ns().map(Json::num).unwrap_or(Json::Null),
        ),
        ("residency_gain_ns", Json::num(report.residency_gain_ns())),
        (
            "residency",
            report
                .residency
                .as_ref()
                .map(|p| p.to_json())
                .unwrap_or(Json::Null),
        ),
        ("served_ns", Json::num(report.served_ns())),
        ("gemm_ns", Json::num(report.gemm_ns())),
        ("vector_ns", Json::num(report.vector_ns())),
        ("nodes", Json::arr(nodes)),
        ("overlap", Json::arr(overlap)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::{layer_geometry, moe_geometry};

    fn fixed(
        machine: &MachineConfig,
        strategy: Strategy,
    ) -> impl FnMut(&GemmProblem) -> anyhow::Result<(Strategy, Tiling, Resolution)> + '_ {
        move |p| {
            Ok((strategy, kernels::select_tiling(machine, p, strategy)?, Resolution::Heuristic))
        }
    }

    #[test]
    fn simulates_all_four_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(r.nodes.len(), 4);
        for n in &r.nodes {
            assert!(n.total_ns > 0.0 && n.total_ns.is_finite());
            assert!(
                n.total_ns <= n.barrier_ns * 1.000001,
                "{}: served {} slower than barrier {}",
                n.kind.name(),
                n.total_ns,
                n.barrier_ns
            );
            assert_eq!(n.count, 1);
            assert_eq!(n.total_ns, n.unit_ns);
        }
        assert!(r.layer_ns() > r.nodes[0].total_ns);
        assert_eq!(r.step_ns(2), 2.0 * r.layer_ns());
    }

    #[test]
    fn render_and_json_carry_all_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::Chunked)).unwrap();
        let text = render_layer(&r, 16);
        for kind in GemmKind::all() {
            assert!(text.contains(kind.name()), "missing {}", kind.name());
        }
        let j = layer_json(&r).to_string();
        let parsed = Json::parse(&j).unwrap();
        assert_eq!(parsed.req("nodes").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn resolver_errors_propagate() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let r = simulate_layer(&m, &layer, |_| anyhow::bail!("no assignment"));
        assert!(r.is_err());
    }

    #[test]
    fn moe_layer_multiplies_expert_batches() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        let r = simulate_layer(&m, &layer, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(r.nodes.len(), 4);
        let experts: Vec<&NodeReport> =
            r.nodes.iter().filter(|n| n.kind == GemmKind::MoeExpert).collect();
        assert_eq!(experts.len(), 2);
        for e in experts {
            assert_eq!(e.count, 64);
            assert!((e.total_ns - 64.0 * e.unit_ns).abs() < 1e-6);
        }
    }

    #[test]
    fn step_covers_gemm_and_vector_nodes() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let r = simulate_step(&m, &step, OverlapMode::Auto, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(r.nodes.len(), 12);
        assert!(r.gemm_ns() > 0.0 && r.vector_ns() > 0.0);
        assert!((r.sequential_ns - r.gemm_ns() - r.vector_ns()).abs() < 1e-6);
        assert!(r.overlapped_ns <= r.sequential_ns);
        assert!(r.served_ns() <= r.sequential_ns);
        assert_eq!(r.gemm_report().nodes.len(), 4);
        // The overlap accounting balances exactly.
        assert!(
            (r.sequential_ns - r.overlap_gain_ns() - r.overlapped_ns).abs() < 1e-6,
            "ledger must price every gain exactly once"
        );
        let text = render_step(&r, 32);
        for name in ["attn_score", "rmsnorm", "qkv", "down", "overlap"] {
            assert!(text.contains(name), "render missing {name}:\n{text}");
        }
        let parsed = Json::parse(&step_json(&r).to_string()).unwrap();
        assert_eq!(parsed.req("nodes").unwrap().as_arr().unwrap().len(), 12);
    }

    #[test]
    fn overlap_modes_order_correctly() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        let step = DecodeStep::new(layer, 2048, 56);
        let seq = simulate_step(&m, &step, OverlapMode::Sequential, fixed(&m, Strategy::SplitK))
            .unwrap();
        let auto =
            simulate_step(&m, &step, OverlapMode::Auto, fixed(&m, Strategy::SplitK)).unwrap();
        assert_eq!(seq.served_ns(), seq.sequential_ns);
        assert!(auto.served_ns() <= seq.served_ns() * 1.000001);
        // Auto serves the min of all three plans — structurally never
        // slower than PR 3's ledger or the exact co-schedule.
        assert!(auto.served_ns() <= auto.overlapped_ns * 1.000001);
        assert!(auto.served_ns() <= auto.exact_ns * 1.000001);
        // Exact itself never loses to the sequential chain: every merge
        // is declined when it prices slower.
        assert!(auto.exact_ns <= auto.sequential_ns * 1.000001);
        // Expert batches expose internal overlap pairs.
        assert!(
            auto.ledger.iter().any(|p| p.producer == p.consumer && p.pairs > 1)
                || auto.ledger.is_empty(),
            "expert fan-out should ledger internal pairs when any gain exists"
        );
    }

    #[test]
    fn residency_auto_never_slower_and_json_carries_the_plan() {
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let off = simulate_step(&m, &step, OverlapMode::Auto, fixed(&m, Strategy::Fused)).unwrap();
        let on = simulate_step_with(
            &m,
            &step,
            OverlapMode::Auto,
            ResidencyMode::Auto,
            fixed(&m, Strategy::Fused),
        )
        .unwrap();
        // Identical chain, so the non-residency prices agree; the resident
        // plan can only improve the served step.
        assert!((on.sequential_ns - off.sequential_ns).abs() < 1e-6);
        assert!(on.served_ns() <= off.served_ns() * 1.000001);
        let plan = on.residency.as_ref().expect("residency auto must carry a plan");
        assert!(plan.pinned_bytes <= plan.budget_bytes);
        assert!(plan.resident_ns <= plan.baseline_ns * 1.000001);
        // llama32's fused K>>N nodes fit the budget: pinning must win.
        assert!(
            on.residency_gain_ns() > 0.0,
            "resident weights must pay on the llama32 fused chain: {plan:?}"
        );
        assert!(on.served_ns() < off.served_ns(), "strictly faster with residency");
        let j = Json::parse(&step_json(&on).to_string()).unwrap();
        assert!(j.req("resident_ns").unwrap().as_f64().is_some());
        assert!(j.req("residency").unwrap().get("pins").is_some());
        let rendered = render_step(&on, 16);
        assert!(rendered.contains("residency:"), "render missing residency:\n{rendered}");
        // Residency off leaves the PR-4 JSON shape (null cells).
        let j = Json::parse(&step_json(&off).to_string()).unwrap();
        assert!(j.req("resident_ns").unwrap().as_f64().is_none());
        assert_eq!(j.req("residency_gain_ns").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn exact_mode_prices_merged_traces_on_forced_splits() {
        // Force a K split on every node so each carries an exposed reduce
        // tail: the co-scheduler must find spliceable pairs, and the
        // served Exact plan must beat (or tie) the sequential chain.
        let m = MachineConfig::ascend910();
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let rep =
            simulate_step(&m, &step, OverlapMode::Exact, forced_split_resolver(&m)).unwrap();
        assert_eq!(rep.served_ns(), rep.exact_ns);
        assert!(rep.exact_ns <= rep.sequential_ns * 1.000001);
        let with_merged: Vec<&OverlapPair> =
            rep.ledger.iter().filter(|p| p.exact.is_some()).collect();
        assert!(
            !with_merged.is_empty(),
            "forced splits must yield at least one spliceable pair: {:?}",
            rep.ledger
        );
        for p in &with_merged {
            let d = p.exact.unwrap();
            assert!(d.gain_ns >= 0.0);
            assert!(d.merged_ns > 0.0 && d.merged_ns.is_finite());
            assert!(
                (d.gain_ns - (d.sequential_ns - d.merged_ns).max(0.0)).abs() < 1e-6,
                "exact gain must be the clamped merged-vs-sequential delta"
            );
        }
        // The accounting balances: exact_ns = sequential - exact gains.
        assert!(
            (rep.sequential_ns - rep.exact_gain_ns() - rep.exact_ns).abs() < 1e-6,
            "exact ledger must price every gain exactly once"
        );
        // JSON carries the exact cells.
        let j = Json::parse(&step_json(&rep).to_string()).unwrap();
        assert_eq!(j.req_str("overlap_mode").unwrap(), "exact");
        assert!(j.req("exact_ns").unwrap().as_f64().unwrap() > 0.0);
        let overlap = j.req("overlap").unwrap().as_arr().unwrap();
        assert!(overlap
            .iter()
            .any(|o| o.req("exact_gain_ns").unwrap().as_f64().is_some()));
    }
}
