//! Performance analysis: roofline model, memory-traffic decomposition and
//! human/machine-readable report rendering.
//!
//! This module backs the paper's §4.2 bottleneck analysis: given a
//! simulated kernel, it decomposes the byte traffic per buffer class,
//! identifies the binding resource, and renders the comparison tables the
//! benches print (Figures 2 and 3).

pub mod coschedule;
pub mod golden;
pub mod layer;
pub mod report;
pub mod residency;
pub mod roofline;
pub mod stepop;
pub mod stepsim;
pub mod sensitivity;
pub mod timeline;
pub mod traffic;
