//! Golden-trace serialization: a stable, diffable JSON digest of a
//! [`KernelTrace`] — and of a full [`DecodeStep`] graph — for the
//! snapshot tests under `rust/tests/fixtures/`.
//!
//! The digests capture what a schedule / step graph *does* — phase
//! structure, engine occupancy, step counts, per-class byte totals, node
//! ordering and problem shapes — without any timing, so schedule and
//! graph refactors diff against known-good structures while timing-model
//! changes leave the fixtures untouched.  Regenerate with
//! `BLESS=1 cargo test --test golden_traces`.

use crate::ascend::{BufferClass, KernelTrace, Phase, Unit, WorkspacePolicy};
use crate::util::json::Json;
use crate::workload::decode_layer::{DecodeStep, StepNode};
use crate::workload::PrefillStep;

/// Every buffer class with its stable fixture label.
const CLASSES: [(BufferClass, &str); 9] = [
    (BufferClass::WeightPacked, "weight_packed"),
    (BufferClass::WeightF16, "weight_f16"),
    (BufferClass::Activation, "activation"),
    (BufferClass::Workspace, "workspace"),
    (BufferClass::Partial, "partial"),
    (BufferClass::Output, "output"),
    (BufferClass::QuantParam, "quant_param"),
    (BufferClass::CarriedPartial, "carried_partial"),
    (BufferClass::CarriedWeight, "carried_weight"),
];

fn bytes_obj(phase: &Phase, write: bool) -> Json {
    let mut pairs: Vec<(&str, Json)> = Vec::new();
    for (class, label) in CLASSES {
        let b = if write { phase.write_bytes(class) } else { phase.read_bytes(class) };
        if b > 0 {
            pairs.push((label, Json::num(b as f64)));
        }
    }
    Json::obj(pairs)
}

/// Serialize one trace to its golden digest.
pub fn trace_to_json(trace: &KernelTrace) -> Json {
    let phases = trace
        .phases
        .iter()
        .map(|ph| {
            Json::obj(vec![
                ("name", Json::str(ph.name)),
                (
                    "unit",
                    Json::str(match ph.unit {
                        Unit::Cube => "cube",
                        Unit::Vector => "vector",
                    }),
                ),
                ("pipelined_with_prev", Json::Bool(ph.pipelined_with_prev)),
                (
                    "chunk",
                    ph.chunk.map(|c| Json::num(c as f64)).unwrap_or(Json::Null),
                ),
                ("engines", Json::num(ph.active_engines() as f64)),
                ("steps", Json::num(ph.total_steps() as f64)),
                ("reads", bytes_obj(ph, false)),
                ("writes", bytes_obj(ph, true)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("name", Json::str(trace.name.clone())),
        ("workspace_bytes", Json::num(trace.workspace_bytes as f64)),
        ("partial_bytes", Json::num(trace.partial_bytes as f64)),
        (
            "workspace_policy",
            match trace.workspace_policy {
                WorkspacePolicy::Buffered => Json::str("buffered"),
                WorkspacePolicy::Pinned { resident_bytes } => Json::obj(vec![(
                    "pinned_resident_bytes",
                    Json::num(resident_bytes as f64),
                )]),
            },
        ),
        ("total_macs", Json::num(trace.total_macs() as f64)),
        ("phases", Json::arr(phases)),
    ])
}

/// Serialize a merged multi-kernel trace (the co-scheduler's splice,
/// DESIGN.md §12) to its golden digest: the merged name plus each spliced
/// kernel's trace digest, in issue order.
pub fn merged_to_json(merged: &crate::ascend::MergedTrace) -> Json {
    Json::obj(vec![
        ("name", Json::str(merged.name.clone())),
        (
            "kernels",
            Json::arr(merged.kernels.iter().map(trace_to_json).collect()),
        ),
    ])
}

/// Serialize a step-graph node list (shared by the decode and prefill
/// digests): problem shapes, expert counts and vector-pass sizing, in
/// issue order.
fn nodes_to_json(nodes: &[StepNode]) -> Json {
    Json::arr(
        nodes
            .iter()
            .map(|node| match node {
                StepNode::Gemm(g) => Json::obj(vec![
                    ("node", Json::str("gemm")),
                    ("kind", Json::str(g.kind.name())),
                    ("m", Json::num(g.problem.m as f64)),
                    ("n", Json::num(g.problem.n as f64)),
                    ("k", Json::num(g.problem.k as f64)),
                    ("group", Json::num(g.problem.group as f64)),
                    ("count", Json::num(g.count as f64)),
                ]),
                StepNode::Vector(v) => Json::obj(vec![
                    ("node", Json::str("vector")),
                    ("kind", Json::str(v.kind.name())),
                    ("elems", Json::num(v.elems as f64)),
                    ("ops_per_elem", Json::num(v.ops_per_elem)),
                    ("hbm_bytes", Json::num(v.hbm_bytes as f64)),
                    ("l2_bytes", Json::num(v.l2_bytes as f64)),
                ]),
            })
            .collect(),
    )
}

/// Serialize a full decode-step graph to its golden digest: the ordered
/// node list with problem shapes, expert counts and vector-pass sizing —
/// everything the step simulator consumes, nothing it produces.
pub fn step_to_json(step: &DecodeStep) -> Json {
    let nodes = nodes_to_json(&step.nodes());
    let moe = match step.layer.moe {
        Some(m) => Json::obj(vec![
            ("experts", Json::num(m.experts as f64)),
            ("topk", Json::num(m.topk as f64)),
            ("expert_ffn", Json::num(m.expert_ffn as f64)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("batch", Json::num(step.layer.batch as f64)),
        ("kv_len", Json::num(step.kv_len as f64)),
        ("heads", Json::num(step.heads as f64)),
        ("hidden", Json::num(step.layer.geometry.hidden as f64)),
        ("ffn", Json::num(step.layer.geometry.ffn as f64)),
        ("kv", Json::num(step.layer.geometry.kv as f64)),
        ("moe", moe),
        ("nodes", nodes),
    ])
}

/// Serialize a causal prefill chunk graph to its golden digest
/// (DESIGN.md §15): the decode digest's shape plus the chunk's causal
/// coordinates (`kv_base`, `kv_end`, the exact `causal_ctx`), so a
/// change to the causal-context arithmetic diffs loudly.
pub fn prefill_step_to_json(step: &PrefillStep) -> Json {
    let nodes = nodes_to_json(&step.nodes());
    let moe = match step.layer.moe {
        Some(m) => Json::obj(vec![
            ("experts", Json::num(m.experts as f64)),
            ("topk", Json::num(m.topk as f64)),
            ("expert_ffn", Json::num(m.expert_ffn as f64)),
        ]),
        None => Json::Null,
    };
    Json::obj(vec![
        ("chunk", Json::num(step.chunk_tokens() as f64)),
        ("kv_base", Json::num(step.kv_base as f64)),
        ("kv_end", Json::num(step.kv_end() as f64)),
        ("causal_ctx", Json::num(step.causal_ctx() as f64)),
        ("heads", Json::num(step.heads as f64)),
        ("hidden", Json::num(step.layer.geometry.hidden as f64)),
        ("ffn", Json::num(step.layer.geometry.ffn as f64)),
        ("kv", Json::num(step.layer.geometry.kv as f64)),
        ("moe", moe),
        ("nodes", nodes),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::MachineConfig;
    use crate::kernels::{self, GemmProblem, Strategy};

    #[test]
    fn digest_round_trips_through_the_parser() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 512, 16384);
        let tr = kernels::schedule(&m, &p, Strategy::SplitK).unwrap();
        let j = trace_to_json(&tr);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j, "digest must survive serialize -> parse");
        assert_eq!(back.req_str("name").unwrap(), tr.name);
        let phases = back.req("phases").unwrap().as_arr().unwrap();
        assert_eq!(phases.len(), tr.phases.len());
        // Phase-0 dequant writes exactly the FP16 workspace.
        let ws = phases[0]
            .req("writes")
            .unwrap()
            .req("workspace")
            .unwrap()
            .as_f64()
            .unwrap();
        assert_eq!(ws, p.f16_weight_bytes() as f64);
    }

    #[test]
    fn pinned_policy_is_structured() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 12288, 5120);
        let tr = kernels::schedule(&m, &p, Strategy::Chunked).unwrap();
        let j = trace_to_json(&tr);
        let policy = j.req("workspace_policy").unwrap();
        assert!(
            policy.get("pinned_resident_bytes").is_some(),
            "spilling shape must pin its rotating slices"
        );
    }

    #[test]
    fn step_digest_round_trips_and_orders_nodes() {
        use crate::model::llm::{layer_geometry, moe_geometry};
        use crate::workload::decode_layer::DecodeLayer;
        let layer = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        let step = DecodeStep::new(layer, 2048, 56);
        let j = step_to_json(&step);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
        let nodes = back.req("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), step.nodes().len());
        assert_eq!(nodes[1].req_str("kind").unwrap(), "qkv");
        assert!(back.req("moe").unwrap().get("experts").is_some());
    }

    #[test]
    fn prefill_digest_carries_causal_coordinates() {
        use crate::model::llm::layer_geometry;
        use crate::workload::decode_layer::DecodeLayer;
        use crate::workload::PrefillStep;
        let geometry = layer_geometry("llama32").unwrap();
        let heads = PrefillStep::default_heads(&geometry);
        let step = PrefillStep::new(DecodeLayer::new(geometry, 512), 1024, heads);
        let j = prefill_step_to_json(&step);
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.req("chunk").unwrap().as_f64().unwrap(), 512.0);
        assert_eq!(back.req("kv_base").unwrap().as_f64().unwrap(), 1024.0);
        assert_eq!(back.req("kv_end").unwrap().as_f64().unwrap(), 1536.0);
        assert_eq!(
            back.req("causal_ctx").unwrap().as_f64().unwrap(),
            step.causal_ctx() as f64
        );
        let nodes = back.req("nodes").unwrap().as_arr().unwrap();
        assert_eq!(nodes.len(), step.nodes().len());
    }
}
