//! The uniform step-graph op contract (DESIGN.md §17).
//!
//! Every node kind of the decode/prefill step graph — tuned or forced
//! GEMM, vector pass, and (inside a GEMM's schedule) the Split-K reduce —
//! prices itself through one trait, [`StepOp`]:
//!
//! * **trace production** — [`StepOp::price`] returns the node's
//!   [`StepNodeReport`] plus, for kernel-backed ops, the served
//!   [`KernelTrace`] the co-scheduler and residency planner consume;
//! * **residency hooks** — [`StepOp::residency_input`] converts a priced
//!   op into the planner's [`PlanNodeInput`] (or `None` for ops whose
//!   weights are not pinnable, which the planner then prices as
//!   plan-independent `extra_ns`);
//! * **splice capability** — [`StepOp::splice_capable`] marks ops whose
//!   served trace the co-scheduler may splice (exposed reduce tail /
//!   dequant prologue adjacency, DESIGN.md §12).
//!
//! The step simulator ([`StepSim`]), co-scheduler, residency planner and
//! router all walk one op list through this trait instead of matching on
//! node kinds — a future collective op (ROADMAP item 1) or a new
//! precision strategy enters as one new impl, not a new match arm per
//! subsystem.
//!
//! [`StepSim`]: super::stepsim::StepSim
//! [`KernelTrace`]: crate::ascend::KernelTrace

use super::layer::{NodeReport, Resolution, StepNodeReport, VectorNodeReport};
use super::residency::PlanNodeInput;
use crate::ascend::{vecpass, KernelTrace, MachineConfig, SimReport, Simulator};
use crate::kernels::{self, tiling::Tiling, GemmProblem, ReduceMode, Strategy};
use crate::workload::decode_layer::{GemmNode, StepNode, VectorOp};

/// One graph node's (strategy, tiling, provenance) assignment.
pub type Assignment = (Strategy, Tiling, Resolution);

/// Everything an op needs to price itself: the machine, a shared
/// simulator, and the resolver that assigns GEMM nodes their schedule.
pub struct PriceCtx<'a> {
    pub machine: &'a MachineConfig,
    pub sim: &'a Simulator,
    pub resolve: &'a mut dyn FnMut(&GemmProblem) -> anyhow::Result<Assignment>,
}

/// A priced op: its report node plus, for kernel-backed ops, the served
/// trace (what the co-scheduler splices and the residency planner pins).
#[derive(Debug, Clone)]
pub struct PricedOp {
    pub report: StepNodeReport,
    pub trace: Option<KernelTrace>,
}

/// The uniform step-graph op: anything the step simulator can price.
pub trait StepOp {
    /// Display name (report tables, ledger rows).
    fn name(&self) -> &'static str;

    /// Identical instances the op issues per step (expert fan-out).
    fn count(&self) -> usize {
        1
    }

    /// Price the op: produce its report node and, when kernel-backed,
    /// the served trace.
    fn price(&self, ctx: &mut PriceCtx) -> anyhow::Result<PricedOp>;

    /// Whether the co-scheduler may splice this op's served trace into
    /// an adjacent op's schedule (DESIGN.md §12).
    fn splice_capable(&self) -> bool {
        false
    }

    /// The residency planner's view of this priced op — `None` when the
    /// op has no pinnable weight stream (the planner then carries its
    /// time as plan-independent `extra_ns`).
    fn residency_input(&self, priced: &PricedOp) -> Option<PlanNodeInput> {
        let _ = priced;
        None
    }

    /// The underlying GEMM node, for walkers (router, tuner seeding)
    /// that only consume the GEMM sub-chain.
    fn gemm(&self) -> Option<&GemmNode> {
        None
    }
}

/// The overlap terms of one served trace: (exposed post-barrier reduce
/// group time, vector-engine slack of the leading dequant phase).
pub(crate) fn overlap_terms(r: &SimReport) -> (f64, f64) {
    let reduce_tail = match r.groups.last() {
        Some(g) if r.groups.len() > 1 => {
            let all_reduce = g
                .phases
                .iter()
                .all(|&pi| r.phase_times[pi].name.starts_with("reduce"));
            if all_reduce {
                g.total_ns
            } else {
                0.0
            }
        }
        _ => 0.0,
    };
    // The weight-only prologue: the first dequant phase's transfer time is
    // independent of upstream activations, so its vector-compute headroom
    // (standalone minus SIMD time) is where an upstream reduce can hide.
    let dequant_slack = r
        .phase_times
        .iter()
        .find(|pt| pt.name.contains("dequant"))
        .map(|pt| (pt.standalone_ns - pt.compute_ns).max(0.0))
        .unwrap_or(0.0);
    (reduce_tail, dequant_slack)
}

/// Simulate one GEMM node: served (auto-reduce) and barrier-reduce
/// pricing plus the overlap terms, multiplied over the node's count.
/// Also returns the served trace itself — the co-scheduler splices it.
pub(crate) fn simulate_gemm_node(
    machine: &MachineConfig,
    sim: &Simulator,
    node: &GemmNode,
    assignment: Assignment,
) -> anyhow::Result<(NodeReport, KernelTrace)> {
    let (strategy, tiling, resolution) = assignment;
    let p = &node.problem;
    let served = kernels::schedule_with_reduce(machine, p, strategy, &tiling, ReduceMode::Auto)?;
    let served_run = sim.run(&served)?;
    let unit_ns = served_run.total_ns;
    let (reduce_tail_ns, dequant_slack_ns) = overlap_terms(&served_run);
    // Only the Split-K family has a reduce; for the other strategies
    // the barrier variant IS the served trace — skip the re-build.
    let unit_barrier_ns = match strategy {
        Strategy::SplitK | Strategy::Chunked => {
            let barrier =
                kernels::schedule_with_reduce(machine, p, strategy, &tiling, ReduceMode::Barrier)?;
            sim.run(&barrier)?.total_ns
        }
        _ => unit_ns,
    };
    let count = node.count.max(1) as f64;
    let report = NodeReport {
        kind: node.kind,
        problem: *p,
        count: node.count.max(1),
        strategy,
        tiling,
        resolution,
        unit_ns,
        unit_barrier_ns,
        total_ns: unit_ns * count,
        barrier_ns: unit_barrier_ns * count,
        reduce_tail_ns,
        dequant_slack_ns,
    };
    Ok((report, served))
}

impl StepOp for GemmNode {
    fn name(&self) -> &'static str {
        self.kind.name()
    }

    fn count(&self) -> usize {
        self.count.max(1)
    }

    fn price(&self, ctx: &mut PriceCtx) -> anyhow::Result<PricedOp> {
        let assignment = (ctx.resolve)(&self.problem)?;
        let (report, trace) = simulate_gemm_node(ctx.machine, ctx.sim, self, assignment)?;
        Ok(PricedOp { report: StepNodeReport::Gemm(report), trace: Some(trace) })
    }

    fn splice_capable(&self) -> bool {
        true
    }

    fn residency_input(&self, priced: &PricedOp) -> Option<PlanNodeInput> {
        let (StepNodeReport::Gemm(g), Some(t)) = (&priced.report, &priced.trace) else {
            return None;
        };
        Some(PlanNodeInput {
            kind: g.kind,
            problem: g.problem,
            count: g.count,
            unit_ns: g.unit_ns,
            trace: t.clone(),
        })
    }

    fn gemm(&self) -> Option<&GemmNode> {
        Some(self)
    }
}

impl StepOp for VectorOp {
    fn name(&self) -> &'static str {
        self.kind.name()
    }

    fn price(&self, ctx: &mut PriceCtx) -> anyhow::Result<PricedOp> {
        let c = vecpass::price_pass(
            ctx.machine,
            self.elems,
            self.ops_per_elem,
            self.hbm_bytes,
            self.l2_bytes,
        );
        Ok(PricedOp {
            report: StepNodeReport::Vector(VectorNodeReport {
                op: *self,
                total_ns: c.total_ns,
                compute_ns: c.compute_ns,
                hbm_ns: c.hbm_ns,
                l2_ns: c.l2_ns,
            }),
            trace: None,
        })
    }
}

/// View a [`StepNode`] as its trait object — the workload layer stays
/// free of analysis dependencies, so the dispatch lives here.
pub fn as_op(node: &StepNode) -> &dyn StepOp {
    match node {
        StepNode::Gemm(g) => g,
        StepNode::Vector(v) => v,
    }
}

impl StepOp for StepNode {
    fn name(&self) -> &'static str {
        as_op(self).name()
    }

    fn count(&self) -> usize {
        as_op(self).count()
    }

    fn price(&self, ctx: &mut PriceCtx) -> anyhow::Result<PricedOp> {
        as_op(self).price(ctx)
    }

    fn splice_capable(&self) -> bool {
        as_op(self).splice_capable()
    }

    fn residency_input(&self, priced: &PricedOp) -> Option<PlanNodeInput> {
        as_op(self).residency_input(priced)
    }

    fn gemm(&self) -> Option<&GemmNode> {
        as_op(self).gemm()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::layer_geometry;
    use crate::workload::decode_layer::{DecodeLayer, DecodeStep};

    #[test]
    fn ops_price_like_their_kinds() {
        let machine = MachineConfig::ascend910();
        let sim = Simulator::new(machine.clone());
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let mut resolve = |p: &GemmProblem| -> anyhow::Result<Assignment> {
            Ok((
                Strategy::SplitK,
                kernels::select_tiling(&machine, p, Strategy::SplitK)?,
                Resolution::Heuristic,
            ))
        };
        let mut ctx = PriceCtx { machine: &machine, sim: &sim, resolve: &mut resolve };
        let mut gemms = 0;
        let mut vectors = 0;
        for node in step.nodes() {
            let priced = node.price(&mut ctx).unwrap();
            assert!(priced.report.total_ns() > 0.0);
            match &priced.report {
                StepNodeReport::Gemm(g) => {
                    gemms += 1;
                    assert!(node.splice_capable());
                    assert!(priced.trace.is_some(), "GEMM ops must produce a trace");
                    assert_eq!(node.gemm().unwrap().kind, g.kind);
                    let input = node.residency_input(&priced).expect("GEMM ops are pinnable");
                    assert_eq!(input.count, g.count);
                    assert_eq!(input.unit_ns, g.unit_ns);
                }
                StepNodeReport::Vector(_) => {
                    vectors += 1;
                    assert!(!node.splice_capable());
                    assert!(priced.trace.is_none());
                    assert!(node.gemm().is_none());
                    assert!(node.residency_input(&priced).is_none());
                }
            }
            assert_eq!(node.name(), priced.report.name());
            assert!(node.count() >= 1);
        }
        assert_eq!(gemms, 4);
        assert_eq!(vectors, 8);
    }
}
