//! Memory-traffic decomposition — the quantitative form of §4.2.
//!
//! Splits a simulated kernel's bytes by buffer class and memory level, and
//! answers the paper's question directly: how much *extra* traffic does the
//! decoupled vector->cube workspace round trip add over the packed weight
//! bytes, and is the type-cast compute ever the bottleneck?

use crate::ascend::npu::SimReport;
use crate::ascend::trace::{BufferClass, Unit};
use crate::ascend::MachineConfig;

/// One row of the decomposition table.
#[derive(Debug, Clone)]
pub struct TrafficRow {
    pub class: BufferClass,
    pub label: &'static str,
    pub hbm_bytes: f64,
    pub l2_bytes: f64,
}

/// Bottleneck verdict for one kernel execution.
#[derive(Debug, Clone)]
pub struct BottleneckReport {
    pub rows: Vec<TrafficRow>,
    /// Workspace round-trip bytes (write + re-read, both levels).
    pub round_trip_bytes: f64,
    /// Packed weight bytes actually read.
    pub packed_bytes: f64,
    /// Ratio of round-trip traffic to packed-weight traffic (the paper's
    /// "extra global memory transfer for the weight").
    pub round_trip_ratio: f64,
    /// Total vector-core compute time (the type-cast cost itself).
    pub cast_compute_ns: f64,
    /// Total transfer-stream time across groups.
    pub transfer_ns: f64,
    /// True when transfers, not the cast, bound the kernel — the paper's
    /// §4.2 claim.
    pub transfer_bound: bool,
}

pub fn class_label(class: BufferClass) -> &'static str {
    match class {
        BufferClass::WeightPacked => "weights (packed INT4)",
        BufferClass::WeightF16 => "weights (FP16)",
        BufferClass::Activation => "activations",
        BufferClass::Workspace => "dequant workspace",
        BufferClass::Partial => "split-K partials",
        BufferClass::Output => "output C",
        BufferClass::QuantParam => "scales/zeros",
        BufferClass::CarriedPartial => "carried split-K partials",
        BufferClass::CarriedWeight => "pinned weights (L2-resident)",
    }
}

/// Decompose one simulated kernel.
pub fn decompose(report: &SimReport) -> BottleneckReport {
    let mut rows = Vec::new();
    for (&class, t) in &report.ledger.by_class {
        rows.push(TrafficRow {
            class,
            label: class_label(class),
            hbm_bytes: t.hbm_total(),
            l2_bytes: t.l2_total(),
        });
    }
    let ws = report.ledger.class(BufferClass::Workspace);
    let packed = report.ledger.class(BufferClass::WeightPacked);
    let round_trip = ws.hbm_total() + ws.l2_total();
    let packed_bytes = packed.hbm_read + packed.l2_read;
    let cast_compute_ns: f64 = report
        .phase_times
        .iter()
        .filter(|p| p.unit == Unit::Vector)
        .map(|p| p.compute_ns)
        .sum();
    let transfer_ns: f64 = report
        .groups
        .iter()
        .map(|g| g.hbm_ns.max(g.l2_ns))
        .sum();
    BottleneckReport {
        rows,
        round_trip_bytes: round_trip,
        packed_bytes,
        round_trip_ratio: if packed_bytes > 0.0 { round_trip / packed_bytes } else { 0.0 },
        cast_compute_ns,
        transfer_ns,
        transfer_bound: transfer_ns > cast_compute_ns,
    }
}

/// The theoretical W4A16 ceiling for a problem on this machine: the ratio
/// of FP16 weight bytes to the bytes W4A16 actually moves through HBM.
/// Equals ~4 only if the workspace round trip were free (the fused path).
pub fn theoretical_speedup_ceiling(machine: &MachineConfig, report: &SimReport) -> f64 {
    let _ = machine;
    let ws = report.ledger.class(BufferClass::Workspace);
    let packed = report.ledger.class(BufferClass::WeightPacked);
    let fp16_equivalent = 4.0 * (packed.hbm_read + packed.l2_read);
    let moved = packed.hbm_read + packed.l2_read + ws.hbm_total();
    if moved > 0.0 {
        fp16_equivalent / moved
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::{self, GemmProblem, Strategy};

    fn sim(p: &GemmProblem, s: Strategy) -> SimReport {
        let m = MachineConfig::ascend910();
        Simulator::new(m.clone())
            .run(&kernels::schedule(&m, p, s).unwrap())
            .unwrap()
    }

    #[test]
    fn round_trip_is_8x_packed_bytes() {
        // write 2KN + read 2KN vs packed KN/2 -> ratio 8 (per M-tile row).
        let r = sim(&GemmProblem::new(8, 2048, 7168), Strategy::SplitK);
        let b = decompose(&r);
        assert!((b.round_trip_ratio - 8.0).abs() < 0.3, "{}", b.round_trip_ratio);
    }

    #[test]
    fn cast_is_not_the_bottleneck() {
        // The paper's §4.2 headline finding.
        let r = sim(&GemmProblem::new(8, 2048, 7168), Strategy::SplitK);
        let b = decompose(&r);
        assert!(b.transfer_bound, "cast {} vs transfer {}", b.cast_compute_ns, b.transfer_ns);
    }

    #[test]
    fn fp16_baseline_has_no_round_trip() {
        let r = sim(&GemmProblem::new(8, 2048, 7168), Strategy::Fp16Native);
        let b = decompose(&r);
        assert_eq!(b.round_trip_bytes, 0.0);
        assert_eq!(b.packed_bytes, 0.0);
    }

    #[test]
    fn ceiling_well_below_4x_for_spilling_shapes() {
        // A workspace far larger than L2 spills; the ceiling collapses.
        let r = sim(&GemmProblem::new(8, 12288, 5120), Strategy::SplitK);
        let m = MachineConfig::ascend910();
        let ceil = theoretical_speedup_ceiling(&m, &r);
        assert!(ceil < 4.0, "ceiling {ceil}");
    }
}
