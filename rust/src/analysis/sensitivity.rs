//! Sensitivity analysis: how the paper's headline numbers move with the
//! architecture parameters.
//!
//! The paper's future-work section argues for hardware co-design (direct
//! vector->cube paths, fused instructions).  This module quantifies the
//! *whole* design space the conclusion points at: sweep one machine
//! parameter (L2 bandwidth, HBM bandwidth, L2 capacity, per-core MTE
//! bandwidth, barrier cost) and report how the W4A16-vs-FP16 cap and the
//! Split-K-vs-DP advantage respond.  This is the analysis a hardware team
//! would run before taping out the paper's proposal.

use crate::ascend::{MachineConfig, Simulator};
use crate::kernels::{self, GemmProblem, Strategy};
use crate::model::llm::paper_shapes;
use crate::util::stats;

/// A machine parameter that can be swept.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Knob {
    L2Bandwidth,
    HbmBandwidth,
    L2Capacity,
    MteCoreBandwidth,
    BarrierCost,
}

impl Knob {
    pub fn all() -> [Knob; 5] {
        [
            Knob::L2Bandwidth,
            Knob::HbmBandwidth,
            Knob::L2Capacity,
            Knob::MteCoreBandwidth,
            Knob::BarrierCost,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Knob::L2Bandwidth => "l2_bw",
            Knob::HbmBandwidth => "hbm_bw",
            Knob::L2Capacity => "l2_bytes",
            Knob::MteCoreBandwidth => "mte_core_bw",
            Knob::BarrierCost => "barrier_ns",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Knob> {
        Knob::all()
            .into_iter()
            .find(|k| k.name() == name)
            .ok_or_else(|| anyhow::anyhow!("unknown knob '{name}'"))
    }

    /// Baseline value on a machine.
    pub fn get(&self, m: &MachineConfig) -> f64 {
        match self {
            Knob::L2Bandwidth => m.l2_bw,
            Knob::HbmBandwidth => m.hbm_bw,
            Knob::L2Capacity => m.l2_bytes as f64,
            Knob::MteCoreBandwidth => m.mte_core_bw,
            Knob::BarrierCost => m.barrier_ns,
        }
    }

    /// Apply a scaled value to a machine copy.
    pub fn apply(&self, m: &MachineConfig, scale: f64) -> MachineConfig {
        let mut out = m.clone();
        match self {
            Knob::L2Bandwidth => out.l2_bw = m.l2_bw * scale,
            Knob::HbmBandwidth => out.hbm_bw = m.hbm_bw * scale,
            Knob::L2Capacity => out.l2_bytes = (m.l2_bytes as f64 * scale) as u64,
            Knob::MteCoreBandwidth => out.mte_core_bw = m.mte_core_bw * scale,
            Knob::BarrierCost => out.barrier_ns = m.barrier_ns * scale,
        }
        // Keep the machine self-consistent: L2 must stay >= HBM bandwidth.
        if out.l2_bw < out.hbm_bw {
            out.l2_bw = out.hbm_bw;
        }
        out
    }
}

/// One sweep point.
#[derive(Debug, Clone)]
pub struct SensitivityPoint {
    pub scale: f64,
    pub value: f64,
    /// Max W4A16-vs-FP16 speedup over the paper shape table (Fig 3 cap).
    pub w4a16_cap: f64,
    /// Geomean W4A16-vs-FP16 speedup.
    pub w4a16_geomean: f64,
    /// Max Split-K-vs-DP speedup over the K>>N shapes (Fig 2 headline).
    pub splitk_max: f64,
}

/// Sweep one knob over the given scale factors at decode batch `m_batch`.
pub fn sweep(
    base: &MachineConfig,
    knob: Knob,
    scales: &[f64],
    m_batch: usize,
) -> anyhow::Result<Vec<SensitivityPoint>> {
    let mut out = Vec::with_capacity(scales.len());
    for &scale in scales {
        let machine = knob.apply(base, scale);
        machine.validate()?;
        let sim = Simulator::new(machine.clone());
        let mut w4a16 = Vec::new();
        let mut splitk_dp = Vec::new();
        for shape in paper_shapes() {
            let p = GemmProblem::new(m_batch, shape.n, shape.k);
            let sk = sim.run(&kernels::schedule(&machine, &p, Strategy::SplitK)?)?;
            let fp = sim.run(&kernels::schedule(&machine, &p, Strategy::Fp16Native)?)?;
            w4a16.push(fp.total_ns / sk.total_ns);
            if shape.k_dominant() {
                let dp = sim.run(&kernels::schedule(&machine, &p, Strategy::DataParallel)?)?;
                splitk_dp.push(dp.total_ns / sk.total_ns);
            }
        }
        out.push(SensitivityPoint {
            scale,
            value: knob.get(&machine),
            w4a16_cap: w4a16.iter().cloned().fold(0.0, f64::max),
            w4a16_geomean: stats::geomean(&w4a16),
            splitk_max: splitk_dp.iter().cloned().fold(0.0, f64::max),
        });
    }
    Ok(out)
}

/// Render a sweep as an aligned table.
pub fn render(knob: Knob, points: &[SensitivityPoint]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "sensitivity of the paper's headline numbers to `{}`\n",
        knob.name()
    ));
    out.push_str(&format!(
        "{:>8} {:>14} | {:>10} {:>14} {:>12}\n",
        "scale", knob.name(), "w4a16_cap", "w4a16_geomean", "splitk_max"
    ));
    for p in points {
        out.push_str(&format!(
            "{:>7.2}x {:>14.0} | {:>9.2}x {:>13.2}x {:>11.2}x\n",
            p.scale, p.value, p.w4a16_cap, p.w4a16_geomean, p.splitk_max
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_round_trips() {
        for k in Knob::all() {
            assert_eq!(Knob::from_name(k.name()).unwrap(), k);
        }
        assert!(Knob::from_name("warp_size").is_err());
    }

    #[test]
    fn apply_scales_the_right_field() {
        let base = MachineConfig::ascend910();
        let m = Knob::HbmBandwidth.apply(&base, 2.0);
        assert_eq!(m.hbm_bw, 2400.0);
        assert_eq!(m.l2_bw, base.l2_bw);
        let m = Knob::L2Capacity.apply(&base, 0.5);
        assert_eq!(m.l2_bytes, base.l2_bytes / 2);
    }

    #[test]
    fn keeps_l2_at_least_hbm() {
        let base = MachineConfig::ascend910();
        let m = Knob::HbmBandwidth.apply(&base, 10.0);
        assert!(m.l2_bw >= m.hbm_bw);
        m.validate().unwrap();
    }

    #[test]
    fn more_l2_bandwidth_raises_the_w4a16_cap() {
        // The paper's cap is L2-bandwidth-limited: doubling L2 bandwidth
        // must raise it; halving HBM bandwidth (same ratio change) too.
        let base = MachineConfig::ascend910();
        let pts = sweep(&base, Knob::L2Bandwidth, &[1.0, 2.0], 8).unwrap();
        assert!(
            pts[1].w4a16_cap > pts[0].w4a16_cap * 1.1,
            "{} vs {}",
            pts[1].w4a16_cap,
            pts[0].w4a16_cap
        );
    }

    #[test]
    fn smaller_l2_capacity_hurts_w4a16() {
        let base = MachineConfig::ascend910();
        let pts = sweep(&base, Knob::L2Capacity, &[1.0, 0.25], 8).unwrap();
        assert!(pts[1].w4a16_geomean < pts[0].w4a16_geomean);
    }

    #[test]
    fn render_is_tabular() {
        let base = MachineConfig::ascend910();
        let pts = sweep(&base, Knob::BarrierCost, &[1.0], 8).unwrap();
        let text = render(Knob::BarrierCost, &pts);
        assert!(text.contains("barrier_ns"));
        assert!(text.contains("w4a16_cap"));
    }
}
