//! Step-level L2 weight-residency planner (DESIGN.md §13).
//!
//! The paper's §4.2 conclusion is that W4A16's ceiling is set by *extra
//! global-memory transfer for the weight*, not by dequant compute — and
//! decode re-reads the same packed-INT4 weights and quant params token
//! after token.  This module decides which GEMM nodes' weights to keep
//! pinned in the shared L2 across the whole decode step:
//!
//! * a pinned node's weight reads are re-classed as
//!   [`BufferClass::CarriedWeight`] and served at L2 bandwidth under the
//!   step-level [`ResidencyLedger`];
//! * every kernel in the step — pinned or not — loses the pinned bytes
//!   from its retained L2 capacity (the pins squeeze the workspace and
//!   partial buffers), so over-pinning prices itself out;
//! * the plan is priced *exactly*: each candidate prefix of the greedy
//!   pin order re-simulates every GEMM node (and, where the overlap mode
//!   asks for it, the co-scheduled pair splices) under the plan's ledger,
//!   and the cheapest prefix wins.  Prefix 0 is the unpinned chain, so a
//!   plan's gain is non-negative by construction and `Auto` serving
//!   `min(PR-4 Auto, resident plan)` stays structurally never slower.
//!
//! Candidates are ordered by *gain density* (saved ns per pinned byte),
//! which puts the small-N / large-K expert and projection weights first —
//! exactly the K >> N decode regime the paper targets.  Expert batches
//! pin at instance granularity: pinning `p` of `count` experts prices
//! `p` resident instances and `count - p` cold ones.

use crate::ascend::{
    BufferClass, KernelTrace, MachineConfig, MergedTrace, ResidencyLedger, Simulator,
};
use crate::kernels::GemmProblem;
use crate::util::json::Json;
use crate::util::pool;
use crate::workload::decode_layer::GemmKind;

use super::coschedule;

/// Whether the step simulator may plan step-level weight residency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ResidencyMode {
    /// PR-4 pricing: every weight read is cold HBM traffic each step.
    Off,
    /// Plan which nodes' weights to pin under the L2 capacity budget and
    /// serve `min(PR-4 plan, resident plan)` — never slower.
    #[default]
    Auto,
}

impl ResidencyMode {
    /// Accepted `--residency` spellings, first alias canonical.
    pub const CHOICES: &'static [(&'static [&'static str], ResidencyMode)] = &[
        (&["off", "none"], ResidencyMode::Off),
        (&["auto", "on"], ResidencyMode::Auto),
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ResidencyMode::Off => "off",
            ResidencyMode::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<ResidencyMode> {
        let lower = name.to_ascii_lowercase();
        for (aliases, mode) in Self::CHOICES {
            if aliases.contains(&lower.as_str()) {
                return Ok(*mode);
            }
        }
        anyhow::bail!("unknown residency mode '{name}'")
    }
}

/// One GEMM node of the chain being planned: everything the planner
/// needs, shared by the step simulator and the tuner's layer seeding.
#[derive(Debug, Clone)]
pub struct PlanNodeInput {
    pub kind: GemmKind,
    pub problem: GemmProblem,
    /// Identical GEMMs the node issues per step (expert fan-out).
    pub count: usize,
    /// Simulated time of one cold GEMM under the served schedule.
    pub unit_ns: f64,
    /// The served kernel trace (weights read cold).
    pub trace: KernelTrace,
}

/// One pinned node of the plan.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NodePin {
    /// Index into the planner's node inputs (GEMM sub-chain order).
    pub node: usize,
    pub kind: GemmKind,
    /// Instances pinned (`<= count`; expert batches pin partially).
    pub instances: usize,
    /// Weight footprint of ONE instance: packed INT4 + quant params.
    pub unit_bytes: u64,
}

impl NodePin {
    pub fn bytes(&self) -> u64 {
        self.instances as u64 * self.unit_bytes
    }
}

/// The step-level residency plan, priced exactly.
#[derive(Debug, Clone)]
pub struct ResidencyPlan {
    pub pins: Vec<NodePin>,
    /// Total weight bytes held resident across the step.
    pub pinned_bytes: u64,
    /// The retained-L2 budget the plan had to fit (bytes).
    pub budget_bytes: u64,
    /// Exact per-step latency of the served plan (the cheapest prefix —
    /// equals `baseline_ns` when pinning never paid).
    pub resident_ns: f64,
    /// Prefix-0 price: the same chain with nothing pinned.
    pub baseline_ns: f64,
}

impl ResidencyPlan {
    /// What the plan buys over the unpinned chain (>= 0 by construction).
    pub fn gain_ns(&self) -> f64 {
        (self.baseline_ns - self.resident_ns).max(0.0)
    }

    pub fn to_json(&self) -> Json {
        let pins = self
            .pins
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("node", Json::num(p.node as f64)),
                    ("kind", Json::str(p.kind.name())),
                    ("instances", Json::num(p.instances as f64)),
                    ("unit_bytes", Json::num(p.unit_bytes as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("pinned_bytes", Json::num(self.pinned_bytes as f64)),
            ("budget_bytes", Json::num(self.budget_bytes as f64)),
            ("resident_ns", Json::num(self.resident_ns)),
            ("baseline_ns", Json::num(self.baseline_ns)),
            ("residency_gain_ns", Json::num(self.gain_ns())),
            ("pins", Json::arr(pins)),
        ])
    }
}

/// Weight footprint of one GEMM instance: packed INT4 codes plus the
/// f32 scale + zero rows (one pair per K group).
pub fn weight_footprint_bytes(p: &GemmProblem) -> u64 {
    p.packed_weight_bytes() + (2 * (p.k / p.group) * p.n * 4) as u64
}

/// The retained-L2 budget the planner may pin (bytes).
pub fn pin_budget_bytes(machine: &MachineConfig) -> u64 {
    (machine.l2_retention * machine.l2_bytes as f64) as u64
}

/// Re-class a trace's packed-weight and quant-param reads as
/// [`BufferClass::CarriedWeight`]: under a pinning ledger they are served
/// from L2; standalone they still price cold (conservative).  Byte counts
/// are untouched — pinning changes *where* weight bytes are served, never
/// *how many* move.
pub fn carry_weights(trace: &KernelTrace) -> KernelTrace {
    let mut carried = trace.clone();
    for phase in &mut carried.phases {
        for steps in &mut phase.steps_per_engine {
            for step in steps.iter_mut() {
                for read in step.reads.iter_mut() {
                    if matches!(read.0, BufferClass::WeightPacked | BufferClass::QuantParam)
                        && read.1 > 0
                    {
                        read.0 = BufferClass::CarriedWeight;
                    }
                }
            }
        }
    }
    carried.name = format!("{}_resident", trace.name);
    carried
}

/// Bytes of packed-weight + quant-param reads in a trace (0 for
/// strategies that read FP16 weights — those are not pinnable).
fn packed_read_bytes(trace: &KernelTrace) -> u64 {
    trace
        .phases
        .iter()
        .map(|p| {
            p.read_bytes(BufferClass::WeightPacked) + p.read_bytes(BufferClass::QuantParam)
        })
        .sum()
}

/// Exact price of the GEMM chain under one pin set: every node is
/// re-simulated with the plan's ledger (pinned instances on the carried
/// trace, the rest on the cold trace — both under the reduced retained
/// capacity), and, when `price_exact` is set, the co-scheduled pair
/// splices are re-priced under the same ledger.  `extra_ns` carries the
/// chain's non-GEMM node time (unaffected by the plan).
fn price_pins(
    sim: &Simulator,
    inputs: &[PlanNodeInput],
    pins: &[NodePin],
    extra_ns: f64,
    price_exact: bool,
) -> anyhow::Result<f64> {
    let pinned_bytes: u64 = pins.iter().map(|p| p.bytes()).sum();
    let ledger = ResidencyLedger::with_pinned_weights(pinned_bytes);
    let pinned_instances = |node: usize| {
        pins.iter().find(|p| p.node == node).map(|p| p.instances).unwrap_or(0)
    };

    // Per-node pricing: the cold variant (weight reads under the reduced
    // capacity) and the resident variant (carried weights), each present
    // only when instances actually serve it.
    let mut cold: Vec<Option<(KernelTrace, f64)>> = Vec::with_capacity(inputs.len());
    let mut resident: Vec<Option<(KernelTrace, f64)>> = Vec::with_capacity(inputs.len());
    let mut pinned: Vec<usize> = Vec::with_capacity(inputs.len());
    let mut total = extra_ns;
    for (i, input) in inputs.iter().enumerate() {
        let count = input.count.max(1);
        let p = pinned_instances(i).min(count);
        let c = if p < count {
            let ns = sim.run_with_residency(&input.trace, &ledger)?.total_ns;
            Some((input.trace.clone(), ns))
        } else {
            None
        };
        let r = if p > 0 {
            let carried = carry_weights(&input.trace);
            let ns = sim.run_with_residency(&carried, &ledger)?.total_ns;
            Some((carried, ns))
        } else {
            None
        };
        total += p as f64 * r.as_ref().map(|(_, ns)| *ns).unwrap_or(0.0)
            + (count - p) as f64 * c.as_ref().map(|(_, ns)| *ns).unwrap_or(0.0);
        cold.push(c);
        resident.push(r);
        pinned.push(p);
    }

    if price_exact {
        // The same adjacency set the overlap ledger prices: expert-batch
        // internal pairs plus each adjacent window, each declined when
        // the merged trace prices slower (gain clamped at zero).
        let mut gain = 0.0;
        for (i, input) in inputs.iter().enumerate() {
            let count = input.count.max(1);
            if count < 2 {
                continue;
            }
            // A partially pinned batch orders resident instances first:
            // p-1 resident->resident adjacencies, count-p-1 cold->cold
            // ones, each priced on its own variant; the single mixed
            // adjacency contributes nothing (conservative) — so the
            // subtracted gains always match instances the total priced.
            let p = pinned[i];
            if p > 1 {
                let (rt, rns) = resident[i].as_ref().expect("p > 0 has a resident variant");
                if let Some(d) =
                    coschedule::pair_decision_with(sim, rt, rt, 2.0 * rns, &ledger)?
                {
                    gain += (p - 1) as f64 * d.gain_ns;
                }
            }
            if count - p > 1 {
                let (ct, cns) = cold[i].as_ref().expect("p < count has a cold variant");
                if let Some(d) =
                    coschedule::pair_decision_with(sim, ct, ct, 2.0 * cns, &ledger)?
                {
                    gain += (count - p - 1) as f64 * d.gain_ns;
                }
            }
        }
        // Window pairs splice at the batch boundary: the adjacency is
        // between one instance of each node, priced on the variant a
        // boundary instance actually serves (a partially pinned batch
        // always has a cold instance at its boundary by the ordering
        // above; fully pinned nodes splice their resident trace).
        let boundary = |i: usize| {
            cold[i].as_ref().or(resident[i].as_ref()).expect("every node has a variant")
        };
        for i in 1..inputs.len() {
            let (pt, pns) = boundary(i - 1);
            let (ct, cns) = boundary(i);
            if let Some(d) =
                coschedule::pair_decision_with(sim, pt, ct, pns + cns, &ledger)?
            {
                gain += d.gain_ns;
            }
        }
        total -= gain;
    }
    Ok(total)
}

/// Greedy pin fill: candidates ordered by exact unit-gain density, filled
/// under the capacity budget.  Shared by the pooled planner and the
/// serial reference — both prefix-price the same fill order.
fn greedy_pins(
    sim: &Simulator,
    inputs: &[PlanNodeInput],
    budget: u64,
) -> anyhow::Result<Vec<NodePin>> {
    // Candidate nodes: packed-INT4 weights that fit the budget at all.
    struct Candidate {
        node: usize,
        unit_bytes: u64,
        density: f64,
    }
    let mut candidates: Vec<Candidate> = Vec::new();
    for (i, input) in inputs.iter().enumerate() {
        if packed_read_bytes(&input.trace) == 0 {
            continue;
        }
        let unit_bytes = weight_footprint_bytes(&input.problem);
        if unit_bytes == 0 || unit_bytes > budget {
            continue;
        }
        // Exact unit gain of pinning ONE instance of this node alone.
        let ledger = ResidencyLedger::with_pinned_weights(unit_bytes);
        let resident_ns = sim.price_with_residency(&carry_weights(&input.trace), &ledger)?;
        let density = (input.unit_ns - resident_ns) / unit_bytes as f64;
        if density > 0.0 {
            candidates.push(Candidate { node: i, unit_bytes, density });
        }
    }
    candidates.sort_by(|a, b| {
        b.density.partial_cmp(&a.density).unwrap().then(a.node.cmp(&b.node))
    });

    // Greedy fill under the budget.
    let mut pins: Vec<NodePin> = Vec::new();
    let mut pinned_bytes = 0u64;
    for c in &candidates {
        let room = (budget - pinned_bytes) / c.unit_bytes;
        let instances = (inputs[c.node].count as u64).min(room) as usize;
        if instances == 0 {
            continue;
        }
        pinned_bytes += instances as u64 * c.unit_bytes;
        pins.push(NodePin {
            node: c.node,
            kind: inputs[c.node].kind,
            instances,
            unit_bytes: c.unit_bytes,
        });
    }
    Ok(pins)
}

/// Ledger-independent constructions hoisted out of the prefix loop: the
/// carried-weight trace of every pinned node and every splice the exact
/// pricer can ask for.  [`coschedule::splice`] never reads a ledger, so
/// one construction serves all prefixes — each prefix then only pays the
/// detail-free re-pricing under its own pinned-bytes ledger.
struct PrefixPrep {
    /// Carried-weight trace per node (`Some` only for nodes in the fill).
    resident: Vec<Option<KernelTrace>>,
    /// Pin instances per node when the node's pin IS in the prefix.
    pin_instances: Vec<usize>,
    /// `splice(resident, resident)` per node (internal pair, `p > 1`).
    rr: Vec<Option<MergedTrace>>,
    /// `splice(cold, cold)` per node (internal pair, `count - p > 1`).
    cc: Vec<Option<MergedTrace>>,
    /// Boundary splice per adjacent pair, indexed
    /// `[left is resident][right is resident]` (a boundary instance is
    /// resident only when its node is fully pinned).
    boundary: Vec<[[Option<MergedTrace>; 2]; 2]>,
}

fn prefix_prep(inputs: &[PlanNodeInput], pins: &[NodePin], price_exact: bool) -> PrefixPrep {
    let n = inputs.len();
    let mut resident: Vec<Option<KernelTrace>> = vec![None; n];
    let mut pin_instances = vec![0usize; n];
    for pin in pins {
        pin_instances[pin.node] = pin.instances;
        resident[pin.node] = Some(carry_weights(&inputs[pin.node].trace));
    }
    let mut rr: Vec<Option<MergedTrace>> = Vec::new();
    let mut cc: Vec<Option<MergedTrace>> = Vec::new();
    let mut boundary: Vec<[[Option<MergedTrace>; 2]; 2]> = Vec::new();
    if price_exact {
        for (i, input) in inputs.iter().enumerate() {
            let count = input.count.max(1);
            rr.push(match resident[i].as_ref() {
                Some(rt) if pin_instances[i].min(count) > 1 => coschedule::splice(rt, rt),
                _ => None,
            });
            cc.push(if count >= 2 {
                coschedule::splice(&input.trace, &input.trace)
            } else {
                None
            });
        }
        // A node's boundary instance serves the resident variant only
        // when every instance is pinned (partial pins order resident
        // instances first, leaving a cold instance at each boundary).
        let variants = |i: usize| -> Vec<(usize, &KernelTrace)> {
            let count = inputs[i].count.max(1);
            let mut v = vec![(0usize, &inputs[i].trace)];
            if let Some(rt) = resident[i].as_ref() {
                if pin_instances[i].min(count) == count {
                    v.push((1, rt));
                }
            }
            v
        };
        for i in 1..n {
            let mut cell: [[Option<MergedTrace>; 2]; 2] = Default::default();
            for &(lv, lt) in &variants(i - 1) {
                for &(rv, rt) in &variants(i) {
                    cell[lv][rv] = coschedule::splice(lt, rt);
                }
            }
            boundary.push(cell);
        }
    }
    PrefixPrep { resident, pin_instances, rr, cc, boundary }
}

/// Exact price of the GEMM chain under one prefix of the fill order,
/// arithmetically identical to [`price_pins`] — same node walk, same
/// accumulation order, same pair adjacencies — but re-simulating through
/// the simulator's detail-free price path on the pre-built traces and
/// splices from [`PrefixPrep`] instead of reconstructing them per prefix.
fn price_prefix(
    sim: &Simulator,
    inputs: &[PlanNodeInput],
    prep: &PrefixPrep,
    pins: &[NodePin],
    extra_ns: f64,
    price_exact: bool,
) -> anyhow::Result<f64> {
    let pinned_bytes: u64 = pins.iter().map(|p| p.bytes()).sum();
    let ledger = ResidencyLedger::with_pinned_weights(pinned_bytes);
    let mut in_prefix = vec![false; inputs.len()];
    for pin in pins {
        in_prefix[pin.node] = true;
    }

    let mut cold_ns: Vec<Option<f64>> = Vec::with_capacity(inputs.len());
    let mut res_ns: Vec<Option<f64>> = Vec::with_capacity(inputs.len());
    let mut pinned: Vec<usize> = Vec::with_capacity(inputs.len());
    let mut total = extra_ns;
    for (i, input) in inputs.iter().enumerate() {
        let count = input.count.max(1);
        let p = if in_prefix[i] { prep.pin_instances[i].min(count) } else { 0 };
        let c = if p < count {
            Some(sim.price_with_residency(&input.trace, &ledger)?)
        } else {
            None
        };
        let r = if p > 0 {
            let carried = prep.resident[i].as_ref().expect("pinned node has a resident trace");
            Some(sim.price_with_residency(carried, &ledger)?)
        } else {
            None
        };
        total += p as f64 * r.unwrap_or(0.0) + (count - p) as f64 * c.unwrap_or(0.0);
        cold_ns.push(c);
        res_ns.push(r);
        pinned.push(p);
    }

    if price_exact {
        let mut gain = 0.0;
        for (i, input) in inputs.iter().enumerate() {
            let count = input.count.max(1);
            if count < 2 {
                continue;
            }
            let p = pinned[i];
            if p > 1 {
                if let Some(merged) = prep.rr[i].as_ref() {
                    let rns = res_ns[i].expect("p > 0 has a resident price");
                    let d = coschedule::decide_merged(sim, merged, 2.0 * rns, &ledger)?;
                    gain += (p - 1) as f64 * d.gain_ns;
                }
            }
            if count - p > 1 {
                if let Some(merged) = prep.cc[i].as_ref() {
                    let cns = cold_ns[i].expect("p < count has a cold price");
                    let d = coschedule::decide_merged(sim, merged, 2.0 * cns, &ledger)?;
                    gain += (count - p - 1) as f64 * d.gain_ns;
                }
            }
        }
        let variant = |i: usize| -> (usize, f64) {
            match cold_ns[i] {
                Some(ns) => (0, ns),
                None => (1, res_ns[i].expect("every node has a variant")),
            }
        };
        for i in 1..inputs.len() {
            let (lv, pns) = variant(i - 1);
            let (rv, cns) = variant(i);
            if let Some(merged) = prep.boundary[i - 1][lv][rv].as_ref() {
                let d = coschedule::decide_merged(sim, merged, pns + cns, &ledger)?;
                gain += d.gain_ns;
            }
        }
        total -= gain;
    }
    Ok(total)
}

/// Plan which nodes' weights to pin for one decode-step GEMM chain.
///
/// Greedy by exact gain density (saved ns per pinned byte), filled under
/// the capacity budget, then every prefix of the fill order is priced
/// exactly and the cheapest kept — prefix 0 being the unpinned chain, so
/// the plan never loses to it.  Splice/trace construction is hoisted out
/// of the prefix loop and the prefixes are priced concurrently on the
/// [`pool`] (each is an independent pure function of its ledger), with
/// results consumed in index order — bit-identical to
/// [`plan_nodes_serial`], which `sim_perf` and the planner's own tests
/// hold it to.
pub fn plan_nodes(
    machine: &MachineConfig,
    inputs: &[PlanNodeInput],
    extra_ns: f64,
    price_exact: bool,
) -> anyhow::Result<ResidencyPlan> {
    let sim = Simulator::new(machine.clone());
    let budget = pin_budget_bytes(machine);
    let mut pins = greedy_pins(&sim, inputs, budget)?;

    let prep = prefix_prep(inputs, &pins, price_exact);
    let lens: Vec<usize> = (0..=pins.len()).collect();
    let priced = pool::par_map(&lens, |&len| {
        price_prefix(&sim, inputs, &prep, &pins[..len], extra_ns, price_exact)
    });
    let mut prices: Vec<f64> = Vec::with_capacity(priced.len());
    for r in priced {
        prices.push(r?);
    }

    let baseline_ns = prices[0];
    let mut best_ns = baseline_ns;
    let mut best_len = 0usize;
    for (len, &ns) in prices.iter().enumerate().skip(1) {
        if ns < best_ns {
            best_ns = ns;
            best_len = len;
        }
    }
    pins.truncate(best_len);
    let pinned_bytes: u64 = pins.iter().map(|p| p.bytes()).sum();
    Ok(ResidencyPlan {
        pins,
        pinned_bytes,
        budget_bytes: budget,
        resident_ns: best_ns,
        baseline_ns,
    })
}

/// Serial reference planner: identical fill order, every prefix priced
/// one after the other through [`price_pins`] (full report assembly,
/// traces and splices rebuilt per prefix).  This is the pre-pooling
/// implementation, kept as the bit-identity oracle for [`plan_nodes`] and
/// as the serial leg of the `sim_perf` wall-clock cells.
pub fn plan_nodes_serial(
    machine: &MachineConfig,
    inputs: &[PlanNodeInput],
    extra_ns: f64,
    price_exact: bool,
) -> anyhow::Result<ResidencyPlan> {
    let sim = Simulator::new(machine.clone());
    let budget = pin_budget_bytes(machine);
    let mut pins = greedy_pins(&sim, inputs, budget)?;

    // Exact prefix pricing: prefix 0 is the unpinned chain.
    let baseline_ns = price_pins(&sim, inputs, &[], extra_ns, price_exact)?;
    let mut best_ns = baseline_ns;
    let mut best_len = 0usize;
    for len in 1..=pins.len() {
        let ns = price_pins(&sim, inputs, &pins[..len], extra_ns, price_exact)?;
        if ns < best_ns {
            best_ns = ns;
            best_len = len;
        }
    }
    pins.truncate(best_len);
    let pinned_bytes: u64 = pins.iter().map(|p| p.bytes()).sum();
    Ok(ResidencyPlan {
        pins,
        pinned_bytes,
        budget_bytes: budget,
        resident_ns: best_ns,
        baseline_ns,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::MachineConfig;
    use crate::kernels::{self, Strategy};

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    fn input(
        kind: GemmKind,
        strategy: Strategy,
        mm: usize,
        n: usize,
        k: usize,
        count: usize,
    ) -> PlanNodeInput {
        let machine = m();
        let p = GemmProblem::new(mm, n, k);
        let trace = kernels::schedule(&machine, &p, strategy).unwrap();
        let unit_ns = Simulator::new(machine).run(&trace).unwrap().total_ns;
        PlanNodeInput { kind, problem: p, count, unit_ns, trace }
    }

    #[test]
    fn carry_weights_preserves_byte_totals_and_reclasses() {
        let machine = m();
        let p = GemmProblem::new(8, 2048, 8192);
        let trace = kernels::schedule(&machine, &p, Strategy::SplitK).unwrap();
        let carried = carry_weights(&trace);
        assert_eq!(carried.phases.len(), trace.phases.len());
        let sum = |t: &KernelTrace, c: BufferClass| -> u64 {
            t.phases.iter().map(|ph| ph.read_bytes(c)).sum()
        };
        let packed = sum(&trace, BufferClass::WeightPacked);
        let qparam = sum(&trace, BufferClass::QuantParam);
        assert!(packed > 0 && qparam > 0);
        assert_eq!(sum(&carried, BufferClass::WeightPacked), 0);
        assert_eq!(sum(&carried, BufferClass::QuantParam), 0);
        assert_eq!(sum(&carried, BufferClass::CarriedWeight), packed + qparam);
        // Everything else is untouched.
        for c in [BufferClass::Activation, BufferClass::Workspace, BufferClass::Partial] {
            assert_eq!(sum(&carried, c), sum(&trace, c));
        }
        assert_eq!(carried.total_macs(), trace.total_macs());
    }

    #[test]
    fn pinned_node_prices_faster_and_plan_never_exceeds_budget() {
        let machine = m();
        // The llama32 K>>N down-projection under the fused schedule (the
        // tuner's usual winner): its group is HBM-bound on the packed
        // weight stream, so keeping the 9 MiB of weights + qparams
        // resident moves the whole stream onto L2.
        let inputs = vec![
            input(GemmKind::Down, Strategy::Fused, 8, 2048, 8192, 1),
            input(GemmKind::Qkv, Strategy::Fused, 8, 6144, 2048, 1),
        ];
        let plan = plan_nodes(&machine, &inputs, 0.0, false).unwrap();
        assert!(plan.pinned_bytes <= plan.budget_bytes);
        assert!(plan.resident_ns <= plan.baseline_ns);
        assert!(
            !plan.pins.is_empty() && plan.gain_ns() > 0.0,
            "resident weights must win on the K>>N decode shape: {plan:?}"
        );
        // Density ordering put a pin on the down node.
        assert!(plan.pins.iter().any(|p| p.kind == GemmKind::Down));
    }

    #[test]
    fn planner_declines_when_pinning_prices_slower() {
        let machine = m();
        // The splitk schedule on a spilling-workspace shape: its group is
        // bound by the L2 workspace stream, and reserving capacity for
        // weights would squeeze the workspace residency — the exact
        // prefix pricing must keep the unpinned chain.
        let inputs = vec![input(GemmKind::Down, Strategy::SplitK, 8, 2048, 8192, 1)];
        let plan = plan_nodes(&machine, &inputs, 0.0, false).unwrap();
        assert!(plan.resident_ns <= plan.baseline_ns, "never slower, by construction");
        assert!(plan.pinned_bytes <= plan.budget_bytes);
    }

    #[test]
    fn oversized_weights_are_not_pinned() {
        let machine = m();
        // glm45 down: 31.5 MiB packed alone exceeds the 28.8 MiB budget.
        let inputs = vec![input(GemmKind::Down, Strategy::Fused, 8, 5120, 12288, 1)];
        let plan = plan_nodes(&machine, &inputs, 0.0, false).unwrap();
        assert!(plan.pins.is_empty());
        assert_eq!(plan.resident_ns, plan.baseline_ns);
        assert_eq!(plan.gain_ns(), 0.0);
    }

    #[test]
    fn expert_batches_pin_at_instance_granularity() {
        let machine = m();
        // One expert's weights are ~8 MiB; 64 experts cannot all fit, so
        // any pin must cover a strict subset of the instances.
        let inputs = vec![input(GemmKind::MoeExpert, Strategy::Fused, 1, 7168, 2048, 64)];
        let plan = plan_nodes(&machine, &inputs, 0.0, false).unwrap();
        assert!(plan.pinned_bytes <= plan.budget_bytes);
        if let Some(pin) = plan.pins.first() {
            assert!(pin.instances < 64, "64 experts cannot all be resident");
            assert!(pin.instances >= 1);
        }
    }

    #[test]
    fn pooled_planner_matches_serial_reference() {
        let machine = m();
        // A mixed chain: dense projections, a Split-K node (spliceable
        // exposed reduce) and a partially-pinnable expert batch, priced
        // both heuristically and exactly.  The pooled planner hoists the
        // trace/splice construction and prices prefixes concurrently; it
        // must land on bit-identical numbers and the same pin set.
        let inputs = vec![
            input(GemmKind::Qkv, Strategy::Fused, 8, 6144, 2048, 1),
            input(GemmKind::Down, Strategy::SplitK, 8, 2048, 8192, 1),
            input(GemmKind::MoeExpert, Strategy::Fused, 1, 7168, 2048, 64),
            input(GemmKind::Down, Strategy::Fused, 8, 2048, 8192, 1),
        ];
        for exact in [false, true] {
            let pooled = plan_nodes(&machine, &inputs, 123.0, exact).unwrap();
            let serial = plan_nodes_serial(&machine, &inputs, 123.0, exact).unwrap();
            assert_eq!(pooled.pins, serial.pins, "price_exact={exact}");
            assert_eq!(pooled.pinned_bytes, serial.pinned_bytes);
            assert_eq!(pooled.budget_bytes, serial.budget_bytes);
            assert_eq!(
                pooled.resident_ns.to_bits(),
                serial.resident_ns.to_bits(),
                "price_exact={exact}: resident_ns diverged"
            );
            assert_eq!(
                pooled.baseline_ns.to_bits(),
                serial.baseline_ns.to_bits(),
                "price_exact={exact}: baseline_ns diverged"
            );
        }
    }

    #[test]
    fn mode_names_round_trip() {
        for mode in [ResidencyMode::Off, ResidencyMode::Auto] {
            assert_eq!(ResidencyMode::from_name(mode.name()).unwrap(), mode);
        }
        assert!(ResidencyMode::from_name("bogus").is_err());
        assert_eq!(ResidencyMode::default(), ResidencyMode::Auto);
    }

    #[test]
    fn plan_json_round_trips() {
        let machine = m();
        let inputs = vec![input(GemmKind::Down, Strategy::Fused, 8, 2048, 8192, 1)];
        let plan = plan_nodes(&machine, &inputs, 0.0, false).unwrap();
        let j = Json::parse(&plan.to_json().to_string()).unwrap();
        assert!(j.req("residency_gain_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            j.req("pins").unwrap().as_arr().unwrap().len(),
            plan.pins.len()
        );
    }
}
