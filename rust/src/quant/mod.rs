//! INT4 group quantization and nibble packing — rust twin of
//! `python/compile/quantize.py`.
//!
//! Storage convention (identical to the python side, asserted by the
//! cross-language tests in `rust/tests/quant_roundtrip.rs`):
//! * weights `W` are `K x N`, quantized group-wise along K (group `g`);
//! * codes are unsigned nibbles `q in [0, 15]`, `w = s * (q - z)`;
//! * two codes per byte along K: byte `b[k][n]` holds `q[2k][n]` in the low
//!   nibble, `q[2k+1][n]` in the high nibble -> `(K/2, N)` i8.

use crate::tensor::MatF32;

pub const DEFAULT_GROUP: usize = 128;
pub const QMAX: u8 = 15;

/// A quantized `K x N` weight matrix (packed codes + group parameters).
#[derive(Debug, Clone)]
pub struct QuantizedWeight {
    /// Nibble-packed codes, row-major `(K/2, N)`.
    pub packed: Vec<i8>,
    /// Per-(group, column) scales, row-major `(K/g, N)`.
    pub scales: Vec<f32>,
    /// Per-(group, column) zero points in code units, row-major `(K/g, N)`.
    pub zeros: Vec<f32>,
    pub k: usize,
    pub n: usize,
    pub group: usize,
}

impl QuantizedWeight {
    pub fn groups(&self) -> usize {
        self.k / self.group
    }

    /// Packed weight bytes (the 4x-compression denominator of §2.2).
    pub fn packed_bytes(&self) -> usize {
        self.packed.len()
    }

    /// Dequantize to a dense f32 matrix (host reference path).
    pub fn dequantize(&self) -> MatF32 {
        let mut out = MatF32::zeros(self.k, self.n);
        for kk in 0..self.k {
            let g = kk / self.group;
            let byte_row = kk / 2;
            let hi = kk % 2 == 1;
            for nn in 0..self.n {
                let byte = self.packed[byte_row * self.n + nn] as u8;
                let q = if hi { (byte >> 4) & 0xF } else { byte & 0xF };
                let s = self.scales[g * self.n + nn];
                let z = self.zeros[g * self.n + nn];
                out.set(kk, nn, s * (q as f32 - z));
            }
        }
        out
    }
}

/// Pack unsigned nibble codes `(K, N)` into `(K/2, N)` bytes.
pub fn pack_int4(codes: &[u8], k: usize, n: usize) -> anyhow::Result<Vec<i8>> {
    anyhow::ensure!(codes.len() == k * n, "codes length mismatch");
    anyhow::ensure!(k % 2 == 0, "K must be even for nibble packing");
    anyhow::ensure!(codes.iter().all(|&q| q <= QMAX), "nibble out of range");
    let mut out = vec![0i8; k / 2 * n];
    for kk in (0..k).step_by(2) {
        for nn in 0..n {
            let lo = codes[kk * n + nn];
            let hi = codes[(kk + 1) * n + nn];
            out[(kk / 2) * n + nn] = ((hi << 4) | lo) as i8;
        }
    }
    Ok(out)
}

/// Unpack `(K/2, N)` bytes back to `(K, N)` nibble codes.
pub fn unpack_int4(packed: &[i8], k: usize, n: usize) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(packed.len() * 2 == k * n, "packed length mismatch");
    let mut out = vec![0u8; k * n];
    for row in 0..k / 2 {
        for nn in 0..n {
            let byte = packed[row * n + nn] as u8;
            out[(2 * row) * n + nn] = byte & 0xF;
            out[(2 * row + 1) * n + nn] = (byte >> 4) & 0xF;
        }
    }
    Ok(out)
}

/// Group-wise INT4 quantization of a `K x N` f32 matrix.
///
/// `symmetric=true` pins the zero point at mid-code 8 with a max-|w| scale;
/// otherwise a min/max affine fit per group is used (degenerate constant
/// groups fall back to the symmetric form so constants stay representable).
pub fn quantize_groupwise(
    w: &MatF32,
    group: usize,
    symmetric: bool,
) -> anyhow::Result<QuantizedWeight> {
    let (k, n) = (w.rows, w.cols);
    anyhow::ensure!(k % group == 0, "K={k} not divisible by group={group}");
    anyhow::ensure!(k % 2 == 0, "K={k} must be even");
    let groups = k / group;
    let mut scales = vec![0f32; groups * n];
    let mut zeros = vec![0f32; groups * n];
    let mut codes = vec![0u8; k * n];

    for g in 0..groups {
        for nn in 0..n {
            let mut lo = f32::INFINITY;
            let mut hi = f32::NEG_INFINITY;
            for kk in g * group..(g + 1) * group {
                let v = w.at(kk, nn);
                lo = lo.min(v);
                hi = hi.max(v);
            }
            let (s, z) = if symmetric || hi == lo {
                let amax = lo.abs().max(hi.abs());
                (if amax == 0.0 { 1.0 } else { amax / 7.0 }, 8.0)
            } else {
                let s = (hi - lo) / QMAX as f32;
                (s, (-lo / s).round().clamp(0.0, QMAX as f32))
            };
            scales[g * n + nn] = s;
            zeros[g * n + nn] = z;
            for kk in g * group..(g + 1) * group {
                let q = (w.at(kk, nn) / s + z).round().clamp(0.0, QMAX as f32);
                codes[kk * n + nn] = q as u8;
            }
        }
    }

    Ok(QuantizedWeight {
        packed: pack_int4(&codes, k, n)?,
        scales,
        zeros,
        k,
        n,
        group,
    })
}

/// W4A16 host reference: dequantize then f16-rounded GEMM with f32 accumulate.
/// This is what every artifact's output is compared against.
pub fn w4a16_reference(a: &MatF32, qw: &QuantizedWeight) -> MatF32 {
    let b = qw.dequantize();
    // Weights pass through f16 in the kernel (workspace dtype).
    a.matmul_f16acc(&b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn random_mat(k: usize, n: usize, seed: u64) -> MatF32 {
        let mut rng = Rng::new(seed);
        MatF32::from_vec(k, n, rng.normal_vec(k * n, 0.05))
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let codes: Vec<u8> = (0..256u32).map(|i| (i % 16) as u8).collect();
        let packed = pack_int4(&codes, 16, 16).unwrap();
        assert_eq!(unpack_int4(&packed, 16, 16).unwrap(), codes);
    }

    #[test]
    fn pack_layout_matches_python() {
        // q[0]=1 (low), q[1]=2 (high) -> byte 0x21
        let packed = pack_int4(&[1, 2], 2, 1).unwrap();
        assert_eq!(packed[0], 0x21);
        // codes >= 8 set the sign bit; must still round-trip
        let packed = pack_int4(&[15, 15], 2, 1).unwrap();
        assert_eq!(packed[0] as u8, 0xFF);
        assert_eq!(unpack_int4(&packed, 2, 1).unwrap(), vec![15, 15]);
    }

    #[test]
    fn quantization_error_bounded_by_half_step() {
        let w = random_mat(256, 16, 3);
        let qw = quantize_groupwise(&w, 128, false).unwrap();
        let back = qw.dequantize();
        for kk in 0..256 {
            for nn in 0..16 {
                let s = qw.scales[(kk / 128) * 16 + nn];
                assert!(
                    (w.at(kk, nn) - back.at(kk, nn)).abs() <= s * 0.5 + 1e-6,
                    "({kk},{nn})"
                );
            }
        }
    }

    #[test]
    fn symmetric_zero_is_mid_code() {
        let w = random_mat(128, 8, 5);
        let qw = quantize_groupwise(&w, 128, true).unwrap();
        assert!(qw.zeros.iter().all(|&z| z == 8.0));
    }

    #[test]
    fn constant_group_exact() {
        let w = MatF32::from_vec(128, 2, vec![0.25; 256]);
        let qw = quantize_groupwise(&w, 128, false).unwrap();
        let back = qw.dequantize();
        assert!(back.data.iter().all(|&v| (v - 0.25).abs() < 1e-6));
    }

    #[test]
    fn compression_is_4x_vs_fp16() {
        let qw = quantize_groupwise(&random_mat(512, 64, 7), 128, false).unwrap();
        assert_eq!(qw.packed_bytes() * 4, 512 * 64 * 2);
    }

    #[test]
    fn rejects_bad_shapes() {
        assert!(quantize_groupwise(&MatF32::zeros(100, 4), 128, false).is_err());
        assert!(pack_int4(&[16, 0], 2, 1).is_err());
    }
}
