//! Exhaustive-but-pruned schedule search for one GEMM shape.
//!
//! The per-strategy tilers (`kernels::tiling::select_*`) are analytic
//! heuristics; the tuner wraps them in a simulator-scored neighborhood
//! search: every concrete strategy contributes its heuristic pick plus a
//! small perturbation set (split factor halved/doubled, alternate B-tile
//! widths, chunk depth halved/doubled), illegal candidates are pruned by
//! `Tiling::validate`, and the survivors are scored exactly by the full
//! simulator.  A dozen simulations per strategy is enough to beat any
//! single heuristic across the paper's sweep while keeping `repro tune`
//! instantaneous.

use crate::ascend::{cube, MachineConfig, Simulator};
use crate::kernels::tiling::Tiling;
use crate::kernels::{self, GemmProblem, Strategy};

use super::cache::TunedEntry;

/// Outcome of one shape search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub best: TunedEntry,
    /// All scored (strategy, time) pairs, best first — for the CLI report.
    pub scored: Vec<(Strategy, Tiling, f64)>,
    /// Candidates simulated (after pruning).
    pub evaluated: usize,
}

/// Search every concrete strategy for `p` and return the fastest schedule.
pub fn search(machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<SearchResult> {
    let sim = Simulator::new(machine.clone());
    let mut scored: Vec<(Strategy, Tiling, f64)> = Vec::new();
    for strategy in Strategy::all_concrete() {
        for t in candidates(machine, p, strategy) {
            if t.validate(machine, p).is_err() {
                continue;
            }
            let trace = match kernels::schedule_with(machine, p, strategy, &t) {
                Ok(trace) => trace,
                Err(_) => continue,
            };
            match sim.run(&trace) {
                Ok(r) => scored.push((strategy, t, r.total_ns)),
                Err(_) => continue,
            }
        }
    }
    anyhow::ensure!(
        !scored.is_empty(),
        "no legal schedule for M={} N={} K={} group={}",
        p.m,
        p.n,
        p.k,
        p.group
    );
    scored.sort_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    let evaluated = scored.len();
    let (strategy, tiling, total_ns) = scored[0];
    Ok(SearchResult {
        best: TunedEntry { strategy, tiling, total_ns },
        scored,
        evaluated,
    })
}

/// Shrink `bk` until the MMAD block fits L0 (or hits the floor).
fn fit_bk(machine: &MachineConfig, bm: usize, bn: usize, mut bk: usize) -> usize {
    while !cube::block_fits_l0(machine, bm, bn, bk) && bk > 16 {
        bk /= 2;
    }
    bk
}

/// The pruned candidate neighborhood for one strategy.
fn candidates(machine: &MachineConfig, p: &GemmProblem, strategy: Strategy) -> Vec<Tiling> {
    let base = match kernels::select_tiling(machine, p, strategy) {
        Ok(t) => t,
        Err(_) => return Vec::new(),
    };
    let mut out = vec![base];
    let mut push = |t: Tiling| {
        if !out.contains(&t) {
            out.push(t);
        }
    };

    // Split-factor neighborhood (occupancy vs reduce overhead).  W4A8
    // inherits Split-K's reduce machinery, so the same trade-off applies.
    if matches!(
        strategy,
        Strategy::SplitK | Strategy::Fused | Strategy::Chunked | Strategy::W4A8
    ) {
        if base.splits > 1 {
            push(Tiling { splits: base.splits / 2, ..base });
        }
        push(Tiling { splits: base.splits * 2, ..base });
    }

    // Chunk-depth neighborhood (slice residency vs rotation count).
    if strategy == Strategy::Chunked {
        if base.chunks > 1 {
            push(Tiling { chunks: base.chunks / 2, ..base });
            push(Tiling { chunks: 1, ..base });
        }
        push(Tiling { chunks: base.chunks * 2, ..base });
    }

    // B-tile width neighborhood (DMA burst efficiency vs grid size).
    for bn in [256usize, 128, 64] {
        if bn == base.bn || p.n % bn != 0 {
            continue;
        }
        let bk = fit_bk(machine, base.bm, bn, p.group.min(p.k));
        push(Tiling { bn, bk, ..base });
    }

    // M-tile neighborhood: a narrower bm raises the grid for mid-size
    // batches (ROADMAP follow-up: bm perturbations; halving bm keeps the
    // block inside L0, so no bk refit is needed).
    if base.bm > 16 {
        push(Tiling { bm: base.bm / 2, ..base });
    }

    // Dequant-tile width neighborhood (ROADMAP follow-up: dequant_bn):
    // narrower vector tiles trade UB pressure for Phase-1 grid size.
    for dequant_bn in [256usize, 128, 64] {
        if dequant_bn == base.dequant_bn || p.n % dequant_bn != 0 {
            continue;
        }
        push(Tiling { dequant_bn, ..base });
    }

    // Vector/cube rebalance neighborhood (W4A8 only): `select_w4a8`
    // already scored the coarse grid, but re-offering it here lets the
    // knob combine with the split/width perturbations above.
    if strategy == Strategy::W4A8 {
        for rebalance in [0usize, 50, 100] {
            if rebalance != base.rebalance {
                push(Tiling { rebalance, ..base });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn search_finds_a_winner_for_decode_shape() {
        let p = GemmProblem::new(8, 512, 16384);
        let r = search(&m(), &p).unwrap();
        assert!(r.evaluated >= Strategy::all_concrete().len());
        assert!(r.best.total_ns > 0.0);
        assert!(r.scored.windows(2).all(|w| w[0].2 <= w[1].2), "sorted");
    }

    #[test]
    fn winner_never_slower_than_heuristic_splitk() {
        let machine = m();
        let sim = Simulator::new(machine.clone());
        for (n, k) in [(512, 16384), (2048, 7168), (12288, 5120)] {
            let p = GemmProblem::new(8, n, k);
            let sk = sim
                .run(&kernels::schedule(&machine, &p, Strategy::SplitK).unwrap())
                .unwrap();
            let best = search(&machine, &p).unwrap().best;
            assert!(
                best.total_ns <= sk.total_ns * 1.000001,
                "n={n} k={k}: tuned {} vs splitk {}",
                best.total_ns,
                sk.total_ns
            );
        }
    }

    #[test]
    fn w4a8_tagged_search_never_loses_to_the_w4a16_family() {
        // The W4A8-tagged candidate set is a superset of the W4A16 one
        // (the five precision-agnostic strategies stay searchable), so
        // Auto-with-W4A8 can never be slower than W4A16-only.
        use crate::model::Precision;
        let machine = m();
        for (n, k) in [(512, 16384), (2048, 7168), (12288, 5120)] {
            let a16 = search(&machine, &GemmProblem::new(8, n, k)).unwrap().best;
            let a8 = search(
                &machine,
                &GemmProblem::new(8, n, k).with_precision(Precision::W4A8),
            )
            .unwrap()
            .best;
            assert!(
                a8.total_ns <= a16.total_ns * 1.000001,
                "n={n} k={k}: w4a8-tagged {} vs w4a16 {}",
                a8.total_ns,
                a16.total_ns
            );
        }
    }

    #[test]
    fn w4a16_candidate_sets_ignore_the_w4a8_strategy() {
        // W4A8 contributes zero candidates to an untagged problem, so
        // pre-existing searches (and their cached winners) are unchanged.
        assert!(candidates(&m(), &GemmProblem::new(8, 2048, 7168), Strategy::W4A8).is_empty());
    }

    #[test]
    fn candidate_set_is_deduplicated() {
        let c = candidates(&m(), &GemmProblem::new(8, 2048, 7168), Strategy::Chunked);
        for (i, a) in c.iter().enumerate() {
            assert!(!c[i + 1..].contains(a), "duplicate candidate {a:?}");
        }
    }
}
