//! Per-shape schedule autotuner with a persisted cache.
//!
//! The tuner closes the loop between the kernel schedules and the serving
//! stack (DESIGN.md §9): `repro tune` searches strategies x tilings per
//! `(machine, M_pad, N, K, group)` shape with [`search`], persists the
//! winners to a JSON [`cache::TuneCache`], and everything downstream —
//! `simulate --strategy auto`, the benches, the coordinator router —
//! resolves [`Strategy::Auto`](crate::kernels::Strategy) through that
//! cache without re-searching.
//!
//! Cache misses at resolve time fall back to a live search (and populate
//! the in-memory cache) so first runs still work; [`Tuner::lookup`] is
//! the search-free variant the serving hot path uses.

pub mod cache;
pub mod search;

pub use cache::{machine_tag, pair_key, shape_key, TuneCache, TunedEntry};
pub use search::{search, SearchResult};

use std::path::{Path, PathBuf};

use crate::analysis::coschedule;
use crate::ascend::{KernelTrace, MachineConfig, Simulator};
use crate::kernels::{self, GemmProblem, Strategy};

/// Default cache file name (next to the artifacts / working directory).
pub const DEFAULT_CACHE_FILE: &str = "tune_cache.json";

/// The autotuner: a machine, its cache, and hit/search accounting.
#[derive(Debug, Clone)]
pub struct Tuner {
    machine: MachineConfig,
    pub cache: TuneCache,
    /// Where `save()` writes (set by `load`; `None` for in-memory tuners).
    path: Option<PathBuf>,
    /// Resolutions served from the cache.
    pub hits: usize,
    /// Resolutions that required a live search.
    pub searches: usize,
    /// Co-schedule pair decisions served from the cache.
    pub overlap_hits: usize,
    /// Pair decisions that required a live merged-trace simulation.
    pub overlap_searches: usize,
}

impl Tuner {
    pub fn new(machine: MachineConfig) -> Tuner {
        Tuner {
            machine,
            cache: TuneCache::new(),
            path: None,
            hits: 0,
            searches: 0,
            overlap_hits: 0,
            overlap_searches: 0,
        }
    }

    /// Load (or start) the cache at `path`.
    pub fn load(machine: MachineConfig, path: impl AsRef<Path>) -> anyhow::Result<Tuner> {
        let path = path.as_ref().to_path_buf();
        let cache = TuneCache::load(&path)?;
        Ok(Tuner {
            machine,
            cache,
            path: Some(path),
            hits: 0,
            searches: 0,
            overlap_hits: 0,
            overlap_searches: 0,
        })
    }

    pub fn machine(&self) -> &MachineConfig {
        &self.machine
    }

    pub fn key(&self, p: &GemmProblem) -> String {
        shape_key(&self.machine, p)
    }

    /// Cache-only resolution — never searches (the serving hot path).
    pub fn lookup(&mut self, p: &GemmProblem) -> Option<TunedEntry> {
        let hit = self.cache.get(&self.key(p)).copied();
        if hit.is_some() {
            self.hits += 1;
        }
        hit
    }

    /// Resolve a shape to its tuned schedule: cache hit, or search + fill.
    pub fn resolve(&mut self, p: &GemmProblem) -> anyhow::Result<TunedEntry> {
        let key = self.key(p);
        if let Some(e) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(*e);
        }
        let result = search::search(&self.machine, p)?;
        self.searches += 1;
        self.cache.insert(key, result.best);
        Ok(result.best)
    }

    /// Resolve a strategy selector: `Auto` goes through the cache/search,
    /// concrete strategies keep their heuristic tiling.
    pub fn resolve_strategy(
        &mut self,
        p: &GemmProblem,
        strategy: Strategy,
    ) -> anyhow::Result<(Strategy, kernels::tiling::Tiling)> {
        if strategy == Strategy::Auto {
            let e = self.resolve(p)?;
            Ok((e.strategy, e.tiling))
        } else {
            Ok((strategy, kernels::select_tiling(&self.machine, p, strategy)?))
        }
    }

    /// Build the tuned trace for a problem (resolving `Auto`).
    pub fn schedule(&mut self, p: &GemmProblem, strategy: Strategy) -> anyhow::Result<KernelTrace> {
        let (s, t) = self.resolve_strategy(p, strategy)?;
        kernels::schedule_with(&self.machine, p, s, &t)
    }

    /// Cache-only lookup of the co-schedule decision for one adjacent
    /// (producer, consumer) pair — the serving hot path (`Router::
    /// layer_plan`) never pays a merged-trace simulation.
    pub fn lookup_overlap(&mut self, producer: &GemmProblem, consumer: &GemmProblem) -> Option<f64> {
        let key = cache::pair_key(&self.machine, producer, consumer);
        let hit = self.cache.overlap_get(&key);
        if hit.is_some() {
            self.overlap_hits += 1;
        }
        hit
    }

    /// Resolve the co-schedule decision for one adjacent pair: cache hit,
    /// or splice the pair's tuned schedules, re-simulate the merged trace
    /// (DESIGN.md §12) and cache the exact gain.  A cached 0.0 means the
    /// pair is not spliceable (or the merge priced slower) — either way,
    /// resolving it again is a pure cache hit.
    pub fn resolve_overlap(
        &mut self,
        producer: &GemmProblem,
        consumer: &GemmProblem,
    ) -> anyhow::Result<f64> {
        let key = cache::pair_key(&self.machine, producer, consumer);
        if let Some(gain) = self.cache.overlap_get(&key) {
            self.overlap_hits += 1;
            return Ok(gain);
        }
        let pe = self.resolve(producer)?;
        let ce = self.resolve(consumer)?;
        let pt = kernels::schedule_with(&self.machine, producer, pe.strategy, &pe.tiling)?;
        let ct = kernels::schedule_with(&self.machine, consumer, ce.strategy, &ce.tiling)?;
        let sim = Simulator::new(self.machine.clone());
        // The tuned entries carry each schedule's simulated unit time, so
        // the sequential pair price is cache-exact.
        let gain = match coschedule::pair_decision(&sim, &pt, &ct, pe.total_ns + ce.total_ns)? {
            Some(d) => d.gain_ns,
            None => 0.0,
        };
        self.overlap_searches += 1;
        self.cache.overlap_insert(key, gain);
        Ok(gain)
    }

    /// Persist the cache to its load path (no-op destination error if the
    /// tuner was created in-memory).
    pub fn save(&self) -> anyhow::Result<()> {
        let path = self
            .path
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("in-memory tuner has no cache path"))?;
        self.cache.save(path)
    }

    /// Persist the cache to an explicit path.
    pub fn save_to(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        self.cache.save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;

    fn machine() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn resolve_searches_once_then_hits() {
        let mut tuner = Tuner::new(machine());
        let p = GemmProblem::new(8, 512, 16384);
        let a = tuner.resolve(&p).unwrap();
        assert_eq!((tuner.searches, tuner.hits), (1, 0));
        let b = tuner.resolve(&p).unwrap();
        assert_eq!((tuner.searches, tuner.hits), (1, 1));
        assert_eq!(a, b);
        // Padded-M aliasing: batch 3 resolves to the same entry, no search.
        let c = tuner.resolve(&GemmProblem::new(3, 512, 16384)).unwrap();
        assert_eq!((tuner.searches, tuner.hits), (1, 2));
        assert_eq!(a, c);
    }

    #[test]
    fn persisted_cache_resolves_without_search() {
        let dir = std::env::temp_dir().join(format!("w4a16-tuner-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(DEFAULT_CACHE_FILE);
        let p = GemmProblem::new(8, 512, 16384);

        let mut warm = Tuner::load(machine(), &path).unwrap();
        warm.resolve(&p).unwrap();
        warm.save().unwrap();

        let mut cold = Tuner::load(machine(), &path).unwrap();
        let e = cold.resolve(&p).unwrap();
        assert_eq!(cold.searches, 0, "persisted winner must be reused");
        assert_eq!(cold.hits, 1);
        assert!(e.total_ns > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_schedules_through_the_tuner() {
        let mut tuner = Tuner::new(machine());
        let p = GemmProblem::new(8, 512, 16384);
        let trace = tuner.schedule(&p, Strategy::Auto).unwrap();
        let r = Simulator::new(machine()).run(&trace).unwrap();
        assert!(r.total_ns > 0.0);
        // The tuned schedule can never lose to the heuristic splitk pick.
        let sk = Simulator::new(machine())
            .run(&kernels::schedule(&machine(), &p, Strategy::SplitK).unwrap())
            .unwrap();
        assert!(r.total_ns <= sk.total_ns * 1.000001);
    }

    #[test]
    fn overlap_resolves_once_then_hits_and_persists() {
        let dir = std::env::temp_dir().join(format!("w4a16-overlap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(DEFAULT_CACHE_FILE);
        let prod = GemmProblem::new(8, 512, 16384);
        let cons = GemmProblem::new(8, 2048, 8192);

        let mut warm = Tuner::load(machine(), &path).unwrap();
        assert_eq!(warm.lookup_overlap(&prod, &cons), None, "cold cache");
        let gain = warm.resolve_overlap(&prod, &cons).unwrap();
        assert_eq!(warm.overlap_searches, 1);
        assert!(gain >= 0.0 && gain.is_finite());
        let again = warm.resolve_overlap(&prod, &cons).unwrap();
        assert_eq!(warm.overlap_searches, 1, "second resolve must hit");
        assert_eq!(again, gain);
        warm.save().unwrap();

        // A fresh tuner serves the pair cache-only (the router hot path).
        let mut cold = Tuner::load(machine(), &path).unwrap();
        assert_eq!(cold.lookup_overlap(&prod, &cons), Some(gain));
        assert_eq!((cold.overlap_hits, cold.overlap_searches), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn concrete_strategy_passes_through() {
        let mut tuner = Tuner::new(machine());
        let p = GemmProblem::new(8, 512, 16384);
        let (s, _) = tuner.resolve_strategy(&p, Strategy::DataParallel).unwrap();
        assert_eq!(s, Strategy::DataParallel);
        assert_eq!(tuner.searches, 0);
    }
}
