//! Per-shape schedule autotuner with a persisted cache.
//!
//! The tuner closes the loop between the kernel schedules and the serving
//! stack (DESIGN.md §9): `repro tune` searches strategies x tilings per
//! `(machine, M_pad, N, K, group)` shape with [`search`], persists the
//! winners to a JSON [`cache::TuneCache`], and everything downstream —
//! `simulate --strategy auto`, the benches, the coordinator router —
//! resolves [`Strategy::Auto`](crate::kernels::Strategy) through that
//! cache without re-searching.
//!
//! Cache misses at resolve time fall back to a live search (and populate
//! the in-memory cache) so first runs still work; [`Tuner::lookup`] is
//! the search-free variant the serving hot path uses.

pub mod cache;
pub mod search;

pub use cache::{layer_key, machine_tag, pair_key, shape_key, ResidencyEntry, TuneCache, TunedEntry};
pub use search::{search, SearchResult};

use std::path::{Path, PathBuf};

use crate::analysis::{coschedule, residency};
use crate::ascend::{KernelTrace, MachineConfig, Simulator};
use crate::kernels::{self, GemmProblem, Strategy};
use crate::workload::decode_layer::DecodeLayer;

/// Default cache file name (next to the artifacts / working directory).
pub const DEFAULT_CACHE_FILE: &str = "tune_cache.json";

/// The autotuner: a machine, its cache, and hit/search accounting.
#[derive(Debug, Clone)]
pub struct Tuner {
    machine: MachineConfig,
    pub cache: TuneCache,
    /// Where `save()` writes (set by `load`; `None` for in-memory tuners).
    path: Option<PathBuf>,
    /// Resolutions served from the cache.
    pub hits: usize,
    /// Resolutions that required a live search.
    pub searches: usize,
    /// Co-schedule pair decisions served from the cache.
    pub overlap_hits: usize,
    /// Pair decisions that required a live merged-trace simulation.
    pub overlap_searches: usize,
    /// Step-level residency plans served from the cache.
    pub residency_hits: usize,
    /// Residency plans that required live planning.
    pub residency_searches: usize,
}

impl Tuner {
    pub fn new(machine: MachineConfig) -> Tuner {
        Tuner {
            machine,
            cache: TuneCache::new(),
            path: None,
            hits: 0,
            searches: 0,
            overlap_hits: 0,
            overlap_searches: 0,
            residency_hits: 0,
            residency_searches: 0,
        }
    }

    /// Load (or start) the cache at `path`.
    pub fn load(machine: MachineConfig, path: impl AsRef<Path>) -> anyhow::Result<Tuner> {
        let path = path.as_ref().to_path_buf();
        let cache = TuneCache::load(&path)?;
        Ok(Tuner {
            machine,
            cache,
            path: Some(path),
            hits: 0,
            searches: 0,
            overlap_hits: 0,
            overlap_searches: 0,
            residency_hits: 0,
            residency_searches: 0,
        })
    }

    pub fn machine(&self) -> &MachineConfig {
        &self.machine
    }

    pub fn key(&self, p: &GemmProblem) -> String {
        shape_key(&self.machine, p)
    }

    /// Cache-only resolution — never searches (the serving hot path).
    pub fn lookup(&mut self, p: &GemmProblem) -> Option<TunedEntry> {
        let hit = self.cache.get(&self.key(p)).copied();
        if hit.is_some() {
            self.hits += 1;
        }
        hit
    }

    /// Resolve a shape to its tuned schedule: cache hit, or search + fill.
    pub fn resolve(&mut self, p: &GemmProblem) -> anyhow::Result<TunedEntry> {
        let key = self.key(p);
        if let Some(e) = self.cache.get(&key) {
            self.hits += 1;
            return Ok(*e);
        }
        let result = search::search(&self.machine, p)?;
        self.searches += 1;
        self.cache.insert(key, result.best);
        Ok(result.best)
    }

    /// Resolve a whole problem list at once, running the live searches on
    /// the worker pool (the tune-sweep seeding path).  Counter and cache
    /// semantics replicate a serial `resolve` loop exactly: duplicate
    /// shapes behind one miss count as hits, entries land in the cache in
    /// first-appearance order, and the first failing search (by input
    /// index) reports its error.
    pub fn resolve_many(&mut self, problems: &[GemmProblem]) -> anyhow::Result<Vec<TunedEntry>> {
        use std::collections::{HashMap, HashSet};
        use crate::util::pool;

        // Pass 1: classify in input order against the evolving key set —
        // exactly which problems a serial loop would have searched.
        let mut pending: HashSet<String> = HashSet::new();
        let mut misses: Vec<GemmProblem> = Vec::new();
        for p in problems {
            let key = self.key(p);
            if self.cache.get(&key).is_none() && pending.insert(key) {
                misses.push(*p);
            }
        }
        // Pass 2: the searches are independent pure functions of
        // (machine, problem) — fan them out.
        let machine = self.machine.clone();
        let searched = pool::par_map(&misses, |p| search::search(&machine, p));
        let mut found: HashMap<String, anyhow::Result<TunedEntry>> = HashMap::new();
        for (p, result) in misses.iter().zip(searched) {
            found.insert(self.key(p), result.map(|r| r.best));
        }
        // Pass 3: replay the serial loop's observable effects in order.
        let mut out = Vec::with_capacity(problems.len());
        for p in problems {
            let key = self.key(p);
            if let Some(e) = self.cache.get(&key) {
                self.hits += 1;
                out.push(*e);
                continue;
            }
            let best = found
                .remove(&key)
                .ok_or_else(|| anyhow::anyhow!("resolve_many missed key {key}"))??;
            self.searches += 1;
            self.cache.insert(key, best);
            out.push(best);
        }
        Ok(out)
    }

    /// Resolve a strategy selector: `Auto` goes through the cache/search,
    /// concrete strategies keep their heuristic tiling.
    pub fn resolve_strategy(
        &mut self,
        p: &GemmProblem,
        strategy: Strategy,
    ) -> anyhow::Result<(Strategy, kernels::tiling::Tiling)> {
        if strategy == Strategy::Auto {
            let e = self.resolve(p)?;
            Ok((e.strategy, e.tiling))
        } else {
            Ok((strategy, kernels::select_tiling(&self.machine, p, strategy)?))
        }
    }

    /// Build the tuned trace for a problem (resolving `Auto`).
    pub fn schedule(&mut self, p: &GemmProblem, strategy: Strategy) -> anyhow::Result<KernelTrace> {
        let (s, t) = self.resolve_strategy(p, strategy)?;
        kernels::schedule_with(&self.machine, p, s, &t)
    }

    /// Cache-only lookup of the co-schedule decision for one adjacent
    /// (producer, consumer) pair — the serving hot path (`Router::
    /// layer_plan`) never pays a merged-trace simulation.
    pub fn lookup_overlap(&mut self, producer: &GemmProblem, consumer: &GemmProblem) -> Option<f64> {
        let key = cache::pair_key(&self.machine, producer, consumer);
        let hit = self.cache.overlap_get(&key);
        if hit.is_some() {
            self.overlap_hits += 1;
        }
        hit
    }

    /// Resolve the co-schedule decision for one adjacent pair: cache hit,
    /// or splice the pair's tuned schedules, re-simulate the merged trace
    /// (DESIGN.md §12) and cache the exact gain.  A cached 0.0 means the
    /// pair is not spliceable (or the merge priced slower) — either way,
    /// resolving it again is a pure cache hit.
    pub fn resolve_overlap(
        &mut self,
        producer: &GemmProblem,
        consumer: &GemmProblem,
    ) -> anyhow::Result<f64> {
        let key = cache::pair_key(&self.machine, producer, consumer);
        if let Some(gain) = self.cache.overlap_get(&key) {
            self.overlap_hits += 1;
            return Ok(gain);
        }
        let pe = self.resolve(producer)?;
        let ce = self.resolve(consumer)?;
        let pt = kernels::schedule_with(&self.machine, producer, pe.strategy, &pe.tiling)?;
        let ct = kernels::schedule_with(&self.machine, consumer, ce.strategy, &ce.tiling)?;
        let sim = Simulator::new(self.machine.clone());
        // The tuned entries carry each schedule's simulated unit time, so
        // the sequential pair price is cache-exact.
        let gain = match coschedule::pair_decision(&sim, &pt, &ct, pe.total_ns + ce.total_ns)? {
            Some(d) => d.gain_ns,
            None => 0.0,
        };
        self.overlap_searches += 1;
        self.cache.overlap_insert(key, gain);
        Ok(gain)
    }

    /// The full cache key of a layer's residency plan: the shape chain
    /// ([`cache::layer_key`]) plus a fingerprint of every node's cached
    /// schedule *winner* — the plan was priced under those exact
    /// schedules, so a re-tuned winner (a search-space change, the PR-2
    /// precedent) invalidates it instead of serving a stale gain.
    /// `None` when any node's shape entry is missing from the cache.
    fn residency_key(&self, layer: &DecodeLayer) -> Option<String> {
        let mut key = cache::layer_key(&self.machine, layer);
        key.push('@');
        for node in layer.gemm_nodes() {
            if node.problem.validate().is_err() {
                continue;
            }
            let e = self.cache.get(&shape_key(&self.machine, &node.problem))?;
            let t = e.tiling;
            key.push_str(&format!(
                "{}:bm{}bn{}bk{}s{}c{}dk{}dn{};",
                e.strategy.name(),
                t.bm,
                t.bn,
                t.bk,
                t.splits,
                t.chunks,
                t.dequant_bk,
                t.dequant_bn
            ));
        }
        Some(key)
    }

    /// Cache-only lookup of the step-level residency plan for one decode
    /// layer's GEMM chain (DESIGN.md §13) — the serving hot path
    /// (`Router::layer_plan`) never pays a planning pass.  Misses when
    /// the plan was never seeded OR when any node's tuned winner changed
    /// since it was priced.
    pub fn lookup_residency(&mut self, layer: &DecodeLayer) -> Option<ResidencyEntry> {
        let key = self.residency_key(layer)?;
        let hit = self.cache.residency_get(&key);
        if hit.is_some() {
            self.residency_hits += 1;
        }
        hit
    }

    /// Resolve the step-level residency decision for one decode layer:
    /// cache hit, or run the planner over the layer's tuned GEMM chain
    /// (DESIGN.md §13) and cache what it buys.  A cached zero-gain entry
    /// means planning found nothing worth pinning — re-resolving it is a
    /// pure cache hit either way.
    pub fn resolve_residency(&mut self, layer: &DecodeLayer) -> anyhow::Result<ResidencyEntry> {
        let mut inputs = Vec::new();
        for node in layer.gemm_nodes() {
            if node.problem.validate().is_err() {
                continue;
            }
            let tuned = self.resolve(&node.problem)?;
            let trace = kernels::schedule_with(
                &self.machine,
                &node.problem,
                tuned.strategy,
                &tuned.tiling,
            )?;
            inputs.push(residency::PlanNodeInput {
                kind: node.kind,
                problem: node.problem,
                count: node.count.max(1),
                unit_ns: tuned.total_ns,
                trace,
            });
        }
        // Every shape entry resolved above, so the winner-fingerprinted
        // key always exists here.
        let key = self
            .residency_key(layer)
            .ok_or_else(|| anyhow::anyhow!("residency key missing after resolving all nodes"))?;
        if let Some(e) = self.cache.residency_get(&key) {
            self.residency_hits += 1;
            return Ok(e);
        }
        let plan = residency::plan_nodes(&self.machine, &inputs, 0.0, true)?;
        let entry = ResidencyEntry { gain_ns: plan.gain_ns(), pinned_bytes: plan.pinned_bytes };
        self.residency_searches += 1;
        self.cache.residency_insert(key, entry);
        Ok(entry)
    }

    /// Persist the cache to its load path (no-op destination error if the
    /// tuner was created in-memory).
    pub fn save(&self) -> anyhow::Result<()> {
        let path = self
            .path
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("in-memory tuner has no cache path"))?;
        self.cache.save(path)
    }

    /// Persist the cache to an explicit path.
    pub fn save_to(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        self.cache.save(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;

    fn machine() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn resolve_searches_once_then_hits() {
        let mut tuner = Tuner::new(machine());
        let p = GemmProblem::new(8, 512, 16384);
        let a = tuner.resolve(&p).unwrap();
        assert_eq!((tuner.searches, tuner.hits), (1, 0));
        let b = tuner.resolve(&p).unwrap();
        assert_eq!((tuner.searches, tuner.hits), (1, 1));
        assert_eq!(a, b);
        // Padded-M aliasing: batch 3 resolves to the same entry, no search.
        let c = tuner.resolve(&GemmProblem::new(3, 512, 16384)).unwrap();
        assert_eq!((tuner.searches, tuner.hits), (1, 2));
        assert_eq!(a, c);
    }

    #[test]
    fn persisted_cache_resolves_without_search() {
        let dir = std::env::temp_dir().join(format!("w4a16-tuner-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(DEFAULT_CACHE_FILE);
        let p = GemmProblem::new(8, 512, 16384);

        let mut warm = Tuner::load(machine(), &path).unwrap();
        warm.resolve(&p).unwrap();
        warm.save().unwrap();

        let mut cold = Tuner::load(machine(), &path).unwrap();
        let e = cold.resolve(&p).unwrap();
        assert_eq!(cold.searches, 0, "persisted winner must be reused");
        assert_eq!(cold.hits, 1);
        assert!(e.total_ns > 0.0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn auto_schedules_through_the_tuner() {
        let mut tuner = Tuner::new(machine());
        let p = GemmProblem::new(8, 512, 16384);
        let trace = tuner.schedule(&p, Strategy::Auto).unwrap();
        let r = Simulator::new(machine()).run(&trace).unwrap();
        assert!(r.total_ns > 0.0);
        // The tuned schedule can never lose to the heuristic splitk pick.
        let sk = Simulator::new(machine())
            .run(&kernels::schedule(&machine(), &p, Strategy::SplitK).unwrap())
            .unwrap();
        assert!(r.total_ns <= sk.total_ns * 1.000001);
    }

    #[test]
    fn overlap_resolves_once_then_hits_and_persists() {
        let dir = std::env::temp_dir().join(format!("w4a16-overlap-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(DEFAULT_CACHE_FILE);
        let prod = GemmProblem::new(8, 512, 16384);
        let cons = GemmProblem::new(8, 2048, 8192);

        let mut warm = Tuner::load(machine(), &path).unwrap();
        assert_eq!(warm.lookup_overlap(&prod, &cons), None, "cold cache");
        let gain = warm.resolve_overlap(&prod, &cons).unwrap();
        assert_eq!(warm.overlap_searches, 1);
        assert!(gain >= 0.0 && gain.is_finite());
        let again = warm.resolve_overlap(&prod, &cons).unwrap();
        assert_eq!(warm.overlap_searches, 1, "second resolve must hit");
        assert_eq!(again, gain);
        warm.save().unwrap();

        // A fresh tuner serves the pair cache-only (the router hot path).
        let mut cold = Tuner::load(machine(), &path).unwrap();
        assert_eq!(cold.lookup_overlap(&prod, &cons), Some(gain));
        assert_eq!((cold.overlap_hits, cold.overlap_searches), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn residency_resolves_once_then_hits_and_persists() {
        use crate::model::llm::layer_geometry;
        let dir = std::env::temp_dir().join(format!("w4a16-residency-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(DEFAULT_CACHE_FILE);
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);

        let mut warm = Tuner::load(machine(), &path).unwrap();
        assert_eq!(warm.lookup_residency(&layer), None, "cold cache");
        let e = warm.resolve_residency(&layer).unwrap();
        assert_eq!(warm.residency_searches, 1);
        assert!(e.gain_ns >= 0.0 && e.gain_ns.is_finite());
        assert!(e.pinned_bytes as f64 <= machine().l2_retention * machine().l2_bytes as f64);
        let again = warm.resolve_residency(&layer).unwrap();
        assert_eq!(warm.residency_searches, 1, "second resolve must hit");
        assert_eq!(again, e);
        warm.save().unwrap();

        // A fresh tuner serves the plan cache-only (the router hot path).
        let mut cold = Tuner::load(machine(), &path).unwrap();
        assert_eq!(cold.lookup_residency(&layer), Some(e));
        assert_eq!((cold.residency_hits, cold.residency_searches), (1, 0));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn residency_plan_invalidates_when_a_tuned_winner_changes() {
        use crate::model::llm::layer_geometry;
        let mut tuner = Tuner::new(machine());
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        tuner.resolve_residency(&layer).unwrap();
        assert!(tuner.lookup_residency(&layer).is_some());
        // Re-tune one node to a different winner (the search-space-change
        // scenario): the plan was priced under the old schedule, so it
        // must MISS, not serve a stale gain.
        let down = layer.problem(crate::workload::decode_layer::GemmKind::Down);
        let key = tuner.key(&down);
        let old = *tuner.cache.get(&key).unwrap();
        let flipped = TunedEntry {
            strategy: if old.strategy == Strategy::SplitK {
                Strategy::Chunked
            } else {
                Strategy::SplitK
            },
            ..old
        };
        tuner.cache.insert(key, flipped);
        assert_eq!(tuner.lookup_residency(&layer), None, "stale plan must not serve");
    }

    #[test]
    fn resolve_many_matches_a_serial_resolve_loop() {
        let problems = vec![
            GemmProblem::new(8, 512, 16384),
            GemmProblem::new(8, 2048, 8192),
            // Padded-M alias of the first shape: a serial loop counts it
            // as a hit (the first resolve already filled the cache).
            GemmProblem::new(3, 512, 16384),
            GemmProblem::new(8, 512, 16384),
        ];
        let mut serial = Tuner::new(machine());
        let expected: Vec<TunedEntry> =
            problems.iter().map(|p| serial.resolve(p).unwrap()).collect();

        let mut pooled = Tuner::new(machine());
        let got = pooled.resolve_many(&problems).unwrap();
        assert_eq!(got, expected);
        assert_eq!((pooled.hits, pooled.searches), (serial.hits, serial.searches));
        assert_eq!((pooled.hits, pooled.searches), (2, 2));

        // A warm cache serves everything without a search.
        let again = pooled.resolve_many(&problems).unwrap();
        assert_eq!(again, expected);
        assert_eq!(pooled.searches, 2);
        assert_eq!(pooled.hits, 2 + problems.len());
    }

    #[test]
    fn concrete_strategy_passes_through() {
        let mut tuner = Tuner::new(machine());
        let p = GemmProblem::new(8, 512, 16384);
        let (s, _) = tuner.resolve_strategy(&p, Strategy::DataParallel).unwrap();
        assert_eq!(s, Strategy::DataParallel);
        assert_eq!(tuner.searches, 0);
    }
}
