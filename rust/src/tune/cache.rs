//! Persistent tune cache: maps `(machine, M_pad, N, K, group)` shapes to
//! their winning (strategy, tiling) schedule, as found by [`super::search`].
//!
//! The on-disk format is a single JSON document (`util::json`-based, no
//! external serializer), format v2 (DESIGN.md §13) — v1 documents (no
//! `"overlaps"` / `"residency"` maps) still parse, with those maps empty:
//!
//! ```json
//! {
//!   "version": 2,
//!   "entries": {
//!     "aic32_l233554432_hbm1200/m16_n512_k16384_g128": {
//!       "strategy": "chunked",
//!       "total_ns": 28514.2,
//!       "tiling": {"bm":16,"bn":256,"bk":128,"splits":16,"chunks":1,
//!                  "dequant_bk":128,"dequant_bn":256}
//!     }
//!   },
//!   "overlaps": {"<pair_key>": 2345.5},
//!   "residency": {"<layer_key>": {"gain_ns": 5120.0, "pinned_bytes": 9961472}}
//! }
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use crate::ascend::MachineConfig;
use crate::kernels::tiling::Tiling;
use crate::kernels::{GemmProblem, Strategy};
use crate::util::json::Json;
use crate::workload::decode_layer::DecodeLayer;

/// One cached winner.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedEntry {
    pub strategy: Strategy,
    pub tiling: Tiling,
    /// Simulated execution time of the winner (for reporting / staleness).
    pub total_ns: f64,
}

/// A machine tag that keys the cache to the architecture it was tuned on:
/// winners are invalid once core counts, L2 capacity or HBM bandwidth move.
pub fn machine_tag(machine: &MachineConfig) -> String {
    format!(
        "aic{}_l2{}_hbm{}",
        machine.ai_cores, machine.l2_bytes, machine.hbm_bw as u64
    )
}

/// Key suffix carrying the precision tag.  W4A16 — the only family member
/// before the precision axis opened — keeps the bare key, so every
/// pre-existing cache file parses AND routes without retuning; W4A8
/// entries are disjoint by construction (DESIGN.md §16).
fn precision_suffix(p: &GemmProblem) -> &'static str {
    match p.precision {
        crate::model::Precision::W4A16 => "",
        crate::model::Precision::W4A8 => "_a8",
    }
}

/// Cache key for one problem on one machine.  M is padded to the cube tile
/// so every decode batch in 1..=16 shares one entry, as the hardware does.
pub fn shape_key(machine: &MachineConfig, p: &GemmProblem) -> String {
    format!(
        "{}/m{}_n{}_k{}_g{}{}",
        machine_tag(machine),
        p.m_padded(machine),
        p.n,
        p.k,
        p.group,
        precision_suffix(p)
    )
}

/// Cache key for one adjacent (producer reduce -> consumer dequant) pair:
/// the co-scheduler's exact gain is a function of both tuned schedules,
/// which the shape keys determine on a given machine (DESIGN.md §12).
pub fn pair_key(machine: &MachineConfig, producer: &GemmProblem, consumer: &GemmProblem) -> String {
    format!(
        "{}->m{}_n{}_k{}_g{}{}",
        shape_key(machine, producer),
        consumer.m_padded(machine),
        consumer.n,
        consumer.k,
        consumer.group,
        precision_suffix(consumer)
    )
}

/// Cache key for one decode layer's step-level weight-residency plan
/// (DESIGN.md §13): the plan is a function of the layer's whole GEMM
/// chain on one machine, so the key concatenates every node's padded
/// shape (and expert fan-out) in issue order.
pub fn layer_key(machine: &MachineConfig, layer: &DecodeLayer) -> String {
    let nodes: Vec<String> = layer
        .gemm_nodes()
        .iter()
        .map(|n| {
            format!(
                "{}x{}:m{}_n{}_k{}_g{}{}",
                n.kind.name(),
                n.count,
                n.problem.m_padded(machine),
                n.problem.n,
                n.problem.k,
                n.problem.group,
                precision_suffix(&n.problem)
            )
        })
        .collect();
    format!("{}/layer[{}]", machine_tag(machine), nodes.join(","))
}

/// One cached step-level residency decision: what the plan buys and how
/// many weight bytes it holds resident (0/0 = planning found nothing
/// worth pinning — still a pure cache hit on re-resolve).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ResidencyEntry {
    pub gain_ns: f64,
    pub pinned_bytes: u64,
}

/// The cache proper: per-shape schedule winners plus per-adjacent-pair
/// co-schedule decisions (the exact overlap gain in ns per pair; 0.0 means
/// the co-scheduler declined to merge that pair) plus per-layer
/// step-level residency decisions.
#[derive(Debug, Clone, Default)]
pub struct TuneCache {
    entries: BTreeMap<String, TunedEntry>,
    overlaps: BTreeMap<String, f64>,
    residency: BTreeMap<String, ResidencyEntry>,
}

impl TuneCache {
    pub fn new() -> TuneCache {
        TuneCache::default()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn get(&self, key: &str) -> Option<&TunedEntry> {
        self.entries.get(key)
    }

    pub fn insert(&mut self, key: String, entry: TunedEntry) {
        self.entries.insert(key, entry);
    }

    pub fn iter(&self) -> impl Iterator<Item = (&String, &TunedEntry)> {
        self.entries.iter()
    }

    // ----- co-schedule pair decisions --------------------------------------

    pub fn overlap_get(&self, key: &str) -> Option<f64> {
        self.overlaps.get(key).copied()
    }

    pub fn overlap_insert(&mut self, key: String, gain_ns: f64) {
        self.overlaps.insert(key, gain_ns);
    }

    pub fn overlap_len(&self) -> usize {
        self.overlaps.len()
    }

    // ----- step-level residency decisions ----------------------------------

    pub fn residency_get(&self, key: &str) -> Option<ResidencyEntry> {
        self.residency.get(key).copied()
    }

    pub fn residency_insert(&mut self, key: String, entry: ResidencyEntry) {
        self.residency.insert(key, entry);
    }

    pub fn residency_len(&self) -> usize {
        self.residency.len()
    }

    // ----- staleness --------------------------------------------------------

    /// Whether any entry (shape winner, pair decision, or residency plan)
    /// was tuned under machine tag `tag`.  A non-empty cache with no
    /// matching tag is *stale* — tuned on different hardware — and the
    /// router's degradation ladder treats it like a miss (DESIGN.md §14).
    pub fn has_tag(&self, tag: &str) -> bool {
        let prefix = format!("{tag}/");
        self.entries.keys().any(|k| k.starts_with(&prefix))
            || self.overlaps.keys().any(|k| k.starts_with(&prefix))
            || self.residency.keys().any(|k| k.starts_with(&prefix))
    }

    /// Total decisions across all three maps (staleness reporting).
    pub fn total_len(&self) -> usize {
        self.entries.len() + self.overlaps.len() + self.residency.len()
    }

    /// Drop every entry (shape winners, pair decisions, residency plans)
    /// whose machine tag no longer matches `tag` — the `repro tune
    /// --prune` eviction policy.  The machine-tag key already guarantees
    /// stale entries are never *served*; pruning reclaims the file.
    /// Returns how many entries were removed.
    pub fn prune_mismatched(&mut self, tag: &str) -> usize {
        let prefix = format!("{tag}/");
        let before = self.entries.len() + self.overlaps.len() + self.residency.len();
        self.entries.retain(|k, _| k.starts_with(&prefix));
        self.overlaps.retain(|k, _| k.starts_with(&prefix));
        self.residency.retain(|k, _| k.starts_with(&prefix));
        before - (self.entries.len() + self.overlaps.len() + self.residency.len())
    }

    // ----- persistence ------------------------------------------------------

    pub fn to_json(&self) -> Json {
        let entries = self
            .entries
            .iter()
            .map(|(k, e)| (k.clone(), entry_to_json(e)))
            .collect();
        let overlaps = self
            .overlaps
            .iter()
            .map(|(k, &gain)| (k.clone(), Json::num(gain)))
            .collect();
        let residency = self
            .residency
            .iter()
            .map(|(k, e)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("gain_ns", Json::num(e.gain_ns)),
                        ("pinned_bytes", Json::num(e.pinned_bytes as f64)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(2.0)),
            ("entries", Json::Obj(entries)),
            ("overlaps", Json::Obj(overlaps)),
            ("residency", Json::Obj(residency)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<TuneCache> {
        let version = j.req_usize("version")?;
        anyhow::ensure!(
            version == 1 || version == 2,
            "unsupported tune cache version {version}"
        );
        let mut cache = TuneCache::new();
        let entries = j
            .req("entries")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("'entries' is not an object"))?;
        for (key, e) in entries {
            cache.insert(key.clone(), entry_from_json(e)?);
        }
        // Pre-PR-4 caches have no pair decisions: absent = empty (the
        // shape entries stay valid; pairs re-resolve on demand).
        if let Some(overlaps) = j.get("overlaps").and_then(|o| o.as_obj()) {
            for (key, gain) in overlaps {
                let gain = gain
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("overlap '{key}' is not a number"))?;
                cache.overlap_insert(key.clone(), gain);
            }
        }
        // Pre-PR-5 caches have no residency plans: absent = empty.
        if let Some(residency) = j.get("residency").and_then(|o| o.as_obj()) {
            for (key, e) in residency {
                let gain_ns = e
                    .req("gain_ns")?
                    .as_f64()
                    .ok_or_else(|| anyhow::anyhow!("residency '{key}' gain is not a number"))?;
                let pinned_bytes = e.req_usize("pinned_bytes")? as u64;
                cache.residency_insert(key.clone(), ResidencyEntry { gain_ns, pinned_bytes });
            }
        }
        Ok(cache)
    }

    /// Load from a file; a missing file is an empty cache (first run).
    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<TuneCache> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(TuneCache::new());
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        let j = Json::parse(&text)?;
        Self::from_json(&j)
            .map_err(|e| anyhow::anyhow!("parsing tune cache {}: {e}", path.display()))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> anyhow::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.to_json().to_string())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", path.display()))
    }
}

fn entry_to_json(e: &TunedEntry) -> Json {
    Json::obj(vec![
        ("strategy", Json::str(e.strategy.name())),
        ("total_ns", Json::num(e.total_ns)),
        (
            "tiling",
            Json::obj(vec![
                ("bm", Json::num(e.tiling.bm as f64)),
                ("bn", Json::num(e.tiling.bn as f64)),
                ("bk", Json::num(e.tiling.bk as f64)),
                ("splits", Json::num(e.tiling.splits as f64)),
                ("chunks", Json::num(e.tiling.chunks as f64)),
                ("dequant_bk", Json::num(e.tiling.dequant_bk as f64)),
                ("dequant_bn", Json::num(e.tiling.dequant_bn as f64)),
                ("rebalance", Json::num(e.tiling.rebalance as f64)),
            ]),
        ),
    ])
}

fn entry_from_json(j: &Json) -> anyhow::Result<TunedEntry> {
    let t = j.req("tiling")?;
    Ok(TunedEntry {
        strategy: Strategy::from_name(j.req_str("strategy")?)?,
        total_ns: j
            .req("total_ns")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("total_ns is not a number"))?,
        tiling: Tiling {
            bm: t.req_usize("bm")?,
            bn: t.req_usize("bn")?,
            bk: t.req_usize("bk")?,
            splits: t.req_usize("splits")?,
            chunks: t.req_usize("chunks")?,
            dequant_bk: t.req_usize("dequant_bk")?,
            dequant_bn: t.req_usize("dequant_bn")?,
            // Pre-W4A8 cache files carry no rebalance knob: absent = 0
            // (scales applied in the prologue), so stale W4A16 caches
            // parse and route unchanged.
            rebalance: t
                .get("rebalance")
                .and_then(|v| v.as_f64())
                .map(|v| v as usize)
                .unwrap_or(0),
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry() -> TunedEntry {
        TunedEntry {
            strategy: Strategy::Chunked,
            total_ns: 1234.5,
            tiling: Tiling {
                bm: 16,
                bn: 256,
                bk: 128,
                splits: 4,
                chunks: 8,
                dequant_bk: 128,
                dequant_bn: 256,
                rebalance: 0,
            },
        }
    }

    #[test]
    fn json_round_trips_entries() {
        let mut c = TuneCache::new();
        c.insert("k1".into(), entry());
        c.overlap_insert("k1->m16_n512_k16384_g128".into(), 2345.5);
        c.overlap_insert("declined".into(), 0.0);
        c.residency_insert(
            "tag/layer[down x1:m16_n2048_k8192_g128]".into(),
            ResidencyEntry { gain_ns: 5120.0, pinned_bytes: 9 << 20 },
        );
        let j = c.to_json();
        let back = TuneCache::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.get("k1").copied().unwrap(), entry());
        assert_eq!(back.overlap_len(), 2);
        assert_eq!(back.overlap_get("k1->m16_n512_k16384_g128"), Some(2345.5));
        assert_eq!(back.overlap_get("declined"), Some(0.0));
        assert_eq!(back.overlap_get("missing"), None);
        assert_eq!(back.residency_len(), 1);
        assert_eq!(
            back.residency_get("tag/layer[down x1:m16_n2048_k8192_g128]"),
            Some(ResidencyEntry { gain_ns: 5120.0, pinned_bytes: 9 << 20 })
        );
        assert_eq!(back.residency_get("missing"), None);
    }

    #[test]
    fn caches_without_overlaps_still_parse() {
        // Pre-co-scheduler cache files carry no "overlaps" key.
        let j = Json::parse(r#"{"version": 1, "entries": {}}"#).unwrap();
        let c = TuneCache::from_json(&j).unwrap();
        assert_eq!(c.overlap_len(), 0);
        assert_eq!(c.residency_len(), 0);
    }

    #[test]
    fn v1_caches_without_residency_still_parse() {
        // Pre-PR-5 caches carry overlaps but no "residency" map.
        let j = Json::parse(r#"{"version": 1, "entries": {}, "overlaps": {"a": 1.5}}"#).unwrap();
        let c = TuneCache::from_json(&j).unwrap();
        assert_eq!(c.overlap_get("a"), Some(1.5));
        assert_eq!(c.residency_len(), 0);
    }

    #[test]
    fn layer_key_is_machine_and_chain_specific() {
        use crate::model::llm::{layer_geometry, moe_geometry};
        let m = MachineConfig::ascend910();
        let dense = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let key = layer_key(&m, &dense);
        assert!(key.starts_with(&format!("{}/layer[", machine_tag(&m))));
        assert!(key.contains("qkv") && key.contains("down"));
        // Padded-M aliasing: batches below the cube tile share a plan.
        let small = DecodeLayer::new(layer_geometry("llama32").unwrap(), 3);
        assert_eq!(key, layer_key(&m, &small));
        // A different chain (MoE fan-out) gets a different key.
        let moe = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        assert_ne!(key, layer_key(&m, &moe));
        assert!(layer_key(&m, &moe).contains("moe_expertx64"));
    }

    #[test]
    fn has_tag_detects_stale_caches_across_all_maps() {
        let m = MachineConfig::ascend910();
        let tag = machine_tag(&m);
        let mut c = TuneCache::new();
        assert!(!c.has_tag(&tag), "empty cache has no tags");
        c.insert("aic16_l216777216_hbm600/m16_n512_k16384_g128".into(), entry());
        assert!(!c.has_tag(&tag), "foreign-tag cache is stale for this machine");
        assert!(c.has_tag("aic16_l216777216_hbm600"));
        assert_eq!(c.total_len(), 1);
        // A matching overlap decision alone also counts as current.
        c.overlap_insert(format!("{tag}/a->b"), 1.0);
        assert!(c.has_tag(&tag));
        assert_eq!(c.total_len(), 2);
    }

    #[test]
    fn prune_drops_only_mismatched_machine_tags() {
        let m = MachineConfig::ascend910();
        let tag = machine_tag(&m);
        let mut c = TuneCache::new();
        c.insert(format!("{tag}/m16_n512_k16384_g128"), entry());
        c.insert("aic16_l216777216_hbm600/m16_n512_k16384_g128".into(), entry());
        c.overlap_insert(format!("{tag}/m16_n512_k16384_g128->m16_n2048_k8192_g128"), 1.0);
        c.overlap_insert("aic16_l216777216_hbm600/stale->pair".into(), 2.0);
        c.residency_insert(
            format!("{tag}/layer[downx1:m16_n2048_k8192_g128]"),
            ResidencyEntry::default(),
        );
        c.residency_insert(
            "aic16_l216777216_hbm600/layer[stale]".into(),
            ResidencyEntry::default(),
        );
        let removed = c.prune_mismatched(&tag);
        assert_eq!(removed, 3);
        assert_eq!(c.len(), 1);
        assert_eq!(c.overlap_len(), 1);
        assert_eq!(c.residency_len(), 1);
        assert!(c.get(&format!("{tag}/m16_n512_k16384_g128")).is_some());
        // Idempotent: a second prune removes nothing.
        assert_eq!(c.prune_mismatched(&tag), 0);
    }

    #[test]
    fn pair_key_pads_both_sides_and_orders() {
        let m = MachineConfig::ascend910();
        let a = GemmProblem::new(3, 512, 16384);
        let b = GemmProblem::new(16, 2048, 7168);
        let ab = pair_key(&m, &a, &b);
        // Padded-M aliasing applies to both sides.
        assert_eq!(ab, pair_key(&m, &GemmProblem::new(16, 512, 16384), &b));
        // Direction matters: a->b is not b->a.
        assert_ne!(ab, pair_key(&m, &b, &a));
    }

    #[test]
    fn w4a8_shape_keys_are_tagged_and_disjoint() {
        use crate::model::Precision;
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 512, 16384);
        let a16 = shape_key(&m, &p);
        let a8 = shape_key(&m, &p.with_precision(Precision::W4A8));
        assert!(!a16.ends_with("_a8"), "W4A16 keeps the legacy untagged key");
        assert!(a8.ends_with("_a8"));
        assert_ne!(a16, a8);
        // Pair keys tag both endpoints independently.
        let q = GemmProblem::new(8, 2048, 8192).with_precision(Precision::W4A8);
        assert!(pair_key(&m, &p, &q).ends_with("_a8"));
        assert!(!pair_key(&m, &q, &p).ends_with("_a8"));
    }

    #[test]
    fn tilings_without_rebalance_parse_as_zero() {
        // Pre-W4A8 cache entries carry 7-field tilings; they must load
        // (and route) rather than abort the whole cache.
        let j = Json::parse(
            r#"{"version": 2, "entries": {"k": {
                "strategy": "splitk", "total_ns": 10.0,
                "tiling": {"bm":16,"bn":256,"bk":128,"splits":4,"chunks":1,
                           "dequant_bk":128,"dequant_bn":256}}}}"#,
        )
        .unwrap();
        let c = TuneCache::from_json(&j).unwrap();
        assert_eq!(c.get("k").unwrap().tiling.rebalance, 0);
        // And the current writer round-trips a non-zero knob.
        let mut tagged = entry();
        tagged.tiling.rebalance = 50;
        let mut c2 = TuneCache::new();
        c2.insert("w".into(), tagged);
        let back = TuneCache::from_json(&Json::parse(&c2.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.get("w").unwrap().tiling.rebalance, 50);
    }

    #[test]
    fn shape_key_pads_m_to_cube_tile() {
        let m = MachineConfig::ascend910();
        let a = shape_key(&m, &GemmProblem::new(3, 512, 16384));
        let b = shape_key(&m, &GemmProblem::new(16, 512, 16384));
        assert_eq!(a, b, "batches below the cube tile share one schedule");
        let c = shape_key(&m, &GemmProblem::new(17, 512, 16384));
        assert_ne!(a, c);
    }

    #[test]
    fn load_missing_file_is_empty() {
        let c = TuneCache::load("/nonexistent/tune_cache.json").unwrap();
        assert!(c.is_empty());
    }

    #[test]
    fn save_load_round_trip() {
        let dir = std::env::temp_dir().join(format!("w4a16-tune-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune_cache.json");
        let mut c = TuneCache::new();
        c.insert("a/b".into(), entry());
        c.save(&path).unwrap();
        let back = TuneCache::load(&path).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(back.get("a/b").unwrap().strategy, Strategy::Chunked);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rejects_unknown_version() {
        let j = Json::parse(r#"{"version": 9, "entries": {}}"#).unwrap();
        assert!(TuneCache::from_json(&j).is_err());
    }
}
