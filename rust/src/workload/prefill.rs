//! Prefill step graph: the large-M chunk a serving scheduler runs to
//! ingest prompt tokens, built from the same [`DecodeLayer`] GEMM chain
//! and vector-pass vocabulary as the decode step (DESIGN.md §15).
//!
//! Where a decode step is M=batch rows each attending a full `kv_len`
//! cache, a prefill chunk is `m` *consecutive positions of one sequence*
//! with causal attention: row `i` (at absolute position `kv_base + i`)
//! attends the `kv_base + i + 1` keys at or before it.  The score/AV
//! passes are therefore sized by the exact causal context
//!
//! ```text
//! ctx(m, kv_base) = m * kv_base + m * (m + 1) / 2
//! ```
//!
//! — integer math, so the golden fixtures and the Python mirrors
//! reproduce it bit-for-bit.  The projection GEMMs are the decode
//! problems at M = m: exactly the "large-M variant" the paper's K >> N
//! analysis says shifts shapes back toward the compute-bound regime, and
//! why prefill chunks route through the same tune cache as decode.

use crate::model::llm::LayerGeometry;
use crate::workload::decode_layer::{DecodeLayer, GemmNode, StepNode, VectorOp, VectorOpKind};

/// One causal prefill chunk of a decoder layer: `layer.batch` prompt
/// tokens entering at absolute positions `[kv_base, kv_base + m)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefillStep {
    /// Layer graph with `batch` = the chunk's token count `m`.
    pub layer: DecodeLayer,
    /// KV-cache tokens already resident before this chunk.
    pub kv_base: usize,
    /// Attention head count (scores are priced per head).
    pub heads: usize,
}

impl PrefillStep {
    pub fn new(layer: DecodeLayer, kv_base: usize, heads: usize) -> PrefillStep {
        PrefillStep { layer, kv_base, heads: heads.max(1) }
    }

    /// Default head count for a geometry (same rule as decode).
    pub fn default_heads(geometry: &LayerGeometry) -> usize {
        (geometry.hidden / 128).max(1)
    }

    /// Chunk token count `m`.
    pub fn chunk_tokens(&self) -> usize {
        self.layer.batch
    }

    /// KV-cache length after the chunk lands.
    pub fn kv_end(&self) -> usize {
        self.kv_base + self.layer.batch
    }

    /// Exact causal context: total (query, key) pairs the chunk attends.
    pub fn causal_ctx(&self) -> u64 {
        let m = self.layer.batch as u64;
        m * self.kv_base as u64 + m * (m + 1) / 2
    }

    /// All step nodes in issue order — the decode-step graph shape with
    /// the attention passes resized to the causal context.
    pub fn nodes(&self) -> Vec<StepNode> {
        let g = self.layer.geometry;
        let m = self.layer.batch as u64;
        let h = g.hidden as u64;
        let kvw = g.kv as u64;
        let heads = self.heads as u64;
        let head_dim = g.hidden as f64 / self.heads as f64;
        let ctx = self.causal_ctx();
        let scores = heads * ctx;

        let norm = StepNode::Vector(VectorOp {
            kind: VectorOpKind::RmsNorm,
            elems: m * h,
            ops_per_elem: 6.0,
            hbm_bytes: 0,
            l2_bytes: 2 * m * h * 2,
        });
        let residual = StepNode::Vector(VectorOp {
            kind: VectorOpKind::Residual,
            elems: m * h,
            ops_per_elem: 1.0,
            hbm_bytes: 0,
            l2_bytes: 3 * m * h * 2,
        });
        let gemm = |node: GemmNode| StepNode::Gemm(node);
        let dense = |kind| GemmNode { kind, problem: self.layer.problem(kind), count: 1 };

        use crate::workload::decode_layer::GemmKind;
        let mut nodes = vec![
            norm,
            gemm(dense(GemmKind::Qkv)),
            // Causal Q · Kᵀ: row i reads the kv_base + i + 1 keys at or
            // before it, so the cold K read and the score count are both
            // `ctx` rows, not m * kv_len.
            StepNode::Vector(VectorOp {
                kind: VectorOpKind::AttnScore,
                elems: scores,
                ops_per_elem: 2.0 * head_dim,
                hbm_bytes: ctx * kvw * 2,
                l2_bytes: m * h * 2 + scores * 2,
            }),
            StepNode::Vector(VectorOp {
                kind: VectorOpKind::AttnSoftmax,
                elems: scores,
                ops_per_elem: 8.0,
                hbm_bytes: 0,
                l2_bytes: 2 * scores * 2,
            }),
            StepNode::Vector(VectorOp {
                kind: VectorOpKind::AttnAv,
                elems: scores,
                ops_per_elem: 2.0 * head_dim,
                hbm_bytes: ctx * kvw * 2,
                l2_bytes: scores * 2 + m * h * 2,
            }),
            gemm(dense(GemmKind::AttnOut)),
            residual,
            norm,
        ];

        match self.layer.moe_nodes() {
            None => {
                let ffn = g.ffn as u64;
                nodes.push(gemm(dense(GemmKind::UpGate)));
                nodes.push(StepNode::Vector(VectorOp {
                    kind: VectorOpKind::Activation,
                    elems: m * ffn,
                    ops_per_elem: 4.0,
                    hbm_bytes: 0,
                    l2_bytes: 3 * m * ffn * 2,
                }));
                nodes.push(gemm(dense(GemmKind::Down)));
            }
            Some([up, down]) => {
                let moe = self.layer.moe.unwrap();
                let experts = moe.experts as u64;
                nodes.push(StepNode::Vector(VectorOp {
                    kind: VectorOpKind::MoeRoute,
                    elems: m * experts,
                    ops_per_elem: 2.0 * g.hidden as f64 + 8.0,
                    hbm_bytes: h * experts * 2,
                    l2_bytes: m * h * 2 + m * experts * 2,
                }));
                nodes.push(gemm(up));
                let routed = (up.count * up.problem.m) as u64;
                let ef = moe.expert_ffn as u64;
                nodes.push(StepNode::Vector(VectorOp {
                    kind: VectorOpKind::Activation,
                    elems: routed * ef,
                    ops_per_elem: 4.0,
                    hbm_bytes: 0,
                    l2_bytes: 3 * routed * ef * 2,
                }));
                nodes.push(gemm(down));
            }
        }
        nodes.push(residual);
        nodes
    }

    /// The GEMM sub-chain of the chunk, in issue order.
    pub fn gemm_nodes(&self) -> Vec<GemmNode> {
        self.layer.gemm_nodes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::{layer_geometry, moe_geometry};
    use crate::workload::decode_layer::{DecodeStep, GemmKind};

    #[test]
    fn causal_ctx_is_exact() {
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 4);
        // m=4 at kv_base=10: rows attend 11 + 12 + 13 + 14 = 50 keys.
        let step = PrefillStep::new(layer, 10, 16);
        assert_eq!(step.causal_ctx(), 50);
        assert_eq!(step.kv_end(), 14);
        // First chunk (kv_base = 0): pure triangle m(m+1)/2.
        assert_eq!(PrefillStep::new(layer, 0, 16).causal_ctx(), 10);
    }

    #[test]
    fn graph_shape_matches_decode_with_causal_attention() {
        let geometry = layer_geometry("llama32").unwrap();
        let m = 512;
        let heads = PrefillStep::default_heads(&geometry);
        let prefill = PrefillStep::new(DecodeLayer::new(geometry, m), 0, heads);
        let decode = DecodeStep::new(DecodeLayer::new(geometry, m), 1, heads);
        let names = |nodes: &[StepNode]| -> Vec<&str> {
            nodes
                .iter()
                .map(|n| match n {
                    StepNode::Gemm(g) => g.kind.name(),
                    StepNode::Vector(v) => v.kind.name(),
                })
                .collect()
        };
        assert_eq!(names(&prefill.nodes()), names(&decode.nodes()));
        // The projection GEMMs are the decode problems at M = m.
        for (p, d) in prefill.gemm_nodes().iter().zip(decode.gemm_nodes()) {
            assert_eq!(p.problem, d.problem);
        }
        assert_eq!(prefill.gemm_nodes()[0].problem.m, m);
    }

    #[test]
    fn attention_traffic_uses_the_causal_context() {
        let geometry = layer_geometry("llama32").unwrap();
        let step = PrefillStep::new(DecodeLayer::new(geometry, 512), 0, 16);
        let ctx = step.causal_ctx();
        assert_eq!(ctx, 512 * 513 / 2);
        let score = step
            .nodes()
            .into_iter()
            .find_map(|n| match n {
                StepNode::Vector(v) if v.kind == VectorOpKind::AttnScore => Some(v),
                _ => None,
            })
            .unwrap();
        assert_eq!(score.elems, 16 * ctx);
        assert_eq!(score.hbm_bytes, ctx * geometry.kv as u64 * 2);
        // A later chunk of the same sequence attends strictly more.
        let later = PrefillStep::new(DecodeLayer::new(geometry, 512), 1024, 16);
        assert!(later.causal_ctx() > ctx);
    }

    #[test]
    fn moe_prefill_routes_all_chunk_tokens() {
        let geom = layer_geometry("deepseek-moe").unwrap();
        let moe = moe_geometry("deepseek-moe").unwrap();
        let step = PrefillStep::new(DecodeLayer::new(geom, 256).with_moe(moe), 0, 56);
        let kinds: Vec<&str> = step
            .nodes()
            .iter()
            .map(|n| match n {
                StepNode::Gemm(g) => g.kind.name(),
                StepNode::Vector(v) => v.kind.name(),
            })
            .collect();
        assert!(kinds.contains(&"moe_route"));
        let experts = step.gemm_nodes().iter().filter(|n| n.kind == GemmKind::MoeExpert).count();
        assert_eq!(experts, 2);
        // 256 tokens top-8 saturate all 256 experts with 8 tokens each.
        let up = step.gemm_nodes()[2];
        assert_eq!((up.count, up.problem.m), (256, 8));
        step.layer.validate().unwrap();
    }
}
