//! Request-arrival processes for the continuous-batching serve loop:
//! seeded Poisson traffic and replayable trace files (DESIGN.md §15).
//!
//! Arrivals are *plans*, not live streams: a plan is materialized up
//! front (timestamps quantized to whole virtual-clock microseconds, so
//! every downstream scheduling decision is integer-exact), can be saved
//! to / loaded from a JSON trace file, and replays bit-identically — the
//! seed-replay determinism property in `tests/serve_load.rs` and the
//! `BENCH_serve.json` mirror both lean on this.
//!
//! Prompt token *values* are a pure keyed hash of (request id, position),
//! not PRNG draws, so a trace that stores only lengths still replays the
//! exact token stream.

use crate::util::json::Json;
use crate::util::prng::Rng;

/// One planned request arrival on the virtual clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// Virtual-clock arrival time (µs).
    pub at_us: u64,
    /// Prompt length in tokens (≥ 2: at least one prefill + one decode).
    pub prompt_len: usize,
    /// Output budget in tokens.
    pub max_new_tokens: usize,
}

/// A materialized arrival schedule.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ArrivalPlan {
    pub arrivals: Vec<Arrival>,
}

/// Deterministic prompt token for (request, position): a splitmix-style
/// hash into [1, vocab - 1], matching the generator's "never 0 or the
/// top id" convention.
pub fn prompt_token(request_id: u64, position: usize, vocab: usize) -> i32 {
    let mut z = request_id
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add((position as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F))
        .wrapping_add(0xD6E8_FEB8_6659_FD93);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (1 + (z % (vocab.max(3) as u64 - 2))) as i32
}

impl ArrivalPlan {
    /// Seeded Poisson arrivals: exponential gaps with the given mean,
    /// rounded *up* to whole microseconds (never zero, so arrival order
    /// is total), with prompt/output lengths drawn the same way the
    /// burst-mode [`crate::workload::RequestGenerator`] draws them.
    pub fn poisson(seed: u64, mean_gap_us: f64, count: usize, max_seq: usize) -> ArrivalPlan {
        let mut rng = Rng::new(seed);
        let rate = 1.0 / mean_gap_us.max(1.0);
        let mut at_us = 0u64;
        let mut arrivals = Vec::with_capacity(count);
        for _ in 0..count {
            at_us += (rng.exponential(rate).ceil() as u64).max(1);
            // Both draws clamp so lo <= hi for ANY max_seq: the prompt
            // draw's upper bound floors at the lower bound (2), and the
            // output-budget draw's upper bound floors at its lower bound
            // (inverted at small max_seq, e.g. max_seq = 7 gave lo=4 >
            // hi=3 before).  For max_seq >= 18 every range is already
            // valid, so large-seq plans are bit-identical to the old ones.
            let prompt_len = rng.usize_range(2, (max_seq / 4).max(2));
            let budget_cap = (max_seq.saturating_sub(prompt_len)).saturating_sub(1).max(1);
            let new_lo = 4.min(budget_cap);
            let new_hi = (max_seq / 2).min(budget_cap).max(new_lo);
            let max_new_tokens = rng.usize_range(new_lo, new_hi);
            arrivals.push(Arrival { at_us, prompt_len, max_new_tokens });
        }
        ArrivalPlan { arrivals }
    }

    /// Total output budget across the plan (goodput denominator bound).
    pub fn offered_tokens(&self) -> u64 {
        self.arrivals.iter().map(|a| a.max_new_tokens as u64).sum()
    }

    /// Makespan of the offered load (µs of the last arrival).
    pub fn horizon_us(&self) -> u64 {
        self.arrivals.last().map(|a| a.at_us).unwrap_or(0)
    }

    /// Serialize to the trace-file digest.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "arrivals",
            Json::arr(
                self.arrivals
                    .iter()
                    .map(|a| {
                        Json::obj(vec![
                            ("at_us", Json::num(a.at_us as f64)),
                            ("prompt_len", Json::num(a.prompt_len as f64)),
                            ("max_new_tokens", Json::num(a.max_new_tokens as f64)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }

    /// Parse a trace-file digest (arrival times must be non-decreasing).
    pub fn from_json(j: &Json) -> anyhow::Result<ArrivalPlan> {
        let mut arrivals = Vec::new();
        let mut last = 0u64;
        for a in j.req_arr("arrivals")? {
            let at_us = a.req("at_us")?.as_f64().unwrap_or(-1.0);
            anyhow::ensure!(at_us >= 0.0, "at_us must be a non-negative number");
            let at_us = at_us as u64;
            anyhow::ensure!(at_us >= last, "trace arrivals must be time-ordered");
            last = at_us;
            let prompt_len = a.req_usize("prompt_len")?;
            let max_new_tokens = a.req_usize("max_new_tokens")?;
            anyhow::ensure!(prompt_len >= 2, "prompt_len must be >= 2");
            anyhow::ensure!(max_new_tokens >= 1, "max_new_tokens must be >= 1");
            arrivals.push(Arrival { at_us, prompt_len, max_new_tokens });
        }
        Ok(ArrivalPlan { arrivals })
    }

    /// Write the plan as a replayable trace file.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))?;
        Ok(())
    }

    /// Load a trace file written by [`ArrivalPlan::save`] (or by hand).
    pub fn load(path: &std::path::Path) -> anyhow::Result<ArrivalPlan> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e:?}", path.display()))?;
        ArrivalPlan::from_json(&j)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_seed_deterministic_and_ordered() {
        let a = ArrivalPlan::poisson(7, 500.0, 64, 128);
        let b = ArrivalPlan::poisson(7, 500.0, 64, 128);
        assert_eq!(a, b);
        assert_ne!(a, ArrivalPlan::poisson(8, 500.0, 64, 128));
        let mut last = 0;
        for arr in &a.arrivals {
            assert!(arr.at_us > last, "gaps are at least 1 µs");
            last = arr.at_us;
            assert!(arr.prompt_len >= 2);
            assert!(arr.prompt_len + arr.max_new_tokens < 128);
        }
    }

    #[test]
    fn small_max_seq_plans_are_well_formed() {
        // Regression: max_seq <= 8 used to build inverted sampling ranges
        // (lo > hi) for the output budget, underflowing usize_range's
        // modulus.  Every range must now clamp so the plan stays legal.
        for max_seq in 4..=64 {
            let plan = ArrivalPlan::poisson(13, 100.0, 32, max_seq);
            assert_eq!(plan.arrivals.len(), 32);
            for a in &plan.arrivals {
                assert!(a.prompt_len >= 2, "max_seq={max_seq}");
                assert!(a.max_new_tokens >= 1, "max_seq={max_seq}");
                assert!(
                    a.prompt_len + a.max_new_tokens < max_seq.max(4),
                    "max_seq={max_seq}: prompt {} + new {} must fit",
                    a.prompt_len,
                    a.max_new_tokens
                );
            }
            assert_eq!(plan, ArrivalPlan::poisson(13, 100.0, 32, max_seq), "seed-stable");
        }
    }

    #[test]
    fn mean_gap_roughly_holds() {
        let plan = ArrivalPlan::poisson(3, 1000.0, 4000, 64);
        let mean = plan.horizon_us() as f64 / plan.arrivals.len() as f64;
        assert!((mean - 1000.0).abs() < 100.0, "mean gap {mean}");
    }

    #[test]
    fn trace_round_trips_bit_identically() {
        let plan = ArrivalPlan::poisson(11, 250.0, 32, 96);
        let back = ArrivalPlan::from_json(&plan.to_json()).unwrap();
        assert_eq!(plan, back);
        let dir = std::env::temp_dir().join("ascend_w4a16_arrivals_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        plan.save(&path).unwrap();
        assert_eq!(ArrivalPlan::load(&path).unwrap(), plan);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn bad_traces_are_rejected() {
        let j = Json::parse(
            r#"{"arrivals": [{"at_us": 5, "prompt_len": 4, "max_new_tokens": 2},
                             {"at_us": 3, "prompt_len": 4, "max_new_tokens": 2}]}"#,
        )
        .unwrap();
        assert!(ArrivalPlan::from_json(&j).is_err(), "out-of-order trace must fail");
        let j = Json::parse(r#"{"arrivals": [{"at_us": 1, "prompt_len": 1, "max_new_tokens": 2}]}"#)
            .unwrap();
        assert!(ArrivalPlan::from_json(&j).is_err(), "prompt_len < 2 must fail");
    }

    #[test]
    fn prompt_tokens_are_pure_and_in_range() {
        for id in 0..8u64 {
            for pos in 0..32usize {
                let t = prompt_token(id, pos, 512);
                assert_eq!(t, prompt_token(id, pos, 512));
                assert!((1..511).contains(&t));
            }
        }
        assert_ne!(prompt_token(1, 0, 512), prompt_token(2, 0, 512));
    }
}
