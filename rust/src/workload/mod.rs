//! Workload generators for benches, examples and tests, plus the
//! decode-layer GEMM graph, the full decode-step graph
//! ([`decode_layer`]), the causal prefill chunk graph ([`prefill`]) and
//! the serving arrival processes ([`arrivals`]).

pub mod arrivals;
pub mod decode_layer;
pub mod prefill;

pub use arrivals::{prompt_token, Arrival, ArrivalPlan};
pub use decode_layer::{
    DecodeLayer, DecodeStep, GemmKind, GemmNode, StepNode, VectorOp, VectorOpKind,
};
pub use prefill::PrefillStep;

use crate::coordinator::DecodeRequest;
use crate::kernels::GemmProblem;
use crate::model::llm::{paper_shapes, LlmShape, PAPER_BATCH_SIZES};
use crate::util::prng::Rng;

/// The full Figure 2/3 sweep: every paper shape x every batch size.
pub fn paper_sweep() -> Vec<(LlmShape, usize)> {
    let mut out = Vec::new();
    for shape in paper_shapes() {
        for &batch in &PAPER_BATCH_SIZES {
            out.push((shape, batch));
        }
    }
    out
}

/// GEMM problem for one sweep cell.
pub fn problem_for(shape: &LlmShape, batch: usize) -> GemmProblem {
    GemmProblem::new(batch, shape.n, shape.k)
}

/// Synthetic decode request stream with geometric-ish prompt lengths.
pub struct RequestGenerator {
    rng: Rng,
    vocab: usize,
    max_seq: usize,
    next_id: u64,
}

impl RequestGenerator {
    pub fn new(seed: u64, vocab: usize, max_seq: usize) -> RequestGenerator {
        RequestGenerator { rng: Rng::new(seed), vocab, max_seq, next_id: 0 }
    }

    /// One request: prompt length in [2, max_seq/4], budget in [4, max_seq/2],
    /// clamped so prompt + budget fits the cache.
    pub fn next_request(&mut self) -> DecodeRequest {
        let prompt_len = self.rng.usize_range(2, (self.max_seq / 4).max(2));
        let budget_cap = (self.max_seq - prompt_len).saturating_sub(1).max(1);
        let budget = self.rng.usize_range(4.min(budget_cap), (self.max_seq / 2).min(budget_cap));
        let prompt = (0..prompt_len)
            .map(|_| self.rng.usize_range(1, self.vocab - 1) as i32)
            .collect();
        let id = self.next_id;
        self.next_id += 1;
        DecodeRequest::new(id, prompt, budget)
    }

    /// A batch of requests.
    pub fn burst(&mut self, count: usize) -> Vec<DecodeRequest> {
        (0..count).map(|_| self.next_request()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_covers_all_cells() {
        let sweep = paper_sweep();
        assert_eq!(sweep.len(), 12 * 7);
    }

    #[test]
    fn generated_requests_validate() {
        let mut g = RequestGenerator::new(3, 512, 32);
        for _ in 0..200 {
            let r = g.next_request();
            r.validate(512, 32).unwrap();
        }
    }

    #[test]
    fn ids_are_unique() {
        let mut g = RequestGenerator::new(5, 512, 32);
        let ids: std::collections::BTreeSet<u64> =
            g.burst(50).iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), 50);
    }
}
