//! Decode-layer graph: the GEMM nodes one transformer decoder layer
//! issues per decode step, plus the full decode-step graph with the
//! non-GEMM work around them (DESIGN.md §10–§11).
//!
//! The paper profiles a single decode GEMM (the K >> N FFN
//! down-projection), but a real decode step runs four dense projections
//! per layer — QKV, attention-out, up/gate, and down — and the shapes
//! straddle the paper's K >> N boundary, so per-node strategy selection
//! through the tune cache is exactly where the autotuner pays off.
//! MoE layers replace the dense FFN pair with a routed expert fan-out
//! ([`GemmKind::MoeExpert`]): the M·topk routed (token, expert) pairs
//! group into batched small-N / large-K expert GEMMs.  [`DecodeStep`]
//! adds the non-GEMM nodes (attention score/softmax/AV, RMSNorm,
//! residuals, activation glue, MoE routing) priced by the
//! [`crate::ascend::vecpass`] bandwidth model, so the graph simulator
//! ([`crate::analysis::layer`]) predicts *full* decode-step latency, not
//! just GEMM headroom.

use crate::kernels::GemmProblem;
use crate::model::llm::{LayerGeometry, MoeGeometry};
use crate::model::Precision;
use crate::runtime::artifacts::DecodeConfig;

/// Which projection GEMM a graph node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GemmKind {
    /// Fused Q/K/V projection: `N = hidden + 2 * kv`, `K = hidden`.
    Qkv,
    /// Attention output projection: `N = hidden`, `K = hidden`.
    AttnOut,
    /// Fused up + gate projection: `N = 2 * ffn`, `K = hidden`.
    UpGate,
    /// FFN down-projection (the paper's bottleneck): `N = hidden`, `K = ffn`.
    Down,
    /// One routed expert's batched GEMM (MoE layers): the up/gate and
    /// down projections of an expert, issued once per active expert.
    MoeExpert,
}

impl GemmKind {
    /// The four dense projection nodes in issue order (MoE layers swap
    /// the FFN pair for [`GemmKind::MoeExpert`] fan-outs — see
    /// [`DecodeLayer::gemm_nodes`]).
    pub fn all() -> [GemmKind; 4] {
        [GemmKind::Qkv, GemmKind::AttnOut, GemmKind::UpGate, GemmKind::Down]
    }

    pub fn name(&self) -> &'static str {
        match self {
            GemmKind::Qkv => "qkv",
            GemmKind::AttnOut => "attn_out",
            GemmKind::UpGate => "up_gate",
            GemmKind::Down => "down",
            GemmKind::MoeExpert => "moe_expert",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<GemmKind> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "qkv" => GemmKind::Qkv,
            "attn_out" | "attnout" | "o" => GemmKind::AttnOut,
            "up_gate" | "upgate" | "up" => GemmKind::UpGate,
            "down" => GemmKind::Down,
            "moe_expert" | "moe" | "expert" => GemmKind::MoeExpert,
            other => anyhow::bail!("unknown GEMM kind '{other}'"),
        })
    }
}

/// One GEMM node of the layer graph: `count` identical GEMMs issued back
/// to back (1 for the dense projections; the active-expert count for the
/// MoE fan-out — the expert batch the chunked schedule pipelines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmNode {
    pub kind: GemmKind,
    pub problem: GemmProblem,
    pub count: usize,
}

/// One decoder layer's GEMM graph for a given decode batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeLayer {
    pub geometry: LayerGeometry,
    /// Decode batch size (the M of every dense node).
    pub batch: usize,
    /// Routed expert fan-out replacing the dense FFN pair (`None` = dense).
    pub moe: Option<MoeGeometry>,
    /// Precision family every node of this layer runs at (W4A16 unless
    /// the deployment opts the layer into W4A8).
    pub precision: Precision,
}

impl DecodeLayer {
    pub fn new(geometry: LayerGeometry, batch: usize) -> DecodeLayer {
        DecodeLayer { geometry, batch, moe: None, precision: Precision::default() }
    }

    /// Attach a routed expert fan-out (the MoE decoding scenario).
    pub fn with_moe(mut self, moe: MoeGeometry) -> DecodeLayer {
        self.moe = Some(moe);
        self
    }

    /// Run every node of the layer at `precision` (the per-layer knob the
    /// router and CLI thread down to each GEMM problem's tune-cache key).
    pub fn with_precision(mut self, precision: Precision) -> DecodeLayer {
        self.precision = precision;
        self
    }

    /// Layer graph of an AOT decode artifact's model config (the serving
    /// path; those models use vanilla MHA, so `kv = hidden`).  Configs
    /// with `moe_experts > 0` route their FFN over experts of inner
    /// width `ffn`.
    pub fn from_decode_config(cfg: &DecodeConfig, batch: usize) -> DecodeLayer {
        let layer = DecodeLayer::new(
            LayerGeometry { hidden: cfg.hidden, ffn: cfg.ffn, kv: cfg.hidden, group: cfg.group },
            batch,
        );
        if cfg.moe_experts > 0 {
            layer.with_moe(MoeGeometry {
                experts: cfg.moe_experts,
                topk: cfg.moe_topk.max(1),
                expert_ffn: cfg.ffn,
            })
        } else {
            layer
        }
    }

    /// The GEMM problem of one dense node.  Expert shapes depend on the
    /// routed batch, so they live in [`DecodeLayer::moe_nodes`] only.
    ///
    /// # Panics
    /// On [`GemmKind::MoeExpert`] — there is no single expert problem
    /// (the fan-out carries an up/gate and a down shape per expert).
    pub fn problem(&self, kind: GemmKind) -> GemmProblem {
        let g = self.geometry;
        let (n, k) = match kind {
            GemmKind::Qkv => (g.hidden + 2 * g.kv, g.hidden),
            GemmKind::AttnOut => (g.hidden, g.hidden),
            GemmKind::UpGate => (2 * g.ffn, g.hidden),
            GemmKind::Down => (g.hidden, g.ffn),
            GemmKind::MoeExpert => {
                panic!("MoeExpert has no single dense problem; use DecodeLayer::moe_nodes()")
            }
        };
        GemmProblem { m: self.batch, n, k, group: g.group, precision: self.precision }
    }

    /// The four dense projection problems in issue order (the serving
    /// shape of non-MoE layers; see [`DecodeLayer::gemm_nodes`] for the
    /// actual graph including the expert fan-out).
    pub fn problems(&self) -> [(GemmKind, GemmProblem); 4] {
        GemmKind::all().map(|kind| (kind, self.problem(kind)))
    }

    /// The expert-batch GEMM pair of a MoE layer: the up/gate and down
    /// projections one active expert runs over its routed tokens, plus
    /// how many such experts fire (`count`).
    pub fn moe_nodes(&self) -> Option<[GemmNode; 2]> {
        let moe = self.moe?;
        let g = self.geometry;
        let m = moe.tokens_per_expert(self.batch);
        let count = moe.active_experts(self.batch);
        Some([
            GemmNode {
                kind: GemmKind::MoeExpert,
                problem: GemmProblem {
                    m,
                    n: 2 * moe.expert_ffn,
                    k: g.hidden,
                    group: g.group,
                    precision: self.precision,
                },
                count,
            },
            GemmNode {
                kind: GemmKind::MoeExpert,
                problem: GemmProblem {
                    m,
                    n: g.hidden,
                    k: moe.expert_ffn,
                    group: g.group,
                    precision: self.precision,
                },
                count,
            },
        ])
    }

    /// The layer's GEMM graph in issue order: the dense projections, with
    /// the FFN pair replaced by the routed expert fan-out on MoE layers.
    pub fn gemm_nodes(&self) -> Vec<GemmNode> {
        let dense = |kind| GemmNode { kind, problem: self.problem(kind), count: 1 };
        match self.moe_nodes() {
            None => GemmKind::all().map(dense).to_vec(),
            Some([up, down]) => {
                vec![dense(GemmKind::Qkv), dense(GemmKind::AttnOut), up, down]
            }
        }
    }

    /// Every node must be a legal GEMM (group-aligned K, tile-aligned N).
    pub fn validate(&self) -> anyhow::Result<()> {
        if let Some(moe) = self.moe {
            moe.validate()?;
        }
        for node in self.gemm_nodes() {
            node.problem.validate().map_err(|e| {
                anyhow::anyhow!(
                    "{} node (M={} N={} K={} x{}): {e}",
                    node.kind.name(),
                    node.problem.m,
                    node.problem.n,
                    node.problem.k,
                    node.count
                )
            })?;
        }
        Ok(())
    }

    /// The layer's adjacent (producer reduce -> consumer dequant)
    /// co-schedule pairs (DESIGN.md §12): expert batches pair internally
    /// (`pairs = count - 1`), and each adjacent window pairs once.  This
    /// is THE pair enumeration — shared by `repro tune`'s seeding, the
    /// router's cache-only resolution and the test fixtures, so the
    /// cached pair set always matches what serving looks up.  (The step
    /// simulator prices the same pairs, at report-node granularity, in
    /// `analysis::layer::build_ledger`.)  Invalid problems are skipped —
    /// they cannot be scheduled, so they cannot be spliced.
    pub fn overlap_pairs(&self) -> Vec<OverlapPairSpec> {
        let nodes = self.gemm_nodes();
        let valid = |p: &GemmProblem| p.validate().is_ok();
        let mut out = Vec::new();
        for node in &nodes {
            if node.count > 1 && valid(&node.problem) {
                out.push(OverlapPairSpec {
                    producer: node.problem,
                    consumer: node.problem,
                    pairs: node.count - 1,
                });
            }
        }
        for w in nodes.windows(2) {
            if valid(&w[0].problem) && valid(&w[1].problem) {
                out.push(OverlapPairSpec {
                    producer: w[0].problem,
                    consumer: w[1].problem,
                    pairs: 1,
                });
            }
        }
        out
    }

    /// Packed INT4 weight bytes of the whole layer (capacity planning).
    /// MoE layers hold *every* expert resident, not just the active ones.
    pub fn packed_weight_bytes(&self) -> u64 {
        let dense = |kind| self.problem(kind).packed_weight_bytes();
        match self.moe {
            None => GemmKind::all().iter().map(|&k| dense(k)).sum(),
            Some(moe) => {
                let g = self.geometry;
                let per_expert =
                    (2 * moe.expert_ffn * g.hidden + g.hidden * moe.expert_ffn) as u64 / 2;
                dense(GemmKind::Qkv)
                    + dense(GemmKind::AttnOut)
                    + moe.experts as u64 * per_expert
            }
        }
    }
}

/// One adjacent co-schedule pair of a layer's GEMM chain: `pairs`
/// identical (producer reduce -> consumer dequant) adjacencies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OverlapPairSpec {
    pub producer: GemmProblem,
    pub consumer: GemmProblem,
    /// Adjacencies this spec covers (`count - 1` for expert-internal
    /// pairs, 1 for a window between two distinct nodes).
    pub pairs: usize,
}

/// Which non-GEMM vector pass a step node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VectorOpKind {
    /// RMSNorm over the batch activations (pre-attention and pre-FFN).
    RmsNorm,
    /// Attention scores: per-head Q · Kᵀ over the KV-cache length.
    AttnScore,
    /// Row softmax over the score matrix.
    AttnSoftmax,
    /// Attention-weighted value gather: scores · V.
    AttnAv,
    /// Residual add back into the hidden stream.
    Residual,
    /// Gated activation (SwiGLU) between up/gate and down.
    Activation,
    /// MoE router: gate logits + top-k expert selection.
    MoeRoute,
}

impl VectorOpKind {
    pub fn name(&self) -> &'static str {
        match self {
            VectorOpKind::RmsNorm => "rmsnorm",
            VectorOpKind::AttnScore => "attn_score",
            VectorOpKind::AttnSoftmax => "attn_softmax",
            VectorOpKind::AttnAv => "attn_av",
            VectorOpKind::Residual => "residual",
            VectorOpKind::Activation => "activation",
            VectorOpKind::MoeRoute => "moe_route",
        }
    }
}

/// One non-GEMM node: a whole-chip vector pass sized for the
/// [`crate::ascend::vecpass`] bandwidth model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct VectorOp {
    pub kind: VectorOpKind,
    /// Output elements the pass produces.
    pub elems: u64,
    /// SIMD operations per output element.
    pub ops_per_elem: f64,
    /// Cold HBM bytes (KV cache, router weights).
    pub hbm_bytes: u64,
    /// Activation-sized L2 traffic (reads + writes).
    pub l2_bytes: u64,
}

/// One node of the full decode-step graph.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepNode {
    Gemm(GemmNode),
    Vector(VectorOp),
}

/// The full decode-step graph of one decoder layer: the GEMM chain plus
/// attention, normalization and elementwise glue, in issue order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecodeStep {
    pub layer: DecodeLayer,
    /// KV-cache length the attention nodes read (the decode position).
    pub kv_len: usize,
    /// Attention head count (scores are priced per head).
    pub heads: usize,
}

impl DecodeStep {
    pub fn new(layer: DecodeLayer, kv_len: usize, heads: usize) -> DecodeStep {
        DecodeStep { layer, kv_len: kv_len.max(1), heads: heads.max(1) }
    }

    /// Default head count for a geometry (128-wide heads, at least one).
    pub fn default_heads(geometry: &LayerGeometry) -> usize {
        (geometry.hidden / 128).max(1)
    }

    /// All step nodes in issue order: norm → QKV → attention (score /
    /// softmax / AV) → attn-out → residual → norm → FFN or MoE fan-out →
    /// residual.  Byte/op sizes follow the f16 activation layout; KV
    /// cache reads are the cold HBM traffic of the step.
    pub fn nodes(&self) -> Vec<StepNode> {
        let g = self.layer.geometry;
        let m = self.layer.batch as u64;
        let h = g.hidden as u64;
        let kvw = g.kv as u64;
        let heads = self.heads as u64;
        let kv_len = self.kv_len as u64;
        let head_dim = g.hidden as f64 / self.heads as f64;
        let scores = m * heads * kv_len;

        let norm = StepNode::Vector(VectorOp {
            kind: VectorOpKind::RmsNorm,
            elems: m * h,
            ops_per_elem: 6.0,
            hbm_bytes: 0,
            l2_bytes: 2 * m * h * 2,
        });
        let residual = StepNode::Vector(VectorOp {
            kind: VectorOpKind::Residual,
            elems: m * h,
            ops_per_elem: 1.0,
            hbm_bytes: 0,
            l2_bytes: 3 * m * h * 2,
        });
        let gemm = |node: GemmNode| StepNode::Gemm(node);
        let dense = |kind| GemmNode { kind, problem: self.layer.problem(kind), count: 1 };

        let mut nodes = vec![
            norm,
            gemm(dense(GemmKind::Qkv)),
            // Q · Kᵀ: one `head_dim`-deep dot (2 ops each) per score; the
            // K cache is the cold read, Q and the scores stay on-chip.
            StepNode::Vector(VectorOp {
                kind: VectorOpKind::AttnScore,
                elems: scores,
                ops_per_elem: 2.0 * head_dim,
                hbm_bytes: m * kv_len * kvw * 2,
                l2_bytes: m * h * 2 + scores * 2,
            }),
            StepNode::Vector(VectorOp {
                kind: VectorOpKind::AttnSoftmax,
                elems: scores,
                ops_per_elem: 8.0,
                hbm_bytes: 0,
                l2_bytes: 2 * scores * 2,
            }),
            // scores · V: same dot depth, the V cache is the cold read.
            StepNode::Vector(VectorOp {
                kind: VectorOpKind::AttnAv,
                elems: scores,
                ops_per_elem: 2.0 * head_dim,
                hbm_bytes: m * kv_len * kvw * 2,
                l2_bytes: scores * 2 + m * h * 2,
            }),
            gemm(dense(GemmKind::AttnOut)),
            residual,
            norm,
        ];

        match self.layer.moe_nodes() {
            None => {
                let ffn = g.ffn as u64;
                nodes.push(gemm(dense(GemmKind::UpGate)));
                nodes.push(StepNode::Vector(VectorOp {
                    kind: VectorOpKind::Activation,
                    elems: m * ffn,
                    ops_per_elem: 4.0,
                    hbm_bytes: 0,
                    l2_bytes: 3 * m * ffn * 2,
                }));
                nodes.push(gemm(dense(GemmKind::Down)));
            }
            Some([up, down]) => {
                let moe = self.layer.moe.unwrap();
                let experts = moe.experts as u64;
                // Router: gate logits (one hidden-deep dot per expert per
                // token) + softmax/top-k; the gate weight is the cold read.
                nodes.push(StepNode::Vector(VectorOp {
                    kind: VectorOpKind::MoeRoute,
                    elems: m * experts,
                    ops_per_elem: 2.0 * g.hidden as f64 + 8.0,
                    hbm_bytes: h * experts * 2,
                    l2_bytes: m * h * 2 + m * experts * 2,
                }));
                nodes.push(gemm(up));
                // Gated activation over every routed token's expert slice
                // (the batched m may pad beyond the routed pairs).
                let routed = (up.count * up.problem.m) as u64;
                let ef = moe.expert_ffn as u64;
                nodes.push(StepNode::Vector(VectorOp {
                    kind: VectorOpKind::Activation,
                    elems: routed * ef,
                    ops_per_elem: 4.0,
                    hbm_bytes: 0,
                    l2_bytes: 3 * routed * ef * 2,
                }));
                nodes.push(gemm(down));
            }
        }
        nodes.push(residual);
        nodes
    }

    /// The GEMM sub-chain of the step, in issue order.
    pub fn gemm_nodes(&self) -> Vec<GemmNode> {
        self.layer.gemm_nodes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::{
        layer_geometry, moe_geometry, paper_layer_geometries, paper_moe_geometries,
        PAPER_BATCH_SIZES,
    };

    #[test]
    fn glm45_nodes_have_expected_shapes() {
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let p = |kind| layer.problem(kind);
        assert_eq!((p(GemmKind::Qkv).n, p(GemmKind::Qkv).k), (3 * 5120, 5120));
        assert_eq!((p(GemmKind::AttnOut).n, p(GemmKind::AttnOut).k), (5120, 5120));
        assert_eq!((p(GemmKind::UpGate).n, p(GemmKind::UpGate).k), (2 * 12288, 5120));
        assert_eq!((p(GemmKind::Down).n, p(GemmKind::Down).k), (5120, 12288));
        assert!(p(GemmKind::Down).k >= 2 * p(GemmKind::Down).n, "down is the K>>N node");
    }

    #[test]
    fn deepseek_uses_low_rank_kv() {
        let layer = DecodeLayer::new(layer_geometry("deepseek").unwrap(), 8);
        assert_eq!(layer.problem(GemmKind::Qkv).n, 7168 + 2 * 1536);
    }

    #[test]
    fn every_paper_geometry_validates_at_every_batch() {
        for (model, geom) in paper_layer_geometries() {
            for &batch in &PAPER_BATCH_SIZES {
                DecodeLayer::new(geom, batch)
                    .validate()
                    .unwrap_or_else(|e| panic!("{model} b={batch}: {e}"));
            }
        }
        for (model, geom, moe) in paper_moe_geometries() {
            for &batch in &PAPER_BATCH_SIZES {
                DecodeLayer::new(geom, batch)
                    .with_moe(moe)
                    .validate()
                    .unwrap_or_else(|e| panic!("{model} b={batch}: {e}"));
            }
        }
    }

    #[test]
    fn precision_threads_to_every_node() {
        let geom = layer_geometry("deepseek-moe").unwrap();
        let moe = moe_geometry("deepseek-moe").unwrap();
        let layer = DecodeLayer::new(geom, 8)
            .with_moe(moe)
            .with_precision(Precision::W4A8);
        for node in layer.gemm_nodes() {
            assert_eq!(node.problem.precision, Precision::W4A8, "{}", node.kind.name());
        }
        // Default stays the paper's W4A16 kernel.
        let dense = DecodeLayer::new(geom, 8);
        assert!(dense
            .gemm_nodes()
            .iter()
            .all(|n| n.problem.precision == Precision::W4A16));
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in GemmKind::all().into_iter().chain([GemmKind::MoeExpert]) {
            assert_eq!(GemmKind::from_name(kind.name()).unwrap(), kind);
        }
        assert!(GemmKind::from_name("bogus").is_err());
    }

    #[test]
    fn packed_bytes_sum_all_nodes() {
        let layer = DecodeLayer::new(LayerGeometry::mha(2048, 8192), 4);
        // qkv 2048x6144 + attn_out 2048x2048 + up_gate 2048x16384 + down 8192x2048
        let elems: u64 = (2048 * 6144) + (2048 * 2048) + (2048 * 16384) + (8192 * 2048);
        assert_eq!(layer.packed_weight_bytes(), elems / 2);
    }

    #[test]
    #[should_panic(expected = "MoeExpert has no single dense problem")]
    fn moe_expert_has_no_dense_problem() {
        let _ = DecodeLayer::new(LayerGeometry::mha(2048, 8192), 4).problem(GemmKind::MoeExpert);
    }

    #[test]
    fn moe_layer_swaps_ffn_pair_for_expert_fanout() {
        let geom = layer_geometry("deepseek-moe").unwrap();
        let moe = moe_geometry("deepseek-moe").unwrap();
        let layer = DecodeLayer::new(geom, 8).with_moe(moe);
        let nodes = layer.gemm_nodes();
        assert_eq!(nodes.len(), 4);
        assert_eq!(nodes[0].kind, GemmKind::Qkv);
        assert_eq!(nodes[1].kind, GemmKind::AttnOut);
        let (up, down) = (&nodes[2], &nodes[3]);
        assert_eq!((up.kind, down.kind), (GemmKind::MoeExpert, GemmKind::MoeExpert));
        // b=8 top-8: 64 active experts of one token each.
        assert_eq!((up.count, down.count), (64, 64));
        assert_eq!((up.problem.m, up.problem.n, up.problem.k), (1, 2 * 2048, 7168));
        assert_eq!((down.problem.m, down.problem.n, down.problem.k), (1, 7168, 2048));
        assert!(up.problem.k > up.problem.n, "expert GEMMs are small-N / large-K");
        layer.validate().unwrap();
        // All 256 experts stay weight-resident, not just the 64 active.
        let per_expert = (2 * 2048 * 7168 + 7168 * 2048) as u64 / 2;
        let dense = DecodeLayer::new(geom, 8);
        let attn_bytes = dense.problem(GemmKind::Qkv).packed_weight_bytes()
            + dense.problem(GemmKind::AttnOut).packed_weight_bytes();
        assert_eq!(layer.packed_weight_bytes(), attn_bytes + 256 * per_expert);
    }

    #[test]
    fn overlap_pairs_cover_windows_and_expert_internals() {
        // Dense: three adjacent windows, no internal pairs.
        let dense = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let pairs = dense.overlap_pairs();
        assert_eq!(pairs.len(), 3);
        assert!(pairs.iter().all(|p| p.pairs == 1));
        let nodes = dense.gemm_nodes();
        for (spec, w) in pairs.iter().zip(nodes.windows(2)) {
            assert_eq!((spec.producer, spec.consumer), (w[0].problem, w[1].problem));
        }
        // MoE: two expert-internal specs (count - 1 each) plus the windows.
        let moe = DecodeLayer::new(layer_geometry("deepseek-moe").unwrap(), 8)
            .with_moe(moe_geometry("deepseek-moe").unwrap());
        let pairs = moe.overlap_pairs();
        assert_eq!(pairs.len(), 5);
        let internal: Vec<_> = pairs.iter().filter(|p| p.producer == p.consumer).collect();
        assert_eq!(internal.len(), 2, "up + down expert batches pair internally");
        assert!(internal.iter().all(|p| p.pairs == 63), "b=8 top-8 -> 64 instances");
    }

    #[test]
    fn dense_gemm_nodes_match_problems() {
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let nodes = layer.gemm_nodes();
        assert_eq!(nodes.len(), 4);
        for (node, (kind, p)) in nodes.iter().zip(layer.problems()) {
            assert_eq!((node.kind, node.problem, node.count), (kind, p, 1));
        }
    }

    #[test]
    fn step_graph_orders_attention_between_qkv_and_attn_out() {
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let step = DecodeStep::new(layer, 2048, DecodeStep::default_heads(&layer.geometry));
        let names: Vec<&str> = step
            .nodes()
            .iter()
            .map(|n| match n {
                StepNode::Gemm(g) => g.kind.name(),
                StepNode::Vector(v) => v.kind.name(),
            })
            .collect();
        assert_eq!(
            names,
            vec![
                "rmsnorm", "qkv", "attn_score", "attn_softmax", "attn_av", "attn_out",
                "residual", "rmsnorm", "up_gate", "activation", "down", "residual",
            ]
        );
    }

    #[test]
    fn moe_step_graph_routes_before_the_expert_fanout() {
        let geom = layer_geometry("deepseek-moe").unwrap();
        let moe = moe_geometry("deepseek-moe").unwrap();
        let layer = DecodeLayer::new(geom, 8).with_moe(moe);
        let step = DecodeStep::new(layer, 2048, 56);
        let names: Vec<&str> = step
            .nodes()
            .iter()
            .map(|n| match n {
                StepNode::Gemm(g) => g.kind.name(),
                StepNode::Vector(v) => v.kind.name(),
            })
            .collect();
        assert_eq!(
            names,
            vec![
                "rmsnorm", "qkv", "attn_score", "attn_softmax", "attn_av", "attn_out",
                "residual", "rmsnorm", "moe_route", "moe_expert", "activation",
                "moe_expert", "residual",
            ]
        );
    }

    #[test]
    fn attention_traffic_scales_with_kv_len_and_batch() {
        let layer = DecodeLayer::new(layer_geometry("llama32").unwrap(), 8);
        let heads = DecodeStep::default_heads(&layer.geometry);
        let score_hbm = |kv_len: usize, batch: usize| {
            let step =
                DecodeStep::new(DecodeLayer::new(layer.geometry, batch), kv_len, heads);
            step.nodes()
                .iter()
                .find_map(|n| match n {
                    StepNode::Vector(v) if v.kind == VectorOpKind::AttnScore => {
                        Some(v.hbm_bytes)
                    }
                    _ => None,
                })
                .unwrap()
        };
        assert_eq!(score_hbm(4096, 8), 2 * score_hbm(2048, 8));
        assert_eq!(score_hbm(2048, 16), 2 * score_hbm(2048, 8));
        // The K cache read is batch * kv_len * kv_width * 2 bytes exactly.
        assert_eq!(score_hbm(2048, 8), (8 * 2048 * 2048 * 2) as u64);
    }
}
