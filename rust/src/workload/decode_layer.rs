//! Decode-layer GEMM graph: the four projection GEMMs one transformer
//! decoder layer issues per decode step (DESIGN.md §10).
//!
//! The paper profiles a single decode GEMM (the K >> N FFN
//! down-projection), but a real decode step runs four per layer — QKV,
//! attention-out, up/gate, and down — and the shapes straddle the paper's
//! K >> N boundary, so per-node strategy selection through the tune cache
//! is exactly where the autotuner pays off.  `DecodeLayer` enumerates the
//! nodes for a model geometry and batch; the graph simulator
//! ([`crate::analysis::layer`]) composes their traces into per-layer and
//! per-step latency, and the coordinator router resolves every node
//! through the tune cache on the serving path.

use crate::kernels::GemmProblem;
use crate::model::llm::LayerGeometry;
use crate::runtime::artifacts::DecodeConfig;

/// Which projection GEMM a graph node is.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum GemmKind {
    /// Fused Q/K/V projection: `N = hidden + 2 * kv`, `K = hidden`.
    Qkv,
    /// Attention output projection: `N = hidden`, `K = hidden`.
    AttnOut,
    /// Fused up + gate projection: `N = 2 * ffn`, `K = hidden`.
    UpGate,
    /// FFN down-projection (the paper's bottleneck): `N = hidden`, `K = ffn`.
    Down,
}

impl GemmKind {
    /// All four nodes in issue order.
    pub fn all() -> [GemmKind; 4] {
        [GemmKind::Qkv, GemmKind::AttnOut, GemmKind::UpGate, GemmKind::Down]
    }

    pub fn name(&self) -> &'static str {
        match self {
            GemmKind::Qkv => "qkv",
            GemmKind::AttnOut => "attn_out",
            GemmKind::UpGate => "up_gate",
            GemmKind::Down => "down",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<GemmKind> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "qkv" => GemmKind::Qkv,
            "attn_out" | "attnout" | "o" => GemmKind::AttnOut,
            "up_gate" | "upgate" | "up" => GemmKind::UpGate,
            "down" => GemmKind::Down,
            other => anyhow::bail!("unknown GEMM kind '{other}'"),
        })
    }
}

/// One decoder layer's GEMM graph for a given decode batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DecodeLayer {
    pub geometry: LayerGeometry,
    /// Decode batch size (the M of every node).
    pub batch: usize,
}

impl DecodeLayer {
    pub fn new(geometry: LayerGeometry, batch: usize) -> DecodeLayer {
        DecodeLayer { geometry, batch }
    }

    /// Layer graph of an AOT decode artifact's model config (the serving
    /// path; those models use vanilla MHA, so `kv = hidden`).
    pub fn from_decode_config(cfg: &DecodeConfig, batch: usize) -> DecodeLayer {
        DecodeLayer::new(
            LayerGeometry { hidden: cfg.hidden, ffn: cfg.ffn, kv: cfg.hidden, group: cfg.group },
            batch,
        )
    }

    /// The GEMM problem of one node.
    pub fn problem(&self, kind: GemmKind) -> GemmProblem {
        let g = self.geometry;
        let (n, k) = match kind {
            GemmKind::Qkv => (g.hidden + 2 * g.kv, g.hidden),
            GemmKind::AttnOut => (g.hidden, g.hidden),
            GemmKind::UpGate => (2 * g.ffn, g.hidden),
            GemmKind::Down => (g.hidden, g.ffn),
        };
        GemmProblem { m: self.batch, n, k, group: g.group }
    }

    /// All four nodes in issue order.
    pub fn problems(&self) -> [(GemmKind, GemmProblem); 4] {
        GemmKind::all().map(|kind| (kind, self.problem(kind)))
    }

    /// Every node must be a legal GEMM (group-aligned K, tile-aligned N).
    pub fn validate(&self) -> anyhow::Result<()> {
        for (kind, p) in self.problems() {
            p.validate().map_err(|e| {
                anyhow::anyhow!("{} node (M={} N={} K={}): {e}", kind.name(), p.m, p.n, p.k)
            })?;
        }
        Ok(())
    }

    /// Packed INT4 weight bytes of the whole layer (capacity planning).
    pub fn packed_weight_bytes(&self) -> u64 {
        self.problems().iter().map(|(_, p)| p.packed_weight_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::llm::{layer_geometry, paper_layer_geometries, PAPER_BATCH_SIZES};

    #[test]
    fn glm45_nodes_have_expected_shapes() {
        let layer = DecodeLayer::new(layer_geometry("glm45").unwrap(), 8);
        let p = |kind| layer.problem(kind);
        assert_eq!((p(GemmKind::Qkv).n, p(GemmKind::Qkv).k), (3 * 5120, 5120));
        assert_eq!((p(GemmKind::AttnOut).n, p(GemmKind::AttnOut).k), (5120, 5120));
        assert_eq!((p(GemmKind::UpGate).n, p(GemmKind::UpGate).k), (2 * 12288, 5120));
        assert_eq!((p(GemmKind::Down).n, p(GemmKind::Down).k), (5120, 12288));
        assert!(p(GemmKind::Down).k >= 2 * p(GemmKind::Down).n, "down is the K>>N node");
    }

    #[test]
    fn deepseek_uses_low_rank_kv() {
        let layer = DecodeLayer::new(layer_geometry("deepseek").unwrap(), 8);
        assert_eq!(layer.problem(GemmKind::Qkv).n, 7168 + 2 * 1536);
    }

    #[test]
    fn every_paper_geometry_validates_at_every_batch() {
        for (model, geom) in paper_layer_geometries() {
            for &batch in &PAPER_BATCH_SIZES {
                DecodeLayer::new(geom, batch)
                    .validate()
                    .unwrap_or_else(|e| panic!("{model} b={batch}: {e}"));
            }
        }
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in GemmKind::all() {
            assert_eq!(GemmKind::from_name(kind.name()).unwrap(), kind);
        }
        assert!(GemmKind::from_name("bogus").is_err());
    }

    #[test]
    fn packed_bytes_sum_all_nodes() {
        let layer = DecodeLayer::new(LayerGeometry::mha(2048, 8192), 4);
        // qkv 2048x6144 + attn_out 2048x2048 + up_gate 2048x16384 + down 8192x2048
        let elems: u64 = (2048 * 6144) + (2048 * 2048) + (2048 * 16384) + (8192 * 2048);
        assert_eq!(layer.packed_weight_bytes(), elems / 2);
    }
}
