//! Minimal host tensor: row-major, typed, with the comparisons the runtime
//! tests need.  This is deliberately small — the heavy numerics run inside
//! the AOT-compiled XLA executables; the host only prepares inputs and
//! checks outputs.

use crate::util::f16;

/// Element type of a host tensor / artifact parameter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F16,
    I8,
    I32,
}

impl DType {
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::I8 => 1,
        }
    }

    /// Parse the manifest's dtype name.
    pub fn from_name(name: &str) -> anyhow::Result<DType> {
        Ok(match name {
            "f32" => DType::F32,
            "f16" => DType::F16,
            "i8" => DType::I8,
            "i32" => DType::I32,
            other => anyhow::bail!("unknown dtype '{other}'"),
        })
    }
}

/// Row-major f32 host matrix (the lingua franca of the host side).
#[derive(Debug, Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl MatF32 {
    pub fn zeros(rows: usize, cols: usize) -> MatF32 {
        MatF32 { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> MatF32 {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        MatF32 { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Dense f32 GEMM (reference for artifact-output checks; not a hot path).
    pub fn matmul(&self, rhs: &MatF32) -> MatF32 {
        assert_eq!(self.cols, rhs.rows, "inner dimension mismatch");
        let mut out = MatF32::zeros(self.rows, rhs.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self.at(i, k);
                if a == 0.0 {
                    continue;
                }
                let row = &rhs.data[k * rhs.cols..(k + 1) * rhs.cols];
                let orow = &mut out.data[i * rhs.cols..(i + 1) * rhs.cols];
                for (o, &b) in orow.iter_mut().zip(row) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// GEMM with cube-core semantics: inputs rounded to f16, f32 accumulate.
    pub fn matmul_f16acc(&self, rhs: &MatF32) -> MatF32 {
        assert_eq!(self.cols, rhs.rows, "inner dimension mismatch");
        let a16: Vec<f32> = self.data.iter().map(|&x| f16::round_to_f16(x)).collect();
        let b16: Vec<f32> = rhs.data.iter().map(|&x| f16::round_to_f16(x)).collect();
        let a = MatF32::from_vec(self.rows, self.cols, a16);
        let b = MatF32::from_vec(rhs.rows, rhs.cols, b16);
        a.matmul(&b)
    }

    /// Max |a - b| over all elements (panics on shape mismatch).
    pub fn max_abs_diff(&self, other: &MatF32) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative error check with a mixed abs/rel tolerance.
    pub fn allclose(&self, other: &MatF32, rtol: f32, atol: f32) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + rtol * b.abs().max(a.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = MatF32::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = MatF32::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&b), a);
        let c = a.matmul(&a);
        assert_eq!(c.data, vec![7.0, 10.0, 15.0, 22.0]);
    }

    #[test]
    fn f16acc_rounds_inputs() {
        // 1 + 2^-12 is not representable in f16 -> rounds to 1.0 before GEMM
        let a = MatF32::from_vec(1, 1, vec![1.0 + 2.0f32.powi(-12)]);
        let b = MatF32::from_vec(1, 1, vec![1.0]);
        assert_eq!(a.matmul_f16acc(&b).data, vec![1.0]);
    }

    #[test]
    fn allclose_tolerances() {
        let a = MatF32::from_vec(1, 2, vec![1.0, 100.0]);
        let b = MatF32::from_vec(1, 2, vec![1.001, 100.1]);
        assert!(a.allclose(&b, 2e-3, 1e-6));
        assert!(!a.allclose(&b, 1e-5, 1e-6));
    }

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F16.size_bytes(), 2);
        assert_eq!(DType::from_name("i8").unwrap(), DType::I8);
        assert!(DType::from_name("bf16").is_err());
    }
}
