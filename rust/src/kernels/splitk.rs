//! **Algorithm 1** — the paper's Split-K W4A16 schedule.
//!
//! Three phases on the decoupled units:
//! 1. *Dequant* (vector cores): each AIV loads packed INT4 tiles + group
//!    parameters, dequantizes to FP16 and writes the GM workspace.
//! 2. *Split-K MMAD* (cube cores): work items `(s, m-tile, n-tile)` spread
//!    round-robin over the cube cores; each item walks its `K/S` range in
//!    `bk` steps, accumulating in L0C, then writes its FP32 partial tile to
//!    the split buffer `C_s`.  Pipelined against Phase 1 (double buffering
//!    — the paper "hides the dequantization latency in data copies").
//! 3. *Reduce* (vector cores, after a grid barrier — "wait for all AIC
//!    cores"): output tiles are partitioned over the AIVs, the S partials
//!    are summed in FP32 and cast to FP16.
//!
//! The work-item interpretation: the paper's listing iterates splits
//! serially per core with parallelism over N-tiles, but its §4.1 analysis
//! ("Split-K can more effectively partition the computational workload
//! across each cube core") only holds if the S dimension also spreads over
//! cores, as in the CUTLASS/CATLASS Split-K it cites; we follow that
//! reading (documented in DESIGN.md §6).

use crate::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, TileStep, Unit,
    WorkspacePolicy,
};

use super::{round_robin, round_robin_steps, tiling::Tiling, GemmProblem, ReduceMode};

/// Build the Phase-1 dequant phase (shared with the data-parallel and
/// chunked schedules; the former restricts it to the active cores' vector
/// units, the latter builds one per K chunk).
pub(crate) fn dequant_phase(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
    engines: usize,
    pipelined_with_prev: bool,
) -> Phase {
    let k_tiles = p.k / t.dequant_bk;
    let n_tiles = p.n / t.dequant_bn;
    let tiles = k_tiles * n_tiles;
    let elems = t.dequant_bk * t.dequant_bn;
    let step = TileStep::new(ComputeOp::Dequant { elems })
        .read(BufferClass::WeightPacked, (elems / 2) as u64)
        // One scale + one zero row (f32) per group covered by the tile.
        .read(
            BufferClass::QuantParam,
            (2 * (t.dequant_bk / p.group) * t.dequant_bn * 4) as u64,
        )
        .write(BufferClass::Workspace, (elems * 2) as u64);
    let steps_per_engine = round_robin(tiles, engines)
        .into_iter()
        .map(|items| vec![step; items.len()])
        .collect();
    let _ = machine;
    Phase {
        name: "dequant",
        unit: Unit::Vector,
        steps_per_engine,
        pipelined_with_prev,
        chunk: None,
    }
}

/// Build the Phase-3 reduce as one or more phases, shared by the splitk
/// and chunked schedules (DESIGN.md §10).
///
/// * [`ReduceMode::Barrier`] — Algorithm 1: a single vector phase behind
///   the grid barrier covering every output tile.
/// * [`ReduceMode::Pipelined`] — stream-K-style fixup: output tiles whose
///   partials have drained from the cube cores are reduced concurrently
///   with the tail MMAD waves ("reduce_stream", pipelined into the MMAD
///   group), and only the final wave — one tile per vector engine — waits
///   behind the barrier ("reduce_tail").  The stream phase is emitted
///   whenever every vector engine owns at least two tiles (`out_tiles >=
///   2 * engines`): each engine streams all but its last tile and tails
///   exactly one.  When the tiles divide evenly the overlapped total is
///   provably never slower under the group-max model (DESIGN.md §10); on
///   uneven assignments the ceil-wave engines stream one extra step — the
///   floor-wave generalization of §11 — and [`ReduceMode::Auto`]'s
///   simulate-both guarantee keeps the *served* schedule never slower.
///   Tile counts below two waves degenerate to the barrier reduce exactly.
/// * [`ReduceMode::Auto`] is resolved by the schedule entry points (both
///   variants are simulated and the faster kept), never passed here.
pub(crate) fn reduce_phases(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
    mode: ReduceMode,
) -> Vec<Phase> {
    let m_pad = p.m_padded(machine);
    let out_tiles = (m_pad / t.bm) * (p.n / t.bn);
    let elems = t.bm * t.bn;
    let step = TileStep::new(ComputeOp::Reduce { elems, terms: t.splits })
        .read(BufferClass::Partial, (t.splits * elems * 4) as u64)
        .write(BufferClass::Output, (elems * 2) as u64);
    let engines = machine.total_vector_cores();
    let assign = round_robin(out_tiles, engines);
    let streamable = mode == ReduceMode::Pipelined && out_tiles >= 2 * engines;
    if !streamable {
        return vec![Phase {
            name: "reduce",
            unit: Unit::Vector,
            steps_per_engine: assign.iter().map(|tiles| vec![step; tiles.len()]).collect(),
            pipelined_with_prev: false,
            chunk: None,
        }];
    }
    let stream: Vec<Vec<TileStep>> = assign
        .iter()
        .map(|tiles| vec![step; tiles.len() - 1])
        .collect();
    let tail: Vec<Vec<TileStep>> = assign.iter().map(|_| vec![step; 1]).collect();
    vec![
        Phase {
            name: "reduce_stream",
            unit: Unit::Vector,
            steps_per_engine: stream,
            pipelined_with_prev: true,
            chunk: None,
        },
        Phase {
            name: "reduce_tail",
            unit: Unit::Vector,
            steps_per_engine: tail,
            pipelined_with_prev: false,
            chunk: None,
        },
    ]
}

/// Build the full Split-K trace (reduce mode resolved automatically).
pub fn schedule(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
) -> anyhow::Result<KernelTrace> {
    schedule_reduce(machine, p, t, ReduceMode::Auto)
}

/// Build the full Split-K trace with an explicit reduce mode.
pub fn schedule_reduce(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
    reduce: ReduceMode,
) -> anyhow::Result<KernelTrace> {
    if reduce == ReduceMode::Auto {
        return super::resolve_reduce_auto(machine, |mode| schedule_reduce(machine, p, t, mode));
    }
    t.validate(machine, p)?;
    let m_pad = p.m_padded(machine);
    let ks = p.k / t.splits;
    let k_steps = ks / t.bk;

    // Phase 1: dequant over all vector cores.
    let p1 = dequant_phase(machine, p, t, machine.total_vector_cores(), false);

    // Phase 2: (s, m, n) items round-robin over cube cores.  With S = 1
    // there is nothing to reduce: the MTE3 casts FP32 -> FP16 on the fly
    // and writes the output directly (no partial buffers, no Phase 3),
    // which is exactly the data-parallel epilogue.
    let single_split = t.splits == 1;
    let items = t.mmad_items(machine, p);
    let a_tile = (t.bm * t.bk * 2) as u64;
    let b_tile = (t.bk * t.bn * 2) as u64;
    let c_tile = if single_split {
        (t.bm * t.bn * 2) as u64
    } else {
        (t.bm * t.bn * 4) as u64
    };
    let c_class = if single_split { BufferClass::Output } else { BufferClass::Partial };
    let mid_step = TileStep::new(ComputeOp::Mmad { m: t.bm, n: t.bn, k: t.bk })
        .with_burst((t.bn * 2) as u64)
        .read(BufferClass::Workspace, b_tile)
        .read(BufferClass::Activation, a_tile);
    let last_step = mid_step.write(c_class, c_tile);
    let steps_per_engine = round_robin_steps(items, machine.ai_cores, k_steps, mid_step, last_step);
    let p2 = Phase {
        name: "splitk_mmad",
        unit: Unit::Cube,
        steps_per_engine,
        pipelined_with_prev: true,
        chunk: None,
    };
    if single_split {
        return Ok(KernelTrace {
            name: format!("splitk_m{}_n{}_k{}_s1", p.m, p.n, p.k),
            phases: vec![p1, p2],
            workspace_bytes: p.f16_weight_bytes(),
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        });
    }

    // Phase 3: reduce the split partials into the FP16 output (streamed
    // into the MMAD group where the mode and tile count allow).
    let mut phases = vec![p1, p2];
    phases.extend(reduce_phases(machine, p, t, reduce));

    Ok(KernelTrace {
        name: format!("splitk_m{}_n{}_k{}_s{}", p.m, p.n, p.k, t.splits),
        phases,
        workspace_bytes: p.f16_weight_bytes(),
        partial_bytes: (t.splits * m_pad * p.n * 4) as u64,
        workspace_policy: WorkspacePolicy::Buffered,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::tiling;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    fn build(mm: usize, n: usize, k: usize) -> KernelTrace {
        let p = GemmProblem::new(mm, n, k);
        let t = tiling::select_splitk(&m(), &p).unwrap();
        schedule(&m(), &p, &t).unwrap()
    }

    #[test]
    fn has_three_phases_with_correct_units() {
        // N=512 starves a data-parallel grid, so the tiler must split K.
        let tr = build(16, 512, 16384);
        assert_eq!(tr.phases.len(), 3);
        assert_eq!(tr.phases[0].unit, Unit::Vector);
        assert_eq!(tr.phases[1].unit, Unit::Cube);
        assert_eq!(tr.phases[2].unit, Unit::Vector);
        assert!(tr.phases[1].pipelined_with_prev);
        assert!(!tr.phases[2].pipelined_with_prev);
    }

    #[test]
    fn covers_all_macs_exactly_once() {
        let p = GemmProblem::new(16, 2048, 7168);
        let tr = build(16, 2048, 7168);
        assert_eq!(tr.total_macs(), p.macs(&m()));
    }

    #[test]
    fn workspace_write_equals_f16_weight_bytes() {
        let p = GemmProblem::new(16, 1024, 4096);
        let tr = build(16, 1024, 4096);
        assert_eq!(
            tr.phases[0].write_bytes(BufferClass::Workspace),
            p.f16_weight_bytes()
        );
        // Phase 2 re-reads the whole workspace exactly once per M-tile row
        // (one M-tile here): the extra GM round trip of §4.2.
        assert_eq!(
            tr.phases[1].read_bytes(BufferClass::Workspace),
            p.f16_weight_bytes()
        );
    }

    #[test]
    fn packed_reads_are_quarter_of_workspace() {
        let tr = build(16, 2048, 7168);
        let packed = tr.phases[0].read_bytes(BufferClass::WeightPacked);
        let ws = tr.phases[0].write_bytes(BufferClass::Workspace);
        assert_eq!(packed * 4, ws);
    }

    #[test]
    fn partial_traffic_matches_split_count() {
        let p = GemmProblem::new(16, 1024, 8192);
        // Force an explicit multi-split tiling: the accounting must hold
        // for any S, not just the auto-selected one.
        let t = tiling::Tiling {
            splits: 4,
            ..tiling::select_splitk(&m(), &p).unwrap()
        };
        t.validate(&m(), &p).unwrap();
        let tr = schedule(&m(), &p, &t).unwrap();
        let written = tr.phases[1].write_bytes(BufferClass::Partial);
        assert_eq!(written, (t.splits * 16 * 1024 * 4) as u64);
        let read = tr.phases[2].read_bytes(BufferClass::Partial);
        assert_eq!(read, written);
    }

    #[test]
    fn simulates_clean() {
        let tr = build(8, 512, 16384);
        let r = Simulator::new(m()).run(&tr).unwrap();
        assert!(r.total_ns > 0.0);
        assert_eq!(r.groups.len(), 2, "ph1+ph2 pipelined, ph3 separate");
    }

    /// Explicit tiling whose output-tile count (192) divides the 64 vector
    /// engines evenly with three waves: the streaming gate is open.
    fn streaming_tiling() -> (GemmProblem, Tiling) {
        let p = GemmProblem::new(16, 12288, 5120);
        let t = Tiling {
            bm: 16,
            bn: 64,
            bk: 128,
            splits: 2,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        (p, t)
    }

    #[test]
    fn pipelined_reduce_streams_all_but_final_wave() {
        let (p, t) = streaming_tiling();
        let tr = schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap();
        let names: Vec<&str> = tr.phases.iter().map(|ph| ph.name).collect();
        assert_eq!(names, vec!["dequant", "splitk_mmad", "reduce_stream", "reduce_tail"]);
        let stream = &tr.phases[2];
        let tail = &tr.phases[3];
        assert!(stream.pipelined_with_prev, "stream overlaps the MMAD group");
        assert!(!tail.pipelined_with_prev, "final wave waits for the grid");
        let out_tiles = (p.m_padded(&m()) / t.bm) * (p.n / t.bn);
        let engines = m().total_vector_cores();
        assert_eq!(stream.total_steps(), out_tiles - engines);
        assert_eq!(tail.total_steps(), engines);
        // Every output tile reduced exactly once across the two phases.
        let out: u64 = tr.phases[2..]
            .iter()
            .map(|ph| ph.write_bytes(BufferClass::Output))
            .sum();
        assert_eq!(out, (p.m_padded(&m()) * p.n * 2) as u64);
    }

    #[test]
    fn pipelined_reduce_never_slower_than_barrier() {
        let machine = m();
        let sim = Simulator::new(machine.clone());
        let (p, t) = streaming_tiling();
        let pip = sim
            .run(&schedule_reduce(&machine, &p, &t, ReduceMode::Pipelined).unwrap())
            .unwrap();
        let bar = sim
            .run(&schedule_reduce(&machine, &p, &t, ReduceMode::Barrier).unwrap())
            .unwrap();
        assert!(
            pip.total_ns <= bar.total_ns * 1.000001,
            "pipelined {} slower than barrier {}",
            pip.total_ns,
            bar.total_ns
        );
        // Auto picks the winner, so the default schedule matches the min.
        let auto = sim.run(&schedule(&machine, &p, &t).unwrap()).unwrap();
        assert!(auto.total_ns <= pip.total_ns.min(bar.total_ns) * 1.000001);
    }

    #[test]
    fn pipelined_reduce_degenerates_below_two_waves() {
        // 4 output tiles over 64 engines: no streaming, the pipelined trace
        // IS the barrier trace (Algorithm 1 preserved).
        let p = GemmProblem::new(16, 1024, 8192);
        let t = Tiling {
            splits: 4,
            ..tiling::select_splitk(&m(), &p).unwrap()
        };
        let pip = schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap();
        let bar = schedule_reduce(&m(), &p, &t, ReduceMode::Barrier).unwrap();
        assert_eq!(pip.phases.len(), bar.phases.len());
        let last = pip.phases.last().unwrap();
        assert_eq!(last.name, "reduce");
        assert!(!last.pipelined_with_prev);
    }

    #[test]
    fn pipelined_reduce_streams_floor_wave_on_uneven_tiles() {
        // 224 output tiles over 64 engines (3.5 waves): the ceil engines
        // own 4 tiles and the floor engines 3; every engine streams all but
        // its last tile and tails exactly one (DESIGN.md §11).
        let p = GemmProblem::new(8, 7168, 2048);
        let t = Tiling {
            bm: 16,
            bn: 32,
            bk: 128,
            splits: 4,
            chunks: 1,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&m(), &p).unwrap();
        let out_tiles = (p.m_padded(&m()) / t.bm) * (p.n / t.bn);
        let engines = m().total_vector_cores();
        assert_eq!(out_tiles, 224);
        assert!(out_tiles % engines != 0, "shape chosen to be uneven");
        let tr = schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap();
        let names: Vec<&str> = tr.phases.iter().map(|ph| ph.name).collect();
        assert_eq!(names, vec!["dequant", "splitk_mmad", "reduce_stream", "reduce_tail"]);
        let stream = &tr.phases[2];
        let tail = &tr.phases[3];
        assert_eq!(stream.total_steps(), out_tiles - engines);
        assert_eq!(tail.total_steps(), engines);
        let lens: Vec<usize> = stream.steps_per_engine.iter().map(|s| s.len()).collect();
        assert_eq!(lens.iter().max(), Some(&3), "ceil engines stream W tiles");
        assert_eq!(lens.iter().min(), Some(&2), "floor engines stream W-1 tiles");
        // Every output tile still reduced exactly once across both phases.
        let out: u64 = tr.phases[2..]
            .iter()
            .map(|ph| ph.write_bytes(BufferClass::Output))
            .sum();
        assert_eq!(out, (p.m_padded(&m()) * p.n * 2) as u64);
    }

    #[test]
    fn schedules_expose_spliceable_sub_traces() {
        // The co-scheduler contract (DESIGN.md §12): every Split-K trace
        // with a reduce exposes its tail as the trailing barrier group,
        // and every trace opens with a weight-only dequant prologue.
        let (p, t) = streaming_tiling();
        let pip = schedule_reduce(&m(), &p, &t, ReduceMode::Pipelined).unwrap();
        let tail = pip.exposed_reduce_range().expect("streamed reduce exposes its tail wave");
        assert_eq!(tail.len(), 1);
        assert_eq!(pip.phases[tail.start].name, "reduce_tail");
        assert_eq!(pip.dequant_prologue(), Some(0));
        assert!(pip.phases[0].is_dequant());
        let bar = schedule_reduce(&m(), &p, &t, ReduceMode::Barrier).unwrap();
        let tail = bar.exposed_reduce_range().expect("barrier reduce is fully exposed");
        assert_eq!(bar.phases[tail.start].name, "reduce");
        // S = 1: no reduce anywhere, nothing exposed — and the reduce
        // step count helper agrees.
        let p1 = GemmProblem::new(8, 4096, 2048);
        let t1 = Tiling { splits: 1, ..tiling::select_splitk(&m(), &p1).unwrap() };
        t1.validate(&m(), &p1).unwrap();
        let tr = schedule(&m(), &p1, &t1).unwrap();
        assert_eq!(tr.exposed_reduce_range(), None);
        assert_eq!(tr.reduce_steps(), 0);
        assert!(bar.reduce_steps() > 0);
    }

    #[test]
    fn occupancy_raised_when_k_dominant() {
        // N=512 gives only ~2 data-parallel strips; the split factor must
        // raise cube occupancy until the MTEs saturate the L2 stream
        // (active * mte_core_bw >= l2_bw).
        let machine = m();
        let p = GemmProblem::new(8, 512, 16384);
        let t = tiling::select_splitk(&machine, &p).unwrap();
        assert!(t.splits > 1, "expected a K split, got S={}", t.splits);
        let tr = schedule(&machine, &p, &t).unwrap();
        let active = tr.phases[1].active_engines();
        assert!(
            active as f64 * machine.mte_core_bw >= machine.l2_bw,
            "occupancy {active} cannot saturate L2"
        );
    }
}
