//! Block-size and split-factor selection under L0/UB capacity constraints.
//!
//! Mirrors `python/compile/configs.select_blocks` for the Pallas side, with
//! the hardware-capacity checks the simulator cares about:
//! * Phase-2 MMAD blocks must fit L0A/L0B (double-buffered) and L0C;
//! * Phase-1 dequant tiles must fit the Unified Buffer;
//! * the K block is a multiple of the quantization group so every dequant
//!   tile maps to whole scale rows.

use crate::ascend::{cube, vector, MachineConfig};

use super::GemmProblem;

/// Complete tiling decision for one kernel execution.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Tiling {
    /// Cube MMAD block (the paper's `[m, n, k]`).
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
    /// Split-K factor S (1 = data-parallel / native).
    pub splits: usize,
    /// K-chunk count C for the chunk-pipelined schedule (1 = monolithic).
    /// Each chunk's dequanted FP16 slice is `(K/C) x N`; the chunked
    /// schedule keeps two slices live in a pinned L2 double buffer.
    pub chunks: usize,
    /// Vector-core dequant tile (Phase 1).
    pub dequant_bk: usize,
    pub dequant_bn: usize,
    /// W4A8 vector/cube rebalance knob, in percent (0..=100): the fraction
    /// of weight tiles whose per-group scale application is *deferred*
    /// from the dequant prologue into the reduce epilogue.  Deferred tiles
    /// run a cheap 1-op/elem repack in the prologue instead of the full
    /// 4-op dequant sequence; the epilogue pays the scale multiply per
    /// group instead.  Ignored (must be 0-compatible) by the W4A16
    /// schedules (DESIGN.md §16).
    pub rebalance: usize,
}

impl Tiling {
    pub fn validate(&self, machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<()> {
        let m_pad = p.m_padded(machine);
        anyhow::ensure!(
            cube::block_fits_l0(machine, self.bm, self.bn, self.bk),
            "MMAD block ({},{},{}) exceeds L0 capacity", self.bm, self.bn, self.bk
        );
        anyhow::ensure!(
            vector::dequant_tile_fits_ub(machine, self.dequant_bk, self.dequant_bn),
            "dequant tile ({},{}) exceeds UB capacity", self.dequant_bk, self.dequant_bn
        );
        anyhow::ensure!(p.k % self.splits == 0, "splits {} !| K={}", self.splits, p.k);
        let ks = p.k / self.splits;
        anyhow::ensure!(ks % self.bk == 0, "bk {} !| K/S={ks}", self.bk);
        anyhow::ensure!(m_pad % self.bm == 0, "bm {} !| M_pad={m_pad}", self.bm);
        anyhow::ensure!(p.n % self.bn == 0, "bn {} !| N={}", self.bn, p.n);
        anyhow::ensure!(self.dequant_bk % p.group == 0, "dequant bk not group-aligned");
        anyhow::ensure!(p.k % self.dequant_bk == 0 && p.n % self.dequant_bn == 0,
            "dequant tile must tile (K, N)");
        anyhow::ensure!(self.chunks >= 1, "chunk count must be positive");
        anyhow::ensure!(self.rebalance <= 100, "rebalance is a percentage (0..=100)");
        if self.chunks > 1 {
            anyhow::ensure!(p.k % self.chunks == 0, "chunks {} !| K={}", self.chunks, p.k);
            let kc = p.k / self.chunks;
            anyhow::ensure!(kc % self.splits == 0, "splits {} !| K/C={kc}", self.splits);
            anyhow::ensure!(
                (kc / self.splits) % self.bk == 0,
                "bk {} !| K/C/S={}", self.bk, kc / self.splits
            );
            anyhow::ensure!(kc % self.dequant_bk == 0, "dequant bk !| chunk extent {kc}");
        }
        Ok(())
    }

    /// Number of Phase-2 work items (s, m-tile, n-tile) for a problem.
    pub fn mmad_items(&self, machine: &MachineConfig, p: &GemmProblem) -> usize {
        self.splits * (p.m_padded(machine) / self.bm) * (p.n / self.bn)
    }
}

/// Largest power-of-two divisor of `n` that is `<= cap` (at least `floor`).
fn pow2_divisor(n: usize, cap: usize, floor: usize) -> usize {
    let mut b = cap;
    while b > floor && n % b != 0 {
        b /= 2;
    }
    b
}

/// Estimated Phase-2 cost of a candidate tiling: a two-stream transfer
/// model (workspace bytes against L2, activation re-reads + split partials
/// against HBM) with aggregate bandwidth limited by the candidate's cube
/// occupancy.  This is the tiler's internal objective — the full simulator
/// scores the resulting schedule exactly.
fn phase2_cost(machine: &MachineConfig, p: &GemmProblem, t: &Tiling) -> f64 {
    let m_pad = p.m_padded(machine);
    let items = t.mmad_items(machine, p);
    let active = items.min(machine.ai_cores).max(1) as f64;
    let agg = |shared: f64| (machine.mte_core_bw * active).min(shared);
    let ws_bytes = p.f16_weight_bytes() as f64 * (m_pad / t.bm) as f64;
    // A is re-read once per (s, m-tile, n-tile) item over its K/S range;
    // partials are written + re-read.
    let a_bytes = items as f64 * (t.bm * (p.k / t.splits) * 2) as f64;
    let partial_bytes = (t.splits * m_pad * p.n * 4 * 2) as f64;
    // Narrow B tiles read short row segments and waste DMA bandwidth.
    let eff = (t.bn as f64 * 2.0 / machine.dma_burst_bytes).min(1.0);
    let t_l2 = ws_bytes / eff / agg(machine.l2_bw);
    let t_hbm = (a_bytes / eff + partial_bytes) / agg(machine.hbm_bw);
    // S > 1 pays the Phase-3 barrier and the reduce pass; for tiny
    // problems that overhead outweighs the occupancy gain.
    let sync = if t.splits > 1 { machine.barrier_ns } else { 0.0 };
    t_l2.max(t_hbm) + sync
}

/// Tiling for Algorithm 1 (Split-K).
///
/// Candidate search over B-tile widths: for each legal `bn` the split
/// factor S doubles until `S * n_tiles * m_tiles >= ai_cores` (subject to
/// `K/S` staying group-aligned), then candidates are ranked by the
/// estimated Phase-2 cost (occupancy vs activation re-read traffic), with
/// a preference for wider tiles on near-ties — mirroring how CATLASS
/// swizzles its Split-K grid.
pub fn select_splitk(machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<Tiling> {
    p.validate()?;
    let m_pad = p.m_padded(machine);
    let bm = pow2_divisor(m_pad, 64, 16);
    let m_tiles = m_pad / bm;

    let mut best: Option<(f64, Tiling)> = None;
    for bn in [256usize, 128, 64, 32, 16] {
        if p.n % bn != 0 {
            continue;
        }
        // Largest group-divisor bk that fits L0B double-buffered.
        let mut bk = p.group.min(p.k);
        while !cube::block_fits_l0(machine, bm, bn, bk) && bk > 16 {
            bk /= 2;
        }
        let n_tiles = p.n / bn;
        let base = n_tiles * m_tiles;
        // Score every legal split factor up to full occupancy.
        let mut splits = 1;
        loop {
            let t = Tiling {
                bm,
                bn,
                bk,
                splits,
                chunks: 1,
                dequant_bk: p.group,
                dequant_bn: pow2_divisor(p.n, 256, 16),
                rebalance: 0,
            };
            if t.validate(machine, p).is_ok() {
                let score = phase2_cost(machine, p, &t);
                let better = match &best {
                    None => true,
                    // Require a >5% cost win to justify a narrower tile
                    // (wide tiles stream better on real hardware).
                    Some((best_score, best_t)) => {
                        score < best_score * 0.95
                            || (score <= *best_score && bn > best_t.bn)
                    }
                };
                if better {
                    best = Some((score, t));
                }
            }
            if splits * base >= machine.ai_cores
                || p.k % (2 * splits) != 0
                || (p.k / (2 * splits)) % p.group != 0
                || (p.k / (2 * splits)) % bk != 0
            {
                break;
            }
            splits *= 2;
        }
    }
    let (_, t) = best.ok_or_else(|| anyhow::anyhow!("no legal splitk tiling"))?;
    Ok(t)
}

/// Tiling for the native FP16 baseline ("PyTorch"): a *tuned* single-pass
/// GEMM.  Unlike the paper's fixed-tile DP W4A16 baseline, the vendor
/// FP16 GEMM picks its strip width per problem, so we search candidates
/// and take the one minimizing max(weight-transfer, compute) time.
pub fn select_fp16(machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<Tiling> {
    p.validate()?;
    let m_pad = p.m_padded(machine);
    let mut best: Option<(f64, Tiling)> = None;
    for bn in [256usize, 128, 64, 32, 16] {
        if p.n % bn != 0 {
            continue;
        }
        for bm in [128usize, 64, 32, 16] {
            if m_pad % bm != 0 {
                continue;
            }
            let mut bk = p.group.min(p.k);
            while !cube::block_fits_l0(machine, bm, bn, bk) && bk > 16 {
                bk /= 2;
            }
            let t = Tiling {
                bm,
                bn,
                bk,
                splits: 1,
                chunks: 1,
                dequant_bk: p.group,
                dequant_bn: pow2_divisor(p.n, 256, 16),
                rebalance: 0,
            };
            if t.validate(machine, p).is_err() {
                continue;
            }
            let strips = (m_pad / bm) * (p.n / bn);
            let active = strips.min(machine.ai_cores).max(1) as f64;
            let weight_bytes = p.f16_weight_bytes() as f64 * (m_pad / bm) as f64;
            let t_hbm = weight_bytes / (machine.mte_core_bw * active).min(machine.hbm_bw);
            let macs = p.macs(machine) as f64;
            let t_compute =
                machine.cycles_to_ns(macs / machine.cube_macs_per_cycle) / active;
            let score = t_hbm.max(t_compute);
            let better = match &best {
                None => true,
                Some((s, bt)) => score < s * 0.98 || (score <= *s && bn + bm > bt.bn + bt.bm),
            };
            if better {
                best = Some((score, t));
            }
        }
    }
    let (_, t) = best.ok_or_else(|| anyhow::anyhow!("no legal fp16 tiling"))?;
    Ok(t)
}

/// Tiling for the data-parallel comparator: CATLASS-style fixed 256-wide
/// output strips, full-K per strip, S = 1 (the paper's baseline kernel is
/// a fixed-template implementation, not an auto-tuned one).
pub fn select_data_parallel(machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<Tiling> {
    p.validate()?;
    let m_pad = p.m_padded(machine);
    let bn = pow2_divisor(p.n, 256, 16);
    // bk shrinks so the double-buffered B tile fits L0B: 2*bk*bn*2 <= L0B.
    let mut bk = p.group;
    while !cube::block_fits_l0(machine, 16, bn, bk) && bk > 16 {
        bk /= 2;
    }
    let bm = pow2_divisor(m_pad, 128, 16);
    let t = Tiling {
        bm,
        bn,
        bk,
        splits: 1,
        chunks: 1,
        dequant_bk: p.group,
        dequant_bn: pow2_divisor(p.n, 256, 16),
        rebalance: 0,
    };
    t.validate(machine, p)?;
    Ok(t)
}

/// Tiling for the chunk-pipelined schedule: start from the Split-K
/// decision (occupancy within a chunk obeys the same math), then pick the
/// chunk count C.
///
/// Candidates: C = 1 (which degenerates to Algorithm 1's buffered
/// handoff — best when the whole workspace fits, or when chunking would
/// move the bottleneck onto the L2 stream), and the shallowest legal C
/// whose double-buffered FP16 slice pair `2 * (K/C) * N * 2` fits the
/// retained L2 capacity (or the deepest legal C when none fits —
/// smallest slices degrade most gracefully).  The two candidates are
/// scored by the full simulator: chunk rotation trades HBM spill traffic
/// for extra L2 stream occupancy, and which side wins is exactly the
/// max-of-streams question the simulator answers.  Because C = 1 is
/// always in the candidate set, the chunked strategy never loses to the
/// heuristic Split-K schedule.
pub fn select_chunked(machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<Tiling> {
    use crate::ascend::Simulator;
    use crate::kernels::chunked;

    let base = select_splitk(machine, p)?;
    let budget = machine.l2_retention * machine.l2_bytes as f64;
    let resident = |c: usize| {
        let slice = (p.k / c) * p.n * 2;
        (slice * c.min(2)) as f64
    };
    if resident(1) <= budget {
        // The whole workspace pins: chunking could only add rotations.
        return Ok(base);
    }
    let legal = |c: usize| {
        let cand = Tiling { chunks: c, ..base };
        cand.validate(machine, p).is_ok()
    };
    let max_chunks = (p.k / base.dequant_bk).min(64);
    let mut fit: Option<usize> = None;
    let mut deepest = 1usize;
    for c in 2..=max_chunks {
        if !legal(c) {
            continue;
        }
        deepest = c;
        if resident(c) <= budget {
            fit = Some(c);
            break;
        }
    }
    let candidate = fit.unwrap_or(deepest);
    if candidate == 1 {
        return Ok(base);
    }
    let sim = Simulator::new(machine.clone());
    let mono = base; // chunks == 1
    let chunky = Tiling { chunks: candidate, ..base };
    let mono_ns = sim.run(&chunked::schedule(machine, p, &mono)?)?.total_ns;
    let chunky_ns = sim.run(&chunked::schedule(machine, p, &chunky)?)?.total_ns;
    Ok(if chunky_ns <= mono_ns { chunky } else { mono })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn splitk_increases_splits_when_n_small() {
        let small_n = select_splitk(&m(), &GemmProblem::new(16, 512, 8192)).unwrap();
        let large_n = select_splitk(&m(), &GemmProblem::new(16, 8192, 512)).unwrap();
        assert!(small_n.splits > large_n.splits,
            "{} vs {}", small_n.splits, large_n.splits);
    }

    #[test]
    fn splitk_keeps_group_alignment() {
        for (n, k) in [(512, 8192), (2048, 7168), (1024, 16384), (7680, 7680)] {
            let t = select_splitk(&m(), &GemmProblem::new(8, n, k)).unwrap();
            assert_eq!((k / t.splits) % 128, 0, "n={n} k={k}");
        }
    }

    #[test]
    fn dp_is_single_split_with_wide_strips() {
        let t = select_data_parallel(&m(), &GemmProblem::new(16, 2048, 7168)).unwrap();
        assert_eq!(t.splits, 1);
        assert_eq!(t.bn, 256);
        assert!(cube::block_fits_l0(&m(), t.bm, t.bn, t.bk));
    }

    #[test]
    fn all_paper_shapes_tile() {
        for (n, k) in [
            (2048, 2048), (8192, 2048), (2048, 8192),
            (5120, 5120), (12288, 5120), (5120, 12288),
            (7168, 7168), (2048, 7168), (7168, 2048), (1536, 7168),
            (7680, 7680), (1024, 7680),
        ] {
            for batch in [1, 2, 4, 8, 16, 32, 64] {
                let p = GemmProblem::new(batch, n, k);
                select_splitk(&m(), &p).unwrap_or_else(|e| panic!("splitk {n}x{k} m={batch}: {e}"));
                select_data_parallel(&m(), &p).unwrap_or_else(|e| panic!("dp {n}x{k} m={batch}: {e}"));
            }
        }
    }

    #[test]
    fn mmad_item_count() {
        let p = GemmProblem::new(16, 1024, 4096);
        let t = select_splitk(&m(), &p).unwrap();
        assert_eq!(t.mmad_items(&m(), &p), t.splits * (1024 / t.bn));
    }

    #[test]
    fn chunked_picks_resident_slices_for_spilling_shapes() {
        let machine = m();
        let budget = machine.l2_retention * machine.l2_bytes as f64;
        // Workspaces far beyond L2 (120+ MiB): chunking must win and the
        // chosen rotating slice pair must stay resident.
        for (n, k) in [(12288, 5120), (5120, 12288), (7168, 7168)] {
            let p = GemmProblem::new(8, n, k);
            let t = select_chunked(&machine, &p).unwrap();
            assert!(t.chunks > 1, "n={n} k={k}: expected chunking, got C={}", t.chunks);
            let slice = ((k / t.chunks) * n * 2) as f64;
            assert!(
                slice * 2.0 <= budget,
                "n={n} k={k}: C={} slice pair {} exceeds {budget}",
                t.chunks,
                slice * 2.0
            );
        }
    }

    #[test]
    fn chunked_skips_chunking_when_workspace_fits() {
        // 16 MiB of FP16 weights fit the retained 28.8 MiB outright.
        let t = select_chunked(&m(), &GemmProblem::new(8, 512, 16384)).unwrap();
        assert_eq!(t.chunks, 1);
    }

    #[test]
    fn all_paper_shapes_tile_chunked() {
        for (n, k) in [
            (2048, 2048), (8192, 2048), (2048, 8192),
            (5120, 5120), (12288, 5120), (5120, 12288),
            (7168, 7168), (2048, 7168), (7168, 2048), (1536, 7168),
            (7680, 7680), (1024, 7680),
        ] {
            for batch in [1, 8, 64] {
                let p = GemmProblem::new(batch, n, k);
                let t = select_chunked(&m(), &p)
                    .unwrap_or_else(|e| panic!("chunked {n}x{k} m={batch}: {e}"));
                t.validate(&m(), &p).unwrap();
            }
        }
    }

    #[test]
    fn chunk_validation_rejects_misaligned_counts() {
        let p = GemmProblem::new(8, 512, 16384);
        let base = select_splitk(&m(), &p).unwrap();
        let bad = Tiling { chunks: 3, ..base }; // 3 does not divide 16384
        assert!(bad.validate(&m(), &p).is_err());
    }

    #[test]
    fn rebalance_is_bounded_to_a_percentage() {
        let p = GemmProblem::new(8, 512, 16384);
        let base = select_splitk(&m(), &p).unwrap();
        assert_eq!(base.rebalance, 0, "W4A16 tilings never defer scales");
        assert!(Tiling { rebalance: 100, ..base }.validate(&m(), &p).is_ok());
        assert!(Tiling { rebalance: 101, ..base }.validate(&m(), &p).is_err());
    }
}
