//! Native FP16 x FP16 GEMM — the "PyTorch" baseline of Figure 3.
//!
//! Single-pass data-parallel GEMM over FP16 weights: every weight byte is
//! read from HBM exactly once, no dequant phase, no workspace round trip,
//! no reduce.  The weight traffic is 4x the packed INT4 bytes — that 4x is
//! the *theoretical* W4A16 speedup that the workspace round trip then eats
//! (the paper's §4.2).

use crate::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, TileStep, Unit,
    WorkspacePolicy,
};

use super::{round_robin_steps, tiling::Tiling, GemmProblem};

/// Build the native-FP16 trace.
pub fn schedule(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
) -> anyhow::Result<KernelTrace> {
    t.validate(machine, p)?;
    anyhow::ensure!(t.splits == 1, "native schedule has no K split");
    let m_pad = p.m_padded(machine);
    let strips = (m_pad / t.bm) * (p.n / t.bn);
    let k_steps = p.k / t.bk;
    let a_tile = (t.bm * t.bk * 2) as u64;
    let b_tile = (t.bk * t.bn * 2) as u64;
    let out_tile = (t.bm * t.bn * 2) as u64;
    let mid_step = TileStep::new(ComputeOp::Mmad { m: t.bm, n: t.bn, k: t.bk })
        .with_burst((t.bn * 2) as u64)
        .read(BufferClass::WeightF16, b_tile)
        .read(BufferClass::Activation, a_tile);
    let last_step = mid_step.write(BufferClass::Output, out_tile);
    let steps_per_engine =
        round_robin_steps(strips, machine.ai_cores, k_steps, mid_step, last_step);
    let phase = Phase {
        name: "fp16_mmad",
        unit: Unit::Cube,
        steps_per_engine,
        pipelined_with_prev: false,
        chunk: None,
    };
    Ok(KernelTrace {
        name: format!("fp16_m{}_n{}_k{}", p.m, p.n, p.k),
        phases: vec![phase],
        workspace_bytes: 0,
        partial_bytes: 0,
        workspace_policy: WorkspacePolicy::Buffered,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::tiling;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn single_phase_reads_weights_once() {
        let p = GemmProblem::new(16, 2048, 7168);
        let t = tiling::select_data_parallel(&m(), &p).unwrap();
        let tr = schedule(&m(), &p, &t).unwrap();
        assert_eq!(tr.phases.len(), 1);
        assert_eq!(
            tr.phases[0].read_bytes(BufferClass::WeightF16),
            p.f16_weight_bytes()
        );
        assert_eq!(tr.workspace_bytes, 0);
    }

    #[test]
    fn flat_in_m_below_cube_tile() {
        // The paper: small batches are padded to the tile, so exec time is
        // flat in M for M <= 16.
        let sim = Simulator::new(m());
        let times: Vec<f64> = [1, 2, 4, 8, 16]
            .iter()
            .map(|&batch| {
                let p = GemmProblem::new(batch, 2048, 7168);
                let t = tiling::select_data_parallel(&m(), &p).unwrap();
                sim.run(&schedule(&m(), &p, &t).unwrap()).unwrap().total_ns
            })
            .collect();
        for w in times.windows(2) {
            assert!((w[0] - w[1]).abs() < 1e-6, "{times:?}");
        }
    }

    #[test]
    fn bandwidth_bound_at_decode_shapes() {
        let p = GemmProblem::new(8, 2048, 7168);
        let t = tiling::select_data_parallel(&m(), &p).unwrap();
        let r = Simulator::new(m()).run(&schedule(&m(), &p, &t).unwrap()).unwrap();
        assert_eq!(r.groups[0].bound_by, "hbm");
    }
}
