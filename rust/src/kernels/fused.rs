//! Fused direct-path ablation — the paper's future-work hypothesis.
//!
//! "Future work should explore hardware-software co-design to enable
//! direct data paths between vector and cube units or fused instructions
//! that bypass global memory" (§5).  This schedule models that machine:
//! the cube pipeline ingests packed INT4 tiles directly (a hypothetical
//! in-pipe dequant, akin to an MTE format conversion on the L1 -> L0B
//! path), so the FP16 workspace never exists.  Split-K and the reduce
//! phase are kept so the only delta versus Algorithm 1 is the round trip —
//! Ablation A quantifies exactly the §4.2 bottleneck.

use crate::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, TileStep, Unit,
    WorkspacePolicy,
};

use super::{round_robin, round_robin_steps, tiling::Tiling, GemmProblem};

/// Build the fused-path trace.
pub fn schedule(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
) -> anyhow::Result<KernelTrace> {
    t.validate(machine, p)?;
    let m_pad = p.m_padded(machine);
    let ks = p.k / t.splits;
    let k_steps = ks / t.bk;
    let single_split = t.splits == 1;
    let items = t.mmad_items(machine, p);
    let a_tile = (t.bm * t.bk * 2) as u64;
    let b_packed_tile = (t.bk * t.bn / 2) as u64;
    let qparam_tile = (2 * (t.bk / p.group).max(1) * t.bn * 4) as u64;
    // S = 1 writes FP16 output directly (MTE3 cast), no partials/reduce.
    let c_tile = if single_split {
        (t.bm * t.bn * 2) as u64
    } else {
        (t.bm * t.bn * 4) as u64
    };
    let c_class = if single_split { BufferClass::Output } else { BufferClass::Partial };
    // Packed weights flow straight into the cube pipe; the hypothetical
    // fused conversion rides the transfer.  Weights are static, so a real
    // fused design repacks them offline into the pipe's native tile order
    // (Marlin-style) — transfers are fully contiguous.
    let mid_step = TileStep::new(ComputeOp::Mmad { m: t.bm, n: t.bn, k: t.bk })
        .read(BufferClass::WeightPacked, b_packed_tile + qparam_tile)
        .read(BufferClass::Activation, a_tile);
    let last_step = mid_step.write(c_class, c_tile);
    let steps_per_engine = round_robin_steps(items, machine.ai_cores, k_steps, mid_step, last_step);
    let p1 = Phase {
        name: "fused_mmad",
        unit: Unit::Cube,
        steps_per_engine,
        pipelined_with_prev: false,
        chunk: None,
    };
    if single_split {
        return Ok(KernelTrace {
            name: format!("fused_m{}_n{}_k{}_s1", p.m, p.n, p.k),
            phases: vec![p1],
            workspace_bytes: 0,
            partial_bytes: 0,
            workspace_policy: WorkspacePolicy::Buffered,
        });
    }

    // Reduce phase (unchanged from Algorithm 1).
    let out_tiles = (m_pad / t.bm) * (p.n / t.bn);
    let elems = t.bm * t.bn;
    let reduce_step = TileStep::new(ComputeOp::Reduce { elems, terms: t.splits })
        .read(BufferClass::Partial, (t.splits * elems * 4) as u64)
        .write(BufferClass::Output, (elems * 2) as u64);
    let steps_per_engine = round_robin(out_tiles, machine.total_vector_cores())
        .into_iter()
        .map(|items| vec![reduce_step; items.len()])
        .collect();
    let p2 = Phase {
        name: "reduce",
        unit: Unit::Vector,
        steps_per_engine,
        pipelined_with_prev: false,
        chunk: None,
    };

    Ok(KernelTrace {
        name: format!("fused_m{}_n{}_k{}_s{}", p.m, p.n, p.k, t.splits),
        phases: vec![p1, p2],
        workspace_bytes: 0,
        partial_bytes: (t.splits * m_pad * p.n * 4) as u64,
        workspace_policy: WorkspacePolicy::Buffered,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::{fp16_native, splitk, tiling};

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    #[test]
    fn no_workspace_traffic() {
        let p = GemmProblem::new(16, 2048, 7168);
        let t = tiling::select_splitk(&m(), &p).unwrap();
        let tr = schedule(&m(), &p, &t).unwrap();
        for phase in &tr.phases {
            assert_eq!(phase.read_bytes(BufferClass::Workspace), 0);
            assert_eq!(phase.write_bytes(BufferClass::Workspace), 0);
        }
        assert_eq!(tr.workspace_bytes, 0);
    }

    #[test]
    fn fused_beats_three_phase_splitk() {
        // Removing the round trip must strictly help: that is the paper's
        // whole future-work argument.
        let machine = m();
        let sim = Simulator::new(machine.clone());
        let p = GemmProblem::new(8, 2048, 7168);
        let t = tiling::select_splitk(&machine, &p).unwrap();
        let fused_ns = sim.run(&schedule(&machine, &p, &t).unwrap()).unwrap().total_ns;
        let splitk_ns = sim.run(&splitk::schedule(&machine, &p, &t).unwrap()).unwrap().total_ns;
        assert!(fused_ns < splitk_ns, "{fused_ns} !< {splitk_ns}");
    }

    #[test]
    fn fused_approaches_the_4x_promise() {
        // Against the FP16 native baseline the fused path should recover
        // most of the 4x weight-traffic reduction at decode shapes.
        let machine = m();
        let sim = Simulator::new(machine.clone());
        let p = GemmProblem::new(8, 2048, 7168);
        let t_sk = tiling::select_splitk(&machine, &p).unwrap();
        let fused_ns = sim.run(&schedule(&machine, &p, &t_sk).unwrap()).unwrap().total_ns;
        let t_dp = tiling::select_data_parallel(&machine, &p).unwrap();
        let fp16_ns = sim
            .run(&fp16_native::schedule(&machine, &p, &t_dp).unwrap())
            .unwrap()
            .total_ns;
        let speedup = fp16_ns / fused_ns;
        assert!(speedup > 1.8, "fused speedup only {speedup:.2}");
    }
}
