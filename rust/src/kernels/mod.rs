//! Kernel schedules: compile a W4A16 (or FP16) GEMM problem into a
//! simulator [`KernelTrace`](crate::ascend::KernelTrace).
//!
//! Strategies, mirroring the paper's evaluation plus this repo's additions:
//! * [`splitk`] — **Algorithm 1**: vector-core dequant into a GM workspace,
//!   Split-K cube MMAD into FP32 split buffers, vector-core reduce.
//! * [`data_parallel`] — the CATLASS-style comparator: each active AI core
//!   owns an output strip end-to-end (dequant + full-K GEMM), no K split.
//! * [`fp16_native`] — native FP16xFP16 single-pass GEMM (the "PyTorch"
//!   baseline of Figure 3).
//! * [`fused`] — the paper's future-work ablation: a hypothetical direct
//!   vector->cube path that skips the workspace round trip entirely.
//! * [`chunked`] — chunk-pipelined Split-K: K is partitioned into chunks
//!   whose dequanted FP16 slice rotates through a pinned L2 double buffer,
//!   so Workspace bytes never touch HBM (DESIGN.md §8).
//! * `Auto` — resolved per shape through the [`crate::tune`] cache.

pub mod chunked;
pub mod data_parallel;
pub mod fp16_native;
pub mod fused;
pub mod splitk;
pub mod tiling;
pub mod w4a8;

use crate::ascend::{KernelTrace, MachineConfig, TileStep};
use crate::model::quant::Precision;

/// A GEMM problem: `C[M,N] = A[M,K] @ W[K,N]` with group-quantized weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmProblem {
    /// Batch dimension (decode batch size before padding).
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Quantization group size along K.
    pub group: usize,
    /// Precision family member (weight bits x activation bits) the
    /// schedule must realize.  Defaults to the paper's W4A16.
    pub precision: Precision,
}

impl GemmProblem {
    pub fn new(m: usize, n: usize, k: usize) -> GemmProblem {
        GemmProblem { m, n, k, group: 128, precision: Precision::W4A16 }
    }

    /// The same problem at another precision (builder style).
    pub fn with_precision(self, precision: Precision) -> GemmProblem {
        GemmProblem { precision, ..self }
    }

    /// M padded to the cube tile (the hardware pads small batches).
    pub fn m_padded(&self, machine: &MachineConfig) -> usize {
        let t = machine.cube_tile;
        self.m.div_ceil(t) * t
    }

    /// Total multiply-accumulates of the padded problem.
    pub fn macs(&self, machine: &MachineConfig) -> u64 {
        (self.m_padded(machine) * self.n * self.k) as u64
    }

    /// Packed INT4 weight bytes.
    pub fn packed_weight_bytes(&self) -> u64 {
        (self.k * self.n) as u64 / 2
    }

    /// FP16 weight bytes (native baseline, and the workspace footprint).
    pub fn f16_weight_bytes(&self) -> u64 {
        (self.k * self.n * 2) as u64
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.m >= 1, "M must be positive");
        anyhow::ensure!(self.group >= 1, "group must be positive");
        anyhow::ensure!(
            self.k % self.group == 0,
            "K={} not a multiple of group={}",
            self.k,
            self.group
        );
        anyhow::ensure!(self.n % 16 == 0, "N={} not a multiple of the cube tile", self.n);
        Ok(())
    }
}

/// How Split-K partials are reduced into the FP16 output (DESIGN.md §10).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ReduceMode {
    /// Algorithm 1's listing: wait for the grid barrier, then reduce every
    /// output tile on the vector cores.
    Barrier,
    /// Stream-K-style fixup: the early waves of output tiles are reduced
    /// while the cube cores drain the tail MMAD waves; only the final wave
    /// (one tile per vector engine) stays behind the grid barrier.  Emitted
    /// only when the output-tile count divides evenly over the vector
    /// engines with at least two waves — the regime where the overlapped
    /// schedule is provably never slower (DESIGN.md §10); otherwise the
    /// trace degenerates to the barrier reduce exactly.
    Pipelined,
    /// Build both variants, simulate them, keep the faster (ties go to the
    /// pipelined trace).  This is what `schedule`/`schedule_with` serve.
    #[default]
    Auto,
}

impl ReduceMode {
    pub fn name(&self) -> &'static str {
        match self {
            ReduceMode::Barrier => "barrier",
            ReduceMode::Pipelined => "pipelined",
            ReduceMode::Auto => "auto",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<ReduceMode> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "barrier" => ReduceMode::Barrier,
            "pipelined" => ReduceMode::Pipelined,
            "auto" => ReduceMode::Auto,
            other => anyhow::bail!("unknown reduce mode '{other}'"),
        })
    }
}

/// Strategy selector used by the CLI / benches / router.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Strategy {
    SplitK,
    DataParallel,
    Fp16Native,
    Fused,
    Chunked,
    /// W4A8 Split-K: INT8 activation-quantize vector prologue, INT4 -> INT8
    /// weight conversion, INT8 MMAD at twice the MAC rate (DESIGN.md §16).
    /// Only legal for problems tagged [`Precision::W4A8`].
    W4A8,
    /// Resolved per shape through the persisted tune cache (see
    /// [`crate::tune`]); cannot be scheduled directly.
    Auto,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::SplitK => "splitk",
            Strategy::DataParallel => "data_parallel",
            Strategy::Fp16Native => "fp16_native",
            Strategy::Fused => "fused",
            Strategy::Chunked => "chunked",
            Strategy::W4A8 => "w4a8",
            Strategy::Auto => "auto",
        }
    }

    /// Parse a strategy name (case-insensitive, accepts the short aliases
    /// used by the CLI and the python manifest).
    pub fn from_name(name: &str) -> anyhow::Result<Strategy> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "splitk" | "split_k" => Strategy::SplitK,
            "dp" | "data_parallel" => Strategy::DataParallel,
            "fp16" | "fp16_native" => Strategy::Fp16Native,
            "fused" => Strategy::Fused,
            "chunked" => Strategy::Chunked,
            "w4a8" => Strategy::W4A8,
            "auto" => Strategy::Auto,
            other => anyhow::bail!("unknown strategy '{other}'"),
        })
    }

    /// Every directly schedulable strategy (excludes `Auto`).  W4A8 is
    /// listed but returns an error from its tiler for W4A16-tagged
    /// problems, so W4A16 searches see exactly the pre-existing space —
    /// the Auto-never-slower guarantee holds by construction.
    pub fn all_concrete() -> [Strategy; 6] {
        [
            Strategy::SplitK,
            Strategy::DataParallel,
            Strategy::Fp16Native,
            Strategy::Fused,
            Strategy::Chunked,
            Strategy::W4A8,
        ]
    }
}

/// Auto-select a tiling for a (problem, strategy) pair.
pub fn select_tiling(
    machine: &MachineConfig,
    problem: &GemmProblem,
    strategy: Strategy,
) -> anyhow::Result<tiling::Tiling> {
    match strategy {
        Strategy::SplitK | Strategy::Fused => tiling::select_splitk(machine, problem),
        Strategy::DataParallel => tiling::select_data_parallel(machine, problem),
        Strategy::Fp16Native => tiling::select_fp16(machine, problem),
        Strategy::Chunked => tiling::select_chunked(machine, problem),
        Strategy::W4A8 => w4a8::select_w4a8(machine, problem),
        Strategy::Auto => anyhow::bail!(
            "Strategy::Auto must be resolved through the tune cache (crate::tune)"
        ),
    }
}

/// Build the trace for a (problem, strategy) pair with auto-selected tiling.
pub fn schedule(
    machine: &MachineConfig,
    problem: &GemmProblem,
    strategy: Strategy,
) -> anyhow::Result<KernelTrace> {
    let t = select_tiling(machine, problem, strategy)?;
    schedule_with(machine, problem, strategy, &t)
}

/// Build the trace for a (problem, strategy) pair with an explicit tiling
/// (the tuner's entry point: cached winners carry their tiling).
pub fn schedule_with(
    machine: &MachineConfig,
    problem: &GemmProblem,
    strategy: Strategy,
    t: &tiling::Tiling,
) -> anyhow::Result<KernelTrace> {
    schedule_with_reduce(machine, problem, strategy, t, ReduceMode::Auto)
}

/// Build the trace with an explicit tiling *and* reduce mode.  Only the
/// Split-K family (splitk, chunked) has a reduce phase; the other
/// strategies ignore the mode.
pub fn schedule_with_reduce(
    machine: &MachineConfig,
    problem: &GemmProblem,
    strategy: Strategy,
    t: &tiling::Tiling,
    reduce: ReduceMode,
) -> anyhow::Result<KernelTrace> {
    match strategy {
        Strategy::SplitK => splitk::schedule_reduce(machine, problem, t, reduce),
        Strategy::DataParallel => data_parallel::schedule(machine, problem, t),
        Strategy::Fp16Native => fp16_native::schedule(machine, problem, t),
        Strategy::Fused => fused::schedule(machine, problem, t),
        Strategy::Chunked => chunked::schedule_reduce(machine, problem, t, reduce),
        Strategy::W4A8 => w4a8::schedule_reduce(machine, problem, t, reduce),
        Strategy::Auto => anyhow::bail!(
            "Strategy::Auto must be resolved through the tune cache (crate::tune)"
        ),
    }
}

/// Resolve `ReduceMode::Auto` for a schedule builder: build the pipelined
/// variant, and if it actually streams (a tail-only pipelined reduce IS
/// the barrier reduce), simulate it against the barrier variant and keep
/// the faster (ties go to pipelined, so the served schedule is never
/// slower than Algorithm 1's barrier reduce).
pub(crate) fn resolve_reduce_auto(
    machine: &MachineConfig,
    mut build: impl FnMut(ReduceMode) -> anyhow::Result<KernelTrace>,
) -> anyhow::Result<KernelTrace> {
    let pipelined = build(ReduceMode::Pipelined)?;
    if !pipelined.phases.iter().any(|ph| ph.name == "reduce_stream") {
        return Ok(pipelined);
    }
    let barrier = build(ReduceMode::Barrier)?;
    let sim = crate::ascend::Simulator::new(machine.clone());
    let p_ns = sim.run(&pipelined)?.total_ns;
    let b_ns = sim.run(&barrier)?.total_ns;
    Ok(if p_ns <= b_ns { pipelined } else { barrier })
}

/// Assign `items` work items round-robin over `engines` engine slots,
/// returning the item indices per engine (empty vecs for idle engines).
pub(crate) fn round_robin(items: usize, engines: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); engines];
    for item in 0..items {
        out[item % engines].push(item);
    }
    out
}

/// Expand a round-robin item assignment into per-engine step sequences:
/// each item contributes `k_steps` steps — `mid` for every step but the
/// last, `last` for the final one (the epilogue write).  Engines carry
/// only two distinct item counts (ceil/floor of the round-robin), so the
/// two sequences are built once and cloned — shared by every schedule.
pub(crate) fn round_robin_steps(
    items: usize,
    engines: usize,
    k_steps: usize,
    mid: TileStep,
    last: TileStep,
) -> Vec<Vec<TileStep>> {
    debug_assert!(k_steps >= 1, "each work item needs at least one step");
    let assign = round_robin(items, engines);
    let mut cache: [(usize, Vec<TileStep>); 2] =
        [(usize::MAX, Vec::new()), (usize::MAX, Vec::new())];
    assign
        .iter()
        .map(|engine_items| {
            let count = engine_items.len();
            if let Some((_, v)) = cache.iter().find(|(c, _)| *c == count) {
                return v.clone();
            }
            let mut steps = Vec::with_capacity(count * k_steps);
            for _ in 0..count {
                for kstep in 0..k_steps {
                    steps.push(if kstep == k_steps - 1 { last } else { mid });
                }
            }
            let slot = if cache[0].0 == usize::MAX { 0 } else { 1 };
            cache[slot] = (count, steps.clone());
            steps
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_padding_and_sizes() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(3, 2048, 7168);
        assert_eq!(p.m_padded(&m), 16);
        assert_eq!(p.packed_weight_bytes(), 7168 * 2048 / 2);
        assert_eq!(p.f16_weight_bytes(), 7168 * 2048 * 2);
        assert_eq!(p.macs(&m), 16 * 2048 * 7168);
    }

    #[test]
    fn round_robin_covers_all_items() {
        let assign = round_robin(10, 4);
        let total: usize = assign.iter().map(|v| v.len()).sum();
        assert_eq!(total, 10);
        assert_eq!(assign[0], vec![0, 4, 8]);
        assert_eq!(assign[3], vec![3, 7]);
    }

    #[test]
    fn round_robin_steps_places_epilogue_last() {
        use crate::ascend::{BufferClass, ComputeOp};
        let mid = TileStep::new(ComputeOp::Nop).read(BufferClass::Activation, 1);
        let last = mid.write(BufferClass::Output, 2);
        let steps = round_robin_steps(5, 2, 3, mid, last);
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].len(), 3 * 3, "ceil engine gets 3 items");
        assert_eq!(steps[1].len(), 2 * 3, "floor engine gets 2 items");
        for engine in &steps {
            for (i, s) in engine.iter().enumerate() {
                let is_last = i % 3 == 2;
                assert_eq!(s.write_bytes() == 2, is_last, "step {i}");
            }
        }
    }

    #[test]
    fn round_robin_steps_single_step_items_are_all_epilogues() {
        use crate::ascend::{BufferClass, ComputeOp};
        let mid = TileStep::new(ComputeOp::Nop);
        let last = TileStep::new(ComputeOp::Nop).write(BufferClass::Output, 2);
        let steps = round_robin_steps(3, 8, 1, mid, last);
        let total_writes: u64 = steps
            .iter()
            .flatten()
            .map(|s| s.write_bytes())
            .sum();
        assert_eq!(total_writes, 6);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [
            Strategy::SplitK,
            Strategy::DataParallel,
            Strategy::Fp16Native,
            Strategy::Fused,
            Strategy::Chunked,
            Strategy::W4A8,
            Strategy::Auto,
        ] {
            assert_eq!(Strategy::from_name(s.name()).unwrap(), s);
        }
        assert!(Strategy::from_name("bogus").is_err());
    }

    #[test]
    fn w4a8_strategy_rejects_w4a16_problems() {
        // W4A8 sits in all_concrete() but its tiler refuses precision
        // mismatches, so W4A16 searches see the pre-existing space only.
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 512, 16384);
        assert!(select_tiling(&m, &p, Strategy::W4A8).is_err());
        assert!(select_tiling(&m, &p.with_precision(Precision::W4A8), Strategy::W4A8).is_ok());
    }

    #[test]
    fn strategy_names_case_insensitive() {
        assert_eq!(Strategy::from_name("SplitK").unwrap(), Strategy::SplitK);
        assert_eq!(Strategy::from_name("CHUNKED").unwrap(), Strategy::Chunked);
        assert_eq!(Strategy::from_name("Auto").unwrap(), Strategy::Auto);
        assert_eq!(Strategy::from_name("DP").unwrap(), Strategy::DataParallel);
    }

    #[test]
    fn problem_validation_uses_own_group() {
        assert!(GemmProblem::new(1, 2048, 7168).validate().is_ok());
        assert!(GemmProblem::new(1, 2048, 100).validate().is_err());
        assert!(GemmProblem::new(1, 17, 256).validate().is_err());
        let coarse = GemmProblem { group: 256, ..GemmProblem::new(1, 2048, 384) };
        assert!(coarse.validate().is_err(), "K=384 not a multiple of group=256");
        let fine = GemmProblem { group: 64, ..GemmProblem::new(1, 2048, 384) };
        assert!(fine.validate().is_ok());
    }

    #[test]
    fn auto_cannot_schedule_directly() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(8, 512, 16384);
        assert!(schedule(&m, &p, Strategy::Auto).is_err());
    }
}
