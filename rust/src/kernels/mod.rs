//! Kernel schedules: compile a W4A16 (or FP16) GEMM problem into a
//! simulator [`KernelTrace`](crate::ascend::KernelTrace).
//!
//! Four strategies, mirroring the paper's evaluation:
//! * [`splitk`] — **Algorithm 1**: vector-core dequant into a GM workspace,
//!   Split-K cube MMAD into FP32 split buffers, vector-core reduce.
//! * [`data_parallel`] — the CATLASS-style comparator: each active AI core
//!   owns an output strip end-to-end (dequant + full-K GEMM), no K split.
//! * [`fp16_native`] — native FP16xFP16 single-pass GEMM (the "PyTorch"
//!   baseline of Figure 3).
//! * [`fused`] — the paper's future-work ablation: a hypothetical direct
//!   vector->cube path that skips the workspace round trip entirely.

pub mod data_parallel;
pub mod fp16_native;
pub mod fused;
pub mod splitk;
pub mod tiling;

use crate::ascend::{KernelTrace, MachineConfig};

/// A GEMM problem: `C[M,N] = A[M,K] @ W[K,N]` with group-quantized weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmProblem {
    /// Batch dimension (decode batch size before padding).
    pub m: usize,
    pub n: usize,
    pub k: usize,
    /// Quantization group size along K.
    pub group: usize,
}

impl GemmProblem {
    pub fn new(m: usize, n: usize, k: usize) -> GemmProblem {
        GemmProblem { m, n, k, group: 128 }
    }

    /// M padded to the cube tile (the hardware pads small batches).
    pub fn m_padded(&self, machine: &MachineConfig) -> usize {
        let t = machine.cube_tile;
        self.m.div_ceil(t) * t
    }

    /// Total multiply-accumulates of the padded problem.
    pub fn macs(&self, machine: &MachineConfig) -> u64 {
        (self.m_padded(machine) * self.n * self.k) as u64
    }

    /// Packed INT4 weight bytes.
    pub fn packed_weight_bytes(&self) -> u64 {
        (self.k * self.n) as u64 / 2
    }

    /// FP16 weight bytes (native baseline, and the workspace footprint).
    pub fn f16_weight_bytes(&self) -> u64 {
        (self.k * self.n * 2) as u64
    }

    pub fn validate(&self, group: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.m >= 1, "M must be positive");
        anyhow::ensure!(self.k % group == 0, "K={} not a multiple of group={group}", self.k);
        anyhow::ensure!(self.n % 16 == 0, "N={} not a multiple of the cube tile", self.n);
        Ok(())
    }
}

/// Strategy selector used by the CLI / benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    SplitK,
    DataParallel,
    Fp16Native,
    Fused,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::SplitK => "splitk",
            Strategy::DataParallel => "data_parallel",
            Strategy::Fp16Native => "fp16_native",
            Strategy::Fused => "fused",
        }
    }

    pub fn from_name(name: &str) -> anyhow::Result<Strategy> {
        Ok(match name {
            "splitk" => Strategy::SplitK,
            "dp" | "data_parallel" => Strategy::DataParallel,
            "fp16" | "fp16_native" => Strategy::Fp16Native,
            "fused" => Strategy::Fused,
            other => anyhow::bail!("unknown strategy '{other}'"),
        })
    }
}

/// Build the trace for a (problem, strategy) pair with auto-selected tiling.
pub fn schedule(
    machine: &MachineConfig,
    problem: &GemmProblem,
    strategy: Strategy,
) -> anyhow::Result<KernelTrace> {
    match strategy {
        Strategy::SplitK => {
            let t = tiling::select_splitk(machine, problem)?;
            splitk::schedule(machine, problem, &t)
        }
        Strategy::DataParallel => {
            let t = tiling::select_data_parallel(machine, problem)?;
            data_parallel::schedule(machine, problem, &t)
        }
        Strategy::Fp16Native => {
            let t = tiling::select_fp16(machine, problem)?;
            fp16_native::schedule(machine, problem, &t)
        }
        Strategy::Fused => {
            let t = tiling::select_splitk(machine, problem)?;
            fused::schedule(machine, problem, &t)
        }
    }
}

/// Assign `items` work items round-robin over `engines` engine slots,
/// returning the item indices per engine (empty vecs for idle engines).
pub(crate) fn round_robin(items: usize, engines: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); engines];
    for item in 0..items {
        out[item % engines].push(item);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn problem_padding_and_sizes() {
        let m = MachineConfig::ascend910();
        let p = GemmProblem::new(3, 2048, 7168);
        assert_eq!(p.m_padded(&m), 16);
        assert_eq!(p.packed_weight_bytes(), 7168 * 2048 / 2);
        assert_eq!(p.f16_weight_bytes(), 7168 * 2048 * 2);
        assert_eq!(p.macs(&m), 16 * 2048 * 7168);
    }

    #[test]
    fn round_robin_covers_all_items() {
        let assign = round_robin(10, 4);
        let total: usize = assign.iter().map(|v| v.len()).sum();
        assert_eq!(total, 10);
        assert_eq!(assign[0], vec![0, 4, 8]);
        assert_eq!(assign[3], vec![3, 7]);
    }

    #[test]
    fn strategy_names_round_trip() {
        for s in [Strategy::SplitK, Strategy::DataParallel, Strategy::Fp16Native, Strategy::Fused] {
            assert_eq!(Strategy::from_name(s.name()).unwrap(), s);
        }
        assert!(Strategy::from_name("bogus").is_err());
    }

    #[test]
    fn problem_validation() {
        assert!(GemmProblem::new(1, 2048, 7168).validate(128).is_ok());
        assert!(GemmProblem::new(1, 2048, 100).validate(128).is_err());
        assert!(GemmProblem::new(1, 17, 256).validate(128).is_err());
    }
}
