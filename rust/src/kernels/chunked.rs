//! Chunk-pipelined Split-K — this repo's answer to the paper's §4.2
//! bottleneck (DESIGN.md §8).
//!
//! Algorithm 1 dequantizes the *whole* `K x N` weight matrix into a GM
//! workspace before the cube cores consume it, so once the FP16 footprint
//! exceeds the retained L2 capacity the workspace round trip spills to
//! HBM — the very traffic the paper blames for capping the W4A16 speedup
//! at 1.48x.  The chunked schedule partitions K into C chunks sized so one
//! chunk's dequanted FP16 slice `(K/C) x N` fits a pinned L2 double
//! buffer, then software-pipelines the units:
//!
//! * the vector cores dequantize chunk `i+1` into one half of the rotating
//!   buffer while the cube cores run MMAD over chunk `i` from the other;
//! * each cube work item `(s, m-tile, n-tile)` keeps its FP32 accumulator
//!   live in L0C across *all* chunks (the chunk walk is just its K walk in
//!   a different order), so no extra partial traffic appears;
//! * only the rotating slice pair is ever live in GM, and the simulator's
//!   pinned-residency class serves every Workspace byte from L2 — HBM
//!   Workspace traffic is exactly zero whenever the pair fits.
//!
//! With C = 1 the schedule degenerates to Algorithm 1 exactly (same
//! phases, same buffered workspace handoff), which is what
//! `tiling::select_chunked` falls back to whenever its simulated
//! comparison says rotation would not pay — so `chunked` never loses to
//! `splitk`, it only adds the pinned fast path.
//!
//! Multi-Scale Dequant (arXiv 2605.13915) and LiquidGEMM
//! (arXiv 2509.01229) restructure the dequant->GEMM handoff the same way
//! on CUDA-class hardware; this is the decoupled-architecture rendition.

use crate::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, TileStep, Unit,
    WorkspacePolicy,
};

use super::{
    round_robin_steps,
    splitk::{dequant_phase, reduce_phases},
    tiling::Tiling,
    GemmProblem, ReduceMode,
};

/// Build the chunk-pipelined trace (reduce mode resolved automatically).
pub fn schedule(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
) -> anyhow::Result<KernelTrace> {
    schedule_reduce(machine, p, t, ReduceMode::Auto)
}

/// Build the chunk-pipelined trace with an explicit reduce mode.  The
/// cube accumulators stay live in L0C across every chunk, so physically
/// the reduce can only overlap the *tail* chunk's MMAD waves; in the
/// trace the streamed reduce phase joins the tail of the chunked
/// pipeline group, and the §7 group-granular executor prices its overlap
/// against the group's pooled streams (same-engine vector work still
/// serializes — the group sums per-stream — but cross-stream slack from
/// any chunk can hide it, the same coarse approximation the model makes
/// for dequant/MMAD overlap).  The exposed tail wave bounds the optimism
/// and `ReduceMode::Auto` keeps the never-slower guarantee model-exact.
pub fn schedule_reduce(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
    reduce: ReduceMode,
) -> anyhow::Result<KernelTrace> {
    if reduce == ReduceMode::Auto {
        return super::resolve_reduce_auto(machine, |mode| schedule_reduce(machine, p, t, mode));
    }
    t.validate(machine, p)?;
    let chunks = t.chunks.max(1);
    anyhow::ensure!(p.k % chunks == 0, "chunks {chunks} !| K={}", p.k);
    let kc = p.k / chunks;
    let m_pad = p.m_padded(machine);
    let k_steps = (kc / t.splits) / t.bk;
    anyhow::ensure!(k_steps >= 1, "chunk extent {kc} too small for S={} bk={}", t.splits, t.bk);
    let single_split = t.splits == 1;
    let items = t.mmad_items(machine, p);

    let a_tile = (t.bm * t.bk * 2) as u64;
    let b_tile = (t.bk * t.bn * 2) as u64;
    let c_tile = if single_split {
        (t.bm * t.bn * 2) as u64
    } else {
        (t.bm * t.bn * 4) as u64
    };
    let c_class = if single_split { BufferClass::Output } else { BufferClass::Partial };
    let mid_step = TileStep::new(ComputeOp::Mmad { m: t.bm, n: t.bn, k: t.bk })
        .with_burst((t.bn * 2) as u64)
        .read(BufferClass::Workspace, b_tile)
        .read(BufferClass::Activation, a_tile);
    let last_step = mid_step.write(c_class, c_tile);

    // The dequant of one chunk is exactly the Phase-1 dequant of a problem
    // whose K is the chunk extent (same group geometry, same tiles).
    let chunk_problem = GemmProblem { k: kc, ..*p };

    let mut phases: Vec<Phase> = Vec::with_capacity(2 * chunks + 1);
    for c in 0..chunks {
        let mut dq = dequant_phase(
            machine,
            &chunk_problem,
            t,
            machine.total_vector_cores(),
            c > 0, // chunk 0 opens the group; later chunks overlap MMAD
        );
        dq.name = "chunk_dequant";
        dq.chunk = Some(c as u32);
        phases.push(dq);

        // The epilogue (L0C drain) happens once, after the final chunk.
        let tail = if c == chunks - 1 { last_step } else { mid_step };
        let mm = Phase {
            name: "chunk_mmad",
            unit: Unit::Cube,
            steps_per_engine: round_robin_steps(
                items,
                machine.ai_cores,
                k_steps,
                mid_step,
                tail,
            ),
            pipelined_with_prev: true,
            chunk: Some(c as u32),
        };
        phases.push(mm);
    }

    if !single_split {
        // Reduce the S split partials (streamed into the tail of the
        // chunked group where the mode and tile count allow, otherwise
        // after a grid barrier as Algorithm 1).
        phases.extend(reduce_phases(machine, p, t, reduce));
    }

    // With C = 1 there is no rotation: the schedule IS Algorithm 1 and
    // uses its whole-buffer handoff (identical simulation, by design).
    // With C >= 2 GM only ever holds the rotating slice pair, and the
    // pinned-residency class keeps it in L2.
    let slice_bytes = (kc * p.n * 2) as u64;
    let resident_bytes = slice_bytes * chunks.min(2) as u64;
    let (workspace_bytes, workspace_policy) = if chunks > 1 {
        (resident_bytes, WorkspacePolicy::Pinned { resident_bytes })
    } else {
        (p.f16_weight_bytes(), WorkspacePolicy::Buffered)
    };
    Ok(KernelTrace {
        name: format!("chunked_m{}_n{}_k{}_s{}_c{}", p.m, p.n, p.k, t.splits, chunks),
        phases,
        workspace_bytes,
        partial_bytes: if single_split {
            0
        } else {
            (t.splits * m_pad * p.n * 4) as u64
        },
        workspace_policy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;
    use crate::kernels::{splitk, tiling, Strategy};

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    fn build(mm: usize, n: usize, k: usize) -> (GemmProblem, Tiling, KernelTrace) {
        let p = GemmProblem::new(mm, n, k);
        let t = tiling::select_chunked(&m(), &p).unwrap();
        let tr = schedule(&m(), &p, &t).unwrap();
        (p, t, tr)
    }

    #[test]
    fn phase_structure_alternates_dequant_and_mmad() {
        let (_, t, tr) = build(8, 5120, 12288);
        assert!(t.chunks > 1, "shape chosen to require chunking");
        let body: Vec<&Phase> = tr
            .phases
            .iter()
            .filter(|ph| !ph.name.starts_with("reduce"))
            .collect();
        assert_eq!(body.len(), 2 * t.chunks);
        for (i, phase) in body.iter().enumerate() {
            let expect_chunk = (i / 2) as u32;
            assert_eq!(phase.chunk, Some(expect_chunk), "phase {i}");
            if i % 2 == 0 {
                assert_eq!(phase.unit, Unit::Vector);
                assert_eq!(phase.name, "chunk_dequant");
            } else {
                assert_eq!(phase.unit, Unit::Cube);
                assert!(phase.pipelined_with_prev);
            }
        }
        // Everything up to the reduce runs as ONE pipelined group.
        assert!(body.iter().skip(1).all(|p| p.pipelined_with_prev));
    }

    #[test]
    fn covers_all_macs_exactly_once() {
        for (n, k) in [(512, 16384), (2048, 8192), (12288, 5120), (5120, 12288)] {
            let (p, _, tr) = build(16, n, k);
            assert_eq!(tr.total_macs(), p.macs(&m()), "n={n} k={k}");
        }
    }

    #[test]
    fn dequant_covers_full_weight_matrix_once() {
        let (p, _, tr) = build(8, 2048, 8192);
        let written: u64 = tr
            .phases
            .iter()
            .map(|ph| ph.write_bytes(BufferClass::Workspace))
            .sum();
        assert_eq!(written, p.f16_weight_bytes());
    }

    #[test]
    fn workspace_hbm_traffic_is_zero() {
        // The acceptance shape: M=8, N=512, K=16384 — and a spilling one.
        for (n, k) in [(512, 16384), (12288, 5120), (5120, 12288)] {
            let (_, _, tr) = build(8, n, k);
            let r = Simulator::new(m()).run(&tr).unwrap();
            let ws = r.ledger.class(BufferClass::Workspace);
            assert_eq!(ws.hbm_read, 0.0, "n={n} k={k}");
            assert_eq!(ws.hbm_write, 0.0, "n={n} k={k}");
            assert!(ws.l2_read > 0.0, "n={n} k={k}: workspace must flow through L2");
            assert_eq!(r.l2_model.workspace_hit, 1.0, "n={n} k={k}");
        }
    }

    #[test]
    fn output_written_exactly_once() {
        let (p, t, tr) = build(8, 2048, 8192);
        let per_pass = (p.m_padded(&m()) * p.n) as u64;
        if t.splits == 1 {
            let out: u64 = tr.phases.iter().map(|ph| ph.write_bytes(BufferClass::Output)).sum();
            assert_eq!(out, per_pass * 2);
        } else {
            let partial: u64 =
                tr.phases.iter().map(|ph| ph.write_bytes(BufferClass::Partial)).sum();
            assert_eq!(partial, t.splits as u64 * per_pass * 4, "one FP32 tile per split");
        }
    }

    #[test]
    fn beats_splitk_when_workspace_spills() {
        // 120 MiB of FP16 weights against a 32 MiB L2: Algorithm 1 spills
        // most of the workspace round trip to HBM, the chunked pipeline
        // keeps all of it on-chip.
        let machine = m();
        let sim = Simulator::new(machine.clone());
        let p = GemmProblem::new(8, 12288, 5120);
        let sk = sim
            .run(&splitk::schedule(&machine, &p, &tiling::select_splitk(&machine, &p).unwrap()).unwrap())
            .unwrap();
        let ck = sim
            .run(&schedule(&machine, &p, &tiling::select_chunked(&machine, &p).unwrap()).unwrap())
            .unwrap();
        assert!(
            ck.total_ns < sk.total_ns,
            "chunked {} !< splitk {}",
            ck.total_ns,
            sk.total_ns
        );
        // And the splitk run really did spill (otherwise this test is vacuous).
        assert!(sk.ledger.class(BufferClass::Workspace).hbm_total() > 0.0);
    }

    #[test]
    fn degenerates_to_splitk_when_workspace_fits() {
        // 16 MiB fits the retained L2, so C=1 and the streams match
        // Algorithm 1 exactly (no chunk-rotation overhead either).
        let machine = m();
        let sim = Simulator::new(machine.clone());
        let p = GemmProblem::new(8, 512, 16384);
        let t = tiling::select_chunked(&machine, &p).unwrap();
        assert_eq!(t.chunks, 1);
        let ck = sim.run(&schedule(&machine, &p, &t).unwrap()).unwrap();
        let sk = sim
            .run(&crate::kernels::schedule(&machine, &p, Strategy::SplitK).unwrap())
            .unwrap();
        let rel = (ck.total_ns - sk.total_ns).abs() / sk.total_ns;
        assert!(rel < 1e-9, "chunked {} vs splitk {}", ck.total_ns, sk.total_ns);
    }

    #[test]
    fn pipelined_reduce_joins_chunk_group_and_never_loses() {
        // 192 output tiles over 64 vector engines (even, three waves): the
        // streamed reduce overlaps the tail chunk's MMAD.
        let machine = m();
        let p = GemmProblem::new(8, 12288, 5120);
        let t = Tiling {
            bm: 16,
            bn: 64,
            bk: 128,
            splits: 2,
            chunks: 4,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&machine, &p).unwrap();
        let pip = schedule_reduce(&machine, &p, &t, ReduceMode::Pipelined).unwrap();
        let names: Vec<&str> = pip.phases.iter().map(|ph| ph.name).collect();
        assert_eq!(&names[names.len() - 2..], &["reduce_stream", "reduce_tail"]);
        assert!(pip.phases[pip.phases.len() - 2].pipelined_with_prev);
        let sim = Simulator::new(machine.clone());
        let pip_ns = sim.run(&pip).unwrap().total_ns;
        let bar_ns = sim
            .run(&schedule_reduce(&machine, &p, &t, ReduceMode::Barrier).unwrap())
            .unwrap()
            .total_ns;
        assert!(
            pip_ns <= bar_ns * 1.000001,
            "pipelined {pip_ns} slower than barrier {bar_ns}"
        );
        let auto_ns = sim.run(&schedule(&machine, &p, &t).unwrap()).unwrap().total_ns;
        assert!(auto_ns <= pip_ns.min(bar_ns) * 1.000001);
    }

    #[test]
    fn chunked_schedules_expose_spliceable_sub_traces() {
        // Co-scheduler contract (DESIGN.md §12): the chunk-0 dequant is
        // the prologue (weight-only, opens the trace), and whatever
        // reduce stays behind the barrier is the exposed tail — the
        // streamed reduce joins the chunk group and is NOT exposed.
        let machine = m();
        let p = GemmProblem::new(8, 12288, 5120);
        let t = Tiling {
            bm: 16,
            bn: 64,
            bk: 128,
            splits: 2,
            chunks: 4,
            dequant_bk: 128,
            dequant_bn: 256,
            rebalance: 0,
        };
        t.validate(&machine, &p).unwrap();
        let tr = schedule_reduce(&machine, &p, &t, ReduceMode::Pipelined).unwrap();
        assert_eq!(tr.dequant_prologue(), Some(0));
        assert_eq!(tr.phases[0].name, "chunk_dequant");
        assert_eq!(tr.phases[0].chunk, Some(0));
        let tail = tr.exposed_reduce_range().expect("tail wave stays exposed");
        assert!(tr.phases[tail.start..].iter().all(|ph| ph.name == "reduce_tail"));
        assert!(
            tr.phases[..tail.start].iter().any(|ph| ph.name == "reduce_stream"),
            "the streamed reduce belongs to the chunk group, not the exposed tail"
        );
    }

    #[test]
    fn simulates_clean_across_batches() {
        for batch in [1, 8, 64] {
            let (_, _, tr) = build(batch, 5120, 12288);
            let r = Simulator::new(m()).run(&tr).unwrap();
            assert!(r.total_ns > 0.0 && r.total_ns.is_finite());
        }
    }
}
