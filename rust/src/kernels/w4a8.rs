//! W4A8 Split-K: the first non-W4A16 member of the precision family
//! (DESIGN.md §16).
//!
//! The schedule keeps Algorithm 1's decoupled skeleton but moves every
//! stream to its INT8 width:
//! 1. *Weight convert* (vector, `w4a8_dequant`): packed INT4 tiles are
//!    expanded to INT8 codes in the GM workspace.  Per-group scale
//!    handling is split by the [`Tiling::rebalance`] knob: full-path
//!    tiles run the 4-op dequant sequence (scales applied here), while
//!    deferred tiles run a 1-op repack and push their scale application
//!    into the reduce epilogue — the vector/cube rebalancing lever, in
//!    the APEX/LiquidGEMM lineage.
//! 2. *Activation quantize* (vector, `act_quant`, pipelined): the FP16
//!    activations are quantized to INT8 — the new vector prologue W4A8
//!    pays for halving the activation MTE stream.
//! 3. *INT8 MMAD* (cube, `w4a8_mmad`, pipelined): Split-K work items
//!    walk their K range at the INT8 datapath's doubled MAC rate,
//!    reading INT8 weight and activation tiles (half the W4A16 bytes).
//! 4. *Reduce* (vector): the unchanged Split-K reduce machinery
//!    ([`splitk::reduce_phases`]), plus a trailing `reduce_scale` wave
//!    when `rebalance > 0` that applies the deferred per-group scales
//!    over the output tiles.
//!
//! Strategy legality: [`select_w4a8`] refuses problems not tagged
//! [`Precision::W4A8`], which is what lets the strategy sit in
//! `Strategy::all_concrete()` without widening any W4A16 search.

use crate::ascend::{
    BufferClass, ComputeOp, KernelTrace, MachineConfig, Phase, TileStep, Unit,
    WorkspacePolicy,
};
use crate::model::Precision;

use super::{round_robin, round_robin_steps, splitk, tiling, tiling::Tiling, GemmProblem, ReduceMode};

/// Number of dequant tiles whose scale application is deferred to the
/// epilogue under a rebalance percentage (floor: 0% defers none, 100%
/// defers all).
fn deferred_tiles(tiles: usize, rebalance: usize) -> usize {
    tiles * rebalance / 100
}

/// Phase 1: INT4 -> INT8 weight conversion into the GM workspace.
fn weight_convert_phase(machine: &MachineConfig, p: &GemmProblem, t: &Tiling) -> Phase {
    let k_tiles = p.k / t.dequant_bk;
    let n_tiles = p.n / t.dequant_bn;
    let tiles = k_tiles * n_tiles;
    let deferred = deferred_tiles(tiles, t.rebalance);
    let elems = t.dequant_bk * t.dequant_bn;
    let param_bytes = (2 * (t.dequant_bk / p.group) * t.dequant_bn * 4) as u64;
    // Full path: unpack + zero-point + scale (the W4A16 dequant op count).
    let full_step = TileStep::new(ComputeOp::Dequant { elems })
        .read(BufferClass::WeightPacked, (elems / 2) as u64)
        .read(BufferClass::QuantParam, param_bytes)
        .write(BufferClass::Workspace, elems as u64);
    // Deferred path: bare repack, scales applied in `reduce_scale`.
    let deferred_step = TileStep::new(ComputeOp::Cast { elems })
        .read(BufferClass::WeightPacked, (elems / 2) as u64)
        .read(BufferClass::QuantParam, param_bytes)
        .write(BufferClass::Workspace, elems as u64);
    // Tiles [0, deferred) defer, the rest run the full sequence; the
    // round-robin keeps both kinds spread over every vector engine.
    let steps_per_engine = round_robin(tiles, machine.total_vector_cores())
        .into_iter()
        .map(|items| {
            items
                .into_iter()
                .map(|i| if i < deferred { deferred_step } else { full_step })
                .collect()
        })
        .collect();
    Phase {
        name: "w4a8_dequant",
        unit: Unit::Vector,
        steps_per_engine,
        pipelined_with_prev: false,
        chunk: None,
    }
}

/// Phase 2: FP16 -> INT8 activation quantization (the W4A8 prologue).
fn act_quant_phase(machine: &MachineConfig, p: &GemmProblem, t: &Tiling) -> Phase {
    let m_pad = p.m_padded(machine);
    let rows = m_pad / 16;
    let k_tiles = p.k / t.dequant_bk;
    let tiles = rows * k_tiles;
    let elems = 16 * t.dequant_bk;
    let step = TileStep::new(ComputeOp::QuantizeAct { elems })
        .read(BufferClass::Activation, (elems * 2) as u64)
        .write(BufferClass::Workspace, elems as u64);
    let steps_per_engine = round_robin(tiles, machine.total_vector_cores())
        .into_iter()
        .map(|items| vec![step; items.len()])
        .collect();
    Phase {
        name: "act_quant",
        unit: Unit::Vector,
        steps_per_engine,
        pipelined_with_prev: true,
        chunk: None,
    }
}

/// The trailing `reduce_scale` wave applying deferred per-group scales
/// over the output tiles (only built when `rebalance > 0`).
fn reduce_scale_phase(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
    pipelined_with_prev: bool,
) -> Option<Phase> {
    let k_tiles = p.k / t.dequant_bk;
    let n_tiles = p.n / t.dequant_bn;
    let deferred = deferred_tiles(k_tiles * n_tiles, t.rebalance);
    if deferred == 0 {
        return None;
    }
    let m_pad = p.m_padded(machine);
    // One correction pass per deferred tile: its group columns scale the
    // m_pad x dequant_bn output strip.
    let elems = m_pad * t.dequant_bn * (t.dequant_bk / p.group);
    let step = TileStep::new(ComputeOp::Cast { elems })
        .read(BufferClass::Output, (m_pad * t.dequant_bn * 2) as u64)
        .read(
            BufferClass::QuantParam,
            (2 * (t.dequant_bk / p.group) * t.dequant_bn * 4) as u64,
        )
        .write(BufferClass::Output, (m_pad * t.dequant_bn * 2) as u64);
    let steps_per_engine = round_robin(deferred, machine.total_vector_cores())
        .into_iter()
        .map(|items| vec![step; items.len()])
        .collect();
    Some(Phase {
        name: "reduce_scale",
        unit: Unit::Vector,
        steps_per_engine,
        pipelined_with_prev,
        chunk: None,
    })
}

/// Build the full W4A8 trace (reduce mode resolved automatically).
pub fn schedule(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
) -> anyhow::Result<KernelTrace> {
    schedule_reduce(machine, p, t, ReduceMode::Auto)
}

/// Build the full W4A8 trace with an explicit reduce mode.
pub fn schedule_reduce(
    machine: &MachineConfig,
    p: &GemmProblem,
    t: &Tiling,
    reduce: ReduceMode,
) -> anyhow::Result<KernelTrace> {
    anyhow::ensure!(
        p.precision == Precision::W4A8,
        "w4a8 schedule requires a W4A8-tagged problem (got {})",
        p.precision.name()
    );
    if reduce == ReduceMode::Auto {
        return super::resolve_reduce_auto(machine, |mode| schedule_reduce(machine, p, t, mode));
    }
    t.validate(machine, p)?;
    let m_pad = p.m_padded(machine);
    let ks = p.k / t.splits;
    let k_steps = ks / t.bk;

    let p1 = weight_convert_phase(machine, p, t);
    let p2 = act_quant_phase(machine, p, t);

    // Phase 3: (s, m, n) items over the cube cores at INT8 widths.
    let single_split = t.splits == 1;
    let items = t.mmad_items(machine, p);
    let a_tile = (t.bm * t.bk) as u64; // INT8 activations
    let b_tile = (t.bk * t.bn) as u64; // INT8 weights
    let c_tile = if single_split {
        (t.bm * t.bn * 2) as u64
    } else {
        (t.bm * t.bn * 4) as u64
    };
    let c_class = if single_split { BufferClass::Output } else { BufferClass::Partial };
    let mid_step = TileStep::new(ComputeOp::MmadInt8 { m: t.bm, n: t.bn, k: t.bk })
        .with_burst(t.bn as u64)
        .read(BufferClass::Workspace, b_tile)
        .read(BufferClass::Workspace, a_tile);
    let last_step = mid_step.write(c_class, c_tile);
    let steps_per_engine = round_robin_steps(items, machine.ai_cores, k_steps, mid_step, last_step);
    let p3 = Phase {
        name: "w4a8_mmad",
        unit: Unit::Cube,
        steps_per_engine,
        pipelined_with_prev: true,
        chunk: None,
    };

    let mut phases = vec![p1, p2, p3];
    if !single_split {
        phases.extend(splitk::reduce_phases(machine, p, t, reduce));
    }
    // The deferred-scale wave joins the trailing barrier group when one
    // exists (keeping the exposed reduce tail pure-reduce); with S = 1
    // it becomes its own barrier group behind the MMAD drain.
    if let Some(scale) = reduce_scale_phase(machine, p, t, !single_split) {
        phases.push(scale);
    }

    // Workspace: INT8 weight codes + INT8 quantized activations.
    let workspace_bytes = (p.k * p.n) as u64 + (m_pad * p.k) as u64;
    let partial_bytes = if single_split {
        0
    } else {
        (t.splits * m_pad * p.n * 4) as u64
    };
    Ok(KernelTrace {
        name: format!("w4a8_m{}_n{}_k{}_s{}", p.m, p.n, p.k, t.splits),
        phases,
        workspace_bytes,
        partial_bytes,
        workspace_policy: WorkspacePolicy::Buffered,
    })
}

/// Tiling for the W4A8 schedule: start from the Split-K decision (the
/// occupancy math is precision-independent), then pick the rebalance
/// knob by simulating the three canonical settings (0 / 50 / 100 percent
/// deferred) and keeping the fastest.  Refuses W4A16-tagged problems so
/// the strategy never widens a W4A16 search.
pub fn select_w4a8(machine: &MachineConfig, p: &GemmProblem) -> anyhow::Result<Tiling> {
    use crate::ascend::Simulator;
    anyhow::ensure!(
        p.precision == Precision::W4A8,
        "w4a8 strategy requires a W4A8-tagged problem (got {})",
        p.precision.name()
    );
    let base = tiling::select_splitk(machine, p)?;
    let sim = Simulator::new(machine.clone());
    let mut best: Option<(f64, Tiling)> = None;
    for rebalance in [0usize, 50, 100] {
        let t = Tiling { rebalance, ..base };
        let ns = match schedule(machine, p, &t) {
            Ok(trace) => match sim.run(&trace) {
                Ok(r) => r.total_ns,
                Err(_) => continue,
            },
            Err(_) => continue,
        };
        let better = match &best {
            None => true,
            Some((b, _)) => ns < *b,
        };
        if better {
            best = Some((ns, t));
        }
    }
    let (_, t) = best.ok_or_else(|| anyhow::anyhow!("no legal w4a8 tiling"))?;
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ascend::Simulator;

    fn m() -> MachineConfig {
        MachineConfig::ascend910()
    }

    fn problem(mm: usize, n: usize, k: usize) -> GemmProblem {
        GemmProblem::new(mm, n, k).with_precision(Precision::W4A8)
    }

    fn build(mm: usize, n: usize, k: usize) -> (GemmProblem, Tiling, KernelTrace) {
        let p = problem(mm, n, k);
        let t = select_w4a8(&m(), &p).unwrap();
        let tr = schedule(&m(), &p, &t).unwrap();
        (p, t, tr)
    }

    #[test]
    fn rejects_w4a16_problems() {
        let p = GemmProblem::new(8, 512, 16384);
        assert!(select_w4a8(&m(), &p).is_err());
        let t = tiling::select_splitk(&m(), &p).unwrap();
        assert!(schedule(&m(), &p, &t).is_err());
    }

    #[test]
    fn phase_order_and_units() {
        let (_, t, tr) = build(8, 512, 16384);
        assert!(t.splits > 1, "large-K decode shape must split");
        assert_eq!(tr.phases[0].name, "w4a8_dequant");
        assert_eq!(tr.phases[0].unit, Unit::Vector);
        assert!(!tr.phases[0].pipelined_with_prev);
        assert_eq!(tr.phases[1].name, "act_quant");
        assert_eq!(tr.phases[1].unit, Unit::Vector);
        assert!(tr.phases[1].pipelined_with_prev);
        assert_eq!(tr.phases[2].name, "w4a8_mmad");
        assert_eq!(tr.phases[2].unit, Unit::Cube);
        assert!(tr.phases[2].pipelined_with_prev);
        assert!(tr.phases[3..].iter().all(|ph| ph.unit == Unit::Vector));
    }

    #[test]
    fn covers_all_macs_exactly_once() {
        let (p, _, tr) = build(8, 2048, 7168);
        assert_eq!(tr.total_macs(), p.macs(&m()));
    }

    #[test]
    fn streams_are_half_the_w4a16_widths() {
        let machine = m();
        let (p, t, tr) = build(8, 512, 16384);
        // Activations: read once at FP16 by act_quant, streamed to the
        // cube at INT8 (m_pad * K bytes per M-tile row walk).
        assert_eq!(
            tr.phases[1].read_bytes(BufferClass::Activation),
            (p.m_padded(&machine) * p.k * 2) as u64
        );
        assert_eq!(
            tr.phases[1].write_bytes(BufferClass::Workspace),
            (p.m_padded(&machine) * p.k) as u64
        );
        // Weight workspace is INT8: half the W4A16 FP16 footprint.
        assert_eq!(
            tr.phases[0].write_bytes(BufferClass::Workspace),
            (p.k * p.n) as u64
        );
        // The MMAD phase reads INT8 weight tiles + INT8 activation tiles.
        let expect_b = (t.mmad_items(&machine, &p) * (p.k / t.splits / t.bk) * t.bk * t.bn) as u64;
        let expect_a = (t.mmad_items(&machine, &p) * (p.k / t.splits / t.bk) * t.bm * t.bk) as u64;
        assert_eq!(tr.phases[2].read_bytes(BufferClass::Workspace), expect_a + expect_b);
    }

    #[test]
    fn rebalance_moves_vector_work_into_the_epilogue() {
        let machine = m();
        let p = problem(8, 512, 16384);
        let base = tiling::select_splitk(&machine, &p).unwrap();
        let full = schedule(&machine, &p, &Tiling { rebalance: 0, ..base }).unwrap();
        let deferred = schedule(&machine, &p, &Tiling { rebalance: 100, ..base }).unwrap();
        assert!(full.phases.iter().all(|ph| ph.name != "reduce_scale"));
        assert_eq!(deferred.phases.last().unwrap().name, "reduce_scale");
        // The prologue gets cheaper (Cast vs Dequant) tile for tile.
        let prologue_ops = |tr: &KernelTrace| -> usize {
            tr.phases[0]
                .steps_per_engine
                .iter()
                .flatten()
                .filter(|s| matches!(s.compute, ComputeOp::Dequant { .. }))
                .count()
        };
        assert!(prologue_ops(&full) > 0);
        assert_eq!(prologue_ops(&deferred), 0, "100% defers every tile");
        // Both settings still cover every MAC.
        assert_eq!(full.total_macs(), deferred.total_macs());
    }

    #[test]
    fn half_rebalance_splits_the_prologue() {
        let machine = m();
        let p = problem(8, 2048, 7168);
        let base = tiling::select_splitk(&machine, &p).unwrap();
        let tr = schedule(&machine, &p, &Tiling { rebalance: 50, ..base }).unwrap();
        let tiles = (p.k / base.dequant_bk) * (p.n / base.dequant_bn);
        let casts: usize = tr.phases[0]
            .steps_per_engine
            .iter()
            .flatten()
            .filter(|s| matches!(s.compute, ComputeOp::Cast { .. }))
            .count();
        assert_eq!(casts, tiles / 2);
        assert_eq!(tr.phases.last().unwrap().name, "reduce_scale");
    }

    #[test]
    fn simulates_clean_and_exposes_splice_tags() {
        let (_, _, tr) = build(8, 512, 16384);
        let r = Simulator::new(m()).run(&tr).unwrap();
        assert!(r.total_ns > 0.0);
        // The weight-convert prologue opens the trace (splice consumer).
        assert_eq!(tr.dequant_prologue(), Some(0));
        assert!(tr.phases[0].is_dequant());
        // A trailing reduce group stays exposed (splice producer) even
        // with a deferred-scale wave appended.
        let p = problem(8, 512, 16384);
        let base = tiling::select_splitk(&m(), &p).unwrap();
        let t = Tiling { rebalance: 100, ..base };
        let tr = schedule_reduce(&m(), &p, &t, ReduceMode::Barrier).unwrap();
        let range = tr.exposed_reduce_range().expect("barrier reduce + scale wave exposed");
        assert!(tr.phases[range.start..].iter().all(|ph| ph.is_reduce()));
        assert_eq!(tr.phases.last().unwrap().name, "reduce_scale");
    }

    #[test]
    fn beats_w4a16_splitk_on_large_k_decode_shapes() {
        // The headline claim: half the activation/weight streams plus the
        // doubled INT8 MAC rate must win on the K >> N decode shapes.
        let machine = m();
        let sim = Simulator::new(machine.clone());
        for (n, k) in [(512, 16384), (2048, 8192)] {
            let p8 = problem(8, n, k);
            let p16 = GemmProblem::new(8, n, k);
            let t8 = select_w4a8(&machine, &p8).unwrap();
            let w4a8_ns = sim.run(&schedule(&machine, &p8, &t8).unwrap()).unwrap().total_ns;
            let t16 = tiling::select_splitk(&machine, &p16).unwrap();
            let w4a16_ns = sim
                .run(&splitk::schedule(&machine, &p16, &t16).unwrap())
                .unwrap()
                .total_ns;
            assert!(
                w4a8_ns < w4a16_ns,
                "n={n} k={k}: w4a8 {w4a8_ns} not faster than splitk {w4a16_ns}"
            );
        }
    }
}
